//! Compare all six freezing methods on the real engine (small config):
//! throughput, κ, freeze ratio, and final loss side by side.
//!
//!     make artifacts && cargo run --release --example freeze_comparison

use timelyfreeze::engine::{train, EngineConfig};
use timelyfreeze::freeze::PhaseConfig;
use timelyfreeze::types::FreezeMethod;
use timelyfreeze::util::table::Table;

fn main() {
    let dir = std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    let mut t = Table::new(
        "real-engine comparison (8 blocks / 4 stages / 1F1B / 48 steps)",
        &["Method", "tok/s", "steady tok/s", "κ", "Frz %", "final loss"],
    );
    for method in FreezeMethod::all() {
        let mut cfg = EngineConfig::quick_defaults(dir.clone());
        cfg.steps = 48;
        cfg.phases = PhaseConfig::new(6, 14, 24);
        cfg.method = method;
        cfg.check_interval = 4;
        match train(&cfg) {
            Ok(r) => t.row(vec![
                method.name().to_string(),
                format!("{:.0}", r.throughput),
                format!("{:.0}", r.steady_throughput),
                format!("{:.3}", r.kappa()),
                format!("{:.1}", r.freeze_ratio),
                format!("{:.3}", r.final_loss),
            ]),
            Err(e) => t.row(vec![
                method.name().to_string(),
                format!("error: {e}"),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
            ]),
        }
    }
    println!("{}", t.render());
}
