//! Figure 2 walkthrough: the paper's method-overview example — monitor
//! upper/lower execution bounds, build the DAG, solve the LP, and show
//! the batch time dropping to ≈70% with an average expected freeze
//! ratio around 0.6.
//!
//!     cargo run --release --example lp_walkthrough
//!
//! The printed table is the LP's white-box output: one row per backward
//! action with its expected freeze ratio r*, the chosen duration w, and
//! the monitored bounds [w_min, w_max] it interpolates between. Reading
//! it against the Figure 2 narrative: actions on the critical path get
//! r* near the budget (their time reduction moves P_d), off-path
//! actions stay near 0 (the λ tie-breaker refuses freezing that buys no
//! time — the paper's answer to APF's over-freezing).

use timelyfreeze::graph::pipeline::PipelineDag;
use timelyfreeze::lp::{solve_freeze_lp, FreezeLpInput, DEFAULT_LAMBDA};
use timelyfreeze::schedule::Schedule;
use timelyfreeze::types::{ActionKind, ScheduleKind};
use timelyfreeze::util::table::Table;

fn main() {
    // The white-box setting of Figure 2: a small GPipe pipeline whose
    // backward actions dominate the critical path.
    let schedule = Schedule::build(ScheduleKind::GPipe, 4, 4, 1);
    let pdag = PipelineDag::from_schedule(&schedule);

    // "Monitoring" produced these bounds: backward is 2× forward and
    // ~70% of it is parameter-gradient work.
    let w_max = pdag.weights(|a| match a.kind {
        ActionKind::Forward => 1.0,
        _ => 2.0,
    });
    let w_min = pdag.weights(|a| match a.kind {
        ActionKind::Forward => 1.0,
        _ => 0.6,
    });

    println!("Phase II — Freeze Ratio Formulation (§3.2)\n");
    let sol = solve_freeze_lp(&FreezeLpInput::new(&pdag, &w_min, &w_max, 0.8, DEFAULT_LAMBDA))
        .unwrap();

    let mut t = Table::new(
        "expected freeze ratios r* per backward action",
        &["Action", "r*", "w (opt)", "[w_min, w_max]"],
    );
    for id in pdag.action_nodes() {
        let a = pdag.node_action(id).unwrap();
        if a.kind.freezable() {
            t.row(vec![
                a.to_string(),
                format!("{:.2}", sol.ratios[id]),
                format!("{:.2}", sol.w[id]),
                format!("[{:.1}, {:.1}]", w_min[id], w_max[id]),
            ]);
        }
    }
    println!("{}", t.render());
    println!("batch execution time: {:.2} → {:.2} ({:.0}% of original)",
        sol.p_d_max, sol.batch_time, 100.0 * sol.kappa());
    println!("average expected freeze ratio: {:.2}", sol.mean_freezable_ratio(&pdag));
    assert!(sol.kappa() < 0.85, "the Figure 2 setting must show a clear win");
}
