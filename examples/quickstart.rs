//! Quickstart: the TimelyFreeze pipeline in five steps — build a
//! schedule, derive its DAG, measure (here: model) action costs, solve
//! the freeze LP, and read off the expected freeze ratios and speedup.
//!
//!     cargo run --release --example quickstart

use timelyfreeze::graph::pipeline::PipelineDag;
use timelyfreeze::lp::{solve_freeze_lp, FreezeLpInput, DEFAULT_LAMBDA};
use timelyfreeze::schedule::Schedule;
use timelyfreeze::types::{ActionKind, ScheduleKind};
use timelyfreeze::viz;

fn main() {
    // 1. A 1F1B schedule over 4 GPUs and 8 microbatches.
    let schedule = Schedule::build(ScheduleKind::OneFOneB, 4, 8, 1);
    println!("schedule: {} actions across {} ranks", schedule.action_count(), schedule.ranks);

    // 2. Its execution DAG (§3.2.1).
    let pdag = PipelineDag::from_schedule(&schedule);
    println!("pipeline DAG: {} nodes, {} edges", pdag.len(), pdag.dag.edge_count());

    // 3. Monitored bounds: forward 10 ms; backward 22 ms unfrozen,
    //    9 ms fully frozen (the dgrad share, Figure 3).
    let w_max = pdag.weights(|a| match a.kind {
        ActionKind::Forward => 0.010,
        _ => 0.022,
    });
    let w_min = pdag.weights(|a| match a.kind {
        ActionKind::Forward => 0.010,
        _ => 0.009,
    });

    // 4. Solve the LP (eq. 6 with constraints [1]–[4]).
    let sol = solve_freeze_lp(&FreezeLpInput {
        pdag: &pdag,
        w_min: &w_min,
        w_max: &w_max,
        r_max: 0.8,
        lambda: DEFAULT_LAMBDA,
    })
    .expect("LP is always feasible");

    // 5. Results.
    println!("batch time: {:.1} ms → {:.1} ms (κ = {:.3})",
        sol.p_d_max * 1e3, sol.batch_time * 1e3, sol.kappa());
    println!("mean expected freeze ratio r̄* = {:.2}", sol.mean_freezable_ratio(&pdag));

    // Bonus: draw the optimized pipeline.
    let starts = pdag.start_times(&sol.w);
    let blocks: Vec<timelyfreeze::sim::GanttBlock> = pdag
        .action_nodes()
        .into_iter()
        .map(|id| timelyfreeze::sim::GanttBlock {
            action: pdag.node_action(id).unwrap(),
            rank: pdag.rank_of_node[id],
            start: starts[id],
            duration: sol.w[id],
            afr: sol.ratios[id],
        })
        .collect();
    print!("{}", viz::ascii(&blocks, 4, 100));
}
