//! Quickstart: the TimelyFreeze pipeline in five steps — build a
//! schedule, derive its DAG, measure (here: model) action costs, solve
//! the freeze LP, and read off the expected freeze ratios and speedup.
//!
//!     cargo run --release --example quickstart
//!
//! What you should see: the LP keeps forward durations fixed (they are
//! freeze-invariant), shrinks backward durations on the critical path
//! toward their dgrad-only floor, and reports κ < 1 — the batch-time
//! reduction eq. 6 buys under the per-stage budget `r_max`. The ASCII
//! Gantt at the end draws the optimized pipeline; compare its bubble
//! structure with `examples/schedule_explorer.rs`. For the memory-aware
//! variant of the same LP (constraint [5]), run
//! `tfreeze lp --mem-budget 0.3` or see `benches/fig16_memory_pareto.rs`.

use timelyfreeze::graph::pipeline::PipelineDag;
use timelyfreeze::lp::{solve_freeze_lp, FreezeLpInput, DEFAULT_LAMBDA};
use timelyfreeze::schedule::Schedule;
use timelyfreeze::types::{ActionKind, ScheduleKind};
use timelyfreeze::viz;

fn main() {
    // 1. A 1F1B schedule over 4 GPUs and 8 microbatches.
    let schedule = Schedule::build(ScheduleKind::OneFOneB, 4, 8, 1);
    println!("schedule: {} actions across {} ranks", schedule.action_count(), schedule.ranks);

    // 2. Its execution DAG (§3.2.1).
    let pdag = PipelineDag::from_schedule(&schedule);
    println!("pipeline DAG: {} nodes, {} edges", pdag.len(), pdag.dag.edge_count());

    // 3. Monitored bounds: forward 10 ms; backward 22 ms unfrozen,
    //    9 ms fully frozen (the dgrad share, Figure 3).
    let w_max = pdag.weights(|a| match a.kind {
        ActionKind::Forward => 0.010,
        _ => 0.022,
    });
    let w_min = pdag.weights(|a| match a.kind {
        ActionKind::Forward => 0.010,
        _ => 0.009,
    });

    // 4. Solve the LP (eq. 6 with constraints [1]–[4]).
    let sol = solve_freeze_lp(&FreezeLpInput::new(&pdag, &w_min, &w_max, 0.8, DEFAULT_LAMBDA))
        .expect("LP is always feasible");

    // 5. Results.
    println!("batch time: {:.1} ms → {:.1} ms (κ = {:.3})",
        sol.p_d_max * 1e3, sol.batch_time * 1e3, sol.kappa());
    println!("mean expected freeze ratio r̄* = {:.2}", sol.mean_freezable_ratio(&pdag));

    // Bonus: draw the optimized pipeline.
    let starts = pdag.start_times(&sol.w);
    let blocks: Vec<timelyfreeze::sim::GanttBlock> = pdag
        .action_nodes()
        .into_iter()
        .map(|id| timelyfreeze::sim::GanttBlock {
            action: pdag.node_action(id).unwrap(),
            rank: pdag.rank_of_node[id],
            start: starts[id],
            duration: sol.w[id],
            afr: sol.ratios[id],
        })
        .collect();
    print!("{}", viz::ascii(&blocks, 4, 100));
}
