//! Explore the four pipeline schedules: per-rank action orders, DAG
//! sizes, bubble ratios, and how each responds to freezing.
//!
//!     cargo run --release --example schedule_explorer

use timelyfreeze::graph::pipeline::PipelineDag;
use timelyfreeze::schedule::Schedule;
use timelyfreeze::types::{ActionKind, ScheduleKind};
use timelyfreeze::util::table::Table;

fn main() {
    let ranks = 4;
    let m = 8;
    let mut t = Table::new(
        &format!("schedules at {ranks} ranks × {m} microbatches (uniform costs)"),
        &["Schedule", "Actions", "DAG edges", "Batch time", "Bubble %", "Full-freeze time"],
    );
    for kind in ScheduleKind::all() {
        let s = Schedule::build(kind, ranks, m, Schedule::default_chunks(kind));
        let g = PipelineDag::from_schedule(&s);
        // Unit forward cost; backward 2× (half of it wgrad). Chunked
        // schedules split the same work across 2× stages.
        let scale = 1.0 / s.chunks as f64;
        let w_max = g.weights(|a| match a.kind {
            ActionKind::Forward | ActionKind::BackwardDgrad => scale,
            ActionKind::Backward => 2.0 * scale,
            ActionKind::BackwardWgrad => scale,
        });
        let w_min = g.weights(|a| match a.kind {
            ActionKind::Forward | ActionKind::BackwardDgrad => scale,
            ActionKind::Backward => scale,
            ActionKind::BackwardWgrad => 0.0,
        });
        let batch = g.batch_time(&w_max);
        let ideal: f64 = 3.0 * m as f64; // per-rank compute under uniform costs
        let bubble = 100.0 * (1.0 - ideal / batch);
        t.row(vec![
            kind.name().to_string(),
            format!("{}", s.action_count()),
            format!("{}", g.dag.edge_count()),
            format!("{batch:.1}"),
            format!("{bubble:.1}"),
            format!("{:.1}", g.batch_time(&w_min)),
        ]);
    }
    println!("{}", t.render());
    println!("ZBV's W actions absorb bubbles; freezing then shrinks exactly those W blocks.");
    println!("\nPer-rank orders (1F1B):");
    let s = Schedule::build(ScheduleKind::OneFOneB, ranks, m, 1);
    for (rank, order) in s.orders.iter().enumerate() {
        let line: String = order.iter().map(|a| a.kind.label()).collect();
        println!("  rank {rank}: {line}");
    }
}
