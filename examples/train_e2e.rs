//! End-to-end validation (DESIGN.md §2, last row): train a transformer on
//! the real three-layer stack — Rust coordinator (L3) driving AOT-lowered
//! JAX artifacts (L2) containing Pallas kernels (L1) over PJRT — on a
//! synthetic tiny corpus, with TimelyFreeze's full phase machine
//! (warm-up → monitoring → LP → progressive freezing) and real wall-clock
//! freezing gains. Logs the loss curve and writes it to bench_out/.
//!
//!     make artifacts && cargo run --release --example train_e2e
//!     # ~100M-parameter variant (rebuild artifacts first):
//!     #   make artifacts D_MODEL=768 D_FF=3072 VOCAB=8192
//!     #   cargo run --release --example train_e2e -- --large
//!
//! Flags: --steps N, --method NAME, --baseline (also run No-Freezing for
//! a paired comparison), --large (12 blocks — combine with the wider
//! artifact build above for ~126M params).

use timelyfreeze::engine::{train, EngineConfig};
use timelyfreeze::freeze::PhaseConfig;
use timelyfreeze::metrics::Recorder;
use timelyfreeze::types::FreezeMethod;
use timelyfreeze::util::json::Json;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |name: &str| -> Option<String> {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1).cloned())
    };
    let large = args.iter().any(|a| a == "--large");
    let with_baseline = args.iter().any(|a| a == "--baseline");

    let dir = std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    let mut cfg = EngineConfig::quick_defaults(dir);
    cfg.blocks = if large { 12 } else { 8 };
    cfg.stages = 4;
    cfg.microbatches = 4;
    cfg.steps = get("--steps").and_then(|s| s.parse().ok()).unwrap_or(if large { 60 } else { 300 });
    cfg.method = get("--method")
        .and_then(|m| FreezeMethod::parse(&m))
        .unwrap_or(FreezeMethod::TimelyFreeze);
    // Paper-shaped phases scaled to the run length.
    let s = cfg.steps;
    cfg.phases = PhaseConfig::new((s / 10).max(3), (s / 5).max(6), (s * 3 / 10).max(9));
    cfg.corpus_cycle = 16;

    let manifest = timelyfreeze::runtime::Manifest::load(&cfg.artifacts_dir)
        .expect("run `make artifacts` first");
    let c = &manifest.config;
    let block = c
        .matrix_shapes
        .values()
        .map(|&(a, b)| a * b)
        .sum::<usize>()
        + 2 * c.d_model;
    let total = c.vocab * c.d_model * 2 + cfg.blocks * block;
    println!(
        "model: d={} ff={} vocab={} × {} blocks → {:.1}M params | {} stages, {} microbatches, {} steps, {}",
        c.d_model, c.d_ff, c.vocab, cfg.blocks,
        total as f64 / 1e6, cfg.stages, cfg.microbatches, cfg.steps, cfg.method.name()
    );

    let mut rec = Recorder::default_dir();
    let mut run = |method: FreezeMethod| {
        let mut c2 = cfg.clone();
        c2.method = method;
        println!("\n=== {} ===", method.name());
        let t0 = std::time::Instant::now();
        let report = train(&c2).expect("training failed");
        let wall = t0.elapsed().as_secs_f64();
        for p in &report.loss_curve {
            if p.step == 1 || p.step % (cfg.steps / 20).max(1) == 0 {
                println!(
                    "  step {:>5}  loss {:>7.4}  afr {:>5.2}  step {:>7.0} ms",
                    p.step, p.loss, p.mean_afr, p.step_time * 1e3
                );
            }
            rec.push(
                &format!("e2e_loss_{}", method.name().replace([' ', '+'], "_")),
                Json::obj(vec![
                    ("step", Json::num(p.step as f64)),
                    ("loss", Json::num(p.loss)),
                    ("afr", Json::num(p.mean_afr)),
                    ("step_time", Json::num(p.step_time)),
                ]),
            );
        }
        println!(
            "  wall {:.1}s | throughput {:.0} tok/s (steady {:.0}) | κ = {:.3} | freeze ratio {:.1}% | loss {:.3} → {:.3}",
            wall,
            report.throughput,
            report.steady_throughput,
            report.kappa(),
            report.freeze_ratio,
            report.initial_loss,
            report.final_loss
        );
        report
    };

    let ours = run(cfg.method);
    if with_baseline {
        let base = run(FreezeMethod::NoFreezing);
        println!(
            "\nthroughput gain vs No-Freezing: {:+.1}% (steady {:+.1}%) | Δfinal-loss {:+.4}",
            100.0 * (ours.throughput / base.throughput - 1.0),
            100.0 * (ours.steady_throughput / base.steady_throughput - 1.0),
            ours.final_loss - base.final_loss
        );
    }
    rec.flush().unwrap();
    println!("\nloss curves recorded under bench_out/.");
}
