"""AOT pipeline: lower every artifact of ``model.py`` to HLO **text** and
write a manifest the Rust runtime consumes.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from ``make artifacts``):
    cd python && python -m compile.aot --out-dir ../artifacts \
        [--d-model 256 --n-heads 8 --d-ff 1024 --vocab 4096 \
         --seq 128 --microbatch 1]
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile.model import (
    ARTIFACT_BUILDERS,
    MASKED_NAMES,
    PARAM_NAMES,
    ModelConfig,
    example_inputs,
)


def to_hlo_text(fn, example_args):
    """Lower a function to XLA HLO text via StableHLO (return_tuple=True:
    the Rust side unwraps with ``to_tuple``)."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def describe(arrays):
    return [
        {"shape": list(a.shape), "dtype": str(a.dtype)}
        for a in arrays
    ]


def build_all(cfg: ModelConfig, out_dir: str, kinds=None):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "config": {
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "vocab": cfg.vocab,
            "seq_len": cfg.seq_len,
            "microbatch": cfg.microbatch,
            "param_names": list(PARAM_NAMES),
            "masked_names": list(MASKED_NAMES),
            "mask_shapes": {n: list(cfg.mask_shape(n)) for n in MASKED_NAMES},
            "matrix_shapes": {n: list(cfg.matrix_shape(n)) for n in MASKED_NAMES},
        },
        "artifacts": {},
    }
    for kind, builder in ARTIFACT_BUILDERS.items():
        if kinds and kind not in kinds:
            continue
        fn = builder(cfg)
        args = example_inputs(cfg, kind)
        text = to_hlo_text(fn, args)
        fname = f"{kind}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *args)
        outs = outs if isinstance(outs, (tuple, list)) else (outs,)
        manifest["artifacts"][kind] = {
            "file": fname,
            "inputs": describe(args),
            "outputs": describe(list(outs)),
        }
        print(f"  lowered {kind:16} ({len(text) / 1024:.0f} KiB)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {out_dir}/manifest.json")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-heads", type=int, default=8)
    ap.add_argument("--d-ff", type=int, default=1024)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--only", nargs="*", help="subset of artifact kinds")
    args = ap.parse_args()
    cfg = ModelConfig(
        d_model=args.d_model,
        n_heads=args.n_heads,
        d_ff=args.d_ff,
        vocab=args.vocab,
        seq_len=args.seq,
        microbatch=args.microbatch,
    )
    print(f"AOT-lowering artifacts for {cfg} → {args.out_dir}")
    build_all(cfg, args.out_dir, kinds=args.only)


if __name__ == "__main__":
    main()
