"""L1 Pallas kernel: tiled causal flash attention.

Hardware adaptation (DESIGN.md §4): the GPU flash-attention pattern
(threadblock per query tile, K/V streamed through shared memory) becomes
a BlockSpec-scheduled HBM→VMEM pipeline — each grid step holds one query
tile resident while K/V tiles stream through the online-softmax
recurrence carried in VMEM scratch. Matmul tiles are sized for the MXU
systolic array (multiples of 128 where the model dims allow).

Runs with ``interpret=True`` only: real-TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md). Numerics are validated against
``ref.ref_attention`` by pytest + hypothesis.

VMEM footprint per grid step (f32): Bq·D (q) + 2·Bk·D (k,v tiles) +
Bq·Bk (scores) + Bq·D (acc) + 2·Bq (m, l) bytes×4 — ≈ 200 KiB at
Bq = Bk = 128, D = 64, comfortably inside a 16 MiB VMEM budget with
double buffering.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq, causal):
    """One (head, q-tile) grid step: stream K/V tiles, online softmax."""
    qi = pl.program_id(1)
    head_dim = q_ref.shape[-1]
    scale = 1.0 / math.sqrt(head_dim)
    q = q_ref[...] * scale  # (block_q, d)

    num_k_blocks = seq // block_k
    if causal:
        # Tiles strictly above the diagonal contribute nothing.
        last = lax.div((qi + 1) * block_q + block_k - 1, block_k)
        num_k_iters = jnp.minimum(last, num_k_blocks)
    else:
        num_k_iters = num_k_blocks

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        s = q @ k.T  # (block_q, block_k) — MXU tile
        if causal:
            rows = qi * block_q + lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = i * block_k + lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    init = (
        jnp.full((block_q,), NEG_INF, dtype=jnp.float32),
        jnp.zeros((block_q,), dtype=jnp.float32),
        jnp.zeros((block_q, head_dim), dtype=jnp.float32),
    )
    _, l, acc = lax.fori_loop(0, num_k_iters, body, init)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@jax.custom_vjp
def attention(q, k, v):
    """Differentiable causal attention: Pallas flash kernel forward, with
    the backward defined through the reference attention's VJP
    (``pallas_call`` has no autodiff rule; the two are numerically
    equivalent, which the kernel tests assert)."""
    return flash_attention(q, k, v, causal=True)


def _attention_fwd(q, k, v):
    return flash_attention(q, k, v, causal=True), (q, k, v)


def _attention_bwd(res, g):
    from compile.kernels.ref import ref_attention

    q, k, v = res
    _, vjp = jax.vjp(lambda a, b, c: ref_attention(a, b, c, causal=True), q, k, v)
    return vjp(g)


attention.defvjp(_attention_fwd, _attention_bwd)


def flash_attention(q, k, v, *, causal=True, block_q=None, block_k=None):
    """Causal flash attention over ``(batch·heads, seq, head_dim)`` inputs.

    The leading axis folds batch and heads so no vmap is needed around the
    ``pallas_call`` (grid axis 0 walks it directly).
    """
    bh, seq, head_dim = q.shape
    assert k.shape == (bh, seq, head_dim) and v.shape == (bh, seq, head_dim)
    block_q = block_q or min(64, seq)
    block_k = block_k or min(64, seq)
    assert seq % block_q == 0, f"seq {seq} % block_q {block_q} != 0"
    assert seq % block_k == 0, f"seq {seq} % block_k {block_k} != 0"

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq=seq, causal=causal
    )
    grid = (bh, seq // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Query tile resident per grid step…
            pl.BlockSpec((None, block_q, head_dim), lambda h, i: (h, i, 0)),
            # …K/V for the head mapped whole; tiles stream inside the
            # kernel through the online-softmax loop.
            pl.BlockSpec((None, seq, head_dim), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((None, seq, head_dim), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, head_dim), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, head_dim), q.dtype),
        interpret=True,
    )(q, k, v)
