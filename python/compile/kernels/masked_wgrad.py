"""L1 Pallas kernel: block-masked weight gradient — the paper's freezing
mechanism expressed at kernel level.

``dW = xᵀ @ g`` computed tile-by-tile over a (d_in/B_i, d_out/B_o) grid;
a per-tile freeze mask gates the MXU work: frozen tiles write zeros and
skip the GEMM via ``pl.when``. On a real TPU the skipped tiles save both
MXU cycles and the HBM→VMEM streaming of their x/g columns; under
``interpret=True`` (mandatory on CPU-PJRT, see attention.py) the saving
is structural only — wall-clock freezing gains on the CPU path come from
the Rust engine skipping whole wgrad artifact calls per layer.

VMEM per grid step (f32): T·B_i (x tile) + T·B_o (g tile) + B_i·B_o
(out). At T = 4096 chunks this exceeds VMEM, so the token axis would be
chunked on real hardware; the e2e configs here keep T ≤ 2048 which fits
(< 4 MiB).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wgrad_kernel(mask_ref, x_ref, g_ref, o_ref):
    frozen = mask_ref[0, 0] != 0.0

    @pl.when(jnp.logical_not(frozen))
    def _compute():
        x = x_ref[...]  # (tokens, block_in)
        g = g_ref[...]  # (tokens, block_out)
        o_ref[...] = (x.T @ g).astype(o_ref.dtype)

    @pl.when(frozen)
    def _skip():
        o_ref[...] = jnp.zeros_like(o_ref)


def pick_block(dim, preferred=128):
    """Largest divisor of ``dim`` that is ≤ preferred (MXU-aligned when
    the dim allows)."""
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


def masked_wgrad(x, g, mask, *, block_in=None, block_out=None):
    """Masked weight gradient.

    Args:
        x: (tokens, d_in) layer-input activations.
        g: (tokens, d_out) output gradient.
        mask: (d_in // block_in, d_out // block_out) float32; nonzero
            entries mark *frozen* tiles (gradient forced to zero).

    Returns:
        (d_in, d_out) gradient with frozen tiles zeroed.
    """
    tokens, d_in = x.shape
    tokens_g, d_out = g.shape
    assert tokens == tokens_g, f"token mismatch {tokens} vs {tokens_g}"
    block_in = block_in or pick_block(d_in)
    block_out = block_out or pick_block(d_out)
    assert d_in % block_in == 0 and d_out % block_out == 0
    gi, go = d_in // block_in, d_out // block_out
    assert mask.shape == (gi, go), f"mask shape {mask.shape} != ({gi}, {go})"

    return pl.pallas_call(
        functools.partial(_wgrad_kernel),
        grid=(gi, go),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((tokens, block_in), lambda i, j: (0, i)),
            pl.BlockSpec((tokens, block_out), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_in, block_out), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d_in, d_out), x.dtype),
        interpret=True,
    )(mask, x, g)
