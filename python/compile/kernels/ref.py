"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the kernel tests (and hypothesis sweeps)
compare against; they are also used by the L2 model tests to validate the
full block forward/backward against plain autodiff.
"""

import jax.numpy as jnp


def ref_attention(q, k, v, causal=True):
    """Plain softmax attention.

    Args:
        q, k, v: (heads, seq, head_dim) arrays.
        causal: apply a lower-triangular mask.

    Returns:
        (heads, seq, head_dim) attention output.
    """
    _, s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask[None, :, :], scores, jnp.asarray(-1e30, q.dtype))
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def ref_masked_wgrad(x, g, mask, block_in, block_out):
    """Block-masked weight gradient: dW = xᵀ @ g with frozen tiles zeroed.

    Args:
        x: (tokens, d_in) activations.
        g: (tokens, d_out) output gradients.
        mask: (d_in // block_in, d_out // block_out); nonzero = frozen.
        block_in, block_out: tile sizes.

    Returns:
        (d_in, d_out) masked gradient.
    """
    dw = x.T @ g
    keep = (mask == 0).astype(dw.dtype)
    expanded = jnp.kron(keep, jnp.ones((block_in, block_out), dtype=dw.dtype))
    return dw * expanded


def ref_rms_norm(x, scale, eps=1e-6):
    """RMSNorm oracle: x / rms(x) * scale."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * scale
