"""L2: LLaMA-style transformer decomposed into per-layer AOT artifacts.

The model is expressed as *reusable layer kinds* — every transformer
block shares one shape, so three HLO artifacts (``block_fwd``,
``block_dgrad``, ``block_bwd``/``block_wgrad``) serve every layer of
every pipeline stage, plus embedding and head/loss artifacts. This is
the decomposition the paper's Figure 3 relies on: the backward splits
into the activation-gradient part (B — ``block_dgrad``, irreducible
under freezing) and the parameter-gradient part (W — ``block_wgrad``,
what freezing removes).

Freezing reaches the kernels through ``dense``: a ``custom_vjp`` matmul
whose backward routes dW through the L1 ``masked_wgrad`` Pallas kernel
with a per-tile freeze mask supplied *at run time* by the Rust
coordinator. Forward attention goes through the L1 ``flash_attention``
kernel.

Everything here is build-time only: ``aot.py`` lowers these functions to
HLO text once; Python never runs on the training path.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from compile.kernels.attention import attention
from compile.kernels.masked_wgrad import masked_wgrad, pick_block

# Canonical flattened parameter order of a block — the contract with the
# Rust runtime (recorded in the AOT manifest).
PARAM_NAMES = ("wq", "wk", "wv", "wo", "w1", "w2", "w3", "norm1", "norm2")
# The dense matrices that take freeze masks, in signature order.
MASKED_NAMES = ("wq", "wk", "wv", "wo", "w1", "w2", "w3")


@dataclass(frozen=True)
class ModelConfig:
    """Shape of one transformer block (shared across all layers)."""

    d_model: int = 256
    n_heads: int = 8
    d_ff: int = 1024
    vocab: int = 4096
    seq_len: int = 128
    microbatch: int = 1

    @property
    def head_dim(self):
        return self.d_model // self.n_heads

    @property
    def tokens(self):
        return self.microbatch * self.seq_len

    def mask_shape(self, name):
        """Freeze-mask tile grid of one dense matrix."""
        din, dout = self.matrix_shape(name)
        return (din // pick_block(din), dout // pick_block(dout))

    def matrix_shape(self, name):
        d, f = self.d_model, self.d_ff
        return {
            "wq": (d, d),
            "wk": (d, d),
            "wv": (d, d),
            "wo": (d, d),
            "w1": (d, f),
            "w2": (f, d),
            "w3": (d, f),
        }[name]


# --------------------------------------------------------------------------
# Masked dense layer (custom VJP → L1 masked_wgrad kernel)
# --------------------------------------------------------------------------


@jax.custom_vjp
def dense(x, w, mask):
    """``x @ w`` whose weight gradient is tile-masked by ``mask``.

    x: (..., d_in); w: (d_in, d_out); mask: tile grid (see
    ``ModelConfig.mask_shape``), nonzero = frozen.
    """
    return x @ w


def _dense_fwd(x, w, mask):
    return x @ w, (x, w, mask)


def _dense_bwd(res, g):
    x, w, mask = res
    gx = g @ w.T
    x2 = x.reshape(-1, x.shape[-1])
    g2 = g.reshape(-1, g.shape[-1])
    gw = masked_wgrad(x2, g2, mask)
    return gx, gw, jnp.zeros_like(mask)


dense.defvjp(_dense_fwd, _dense_bwd)


# --------------------------------------------------------------------------
# Block primitives
# --------------------------------------------------------------------------


def rms_norm(x, scale, eps=1e-6):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * scale


def rope(x, positions):
    """Rotary position embedding over the last axis (pairs convention)."""
    *_, seq, d = x.shape
    assert d % 2 == 0
    half = d // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def silu(x):
    return x * jax.nn.sigmoid(x)


def block_fwd(params, masks, x, cfg: ModelConfig):
    """One pre-norm LLaMA block: attention + SwiGLU, residual wired.

    params: tuple in ``PARAM_NAMES`` order.
    masks: tuple in ``MASKED_NAMES`` order (forward ignores their values —
    they only steer the backward's masked wgrad).
    x: (microbatch, seq, d_model).
    """
    wq, wk, wv, wo, w1, w2, w3, n1, n2 = params
    mq, mk, mv, mo, m1, m2, m3 = masks
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    # --- attention ---
    hidden = rms_norm(x, n1)
    q = dense(hidden, wq, mq)
    k = dense(hidden, wk, mk)
    v = dense(hidden, wv, mv)

    def split(t):
        return t.reshape(b, s, h, hd).transpose(0, 2, 1, 3)  # (b, h, s, hd)

    positions = jnp.arange(s)
    q = rope(split(q), positions)
    k = rope(split(k), positions)
    v = split(v)
    # Fold batch into heads for the flash kernel.
    fold = lambda t: t.reshape(b * h, s, hd)
    attn = attention(fold(q), fold(k), fold(v))
    attn = attn.reshape(b, h, s, hd).transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + dense(attn, wo, mo)

    # --- SwiGLU MLP ---
    hidden = rms_norm(x, n2)
    ff = silu(dense(hidden, w1, m1)) * dense(hidden, w3, m3)
    return x + dense(ff, w2, m2)


def ones_masks(cfg: ModelConfig, frozen=False):
    """All-tiles mask tuple (0 = live, 1 = frozen)."""
    fill = 1.0 if frozen else 0.0
    return tuple(
        jnp.full(cfg.mask_shape(name), fill, dtype=jnp.float32) for name in MASKED_NAMES
    )


# --------------------------------------------------------------------------
# Artifact entry points (flat signatures — the Rust runtime contract)
# --------------------------------------------------------------------------


def artifact_block_fwd(cfg: ModelConfig):
    def fn(*args):
        params, x = args[:9], args[9]
        return (block_fwd(params, ones_masks(cfg), x, cfg),)

    return fn


def artifact_block_dgrad(cfg: ModelConfig):
    """gx only — the Zero-Bubble "B" unit. JAX dead-code-eliminates the
    parameter-gradient computations, so this artifact is genuinely
    cheaper than the full backward."""

    def fn(*args):
        params, x, gy = args[:9], args[9], args[10]
        _, vjp = jax.vjp(lambda xx: block_fwd(params, ones_masks(cfg), xx, cfg), x)
        return (vjp(gy)[0],)

    return fn


def artifact_block_wgrad(cfg: ModelConfig):
    """Parameter gradients only — the Zero-Bubble "W" unit, with runtime
    freeze masks routed to the masked_wgrad kernel."""

    def fn(*args):
        params, masks, x, gy = args[:9], args[9:16], args[16], args[17]
        _, vjp = jax.vjp(lambda p: block_fwd(p, masks, x, cfg), params)
        return tuple(vjp(gy)[0])

    return fn


def artifact_block_bwd(cfg: ModelConfig):
    """Combined backward: (gx, param grads) in one pass — used by
    GPipe/1F1B-style combined-backward schedules."""

    def fn(*args):
        params, masks, x, gy = args[:9], args[9:16], args[16], args[17]
        _, vjp = jax.vjp(
            lambda p, xx: block_fwd(p, masks, xx, cfg), params, x
        )
        gparams, gx = vjp(gy)
        return (gx,) + tuple(gparams)

    return fn


def artifact_embed_fwd(cfg: ModelConfig):
    def fn(emb, tokens):
        return (emb[tokens],)

    return fn


def artifact_embed_wgrad(cfg: ModelConfig):
    def fn(tokens, gx):
        gemb = jnp.zeros((cfg.vocab, cfg.d_model), dtype=gx.dtype)
        return (gemb.at[tokens].add(gx),)

    return fn


def _ce_loss(w_head, x, targets):
    logits = x @ w_head  # (b, s, vocab)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def artifact_head_loss_grad(cfg: ModelConfig):
    """Loss + gradients w.r.t. (x, w_head) in one artifact — the last
    pipeline stage's fused head+loss backward."""

    def fn(w_head, x, targets):
        loss, (gw, gx) = jax.value_and_grad(_ce_loss, argnums=(0, 1))(
            w_head, x, targets
        )
        return loss, gx, gw

    return fn


def artifact_head_loss_eval(cfg: ModelConfig):
    def fn(w_head, x, targets):
        return (_ce_loss(w_head, x, targets),)

    return fn


# --------------------------------------------------------------------------
# Parameter initialization (used by tests and by the Rust engine's
# deterministic init — both sides generate identical trees from the seed)
# --------------------------------------------------------------------------


def init_block_params(cfg: ModelConfig, key):
    keys = jax.random.split(key, 7)
    shapes = [cfg.matrix_shape(n) for n in MASKED_NAMES]
    mats = [
        jax.random.normal(k, s, jnp.float32) * (s[0] ** -0.5)
        for k, s in zip(keys, shapes)
    ]
    norms = [jnp.ones((cfg.d_model,), jnp.float32)] * 2
    return tuple(mats) + tuple(norms)


def example_inputs(cfg: ModelConfig, kind, key=None):
    """Example (shape-defining) inputs of each artifact kind."""
    key = key if key is not None else jax.random.PRNGKey(0)
    params = init_block_params(cfg, key)
    x = jnp.zeros((cfg.microbatch, cfg.seq_len, cfg.d_model), jnp.float32)
    gy = x
    masks = ones_masks(cfg)
    emb = jnp.zeros((cfg.vocab, cfg.d_model), jnp.float32)
    tokens = jnp.zeros((cfg.microbatch, cfg.seq_len), jnp.int32)
    if kind == "block_fwd":
        return (*params, x)
    if kind == "block_dgrad":
        return (*params, x, gy)
    if kind in ("block_wgrad", "block_bwd"):
        return (*params, *masks, x, gy)
    if kind == "embed_fwd":
        return (emb, tokens)
    if kind == "embed_wgrad":
        return (tokens, x)
    if kind in ("head_loss_grad", "head_loss_eval"):
        w_head = jnp.zeros((cfg.d_model, cfg.vocab), jnp.float32)
        return (w_head, x, tokens)
    raise ValueError(f"unknown artifact kind {kind}")


ARTIFACT_BUILDERS = {
    "block_fwd": artifact_block_fwd,
    "block_dgrad": artifact_block_dgrad,
    "block_wgrad": artifact_block_wgrad,
    "block_bwd": artifact_block_bwd,
    "embed_fwd": artifact_embed_fwd,
    "embed_wgrad": artifact_embed_wgrad,
    "head_loss_grad": artifact_head_loss_grad,
    "head_loss_eval": artifact_head_loss_eval,
}
