"""AOT pipeline tests: HLO-text lowering + manifest integrity."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile.aot import build_all, describe, to_hlo_text
from compile.model import ARTIFACT_BUILDERS, ModelConfig, example_inputs

TINY = ModelConfig(d_model=32, n_heads=2, d_ff=64, vocab=128, seq_len=16, microbatch=1)


def test_to_hlo_text_emits_parseable_module():
    fn = lambda a, b: (a @ b + 1.0,)
    spec = jnp.zeros((4, 4), jnp.float32)
    text = to_hlo_text(fn, (spec, spec))
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_describe_shapes_and_dtypes():
    d = describe([jnp.zeros((2, 3), jnp.float32), jnp.zeros((1,), jnp.int32)])
    assert d == [
        {"shape": [2, 3], "dtype": "float32"},
        {"shape": [1], "dtype": "int32"},
    ]


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = build_all(TINY, str(out), kinds=["block_fwd", "embed_fwd", "head_loss_grad"])
    return out, manifest


def test_manifest_written_and_consistent(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk["config"]["d_model"] == TINY.d_model
    assert set(on_disk["artifacts"]) == {"block_fwd", "embed_fwd", "head_loss_grad"}
    for kind, meta in on_disk["artifacts"].items():
        path = os.path.join(out, meta["file"])
        assert os.path.exists(path), kind
        text = open(path).read()
        assert "HloModule" in text
        # Input arity matches the example inputs.
        assert len(meta["inputs"]) == len(example_inputs(TINY, kind))


def test_lowered_hlo_has_runtime_mask_inputs():
    """The wgrad artifact's HLO must keep the 7 mask tensors as runtime
    parameters (not baked constants)."""
    fn = ARTIFACT_BUILDERS["block_wgrad"](TINY)
    args = example_inputs(TINY, "block_wgrad")
    text = to_hlo_text(fn, args)
    # 9 params + 7 masks + x + gy = 18 parameters.
    assert text.count("parameter(") >= 18


def test_mask_shapes_recorded(built):
    _, manifest = built
    shapes = manifest["config"]["mask_shapes"]
    assert set(shapes) == {"wq", "wk", "wv", "wo", "w1", "w2", "w3"}
    for name, shape in shapes.items():
        assert len(shape) == 2 and all(s >= 1 for s in shape), name
