"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles, including
hypothesis sweeps over shapes/dtypes — the CORE correctness signal of the
build path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention, flash_attention
from compile.kernels.masked_wgrad import masked_wgrad, pick_block
from compile.kernels.ref import ref_attention, ref_masked_wgrad, ref_rms_norm


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---------------------------------------------------------------- attention


class TestFlashAttention:
    def test_matches_reference_basic(self):
        q, k, v = (rand(i, (4, 128, 32)) for i in range(3))
        np.testing.assert_allclose(
            flash_attention(q, k, v), ref_attention(q, k, v), rtol=2e-5, atol=2e-5
        )

    def test_non_causal(self):
        q, k, v = (rand(i + 10, (2, 64, 16)) for i in range(3))
        np.testing.assert_allclose(
            flash_attention(q, k, v, causal=False),
            ref_attention(q, k, v, causal=False),
            rtol=2e-5,
            atol=2e-5,
        )

    def test_causality_first_token_attends_only_itself(self):
        q, k, v = (rand(i + 20, (1, 64, 16)) for i in range(3))
        out = flash_attention(q, k, v)
        # Row 0 of causal attention = v[0] exactly.
        np.testing.assert_allclose(out[0, 0], v[0, 0], rtol=1e-5, atol=1e-5)

    def test_block_size_invariance(self):
        q, k, v = (rand(i + 30, (2, 128, 32)) for i in range(3))
        a = flash_attention(q, k, v, block_q=32, block_k=64)
        b = flash_attention(q, k, v, block_q=64, block_k=32)
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)

    def test_scale_invariance_of_softmax_shift(self):
        # Adding a constant to all scores must not change output — the
        # online-softmax recurrence must be numerically shift-stable.
        q, k, v = (rand(i + 40, (1, 64, 16)) for i in range(3))
        out1 = flash_attention(q, k, v)
        out2 = flash_attention(q * 1.0, k, v)
        np.testing.assert_allclose(out1, out2, rtol=1e-6)

    def test_large_magnitude_stability(self):
        q, k, v = (rand(i + 50, (1, 64, 16), scale=30.0) for i in range(3))
        out = flash_attention(q, k, v)
        assert bool(jnp.isfinite(out).all())

    @settings(max_examples=12, deadline=None)
    @given(
        heads=st.sampled_from([1, 2, 4]),
        seq=st.sampled_from([32, 64, 128]),
        dim=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
        causal=st.booleans(),
    )
    def test_hypothesis_shape_sweep(self, heads, seq, dim, seed, causal):
        keys = jax.random.split(jax.random.PRNGKey(seed), 3)
        q, k, v = (jax.random.normal(kk, (heads, seq, dim), jnp.float32) for kk in keys)
        np.testing.assert_allclose(
            flash_attention(q, k, v, causal=causal),
            ref_attention(q, k, v, causal=causal),
            rtol=3e-5,
            atol=3e-5,
        )

    def test_custom_vjp_gradients_match_reference(self):
        q, k, v = (rand(i + 60, (2, 32, 16)) for i in range(3))
        g = rand(99, (2, 32, 16))
        gq, gk, gv = jax.vjp(attention, q, k, v)[1](g)
        rq, rk, rv = jax.vjp(lambda a, b, c: ref_attention(a, b, c), q, k, v)[1](g)
        np.testing.assert_allclose(gq, rq, rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(gk, rk, rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(gv, rv, rtol=3e-5, atol=3e-5)


# -------------------------------------------------------------- masked wgrad


class TestMaskedWgrad:
    def test_unmasked_equals_plain_matmul(self):
        x, g = rand(1, (256, 128)), rand(2, (256, 64))
        mask = jnp.zeros((1, 1), jnp.float32)
        np.testing.assert_allclose(
            masked_wgrad(x, g, mask, block_in=128, block_out=64),
            x.T @ g,
            rtol=1e-5,
            atol=1e-5,
        )

    def test_fully_masked_is_zero(self):
        x, g = rand(3, (64, 32)), rand(4, (64, 16))
        mask = jnp.ones((2, 2), jnp.float32)
        out = masked_wgrad(x, g, mask, block_in=16, block_out=8)
        assert float(jnp.abs(out).max()) == 0.0

    def test_partial_mask_matches_reference(self):
        x, g = rand(5, (128, 64)), rand(6, (128, 48))
        mask = jnp.asarray([[0, 1, 0], [1, 0, 1]], jnp.float32)
        out = masked_wgrad(x, g, mask, block_in=32, block_out=16)
        ref = ref_masked_wgrad(x, g, mask, 32, 16)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    @settings(max_examples=12, deadline=None)
    @given(
        tokens=st.sampled_from([16, 64, 128]),
        din=st.sampled_from([16, 32, 64]),
        dout=st.sampled_from([16, 48]),
        bi=st.sampled_from([8, 16]),
        bo=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**16),
        p=st.floats(0.0, 1.0),
    )
    def test_hypothesis_mask_sweep(self, tokens, din, dout, bi, bo, seed, p):
        if din % bi or dout % bo:
            return
        keys = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(keys[0], (tokens, din), jnp.float32)
        g = jax.random.normal(keys[1], (tokens, dout), jnp.float32)
        mask = (
            jax.random.uniform(keys[2], (din // bi, dout // bo)) < p
        ).astype(jnp.float32)
        out = masked_wgrad(x, g, mask, block_in=bi, block_out=bo)
        ref = ref_masked_wgrad(x, g, mask, bi, bo)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_pick_block(self):
        assert pick_block(256) == 128
        assert pick_block(100) == 100
        assert pick_block(96) == 96
        assert pick_block(384) == 128
        assert pick_block(48, preferred=32) == 24

    def test_mask_shape_validation(self):
        x, g = rand(7, (32, 16)), rand(8, (32, 16))
        with pytest.raises(AssertionError):
            masked_wgrad(x, g, jnp.zeros((3, 3)), block_in=8, block_out=8)


# ------------------------------------------------------------------ rmsnorm


def test_ref_rms_norm_unit_scale():
    x = rand(11, (4, 32))
    out = ref_rms_norm(x, jnp.ones((32,)))
    rms = jnp.sqrt(jnp.mean(out * out, axis=-1))
    np.testing.assert_allclose(rms, jnp.ones_like(rms), rtol=1e-3)
