"""L2 model correctness: the per-layer artifact functions versus plain
jnp autodiff of a reference block (no Pallas, no custom VJPs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import compile.model as M
from compile.kernels.ref import ref_attention
from compile.model import (
    MASKED_NAMES,
    PARAM_NAMES,
    ModelConfig,
    example_inputs,
    init_block_params,
    ones_masks,
)

CFG = ModelConfig(d_model=64, n_heads=4, d_ff=128, vocab=256, seq_len=32, microbatch=2)


def rand(seed, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale


@pytest.fixture(scope="module")
def block_data():
    params = init_block_params(CFG, jax.random.PRNGKey(1))
    x = rand(2, (2, 32, 64))
    gy = rand(3, (2, 32, 64))
    return params, x, gy


def ref_block(params, x, cfg=CFG):
    """Reference block: identical math, plain jnp ops only."""
    wq, wk, wv, wo, w1, w2, w3, n1, n2 = params
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    hidden = M.rms_norm(x, n1)
    q, k, v = hidden @ wq, hidden @ wk, hidden @ wv
    split = lambda t: t.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    pos = jnp.arange(s)
    q, k, v = M.rope(split(q), pos), M.rope(split(k), pos), split(v)
    fold = lambda t: t.reshape(b * h, s, hd)
    attn = ref_attention(fold(q), fold(k), fold(v), causal=True)
    attn = attn.reshape(b, h, s, hd).transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + attn @ wo
    hidden = M.rms_norm(x, n2)
    ff = M.silu(hidden @ w1) * (hidden @ w3)
    return x + ff @ w2


class TestBlockForward:
    def test_matches_reference(self, block_data):
        params, x, _ = block_data
        y = M.artifact_block_fwd(CFG)(*params, x)[0]
        np.testing.assert_allclose(y, ref_block(params, x), rtol=2e-5, atol=2e-5)

    def test_residual_identity_at_zero_weights(self):
        zero = tuple(
            jnp.zeros(CFG.matrix_shape(n), jnp.float32) for n in MASKED_NAMES
        ) + (jnp.ones((64,)),) * 2
        x = rand(5, (2, 32, 64))
        y = M.artifact_block_fwd(CFG)(*zero, x)[0]
        np.testing.assert_allclose(y, x, atol=1e-6)


class TestBlockBackward:
    def test_combined_bwd_matches_autodiff(self, block_data):
        params, x, gy = block_data
        out = M.artifact_block_bwd(CFG)(*params, *ones_masks(CFG), x, gy)
        gx, gparams = out[0], out[1:]

        def scal(p, xx):
            return jnp.vdot(ref_block(p, xx), gy)

        gp_ref, gx_ref = jax.grad(scal, argnums=(0, 1))(params, x)
        np.testing.assert_allclose(gx, gx_ref, rtol=1e-4, atol=1e-4)
        for name, a, b in zip(PARAM_NAMES, gparams, gp_ref):
            np.testing.assert_allclose(
                a, b, rtol=1e-4, atol=1e-4, err_msg=f"grad {name}"
            )

    def test_dgrad_matches_combined(self, block_data):
        params, x, gy = block_data
        gx1 = M.artifact_block_dgrad(CFG)(*params, x, gy)[0]
        gx2 = M.artifact_block_bwd(CFG)(*params, *ones_masks(CFG), x, gy)[0]
        np.testing.assert_allclose(gx1, gx2, rtol=1e-6)

    def test_wgrad_matches_combined(self, block_data):
        params, x, gy = block_data
        w1 = M.artifact_block_wgrad(CFG)(*params, *ones_masks(CFG), x, gy)
        full = M.artifact_block_bwd(CFG)(*params, *ones_masks(CFG), x, gy)[1:]
        for name, a, b in zip(PARAM_NAMES, w1, full):
            np.testing.assert_allclose(a, b, rtol=1e-6, err_msg=name)

    def test_fully_frozen_masks_zero_matrix_grads(self, block_data):
        params, x, gy = block_data
        grads = M.artifact_block_wgrad(CFG)(
            *params, *ones_masks(CFG, frozen=True), x, gy
        )
        for name, g in zip(PARAM_NAMES, grads):
            if name in MASKED_NAMES:
                assert float(jnp.abs(g).max()) == 0.0, name
            else:
                # Norm scales are not tile-masked.
                assert float(jnp.abs(g).max()) > 0.0, name

    def test_per_matrix_mask_zeroes_only_masked_matrix(self, block_data):
        params, x, gy = block_data
        masks = list(ones_masks(CFG))
        # Freeze all tiles of wq only (at this block size the tile grid
        # is 1×1, i.e. whole-matrix granularity; sub-matrix tiles are
        # covered by test_kernels.TestMaskedWgrad).
        masks[0] = jnp.ones(CFG.mask_shape("wq"), jnp.float32)
        grads = M.artifact_block_wgrad(CFG)(*params, *masks, x, gy)
        gwq, gwk = grads[0], grads[1]
        assert float(jnp.abs(gwq).max()) == 0.0
        assert float(jnp.abs(gwk).max()) > 0.0

    def test_frozen_mask_does_not_change_gx(self, block_data):
        params, x, gy = block_data
        gx_live = M.artifact_block_bwd(CFG)(*params, *ones_masks(CFG), x, gy)[0]
        gx_frozen = M.artifact_block_bwd(CFG)(
            *params, *ones_masks(CFG, frozen=True), x, gy
        )[0]
        np.testing.assert_allclose(gx_live, gx_frozen, rtol=1e-6)


class TestEmbedAndHead:
    def test_embed_roundtrip(self):
        emb = rand(7, (256, 64))
        tokens = jax.random.randint(jax.random.PRNGKey(8), (2, 32), 0, 256)
        x = M.artifact_embed_fwd(CFG)(emb, tokens)[0]
        assert x.shape == (2, 32, 64)
        np.testing.assert_allclose(x[0, 0], emb[tokens[0, 0]])

    def test_embed_wgrad_is_scatter_add(self):
        tokens = jnp.zeros((2, 32), jnp.int32)  # all token 0
        gx = jnp.ones((2, 32, 64), jnp.float32)
        g = M.artifact_embed_wgrad(CFG)(tokens, gx)[0]
        np.testing.assert_allclose(g[0], jnp.full((64,), 64.0))
        assert float(jnp.abs(g[1:]).max()) == 0.0

    def test_head_loss_uniform_logits(self):
        w = jnp.zeros((64, 256), jnp.float32)
        x = rand(9, (2, 32, 64))
        t = jax.random.randint(jax.random.PRNGKey(10), (2, 32), 0, 256)
        loss = M.artifact_head_loss_eval(CFG)(w, x, t)[0]
        np.testing.assert_allclose(loss, jnp.log(256.0), rtol=1e-5)

    def test_head_grad_matches_autodiff(self):
        w = rand(11, (64, 256), 0.05)
        x = rand(12, (2, 32, 64))
        t = jax.random.randint(jax.random.PRNGKey(13), (2, 32), 0, 256)
        loss, gx, gw = M.artifact_head_loss_grad(CFG)(w, x, t)
        loss2, (gw2, gx2) = jax.value_and_grad(M._ce_loss, argnums=(0, 1))(w, x, t)
        np.testing.assert_allclose(loss, loss2)
        np.testing.assert_allclose(gx, gx2, rtol=1e-6)
        np.testing.assert_allclose(gw, gw2, rtol=1e-6)


class TestExampleInputs:
    def test_all_kinds_have_examples(self):
        for kind in M.ARTIFACT_BUILDERS:
            args = example_inputs(CFG, kind)
            assert len(args) > 0, kind

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError):
            example_inputs(CFG, "nope")


class TestRope:
    def test_rope_preserves_norm(self):
        x = rand(20, (1, 2, 16, 32))
        pos = jnp.arange(16)
        y = M.rope(x, pos)
        np.testing.assert_allclose(
            jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5
        )

    def test_rope_position_zero_is_identity(self):
        x = rand(21, (1, 1, 4, 8))
        y = M.rope(x, jnp.zeros((4,), jnp.int32))
        np.testing.assert_allclose(y, x, rtol=1e-6)
