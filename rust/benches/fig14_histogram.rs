//! Figure 14 (Appendix H): per-parameter freeze-ratio histograms on the
//! last rank, per method — TimelyFreeze near-uniform, APF bimodal,
//! AutoFreeze layer-skewed.
use timelyfreeze::bench_support::tables::apply_quick;
use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::sim;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};
use timelyfreeze::viz::hist;

fn main() {
    for method in [
        FreezeMethod::TimelyFreeze,
        FreezeMethod::Apf,
        FreezeMethod::AutoFreeze,
        FreezeMethod::TimelyApf,
        FreezeMethod::TimelyAuto,
    ] {
        let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        apply_quick(&mut cfg);
        cfg.schedule = ScheduleKind::OneFOneB;
        cfg.method = method;
        let r = sim::run(&cfg).expect("feasible config");
        let layout = sim::build_layout(&cfg, timelyfreeze::partition::PartitionMethod::Parameter);
        // Rank 3 = last stage's units.
        let last_stage = cfg.stages() - 1;
        let vals: Vec<f64> = layout
            .units_of_stage(last_stage)
            .iter()
            .map(|&u| r.unit_freeze_freq[u])
            .collect();
        print!("{}", hist::histogram(&vals, 10, 50, &format!("{} (rank 3)", method.name())));
        let s = hist::spread(&vals);
        println!(
            "   mean {:.3}  stddev {:.3}  always-frozen {:.0}%  never {:.0}%\n",
            s.mean,
            s.stddev,
            100.0 * s.saturated,
            100.0 * s.untouched
        );
    }
}
