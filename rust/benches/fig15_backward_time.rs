//! Figure 15 (Appendix I): backward computation time vs effective freeze
//! ratio per pipeline stage, with linear fits `t = slope·r + intercept` —
//! validating the LP's linear-interpolation model (eq. 4).
use timelyfreeze::bench_support::tables::apply_quick;
use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::monitor::{TimingMonitor, TimingSample};
use timelyfreeze::sim;
use timelyfreeze::types::{Action, FreezeMethod, ScheduleKind};

fn main() {
    let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
    apply_quick(&mut cfg);
    cfg.schedule = ScheduleKind::OneFOneB;
    cfg.method = FreezeMethod::TimelyFreeze;
    let r = sim::run(&cfg).expect("feasible config");
    let mut mon = TimingMonitor::new();
    mon.record_all(r.backward_samples.iter().map(|s| TimingSample {
        action: Action::b(s.mb, s.stage),
        afr: s.afr,
        duration: s.time,
    }));
    println!("Figure 15 — backward time vs freeze ratio ({} samples)", mon.len());
    for (stage, fit) in mon.backward_regression(cfg.stages()).iter().enumerate() {
        match fit {
            Some(f) => {
                println!(
                    "  stage {stage}: t = {:+.2}·r + {:.2}  (ms: {:+.2}·r + {:.2})  R² = {:.4}",
                    f.slope, f.intercept, f.slope * 1e3, f.intercept * 1e3, f.r2
                );
                assert!(f.slope < 0.0, "backward time must decrease with freezing");
                assert!(f.r2 > 0.9, "stage {stage}: fit not linear enough (R²={})", f.r2);
            }
            None => println!("  stage {stage}: insufficient samples"),
        }
    }
    println!("linear model confirmed: freezing removes wgrad time proportionally (Fig. 3)");
}
