//! Figure 16 (this repo's extension): the memory–throughput Pareto
//! frontier of the memory-aware freeze LP, swept **per recompute
//! policy**. Sweeping the per-device memory budget from the full card
//! down to the OOM wall, the LP's per-stage freeze-ratio floor
//! (constraint [5]) rises, forced freezing grows, and batch time
//! *falls* — freezing bought as memory headroom instead of (only)
//! speed.
//!
//! Three policies trace three frontiers:
//!
//! * `off` — the freeze-only floor (pre-recompute behavior, row
//!   numerics bit-identical to it). The sweep stops where the floor
//!   conflicts with `r_max` or the device overflows even fully frozen.
//! * `auto` — freeze up to `r_max` first, recompute only the deficit:
//!   identical to `off` wherever `off` is feasible (asserted in-bench),
//!   and it keeps going *past* `off`'s wall — recompute dominating pure
//!   freezing at tight budgets.
//! * `full` — every stage recomputes all activations: lowest floors and
//!   the deepest feasible budgets, paying the forward re-run on every
//!   backward.
//!
//! Successive budgets re-solve through one [`FreezeLpSolver`] per
//! policy, the controller's warm-start pattern: adjacent budgets move
//! only the [5] RHS entries once the same stages bind.
//!
//!     TF_BENCH_JSON=out.json cargo bench --bench fig16_memory_pareto

use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::cost::{
    peak_inflight, CostModel, MemoryError, MemoryModel, RecomputePolicy,
};
use timelyfreeze::graph::pipeline::PipelineDag;
use timelyfreeze::lp::{FreezeLpError, FreezeLpInput, FreezeLpSolver};
use timelyfreeze::metrics::Recorder;
use timelyfreeze::partition::PartitionMethod;
use timelyfreeze::schedule::Schedule;
use timelyfreeze::sim;
use timelyfreeze::types::ScheduleKind;
use timelyfreeze::util::json::Json;

const GIB: f64 = (1u64 << 30) as f64;

fn main() {
    let mut rec = Recorder::default_dir();
    for preset in ["llama-1b", "llama-8b"] {
        let cfg = ExperimentConfig::paper_preset(preset).unwrap();
        for schedule_kind in [ScheduleKind::OneFOneB, ScheduleKind::GPipe] {
            sweep(&mut rec, preset, &cfg, schedule_kind);
        }
    }
    rec.flush().unwrap();
    println!("\nrows recorded under bench_out/fig16_memory_pareto.json");
}

/// One feasible frontier row, kept for the cross-policy asserts.
struct Row {
    frac_bits: u64,
    batch_time: f64,
}

fn sweep(rec: &mut Recorder, preset: &str, cfg: &ExperimentConfig, kind: ScheduleKind) {
    let mut cfg = cfg.clone();
    cfg.schedule = kind;
    let schedule =
        Schedule::build(kind, cfg.ranks, cfg.microbatches, cfg.effective_chunks());
    let pdag = PipelineDag::from_schedule(&schedule);
    let layout = sim::build_layout(&cfg, PartitionMethod::Parameter);
    let cost = CostModel::new(
        &cfg.model,
        &cfg.gpu,
        &layout.layer_stage,
        cfg.stages(),
        cfg.microbatch_size,
        cfg.seq_len,
    );
    let mem = MemoryModel::from_presets(
        &cfg.model,
        &cfg.gpu,
        &layout.layer_stage,
        cfg.stages(),
        cfg.microbatch_size,
        cfg.seq_len,
        cfg.effective_chunks(),
    );
    let inflight = peak_inflight(&schedule);
    let w_min = pdag.weights(|a| cost.bounds(a).0);
    let w_max = pdag.weights(|a| cost.bounds(a).1);

    println!(
        "\n== {} — {} ({} ranks × {} microbatches, {:.0} GiB/device) ==",
        cfg.model.name,
        kind.name(),
        cfg.ranks,
        cfg.microbatches,
        cfg.gpu.memory_bytes / GIB
    );

    let mut off_rows: Vec<Row> = Vec::new();
    for policy in [RecomputePolicy::Off, RecomputePolicy::Auto, RecomputePolicy::Full] {
        let rows = sweep_policy(
            rec, preset, &cfg, kind, &pdag, &cost, &mem, &inflight, &w_min, &w_max, &policy,
        );
        match policy {
            RecomputePolicy::Off => off_rows = rows,
            RecomputePolicy::Auto => {
                // Wherever pure freezing is feasible, auto resolves to
                // the same plan; past the freeze-only wall it keeps
                // producing feasible rows — the domination claim.
                for off in &off_rows {
                    let auto = rows
                        .iter()
                        .find(|r| r.frac_bits == off.frac_bits)
                        .expect("auto must cover every freeze-only-feasible budget");
                    assert!(
                        auto.batch_time <= off.batch_time + 1e-9,
                        "auto worse than off at budget {}: {} vs {}",
                        f64::from_bits(off.frac_bits),
                        auto.batch_time,
                        off.batch_time
                    );
                }
                assert!(
                    rows.len() >= off_rows.len(),
                    "auto frontier shorter than freeze-only: {} vs {}",
                    rows.len(),
                    off_rows.len()
                );
            }
            RecomputePolicy::Full => {}
            RecomputePolicy::Fraction(_) => unreachable!(),
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn sweep_policy(
    rec: &mut Recorder,
    preset: &str,
    cfg: &ExperimentConfig,
    kind: ScheduleKind,
    pdag: &PipelineDag,
    cost: &CostModel,
    mem: &MemoryModel,
    inflight: &[usize],
    w_min: &[f64],
    w_max: &[f64],
    policy: &RecomputePolicy,
) -> Vec<Row> {
    let tokens = cfg.tokens_per_step() as f64;
    println!("-- recompute: {} --", policy.name());
    println!(
        "{:>8} {:>10} {:>8} {:>12} {:>12} {:>10} {:>12} {:>12}",
        "budget", "floor̄", "ρ̄", "mean r*", "P_d (s)", "tok/s", "peak GiB", "cap GiB"
    );

    let mut rows = Vec::new();
    let mut solver = FreezeLpSolver::new();
    let infeasible_row = |frac: f64, reason: &str| {
        Json::obj(vec![
            ("model", Json::str(preset)),
            ("schedule", Json::str(kind.name())),
            ("recompute", Json::str(&policy.name())),
            ("budget_frac", Json::num(frac)),
            ("feasible", Json::Bool(false)),
            ("reason", Json::str(reason)),
        ])
    };
    // Sweep from the full device down to the OOM wall in 5% steps.
    let mut frac = 1.0f64;
    while frac > 0.02 {
        let m = mem.clone().scaled_capacity(frac);
        let cap_gib = m.capacity_bytes[0] / GIB;
        // Resolve the policy against this budget's capacities — the
        // same `MemoryModel::policy_floor` core `memory_plan_for`
        // (hence the simulator and the CLI) runs, so the bench can
        // never drift from the executed recipe.
        match m.policy_floor(inflight, cfg.r_max, policy) {
            Err(e @ MemoryError::RecomputeInsufficient { .. }) => {
                println!("{frac:>8.2} {:>10} — even full recompute cannot fit: {e}", "—");
                rec.push("fig16_memory_pareto", infeasible_row(frac, "recompute_insufficient"));
                break;
            }
            Err(e) => {
                println!("{frac:>8.2} {:>10} — OOM: {e}", "—");
                rec.push("fig16_memory_pareto", infeasible_row(frac, "over_capacity"));
                break;
            }
            Ok((floor, rho)) => {
                let recomputing = rho.iter().any(|&r| r > 0.0);
                let m = if recomputing { m.apply_recompute(&rho) } else { m };
                let surcharge =
                    recomputing.then(|| cost.recompute_surcharges_for(&rho));
                let mut input =
                    FreezeLpInput::new(pdag, w_min, w_max, cfg.r_max, cfg.lambda);
                if floor.iter().any(|&r| r > 0.0) {
                    input = input.with_stage_floor(&floor);
                }
                if let Some(sur) = &surcharge {
                    input = input.with_recompute(sur);
                }
                let sol = match solver.solve(&input) {
                    Ok(s) => s,
                    Err(e) => {
                        // Record the stop marker (like the OOM branch)
                        // and end the sweep — distinguishing a genuine
                        // budget/accuracy conflict from a numeric
                        // solver failure so the JSON doesn't mislabel.
                        let reason = if matches!(e, FreezeLpError::FloorExceedsBudget { .. })
                        {
                            "floor_exceeds_r_max"
                        } else {
                            "lp_error"
                        };
                        println!("{frac:>8.2} sweep stopped ({reason}): {e}");
                        rec.push(
                            "fig16_memory_pareto",
                            infeasible_row(frac, &format!("{reason}: {e}")),
                        );
                        break;
                    }
                };
                let stage_ratios = sol.stage_ratios(pdag);
                let peak_gib = (0..cfg.stages())
                    .map(|s| m.stage_bytes(s, inflight[s], stage_ratios[s]))
                    .fold(0.0f64, f64::max)
                    / GIB;
                let floor_mean = floor.iter().sum::<f64>() / floor.len() as f64;
                let rho_mean = rho.iter().sum::<f64>() / rho.len() as f64;
                let mean_r = sol.mean_freezable_ratio(pdag);
                let tput = tokens / sol.batch_time;
                println!(
                    "{frac:>8.2} {floor_mean:>10.3} {rho_mean:>8.3} {mean_r:>12.3} {:>12.4} {tput:>10.0} {peak_gib:>12.2} {cap_gib:>12.2}",
                    sol.batch_time
                );
                // Slack: LP rows hold to simplex tolerance (kB-scale
                // once multiplied by multi-GB state sizes).
                assert!(
                    peak_gib <= cap_gib + 1e-4,
                    "plan violates its own memory budget: {peak_gib} > {cap_gib} GiB"
                );
                rows.push(Row { frac_bits: frac.to_bits(), batch_time: sol.batch_time });
                rec.push(
                    "fig16_memory_pareto",
                    Json::obj(vec![
                        ("model", Json::str(preset)),
                        ("schedule", Json::str(kind.name())),
                        ("recompute", Json::str(&policy.name())),
                        ("budget_frac", Json::num(frac)),
                        ("feasible", Json::Bool(true)),
                        ("floor_mean", Json::num(floor_mean)),
                        ("recompute_mean", Json::num(rho_mean)),
                        ("mean_ratio", Json::num(mean_r)),
                        ("batch_time", Json::num(sol.batch_time)),
                        ("throughput", Json::num(tput)),
                        ("kappa", Json::num(sol.kappa())),
                        ("peak_gib", Json::num(peak_gib)),
                        ("cap_gib", Json::num(cap_gib)),
                        ("lp_iterations", Json::num(sol.iterations as f64)),
                    ]),
                );
            }
        }
        frac -= 0.05;
    }
    rows
}
