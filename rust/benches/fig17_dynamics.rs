//! Figure 17 (this repo's extension): runtime dynamics and
//! observation-driven online replanning.
//!
//! The LP of §4 plans against the world the monitoring phase measured.
//! This sweep injects dynamics that world never saw — a straggler rank
//! appearing mid-run, per-action jitter, link contention — and compares,
//! per scenario:
//!
//! * the **static** plan (Algorithm 1 as published: one solve at `T_m`);
//! * the **replanning** run (`replan_interval > 0`): the event engine's
//!   observed action times are distilled into a
//!   [`CostProfile`](timelyfreeze::cost::CostProfile) and the
//!   warm-started LP re-solves at phase boundaries;
//!
//! reporting steady throughput, the recovery replanning buys, and the
//! planned-vs-realized batch-time gap (how far execution drifted from
//! the plan's model — near zero when replanning tracks the dynamics).
//!
//!     TF_BENCH_JSON=out.json cargo bench --bench fig17_dynamics
//!     TF_BENCH_QUICK=1 cargo bench --bench fig17_dynamics   # CI smoke

use timelyfreeze::bench_support::tables::apply_quick;
use timelyfreeze::config::{ExperimentConfig, Scenario};
use timelyfreeze::metrics::Recorder;
use timelyfreeze::sim;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};
use timelyfreeze::util::json::Json;
use timelyfreeze::util::stats;
use timelyfreeze::util::table::Table;

fn main() {
    let mut rec = Recorder::default_dir();
    let mut base = ExperimentConfig::paper_preset("llama-1b").unwrap();
    base.schedule = ScheduleKind::OneFOneB;
    base.method = FreezeMethod::TimelyFreeze;
    apply_quick(&mut base);
    // Dynamics appear after the ramp (T_f) so the static plan is already
    // committed when the world shifts; replans fire twice per remaining
    // run.
    let onset = base.phases.t_freeze + (base.steps - base.phases.t_freeze) / 4;
    let replan_every = ((base.steps - base.phases.t_monitor) / 4).max(1);
    let scenarios: Vec<Scenario> = vec![
        Scenario::calm(),
        Scenario::calm()
            .with_straggler(1, 1.5, onset)
            .relabel(&format!("straggler:1x1.5@{onset}")),
        Scenario::calm()
            .with_straggler(2, 2.0, onset)
            .with_jitter(0.05, 0)
            .relabel(&format!("straggler:2x2.0@{onset}+jitter:0.05")),
        Scenario::jittery(0.10),
        Scenario::calm()
            .with_link(None, 3.0, onset)
            .relabel(&format!("link:3.0@{onset}")),
    ];

    println!(
        "fig17: {} — {} · {} steps, onset {}, replan every {}",
        base.model.name, base.schedule.name(), base.steps, onset, replan_every
    );
    let mut t = Table::new(
        "runtime dynamics — static plan vs online replanning",
        &[
            "Scenario",
            "Static tok/s",
            "Replan tok/s",
            "Recovery %",
            "Plan gap static",
            "Plan gap replan",
            "Replans",
            "Replan p50",
            "Replan p95",
        ],
    );
    let tokens = base.tokens_per_step() as f64;
    for sc in &scenarios {
        let mut static_cfg = base.clone();
        static_cfg.scenario = Some(sc.clone());
        let static_run = sim::run(&static_cfg).expect("scenario config must be feasible");
        let mut replan_cfg = static_cfg.clone();
        replan_cfg.replan_interval = replan_every;
        let replan_run = sim::run(&replan_cfg).expect("scenario config must be feasible");

        // Planned-vs-realized: the LP's expected batch time against the
        // realized mean steady step time.
        let gap = |r: &sim::SimResult| -> f64 {
            let realized = tokens / r.steady_throughput;
            r.planned_batch_time
                .map(|p| 100.0 * (realized - p) / p)
                .unwrap_or(f64::NAN)
        };
        let recovery = 100.0
            * (replan_run.steady_throughput - static_run.steady_throughput)
            / static_run.steady_throughput;
        // Per-replan latency (profile distillation + warm LP re-solve):
        // the "cheap enough to re-solve online" claim as an artifact.
        let lat = &replan_run.replan_latency_s;
        let lat_p50 = stats::percentile(lat, 50.0);
        let lat_p95 = stats::percentile(lat, 95.0);
        t.row(vec![
            sc.to_string(),
            format!("{:.0}", static_run.steady_throughput),
            format!("{:.0}", replan_run.steady_throughput),
            format!("{recovery:+.2}"),
            format!("{:+.2}%", gap(&static_run)),
            format!("{:+.2}%", gap(&replan_run)),
            format!("{}", replan_run.replans),
            format!("{:.1}µs", lat_p50 * 1e6),
            format!("{:.1}µs", lat_p95 * 1e6),
        ]);
        rec.push(
            "fig17_dynamics",
            Json::obj(vec![
                ("scenario", Json::str(&sc.to_string())),
                ("static_steady_tps", Json::num(static_run.steady_throughput)),
                ("replan_steady_tps", Json::num(replan_run.steady_throughput)),
                ("recovery_pct", Json::num(recovery)),
                ("static_plan_gap_pct", Json::num(gap(&static_run))),
                ("replan_plan_gap_pct", Json::num(gap(&replan_run))),
                ("replans", Json::num(replan_run.replans as f64)),
                ("replan_latency_p50_s", Json::num(lat_p50)),
                ("replan_latency_p95_s", Json::num(lat_p95)),
                ("static_acc", Json::num(static_run.accuracy)),
                ("replan_acc", Json::num(replan_run.accuracy)),
            ]),
        );
        // The acceptance contract: under structural dynamics (a
        // straggler or a slowed link — worlds with a *systematically*
        // shifted critical path) the replanned run must not lose to the
        // static plan. Noise-only scenarios (calm, pure jitter) get a
        // looser bound: there is nothing structural to recover, and a
        // short window of noisy observations may wiggle the plan.
        let structural = !sc.stragglers.is_empty() || !sc.links.is_empty();
        let floor = if structural { 0.995 } else { 0.98 };
        assert!(
            replan_run.steady_throughput >= static_run.steady_throughput * floor,
            "{sc}: replanning lost throughput ({} vs {})",
            replan_run.steady_throughput,
            static_run.steady_throughput
        );
    }
    println!("{}", t.render());
    rec.flush().unwrap();
    println!("rows recorded under bench_out/fig17_dynamics.json");
}
