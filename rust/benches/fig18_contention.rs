//! Figure 18 (this repo's extension): contention-aware freeze planning
//! on a shared-link fabric vs the contention-free strawman.
//!
//! Both plans spend the same per-stage freeze budget (constraint [4]
//! binds either way) and both execute on the *same* contended fabric —
//! the difference is what the LP believed about communication when it
//! placed that budget:
//!
//! * **aware** — cross-rank edges priced as a latency floor plus a
//!   freeze-shrinkable serialization share (`NetLpPricing::Contended`):
//!   the LP's critical path reflects fair-shared links, and freezing a
//!   sender visibly relaxes the spine terms, so the budget lands on the
//!   microbatches whose gradient messages gate the contended makespan;
//! * **blind** — cross-rank edges priced at their dedicated-link cost
//!   (`net_blind_lp`, `NetLpPricing::Dedicated`): the LP believes every
//!   transfer has the fabric to itself, sees a compute-dominated
//!   critical path, and places the same budget by compute alone.
//!
//! The sweep grids island size × spine bandwidth on GPipe and 1F1B.
//! Where the spine is fast, contention is a rounding error and the two
//! plans realize (near-)identically; as it tightens, serialization
//! dominates and the aware placement pulls ahead. The acceptance
//! contract is the paper-style flip: at least one grid cell where the
//! contention-aware plan strictly beats the contention-free plan
//! re-evaluated under contention.
//!
//!     TF_BENCH_JSON=out.json cargo bench --bench fig18_contention
//!     TF_BENCH_QUICK=1 cargo bench --bench fig18_contention   # CI smoke
use timelyfreeze::bench_support::tables::apply_quick;
use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::metrics::Recorder;
use timelyfreeze::net::Topology;
use timelyfreeze::sim;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};
use timelyfreeze::util::json::Json;
use timelyfreeze::util::table::Table;

fn main() {
    let mut rec = Recorder::default_dir();
    let mut base = ExperimentConfig::paper_preset("llama-1b").unwrap();
    base.method = FreezeMethod::TimelyFreeze;
    // A tight accuracy budget sharpens the planning question: with only
    // half the stage freezable on average, *which* microbatches' senders
    // get the ratio decides which messages shrink on the wire.
    base.r_max = 0.5;
    apply_quick(&mut base);
    let bytes = base.model.boundary_bytes(base.microbatch_size, base.seq_len);
    println!(
        "fig18: {} — {} steps, {:.1} MB per boundary message, r_max {}",
        base.model.name,
        base.steps,
        bytes / 1e6,
        base.r_max
    );

    // Island links stay NVLink-fast; the spine sweeps from IB-class down
    // to the congested regime where a 34 MB gradient serializes for
    // ~170 ms against ~10 ms of stage compute.
    let islands = [1usize, 2];
    let spines = ["2e8", "1e9", "1e11"];
    let mut flips = 0usize;
    for kind in [ScheduleKind::GPipe, ScheduleKind::OneFOneB] {
        let mut t = Table::new(
            &format!("{} — steady batch time (s), aware vs contention-blind plan", kind.name()),
            &["Island", "Spine B/s", "Aware", "Blind", "Aware wins by %"],
        );
        for &island in &islands {
            for spine in spines {
                let spec = format!("island:{island}x6e10,spine:{spine},lat:0.0002");
                let mut aware_cfg = base.clone();
                aware_cfg.schedule = kind;
                aware_cfg.net = Some(Topology::parse(&spec).unwrap());
                let mut blind_cfg = aware_cfg.clone();
                blind_cfg.net_blind_lp = true;
                let aware = sim::run(&aware_cfg).expect("aware cell must run");
                let blind = sim::run(&blind_cfg).expect("blind cell must run");
                let gain =
                    100.0 * (blind.batch_time_final - aware.batch_time_final)
                        / blind.batch_time_final;
                if aware.batch_time_final < blind.batch_time_final {
                    flips += 1;
                }
                t.row(vec![
                    format!("{island}"),
                    spine.to_string(),
                    format!("{:.4}", aware.batch_time_final),
                    format!("{:.4}", blind.batch_time_final),
                    format!("{gain:+.2}"),
                ]);
                rec.push(
                    "fig18_contention",
                    Json::obj(vec![
                        ("schedule", Json::str(kind.name())),
                        ("island_size", Json::num(island as f64)),
                        ("spine_spec", Json::str(spine)),
                        ("aware_batch_s", Json::num(aware.batch_time_final)),
                        ("blind_batch_s", Json::num(blind.batch_time_final)),
                        ("aware_tps", Json::num(aware.throughput)),
                        ("blind_tps", Json::num(blind.throughput)),
                        ("aware_gain_pct", Json::num(gain)),
                    ]),
                );
                // Sanity inside every cell: same budget, same fabric —
                // the plans may differ only in placement, so realized
                // freeze ratios agree closely and nobody wins by
                // freezing more.
                assert!(
                    (aware.freeze_ratio - blind.freeze_ratio).abs() < 2.0,
                    "{} island {island} spine {spine}: freeze ratios diverged \
                     ({:.2}% vs {:.2}%) — the budget should pin them",
                    kind.name(),
                    aware.freeze_ratio,
                    blind.freeze_ratio
                );
                // Determinism: each cell reproduces bit-identically.
                let again = sim::run(&aware_cfg).expect("aware cell must rerun");
                assert_eq!(
                    aware.batch_time_final.to_bits(),
                    again.batch_time_final.to_bits(),
                    "{} island {island} spine {spine}: contended runs must be deterministic",
                    kind.name()
                );
            }
        }
        println!("{}", t.render());
    }
    // The acceptance contract (the figure's point): somewhere on the
    // grid, planning against the contended fabric must realize a
    // strictly faster steady step than the contention-free plan run on
    // that same fabric.
    assert!(
        flips >= 1,
        "no grid cell had the contention-aware plan beat the blind plan"
    );
    println!("contention-aware plan wins in {flips}/12 grid cells");

    rec.flush().unwrap();
    println!("rows recorded under bench_out/fig18_contention.json");
}
