//! Figure 19 (this repo's extension): fault injection and elastic
//! recovery vs the restart-from-scratch baseline.
//!
//! A rank crash mid-run forces a choice: **elastic** recovery
//! repartitions the layers over the survivors, rebuilds the
//! schedule/DAG/memory floors for the reduced fleet, replans the freeze
//! ratios, and resumes from the last microbatch checkpoint boundary;
//! **restart** rebuilds on the survivors but replays every optimizer
//! step from step 1 after a full weight broadcast. This sweep measures
//! the gap as *throughput retention* — the faulted run's tokens/s over
//! the fault-free reference on the same schedule — across:
//!
//! * all four schedules (GPipe, 1F1B, interleaved, ZBV) at a fixed late
//!   crash (the worst case for restart: almost the whole run replays);
//! * crash time (early / mid / late) on 1F1B — early crashes are where
//!   restart is cheapest, so the retention curves converge there;
//! * fleet size on 1F1B — larger fleets lose a smaller capacity
//!   fraction per crash, so elastic retention *rises* with scale while
//!   restart's replay cost does not shrink.
//!
//! The acceptance contract asserted per schedule: elastic retention
//! strictly beats restart retention for the late crash, and fixed-seed
//! fault runs are bit-identical.
//!
//!     TF_BENCH_JSON=out.json cargo bench --bench fig19_elasticity
//!     TF_BENCH_QUICK=1 cargo bench --bench fig19_elasticity   # CI smoke

use timelyfreeze::bench_support::tables::apply_quick;
use timelyfreeze::config::{ExperimentConfig, RecoveryStrategy, Scenario};
use timelyfreeze::metrics::Recorder;
use timelyfreeze::sim;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};
use timelyfreeze::util::json::Json;
use timelyfreeze::util::table::Table;

fn faulted(
    base: &ExperimentConfig,
    crash_at: usize,
    strategy: RecoveryStrategy,
) -> sim::SimResult {
    let mut cfg = base.clone();
    cfg.scenario = Some(Scenario::crash(1, crash_at));
    cfg.recovery = Some(strategy);
    sim::run(&cfg).expect("fault config must be recoverable")
}

fn main() {
    let mut rec = Recorder::default_dir();
    let mut base = ExperimentConfig::paper_preset("llama-1b").unwrap();
    base.method = FreezeMethod::TimelyFreeze;
    apply_quick(&mut base);
    // Within-step salvage at every other microbatch boundary.
    base.ckpt_interval = 2;
    // The late crash: three quarters of the way through the post-ramp
    // regime, when restart has the most committed work to throw away.
    let late = base.phases.t_freeze + 3 * (base.steps - base.phases.t_freeze) / 4;
    let schedules = [
        ScheduleKind::GPipe,
        ScheduleKind::OneFOneB,
        ScheduleKind::Interleaved1F1B,
        ScheduleKind::ZeroBubbleV,
    ];

    println!(
        "fig19: {} — {} steps, crash rank 1 @ {late}, ckpt every {} microbatches",
        base.model.name, base.steps, base.ckpt_interval
    );
    let mut t = Table::new(
        "elastic recovery vs restart-from-scratch — late crash, per schedule",
        &[
            "Schedule",
            "Ref tok/s",
            "Elastic tok/s",
            "Restart tok/s",
            "Elastic ret %",
            "Restart ret %",
            "Lost mb (e/r)",
            "Recovery s (e/r)",
        ],
    );
    for schedule in schedules {
        let mut ref_cfg = base.clone();
        ref_cfg.schedule = schedule;
        let reference = sim::run(&ref_cfg).expect("fault-free reference must run");
        let elastic = faulted(&ref_cfg, late, RecoveryStrategy::Elastic);
        let restart = faulted(&ref_cfg, late, RecoveryStrategy::Restart);
        let e_ret = 100.0 * elastic.throughput / reference.throughput;
        let r_ret = 100.0 * restart.throughput / reference.throughput;
        t.row(vec![
            schedule.name().to_string(),
            format!("{:.0}", reference.throughput),
            format!("{:.0}", elastic.throughput),
            format!("{:.0}", restart.throughput),
            format!("{e_ret:.1}"),
            format!("{r_ret:.1}"),
            format!("{}/{}", elastic.lost_microbatches, restart.lost_microbatches),
            format!("{:.1}/{:.1}", elastic.recovery_time_s, restart.recovery_time_s),
        ]);
        rec.push(
            "fig19_elasticity",
            Json::obj(vec![
                ("sweep", Json::str("schedule")),
                ("schedule", Json::str(schedule.name())),
                ("crash_at", Json::num(late as f64)),
                ("ranks", Json::num(ref_cfg.ranks as f64)),
                ("reference_tps", Json::num(reference.throughput)),
                ("elastic_tps", Json::num(elastic.throughput)),
                ("restart_tps", Json::num(restart.throughput)),
                ("elastic_retention_pct", Json::num(e_ret)),
                ("restart_retention_pct", Json::num(r_ret)),
                ("elastic_lost_mb", Json::num(elastic.lost_microbatches as f64)),
                ("restart_lost_mb", Json::num(restart.lost_microbatches as f64)),
                ("elastic_recovery_s", Json::num(elastic.recovery_time_s)),
                ("restart_recovery_s", Json::num(restart.recovery_time_s)),
                ("elastic_final_ranks", Json::num(elastic.final_ranks as f64)),
                ("elastic_acc", Json::num(elastic.accuracy)),
                ("restart_acc", Json::num(restart.accuracy)),
            ]),
        );
        // The acceptance contract: with a late crash the elastic path
        // must strictly beat replaying the run from scratch, on every
        // schedule.
        assert!(
            e_ret > r_ret,
            "{}: elastic retention {e_ret:.1}% must beat restart {r_ret:.1}%",
            schedule.name()
        );
        assert_eq!(elastic.final_ranks, ref_cfg.ranks - 1);
        assert_eq!(restart.final_ranks, ref_cfg.ranks - 1);
        // Determinism contract: a fixed-seed fault run is bit-identical.
        let again = faulted(&ref_cfg, late, RecoveryStrategy::Elastic);
        assert_eq!(
            elastic.throughput.to_bits(),
            again.throughput.to_bits(),
            "{}: fault runs must be bit-identical",
            schedule.name()
        );
        assert_eq!(elastic.accuracy.to_bits(), again.accuracy.to_bits());
        assert_eq!(elastic.recovery_time_s.to_bits(), again.recovery_time_s.to_bits());
    }
    println!("{}", t.render());

    // ---- crash-time sweep (1F1B): where does restart stop competing? ----
    let mut sweep_cfg = base.clone();
    sweep_cfg.schedule = ScheduleKind::OneFOneB;
    let sweep_ref = sim::run(&sweep_cfg).expect("reference");
    let span = base.steps - base.phases.t_warmup;
    let mut t2 = Table::new(
        "crash-time sweep — 1F1B, retention % vs when the crash lands",
        &["Crash step", "Elastic ret %", "Restart ret %", "Gap pts"],
    );
    for frac_num in [1usize, 2, 3] {
        let crash_at = base.phases.t_warmup + frac_num * span / 4;
        let elastic = faulted(&sweep_cfg, crash_at, RecoveryStrategy::Elastic);
        let restart = faulted(&sweep_cfg, crash_at, RecoveryStrategy::Restart);
        let e_ret = 100.0 * elastic.throughput / sweep_ref.throughput;
        let r_ret = 100.0 * restart.throughput / sweep_ref.throughput;
        t2.row(vec![
            format!("{crash_at}"),
            format!("{e_ret:.1}"),
            format!("{r_ret:.1}"),
            format!("{:+.1}", e_ret - r_ret),
        ]);
        rec.push(
            "fig19_elasticity",
            Json::obj(vec![
                ("sweep", Json::str("crash_time")),
                ("schedule", Json::str("1F1B")),
                ("crash_at", Json::num(crash_at as f64)),
                ("elastic_retention_pct", Json::num(e_ret)),
                ("restart_retention_pct", Json::num(r_ret)),
            ]),
        );
    }
    println!("{}", t2.render());

    // ---- fleet-size sweep (1F1B): retention vs provisioned ranks ----
    let mut t3 = Table::new(
        "fleet-size sweep — 1F1B, late crash of rank 1",
        &["Ranks", "Elastic ret %", "Restart ret %", "Elastic final ranks"],
    );
    for ranks in [3usize, 4, 6] {
        let mut cfg = base.clone();
        cfg.schedule = ScheduleKind::OneFOneB;
        cfg.ranks = ranks;
        let reference = sim::run(&cfg).expect("reference");
        let elastic = faulted(&cfg, late, RecoveryStrategy::Elastic);
        let restart = faulted(&cfg, late, RecoveryStrategy::Restart);
        let e_ret = 100.0 * elastic.throughput / reference.throughput;
        let r_ret = 100.0 * restart.throughput / reference.throughput;
        t3.row(vec![
            format!("{ranks}"),
            format!("{e_ret:.1}"),
            format!("{r_ret:.1}"),
            format!("{}", elastic.final_ranks),
        ]);
        rec.push(
            "fig19_elasticity",
            Json::obj(vec![
                ("sweep", Json::str("fleet_size")),
                ("schedule", Json::str("1F1B")),
                ("ranks", Json::num(ranks as f64)),
                ("crash_at", Json::num(late as f64)),
                ("elastic_retention_pct", Json::num(e_ret)),
                ("restart_retention_pct", Json::num(r_ret)),
            ]),
        );
        assert_eq!(elastic.final_ranks, ranks - 1);
    }
    println!("{}", t3.render());

    rec.flush().unwrap();
    println!("rows recorded under bench_out/fig19_elasticity.json");
}
