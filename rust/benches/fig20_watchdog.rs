//! Figure 20 (this repo's extension): within-batch transients and the
//! divergence watchdog.
//!
//! Fixed-interval replanning (fig17) reacts to *persistent* regime
//! shifts but is blind between boundaries: a transient straggler that
//! ramps up and decays inside one replan window is paid for in full.
//! This sweep injects within-batch `ramp:`/`burst:` dynamics and
//! compares, per transient scenario:
//!
//! * the **static** plan (no replanning — the full transient damage);
//! * **interval-only** replanning at a coarse fixed cadence;
//! * the **watchdog** (`--watchdog 3`): the two-timescale EWMA monitor
//!   over realized-vs-planned per-rank slack fires an event-driven
//!   replan within a few steps of the divergence, and again when the
//!   transient decays;
//! * **watchdog + event-wc**: the same, on the bounded work-conserving
//!   executor.
//!
//! The acceptance contract: in at least one grid cell the watchdog
//! recovers more than half of the transient throughput loss that
//! interval-only replanning leaves on the table.
//!
//!     TF_BENCH_JSON=out.json cargo bench --bench fig20_watchdog
//!     TF_BENCH_QUICK=1 cargo bench --bench fig20_watchdog   # CI smoke

use timelyfreeze::bench_support::tables::apply_quick;
use timelyfreeze::config::{ExecMode, ExperimentConfig, Scenario};
use timelyfreeze::metrics::Recorder;
use timelyfreeze::sim;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};
use timelyfreeze::util::json::Json;
use timelyfreeze::util::table::Table;

struct Mode {
    name: &'static str,
    interval: usize,
    watchdog: Option<f64>,
    exec: ExecMode,
}

fn main() {
    let quick = std::env::var("TF_BENCH_QUICK").as_deref() == Ok("1");
    let mut rec = Recorder::default_dir();
    let mut base = ExperimentConfig::paper_preset("llama-1b").unwrap();
    base.schedule = ScheduleKind::OneFOneB;
    base.method = FreezeMethod::TimelyFreeze;
    apply_quick(&mut base);
    // Transient windows live entirely inside one coarse replan interval,
    // so interval-only replanning cannot react before the decay.
    let span = base.steps - base.phases.t_freeze;
    let (from, until) = (base.phases.t_freeze + span / 4, base.phases.t_freeze + 3 * span / 4);
    let coarse = (base.steps - base.phases.t_monitor) / 2;
    let scenarios: Vec<Scenario> = vec![
        Scenario::transient(1, 3.0, from, until),
        Scenario::transient(2, 2.0, from, until),
        Scenario::calm()
            .with_ramp(1, 2.5, from, until)
            .with_burst(0.1, from, until)
            .relabel(&format!("ramp:1x2.5@{from}-{until}+burst:0.1")),
    ];
    let modes = [
        Mode { name: "static", interval: 0, watchdog: None, exec: ExecMode::Event },
        Mode { name: "interval", interval: coarse, watchdog: None, exec: ExecMode::Event },
        Mode { name: "watchdog", interval: 0, watchdog: Some(3.0), exec: ExecMode::Event },
        Mode { name: "watchdog+wc", interval: 0, watchdog: Some(3.0), exec: ExecMode::EventWc },
    ];

    let calm = sim::run(&base).expect("calm baseline must run");
    println!(
        "fig20: {} — {} · {} steps, transient window {}-{}, coarse interval {}",
        base.model.name, base.schedule.name(), base.steps, from, until, coarse
    );
    let mut t = Table::new(
        "within-batch transients — static vs interval vs watchdog",
        &["Scenario", "Mode", "Steady tok/s", "Loss vs calm %", "Replans", "Triggers", "Degraded"],
    );
    // Best fraction, over the grid, of interval-only's remaining loss
    // that the watchdog clawed back.
    let mut best_recovery = f64::NEG_INFINITY;
    for sc in &scenarios {
        let mut by_mode: Vec<(usize, sim::SimResult)> = Vec::new();
        for (i, m) in modes.iter().enumerate() {
            let mut cfg = base.clone();
            cfg.scenario = Some(sc.clone());
            cfg.replan_interval = m.interval;
            cfg.watchdog = m.watchdog;
            cfg.exec = m.exec;
            let r = sim::run(&cfg).expect("transient configs must be feasible");
            assert!(r.throughput.is_finite() && r.throughput > 0.0, "{sc} / {}", m.name);
            let loss = 100.0 * (calm.steady_throughput - r.steady_throughput)
                / calm.steady_throughput;
            t.row(vec![
                sc.to_string(),
                m.name.to_string(),
                format!("{:.0}", r.steady_throughput),
                format!("{loss:+.2}"),
                format!("{}", r.replans),
                format!("{}", r.watchdog_triggers.len()),
                if r.degradation.is_empty() { "-".into() } else { r.degradation.summary() },
            ]);
            rec.push(
                "fig20_watchdog",
                Json::obj(vec![
                    ("scenario", Json::str(&sc.to_string())),
                    ("mode", Json::str(m.name)),
                    ("steady_tps", Json::num(r.steady_throughput)),
                    ("loss_vs_calm_pct", Json::num(loss)),
                    ("replans", Json::num(r.replans as f64)),
                    ("watchdog_triggers", Json::num(r.watchdog_triggers.len() as f64)),
                    ("replan_failures", Json::num(r.replan_failures as f64)),
                    (
                        "degradation",
                        Json::Arr(
                            r.degradation
                                .events
                                .iter()
                                .map(|e| {
                                    Json::obj(vec![
                                        ("step", Json::num(e.step as f64)),
                                        ("rung", Json::str(e.rung.name())),
                                        ("cause", Json::str(&e.cause)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("accuracy", Json::num(r.accuracy)),
                ]),
            );
            by_mode.push((i, r));
        }
        let tps = |name: &str| {
            by_mode
                .iter()
                .find(|(i, _)| modes[*i].name == name)
                .map(|(_, r)| r.steady_throughput)
                .unwrap()
        };
        let (stat, int, wd) = (tps("static"), tps("interval"), tps("watchdog"));
        // Watchdog must never do worse than the static plan it augments.
        assert!(wd >= stat * 0.97, "{sc}: watchdog lost to static ({wd} vs {stat})");
        // Fraction of the loss interval-only leaves (vs calm) that the
        // watchdog recovers. Positive denominator = interval-only did
        // not already reach calm throughput.
        let left = calm.steady_throughput - int;
        if left > 1e-9 {
            best_recovery = best_recovery.max((wd - int) / left);
        }
    }
    println!("{}", t.render());
    println!("best watchdog recovery of interval-only's remaining loss: {best_recovery:+.2}");
    // The headline claim — skipped under TF_BENCH_QUICK, where shrunken
    // windows leave the watchdog too few steps to act on.
    if !quick {
        assert!(
            best_recovery > 0.5,
            "watchdog should recover >50% of interval-only's remaining transient loss \
             in at least one grid cell, best was {best_recovery:.2}"
        );
    }
    rec.flush().unwrap();
    println!("rows recorded under bench_out/fig20_watchdog.json");
}
