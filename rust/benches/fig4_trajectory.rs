//! Figure 4: freeze ratio and training throughput across training steps —
//! the progressive ramp from T_m to T_f and the corresponding throughput
//! climb.
use timelyfreeze::bench_support::tables::apply_quick;
use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::metrics::Recorder;
use timelyfreeze::sim;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};
use timelyfreeze::util::json::Json;

fn main() {
    let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
    apply_quick(&mut cfg);
    cfg.schedule = ScheduleKind::OneFOneB;
    cfg.method = FreezeMethod::TimelyFreeze;
    let r = sim::run(&cfg).expect("feasible config");
    println!(
        "Figure 4 — {} · 1F1B · TimelyFreeze (T_w={} T_m={} T_f={})",
        cfg.model.name, cfg.phases.t_warmup, cfg.phases.t_monitor, cfg.phases.t_freeze
    );
    println!("{:>8} {:>12} {:>16}", "step", "freeze ratio", "tokens/s");
    let mut rec = Recorder::default_dir();
    for p in &r.trajectory {
        println!("{:>8} {:>12.3} {:>16.0}", p.step, p.mean_afr, p.throughput);
        rec.push(
            "fig4_trajectory",
            Json::obj(vec![
                ("step", Json::num(p.step as f64)),
                ("freeze_ratio", Json::num(p.mean_afr)),
                ("throughput", Json::num(p.throughput)),
            ]),
        );
    }
    // The figure's qualitative claims, asserted:
    let before: Vec<&sim::TrajPoint> =
        r.trajectory.iter().filter(|p| p.step <= cfg.phases.t_warmup).collect();
    let after: Vec<&sim::TrajPoint> =
        r.trajectory.iter().filter(|p| p.step > cfg.phases.t_freeze).collect();
    if let (Some(b), Some(a)) = (before.last(), after.last()) {
        assert!(a.mean_afr > b.mean_afr, "ramp must raise the freeze ratio");
        assert!(a.throughput > b.throughput, "throughput must climb with it");
        println!(
            "\nthroughput {} → {} tokens/s as freeze ratio {:.2} → {:.2}",
            b.throughput as u64, a.throughput as u64, b.mean_afr, a.mean_afr
        );
    }
    rec.flush().unwrap();
}
