//! Figure 5: accuracy–throughput trade-off (Pareto frontier) for
//! LLaMA-1B/8B/13B under all four schedules and six methods.
use timelyfreeze::bench_support::tables::apply_quick;
use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::metrics::Recorder;
use timelyfreeze::sim;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};
use timelyfreeze::util::json::Json;

fn main() {
    let mut rec = Recorder::default_dir();
    for preset in ["llama-1b", "llama-8b", "llama-13b"] {
        for schedule in ScheduleKind::all() {
            println!("\n== {} — {} ==", preset, schedule.name());
            println!("{:>26} {:>12} {:>10}  pareto?", "method", "tokens/s", "acc");
            let mut points = Vec::new();
            for method in FreezeMethod::all() {
                let mut cfg = ExperimentConfig::paper_preset(preset).unwrap();
                apply_quick(&mut cfg);
                cfg.schedule = schedule;
                cfg.method = method;
                let r = sim::run(&cfg);
                points.push((method, r.throughput, r.accuracy));
            }
            for &(m, t, a) in &points {
                // On the frontier iff no other point dominates it.
                let dominated = points
                    .iter()
                    .any(|&(m2, t2, a2)| m2 != m && t2 >= t && a2 >= a && (t2 > t || a2 > a));
                println!(
                    "{:>26} {:>12.0} {:>10.2}  {}",
                    m.name(),
                    t,
                    a,
                    if dominated { "" } else { "frontier" }
                );
                rec.push(
                    "fig5_pareto",
                    Json::obj(vec![
                        ("model", Json::str(preset)),
                        ("schedule", Json::str(schedule.name())),
                        ("method", Json::str(m.name())),
                        ("throughput", Json::num(t)),
                        ("accuracy", Json::num(a)),
                        ("frontier", Json::Bool(!dominated)),
                    ]),
                );
            }
        }
    }
    rec.flush().unwrap();
}
