//! Figure 5: accuracy–throughput trade-off (Pareto frontier) for
//! LLaMA-1B/8B/13B under all four schedules and six methods. The full
//! model × schedule × method grid fans out across worker threads (every
//! cell is an independent seeded run); printing stays in grid order.
use timelyfreeze::bench_support::parallel::map_parallel;
use timelyfreeze::bench_support::tables::apply_quick;
use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::metrics::Recorder;
use timelyfreeze::sim;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};
use timelyfreeze::util::json::Json;

fn main() {
    let presets = ["llama-1b", "llama-8b", "llama-13b"];
    let grid: Vec<(&str, ScheduleKind, FreezeMethod)> = presets
        .iter()
        .flat_map(|&p| {
            ScheduleKind::all()
                .into_iter()
                .flat_map(move |s| FreezeMethod::all().into_iter().map(move |m| (p, s, m)))
        })
        .collect();
    let runs: Vec<(FreezeMethod, f64, f64)> = map_parallel(&grid, |&(preset, schedule, method)| {
        let mut cfg = ExperimentConfig::paper_preset(preset).unwrap();
        apply_quick(&mut cfg);
        cfg.schedule = schedule;
        cfg.method = method;
        let r = sim::run(&cfg).expect("feasible config");
        (method, r.throughput, r.accuracy)
    });

    let mut rec = Recorder::default_dir();
    let mut runs = runs.into_iter();
    for preset in presets {
        for schedule in ScheduleKind::all() {
            println!("\n== {} — {} ==", preset, schedule.name());
            println!("{:>26} {:>12} {:>10}  pareto?", "method", "tokens/s", "acc");
            let points: Vec<(FreezeMethod, f64, f64)> =
                FreezeMethod::all().iter().map(|_| runs.next().unwrap()).collect();
            for &(m, t, a) in &points {
                // On the frontier iff no other point dominates it.
                let dominated = points
                    .iter()
                    .any(|&(m2, t2, a2)| m2 != m && t2 >= t && a2 >= a && (t2 > t || a2 > a));
                println!(
                    "{:>26} {:>12.0} {:>10.2}  {}",
                    m.name(),
                    t,
                    a,
                    if dominated { "" } else { "frontier" }
                );
                rec.push(
                    "fig5_pareto",
                    Json::obj(vec![
                        ("model", Json::str(preset)),
                        ("schedule", Json::str(schedule.name())),
                        ("method", Json::str(m.name())),
                        ("throughput", Json::num(t)),
                        ("accuracy", Json::num(a)),
                        ("frontier", Json::Bool(!dominated)),
                    ]),
                );
            }
        }
    }
    rec.flush().unwrap();
}
