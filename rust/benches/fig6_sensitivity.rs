//! Figure 6: freezing-controller sensitivity on LLaMA-1B / 1F1B —
//! r_max for TimelyFreeze, T_APF for APF, P_Auto for AutoFreeze.
use timelyfreeze::bench_support::tables::apply_quick;
use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::metrics::Recorder;
use timelyfreeze::sim;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};
use timelyfreeze::util::json::Json;

fn run(cfg: &ExperimentConfig) -> (f64, f64, f64) {
    let r = sim::run(cfg).expect("feasible config");
    (r.throughput, r.accuracy, r.freeze_ratio)
}

fn main() {
    let base = {
        let mut c = ExperimentConfig::paper_preset("llama-1b").unwrap();
        apply_quick(&mut c);
        c.schedule = ScheduleKind::OneFOneB;
        c
    };
    let mut rec = Recorder::default_dir();
    let mut record = |controller: &str, value: f64, t: f64, a: f64, fr: f64| {
        println!("{controller:>14} = {value:<8} → {t:>8.0} tok/s  acc {a:>6.2}  frz {fr:>6.2}%");
        rec.push(
            "fig6_sensitivity",
            Json::obj(vec![
                ("controller", Json::str(controller)),
                ("value", Json::num(value)),
                ("throughput", Json::num(t)),
                ("accuracy", Json::num(a)),
                ("freeze_ratio", Json::num(fr)),
            ]),
        );
    };

    println!("— TimelyFreeze r_max sweep —");
    let mut prev_thpt = 0.0;
    let mut monotone = true;
    for r_max in [0.2, 0.35, 0.5, 0.65, 0.8, 0.9] {
        let mut cfg = base.clone();
        cfg.method = FreezeMethod::TimelyFreeze;
        cfg.r_max = r_max;
        let (t, a, fr) = run(&cfg);
        if t + 1e-9 < prev_thpt {
            monotone = false;
        }
        prev_thpt = t;
        record("r_max", r_max, t, a, fr);
    }
    println!("  throughput monotone in r_max: {monotone}");

    println!("— APF T_APF sweep —");
    for t_apf in [0.05, 0.15, 0.3, 0.45, 0.6] {
        let mut cfg = base.clone();
        cfg.method = FreezeMethod::Apf;
        cfg.apf.threshold = t_apf;
        let (t, a, fr) = run(&cfg);
        record("T_APF", t_apf, t, a, fr);
    }

    println!("— AutoFreeze P_Auto sweep —");
    for p in [20.0, 40.0, 60.0, 80.0, 95.0] {
        let mut cfg = base.clone();
        cfg.method = FreezeMethod::AutoFreeze;
        cfg.auto.percentile = p;
        let (t, a, fr) = run(&cfg);
        record("P_Auto", p, t, a, fr);
    }
    rec.flush().unwrap();
}
