//! Figures 7–13 (Appendix F): pipeline-execution Gantt charts for the
//! four schedules × four methods at 4 GPUs (8B), 6 GPUs (1B, M=6), and
//! 8 GPUs (GPipe), with the batch-time reductions the captions quote.
//! The four method runs of each figure execute on worker threads;
//! rendering stays sequential so the output is unchanged. SVGs land in
//! bench_out/.
//!
//! A trailing synth column runs `--schedule synth` on every unique
//! (preset, fleet) cell of the grid and asserts the synthesized
//! schedule's no-freeze batch time is ≤ the best of the four fixed
//! schedules, reporting bubble fraction and peak in-flight per cell.
use timelyfreeze::bench_support::parallel::map_parallel;
use timelyfreeze::bench_support::tables::apply_quick;
use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::sim::{self, SimResult};
use timelyfreeze::types::{FreezeMethod, ScheduleKind};
use timelyfreeze::viz;

fn render(figure: &str, preset: &str, schedule: ScheduleKind, ranks: usize, mb: usize) {
    println!("\n===== {figure}: {preset} · {} · {ranks} GPUs × {mb} microbatches =====", schedule.name());
    let out_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/bench_out");
    std::fs::create_dir_all(out_dir).ok();
    let methods = [
        FreezeMethod::NoFreezing,
        FreezeMethod::AutoFreeze,
        FreezeMethod::Apf,
        FreezeMethod::TimelyFreeze,
    ];
    let results: Vec<SimResult> = map_parallel(&methods, |&method| {
        let mut cfg = ExperimentConfig::paper_preset(preset).unwrap();
        apply_quick(&mut cfg);
        cfg.schedule = schedule;
        cfg.method = method;
        cfg.ranks = ranks;
        cfg.microbatches = mb;
        sim::run(&cfg).expect("feasible config")
    });
    let mut base_time = None;
    for (method, r) in methods.iter().zip(&results) {
        let bt = base_time.get_or_insert(r.batch_time_nofreeze);
        println!("\n--- {} (batch {:.3}s, −{:.2}% vs baseline) ---",
            method.name(), r.batch_time_final, 100.0 * (1.0 - r.batch_time_final / *bt));
        print!("{}", viz::ascii(&r.gantt_final, ranks, 110));
        let slug = format!(
            "{figure}_{}_{}", schedule.name().replace(' ', ""), method.name().replace([' ', '+'], "")
        );
        let svg = viz::svg(&r.gantt_final, ranks, &format!("{preset} {} {}", schedule.name(), method.name()));
        std::fs::write(format!("{out_dir}/{slug}.svg"), svg).unwrap();
    }
}

/// The synth column: on each unique (preset, ranks, microbatches) cell
/// of the fig7–13 grid, compare the synthesized schedule's no-freeze
/// batch time against all four fixed schedules. The portfolio guarantee
/// (the fixed four are candidates, scored under shape-matched cost
/// models) makes the assertion hold by construction; this is the
/// in-bench regression gate for it.
fn synth_column() {
    println!("\n===== synth column: synthesized vs best fixed schedule =====");
    let cells = [("llama-8b", 4usize, 8usize), ("llama-1b", 6, 6), ("llama-1b", 8, 8)];
    for (preset, ranks, mb) in cells {
        let run_kind = |kind: ScheduleKind| -> SimResult {
            let mut cfg = ExperimentConfig::paper_preset(preset).unwrap();
            apply_quick(&mut cfg);
            // Analytic no-freeze: batch_time_nofreeze is closed-form
            // and independent of step count, so the column stays cheap.
            cfg.exec = timelyfreeze::config::ExecMode::Analytic;
            cfg.method = FreezeMethod::NoFreezing;
            cfg.schedule = kind;
            cfg.ranks = ranks;
            cfg.microbatches = mb;
            sim::run(&cfg).expect("feasible config")
        };
        let fixed: Vec<(ScheduleKind, f64)> = ScheduleKind::all()
            .into_iter()
            .map(|kind| (kind, run_kind(kind).batch_time_nofreeze))
            .collect();
        let (best_kind, best_bt) =
            fixed.iter().cloned().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
        let synth = run_kind(ScheduleKind::Synthesized);
        println!(
            "  {preset} {ranks}x{mb}: synth {:.4}s vs best fixed {:.4}s ({}) · bubble {:.2}% · peak in-flight {} mb",
            synth.batch_time_nofreeze,
            best_bt,
            best_kind.name(),
            100.0 * synth.bubble_fraction,
            synth.peak_inflight.iter().copied().max().unwrap_or(0),
        );
        assert!(
            synth.batch_time_nofreeze <= best_bt * (1.0 + 1e-9),
            "synthesized schedule slower than best fixed on {preset} {ranks}x{mb}: \
             {} > {best_bt} ({})",
            synth.batch_time_nofreeze,
            best_kind.name(),
        );
    }
}

fn main() {
    // Figures 7–10: 4 GPUs, 8 microbatches, LLaMA-8B.
    render("fig7", "llama-8b", ScheduleKind::GPipe, 4, 8);
    render("fig8", "llama-8b", ScheduleKind::OneFOneB, 4, 8);
    render("fig9", "llama-8b", ScheduleKind::Interleaved1F1B, 4, 8);
    render("fig10", "llama-8b", ScheduleKind::ZeroBubbleV, 4, 8);
    // Figures 11–12: 6 GPUs, 6 microbatches, LLaMA-1B.
    render("fig11", "llama-1b", ScheduleKind::GPipe, 6, 6);
    render("fig12", "llama-1b", ScheduleKind::OneFOneB, 6, 6);
    // Figure 13: 8 GPUs GPipe.
    render("fig13", "llama-1b", ScheduleKind::GPipe, 8, 8);
    synth_column();
    println!("\nSVGs written to bench_out/");
}
