//! Figures 7–13 (Appendix F): pipeline-execution Gantt charts for the
//! four schedules × four methods at 4 GPUs (8B), 6 GPUs (1B, M=6), and
//! 8 GPUs (GPipe), with the batch-time reductions the captions quote.
//! The four method runs of each figure execute on worker threads;
//! rendering stays sequential so the output is unchanged. SVGs land in
//! bench_out/.
use timelyfreeze::bench_support::parallel::map_parallel;
use timelyfreeze::bench_support::tables::apply_quick;
use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::sim::{self, SimResult};
use timelyfreeze::types::{FreezeMethod, ScheduleKind};
use timelyfreeze::viz;

fn render(figure: &str, preset: &str, schedule: ScheduleKind, ranks: usize, mb: usize) {
    println!("\n===== {figure}: {preset} · {} · {ranks} GPUs × {mb} microbatches =====", schedule.name());
    let out_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/bench_out");
    std::fs::create_dir_all(out_dir).ok();
    let methods = [
        FreezeMethod::NoFreezing,
        FreezeMethod::AutoFreeze,
        FreezeMethod::Apf,
        FreezeMethod::TimelyFreeze,
    ];
    let results: Vec<SimResult> = map_parallel(&methods, |&method| {
        let mut cfg = ExperimentConfig::paper_preset(preset).unwrap();
        apply_quick(&mut cfg);
        cfg.schedule = schedule;
        cfg.method = method;
        cfg.ranks = ranks;
        cfg.microbatches = mb;
        sim::run(&cfg).expect("feasible config")
    });
    let mut base_time = None;
    for (method, r) in methods.iter().zip(&results) {
        let bt = base_time.get_or_insert(r.batch_time_nofreeze);
        println!("\n--- {} (batch {:.3}s, −{:.2}% vs baseline) ---",
            method.name(), r.batch_time_final, 100.0 * (1.0 - r.batch_time_final / *bt));
        print!("{}", viz::ascii(&r.gantt_final, ranks, 110));
        let slug = format!(
            "{figure}_{}_{}", schedule.name().replace(' ', ""), method.name().replace([' ', '+'], "")
        );
        let svg = viz::svg(&r.gantt_final, ranks, &format!("{preset} {} {}", schedule.name(), method.name()));
        std::fs::write(format!("{out_dir}/{slug}.svg"), svg).unwrap();
    }
}

fn main() {
    // Figures 7–10: 4 GPUs, 8 microbatches, LLaMA-8B.
    render("fig7", "llama-8b", ScheduleKind::GPipe, 4, 8);
    render("fig8", "llama-8b", ScheduleKind::OneFOneB, 4, 8);
    render("fig9", "llama-8b", ScheduleKind::Interleaved1F1B, 4, 8);
    render("fig10", "llama-8b", ScheduleKind::ZeroBubbleV, 4, 8);
    // Figures 11–12: 6 GPUs, 6 microbatches, LLaMA-1B.
    render("fig11", "llama-1b", ScheduleKind::GPipe, 6, 6);
    render("fig12", "llama-1b", ScheduleKind::OneFOneB, 6, 6);
    // Figure 13: 8 GPUs GPipe.
    render("fig13", "llama-1b", ScheduleKind::GPipe, 8, 8);
    println!("\nSVGs written to bench_out/");
}
