//! Micro-benchmarks for the §Perf pass: LP solve (cold + warm-started),
//! DAG longest-path (CSR evaluator vs the dense seed path), schedule
//! construction, and simulator step rate.
//!
//! Set `TF_BENCH_JSON=<path>` to also record the results as a
//! `BENCH_*.json` trajectory point for `scripts/perf_gate.sh`.
use timelyfreeze::bench_support::{bench_auto, header, write_json_if_requested, BenchResult};
use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::graph::pipeline::PipelineDag;
use timelyfreeze::lp::{
    build_lp, solve, solve_freeze_lp, Cmp, FreezeLpInput, FreezeLpSolver, LpProblem,
    LpRow, LpStatus, PersistentSimplex, SolvePath,
};
use timelyfreeze::schedule::Schedule;
use timelyfreeze::sim;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};

fn main() {
    let mut all: Vec<BenchResult> = Vec::new();
    let mut record = |r: BenchResult| {
        println!("{}", r.report());
        all.push(r);
    };
    println!("{}", header());

    // Schedule + DAG construction.
    for kind in ScheduleKind::all() {
        record(bench_auto(&format!("schedule_build/{}", kind.name()), 0.3, || {
            let s = Schedule::build(kind, 4, 8, Schedule::default_chunks(kind));
            std::hint::black_box(s.action_count());
        }));
    }
    let s = Schedule::build(ScheduleKind::ZeroBubbleV, 4, 8, 2);
    record(bench_auto("pipeline_dag_build/zbv_4x8", 0.3, || {
        let g = PipelineDag::from_schedule(&s);
        std::hint::black_box(g.len());
    }));

    // Full schedule synthesis at the warm-resolve scale (8×16): the
    // candidate portfolio, shape-matched scoring, and the LP↔rank fixed
    // point, end to end. Costs are the 1F1B LP-bench fixture flattened
    // to per-stage times (dgrad-heavy backward, zero tails).
    {
        use timelyfreeze::cost::CostModel;
        let stage_cost = |stages: usize, scale: f64| {
            CostModel::from_stage_times(
                vec![scale; stages],
                vec![1.4 * scale; stages],
                vec![0.6 * scale; stages],
                vec![0.0; stages],
                vec![0.0; stages],
                0.0,
                Vec::new(),
            )
        };
        let flat = stage_cost(8, 1.0);
        let chunked = stage_cost(16, 0.5);
        record(bench_auto("synthesize/1f1b_8x16", 1.0, || {
            let out = timelyfreeze::schedule::synthesize(&flat, &chunked, 8, 16, 0.8, 1e-4);
            std::hint::black_box(out.makespan);
        }));
    }

    // Longest path: the CSR evaluator hot path vs the dense seed path
    // (per-call Kahn sort over nested-Vec adjacency).
    let g = PipelineDag::from_schedule(&s);
    let w = g.weights(|_| 1.0);
    let mut evaluator = g.evaluator();
    record(bench_auto("longest_path/zbv_4x8", 0.3, || {
        std::hint::black_box(evaluator.batch_time(&w));
    }));
    record(bench_auto("longest_path_dense/zbv_4x8", 0.3, || {
        std::hint::black_box(g.batch_time_dense(&w));
    }));
    // The discrete-event executor over the same batch (heap-driven;
    // expected a small constant factor above the raw sweep).
    let mut engine = sim::EventEngine::new(&g, &s);
    let zero_delays = vec![0.0; g.dag.edge_count()];
    record(bench_auto("event_exec/zbv_4x8", 0.3, || {
        std::hint::black_box(engine.execute(&w, &zero_delays));
    }));

    // LP solve at several scales (cold: full two-phase simplex).
    for (ranks, m, kind) in [
        (4usize, 8usize, ScheduleKind::OneFOneB),
        (4, 8, ScheduleKind::ZeroBubbleV),
        (8, 16, ScheduleKind::OneFOneB),
    ] {
        let sched = Schedule::build(kind, ranks, m, Schedule::default_chunks(kind));
        let pdag = PipelineDag::from_schedule(&sched);
        let w_max = pdag.weights(|a| if a.kind.freezable() { 2.0 } else { 1.0 });
        let w_min = pdag.weights(|a| if a.kind.freezable() { 0.9 } else { 1.0 });
        record(bench_auto(
            &format!("lp_solve/{}_{ranks}x{m} ({} nodes)", kind.name(), pdag.len()),
            1.0,
            || {
                let sol =
                    solve_freeze_lp(&FreezeLpInput::new(&pdag, &w_min, &w_max, 0.8, 1e-4))
                        .unwrap();
                std::hint::black_box(sol.batch_time);
            },
        ));
    }

    // Warm vs incremental re-solves: the per-check-interval controller
    // pattern — same DAG, previous solver state reused. Bound drift on
    // freezable nodes moves the budget rows' δ coefficients (a matrix
    // change), forcing the warm Gauss-Jordan realization; budget-only
    // drift moves RHS entries alone, so the stored tableau is patched
    // through the basis inverse (dual simplex / phase 2, no
    // realization). The gap between the two entries is the tentpole's
    // measured win.
    {
        let sched = Schedule::build(ScheduleKind::OneFOneB, 8, 16, 1);
        let pdag = PipelineDag::from_schedule(&sched);
        let base_max = pdag.weights(|a| if a.kind.freezable() { 2.0 } else { 1.0 });
        let w_min = pdag.weights(|a| if a.kind.freezable() { 0.9 } else { 1.0 });
        let mut w_max = base_max.clone();
        let mut solver = FreezeLpSolver::new();
        let mut round = 0u64;
        // Prime the state with one cold solve outside the timed loop.
        solver.solve(&FreezeLpInput::new(&pdag, &w_min, &w_max, 0.8, 1e-4)).unwrap();
        record(bench_auto("lp_resolve_warm/1f1b_8x16", 1.0, || {
            // Jitter the measured upper bounds ±1%: δ moves, the matrix
            // changes, and the solver realizes the basis anew each time.
            round += 1;
            let jitter = 1.0 + 0.01 * ((round % 8) as f64 - 3.5) / 3.5;
            for (w, b) in w_max.iter_mut().zip(&base_max) {
                if *b > 1.0 {
                    *w = b * jitter;
                }
            }
            let sol = solver
                .solve(&FreezeLpInput::new(&pdag, &w_min, &w_max, 0.8, 1e-4))
                .unwrap();
            std::hint::black_box(sol.batch_time);
        }));
        // δ drift must never be patched through the stored tableau
        // (the occasional bound move that breaks basis feasibility
        // falls through to cold — same order of cost).
        assert_ne!(
            solver.last_solve_path(),
            Some(SolvePath::Incremental),
            "bound drift must not take the incremental rung"
        );

        // Budget-only drift: RHS entries move, the matrix does not —
        // the incremental rung patches the stored tableau in place.
        let mut solver = FreezeLpSolver::new();
        solver.solve(&FreezeLpInput::new(&pdag, &w_min, &base_max, 0.8, 1e-4)).unwrap();
        let mut round = 0u64;
        record(bench_auto("lp_resolve_incremental/1f1b_8x16", 1.0, || {
            round += 1;
            let r_max = 0.8 - 0.001 * (round % 8) as f64;
            let sol = solver
                .solve(&FreezeLpInput::new(&pdag, &w_min, &base_max, r_max, 1e-4))
                .unwrap();
            std::hint::black_box(sol.batch_time);
        }));
        // The incremental claims, checked on a fresh ladder (the timed
        // loop above may legitimately end on a periodic-refactorization
        // solve): budget drift stays on the incremental rung, and an
        // unchanged re-solve certifies optimality in ~zero pivots.
        let mut fresh = FreezeLpSolver::new();
        fresh.solve(&FreezeLpInput::new(&pdag, &w_min, &base_max, 0.8, 1e-4)).unwrap();
        fresh.solve(&FreezeLpInput::new(&pdag, &w_min, &base_max, 0.79, 1e-4)).unwrap();
        assert_eq!(
            fresh.last_solve_path(),
            Some(SolvePath::Incremental),
            "budget drift must stay on the incremental rung"
        );
        let same =
            fresh.solve(&FreezeLpInput::new(&pdag, &w_min, &base_max, 0.79, 1e-4)).unwrap();
        assert_eq!(fresh.last_solve_path(), Some(SolvePath::Incremental));
        assert!(
            same.iterations <= 4,
            "unchanged-problem incremental restart pivoted {} times",
            same.iterations
        );
    }

    // Sparse revised core vs the dense tableau oracle on the same raw
    // LP. At 8×16 both cores run — the gap is the tentpole's headline
    // number. The synthesized 16×64 instance runs the sparse ladder
    // only: its dense tableau would be ~10⁸ entries, which is exactly
    // why the revised core exists.
    {
        let sched = Schedule::build(ScheduleKind::OneFOneB, 8, 16, 1);
        let pdag = PipelineDag::from_schedule(&sched);
        let w_max = pdag.weights(|a| if a.kind.freezable() { 2.0 } else { 1.0 });
        let w_min = pdag.weights(|a| if a.kind.freezable() { 0.9 } else { 1.0 });
        record(sparse_drift_bench(
            "lp_sparse_vs_dense/1f1b_8x16",
            1.0,
            &pdag,
            &w_min,
            &w_max,
        ));
        // The dense oracle on the same instance, for the ratio.
        let p = build_lp(&FreezeLpInput::new(&pdag, &w_min, &w_max, 0.8, 1e-4)).unwrap();
        record(bench_auto("lp_dense_oracle/1f1b_8x16", 1.0, || {
            std::hint::black_box(solve(&p).objective);
        }));

        // Synthesized 16×64: the acceptance-scale instance. The
        // synthesizer itself replans through the sparse ladder; the
        // bench then drives steady-state resolves on its schedule.
        let stage_cost = |stages: usize, scale: f64| {
            timelyfreeze::cost::CostModel::from_stage_times(
                vec![scale; stages],
                vec![1.4 * scale; stages],
                vec![0.6 * scale; stages],
                vec![0.0; stages],
                vec![0.0; stages],
                0.0,
                Vec::new(),
            )
        };
        let out = timelyfreeze::schedule::synthesize(
            &stage_cost(16, 1.0),
            &stage_cost(32, 0.5),
            16,
            64,
            0.8,
            1e-4,
        );
        let pdag = PipelineDag::from_schedule(&out.schedule);
        let w_max = pdag.weights(|a| if a.kind.freezable() { 2.0 } else { 1.0 });
        let w_min = pdag.weights(|a| if a.kind.freezable() { 0.9 } else { 1.0 });
        record(sparse_drift_bench(
            "lp_sparse_vs_dense/synth_16x64",
            1.5,
            &pdag,
            &w_min,
            &w_max,
        ));
    }

    // Long-step dual ratio test in isolation: a 512-variable box LP
    // whose budget row swings between slack and tight — each resolve
    // repairs the basis by flipping ~hundreds of bounds around a single
    // entering pivot, the BFRT's whole advantage over one-pivot-per-
    // variable dual steps.
    {
        let n = 512;
        let mut p = LpProblem::new();
        for j in 0..n {
            // Distinct costs so the optimum is unique and flip-heavy.
            p.add_var(-1.0 - (j as f64) / (n as f64), 0.0, 1.0);
        }
        p.rows.push(LpRow {
            coeffs: (0..n).map(|j| (j, 1.0)).collect(),
            cmp: Cmp::Le,
            rhs: n as f64 * 0.75,
        });
        let mut ps = PersistentSimplex::new();
        std::hint::black_box(ps.solve(&p).objective);
        let mut round = 0u64;
        record(bench_auto("lp_bound_flip/box_512", 0.5, || {
            round += 1;
            let frac = if round % 2 == 0 { 0.75 } else { 0.25 };
            p.rows[0].rhs = n as f64 * frac;
            std::hint::black_box(ps.solve(&p).objective);
        }));
        let stats = ps.last_stats().expect("stats recorded");
        if std::env::var("TF_BENCH_JSON").map_or(false, |q| !q.is_empty()) {
            println!("lp_bound_flip/box_512: last-resolve stats {stats:?}");
        }
    }

    // The controller replan loop end to end: observed-profile
    // distillation → skeleton refresh → (warm/incremental) LP solve →
    // delta envelope sweeps. This is the hot loop of the online
    // replanning path (PERF.md §2, fig17).
    {
        use timelyfreeze::cost::{CostProfile, StageProfile};
        use timelyfreeze::freeze::{
            Controller, ModelLayout, PhaseConfig, TimelyFreeze, TimelyFreezeConfig,
        };
        use timelyfreeze::types::ActionKind;
        let sched = Schedule::build(ScheduleKind::OneFOneB, 4, 8, 1);
        let layout = ModelLayout::uniform(8, 4, 1000, 4);
        let tf_cfg = TimelyFreezeConfig {
            phases: PhaseConfig::new(10, 30, 50),
            r_max: 0.8,
            lambda: 1e-4,
        };
        let mut tf = TimelyFreeze::new(tf_cfg, &sched, layout);
        // Synthetic monitoring: forward 1, backward 2 unfrozen / 0.8
        // frozen — the timely.rs test fixture.
        for t in 1..=30 {
            let plan = tf.plan(t);
            for a in sched.all_actions() {
                let dur = match a.kind {
                    ActionKind::Forward => 1.0,
                    _ => 2.0 - plan.ratio_of(&a) * 1.2,
                };
                tf.record_time(t, a, dur);
            }
        }
        tf.plan(31); // first LP solve (cold), outside the timed loop
        let mut round = 0u64;
        record(bench_auto("replan_loop/llama1b", 1.0, || {
            // A drifting observed world: stage 2 degrades and recovers,
            // as a straggler would between check intervals.
            round += 1;
            let m = 1.0 + 0.2 * ((round % 16) as f64) / 16.0;
            let profile = CostProfile::profiled(
                (0..4)
                    .map(|s| {
                        let f = if s == 2 { m } else { 1.0 };
                        StageProfile::compute(f * 1.0, f * 0.8, f * 1.2)
                    })
                    .collect(),
            );
            tf.replan_with_profile(&profile);
            std::hint::black_box(tf.solution().map(|s| s.batch_time));
        }));
    }

    // Simulator step rate (steps/sec over a short run).
    let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
    cfg.steps = 100;
    cfg.phases = timelyfreeze::freeze::PhaseConfig::new(8, 26, 40);
    cfg.method = FreezeMethod::TimelyFreeze;
    let r = bench_auto("sim_run/llama1b_100steps", 2.0, || {
        std::hint::black_box(sim::run(&cfg).expect("feasible config").throughput);
    });
    let sim_mean = r.mean_s;
    record(r);
    println!("sim rate ≈ {:.0} steps/s (event executor)", 100.0 / sim_mean);
    // The analytic fast mode of the same run, for the executor-overhead
    // comparison (bit-identical results, pure sweep per step).
    cfg.exec = timelyfreeze::config::ExecMode::Analytic;
    record(bench_auto("sim_run_analytic/llama1b_100steps", 2.0, || {
        std::hint::black_box(sim::run(&cfg).expect("feasible config").throughput);
    }));

    // The same event run with the divergence watchdog armed but calm:
    // its per-step cost is one planned/realized per-rank sum plus two
    // EWMA folds, so the entry must track sim_run/llama1b_100steps
    // within noise (target < 1% overhead; the gate below is loose
    // enough not to flake on shared runners, and perf_gate.sh pins the
    // entry against its own baseline).
    {
        let mut wd_cfg = cfg.clone();
        wd_cfg.exec = timelyfreeze::config::ExecMode::Event;
        wd_cfg.watchdog = Some(3.0);
        let r = bench_auto("watchdog_overhead/llama1b", 2.0, || {
            let res = sim::run(&wd_cfg).expect("feasible config");
            std::hint::black_box(res.throughput);
        });
        let ratio = r.mean_s / sim_mean;
        println!("watchdog armed/unarmed mean ratio: {ratio:.4} (target < 1.01)");
        record(r);
        assert!(
            ratio < 1.10,
            "an armed-but-calm watchdog cost {:.1}% over the plain event run",
            (ratio - 1.0) * 100.0
        );
        // Armed but calm means exactly that: no triggers on this run.
        let res = sim::run(&wd_cfg).expect("feasible config");
        assert!(res.watchdog_triggers.is_empty(), "{:?}", res.watchdog_triggers);
    }

    // The degraded-mode ladder's failure path: a stage floor above
    // r_max makes every solve fail FloorExceedsBudget, so each round
    // pays the failed LP attempt plus ladder bookkeeping (cause
    // formatting, capped event log). This path runs *inside* the step
    // loop whenever the world turns infeasible, so it has to stay at
    // replan-loop cost, not blow up on the error branch.
    {
        use timelyfreeze::cost::{CostProfile, StageProfile};
        use timelyfreeze::freeze::{
            Controller, DegradationRung, ModelLayout, PhaseConfig, TimelyFreeze,
            TimelyFreezeConfig,
        };
        use timelyfreeze::types::ActionKind;
        let sched = Schedule::build(ScheduleKind::OneFOneB, 4, 8, 1);
        let layout = ModelLayout::uniform(8, 4, 1000, 4);
        let tf_cfg = TimelyFreezeConfig {
            phases: PhaseConfig::new(10, 30, 50),
            r_max: 0.8,
            lambda: 1e-4,
        };
        let mut tf = TimelyFreeze::new(tf_cfg, &sched, layout);
        for t in 1..=30 {
            let plan = tf.plan(t);
            for a in sched.all_actions() {
                let dur = match a.kind {
                    ActionKind::Forward => 1.0,
                    _ => 2.0 - plan.ratio_of(&a) * 1.2,
                };
                tf.record_time(t, a, dur);
            }
        }
        tf.plan(31); // first LP solve (cold), outside the timed loop
        tf.set_stage_floor(Some(vec![0.9; 4]));
        let profile = CostProfile::profiled(
            (0..4).map(|_| StageProfile::compute(1.0, 0.8, 1.2)).collect(),
        );
        record(bench_auto("degraded_replan/ladder_exhaust", 0.5, || {
            tf.replan_with_profile(&profile);
            std::hint::black_box(Controller::replan_failures(&tf));
        }));
        assert!(Controller::replan_failures(&tf) >= 3, "every replan must have failed");
        let report = tf.degradation();
        assert_eq!(report.worst(), Some(DegradationRung::SafeMode));
        assert!(
            report.len() <= timelyfreeze::freeze::timely::DEGRADATION_LOG_CAP,
            "the event log must stay capped, got {}",
            report.len()
        );
    }

    // Max-min fair sharing in isolation: admit a burst of island- and
    // spine-crossing transfers, then drain the fabric event by event —
    // the per-step network work of the contended executor, without the
    // pipeline around it. Each arrival/departure re-solves the
    // water-filling allocation, so the burst costs O(events · links ·
    // transfers).
    {
        use timelyfreeze::net::FairShareFabric;
        let caps = [6e10, 6e10, 1e9]; // two islands + the spine
        let paths: [&[usize]; 3] = [&[0], &[0, 2, 1], &[1, 2, 0]];
        let mut fabric = FairShareFabric::new();
        record(bench_auto("net_fair_share/burst_24x3links", 0.3, || {
            fabric.reset(&caps);
            for k in 0..24u64 {
                let _ = fabric.begin(0.001 * k as f64, 3.4e7, paths[(k % 3) as usize], k);
            }
            let mut drained = 0u64;
            while !fabric.idle() {
                let mut next: Option<(f64, usize)> = None;
                fabric.predictions(|id, _, due| {
                    if next.map_or(true, |(t, _)| due < t) {
                        next = Some((due, id));
                    }
                });
                let (due, id) = next.expect("busy fabric predicts completions");
                drained += fabric.complete(due, id);
            }
            std::hint::black_box(drained);
        }));
    }

    // The same 100-step run priced through the shared-link fabric: the
    // event executor's contended path (NetDue events, epoch-versioned
    // lazy deletion, per-step capacity reinstall). The delta against
    // sim_run/llama1b_100steps is the network model's full cost.
    {
        let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        cfg.steps = 100;
        cfg.phases = timelyfreeze::freeze::PhaseConfig::new(8, 26, 40);
        cfg.method = FreezeMethod::TimelyFreeze;
        cfg.net = Some(
            timelyfreeze::net::Topology::parse("island:2x6e10,spine:1e9,lat:0.0002").unwrap(),
        );
        record(bench_auto("contended_sim_run/llama1b_100steps", 2.0, || {
            std::hint::black_box(sim::run(&cfg).expect("feasible config").throughput);
        }));
    }

    // Shadow-run memo telemetry: visible whenever a trajectory point is
    // being recorded, so sweep drivers can check the bounded cache
    // still serves their baseline pattern.
    if std::env::var("TF_BENCH_JSON").map_or(false, |p| !p.is_empty()) {
        let (hits, misses, resident) = sim::shadow_memo_stats();
        println!(
            "shadow-run memo: {hits} hits / {misses} misses, {resident} resident (cap {})",
            sim::SHADOW_MEMO_CAP
        );
    }

    write_json_if_requested("perf_micro", &all);
}

/// Time a steady-state replan round through the sparse ladder with a
/// drifting accuracy budget, then verify — on a fresh ladder, since the
/// timed loop may end on a periodic-refactorization solve — that a
/// drifted resolve rides the incremental rung of the LU + Devex path:
/// real dual work (pivots or bound flips) with zero refactorizations.
/// This is the tentpole's acceptance probe; the stats line prints
/// whenever a `TF_BENCH_JSON` trajectory point is being recorded.
fn sparse_drift_bench(
    name: &str,
    budget_s: f64,
    pdag: &PipelineDag,
    w_min: &[f64],
    w_max: &[f64],
) -> BenchResult {
    let lp_at =
        |r_max: f64| build_lp(&FreezeLpInput::new(pdag, w_min, w_max, r_max, 1e-4)).unwrap();
    let mut ps = PersistentSimplex::new();
    std::hint::black_box(ps.solve(&lp_at(0.8)).objective);
    let mut round = 0u64;
    let result = bench_auto(name, budget_s, || {
        round += 1;
        let r_max = 0.8 - 0.04 * (round % 8) as f64;
        std::hint::black_box(ps.solve(&lp_at(r_max)).objective);
    });
    let mut fresh = PersistentSimplex::new();
    fresh.solve(&lp_at(0.8));
    let drifted = fresh.solve(&lp_at(0.56));
    assert_eq!(drifted.status, LpStatus::Optimal);
    assert_eq!(fresh.last_path(), Some(SolvePath::Incremental));
    let stats = fresh.last_stats().expect("stats recorded");
    assert!(
        stats.pivots + stats.bound_flips > 0,
        "{name}: a 0.8→0.56 budget drop must do dual work, stats {stats:?}"
    );
    assert_eq!(
        stats.refactorizations, 0,
        "{name}: the incremental rung must reuse the factorization"
    );
    if std::env::var("TF_BENCH_JSON").map_or(false, |p| !p.is_empty()) {
        println!("{name}: drifted-resolve stats {stats:?}");
    }
    result
}
