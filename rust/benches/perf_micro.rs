//! Micro-benchmarks for the §Perf pass: LP solve (cold + warm-started),
//! DAG longest-path (CSR evaluator vs the dense seed path), schedule
//! construction, and simulator step rate.
//!
//! Set `TF_BENCH_JSON=<path>` to also record the results as a
//! `BENCH_*.json` trajectory point for `scripts/perf_gate.sh`.
use timelyfreeze::bench_support::{bench_auto, header, write_json_if_requested, BenchResult};
use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::graph::pipeline::PipelineDag;
use timelyfreeze::lp::{solve_freeze_lp, FreezeLpInput, FreezeLpSolver};
use timelyfreeze::schedule::Schedule;
use timelyfreeze::sim;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};

fn main() {
    let mut all: Vec<BenchResult> = Vec::new();
    let mut record = |r: BenchResult| {
        println!("{}", r.report());
        all.push(r);
    };
    println!("{}", header());

    // Schedule + DAG construction.
    for kind in ScheduleKind::all() {
        record(bench_auto(&format!("schedule_build/{}", kind.name()), 0.3, || {
            let s = Schedule::build(kind, 4, 8, Schedule::default_chunks(kind));
            std::hint::black_box(s.action_count());
        }));
    }
    let s = Schedule::build(ScheduleKind::ZeroBubbleV, 4, 8, 2);
    record(bench_auto("pipeline_dag_build/zbv_4x8", 0.3, || {
        let g = PipelineDag::from_schedule(&s);
        std::hint::black_box(g.len());
    }));

    // Longest path: the CSR evaluator hot path vs the dense seed path
    // (per-call Kahn sort over nested-Vec adjacency).
    let g = PipelineDag::from_schedule(&s);
    let w = g.weights(|_| 1.0);
    let mut evaluator = g.evaluator();
    record(bench_auto("longest_path/zbv_4x8", 0.3, || {
        std::hint::black_box(evaluator.batch_time(&w));
    }));
    record(bench_auto("longest_path_dense/zbv_4x8", 0.3, || {
        std::hint::black_box(g.batch_time_dense(&w));
    }));
    // The discrete-event executor over the same batch (heap-driven;
    // expected a small constant factor above the raw sweep).
    let mut engine = sim::EventEngine::new(&g, &s);
    let zero_delays = vec![0.0; g.dag.edge_count()];
    record(bench_auto("event_exec/zbv_4x8", 0.3, || {
        std::hint::black_box(engine.execute(&w, &zero_delays));
    }));

    // LP solve at several scales (cold: full two-phase simplex).
    for (ranks, m, kind) in [
        (4usize, 8usize, ScheduleKind::OneFOneB),
        (4, 8, ScheduleKind::ZeroBubbleV),
        (8, 16, ScheduleKind::OneFOneB),
    ] {
        let sched = Schedule::build(kind, ranks, m, Schedule::default_chunks(kind));
        let pdag = PipelineDag::from_schedule(&sched);
        let w_max = pdag.weights(|a| if a.kind.freezable() { 2.0 } else { 1.0 });
        let w_min = pdag.weights(|a| if a.kind.freezable() { 0.9 } else { 1.0 });
        record(bench_auto(
            &format!("lp_solve/{}_{ranks}x{m} ({} nodes)", kind.name(), pdag.len()),
            1.0,
            || {
                let sol =
                    solve_freeze_lp(&FreezeLpInput::new(&pdag, &w_min, &w_max, 0.8, 1e-4))
                        .unwrap();
                std::hint::black_box(sol.batch_time);
            },
        ));
    }

    // Warm-started re-solve: the per-check-interval controller pattern —
    // same DAG, slightly perturbed bounds, previous basis reused.
    {
        let sched = Schedule::build(ScheduleKind::OneFOneB, 8, 16, 1);
        let pdag = PipelineDag::from_schedule(&sched);
        let w_max = pdag.weights(|a| if a.kind.freezable() { 2.0 } else { 1.0 });
        let w_min = pdag.weights(|a| if a.kind.freezable() { 0.9 } else { 1.0 });
        let mut solver = FreezeLpSolver::new();
        let mut round = 0u64;
        // Prime the basis with one cold solve outside the timed loop.
        solver.solve(&FreezeLpInput::new(&pdag, &w_min, &w_max, 0.8, 1e-4)).unwrap();
        record(bench_auto("lp_resolve_warm/1f1b_8x16", 1.0, || {
            // Nudge the budget each round so the re-solve is not a pure
            // no-op, like a controller tracking drifting measurements.
            round += 1;
            let r_max = 0.8 - 0.001 * (round % 8) as f64;
            let sol = solver
                .solve(&FreezeLpInput::new(&pdag, &w_min, &w_max, r_max, 1e-4))
                .unwrap();
            std::hint::black_box(sol.batch_time);
        }));
    }

    // Simulator step rate (steps/sec over a short run).
    let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
    cfg.steps = 100;
    cfg.phases = timelyfreeze::freeze::PhaseConfig::new(8, 26, 40);
    cfg.method = FreezeMethod::TimelyFreeze;
    let r = bench_auto("sim_run/llama1b_100steps", 2.0, || {
        std::hint::black_box(sim::run(&cfg).expect("feasible config").throughput);
    });
    let sim_mean = r.mean_s;
    record(r);
    println!("sim rate ≈ {:.0} steps/s (event executor)", 100.0 / sim_mean);
    // The analytic fast mode of the same run, for the executor-overhead
    // comparison (bit-identical results, pure sweep per step).
    cfg.exec = timelyfreeze::config::ExecMode::Analytic;
    record(bench_auto("sim_run_analytic/llama1b_100steps", 2.0, || {
        std::hint::black_box(sim::run(&cfg).expect("feasible config").throughput);
    }));

    write_json_if_requested("perf_micro", &all);
}
