//! Micro-benchmarks for the §Perf pass: LP solve, DAG longest-path,
//! schedule construction, and simulator step rate.
use timelyfreeze::bench_support::{bench_auto, header};
use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::graph::pipeline::PipelineDag;
use timelyfreeze::lp::{solve_freeze_lp, FreezeLpInput};
use timelyfreeze::schedule::Schedule;
use timelyfreeze::sim;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};

fn main() {
    println!("{}", header());
    // Schedule + DAG construction.
    for kind in ScheduleKind::all() {
        let r = bench_auto(&format!("schedule_build/{}", kind.name()), 0.3, || {
            let s = Schedule::build(kind, 4, 8, Schedule::default_chunks(kind));
            std::hint::black_box(s.action_count());
        });
        println!("{}", r.report());
    }
    let s = Schedule::build(ScheduleKind::ZeroBubbleV, 4, 8, 2);
    let r = bench_auto("pipeline_dag_build/zbv_4x8", 0.3, || {
        let g = PipelineDag::from_schedule(&s);
        std::hint::black_box(g.len());
    });
    println!("{}", r.report());

    let g = PipelineDag::from_schedule(&s);
    let w = g.weights(|_| 1.0);
    let r = bench_auto("longest_path/zbv_4x8", 0.3, || {
        std::hint::black_box(g.batch_time(&w));
    });
    println!("{}", r.report());

    // LP solve at several scales.
    for (ranks, m, kind) in [
        (4usize, 8usize, ScheduleKind::OneFOneB),
        (4, 8, ScheduleKind::ZeroBubbleV),
        (8, 16, ScheduleKind::OneFOneB),
    ] {
        let sched = Schedule::build(kind, ranks, m, Schedule::default_chunks(kind));
        let pdag = PipelineDag::from_schedule(&sched);
        let w_max = pdag.weights(|a| if a.kind.freezable() { 2.0 } else { 1.0 });
        let w_min = pdag.weights(|a| if a.kind.freezable() { 0.9 } else { 1.0 });
        let r = bench_auto(
            &format!("lp_solve/{}_{ranks}x{m} ({} nodes)", kind.name(), pdag.len()),
            1.0,
            || {
                let sol = solve_freeze_lp(&FreezeLpInput {
                    pdag: &pdag,
                    w_min: &w_min,
                    w_max: &w_max,
                    r_max: 0.8,
                    lambda: 1e-4,
                })
                .unwrap();
                std::hint::black_box(sol.batch_time);
            },
        );
        println!("{}", r.report());
    }

    // Simulator step rate (steps/sec over a short run).
    let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
    cfg.steps = 100;
    cfg.phases = timelyfreeze::freeze::PhaseConfig::new(8, 26, 40);
    cfg.method = FreezeMethod::TimelyFreeze;
    let r = bench_auto("sim_run/llama1b_100steps", 2.0, || {
        std::hint::black_box(sim::run(&cfg).throughput);
    });
    println!("{}", r.report());
    println!(
        "sim rate ≈ {:.0} steps/s",
        100.0 / r.mean_s
    );
}
