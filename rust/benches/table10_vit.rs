//! Table 10 (Appendix G.2): ViT-L/32 fine-tuning on 8×RTX3090 under
//! GPipe and 1F1B.
use timelyfreeze::partition::PartitionMethod;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};

fn main() {
    timelyfreeze::bench_support::tables::run_vision_table(
        "vit-l32",
        "table10_vit",
        &[PartitionMethod::Parameter],
        &[ScheduleKind::GPipe, ScheduleKind::OneFOneB],
        &[
            FreezeMethod::NoFreezing,
            FreezeMethod::Apf,
            FreezeMethod::AutoFreeze,
            FreezeMethod::TimelyFreeze,
        ],
    );
}
