//! Table 1: LLaMA-3-8B across GPipe / 1F1B / Interleaved 1F1B / ZBV for
//! all six freezing methods — Avg. Acc.(Δ), Frz. Ratio, Throughput(Δ), MFU.
//! Set TF_BENCH_QUICK=1 for a short smoke run.
fn main() {
    timelyfreeze::bench_support::tables::run_llm_table("llama-8b", "table1_llama8b");
}
