//! Table 4 (Appendix E.2): LLaMA-3.2-1B grid on 4×A6000.
fn main() {
    timelyfreeze::bench_support::tables::run_llm_table("llama-1b", "table4_llama1b");
}
