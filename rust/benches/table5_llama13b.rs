//! Table 5 (Appendix E.2): LLaMA-2-13B grid on 4×H200.
fn main() {
    timelyfreeze::bench_support::tables::run_llm_table("llama-13b", "table5_llama13b");
}
