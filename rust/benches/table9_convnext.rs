//! Table 9 (Appendix G.1): ConvNeXt-V2-L under memory/parameter/time
//! partitioning heuristics × {GPipe, 1F1B} × {No-Freezing, APF,
//! AutoFreeze, TimelyFreeze} — Top-1(Δ), Train Time(Δ), Freeze Ratio.
use timelyfreeze::partition::PartitionMethod;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};

fn main() {
    timelyfreeze::bench_support::tables::run_vision_table(
        "convnextv2-l",
        "table9_convnext",
        &PartitionMethod::all(),
        &[ScheduleKind::GPipe, ScheduleKind::OneFOneB],
        &[
            FreezeMethod::NoFreezing,
            FreezeMethod::Apf,
            FreezeMethod::AutoFreeze,
            FreezeMethod::TimelyFreeze,
        ],
    );
}
