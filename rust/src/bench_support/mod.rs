//! Minimal benchmark harness (criterion is unavailable in the offline
//! image): warmup + timed iterations with mean/stddev/percentiles, plus
//! helpers shared by the table/figure benches.

use crate::util::stats;
use std::time::Instant;

/// Summary statistics of one timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark id (e.g. "lp_solve/1f1b_4x8").
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Standard deviation, seconds.
    pub stddev_s: f64,
    /// Median seconds.
    pub p50_s: f64,
    /// 95th-percentile seconds.
    pub p95_s: f64,
}

impl BenchResult {
    /// One formatted row (pair with [`header`]).
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10} {:>12} {:>12} {:>12}",
            self.name,
            format!("n={}", self.iters),
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
        )
    }
}

/// Human-friendly duration (ns/µs/ms/s auto-scaled).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples),
        stddev_s: stats::stddev(&samples),
        p50_s: stats::percentile(&samples, 50.0),
        p95_s: stats::percentile(&samples, 95.0),
    }
}

/// Adaptive: pick an iteration count so the bench takes ≈ `budget_s`.
pub fn bench_auto<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / once) as usize).clamp(3, 10_000);
    bench(name, (iters / 10).max(1), iters, f)
}

/// Header matching [`BenchResult::report`].
pub fn header() -> String {
    format!(
        "{:<40} {:>10} {:>12} {:>12} {:>12}",
        "benchmark", "iters", "mean", "p50", "p95"
    )
}

/// Serialize bench results as JSON (the `BENCH_*.json` trajectory files
/// consumed by `scripts/perf_gate.sh`). Written when `TF_BENCH_JSON`
/// names a target path; silent no-op otherwise.
pub fn write_json_if_requested(bench: &str, results: &[BenchResult]) {
    let Ok(path) = std::env::var("TF_BENCH_JSON") else {
        return;
    };
    if path.is_empty() {
        return;
    }
    use crate::util::json::Json;
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::str(&r.name)),
                ("iters", Json::num(r.iters as f64)),
                ("mean_s", Json::num(r.mean_s)),
                ("stddev_s", Json::num(r.stddev_s)),
                ("p50_s", Json::num(r.p50_s)),
                ("p95_s", Json::num(r.p95_s)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![("bench", Json::str(bench)), ("results", Json::Arr(rows))]);
    match std::fs::write(&path, doc.to_pretty()) {
        Ok(()) => println!("bench json → {path}"),
        Err(e) => eprintln!("bench json write failed ({path}): {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let mut acc = 0u64;
        let r = bench("spin", 2, 20, || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(acc > 0);
        assert_eq!(r.iters, 20);
        assert!(r.mean_s > 0.0);
        assert!(r.p95_s >= r.p50_s);
    }

    #[test]
    fn auto_scales_iterations() {
        let r = bench_auto("noop", 0.02, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 3);
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5e-9), "2.5ns");
        assert_eq!(fmt_time(1.5e-5), "15.00µs");
        assert_eq!(fmt_time(2.5e-3), "2.50ms");
        assert_eq!(fmt_time(1.5), "1.500s");
    }

    #[test]
    fn report_aligns_with_header() {
        let r = bench("x", 0, 3, || {});
        assert_eq!(header().split_whitespace().count(), 5);
        assert!(r.report().contains("n=3"));
    }
}
/// Threaded experiment-grid driver.
pub mod parallel;
/// Shared config/printing for the table benches.
pub mod tables;
