//! Threaded experiment-grid driver. Every experiment `run()` is
//! independent and deterministically seeded, so the fig/table benches
//! fan their (method × schedule × scale) grids across scoped worker
//! threads and then print in the original order — identical output,
//! wall-clock divided by the core count.
//!
//! Work distribution is a shared atomic cursor over the item list
//! (work-stealing-lite): long-running cells (e.g. full TimelyFreeze
//! runs) don't leave a statically-assigned worker idle.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker-thread count: `TF_BENCH_THREADS` if set (values `0`/`1`
/// disable threading), else the machine's available parallelism, capped
/// by the item count.
pub fn worker_count(items: usize) -> usize {
    let override_threads =
        std::env::var("TF_BENCH_THREADS").ok().and_then(|v| v.parse::<usize>().ok());
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    resolve_worker_count(override_threads, hw, items)
}

/// Pure policy behind [`worker_count`], split out so tests don't have
/// to mutate process environment variables (concurrent `setenv` +
/// `getenv` across libtest threads is undefined behavior on glibc).
fn resolve_worker_count(override_threads: Option<usize>, hw: usize, items: usize) -> usize {
    override_threads.unwrap_or(hw).max(1).min(items.max(1))
}

/// Map `f` over `items` on scoped worker threads, preserving order.
/// Falls back to a plain sequential map when only one worker is
/// available (or `TF_BENCH_THREADS=1`), so output and behaviour are
/// byte-identical either way — each cell must be independently seeded.
pub fn map_parallel<I, T, F>(items: &[I], f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(&I) -> T + Sync,
{
    let workers = worker_count(items.len());
    if workers <= 1 || items.len() <= 1 {
        return items.iter().map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let result = f(&items[i]);
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .unwrap()
                .expect("worker panicked before filling its slot")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_results() {
        let items: Vec<usize> = (0..64).collect();
        let out = map_parallel(&items, |&i| i * i);
        assert_eq!(out, items.iter().map(|&i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_item_runs_inline() {
        let out = map_parallel(&[41usize], |&i| i + 1);
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = map_parallel(&[] as &[usize], |&i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn worker_count_policy() {
        // Explicit override wins; 0 and 1 both disable threading.
        assert_eq!(resolve_worker_count(Some(1), 16, 100), 1);
        assert_eq!(resolve_worker_count(Some(0), 16, 100), 1);
        assert_eq!(resolve_worker_count(Some(4), 16, 100), 4);
        // No override: hardware parallelism, capped by item count.
        assert_eq!(resolve_worker_count(None, 8, 100), 8);
        assert_eq!(resolve_worker_count(None, 8, 3), 3);
        assert_eq!(resolve_worker_count(None, 8, 0), 1);
        // The live wrapper never returns more workers than items.
        assert_eq!(worker_count(1), 1);
        assert!(worker_count(100) >= 1);
    }

    #[test]
    fn heavier_cells_do_not_starve_workers() {
        // Uneven work: the atomic cursor hands out remaining items to
        // whichever worker frees up first; all results still arrive.
        let items: Vec<u64> = (0..32).map(|i| (i % 7) * 50).collect();
        let out = map_parallel(&items, |&spin| {
            let mut acc = 0u64;
            for k in 0..spin * 1000 {
                acc = acc.wrapping_add(k);
            }
            std::hint::black_box(acc);
            spin
        });
        assert_eq!(out, items);
    }
}
