//! Shared harness for the paper-table benches (Tables 1, 4, 5, 9, 10):
//! runs the full schedule × method grid for a preset and prints rows in
//! the paper's format, recording JSON for regeneration.
//!
//! Grid cells are independent seeded runs, so they execute on the
//! threaded driver ([`crate::bench_support::parallel`]); printing and
//! recording stay in grid order, making the output identical to a
//! sequential run.

use crate::bench_support::parallel::map_parallel;
use crate::config::ExperimentConfig;
use crate::metrics::{result_row, Recorder};
use crate::partition::PartitionMethod;
use crate::sim::{self, SimResult};
use crate::types::{FreezeMethod, ScheduleKind};
use crate::util::table::Table;

/// Honour `TF_BENCH_QUICK=1` by shrinking the run (CI-speed smoke).
pub fn apply_quick(cfg: &mut ExperimentConfig) {
    if std::env::var("TF_BENCH_QUICK").as_deref() == Ok("1") {
        let scale = cfg.steps / 200;
        if scale > 1 {
            cfg.steps /= scale;
            let p = cfg.phases;
            cfg.phases = crate::freeze::PhaseConfig::new(
                (p.t_warmup / scale).max(2),
                (p.t_monitor / scale).max(4),
                (p.t_freeze / scale).max(6),
            );
        }
    }
}

/// Run one (schedule × method) grid for a preset and emit the table.
pub fn run_llm_table(preset: &str, experiment_id: &str) {
    let base = ExperimentConfig::paper_preset(preset).expect("preset");
    let mut recorder = Recorder::default_dir();
    println!(
        "{experiment_id}: {} — {} steps, r_max {}, 4×{}",
        base.model.name, base.steps, base.r_max, base.gpu.name
    );
    println!(
        "(pretrained avg acc {:.2}; paper no-freezing acc {:.2})\n",
        base.model.pretrained_acc, base.model.finetuned_acc
    );
    // Fan the full schedule × method grid across worker threads; each
    // cell is an independent seeded run.
    let grid: Vec<(ScheduleKind, FreezeMethod)> = ScheduleKind::all()
        .into_iter()
        .flat_map(|s| FreezeMethod::all().into_iter().map(move |m| (s, m)))
        .collect();
    let results: Vec<SimResult> = map_parallel(&grid, |&(schedule, method)| {
        let mut cfg = base.clone();
        apply_quick(&mut cfg);
        cfg.schedule = schedule;
        cfg.method = method;
        sim::run(&cfg).expect("table grid config must be feasible")
    });
    let mut results = results.into_iter();
    for schedule in ScheduleKind::all() {
        let mut t = Table::new(
            &format!("{} — {}", base.model.name, schedule.name()),
            &["Freeze Method", "Avg. Acc. (Δ)", "Frz. Ratio", "Throughput (Δ%)", "MFU"],
        );
        let mut baseline: Option<SimResult> = None;
        for method in FreezeMethod::all() {
            let r = results.next().expect("grid result");
            let b = baseline.get_or_insert_with(|| r.clone());
            let acc_delta = r.acc_delta(b);
            let thpt_delta = r.throughput_delta_pct(b);
            t.row(vec![
                method.name().to_string(),
                format!("{:.2} ({:+.2})", r.accuracy, acc_delta),
                format!("{:.2}", r.freeze_ratio),
                format!("{:.0} ({:+.2})", r.throughput, thpt_delta),
                format!("{:.2}", r.mfu),
            ]);
            recorder.push(
                experiment_id,
                result_row(
                    schedule.name(),
                    method.name(),
                    r.accuracy,
                    acc_delta,
                    r.freeze_ratio,
                    r.throughput,
                    thpt_delta,
                    r.mfu,
                ),
            );
        }
        println!("{}", t.render());
    }
    match recorder.flush() {
        Ok(paths) => println!("recorded → {:?}", paths),
        Err(e) => eprintln!("recorder error: {e}"),
    }
}

/// Vision-table harness (Tables 9/10): partition heuristics × schedules,
/// reporting Top-1(Δ), train time(Δ%), freeze ratio.
pub fn run_vision_table(
    preset: &str,
    experiment_id: &str,
    partitions: &[PartitionMethod],
    schedules: &[ScheduleKind],
    methods: &[FreezeMethod],
) {
    let base = ExperimentConfig::paper_preset(preset).expect("preset");
    let mut recorder = Recorder::default_dir();
    println!(
        "{experiment_id}: {} — {} steps on {}×{}",
        base.model.name, base.steps, base.ranks, base.gpu.name
    );
    let grid: Vec<(PartitionMethod, ScheduleKind, FreezeMethod)> = partitions
        .iter()
        .flat_map(|&p| {
            schedules
                .iter()
                .flat_map(move |&s| methods.iter().map(move |&m| (p, s, m)))
        })
        .collect();
    let results: Vec<(SimResult, f64)> = map_parallel(&grid, |&(partition, schedule, method)| {
        let mut cfg = base.clone();
        apply_quick(&mut cfg);
        cfg.schedule = schedule;
        cfg.method = method;
        let r = sim::run_with_partition(&cfg, partition)
            .expect("vision grid config must be feasible");
        let train_time = cfg.tokens_per_step() as f64 * cfg.steps as f64 / r.throughput;
        (r, train_time)
    });
    let mut results = results.into_iter();
    for &partition in partitions {
        for &schedule in schedules {
            let mut t = Table::new(
                &format!(
                    "{} — {} partitioning — {}",
                    base.model.name,
                    partition.name(),
                    schedule.name()
                ),
                &["Freeze Method", "Top1 Acc. (Δ)", "Train Time (Δ%↓)", "Freeze Ratio"],
            );
            let mut baseline: Option<(SimResult, f64)> = None;
            for &method in methods {
                let (r, train_time) = results.next().expect("grid result");
                let (b, bt) = baseline.get_or_insert_with(|| (r.clone(), train_time));
                let acc_delta = r.acc_delta(b);
                let time_delta = 100.0 * (1.0 - train_time / *bt);
                t.row(vec![
                    method.name().to_string(),
                    format!("{:.2} ({:+.2})", r.accuracy, acc_delta),
                    format!("{:.0} ({:.2})", train_time, time_delta),
                    format!("{:.2}", r.freeze_ratio),
                ]);
                recorder.push(
                    experiment_id,
                    crate::util::json::Json::obj(vec![
                        ("partition", crate::util::json::Json::str(partition.name())),
                        ("schedule", crate::util::json::Json::str(schedule.name())),
                        ("method", crate::util::json::Json::str(method.name())),
                        ("accuracy", crate::util::json::Json::num(r.accuracy)),
                        ("acc_delta", crate::util::json::Json::num(acc_delta)),
                        ("train_time_s", crate::util::json::Json::num(train_time)),
                        ("time_delta_pct", crate::util::json::Json::num(time_delta)),
                        ("freeze_ratio", crate::util::json::Json::num(r.freeze_ratio)),
                    ]),
                );
            }
            println!("{}", t.render());
        }
    }
    match recorder.flush() {
        Ok(paths) => println!("recorded → {:?}", paths),
        Err(e) => eprintln!("recorder error: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_env_shrinks_runs() {
        let mut cfg = ExperimentConfig::paper_preset("llama-8b").unwrap();
        std::env::set_var("TF_BENCH_QUICK", "1");
        apply_quick(&mut cfg);
        std::env::remove_var("TF_BENCH_QUICK");
        assert!(cfg.steps <= 200);
        assert!(cfg.phases.t_freeze < cfg.steps);
    }
}
