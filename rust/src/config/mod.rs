//! Configuration system: model / hardware presets matching the paper's
//! experimental setup (Table 3) and a TOML-backed experiment config for
//! the launcher.

pub mod presets;
pub mod scenario;

pub use presets::{GpuPreset, ModelFamily, ModelPreset};
pub use scenario::{
    Burst, FaultEvent, FaultKind, LinkCap, LinkSlowdown, Ramp, Scenario, Squeeze, Straggler,
};

use crate::cost::RecomputePolicy;
use crate::freeze::{ApfConfig, AutoFreezeConfig, PhaseConfig};
use crate::types::{FreezeMethod, ScheduleKind};
use crate::util::toml::TomlDoc;

/// Which executor the simulator runs batches through.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// The discrete-event engine (`sim::engine`): per-rank executors,
    /// P2P messages, event-sourced Gantt data. The default.
    #[default]
    Event,
    /// The event engine in bounded work-conserving mode: a rank whose
    /// planned head is blocked on a late P2P arrival may pull the next
    /// data-ready action of the same stage instead of idling
    /// ([`EventEngine::execute_flex`](crate::sim::engine::EventEngine::execute_flex)).
    /// Deviates from the planned order, so it is *not* covered by the
    /// bit-identity contract.
    EventWc,
    /// The analytic fast path: one longest-path sweep per step
    /// (bit-identical to the event engine when no dynamics are active).
    Analytic,
}

impl ExecMode {
    /// Parse a user-supplied name.
    pub fn parse(s: &str) -> Option<ExecMode> {
        match s.to_ascii_lowercase().as_str() {
            "event" | "engine" | "des" => Some(ExecMode::Event),
            "event-wc" | "eventwc" | "wc" => Some(ExecMode::EventWc),
            "analytic" | "fast" | "sweep" => Some(ExecMode::Analytic),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Event => "event",
            ExecMode::EventWc => "event-wc",
            ExecMode::Analytic => "analytic",
        }
    }

    /// Whether batches run through the discrete-event engine (either
    /// dispatch discipline) rather than the analytic sweep.
    pub fn is_event(self) -> bool {
        matches!(self, ExecMode::Event | ExecMode::EventWc)
    }
}

/// How the simulator reacts to whole-rank fault events
/// ([`FaultEvent`]): shrink and keep going, or start over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryStrategy {
    /// Elastic recovery (`sim/elastic.rs`): repartition layers over the
    /// survivors, rebuild the schedule/DAG/memory floors, replan freeze
    /// ratios, and resume from the last microbatch checkpoint boundary.
    Elastic,
    /// Restart-from-scratch baseline: on every fault the run rebuilds on
    /// the current fleet and replays all optimizer steps from step 0.
    Restart,
}

impl RecoveryStrategy {
    /// Parse a user-supplied name.
    pub fn parse(s: &str) -> Option<RecoveryStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "elastic" => Some(RecoveryStrategy::Elastic),
            "restart" | "scratch" => Some(RecoveryStrategy::Restart),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            RecoveryStrategy::Elastic => "elastic",
            RecoveryStrategy::Restart => "restart",
        }
    }
}

/// Full experiment description — everything a simulator or engine run
/// needs (Table 3 column).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Model under test.
    pub model: ModelPreset,
    /// Testbed device.
    pub gpu: GpuPreset,
    /// Pipeline schedule.
    pub schedule: ScheduleKind,
    /// Freezing method.
    pub method: FreezeMethod,
    /// Physical GPU ranks (pipeline-parallel degree).
    pub ranks: usize,
    /// Model chunks per rank for Interleaved/ZBV.
    pub chunks: usize,
    /// Microbatches per optimizer step.
    pub microbatches: usize,
    /// Samples per microbatch.
    pub microbatch_size: usize,
    /// Sequence length (tokens per sample).
    pub seq_len: usize,
    /// Training steps.
    pub steps: usize,
    /// Phase boundaries {T_w, T_m, T_f}.
    pub phases: PhaseConfig,
    /// Maximum average freeze ratio per stage (§3.2.2).
    pub r_max: f64,
    /// LP tie-breaker weight λ.
    pub lambda: f64,
    /// APF baseline tunables.
    pub apf: ApfConfig,
    /// AutoFreeze baseline tunables.
    pub auto: AutoFreezeConfig,
    /// Master RNG seed.
    pub seed: u64,
    /// Multiplicative timing-noise stddev for the simulator.
    pub timing_noise: f64,
    /// Fraction of each device's memory available to the job
    /// (`(0, 1]`); `None` ⇒ memory-unconstrained. When set, the runner
    /// derives the per-stage freeze-ratio floor from
    /// [`MemoryModel`](crate::cost::MemoryModel) and the TimelyFreeze LP
    /// enforces it (constraint [5]).
    pub memory_budget: Option<f64>,
    /// Per-rank device-memory capacities in bytes for mixed-GPU
    /// clusters, overriding the uniform `gpu.memory_bytes` in the
    /// memory accounting (`None` ⇒ homogeneous). Must name one capacity
    /// per rank, and requires an active `memory_budget` — setting
    /// capacities with no budget is rejected rather than silently
    /// ignored.
    pub rank_memory_bytes: Option<Vec<f64>>,
    /// Activation-recomputation policy (`--recompute {off,full,auto}`):
    /// whether stages may regenerate activations during the backward
    /// pass instead of stashing them, trading a per-stage forward-time
    /// surcharge for activation memory.
    /// [`memory_plan_for`](crate::cost::memory_plan_for) resolves it —
    /// together with `memory_budget` — into per-stage recompute
    /// fractions and a (possibly relaxed) freeze-ratio floor.
    /// [`RecomputePolicy::Off`] keeps every path bit-identical to a
    /// build without the policy.
    pub recompute: RecomputePolicy,
    /// Runtime-dynamics scenario for the event-driven executor
    /// (stragglers, jitter, link slowdowns); `None` or an identity
    /// scenario leaves execution undisturbed.
    pub scenario: Option<Scenario>,
    /// Online-replanning cadence: at every boundary `T_m + k ·
    /// replan_interval` (so possibly during the freeze ramp), the
    /// runner distills observed action times into a
    /// [`CostProfile`](crate::cost::CostProfile) and the TimelyFreeze
    /// family re-solves the warm-started LP against it. `0` ⇒ the plan
    /// stays static after `T_m` (the paper's Algorithm 1).
    pub replan_interval: usize,
    /// Which executor runs batches (event-driven, work-conserving
    /// event-driven, or analytic sweep).
    pub exec: ExecMode,
    /// Divergence-watchdog threshold in sigmas (`--watchdog <sigma>`):
    /// when any rank's EWMA of realized-vs-planned slack stays beyond
    /// `sigma` standard deviations of the calm baseline, the watchdog
    /// fires an event-driven replan ahead of the fixed
    /// `replan_interval` cadence ([`sim::watchdog`](crate::sim)).
    /// `None` ⇒ disabled (fixed-interval-only replanning, the pre-
    /// watchdog behaviour, bit-identical to older builds).
    pub watchdog: Option<f64>,
    /// Reaction to whole-rank fault events in the scenario. `None` with
    /// a faulting scenario is a configuration error
    /// ([`SimError::RankLost`](crate::sim::SimError)): the user must
    /// pick `--elastic` (or `--recovery restart`) explicitly.
    pub recovery: Option<RecoveryStrategy>,
    /// Microbatch checkpoint cadence for fault recovery: progress is
    /// durable at every `k`-th microbatch boundary within a step, so a
    /// faulted step loses only the work past the last boundary. `0` ⇒
    /// only completed optimizer steps are durable (a fault loses the
    /// whole in-flight step).
    pub ckpt_interval: usize,
    /// Network topology (`--net`): `None` or
    /// [`Topology::uniform`](crate::net::Topology::uniform) keeps the
    /// pre-network fixed-delay communication model bit-identically;
    /// a hierarchical topology prices P2P sends through the shared-link
    /// fabric ([`crate::net`]) — expected link costs in the planner,
    /// fair-shared transfers in the event engine.
    pub net: Option<crate::net::Topology>,
    /// Price the freeze LP's cross-rank edges at their *dedicated*
    /// (contention-free) link cost even though execution contends for
    /// the fabric. This is the strawman planner that
    /// `benches/fig18_contention.rs` re-evaluates under contention;
    /// it is deliberately not exposed on the CLI. Ignored when `net`
    /// is `None`.
    pub net_blind_lp: bool,
}

impl ExperimentConfig {
    /// Tokens processed per optimizer step (global batch × seq).
    pub fn tokens_per_step(&self) -> u64 {
        (self.microbatches * self.microbatch_size * self.seq_len) as u64
    }

    /// Chunk count actually used given the schedule kind.
    ///
    /// `Synthesized` is shape-flexible: the portfolio compares flat
    /// (1-chunk, R-stage) candidates against V-shape (2-chunk, 2R-stage)
    /// ones and the winner fixes the chunk count. This method reports the
    /// *configured* shape (defaults to the 2-chunk upper shape); the
    /// simulator re-derives a consistent config from the winning
    /// schedule's actual chunk count before building layouts and memory
    /// plans.
    pub fn effective_chunks(&self) -> usize {
        match self.schedule {
            ScheduleKind::GPipe | ScheduleKind::OneFOneB => 1,
            ScheduleKind::Interleaved1F1B => self.chunks.max(2),
            ScheduleKind::ZeroBubbleV => 2,
            ScheduleKind::Synthesized => self.chunks.clamp(1, 2),
        }
    }

    /// Total virtual stages.
    pub fn stages(&self) -> usize {
        self.ranks * self.effective_chunks()
    }

    /// The paper's experiment presets (Table 3 columns). Valid names:
    /// `llama-1b`, `llama-8b`, `llama-13b`, `vit-l32`, `convnextv2-l`.
    pub fn paper_preset(name: &str) -> Option<ExperimentConfig> {
        let key = name.to_ascii_lowercase().replace(['_', ' '], "-");
        let base = |model: ModelPreset,
                    gpu: GpuPreset,
                    ranks: usize,
                    microbatches: usize,
                    mb_size: usize,
                    seq: usize,
                    steps: usize,
                    phases: PhaseConfig,
                    r_max: f64,
                    t_apf: f64,
                    p_auto: f64| ExperimentConfig {
            model,
            gpu,
            schedule: ScheduleKind::GPipe,
            method: FreezeMethod::TimelyFreeze,
            ranks,
            chunks: 2,
            microbatches,
            microbatch_size: mb_size,
            seq_len: seq,
            steps,
            phases,
            r_max,
            lambda: crate::lp::DEFAULT_LAMBDA,
            apf: ApfConfig { threshold: t_apf, alpha: 0.5, check_interval: 10 },
            auto: AutoFreezeConfig { percentile: p_auto, check_interval: 10 },
            seed: 42,
            timing_noise: 0.02,
            memory_budget: None,
            rank_memory_bytes: None,
            recompute: RecomputePolicy::Off,
            scenario: None,
            replan_interval: 0,
            exec: ExecMode::Event,
            watchdog: None,
            recovery: None,
            ckpt_interval: 0,
            net: None,
            net_blind_lp: false,
        };
        Some(match key.as_str() {
            // LLaMA-3.2-1B · Alpaca-GPT4 · 4×A6000 (Table 3 col 1).
            // Global batch 128 = 8 microbatches × 16.
            "llama-1b" => base(
                ModelPreset::llama_1b(),
                GpuPreset::a6000(),
                4,
                8,
                16,
                1024,
                800,
                PhaseConfig::new(60, 100, 200),
                0.8,
                // Paper thresholds (1e-2 … 1e-4) act on Adam-update
                // statistics; calibrated to the simulator's SGD delta
                // scale (docs/ARCHITECTURE.md §"Accuracy proxy").
                0.30,
                80.0,
            ),
            // LLaMA-3-8B · OpenHermes-2.5 · 4×H200 (Table 3 col 2).
            // Global batch 64: the schedule uses 8 microbatches (§4.2).
            "llama-8b" => base(
                ModelPreset::llama_8b(),
                GpuPreset::h200(),
                4,
                8,
                8,
                1024,
                2000,
                PhaseConfig::new(160, 200, 250),
                0.8,
                0.30,
                80.0,
            ),
            // LLaMA-2-13B · OpenHermes-2.5 · 4×H200 (Table 3 col 3).
            "llama-13b" => base(
                ModelPreset::llama_13b(),
                GpuPreset::h200(),
                4,
                8,
                8,
                1024,
                2000,
                PhaseConfig::new(150, 200, 250),
                0.8,
                0.30,
                80.0,
            ),
            // ViT-L/32 · ImageNet-1K · 8×RTX3090 (Table 3 col 5).
            "vit-l32" => base(
                ModelPreset::vit_l32(),
                GpuPreset::rtx3090(),
                8,
                8,
                64,
                50,
                17_500,
                PhaseConfig::new(1400, 1600, 2400),
                0.8,
                0.38,
                80.0,
            ),
            // ConvNeXt-V2-L · Food-101 · 4×RTX3090 (Table 3 col 4).
            "convnextv2-l" => base(
                ModelPreset::convnextv2_l(),
                GpuPreset::rtx3090(),
                4,
                8,
                8,
                49,
                20_000,
                PhaseConfig::new(2350, 2850, 5600),
                0.5,
                0.32,
                80.0,
            ),
            _ => return None,
        })
    }

    /// Apply overrides from a parsed TOML doc. Recognized keys (all
    /// optional): `experiment.{schedule, method, ranks, chunks,
    /// microbatches, microbatch_size, seq_len, steps, r_max, seed,
    /// timing_noise, memory_budget, rank_memory_gb, recompute, scenario,
    /// replan_interval, exec, watchdog, recovery, ckpt_interval, net}`,
    /// `phases.{warmup, monitor, freeze}`,
    /// a `[network]` topology section
    /// ([`Topology::from_toml`](crate::net::Topology::from_toml)),
    /// `apf.{threshold, alpha, check_interval}`,
    /// `autofreeze.{percentile, check_interval}`. `rank_memory_gb` is an
    /// array of per-rank GB capacities; `recompute` is
    /// `"off" | "full" | "auto"` or a uniform fraction
    /// ([`RecomputePolicy::parse`]); `scenario` uses the
    /// [`Scenario::parse`] mini-language; `exec` is `event`,
    /// `event-wc`, or `analytic`; `watchdog` is a positive sigma
    /// threshold (0 disables); `recovery` is `elastic` or `restart`.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<(), String> {
        if let Some(s) = doc.get_str("experiment.schedule") {
            self.schedule =
                ScheduleKind::parse(s).ok_or_else(|| format!("unknown schedule '{s}'"))?;
        }
        if let Some(s) = doc.get_str("experiment.method") {
            self.method =
                FreezeMethod::parse(s).ok_or_else(|| format!("unknown method '{s}'"))?;
        }
        macro_rules! set_usize {
            ($key:expr, $field:expr) => {
                if let Some(v) = doc.get_usize($key) {
                    $field = v;
                }
            };
        }
        macro_rules! set_f64 {
            ($key:expr, $field:expr) => {
                if let Some(v) = doc.get_f64($key) {
                    $field = v;
                }
            };
        }
        set_usize!("experiment.ranks", self.ranks);
        set_usize!("experiment.chunks", self.chunks);
        set_usize!("experiment.microbatches", self.microbatches);
        set_usize!("experiment.microbatch_size", self.microbatch_size);
        set_usize!("experiment.seq_len", self.seq_len);
        set_usize!("experiment.steps", self.steps);
        set_f64!("experiment.r_max", self.r_max);
        set_f64!("experiment.timing_noise", self.timing_noise);
        if let Some(v) = doc.get_f64("experiment.memory_budget") {
            if !(0.0..=1.0).contains(&v) || v == 0.0 {
                return Err(format!("memory_budget {v} outside (0,1]"));
            }
            self.memory_budget = Some(v);
        }
        if let Some(v) = doc.get("experiment.rank_memory_gb") {
            let arr = v
                .as_arr()
                .ok_or_else(|| "rank_memory_gb must be an array of GB values".to_string())?;
            let caps: Vec<f64> = arr
                .iter()
                .map(|x| {
                    x.as_f64()
                        .filter(|g| *g > 0.0 && g.is_finite())
                        .map(|g| g * 1e9)
                        .ok_or_else(|| {
                            "rank_memory_gb entries must be positive numbers".to_string()
                        })
                })
                .collect::<Result<_, _>>()?;
            self.rank_memory_bytes = Some(caps);
        }
        if let Some(s) = doc.get_str("experiment.recompute") {
            self.recompute = RecomputePolicy::parse(s)?;
        } else if let Some(f) = doc.get_f64("experiment.recompute") {
            self.recompute = RecomputePolicy::parse(&f.to_string())?;
        }
        if let Some(s) = doc.get_str("experiment.scenario") {
            self.scenario = Some(Scenario::parse(s)?);
        }
        set_usize!("experiment.replan_interval", self.replan_interval);
        if let Some(s) = doc.get_str("experiment.exec") {
            self.exec =
                ExecMode::parse(s).ok_or_else(|| format!("unknown exec mode '{s}'"))?;
        }
        if let Some(v) = doc.get_f64("experiment.watchdog") {
            if v < 0.0 || !v.is_finite() {
                return Err(format!("watchdog sigma {v} must be a finite value ≥ 0"));
            }
            self.watchdog = (v > 0.0).then_some(v);
        }
        if let Some(s) = doc.get_str("experiment.recovery") {
            self.recovery = Some(
                RecoveryStrategy::parse(s)
                    .ok_or_else(|| format!("unknown recovery strategy '{s}'"))?,
            );
        }
        set_usize!("experiment.ckpt_interval", self.ckpt_interval);
        if let Some(s) = doc.get_str("experiment.net") {
            self.net = Some(crate::net::Topology::parse(s)?);
        }
        // A `[network]` section (the `--net topo.toml` format) also
        // installs a topology; an inline `experiment.net` spec wins when
        // both are present in one document.
        if self.net.is_none() {
            if let Some(topo) = crate::net::Topology::from_toml(doc)? {
                self.net = Some(topo);
            }
        }
        if let Some(v) = doc.get_i64("experiment.seed") {
            self.seed = v as u64;
        }
        let (mut w, mut m, mut f) =
            (self.phases.t_warmup, self.phases.t_monitor, self.phases.t_freeze);
        set_usize!("phases.warmup", w);
        set_usize!("phases.monitor", m);
        set_usize!("phases.freeze", f);
        if w >= m || m >= f {
            return Err(format!("invalid phase boundaries {w} < {m} < {f} required"));
        }
        self.phases = PhaseConfig::new(w, m, f);
        set_f64!("apf.threshold", self.apf.threshold);
        set_f64!("apf.alpha", self.apf.alpha);
        set_usize!("apf.check_interval", self.apf.check_interval);
        set_f64!("autofreeze.percentile", self.auto.percentile);
        set_usize!("autofreeze.check_interval", self.auto.check_interval);
        if !(0.0..=1.0).contains(&self.r_max) {
            return Err(format!("r_max {} outside [0,1]", self.r_max));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_paper_presets_resolve() {
        for name in ["llama-1b", "llama-8b", "llama-13b", "vit-l32", "convnextv2-l"] {
            let cfg = ExperimentConfig::paper_preset(name)
                .unwrap_or_else(|| panic!("missing preset {name}"));
            assert!(cfg.steps > 0);
            assert!(cfg.model.total_params() > 0.0);
        }
        assert!(ExperimentConfig::paper_preset("nope").is_none());
    }

    #[test]
    fn tokens_per_step_llama8b() {
        let cfg = ExperimentConfig::paper_preset("llama-8b").unwrap();
        // 8 microbatches × 8 samples × 1024 seq = 65536 tokens/step.
        assert_eq!(cfg.tokens_per_step(), 65_536);
    }

    #[test]
    fn toml_overrides() {
        let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        let doc = TomlDoc::parse(
            "[experiment]\nschedule = \"zbv\"\nmethod = \"apf\"\nsteps = 99\nr_max = 0.5\n\
             [phases]\nwarmup = 5\nmonitor = 10\nfreeze = 20\n[apf]\nthreshold = 0.02",
        )
        .unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.schedule, ScheduleKind::ZeroBubbleV);
        assert_eq!(cfg.method, FreezeMethod::Apf);
        assert_eq!(cfg.steps, 99);
        assert_eq!(cfg.r_max, 0.5);
        assert_eq!(cfg.phases.t_warmup, 5);
        assert_eq!(cfg.apf.threshold, 0.02);
    }

    #[test]
    fn toml_rejects_bad_values() {
        let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        let doc = TomlDoc::parse("[experiment]\nschedule = \"warp\"").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        let doc = TomlDoc::parse("[phases]\nwarmup = 50\nmonitor = 10\nfreeze = 60").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        let doc = TomlDoc::parse("[experiment]\nmemory_budget = 1.5").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
    }

    #[test]
    fn toml_sets_dynamics_and_hetero_keys() {
        let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        let doc = TomlDoc::parse(
            "[experiment]\nscenario = \"straggler:1x1.5@30,jitter:0.05\"\n\
             replan_interval = 25\nexec = \"analytic\"\n\
             rank_memory_gb = [48.0, 48.0, 24.0, 48.0]",
        )
        .unwrap();
        cfg.apply_toml(&doc).unwrap();
        let sc = cfg.scenario.as_ref().unwrap();
        assert_eq!(sc.stragglers.len(), 1);
        assert_eq!(sc.jitter_sigma, 0.05);
        assert_eq!(cfg.replan_interval, 25);
        assert_eq!(cfg.exec, ExecMode::Analytic);
        assert_eq!(
            cfg.rank_memory_bytes.as_deref(),
            Some(&[48e9, 48e9, 24e9, 48e9][..])
        );
        // Malformed values are clean errors, not panics.
        let doc = TomlDoc::parse("[experiment]\nscenario = \"warp:9\"").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        let doc = TomlDoc::parse("[experiment]\nexec = \"quantum\"").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        let doc = TomlDoc::parse("[experiment]\nrank_memory_gb = [48.0, -1.0]").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
    }

    #[test]
    fn toml_sets_watchdog_and_wc_exec() {
        let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        assert_eq!(cfg.watchdog, None);
        let doc = TomlDoc::parse(
            "[experiment]\nwatchdog = 3.0\nexec = \"event-wc\"\n\
             scenario = \"ramp:1x2.0@200-400,burst:0.1@100-150\"",
        )
        .unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.watchdog, Some(3.0));
        assert_eq!(cfg.exec, ExecMode::EventWc);
        let sc = cfg.scenario.as_ref().unwrap();
        assert_eq!(sc.ramps.len(), 1);
        assert_eq!(sc.bursts.len(), 1);
        assert!(sc.has_dynamics());
        // 0 disables; negatives are clean errors.
        let doc = TomlDoc::parse("[experiment]\nwatchdog = 0.0").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.watchdog, None);
        let doc = TomlDoc::parse("[experiment]\nwatchdog = -1.0").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        // Round-trip names and aliases.
        assert_eq!(ExecMode::parse("event-wc"), Some(ExecMode::EventWc));
        assert_eq!(ExecMode::parse("wc"), Some(ExecMode::EventWc));
        assert_eq!(ExecMode::EventWc.name(), "event-wc");
        assert!(ExecMode::EventWc.is_event());
        assert!(ExecMode::Event.is_event());
        assert!(!ExecMode::Analytic.is_event());
    }

    #[test]
    fn toml_sets_recovery_keys() {
        let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        assert_eq!(cfg.recovery, None);
        assert_eq!(cfg.ckpt_interval, 0);
        let doc = TomlDoc::parse(
            "[experiment]\nscenario = \"crash:2@500\"\nrecovery = \"elastic\"\n\
             ckpt_interval = 2",
        )
        .unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.recovery, Some(RecoveryStrategy::Elastic));
        assert_eq!(cfg.ckpt_interval, 2);
        assert!(cfg.scenario.as_ref().unwrap().has_faults());
        let doc = TomlDoc::parse("[experiment]\nrecovery = \"restart\"").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.recovery, Some(RecoveryStrategy::Restart));
        // Unknown strategies are clean errors.
        let doc = TomlDoc::parse("[experiment]\nrecovery = \"pray\"").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        // Round-trip names.
        assert_eq!(RecoveryStrategy::parse("elastic"), Some(RecoveryStrategy::Elastic));
        assert_eq!(RecoveryStrategy::parse("scratch"), Some(RecoveryStrategy::Restart));
        assert_eq!(RecoveryStrategy::Elastic.name(), "elastic");
        assert_eq!(RecoveryStrategy::Restart.name(), "restart");
    }

    #[test]
    fn toml_sets_network_topology() {
        use crate::net::Topology;
        let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        assert!(cfg.net.is_none());
        // Inline spec on the experiment table.
        let doc = TomlDoc::parse("[experiment]\nnet = \"island:2x1e12,spine:5e10\"").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.net, Some(Topology::parse("island:2x1e12,spine:5e10").unwrap()));
        // A [network] section (the `--net topo.toml` format).
        let mut cfg2 = ExperimentConfig::paper_preset("llama-1b").unwrap();
        let doc = TomlDoc::parse(
            "[network]\nmode = \"hierarchical\"\nisland_size = 2\n\
             island_bandwidth = 1e12\nspine_bandwidth = 5e10",
        )
        .unwrap();
        cfg2.apply_toml(&doc).unwrap();
        // Labels may differ (parsed spec vs canonical); shapes must not.
        assert_eq!(cfg2.net.as_ref().unwrap().kind, cfg.net.as_ref().unwrap().kind);
        // Uniform is representable and malformed specs are clean errors.
        let doc = TomlDoc::parse("[experiment]\nnet = \"uniform\"").unwrap();
        cfg2.apply_toml(&doc).unwrap();
        assert!(cfg2.net.as_ref().unwrap().is_uniform());
        let doc = TomlDoc::parse("[experiment]\nnet = \"mesh:3\"").unwrap();
        assert!(cfg2.apply_toml(&doc).is_err());
    }

    #[test]
    fn toml_sets_memory_budget() {
        let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        assert_eq!(cfg.memory_budget, None);
        let doc = TomlDoc::parse("[experiment]\nmemory_budget = 0.35").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.memory_budget, Some(0.35));
    }

    #[test]
    fn toml_sets_recompute_policy() {
        let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        assert_eq!(cfg.recompute, RecomputePolicy::Off);
        let doc = TomlDoc::parse("[experiment]\nrecompute = \"auto\"").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.recompute, RecomputePolicy::Auto);
        let doc = TomlDoc::parse("[experiment]\nrecompute = \"full\"").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.recompute, RecomputePolicy::Full);
        // A bare TOML number is a uniform per-stage fraction.
        let doc = TomlDoc::parse("[experiment]\nrecompute = 0.5").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.recompute, RecomputePolicy::Fraction(0.5));
        let doc = TomlDoc::parse("[experiment]\nrecompute = \"off\"").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.recompute, RecomputePolicy::Off);
        // Malformed policies are clean errors.
        let doc = TomlDoc::parse("[experiment]\nrecompute = \"sometimes\"").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        let doc = TomlDoc::parse("[experiment]\nrecompute = 1.7").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
    }

    #[test]
    fn effective_chunks_by_schedule() {
        let mut cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        cfg.schedule = ScheduleKind::GPipe;
        assert_eq!(cfg.effective_chunks(), 1);
        cfg.schedule = ScheduleKind::Interleaved1F1B;
        assert_eq!(cfg.effective_chunks(), 2);
        cfg.schedule = ScheduleKind::ZeroBubbleV;
        assert_eq!(cfg.stages(), 8);
        // Synthesized defaults to the 2-chunk upper shape but follows an
        // explicit 1-chunk request (flat candidates).
        cfg.schedule = ScheduleKind::Synthesized;
        assert_eq!(cfg.effective_chunks(), 2);
        cfg.chunks = 1;
        assert_eq!(cfg.effective_chunks(), 1);
        cfg.chunks = 7;
        assert_eq!(cfg.effective_chunks(), 2);
    }
}
