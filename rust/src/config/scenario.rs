//! Runtime-dynamics scenarios for the discrete-event simulator: seeded,
//! composable perturbations of an execution the static LP plan did not
//! predict.
//!
//! Three dynamics compose freely (OptPipe and Zero Bubble Pipeline
//! Parallelism both observe that exactly these skews degrade static
//! schedules):
//!
//! * **stragglers** — a per-rank multiplier on compute time (a thermally
//!   throttled or contended device), optionally appearing only from an
//!   onset step, so a plan solved during monitoring can be invalidated
//!   mid-run;
//! * **jitter** — multiplicative per-action noise sampled from a seeded
//!   normal, modelling kernel-time variance beyond the simulator's base
//!   `timing_noise`;
//! * **link slowdowns** — multipliers on communication time, either on
//!   every link (node-charged comm and all P2P edges) or on one stage
//!   boundary's P2P link.
//!
//! All randomness derives from `(scenario seed ⊕ run seed, step, node)`
//! counters, never from event order, so a fixed seed makes scenario
//! runs fully deterministic and the event-driven executor stays
//! replayable (`tests/event_engine.rs` pins this).
//!
//! Scenarios are built from presets ([`Scenario::straggler`],
//! [`Scenario::jittery`], [`Scenario::congested`]), composed with the
//! `with_*` builders, or parsed from the CLI/TOML mini-language of
//! [`Scenario::parse`]:
//!
//! ```text
//! straggler:1x1.5          rank 1 runs 1.5× slower from step 0
//! straggler:1x1.5@300      … appearing at step 300
//! jitter:0.1               σ = 0.1 multiplicative action jitter
//! link:2.0                 all communication 2× slower
//! link:0x4.0@100           boundary 0↔1 4× slower from step 100
//! seed:7                   scenario RNG stream
//! ```
//!
//! Terms combine with commas: `straggler:2x2.0@250,jitter:0.05`.

use crate::util::rng::Rng;

/// A per-rank compute slowdown, active from `onset`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Straggler {
    /// The slowed GPU rank.
    pub rank: usize,
    /// Compute-time multiplier (> 1 ⇒ slower).
    pub factor: f64,
    /// First step the slowdown applies to.
    pub onset: usize,
}

/// A communication slowdown, active from `onset`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkSlowdown {
    /// `None` ⇒ every link (including node-charged comm); `Some(b)` ⇒
    /// the stage boundary `b ↔ b+1`: its P2P edge delays when the cost
    /// model charges communication to edges, and the node-charged comm
    /// of the two adjacent stages otherwise
    /// ([`Scenario::stage_link_factor`]).
    pub boundary: Option<usize>,
    /// Communication-time multiplier (> 1 ⇒ slower).
    pub factor: f64,
    /// First step the slowdown applies to.
    pub onset: usize,
}

/// A composed runtime-dynamics scenario (see the module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Human-readable label (the parse spec, or the preset name).
    pub label: String,
    /// Per-rank compute slowdowns.
    pub stragglers: Vec<Straggler>,
    /// Stddev of the multiplicative per-action jitter (0 ⇒ none).
    pub jitter_sigma: f64,
    /// First step the jitter applies to.
    pub jitter_onset: usize,
    /// Communication slowdowns.
    pub links: Vec<LinkSlowdown>,
    /// Scenario RNG stream, xor-folded with the run seed.
    pub seed: u64,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            label: "calm".to_string(),
            stragglers: Vec::new(),
            jitter_sigma: 0.0,
            jitter_onset: 0,
            links: Vec::new(),
            seed: 0,
        }
    }
}

impl std::fmt::Display for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label)
    }
}

impl Scenario {
    /// The identity scenario: no dynamics (bit-identical to running
    /// without a scenario).
    pub fn calm() -> Scenario {
        Scenario::default()
    }

    /// One rank `factor`× slower from step 0.
    pub fn straggler(rank: usize, factor: f64) -> Scenario {
        Scenario::calm()
            .with_straggler(rank, factor, 0)
            .relabel(&format!("straggler:{rank}x{factor}"))
    }

    /// Multiplicative per-action jitter with stddev `sigma`.
    pub fn jittery(sigma: f64) -> Scenario {
        Scenario::calm().with_jitter(sigma, 0).relabel(&format!("jitter:{sigma}"))
    }

    /// Every link `factor`× slower from step 0.
    pub fn congested(factor: f64) -> Scenario {
        Scenario::calm()
            .with_link(None, factor, 0)
            .relabel(&format!("link:{factor}"))
    }

    /// Add a per-rank compute slowdown.
    pub fn with_straggler(mut self, rank: usize, factor: f64, onset: usize) -> Scenario {
        assert!(factor > 0.0 && factor.is_finite(), "straggler factor must be positive");
        self.stragglers.push(Straggler { rank, factor, onset });
        self
    }

    /// Set the per-action jitter stddev and onset.
    pub fn with_jitter(mut self, sigma: f64, onset: usize) -> Scenario {
        assert!(sigma >= 0.0 && sigma.is_finite(), "jitter sigma must be ≥ 0");
        self.jitter_sigma = sigma;
        self.jitter_onset = onset;
        self
    }

    /// Add a communication slowdown (`boundary = None` ⇒ all links).
    pub fn with_link(mut self, boundary: Option<usize>, factor: f64, onset: usize) -> Scenario {
        assert!(factor > 0.0 && factor.is_finite(), "link factor must be positive");
        self.links.push(LinkSlowdown { boundary, factor, onset });
        self
    }

    /// Set the scenario RNG stream.
    pub fn with_seed(mut self, seed: u64) -> Scenario {
        self.seed = seed;
        self
    }

    /// Replace the label.
    pub fn relabel(mut self, label: &str) -> Scenario {
        self.label = label.to_string();
        self
    }

    /// Parse the comma-separated mini-language (see the module docs).
    pub fn parse(spec: &str) -> Result<Scenario, String> {
        let mut sc = Scenario::calm().relabel(spec.trim());
        for term in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (head, rest) = match term.split_once(':') {
                Some((h, r)) => (h.trim(), Some(r.trim())),
                None => (term, None),
            };
            match (head, rest) {
                ("calm", None) => {}
                ("straggler", Some(arg)) => {
                    let (body, onset) = split_onset(arg)?;
                    let (rank, factor) = body.split_once('x').ok_or_else(|| {
                        format!("straggler term '{term}' wants <rank>x<factor>[@onset]")
                    })?;
                    let rank = rank
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| format!("bad straggler rank in '{term}'"))?;
                    let factor = parse_factor(factor, term)?;
                    sc = sc.with_straggler(rank, factor, onset);
                }
                ("jitter", Some(arg)) => {
                    let (body, onset) = split_onset(arg)?;
                    let sigma = body
                        .trim()
                        .parse::<f64>()
                        .ok()
                        .filter(|s| *s >= 0.0 && s.is_finite())
                        .ok_or_else(|| format!("bad jitter sigma in '{term}'"))?;
                    sc = sc.with_jitter(sigma, onset);
                }
                ("link", Some(arg)) => {
                    let (body, onset) = split_onset(arg)?;
                    let (boundary, factor) = match body.split_once('x') {
                        Some((b, f)) => {
                            let b = b
                                .trim()
                                .parse::<usize>()
                                .map_err(|_| format!("bad link boundary in '{term}'"))?;
                            (Some(b), parse_factor(f, term)?)
                        }
                        None => (None, parse_factor(body, term)?),
                    };
                    sc = sc.with_link(boundary, factor, onset);
                }
                ("seed", Some(arg)) => {
                    let seed = arg
                        .parse::<u64>()
                        .map_err(|_| format!("bad scenario seed in '{term}'"))?;
                    sc = sc.with_seed(seed);
                }
                _ => {
                    return Err(format!(
                        "unknown scenario term '{term}' \
                         (try straggler:<rank>x<factor>[@onset], jitter:<sigma>[@onset], \
                         link:[<boundary>x]<factor>[@onset], seed:<n>, calm)"
                    ))
                }
            }
        }
        Ok(sc)
    }

    /// Check rank/boundary indices against a concrete pipeline shape.
    pub fn validate(&self, ranks: usize, stages: usize) -> Result<(), String> {
        for s in &self.stragglers {
            if s.rank >= ranks {
                return Err(format!(
                    "scenario straggles rank {} but the pipeline has {ranks} ranks",
                    s.rank
                ));
            }
        }
        for l in &self.links {
            if let Some(b) = l.boundary {
                if b + 1 >= stages {
                    return Err(format!(
                        "scenario slows boundary {b} but the pipeline has only {} \
                         boundaries",
                        stages.saturating_sub(1)
                    ));
                }
            }
        }
        Ok(())
    }

    /// Whether the scenario perturbs nothing — the runner treats an
    /// identity scenario exactly like no scenario, preserving the
    /// bit-identity contract of the event engine.
    pub fn is_identity(&self) -> bool {
        self.jitter_sigma == 0.0
            && self.stragglers.iter().all(|s| s.factor == 1.0)
            && self.links.iter().all(|l| l.factor == 1.0)
    }

    /// Compute-time multiplier of `rank` at step `t` (product of active
    /// stragglers).
    pub fn rank_factor(&self, rank: usize, t: usize) -> f64 {
        self.stragglers
            .iter()
            .filter(|s| s.rank == rank && t >= s.onset)
            .map(|s| s.factor)
            .product()
    }

    /// Communication multiplier of every link at step `t` (the global
    /// terms only).
    pub fn global_link_factor(&self, t: usize) -> f64 {
        self.links
            .iter()
            .filter(|l| l.boundary.is_none() && t >= l.onset)
            .map(|l| l.factor)
            .product()
    }

    /// Communication multiplier of stage `stage`'s *node-charged* comm
    /// at step `t`: the global terms, times any per-boundary term on a
    /// boundary adjacent to the stage (`stage−1 ↔ stage` carries its
    /// inbound activations, `stage ↔ stage+1` its inbound gradients).
    /// This is how boundary-targeted slowdowns reach the analytic
    /// presets, whose cost models charge communication to nodes rather
    /// than P2P edges.
    pub fn stage_link_factor(&self, stage: usize, t: usize) -> f64 {
        self.links
            .iter()
            .filter(|l| t >= l.onset)
            .filter(|l| match l.boundary {
                None => true,
                Some(b) => b == stage || b + 1 == stage,
            })
            .map(|l| l.factor)
            .product()
    }

    /// Communication multiplier of the P2P link across stage boundary
    /// `boundary` at step `t` (global terms × matching per-boundary
    /// terms).
    pub fn edge_link_factor(&self, boundary: usize, t: usize) -> f64 {
        self.global_link_factor(t)
            * self
                .links
                .iter()
                .filter(|l| l.boundary == Some(boundary) && t >= l.onset)
                .map(|l| l.factor)
                .product::<f64>()
    }

    /// Multiplicative jitter sample for `(step, node)` under the run's
    /// master seed — a counter-derived stream, independent of event
    /// order, clamped away from zero like the simulator's base timing
    /// noise.
    pub fn jitter_mult(&self, run_seed: u64, t: usize, node: usize) -> f64 {
        if self.jitter_sigma == 0.0 || t < self.jitter_onset {
            return 1.0;
        }
        let mut rng = Rng::seed_from_u64(self.seed ^ run_seed ^ 0x5CE0_A11D)
            .derive(t as u64, node as u64);
        (1.0 + self.jitter_sigma * rng.normal()).max(0.05)
    }
}

fn split_onset(arg: &str) -> Result<(&str, usize), String> {
    match arg.split_once('@') {
        None => Ok((arg, 0)),
        Some((body, onset)) => {
            let onset = onset
                .trim()
                .parse::<usize>()
                .map_err(|_| format!("bad onset step in '{arg}'"))?;
            Ok((body, onset))
        }
    }
}

fn parse_factor(s: &str, term: &str) -> Result<f64, String> {
    s.trim()
        .parse::<f64>()
        .ok()
        .filter(|f| *f > 0.0 && f.is_finite())
        .ok_or_else(|| format!("bad factor in '{term}' (must be a positive number)"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calm_is_identity() {
        assert!(Scenario::calm().is_identity());
        assert!(Scenario::parse("calm").unwrap().is_identity());
        assert!(!Scenario::straggler(1, 1.5).is_identity());
        assert!(Scenario::straggler(1, 1.0).is_identity());
        assert!(!Scenario::jittery(0.1).is_identity());
        assert!(!Scenario::congested(2.0).is_identity());
    }

    #[test]
    fn parse_composes_terms() {
        let sc = Scenario::parse("straggler:2x1.5@300, jitter:0.05, link:0x4.0@100, seed:7")
            .unwrap();
        assert_eq!(
            sc.stragglers,
            vec![Straggler { rank: 2, factor: 1.5, onset: 300 }]
        );
        assert_eq!(sc.jitter_sigma, 0.05);
        assert_eq!(sc.jitter_onset, 0);
        assert_eq!(
            sc.links,
            vec![LinkSlowdown { boundary: Some(0), factor: 4.0, onset: 100 }]
        );
        assert_eq!(sc.seed, 7);
        assert_eq!(sc.to_string(), "straggler:2x1.5@300, jitter:0.05, link:0x4.0@100, seed:7");
    }

    #[test]
    fn parse_rejects_malformed_terms() {
        for bad in [
            "straggler:1.5",
            "straggler:ax2",
            "straggler:1x-2",
            "jitter:-0.1",
            "link:0x",
            "wibble:3",
            "seed:x",
            "straggler:1x2@x",
        ] {
            assert!(Scenario::parse(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn onset_gates_factors() {
        let sc = Scenario::calm()
            .with_straggler(1, 2.0, 100)
            .with_link(Some(0), 3.0, 50)
            .with_link(None, 1.5, 0);
        assert_eq!(sc.rank_factor(1, 99), 1.0);
        assert_eq!(sc.rank_factor(1, 100), 2.0);
        assert_eq!(sc.rank_factor(0, 500), 1.0);
        assert_eq!(sc.global_link_factor(10), 1.5);
        assert_eq!(sc.edge_link_factor(0, 49), 1.5);
        assert_eq!(sc.edge_link_factor(0, 50), 4.5);
        assert_eq!(sc.edge_link_factor(1, 50), 1.5);
    }

    #[test]
    fn stage_link_factor_hits_adjacent_stages() {
        let sc = Scenario::calm()
            .with_link(Some(1), 3.0, 0)
            .with_link(None, 2.0, 10);
        // Boundary 1 ↔ 2 touches stages 1 and 2, nothing else.
        assert_eq!(sc.stage_link_factor(0, 0), 1.0);
        assert_eq!(sc.stage_link_factor(1, 0), 3.0);
        assert_eq!(sc.stage_link_factor(2, 0), 3.0);
        assert_eq!(sc.stage_link_factor(3, 0), 1.0);
        // The global term stacks once its onset passes.
        assert_eq!(sc.stage_link_factor(1, 10), 6.0);
        assert_eq!(sc.stage_link_factor(3, 10), 2.0);
    }

    #[test]
    fn stacked_stragglers_multiply() {
        let sc = Scenario::calm()
            .with_straggler(0, 2.0, 0)
            .with_straggler(0, 1.5, 10);
        assert_eq!(sc.rank_factor(0, 5), 2.0);
        assert_eq!(sc.rank_factor(0, 10), 3.0);
    }

    #[test]
    fn jitter_is_deterministic_and_seed_sensitive() {
        let sc = Scenario::jittery(0.1).with_seed(3);
        let a = sc.jitter_mult(42, 5, 17);
        let b = sc.jitter_mult(42, 5, 17);
        assert_eq!(a, b);
        assert!(a > 0.0);
        assert_ne!(a, sc.jitter_mult(43, 5, 17));
        assert_ne!(a, sc.jitter_mult(42, 6, 17));
        assert_ne!(a, sc.jitter_mult(42, 5, 18));
        let other = Scenario::jittery(0.1).with_seed(4);
        assert_ne!(a, other.jitter_mult(42, 5, 17));
        // Onset gates sampling entirely.
        let late = Scenario::calm().with_jitter(0.1, 100);
        assert_eq!(late.jitter_mult(42, 99, 0), 1.0);
        assert_ne!(late.jitter_mult(42, 100, 0), 1.0);
    }

    #[test]
    fn validate_checks_shape() {
        let sc = Scenario::straggler(4, 2.0);
        assert!(sc.validate(4, 4).is_err());
        assert!(sc.validate(5, 5).is_ok());
        let sc = Scenario::calm().with_link(Some(3), 2.0, 0);
        assert!(sc.validate(4, 4).is_err());
        assert!(sc.validate(4, 8).is_ok());
    }
}
