//! Runtime-dynamics scenarios for the discrete-event simulator: seeded,
//! composable perturbations of an execution the static LP plan did not
//! predict.
//!
//! Three dynamics compose freely (OptPipe and Zero Bubble Pipeline
//! Parallelism both observe that exactly these skews degrade static
//! schedules):
//!
//! * **stragglers** — a per-rank multiplier on compute time (a thermally
//!   throttled or contended device), optionally appearing only from an
//!   onset step, so a plan solved during monitoring can be invalidated
//!   mid-run;
//! * **jitter** — multiplicative per-action noise sampled from a seeded
//!   normal, modelling kernel-time variance beyond the simulator's base
//!   `timing_noise`;
//! * **link slowdowns** — multipliers on communication time, either on
//!   every link (node-charged comm and all P2P edges) or on one stage
//!   boundary's P2P link.
//!
//! Two further dynamics vary **within** a batch rather than per step:
//! a **ramp** (`ramp:<rank>x<factor>@<from>-<until>`) is a transient
//! straggler whose multiplier climbs linearly from 1 at the window
//! start to the full factor at the window midpoint and decays back to
//! 1 — and a **burst** (`burst:<sigma>@<from>-<until>`) is jitter
//! active only inside its window. Both are sampled *per action start*
//! by the event executor (at the continuous step coordinate
//! `step + fraction-of-batch-elapsed`), not frozen per batch, so an
//! action launched late in a boundary step sees a different multiplier
//! than one launched early. They therefore require `--exec event`; the
//! runner rejects them on the analytic path, which has no per-action
//! start times to sample at.
//!
//! All randomness derives from `(scenario seed ⊕ run seed, step, node)`
//! counters, never from event order, so a fixed seed makes scenario
//! runs fully deterministic and the event-driven executor stays
//! replayable (`tests/event_engine.rs` pins this).
//!
//! Scenarios are built from presets ([`Scenario::straggler`],
//! [`Scenario::jittery`], [`Scenario::congested`]), composed with the
//! `with_*` builders, or parsed from the CLI/TOML mini-language of
//! [`Scenario::parse`]:
//!
//! ```text
//! straggler:1x1.5          rank 1 runs 1.5× slower from step 0
//! straggler:1x1.5@300      … appearing at step 300
//! jitter:0.1               σ = 0.1 multiplicative action jitter
//! link:2.0                 all communication 2× slower
//! link:0x4.0@100           boundary 0↔1 4× slower from step 100
//! linkcap:0-1x0.5@200      links routing rank 0 → 1 at half capacity
//!                          from step 200 (needs a `--net` topology)
//! ramp:1x2.0@200-400       rank 1 ramps to 2.0× at step 300 and back
//!                          (transient straggler; needs `--exec event`)
//! burst:0.2@100-150        σ = 0.2 jitter during steps 100..150 only
//!                          (needs `--exec event`)
//! squeeze:0.5@300          memory budget halves from step 300, so
//!                          replans may turn infeasible (degradation
//!                          ladder territory)
//! seed:7                   scenario RNG stream
//! crash:2@500              rank 2 fails permanently at step 500
//! preempt:1@300-450        rank 1 is preempted for steps 300..450
//! evict-slowest@400        kill the worst straggler at step 400
//! ```
//!
//! Terms combine with commas: `straggler:2x2.0@250,jitter:0.05`.
//!
//! The three **fault** terms model whole-rank loss rather than slowdown:
//! a crash is permanent, a preemption ends at its `until` step, and
//! `evict-slowest` resolves — at its onset, against the fleet alive at
//! that instant — to the rank with the largest active straggler factor
//! (ties broken toward the highest rank, which is also the choice when
//! no straggler is active). Fault runs require a recovery strategy
//! ([`ExperimentConfig::recovery`](crate::config::ExperimentConfig));
//! see `sim/elastic.rs` for the repartition-and-replan semantics.

use crate::util::rng::Rng;

/// A per-rank compute slowdown, active from `onset`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Straggler {
    /// The slowed GPU rank.
    pub rank: usize,
    /// Compute-time multiplier (> 1 ⇒ slower).
    pub factor: f64,
    /// First step the slowdown applies to.
    pub onset: usize,
}

/// A communication slowdown, active from `onset`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkSlowdown {
    /// `None` ⇒ every link (including node-charged comm); `Some(b)` ⇒
    /// the stage boundary `b ↔ b+1`: its P2P edge delays when the cost
    /// model charges communication to edges, and the node-charged comm
    /// of the two adjacent stages otherwise
    /// ([`Scenario::stage_link_factor`]).
    pub boundary: Option<usize>,
    /// Communication-time multiplier (> 1 ⇒ slower).
    pub factor: f64,
    /// First step the slowdown applies to.
    pub onset: usize,
}

/// A capacity change on the network links between two ranks, active
/// from `onset` (the `linkcap:<a>-<b>x<factor>[@onset]` term).
///
/// Unlike [`LinkSlowdown`] — a multiplier on communication *time* —
/// a `LinkCap` scales the *capacity* of every fabric link on the route
/// from rank `a` to rank `b`, so its effect depends on contention:
/// halving a shared spine hurts every transfer crossing it, not just
/// the named pair. Requires an active `--net` topology; the runner
/// rejects capacity terms on the fixed-delay fabric.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkCap {
    /// Route endpoint (a physical rank).
    pub from: usize,
    /// Route endpoint (a physical rank).
    pub to: usize,
    /// Capacity multiplier (< 1 ⇒ less bandwidth).
    pub factor: f64,
    /// First step the capacity change applies to.
    pub onset: usize,
}

/// A transient straggler (`ramp:<rank>x<factor>@<from>-<until>`): the
/// rank's compute multiplier climbs linearly from 1 at `from` to
/// `factor` at the window midpoint, then decays linearly back to 1 at
/// `until` — a triangular profile sampled per action start by the
/// event executor (see [`Ramp::factor_at`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Ramp {
    /// The affected GPU rank.
    pub rank: usize,
    /// Peak compute-time multiplier, reached at the window midpoint.
    pub factor: f64,
    /// First step of the transient window.
    pub from: usize,
    /// First step past the transient window.
    pub until: usize,
}

impl Ramp {
    /// The multiplier at continuous step coordinate `u` (step units;
    /// the event executor passes `step + fraction-of-batch-elapsed`).
    /// 1 outside `[from, until)`; inside, a triangular interpolation
    /// peaking at `factor` at the window midpoint.
    pub fn factor_at(&self, u: f64) -> f64 {
        let (a, b) = (self.from as f64, self.until as f64);
        if u < a || u >= b {
            return 1.0;
        }
        let x = (u - a) / (b - a);
        let tri = 1.0 - (2.0 * x - 1.0).abs();
        1.0 + (self.factor - 1.0) * tri
    }
}

/// Windowed per-action jitter (`burst:<sigma>@<from>-<until>`): extra
/// multiplicative noise of stddev `sigma`, applied only to actions
/// whose continuous start coordinate falls inside `[from, until)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Burst {
    /// Stddev of the multiplicative jitter inside the window.
    pub sigma: f64,
    /// First step of the burst window.
    pub from: usize,
    /// First step past the burst window.
    pub until: usize,
}

/// A memory-budget squeeze (`squeeze:<factor>@<onset>`): the device
/// memory budget is scaled by `factor` from `onset`, tightening the
/// per-stage freeze floors at the next replan — and possibly past
/// feasibility, exercising the degradation ladder
/// (`freeze/timely.rs`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Squeeze {
    /// Budget multiplier (< 1 ⇒ less memory).
    pub factor: f64,
    /// First step the squeeze applies to.
    pub onset: usize,
}

/// What a [`FaultEvent`] does to its victim.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Permanent rank loss (`crash:RANK@T`).
    Crash {
        /// The failing physical rank.
        rank: usize,
    },
    /// Temporary rank loss (`preempt:RANK@T1-T2`): the rank leaves at
    /// the event's onset and rejoins at `until`.
    Preempt {
        /// The preempted physical rank.
        rank: usize,
        /// First step the rank is available again.
        until: usize,
    },
    /// Permanently evict whichever surviving rank has the largest
    /// active straggler factor at the onset (`evict-slowest@T`).
    EvictSlowest,
}

/// An onset-timed whole-rank fault (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// What happens.
    pub kind: FaultKind,
    /// The wall step the fault strikes during.
    pub onset: usize,
}

impl FaultEvent {
    /// The physical rank this event names, if fixed at parse time
    /// (`None` for `evict-slowest`, resolved against the live fleet).
    pub fn named_rank(&self) -> Option<usize> {
        match self.kind {
            FaultKind::Crash { rank } | FaultKind::Preempt { rank, .. } => Some(rank),
            FaultKind::EvictSlowest => None,
        }
    }
}

/// A composed runtime-dynamics scenario (see the module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Human-readable label (the parse spec, or the preset name).
    pub label: String,
    /// Per-rank compute slowdowns.
    pub stragglers: Vec<Straggler>,
    /// Stddev of the multiplicative per-action jitter (0 ⇒ none).
    pub jitter_sigma: f64,
    /// First step the jitter applies to.
    pub jitter_onset: usize,
    /// Communication slowdowns.
    pub links: Vec<LinkSlowdown>,
    /// Fabric-capacity changes (require an active `--net` topology).
    pub linkcaps: Vec<LinkCap>,
    /// Transient stragglers, sampled per action start (need the event
    /// executor).
    pub ramps: Vec<Ramp>,
    /// Windowed jitter bursts, sampled per action start (need the
    /// event executor).
    pub bursts: Vec<Burst>,
    /// Memory-budget squeezes, applied at replan boundaries.
    pub squeezes: Vec<Squeeze>,
    /// Whole-rank fault events (crash, preempt, evict-slowest).
    pub faults: Vec<FaultEvent>,
    /// Scenario RNG stream, xor-folded with the run seed.
    pub seed: u64,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            label: "calm".to_string(),
            stragglers: Vec::new(),
            jitter_sigma: 0.0,
            jitter_onset: 0,
            links: Vec::new(),
            linkcaps: Vec::new(),
            ramps: Vec::new(),
            bursts: Vec::new(),
            squeezes: Vec::new(),
            faults: Vec::new(),
            seed: 0,
        }
    }
}

impl std::fmt::Display for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label)
    }
}

impl Scenario {
    /// The identity scenario: no dynamics (bit-identical to running
    /// without a scenario).
    pub fn calm() -> Scenario {
        Scenario::default()
    }

    /// One rank `factor`× slower from step 0.
    pub fn straggler(rank: usize, factor: f64) -> Scenario {
        Scenario::calm()
            .with_straggler(rank, factor, 0)
            .relabel(&format!("straggler:{rank}x{factor}"))
    }

    /// Multiplicative per-action jitter with stddev `sigma`.
    pub fn jittery(sigma: f64) -> Scenario {
        Scenario::calm().with_jitter(sigma, 0).relabel(&format!("jitter:{sigma}"))
    }

    /// Every link `factor`× slower from step 0.
    pub fn congested(factor: f64) -> Scenario {
        Scenario::calm()
            .with_link(None, factor, 0)
            .relabel(&format!("link:{factor}"))
    }

    /// Add a per-rank compute slowdown.
    pub fn with_straggler(mut self, rank: usize, factor: f64, onset: usize) -> Scenario {
        assert!(factor > 0.0 && factor.is_finite(), "straggler factor must be positive");
        self.stragglers.push(Straggler { rank, factor, onset });
        self
    }

    /// Set the per-action jitter stddev and onset.
    pub fn with_jitter(mut self, sigma: f64, onset: usize) -> Scenario {
        assert!(sigma >= 0.0 && sigma.is_finite(), "jitter sigma must be ≥ 0");
        self.jitter_sigma = sigma;
        self.jitter_onset = onset;
        self
    }

    /// Add a communication slowdown (`boundary = None` ⇒ all links).
    pub fn with_link(mut self, boundary: Option<usize>, factor: f64, onset: usize) -> Scenario {
        assert!(factor > 0.0 && factor.is_finite(), "link factor must be positive");
        self.links.push(LinkSlowdown { boundary, factor, onset });
        self
    }

    /// Add a fabric-capacity change: every link on the topology route
    /// from rank `from` to rank `to` runs at `factor`× capacity from
    /// `onset`.
    pub fn with_linkcap(mut self, from: usize, to: usize, factor: f64, onset: usize) -> Scenario {
        assert!(factor > 0.0 && factor.is_finite(), "linkcap factor must be positive");
        self.linkcaps.push(LinkCap { from, to, factor, onset });
        self
    }

    /// A transient straggler: `rank` ramps linearly to `factor`× at
    /// the midpoint of `from..until` and back (the
    /// `ramp:<rank>x<factor>@<from>-<until>` term).
    pub fn transient(rank: usize, factor: f64, from: usize, until: usize) -> Scenario {
        Scenario::calm()
            .with_ramp(rank, factor, from, until)
            .relabel(&format!("ramp:{rank}x{factor}@{from}-{until}"))
    }

    /// Add a transient (triangular) straggler over `from..until`.
    pub fn with_ramp(mut self, rank: usize, factor: f64, from: usize, until: usize) -> Scenario {
        assert!(factor > 0.0 && factor.is_finite(), "ramp factor must be positive");
        assert!(until > from, "ramp window must end after it begins");
        self.ramps.push(Ramp { rank, factor, from, until });
        self
    }

    /// Add a windowed jitter burst over `from..until`.
    pub fn with_burst(mut self, sigma: f64, from: usize, until: usize) -> Scenario {
        assert!(sigma >= 0.0 && sigma.is_finite(), "burst sigma must be ≥ 0");
        assert!(until > from, "burst window must end after it begins");
        self.bursts.push(Burst { sigma, from, until });
        self
    }

    /// Add a memory-budget squeeze: the budget is scaled by `factor`
    /// from `onset` on, re-evaluated at each replan boundary.
    pub fn with_squeeze(mut self, factor: f64, onset: usize) -> Scenario {
        assert!(factor > 0.0 && factor.is_finite(), "squeeze factor must be positive");
        self.squeezes.push(Squeeze { factor, onset });
        self
    }

    /// One rank failing permanently at `onset` (the `crash:R@T` term).
    pub fn crash(rank: usize, onset: usize) -> Scenario {
        Scenario::calm()
            .with_crash(rank, onset)
            .relabel(&format!("crash:{rank}@{onset}"))
    }

    /// Add a permanent rank crash at `onset`.
    pub fn with_crash(mut self, rank: usize, onset: usize) -> Scenario {
        self.faults.push(FaultEvent { kind: FaultKind::Crash { rank }, onset });
        self
    }

    /// Add a temporary preemption: `rank` leaves at `onset` and rejoins
    /// at `until` (exclusive window `onset..until`).
    pub fn with_preempt(mut self, rank: usize, onset: usize, until: usize) -> Scenario {
        assert!(until > onset, "preemption must end after it begins");
        self.faults.push(FaultEvent { kind: FaultKind::Preempt { rank, until }, onset });
        self
    }

    /// Add an `evict-slowest` fault at `onset` (victim resolved at run
    /// time against the live fleet — see the module docs).
    pub fn with_evict_slowest(mut self, onset: usize) -> Scenario {
        self.faults.push(FaultEvent { kind: FaultKind::EvictSlowest, onset });
        self
    }

    /// Set the scenario RNG stream.
    pub fn with_seed(mut self, seed: u64) -> Scenario {
        self.seed = seed;
        self
    }

    /// Replace the label.
    pub fn relabel(mut self, label: &str) -> Scenario {
        self.label = label.to_string();
        self
    }

    /// Parse the comma-separated mini-language (see the module docs).
    pub fn parse(spec: &str) -> Result<Scenario, String> {
        let mut sc = Scenario::calm().relabel(spec.trim());
        for term in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (head, rest) = match term.split_once(':') {
                Some((h, r)) => (h.trim(), Some(r.trim())),
                None => (term, None),
            };
            match (head, rest) {
                ("calm", None) => {}
                ("straggler", Some(arg)) => {
                    let (body, onset) = split_onset(arg)?;
                    let (rank, factor) = body.split_once('x').ok_or_else(|| {
                        format!("straggler term '{term}' wants <rank>x<factor>[@onset]")
                    })?;
                    let rank = rank
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| format!("bad straggler rank in '{term}'"))?;
                    let factor = parse_factor(factor, term)?;
                    sc = sc.with_straggler(rank, factor, onset);
                }
                ("jitter", Some(arg)) => {
                    let (body, onset) = split_onset(arg)?;
                    let sigma = body
                        .trim()
                        .parse::<f64>()
                        .ok()
                        .filter(|s| *s >= 0.0 && s.is_finite())
                        .ok_or_else(|| format!("bad jitter sigma in '{term}'"))?;
                    sc = sc.with_jitter(sigma, onset);
                }
                ("link", Some(arg)) => {
                    let (body, onset) = split_onset(arg)?;
                    let (boundary, factor) = match body.split_once('x') {
                        Some((b, f)) => {
                            let b = b
                                .trim()
                                .parse::<usize>()
                                .map_err(|_| format!("bad link boundary in '{term}'"))?;
                            (Some(b), parse_factor(f, term)?)
                        }
                        None => (None, parse_factor(body, term)?),
                    };
                    sc = sc.with_link(boundary, factor, onset);
                }
                ("linkcap", Some(arg)) => {
                    let shape = || {
                        format!(
                            "linkcap term '{term}' wants linkcap:<rankA>-<rankB>x<factor>[@onset]"
                        )
                    };
                    let (body, onset) = split_onset(arg)?;
                    let (route, factor) = body.split_once('x').ok_or_else(shape)?;
                    let (from, to) = route.split_once('-').ok_or_else(shape)?;
                    let from = from
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| format!("bad linkcap rank in '{term}'"))?;
                    let to = to
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| format!("bad linkcap rank in '{term}'"))?;
                    sc = sc.with_linkcap(from, to, parse_factor(factor, term)?, onset);
                }
                ("ramp", Some(arg)) => {
                    let shape =
                        || format!("ramp term '{term}' wants ramp:<rank>x<factor>@<from>-<until>");
                    let (body, window) = arg.split_once('@').ok_or_else(shape)?;
                    let (rank, factor) = body.split_once('x').ok_or_else(shape)?;
                    let rank = rank
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| format!("bad ramp rank in '{term}'"))?;
                    let factor = parse_factor(factor, term)?;
                    let (from, until) = parse_window(window, term)?;
                    sc = sc.with_ramp(rank, factor, from, until);
                }
                ("burst", Some(arg)) => {
                    let shape =
                        || format!("burst term '{term}' wants burst:<sigma>@<from>-<until>");
                    let (body, window) = arg.split_once('@').ok_or_else(shape)?;
                    let sigma = body
                        .trim()
                        .parse::<f64>()
                        .ok()
                        .filter(|s| *s >= 0.0 && s.is_finite())
                        .ok_or_else(|| format!("bad burst sigma in '{term}'"))?;
                    let (from, until) = parse_window(window, term)?;
                    sc = sc.with_burst(sigma, from, until);
                }
                ("squeeze", Some(arg)) => {
                    let (body, onset) = split_onset(arg)?;
                    let factor = parse_factor(body, term)?;
                    sc = sc.with_squeeze(factor, onset);
                }
                ("seed", Some(arg)) => {
                    let seed = arg
                        .parse::<u64>()
                        .map_err(|_| format!("bad scenario seed in '{term}'"))?;
                    sc = sc.with_seed(seed);
                }
                ("crash", Some(arg)) => {
                    let (rank, onset) = arg.split_once('@').ok_or_else(|| {
                        format!("crash term '{term}' wants crash:<rank>@<onset>")
                    })?;
                    let rank = rank
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| format!("bad crash rank in '{term}'"))?;
                    let onset = onset
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| format!("bad onset step in '{term}'"))?;
                    sc = sc.with_crash(rank, onset);
                }
                ("preempt", Some(arg)) => {
                    let shape =
                        || format!("preempt term '{term}' wants preempt:<rank>@<from>-<until>");
                    let (rank, window) = arg.split_once('@').ok_or_else(shape)?;
                    let rank = rank
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| format!("bad preempt rank in '{term}'"))?;
                    let (from, until) = window.split_once('-').ok_or_else(shape)?;
                    let from = from
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| format!("bad onset step in '{term}'"))?;
                    let until = until
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| format!("bad preempt end in '{term}'"))?;
                    if until <= from {
                        return Err(format!(
                            "preempt term '{term}' must end after it begins \
                             (<until> must exceed <from>)"
                        ));
                    }
                    sc = sc.with_preempt(rank, from, until);
                }
                (h, None) if h.starts_with("evict-slowest") => {
                    let onset = h
                        .strip_prefix("evict-slowest")
                        .and_then(|tail| tail.strip_prefix('@'))
                        .ok_or_else(|| {
                            format!("evict term '{term}' wants evict-slowest@<onset>")
                        })?
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| format!("bad onset step in '{term}'"))?;
                    sc = sc.with_evict_slowest(onset);
                }
                _ => {
                    return Err(format!(
                        "unknown scenario term '{term}' \
                         (try straggler:<rank>x<factor>[@onset], jitter:<sigma>[@onset], \
                         link:[<boundary>x]<factor>[@onset], \
                         linkcap:<rankA>-<rankB>x<factor>[@onset], \
                         ramp:<rank>x<factor>@<from>-<until>, \
                         burst:<sigma>@<from>-<until>, squeeze:<factor>[@onset], \
                         seed:<n>, crash:<rank>@<onset>, \
                         preempt:<rank>@<from>-<until>, \
                         evict-slowest@<onset>, calm)"
                    ))
                }
            }
        }
        Ok(sc)
    }

    /// Check rank/boundary indices against a concrete pipeline shape.
    pub fn validate(&self, ranks: usize, stages: usize) -> Result<(), String> {
        for s in &self.stragglers {
            if s.rank >= ranks {
                return Err(format!(
                    "scenario straggles rank {} but the pipeline has {ranks} ranks",
                    s.rank
                ));
            }
        }
        for l in &self.links {
            if let Some(b) = l.boundary {
                if b + 1 >= stages {
                    return Err(format!(
                        "scenario slows boundary {b} but the pipeline has only {} \
                         boundaries",
                        stages.saturating_sub(1)
                    ));
                }
            }
        }
        for lc in &self.linkcaps {
            for rank in [lc.from, lc.to] {
                if rank >= ranks {
                    return Err(format!(
                        "scenario scales link capacity for rank {rank} but the pipeline \
                         has {ranks} ranks"
                    ));
                }
            }
        }
        for r in &self.ramps {
            if r.rank >= ranks {
                return Err(format!(
                    "scenario ramps rank {} but the pipeline has {ranks} ranks",
                    r.rank
                ));
            }
        }
        let mut crashed: Vec<usize> = Vec::new();
        let mut evictions = 0usize;
        for f in &self.faults {
            if let Some(rank) = f.named_rank() {
                if rank >= ranks {
                    return Err(format!(
                        "scenario faults rank {rank} but the pipeline has {ranks} ranks"
                    ));
                }
            }
            match f.kind {
                FaultKind::Crash { rank } => {
                    if !crashed.contains(&rank) {
                        crashed.push(rank);
                    }
                }
                FaultKind::EvictSlowest => evictions += 1,
                FaultKind::Preempt { .. } => {}
            }
        }
        if crashed.len() + evictions >= ranks && ranks > 0 {
            return Err(format!(
                "scenario permanently loses {} of {ranks} ranks — at least one \
                 rank must survive",
                crashed.len() + evictions
            ));
        }
        Ok(())
    }

    /// Whether the scenario perturbs nothing — the runner treats an
    /// identity scenario exactly like no scenario, preserving the
    /// bit-identity contract of the event engine.
    pub fn is_identity(&self) -> bool {
        self.jitter_sigma == 0.0
            && self.stragglers.iter().all(|s| s.factor == 1.0)
            && self.links.iter().all(|l| l.factor == 1.0)
            && self.linkcaps.iter().all(|l| l.factor == 1.0)
            && self.ramps.iter().all(|r| r.factor == 1.0)
            && self.bursts.iter().all(|b| b.sigma == 0.0)
            && self.squeezes.iter().all(|s| s.factor == 1.0)
            && self.faults.is_empty()
    }

    /// Whether any within-batch term (`ramp`/`burst`) ever perturbs an
    /// action — such terms are sampled per action start and need the
    /// event executor; the runner rejects them on the analytic path.
    pub fn has_dynamics(&self) -> bool {
        self.ramps.iter().any(|r| r.factor != 1.0)
            || self.bursts.iter().any(|b| b.sigma != 0.0)
    }

    /// The memory-budget multiplier in effect at step `t` (product of
    /// active squeezes; 1 when none are active).
    pub fn squeeze_factor(&self, t: usize) -> f64 {
        self.squeezes
            .iter()
            .filter(|s| t >= s.onset)
            .map(|s| s.factor)
            .product()
    }

    /// Whether any memory-squeeze term ever takes effect — such terms
    /// shrink the memory budget at replan boundaries and need an active
    /// `--mem-budget` to have a budget to shrink; the runner rejects
    /// them otherwise.
    pub fn has_squeezes(&self) -> bool {
        self.squeezes.iter().any(|s| s.factor != 1.0)
    }

    /// Whether any capacity-scaling term ever takes effect — such terms
    /// need an active `--net` topology to have links to scale, and the
    /// runner rejects them otherwise.
    pub fn has_linkcaps(&self) -> bool {
        self.linkcaps.iter().any(|l| l.factor != 1.0)
    }

    /// Visit the capacity terms active at step `t` as `(from, to,
    /// factor)` route scalings; the caller maps routes onto topology
    /// links (`NetworkModel::path`) and multiplies capacities.
    pub fn active_linkcaps(&self, t: usize, mut f: impl FnMut(usize, usize, f64)) {
        for lc in &self.linkcaps {
            if t >= lc.onset && lc.factor != 1.0 {
                f(lc.from, lc.to, lc.factor);
            }
        }
    }

    /// Whether any whole-rank fault events are scheduled — fault runs
    /// take the elastic-recovery path (`sim/elastic.rs`) instead of the
    /// plain step loop, and require a configured recovery strategy.
    pub fn has_faults(&self) -> bool {
        !self.faults.is_empty()
    }

    /// Compute-time multiplier of `rank` at step `t` (product of active
    /// stragglers).
    pub fn rank_factor(&self, rank: usize, t: usize) -> f64 {
        self.stragglers
            .iter()
            .filter(|s| s.rank == rank && t >= s.onset)
            .map(|s| s.factor)
            .product()
    }

    /// Communication multiplier of every link at step `t` (the global
    /// terms only).
    pub fn global_link_factor(&self, t: usize) -> f64 {
        self.links
            .iter()
            .filter(|l| l.boundary.is_none() && t >= l.onset)
            .map(|l| l.factor)
            .product()
    }

    /// Communication multiplier of stage `stage`'s *node-charged* comm
    /// at step `t`: the global terms, times any per-boundary term on a
    /// boundary adjacent to the stage (`stage−1 ↔ stage` carries its
    /// inbound activations, `stage ↔ stage+1` its inbound gradients).
    /// This is how boundary-targeted slowdowns reach the analytic
    /// presets, whose cost models charge communication to nodes rather
    /// than P2P edges.
    pub fn stage_link_factor(&self, stage: usize, t: usize) -> f64 {
        self.links
            .iter()
            .filter(|l| t >= l.onset)
            .filter(|l| match l.boundary {
                None => true,
                Some(b) => b == stage || b + 1 == stage,
            })
            .map(|l| l.factor)
            .product()
    }

    /// Communication multiplier of the P2P link across stage boundary
    /// `boundary` at step `t` (global terms × matching per-boundary
    /// terms).
    pub fn edge_link_factor(&self, boundary: usize, t: usize) -> f64 {
        self.global_link_factor(t)
            * self
                .links
                .iter()
                .filter(|l| l.boundary == Some(boundary) && t >= l.onset)
                .map(|l| l.factor)
                .product::<f64>()
    }

    /// Multiplicative jitter sample for `(step, node)` under the run's
    /// master seed — a counter-derived stream, independent of event
    /// order, clamped away from zero like the simulator's base timing
    /// noise.
    pub fn jitter_mult(&self, run_seed: u64, t: usize, node: usize) -> f64 {
        if self.jitter_sigma == 0.0 || t < self.jitter_onset {
            return 1.0;
        }
        let mut rng = Rng::seed_from_u64(self.seed ^ run_seed ^ 0x5CE0_A11D)
            .derive(t as u64, node as u64);
        (1.0 + self.jitter_sigma * rng.normal()).max(0.05)
    }

    /// Transient-straggler multiplier of `rank` at continuous step
    /// coordinate `u` (product of active ramps; see
    /// [`Ramp::factor_at`]).
    pub fn ramp_factor(&self, rank: usize, u: f64) -> f64 {
        self.ramps
            .iter()
            .filter(|r| r.rank == rank)
            .map(|r| r.factor_at(u))
            .product()
    }

    /// The effective burst stddev at continuous step coordinate `u`
    /// (sum of the sigmas of all windows containing `u`).
    pub fn burst_sigma(&self, u: f64) -> f64 {
        self.bursts
            .iter()
            .filter(|b| u >= b.from as f64 && u < (b.until as f64))
            .map(|b| b.sigma)
            .sum()
    }

    /// Windowed-jitter sample for the action `(step, node)` starting at
    /// continuous coordinate `u`. The draw is counter-derived from
    /// `(step, node)` exactly like [`Scenario::jitter_mult`] (a
    /// distinct salt keeps the two streams independent), but gated by
    /// `u`, so only actions that actually start inside a burst window
    /// are perturbed.
    pub fn burst_mult(&self, run_seed: u64, t: usize, node: usize, u: f64) -> f64 {
        let sigma = self.burst_sigma(u);
        if sigma == 0.0 {
            return 1.0;
        }
        let mut rng = Rng::seed_from_u64(self.seed ^ run_seed ^ 0xB0B5_7E11)
            .derive(t as u64, node as u64);
        (1.0 + sigma * rng.normal()).max(0.05)
    }

    /// The combined within-batch multiplier the event executor applies
    /// at dispatch: ramps on the action's rank × the windowed burst
    /// draw, both evaluated at the action's continuous start
    /// coordinate `u = step + fraction-of-batch-elapsed`.
    pub fn dynamics_mult(
        &self,
        run_seed: u64,
        t: usize,
        node: usize,
        rank: usize,
        u: f64,
    ) -> f64 {
        self.ramp_factor(rank, u) * self.burst_mult(run_seed, t, node, u)
    }
}

fn split_onset(arg: &str) -> Result<(&str, usize), String> {
    match arg.split_once('@') {
        None => Ok((arg, 0)),
        Some((body, onset)) => {
            let onset = onset
                .trim()
                .parse::<usize>()
                .map_err(|_| format!("bad onset step in '{arg}'"))?;
            Ok((body, onset))
        }
    }
}

fn parse_window(s: &str, term: &str) -> Result<(usize, usize), String> {
    let (from, until) = s
        .split_once('-')
        .ok_or_else(|| format!("bad window in '{term}' (wants @<from>-<until>)"))?;
    let from = from
        .trim()
        .parse::<usize>()
        .map_err(|_| format!("bad onset step in '{term}'"))?;
    let until = until
        .trim()
        .parse::<usize>()
        .map_err(|_| format!("bad window end in '{term}'"))?;
    if until <= from {
        return Err(format!("window in '{term}' must end after it begins"));
    }
    Ok((from, until))
}

fn parse_factor(s: &str, term: &str) -> Result<f64, String> {
    s.trim()
        .parse::<f64>()
        .ok()
        .filter(|f| *f > 0.0 && f.is_finite())
        .ok_or_else(|| format!("bad factor in '{term}' (must be a positive number)"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calm_is_identity() {
        assert!(Scenario::calm().is_identity());
        assert!(Scenario::parse("calm").unwrap().is_identity());
        assert!(!Scenario::straggler(1, 1.5).is_identity());
        assert!(Scenario::straggler(1, 1.0).is_identity());
        assert!(!Scenario::jittery(0.1).is_identity());
        assert!(!Scenario::congested(2.0).is_identity());
    }

    #[test]
    fn parse_composes_terms() {
        let sc = Scenario::parse("straggler:2x1.5@300, jitter:0.05, link:0x4.0@100, seed:7")
            .unwrap();
        assert_eq!(
            sc.stragglers,
            vec![Straggler { rank: 2, factor: 1.5, onset: 300 }]
        );
        assert_eq!(sc.jitter_sigma, 0.05);
        assert_eq!(sc.jitter_onset, 0);
        assert_eq!(
            sc.links,
            vec![LinkSlowdown { boundary: Some(0), factor: 4.0, onset: 100 }]
        );
        assert_eq!(sc.seed, 7);
        assert_eq!(sc.to_string(), "straggler:2x1.5@300, jitter:0.05, link:0x4.0@100, seed:7");
    }

    #[test]
    fn parse_rejects_malformed_terms() {
        for bad in [
            "straggler:1.5",
            "straggler:ax2",
            "straggler:1x-2",
            "jitter:-0.1",
            "link:0x",
            "wibble:3",
            "seed:x",
            "straggler:1x2@x",
        ] {
            assert!(Scenario::parse(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn onset_gates_factors() {
        let sc = Scenario::calm()
            .with_straggler(1, 2.0, 100)
            .with_link(Some(0), 3.0, 50)
            .with_link(None, 1.5, 0);
        assert_eq!(sc.rank_factor(1, 99), 1.0);
        assert_eq!(sc.rank_factor(1, 100), 2.0);
        assert_eq!(sc.rank_factor(0, 500), 1.0);
        assert_eq!(sc.global_link_factor(10), 1.5);
        assert_eq!(sc.edge_link_factor(0, 49), 1.5);
        assert_eq!(sc.edge_link_factor(0, 50), 4.5);
        assert_eq!(sc.edge_link_factor(1, 50), 1.5);
    }

    #[test]
    fn stage_link_factor_hits_adjacent_stages() {
        let sc = Scenario::calm()
            .with_link(Some(1), 3.0, 0)
            .with_link(None, 2.0, 10);
        // Boundary 1 ↔ 2 touches stages 1 and 2, nothing else.
        assert_eq!(sc.stage_link_factor(0, 0), 1.0);
        assert_eq!(sc.stage_link_factor(1, 0), 3.0);
        assert_eq!(sc.stage_link_factor(2, 0), 3.0);
        assert_eq!(sc.stage_link_factor(3, 0), 1.0);
        // The global term stacks once its onset passes.
        assert_eq!(sc.stage_link_factor(1, 10), 6.0);
        assert_eq!(sc.stage_link_factor(3, 10), 2.0);
    }

    #[test]
    fn linkcap_terms_parse_gate_and_validate() {
        let sc = Scenario::parse("linkcap:0-3x0.5@200").unwrap();
        assert_eq!(
            sc.linkcaps,
            vec![LinkCap { from: 0, to: 3, factor: 0.5, onset: 200 }]
        );
        assert!(sc.has_linkcaps());
        assert!(!sc.is_identity());
        assert_eq!(sc.to_string(), "linkcap:0-3x0.5@200");
        // Identity factor: parses, but perturbs nothing.
        let unity = Scenario::parse("linkcap:0-1x1.0").unwrap();
        assert!(unity.is_identity());
        assert!(!unity.has_linkcaps());
        // Onset gating through the visitor.
        let mut seen = Vec::new();
        sc.active_linkcaps(199, |a, b, f| seen.push((a, b, f)));
        assert!(seen.is_empty());
        sc.active_linkcaps(200, |a, b, f| seen.push((a, b, f)));
        assert_eq!(seen, vec![(0, 3, 0.5)]);
        // Rank bounds come from the fleet size.
        assert!(sc.validate(4, 4).is_ok());
        assert!(sc.validate(3, 3).is_err());
        // Malformed shapes name the offence.
        for (bad, needle) in [
            ("linkcap:0x0.5", "wants linkcap:<rankA>-<rankB>x<factor>"),
            ("linkcap:0-1", "wants linkcap:<rankA>-<rankB>x<factor>"),
            ("linkcap:a-1x0.5", "bad linkcap rank"),
            ("linkcap:0-bx0.5", "bad linkcap rank"),
            ("linkcap:0-1x0", "bad factor"),
            ("linkcap:0-1x0.5@x", "bad onset step"),
        ] {
            let err = Scenario::parse(bad).expect_err(bad);
            assert!(err.contains(needle), "'{bad}': error '{err}' lacks '{needle}'");
        }
    }

    #[test]
    fn ramp_terms_parse_interpolate_and_validate() {
        let sc = Scenario::parse("ramp:1x3.0@100-200").unwrap();
        assert_eq!(sc.ramps, vec![Ramp { rank: 1, factor: 3.0, from: 100, until: 200 }]);
        assert!(sc.has_dynamics());
        assert!(!sc.is_identity());
        assert_eq!(sc.to_string(), "ramp:1x3.0@100-200");
        // Triangular profile: 1 at the edges, the full factor at the
        // midpoint, linear in between, 1 outside the window.
        assert_eq!(sc.ramp_factor(1, 99.9), 1.0);
        assert_eq!(sc.ramp_factor(1, 100.0), 1.0);
        assert_eq!(sc.ramp_factor(1, 150.0), 3.0);
        assert!((sc.ramp_factor(1, 125.0) - 2.0).abs() < 1e-12);
        assert!((sc.ramp_factor(1, 175.0) - 2.0).abs() < 1e-12);
        assert_eq!(sc.ramp_factor(1, 200.0), 1.0);
        // Other ranks are untouched.
        assert_eq!(sc.ramp_factor(0, 150.0), 1.0);
        // Identity factor parses but perturbs nothing.
        assert!(Scenario::parse("ramp:0x1.0@0-10").unwrap().is_identity());
        assert!(!Scenario::parse("ramp:0x1.0@0-10").unwrap().has_dynamics());
        // Rank bounds come from the fleet size.
        assert!(sc.validate(2, 2).is_ok());
        assert!(sc.validate(1, 1).is_err());
        // The preset matches the parsed form (labels aside: `{}`
        // renders 3.0 as "3").
        assert_eq!(Scenario::transient(1, 3.0, 100, 200).ramps, sc.ramps);
    }

    #[test]
    fn burst_terms_parse_window_and_sample() {
        let sc = Scenario::parse("burst:0.2@100-150").unwrap();
        assert_eq!(sc.bursts, vec![Burst { sigma: 0.2, from: 100, until: 150 }]);
        assert!(sc.has_dynamics());
        assert!(!sc.is_identity());
        assert_eq!(sc.to_string(), "burst:0.2@100-150");
        assert_eq!(sc.burst_sigma(99.9), 0.0);
        assert_eq!(sc.burst_sigma(100.0), 0.2);
        assert_eq!(sc.burst_sigma(149.9), 0.2);
        assert_eq!(sc.burst_sigma(150.0), 0.0);
        // Outside the window the multiplier is exactly 1; inside it is
        // deterministic per (step, node) and independent of the jitter
        // stream.
        assert_eq!(sc.burst_mult(42, 99, 0, 99.5), 1.0);
        let a = sc.burst_mult(42, 120, 7, 120.5);
        assert_eq!(a, sc.burst_mult(42, 120, 7, 120.5));
        assert!(a > 0.0);
        assert_ne!(a, 1.0);
        assert_ne!(a, sc.burst_mult(42, 121, 7, 121.5));
        assert_ne!(a, sc.burst_mult(42, 120, 8, 120.5));
        let jit = Scenario::jittery(0.2);
        assert_ne!(a, jit.jitter_mult(42, 120, 7));
        // Zero-sigma bursts parse but perturb nothing.
        assert!(Scenario::parse("burst:0.0@0-10").unwrap().is_identity());
        // Overlapping windows stack their sigmas.
        let two = Scenario::calm().with_burst(0.1, 0, 100).with_burst(0.2, 50, 100);
        assert!((two.burst_sigma(75.0) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn squeeze_terms_parse_and_gate() {
        let sc = Scenario::parse("squeeze:0.5@300").unwrap();
        assert_eq!(sc.squeezes, vec![Squeeze { factor: 0.5, onset: 300 }]);
        assert!(!sc.is_identity());
        assert!(!sc.has_dynamics(), "squeeze is a replan-time hook, not a per-action term");
        assert_eq!(sc.squeeze_factor(299), 1.0);
        assert_eq!(sc.squeeze_factor(300), 0.5);
        // Stacked squeezes multiply; identity factor perturbs nothing.
        let two = Scenario::calm().with_squeeze(0.5, 10).with_squeeze(0.5, 20);
        assert_eq!(two.squeeze_factor(20), 0.25);
        assert!(Scenario::parse("squeeze:1.0").unwrap().is_identity());
    }

    #[test]
    fn malformed_dynamics_terms_name_the_offence() {
        for (bad, needle) in [
            ("ramp:1x2.0", "wants ramp:<rank>x<factor>@<from>-<until>"),
            ("ramp:2.0@0-10", "wants ramp:<rank>x<factor>@<from>-<until>"),
            ("ramp:ax2.0@0-10", "bad ramp rank"),
            ("ramp:1x0@0-10", "bad factor"),
            ("ramp:1x2.0@10", "bad window"),
            ("ramp:1x2.0@a-10", "bad onset step"),
            ("ramp:1x2.0@0-b", "bad window end"),
            ("ramp:1x2.0@10-10", "must end after it begins"),
            ("burst:0.1", "wants burst:<sigma>@<from>-<until>"),
            ("burst:-0.1@0-10", "bad burst sigma"),
            ("burst:0.1@10-5", "must end after it begins"),
            ("squeeze:0@10", "bad factor"),
            ("squeeze:0.5@x", "bad onset step"),
        ] {
            let err = Scenario::parse(bad).expect_err(bad);
            assert!(err.contains(needle), "'{bad}': error '{err}' lacks '{needle}'");
        }
    }

    #[test]
    fn stacked_stragglers_multiply() {
        let sc = Scenario::calm()
            .with_straggler(0, 2.0, 0)
            .with_straggler(0, 1.5, 10);
        assert_eq!(sc.rank_factor(0, 5), 2.0);
        assert_eq!(sc.rank_factor(0, 10), 3.0);
    }

    #[test]
    fn jitter_is_deterministic_and_seed_sensitive() {
        let sc = Scenario::jittery(0.1).with_seed(3);
        let a = sc.jitter_mult(42, 5, 17);
        let b = sc.jitter_mult(42, 5, 17);
        assert_eq!(a, b);
        assert!(a > 0.0);
        assert_ne!(a, sc.jitter_mult(43, 5, 17));
        assert_ne!(a, sc.jitter_mult(42, 6, 17));
        assert_ne!(a, sc.jitter_mult(42, 5, 18));
        let other = Scenario::jittery(0.1).with_seed(4);
        assert_ne!(a, other.jitter_mult(42, 5, 17));
        // Onset gates sampling entirely.
        let late = Scenario::calm().with_jitter(0.1, 100);
        assert_eq!(late.jitter_mult(42, 99, 0), 1.0);
        assert_ne!(late.jitter_mult(42, 100, 0), 1.0);
    }

    #[test]
    fn validate_checks_shape() {
        let sc = Scenario::straggler(4, 2.0);
        assert!(sc.validate(4, 4).is_err());
        assert!(sc.validate(5, 5).is_ok());
        let sc = Scenario::calm().with_link(Some(3), 2.0, 0);
        assert!(sc.validate(4, 4).is_err());
        assert!(sc.validate(4, 8).is_ok());
    }

    #[test]
    fn parse_composes_fault_terms() {
        let sc =
            Scenario::parse("crash:2@500,preempt:1@300-450,evict-slowest@400").unwrap();
        assert_eq!(
            sc.faults,
            vec![
                FaultEvent { kind: FaultKind::Crash { rank: 2 }, onset: 500 },
                FaultEvent { kind: FaultKind::Preempt { rank: 1, until: 450 }, onset: 300 },
                FaultEvent { kind: FaultKind::EvictSlowest, onset: 400 },
            ]
        );
        assert!(sc.has_faults());
        assert!(!sc.is_identity());
        assert_eq!(sc.to_string(), "crash:2@500,preempt:1@300-450,evict-slowest@400");
        // Faults compose with the slowdown terms.
        let mixed = Scenario::parse("straggler:1x2.0,evict-slowest@50").unwrap();
        assert_eq!(mixed.stragglers.len(), 1);
        assert_eq!(mixed.faults.len(), 1);
        // The preset matches the parsed form.
        assert_eq!(Scenario::crash(2, 500), Scenario::parse("crash:2@500").unwrap());
    }

    #[test]
    fn parse_rejects_malformed_fault_terms() {
        for (bad, needle) in [
            ("crash:2", "wants crash:<rank>@<onset>"),
            ("crash:x@5", "bad crash rank"),
            ("crash:2@x", "bad onset step"),
            ("preempt:1@300", "wants preempt:<rank>@<from>-<until>"),
            ("preempt:x@1-2", "bad preempt rank"),
            ("preempt:1@a-2", "bad onset step"),
            ("preempt:1@2-a", "bad preempt end"),
            ("preempt:1@450-300", "must end after it begins"),
            ("preempt:1@300-300", "must end after it begins"),
            ("evict-slowest", "wants evict-slowest@<onset>"),
            ("evict-slowest@", "bad onset step"),
            ("evict-slowest@x", "bad onset step"),
        ] {
            let err = Scenario::parse(bad).expect_err(bad);
            assert!(err.contains(needle), "'{bad}': error '{err}' lacks '{needle}'");
        }
    }

    #[test]
    fn validate_checks_fault_ranks_and_survivors() {
        // Fault rank out of range.
        assert!(Scenario::crash(4, 10).validate(4, 4).is_err());
        assert!(Scenario::crash(3, 10).validate(4, 4).is_ok());
        assert!(Scenario::calm().with_preempt(5, 0, 10).validate(4, 4).is_err());
        // Permanent losses must leave a survivor: 2 crashes + 1 eviction
        // on a 4-rank fleet is fine, on a 3-rank fleet it is not.
        let heavy = Scenario::calm()
            .with_crash(0, 10)
            .with_crash(1, 20)
            .with_evict_slowest(30);
        assert!(heavy.validate(4, 4).is_ok());
        assert!(heavy.validate(3, 3).is_err());
        // Repeat crashes on one rank count once.
        let twice = Scenario::calm().with_crash(0, 10).with_crash(0, 20);
        assert!(twice.validate(2, 2).is_ok());
        // Preemptions are temporary and never exhaust the fleet.
        let pre = Scenario::calm().with_preempt(0, 0, 5).with_preempt(1, 10, 15);
        assert!(pre.validate(2, 2).is_ok());
    }
}
