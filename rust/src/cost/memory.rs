//! Per-stage device-memory accounting and the freeze-ratio floor.
//!
//! A pipeline stage holds three kinds of bytes:
//!
//! * **weights** — the stage's parameters (resident regardless of
//!   freezing);
//! * **activations** — stashed between a microbatch's forward and
//!   backward; the peak count of simultaneously in-flight microbatches
//!   is a property of the *schedule* ([`peak_inflight`]);
//! * **trainable state** — gradients + optimizer moments + fp32 master
//!   copy, needed only for *unfrozen* parameters. This is the term
//!   freezing reclaims.
//!
//! Given a capacity, [`MemoryModel::required_ratios`] inverts the
//! accounting into the minimum average freeze ratio each stage needs to
//! fit — the per-stage floor the freeze LP enforces as constraint [5]
//! (see [`crate::lp::freeze_lp`]). This is the memory-pressure regime of
//! "Pipeline Parallelism with Controllable Memory" (Qi et al., 2024):
//! freezing is no longer purely a throughput knob but also a way to fit
//! a model on smaller devices.
//!
//! Forced freezing is not the only way to buy activation memory back:
//! a stage can **recompute** some fraction ρ of its activations during
//! the backward pass instead of stashing them (Zero Bubble Pipeline
//! Parallelism trades compute for exactly this headroom). A
//! [`RecomputePolicy`] scales the stashed activation bytes by `1 − ρ`
//! and charges a per-stage time surcharge of `ρ · fwd_s` on every
//! stash-consuming backward action
//! ([`CostModel::recompute_surcharges_for`](crate::cost::CostModel::recompute_surcharges_for)).
//! [`memory_plan_for`] resolves a configured budget into both knobs at
//! once — the per-stage floor *and* the recompute fractions — choosing,
//! under [`RecomputePolicy::Auto`], the cheaper of "freeze more" (free
//! in time, capped by `r_max`) and "pay forward time again" per stage.

use crate::config::{ExperimentConfig, GpuPreset, ModelPreset};
use crate::schedule::Schedule;
use crate::types::ActionKind;

/// Bytes per parameter held by the resident weights (bf16).
pub const WEIGHT_BYTES_PER_PARAM: f64 = 2.0;

/// Bytes per *trainable* parameter beyond the weight itself: bf16
/// gradient (2) + fp32 Adam moments (8) + fp32 master copy (4).
/// Freezing a parameter reclaims all of it.
pub const TRAIN_STATE_BYTES_PER_PARAM: f64 = 14.0;

/// How stages trade stashed-activation memory for recompute time: the
/// planner-visible knob behind `--recompute {off,full,auto}`.
///
/// Each policy resolves
/// ([`MemoryModel::recompute_fractions`]) to a per-stage fraction
/// `ρ_s ∈ [0, 1]` of activations that are recomputed during the
/// backward pass instead of stashed: stashed bytes scale by `1 − ρ_s`
/// and every stash-consuming backward action at the stage pays a
/// `ρ_s · fwd_s` time surcharge.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum RecomputePolicy {
    /// Stash every activation — the pre-recompute behavior. All paths
    /// stay bit-identical to a build without the policy.
    #[default]
    Off,
    /// Recompute every stage's activations fully (`ρ_s = 1`).
    Full,
    /// A uniform per-stage recompute fraction in `(0, 1]`.
    Fraction(f64),
    /// Planner-chosen per-stage fractions: freezing is free in time (it
    /// *shrinks* backwards) and allowed up to `r_max`, so each stage
    /// first freezes toward the accuracy budget and recomputes only the
    /// remaining deficit — the per-stage minimum of the two closed
    /// forms (see [`MemoryModel::recompute_fractions`]).
    Auto,
}

impl RecomputePolicy {
    /// Parse a user-supplied policy: `off`/`none`, `full`, `auto`, or a
    /// uniform fraction in `(0, 1]` (e.g. `0.5`; `0` means off, `1`
    /// means full).
    pub fn parse(s: &str) -> Result<RecomputePolicy, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" => Ok(RecomputePolicy::Off),
            "full" => Ok(RecomputePolicy::Full),
            "auto" => Ok(RecomputePolicy::Auto),
            other => match other.parse::<f64>() {
                Ok(f) if f == 0.0 => Ok(RecomputePolicy::Off),
                Ok(f) if f == 1.0 => Ok(RecomputePolicy::Full),
                Ok(f) if f > 0.0 && f < 1.0 => Ok(RecomputePolicy::Fraction(f)),
                _ => Err(format!(
                    "bad recompute policy '{s}' (off | full | auto | fraction in (0,1])"
                )),
            },
        }
    }

    /// Display name (`off`, `full`, `auto`, or the fraction).
    pub fn name(&self) -> String {
        match self {
            RecomputePolicy::Off => "off".to_string(),
            RecomputePolicy::Full => "full".to_string(),
            RecomputePolicy::Auto => "auto".to_string(),
            RecomputePolicy::Fraction(f) => format!("{f}"),
        }
    }

    /// Whether the policy is [`RecomputePolicy::Off`].
    pub fn is_off(&self) -> bool {
        matches!(self, RecomputePolicy::Off)
    }
}

/// Per-stage memory accounting for one experiment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct MemoryModel {
    /// Resident weight bytes per stage.
    pub weight_bytes: Vec<f64>,
    /// Activation bytes stashed per in-flight microbatch per stage.
    pub act_bytes_per_mb: Vec<f64>,
    /// Gradient + optimizer + master bytes per stage if *nothing* is
    /// frozen; the freeze ratio scales this term by `1 − r`.
    pub train_state_bytes: Vec<f64>,
    /// Device-memory capacity available to each stage.
    pub capacity_bytes: Vec<f64>,
}

/// Why a memory budget cannot be met.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MemoryError {
    /// Even at full freezing (`r = 1`, zero trainable state) the stage's
    /// weights + activations exceed its capacity.
    OverCapacity {
        /// The offending stage.
        stage: usize,
        /// Bytes required at full freezing.
        required_bytes: f64,
        /// The stage's capacity.
        capacity_bytes: f64,
    },
    /// Even full activation recomputation (`ρ = 1`) combined with
    /// maximal freezing at the accuracy budget `r_max` cannot fit the
    /// stage — the [`RecomputePolicy::Auto`] rescue has nothing left to
    /// give back.
    RecomputeInsufficient {
        /// The offending stage.
        stage: usize,
        /// Bytes required at full recompute and `r = r_max`.
        required_bytes: f64,
        /// The stage's capacity.
        capacity_bytes: f64,
        /// The accuracy budget the freezing side was capped at.
        r_max: f64,
    },
}

impl std::fmt::Display for MemoryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemoryError::OverCapacity { stage, required_bytes, capacity_bytes } => write!(
                f,
                "stage {stage} needs {:.2} GiB even fully frozen but only {:.2} GiB fit",
                required_bytes / (1u64 << 30) as f64,
                capacity_bytes / (1u64 << 30) as f64,
            ),
            MemoryError::RecomputeInsufficient {
                stage,
                required_bytes,
                capacity_bytes,
                r_max,
            } => write!(
                f,
                "stage {stage} needs {:.2} GiB even at full recompute and maximal \
                 freezing (r_max = {r_max}) but only {:.2} GiB fit",
                required_bytes / (1u64 << 30) as f64,
                capacity_bytes / (1u64 << 30) as f64,
            ),
        }
    }
}

impl std::error::Error for MemoryError {}

impl MemoryModel {
    /// Derive the accounting from the paper presets: per-stage parameter
    /// sums from the layer→stage assignment, the coarse per-layer
    /// activation formula of
    /// [`ModelPreset::layer_act_bytes`], and an equal slice of the GPU's
    /// memory per virtual stage hosted on the rank (`chunks` slices).
    pub fn from_presets(
        model: &ModelPreset,
        gpu: &GpuPreset,
        layer_stage: &[usize],
        stages: usize,
        microbatch_size: usize,
        seq_len: usize,
        chunks: usize,
    ) -> MemoryModel {
        assert_eq!(layer_stage.len(), model.num_layers());
        assert!(chunks >= 1, "chunks must be ≥ 1");
        let mut weight = vec![0.0f64; stages];
        let mut act = vec![0.0f64; stages];
        for (l, &s) in layer_stage.iter().enumerate() {
            weight[s] += model.layer_params()[l] * WEIGHT_BYTES_PER_PARAM;
            act[s] += model.layer_act_bytes(l, microbatch_size, seq_len);
        }
        let train_state: Vec<f64> = weight
            .iter()
            .map(|w| w / WEIGHT_BYTES_PER_PARAM * TRAIN_STATE_BYTES_PER_PARAM)
            .collect();
        MemoryModel {
            weight_bytes: weight,
            act_bytes_per_mb: act,
            train_state_bytes: train_state,
            capacity_bytes: vec![gpu.memory_bytes / chunks as f64; stages],
        }
    }

    /// Number of stages covered.
    pub fn num_stages(&self) -> usize {
        self.weight_bytes.len()
    }

    /// Replace the uniform per-stage capacities with per-rank device
    /// capacities — the mixed-GPU-cluster case. Each virtual stage gets
    /// an equal slice (`1/chunks`) of the memory of the rank hosting it
    /// (`rank_of_stage`, from the schedule's placement).
    ///
    /// Panics when a stage names a rank without a capacity entry or a
    /// capacity is not positive.
    pub fn with_rank_capacities(
        mut self,
        rank_capacity_bytes: &[f64],
        rank_of_stage: &[usize],
        chunks: usize,
    ) -> MemoryModel {
        assert_eq!(rank_of_stage.len(), self.num_stages(), "rank_of_stage length mismatch");
        assert!(chunks >= 1, "chunks must be ≥ 1");
        assert!(
            rank_capacity_bytes.iter().all(|c| *c > 0.0 && c.is_finite()),
            "rank capacities must be positive"
        );
        for (s, &r) in rank_of_stage.iter().enumerate() {
            assert!(
                r < rank_capacity_bytes.len(),
                "stage {s} lives on rank {r} but only {} capacities were given",
                rank_capacity_bytes.len()
            );
            self.capacity_bytes[s] = rank_capacity_bytes[r] / chunks as f64;
        }
        self
    }

    /// Scale every stage's capacity by `frac` — the budget-sweep knob of
    /// the fig16 bench (`frac = 1.0` ⇒ the full device).
    pub fn scaled_capacity(mut self, frac: f64) -> MemoryModel {
        assert!(frac > 0.0 && frac.is_finite(), "capacity fraction must be positive");
        for c in &mut self.capacity_bytes {
            *c *= frac;
        }
        self
    }

    /// Scale each stage's stashed activation bytes by `1 − ρ_s`: the
    /// accounting of a run that recomputes a fraction `ρ_s` of stage
    /// `s`'s activations during the backward pass. `rho` must name one
    /// fraction in `[0, 1]` per stage; all-zero fractions leave the
    /// model bit-identical.
    pub fn apply_recompute(mut self, rho: &[f64]) -> MemoryModel {
        assert_eq!(rho.len(), self.num_stages(), "recompute fraction length mismatch");
        for (a, &r) in self.act_bytes_per_mb.iter_mut().zip(rho) {
            assert!(
                r.is_finite() && (0.0..=1.0).contains(&r),
                "recompute fractions must be in [0, 1]"
            );
            if r > 0.0 {
                *a *= 1.0 - r;
            }
        }
        self
    }

    /// Resolve a [`RecomputePolicy`] to per-stage recompute fractions
    /// against this model's capacities.
    ///
    /// [`RecomputePolicy::Auto`] is the per-stage minimum over the two
    /// closed forms: forced freezing is free in time (it *shrinks*
    /// backward durations) and allowed up to the accuracy budget
    /// `r_max`, so each stage freezes first and recomputes only the
    /// deficit beyond it —
    ///
    /// ```text
    /// ρ_s = clamp( (W_s + A_s + (1 − r_max)·T_s − C_s) / A_s , 0, 1 )
    /// ```
    ///
    /// with `W` weights, `A = act/mb × inflight`, `T` trainable state,
    /// `C` capacity. `ρ_s = 0` wherever the freeze floor alone fits
    /// under `r_max` (so a generous budget resolves to the all-zero
    /// vector and stays bit-identical to [`RecomputePolicy::Off`]);
    /// `ρ_s > 1` means even full recompute plus maximal freezing cannot
    /// fit ([`MemoryError::RecomputeInsufficient`]).
    pub fn recompute_fractions(
        &self,
        inflight: &[usize],
        r_max: f64,
        policy: &RecomputePolicy,
    ) -> Result<Vec<f64>, MemoryError> {
        assert_eq!(inflight.len(), self.num_stages(), "inflight length mismatch");
        let n = self.num_stages();
        match policy {
            RecomputePolicy::Off => Ok(vec![0.0; n]),
            RecomputePolicy::Full => Ok(vec![1.0; n]),
            RecomputePolicy::Fraction(f) => {
                assert!(
                    f.is_finite() && *f > 0.0 && *f <= 1.0,
                    "uniform recompute fraction must be in (0, 1]"
                );
                Ok(vec![*f; n])
            }
            RecomputePolicy::Auto => {
                let mut rho = Vec::with_capacity(n);
                for s in 0..n {
                    let act = self.act_bytes_per_mb[s] * inflight[s] as f64;
                    let unreclaimable =
                        self.train_state_bytes[s] * (1.0 - r_max.clamp(0.0, 1.0));
                    let deficit =
                        self.weight_bytes[s] + act + unreclaimable - self.capacity_bytes[s];
                    if deficit <= 0.0 {
                        rho.push(0.0);
                        continue;
                    }
                    // Tolerate the roundoff of an exactly-full-recompute
                    // crossing before declaring the stage unfittable.
                    let r = if act > 0.0 { deficit / act } else { f64::INFINITY };
                    if r > 1.0 + 1e-9 {
                        return Err(MemoryError::RecomputeInsufficient {
                            stage: s,
                            required_bytes: self.weight_bytes[s] + unreclaimable,
                            capacity_bytes: self.capacity_bytes[s],
                            r_max,
                        });
                    }
                    rho.push(r.min(1.0));
                }
                Ok(rho)
            }
        }
    }

    /// Capacity-level core of [`memory_plan_for`]: resolve `policy`
    /// against this (already budget-scaled) model into per-stage
    /// recompute fractions and the freeze-ratio floor derived from the
    /// ρ-scaled activation accounting. For [`RecomputePolicy::Auto`]
    /// the floor is capped at `r_max` (the fractions target exactly
    /// `r_max` on deficit stages; re-deriving the floor from scaled
    /// bytes can land an ulp above it). Returns `(floor, rho)`; errors
    /// are the raw [`MemoryError`]s — the caller decides how to render
    /// infeasibility. Shared by [`memory_plan_for`] and the fig16
    /// bench so the two can never drift.
    pub fn policy_floor(
        &self,
        inflight: &[usize],
        r_max: f64,
        policy: &RecomputePolicy,
    ) -> Result<(Vec<f64>, Vec<f64>), MemoryError> {
        let rho = self.recompute_fractions(inflight, r_max, policy)?;
        let scaled;
        let eff = if rho.iter().any(|&r| r > 0.0) {
            scaled = self.clone().apply_recompute(&rho);
            &scaled
        } else {
            self
        };
        let mut floor = eff.required_ratios(inflight)?;
        if matches!(policy, RecomputePolicy::Auto) {
            for r in &mut floor {
                *r = r.min(r_max);
            }
        }
        Ok((floor, rho))
    }

    /// Peak bytes held by stage `s` with `inflight` microbatches in
    /// flight and an average freeze ratio of `r`.
    pub fn stage_bytes(&self, s: usize, inflight: usize, r: f64) -> f64 {
        self.weight_bytes[s]
            + self.act_bytes_per_mb[s] * inflight as f64
            + self.train_state_bytes[s] * (1.0 - r.clamp(0.0, 1.0))
    }

    /// The minimum average freeze ratio each stage needs to fit its
    /// capacity (0 where memory is not binding) — the LP's per-stage
    /// floor. `inflight[s]` is the schedule's peak in-flight microbatch
    /// count at stage `s` ([`peak_inflight`]).
    pub fn required_ratios(&self, inflight: &[usize]) -> Result<Vec<f64>, MemoryError> {
        assert_eq!(inflight.len(), self.num_stages(), "inflight length mismatch");
        let mut floor = Vec::with_capacity(self.num_stages());
        for s in 0..self.num_stages() {
            let fixed = self.weight_bytes[s] + self.act_bytes_per_mb[s] * inflight[s] as f64;
            let free = self.capacity_bytes[s] - fixed;
            if free < 0.0 {
                return Err(MemoryError::OverCapacity {
                    stage: s,
                    required_bytes: fixed,
                    capacity_bytes: self.capacity_bytes[s],
                });
            }
            let r = if self.train_state_bytes[s] <= free {
                0.0
            } else if self.train_state_bytes[s] > 0.0 {
                1.0 - free / self.train_state_bytes[s]
            } else {
                0.0
            };
            floor.push(r.clamp(0.0, 1.0));
        }
        Ok(floor)
    }
}

/// The planner-visible resolution of an experiment's memory policy: the
/// per-stage freeze-ratio floor the LP enforces as constraint [5], and
/// the per-stage activation-recompute fractions the run executes with.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MemoryPlan {
    /// Per-stage freeze-ratio floor; `None` ⇔ no memory budget active.
    pub floor: Option<Vec<f64>>,
    /// Per-stage recompute fractions `ρ`; `None` ⇔ no recomputation
    /// (all execution and LP paths bit-identical to
    /// [`RecomputePolicy::Off`]).
    pub recompute: Option<Vec<f64>>,
}

/// Resolve a configured experiment's memory policy — budget fraction,
/// per-rank capacities, and [`RecomputePolicy`] — into a [`MemoryPlan`],
/// or a user-facing error when it cannot be satisfied: the device
/// overflows even fully frozen ([`MemoryError::OverCapacity`]), a floor
/// exceeds the accuracy budget `r_max` (the LP would reject it as
/// `FloorExceedsBudget` on every solve, so it is refused upfront here),
/// or even full recompute plus maximal freezing cannot fit
/// ([`MemoryError::RecomputeInsufficient`]).
///
/// Under [`RecomputePolicy::Auto`] the floor is *relaxed* by recompute:
/// each stage freezes up to `r_max` first (free in time) and recomputes
/// only the remaining deficit, so configurations the freeze-only floor
/// would reject as `FloorExceedsBudget` resolve to a feasible plan that
/// pays forward time instead. [`RecomputePolicy::Full`] and
/// [`RecomputePolicy::Fraction`] apply unconditionally — also without a
/// budget, as a pure memory-for-time trade.
///
/// When the config names per-rank capacities
/// (`ExperimentConfig::rank_memory_bytes`, mixed-GPU clusters), each
/// stage is budgeted against the memory of the rank the schedule places
/// it on rather than the uniform GPU preset.
///
/// This is the single recipe shared by the simulator runner and the
/// `tfreeze` CLI, so the `lp` preview and the simulator always agree on
/// both knobs.
pub fn memory_plan_for(
    cfg: &ExperimentConfig,
    layer_stage: &[usize],
    schedule: &Schedule,
) -> Result<MemoryPlan, String> {
    let Some(frac) = cfg.memory_budget else {
        if cfg.rank_memory_bytes.is_some() {
            return Err(
                "per-rank memory capacities are set but no memory budget is active — \
                 set memory_budget (CLI --mem-budget) to enable the per-rank floor"
                    .to_string(),
            );
        }
        // Unbudgeted runs can still recompute unconditionally (a pure
        // memory-for-time trade); Auto has no deficit to cover.
        let recompute = match &cfg.recompute {
            RecomputePolicy::Off | RecomputePolicy::Auto => None,
            RecomputePolicy::Full => Some(vec![1.0; cfg.stages()]),
            RecomputePolicy::Fraction(f) => Some(vec![*f; cfg.stages()]),
        };
        return Ok(MemoryPlan { floor: None, recompute });
    };
    let mut mem = MemoryModel::from_presets(
        &cfg.model,
        &cfg.gpu,
        layer_stage,
        cfg.stages(),
        cfg.microbatch_size,
        cfg.seq_len,
        cfg.effective_chunks(),
    );
    // Mixed-GPU clusters: per-rank capacities override the uniform
    // preset before the budget fraction scales them.
    if let Some(caps) = &cfg.rank_memory_bytes {
        if caps.len() != schedule.ranks {
            return Err(format!(
                "rank_memory_gb names {} ranks but the pipeline has {}",
                caps.len(),
                schedule.ranks
            ));
        }
        mem = mem.with_rank_capacities(caps, &schedule.rank_of_stage, cfg.effective_chunks());
    }
    let mem = mem.scaled_capacity(frac);
    let inflight = peak_inflight(schedule);
    let (floor, rho) = mem
        .policy_floor(&inflight, cfg.r_max, &cfg.recompute)
        .map_err(|e| format!("memory budget {frac} infeasible for {}: {e}", cfg.model.name))?;
    let recomputing = rho.iter().any(|&r| r > 0.0);
    if let Some((s, &r)) = floor.iter().enumerate().find(|&(_, &r)| r > cfg.r_max) {
        let hint = if cfg.recompute.is_off() {
            " or enable activation recomputation (--recompute auto)"
        } else {
            ""
        };
        return Err(format!(
            "memory budget {frac} needs a stage-{s} freeze ratio of at least {r:.3}, \
             above the accuracy budget r_max = {} — raise the budget or r_max{hint}",
            cfg.r_max
        ));
    }
    Ok(MemoryPlan { floor: Some(floor), recompute: recomputing.then_some(rho) })
}

/// [`memory_plan_for`] against a surviving sub-fleet (elastic
/// recovery): project the experiment onto the physical ranks named by
/// `fleet` — the rank count shrinks to `fleet.len()` and any per-rank
/// capacities are filtered to the survivors, preserving heterogeneity —
/// then resolve the memory policy exactly as the full-fleet path would.
/// `layer_stage` and `schedule` must already describe the reduced
/// pipeline (the caller repartitioned layers over `fleet.len()` ranks).
/// This is where `--recompute auto` rescues budgets a shrunken fleet
/// could not satisfy by freezing alone.
pub fn memory_plan_for_fleet(
    cfg: &ExperimentConfig,
    layer_stage: &[usize],
    schedule: &Schedule,
    fleet: &[usize],
) -> Result<MemoryPlan, String> {
    assert!(!fleet.is_empty(), "fleet must name at least one survivor");
    assert_eq!(
        schedule.ranks,
        fleet.len(),
        "schedule must be built for the reduced fleet"
    );
    let mut sub = cfg.clone();
    sub.ranks = fleet.len();
    if let Some(caps) = &cfg.rank_memory_bytes {
        let survivors: Vec<f64> = fleet
            .iter()
            .map(|&r| {
                caps.get(r).copied().ok_or_else(|| {
                    format!(
                        "fleet names physical rank {r} but rank_memory_gb covers only \
                         {} ranks",
                        caps.len()
                    )
                })
            })
            .collect::<Result<_, _>>()?;
        sub.rank_memory_bytes = Some(survivors);
    }
    memory_plan_for(&sub, layer_stage, schedule)
}

/// Derive the per-stage freeze-ratio floor alone: `Ok(None)` when the
/// config carries no memory budget, `Ok(Some(floor))` when the budgeted
/// capacity is satisfiable under the config's [`RecomputePolicy`]. A
/// thin view over [`memory_plan_for`] kept for callers that only
/// consume constraint [5]; anything that executes should take the whole
/// [`MemoryPlan`] so the recompute surcharge is not silently dropped.
pub fn stage_floor_for(
    cfg: &ExperimentConfig,
    layer_stage: &[usize],
    schedule: &Schedule,
) -> Result<Option<Vec<f64>>, String> {
    memory_plan_for(cfg, layer_stage, schedule).map(|p| p.floor)
}

/// Peak number of simultaneously in-flight microbatches per stage: a
/// microbatch occupies a stage's activation memory from its forward
/// until the action that consumes the stashed activations completes —
/// the fused backward, or the parameter-gradient "W" under the
/// Zero-Bubble split ("B" alone still needs the stash for W).
///
/// Derived by replaying each rank's schedule order; deterministic and
/// schedule-exact (GPipe peaks at `M` everywhere, 1F1B at
/// `min(M, ranks − rank)`, ZBV between the two).
pub fn peak_inflight(schedule: &Schedule) -> Vec<usize> {
    let mut peak = vec![0usize; schedule.stages];
    let mut live = vec![0isize; schedule.stages];
    for order in &schedule.orders {
        for a in order {
            match a.kind {
                ActionKind::Forward => {
                    live[a.stage] += 1;
                    peak[a.stage] = peak[a.stage].max(live[a.stage] as usize);
                }
                ActionKind::Backward | ActionKind::BackwardWgrad => {
                    live[a.stage] -= 1;
                }
                ActionKind::BackwardDgrad => {}
            }
        }
    }
    peak
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::partition::balanced_partition;
    use crate::types::ScheduleKind;

    fn model_1b() -> (ExperimentConfig, MemoryModel) {
        let cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        let layer_stage = balanced_partition(&cfg.model.layer_params(), 4);
        let mem = MemoryModel::from_presets(
            &cfg.model,
            &cfg.gpu,
            &layer_stage,
            4,
            cfg.microbatch_size,
            cfg.seq_len,
            1,
        );
        (cfg, mem)
    }

    #[test]
    fn preset_accounting_plausible_for_1b() {
        let (cfg, mem) = model_1b();
        let total_weight: f64 = mem.weight_bytes.iter().sum();
        // ~1.24B params × 2 bytes ≈ 2.5 GB.
        assert!((1.8e9..3.5e9).contains(&total_weight), "{total_weight}");
        let total_state: f64 = mem.train_state_bytes.iter().sum();
        assert!((total_state / total_weight - 7.0).abs() < 1e-9);
        assert!(mem.act_bytes_per_mb.iter().all(|&a| a > 0.0));
        assert!(mem.capacity_bytes.iter().all(|&c| c == cfg.gpu.memory_bytes));
    }

    #[test]
    fn unconstrained_budget_needs_no_freezing() {
        let (cfg, mem) = model_1b();
        let s = Schedule::build(ScheduleKind::OneFOneB, 4, cfg.microbatches, 1);
        let floor = mem.required_ratios(&peak_inflight(&s)).unwrap();
        assert!(floor.iter().all(|&r| r == 0.0), "48 GB fits 1B easily: {floor:?}");
    }

    #[test]
    fn tight_budget_forces_freezing_and_tighter_oom() {
        let (cfg, mem) = model_1b();
        let s = Schedule::build(ScheduleKind::OneFOneB, 4, cfg.microbatches, 1);
        let inflight = peak_inflight(&s);
        // Shrink capacity until the trainable state no longer fits.
        let mut frac = 1.0;
        let floor = loop {
            let m = mem.clone().scaled_capacity(frac);
            match m.required_ratios(&inflight) {
                Ok(f) if f.iter().any(|&r| r > 0.0) => break f,
                Ok(_) => frac *= 0.8,
                Err(e) => panic!("walked past feasibility: {e}"),
            }
        };
        assert!(floor.iter().all(|&r| (0.0..=1.0).contains(&r)));
        // A budget below weights+activations is reported as infeasible.
        let oom = mem.clone().scaled_capacity(1e-4);
        assert!(matches!(
            oom.required_ratios(&inflight),
            Err(MemoryError::OverCapacity { .. })
        ));
    }

    #[test]
    fn floor_is_monotone_in_capacity() {
        let (cfg, mem) = model_1b();
        let s = Schedule::build(ScheduleKind::GPipe, 4, cfg.microbatches, 1);
        let inflight = peak_inflight(&s);
        let mut prev = vec![1.0f64; 4];
        for frac in [0.02, 0.05, 0.1, 0.2, 0.5, 1.0] {
            let m = mem.clone().scaled_capacity(frac);
            if let Ok(floor) = m.required_ratios(&inflight) {
                for (a, b) in floor.iter().zip(&prev) {
                    assert!(a <= b, "floor must shrink as capacity grows");
                }
                prev = floor;
            }
        }
        assert!(prev.iter().all(|&r| r == 0.0));
    }

    #[test]
    fn rank_capacities_map_through_stage_placement() {
        let (cfg, mem) = model_1b();
        // 4 ranks, 1 chunk: stage s lives on rank s. Rank 2 is a small
        // card; only its stage's capacity shrinks.
        let caps = [48e9, 48e9, 24e9, 48e9];
        let m = mem.clone().with_rank_capacities(&caps, &[0, 1, 2, 3], 1);
        assert_eq!(m.capacity_bytes, vec![48e9, 48e9, 24e9, 48e9]);
        // Two chunks per rank split each card across its stages (ZBV's
        // V placement: rank r hosts stages r and 2R−1−r).
        let caps2 = [48e9, 24e9];
        let m = MemoryModel {
            weight_bytes: vec![1.0; 4],
            act_bytes_per_mb: vec![1.0; 4],
            train_state_bytes: vec![7.0; 4],
            capacity_bytes: vec![0.0; 4],
        }
        .with_rank_capacities(&caps2, &[0, 1, 1, 0], 2);
        assert_eq!(m.capacity_bytes, vec![24e9, 12e9, 12e9, 24e9]);
        let _ = cfg;
    }

    #[test]
    fn hetero_floor_binds_only_on_the_small_card() {
        let (cfg, mem) = model_1b();
        let s = Schedule::build(ScheduleKind::OneFOneB, 4, cfg.microbatches, 1);
        let inflight = peak_inflight(&s);
        // Uniform capacity that needs no freezing…
        let uniform = mem.clone().required_ratios(&inflight).unwrap();
        assert!(uniform.iter().all(|&r| r == 0.0));
        // …then shrink one rank until its stage (and only its stage)
        // needs a floor.
        let mut small = cfg.gpu.memory_bytes;
        loop {
            small *= 0.8;
            let caps = [cfg.gpu.memory_bytes, cfg.gpu.memory_bytes, small, cfg.gpu.memory_bytes];
            match mem
                .clone()
                .with_rank_capacities(&caps, &s.rank_of_stage, 1)
                .required_ratios(&inflight)
            {
                Ok(floor) if floor[2] > 0.0 => {
                    assert_eq!(floor[0], 0.0);
                    assert_eq!(floor[1], 0.0);
                    assert_eq!(floor[3], 0.0);
                    break;
                }
                Ok(_) => continue,
                Err(e) => panic!("walked past feasibility: {e}"),
            }
        }
    }

    #[test]
    fn stage_floor_for_threads_rank_capacities() {
        let (mut cfg, mem) = model_1b();
        let s = Schedule::build(ScheduleKind::OneFOneB, 4, cfg.microbatches, 1);
        let layer_stage = balanced_partition(&cfg.model.layer_params(), 4);
        // A budget fraction that is floor-free on uniform cards…
        cfg.memory_budget = Some(1.0);
        let uniform = stage_floor_for(&cfg, &layer_stage, &s).unwrap().unwrap();
        assert!(uniform.iter().all(|&r| r == 0.0));
        // …but binds once rank 1 is a much smaller card. Probe for a
        // size that is binding-but-feasible under r_max.
        let mut small = cfg.gpu.memory_bytes;
        let floor = loop {
            small *= 0.9;
            cfg.rank_memory_bytes = Some(vec![
                cfg.gpu.memory_bytes,
                small,
                cfg.gpu.memory_bytes,
                cfg.gpu.memory_bytes,
            ]);
            match stage_floor_for(&cfg, &layer_stage, &s) {
                Ok(Some(f)) if f[1] > 0.0 => break f,
                Ok(_) => continue,
                Err(e) => panic!("probe overshot: {e}"),
            }
        };
        assert!(floor[1] > 0.0 && floor[0] == 0.0 && floor[2] == 0.0);
        // A capacity vector of the wrong arity is a clean error…
        cfg.rank_memory_bytes = Some(vec![48e9, 48e9]);
        assert!(stage_floor_for(&cfg, &layer_stage, &s).is_err());
        // …and so are rank capacities without an active budget (they
        // would otherwise be silently ignored).
        cfg.memory_budget = None;
        cfg.rank_memory_bytes = Some(vec![48e9; 4]);
        assert!(stage_floor_for(&cfg, &layer_stage, &s).is_err());
        let _ = mem;
    }

    #[test]
    fn peak_inflight_matches_schedule_theory() {
        // GPipe: every forward of the batch is in flight before the
        // first backward → peak M at every stage.
        let s = Schedule::build(ScheduleKind::GPipe, 4, 8, 1);
        assert_eq!(peak_inflight(&s), vec![8, 8, 8, 8]);
        // 1F1B: stage s admits min(M, ranks − s) in-flight microbatches.
        let s = Schedule::build(ScheduleKind::OneFOneB, 4, 8, 1);
        assert_eq!(peak_inflight(&s), vec![4, 3, 2, 1]);
        // ZBV: bounded by M, at least 1, defined for every stage.
        let s = Schedule::build(ScheduleKind::ZeroBubbleV, 4, 8, 2);
        let p = peak_inflight(&s);
        assert_eq!(p.len(), 8);
        assert!(p.iter().all(|&x| (1..=8).contains(&x)), "{p:?}");
    }

    #[test]
    fn stage_bytes_linear_in_ratio() {
        let (_, mem) = model_1b();
        let lo = mem.stage_bytes(0, 4, 1.0);
        let hi = mem.stage_bytes(0, 4, 0.0);
        let mid = mem.stage_bytes(0, 4, 0.5);
        assert!(hi > lo);
        assert!((mid - (lo + hi) / 2.0).abs() < 1.0);
    }

    #[test]
    fn recompute_policy_parses_and_names() {
        assert_eq!(RecomputePolicy::parse("off").unwrap(), RecomputePolicy::Off);
        assert_eq!(RecomputePolicy::parse("none").unwrap(), RecomputePolicy::Off);
        assert_eq!(RecomputePolicy::parse("0").unwrap(), RecomputePolicy::Off);
        assert_eq!(RecomputePolicy::parse("Full").unwrap(), RecomputePolicy::Full);
        assert_eq!(RecomputePolicy::parse("1.0").unwrap(), RecomputePolicy::Full);
        assert_eq!(RecomputePolicy::parse("auto").unwrap(), RecomputePolicy::Auto);
        assert_eq!(
            RecomputePolicy::parse("0.5").unwrap(),
            RecomputePolicy::Fraction(0.5)
        );
        for bad in ["1.5", "-0.2", "sometimes", ""] {
            assert!(RecomputePolicy::parse(bad).is_err(), "'{bad}' should not parse");
        }
        // name() round-trips through parse().
        for p in [
            RecomputePolicy::Off,
            RecomputePolicy::Full,
            RecomputePolicy::Auto,
            RecomputePolicy::Fraction(0.25),
        ] {
            assert_eq!(RecomputePolicy::parse(&p.name()).unwrap(), p);
        }
        assert!(RecomputePolicy::Off.is_off());
        assert!(!RecomputePolicy::Auto.is_off());
    }

    #[test]
    fn apply_recompute_scales_activations_only() {
        let (_, mem) = model_1b();
        let rho = [0.0, 0.5, 1.0, 0.25];
        let scaled = mem.clone().apply_recompute(&rho);
        for s in 0..4 {
            assert_eq!(
                scaled.act_bytes_per_mb[s],
                if rho[s] > 0.0 {
                    mem.act_bytes_per_mb[s] * (1.0 - rho[s])
                } else {
                    mem.act_bytes_per_mb[s]
                }
            );
            assert_eq!(scaled.weight_bytes[s], mem.weight_bytes[s]);
            assert_eq!(scaled.train_state_bytes[s], mem.train_state_bytes[s]);
            assert_eq!(scaled.capacity_bytes[s], mem.capacity_bytes[s]);
        }
        // All-zero fractions are bit-identical.
        assert_eq!(mem.clone().apply_recompute(&[0.0; 4]), mem);
    }

    #[test]
    fn auto_fractions_zero_on_generous_budget() {
        let (cfg, mem) = model_1b();
        let s = Schedule::build(ScheduleKind::OneFOneB, 4, cfg.microbatches, 1);
        let inflight = peak_inflight(&s);
        let rho = mem
            .recompute_fractions(&inflight, cfg.r_max, &RecomputePolicy::Auto)
            .unwrap();
        assert_eq!(rho, vec![0.0; 4], "48 GB fits 1B without recompute");
        // Off and Full resolve to the constant vectors.
        assert_eq!(
            mem.recompute_fractions(&inflight, cfg.r_max, &RecomputePolicy::Off).unwrap(),
            vec![0.0; 4]
        );
        assert_eq!(
            mem.recompute_fractions(&inflight, cfg.r_max, &RecomputePolicy::Full).unwrap(),
            vec![1.0; 4]
        );
        assert_eq!(
            mem.recompute_fractions(&inflight, cfg.r_max, &RecomputePolicy::Fraction(0.3))
                .unwrap(),
            vec![0.3; 4]
        );
    }

    #[test]
    fn auto_fractions_cover_the_deficit_exactly() {
        let (cfg, mem) = model_1b();
        let s = Schedule::build(ScheduleKind::GPipe, 4, cfg.microbatches, 1);
        let inflight = peak_inflight(&s);
        let r_max = 0.8;
        // Shrink capacity until the freeze-only floor conflicts with
        // r_max — the regime Auto exists to rescue. Fine 1% steps: the
        // conflict window is only (1 − r_max)·T wide before the OOM
        // wall, and a coarse probe would jump straight past it.
        let mut frac = 1.0f64;
        let mem = loop {
            let m = mem.clone().scaled_capacity(frac);
            match m.required_ratios(&inflight) {
                Ok(f) if f.iter().any(|&r| r > r_max) => break m,
                Ok(_) => frac *= 0.99,
                Err(e) => panic!("walked past the OOM wall: {e}"),
            }
        };
        let rho = mem.recompute_fractions(&inflight, r_max, &RecomputePolicy::Auto).unwrap();
        assert!(rho.iter().any(|&r| r > 0.0), "deficit stages must recompute");
        assert!(rho.iter().all(|&r| (0.0..=1.0).contains(&r)));
        // The scaled accounting fits with the floor capped at r_max.
        let scaled = mem.clone().apply_recompute(&rho);
        let floor = scaled.required_ratios(&inflight).unwrap();
        for s in 0..4 {
            assert!(
                floor[s] <= r_max + 1e-9,
                "stage {s}: relaxed floor {} still above r_max",
                floor[s]
            );
            let used = scaled.stage_bytes(s, inflight[s], floor[s].min(r_max));
            assert!(
                used <= scaled.capacity_bytes[s] + scaled.train_state_bytes[s] * 1e-9 + 1.0,
                "stage {s}: {used} bytes over capacity {}",
                scaled.capacity_bytes[s]
            );
        }
        // A budget below even weights + (1 − r_max)·state is reported as
        // unfittable-with-recompute.
        let hopeless = mem.clone().scaled_capacity(1e-4);
        assert!(matches!(
            hopeless.recompute_fractions(&inflight, r_max, &RecomputePolicy::Auto),
            Err(MemoryError::RecomputeInsufficient { .. })
        ));
    }

    #[test]
    fn memory_plan_auto_rescues_floor_exceeds_budget() {
        let (mut cfg, mem) = model_1b();
        let s = Schedule::build(ScheduleKind::GPipe, 4, cfg.microbatches, 1);
        let layer_stage = balanced_partition(&cfg.model.layer_params(), 4);
        let inflight = peak_inflight(&s);
        // Probe for a budget fraction whose freeze-only floor exceeds
        // r_max but stays above the OOM wall (fine 1% steps — the
        // window is only (1 − r_max)·T wide).
        let mut frac = 1.0f64;
        loop {
            match mem.clone().scaled_capacity(frac).required_ratios(&inflight) {
                Ok(f) if f.iter().any(|&r| r > cfg.r_max) => break,
                Ok(_) => frac *= 0.99,
                Err(e) => panic!("walked past the OOM wall: {e}"),
            }
        }
        cfg.memory_budget = Some(frac);
        // Freeze-only: a clean upfront error that names the conflict.
        cfg.recompute = RecomputePolicy::Off;
        let err = memory_plan_for(&cfg, &layer_stage, &s).unwrap_err();
        assert!(err.contains("above the accuracy budget"), "{err}");
        assert!(err.contains("--recompute auto"), "{err}");
        // Auto: same budget resolves to a feasible plan with the floor
        // capped at r_max and a nonzero recompute vector.
        cfg.recompute = RecomputePolicy::Auto;
        let plan = memory_plan_for(&cfg, &layer_stage, &s).unwrap();
        let floor = plan.floor.expect("budgeted plan must carry a floor");
        assert!(floor.iter().all(|&r| r <= cfg.r_max));
        let rho = plan.recompute.expect("deficit must be covered by recompute");
        assert!(rho.iter().any(|&r| r > 0.0));
        // Full also fits here (it frees even more activation memory) and
        // its floor can only be lower or equal.
        cfg.recompute = RecomputePolicy::Full;
        let full = memory_plan_for(&cfg, &layer_stage, &s).unwrap();
        for (a, b) in full.floor.unwrap().iter().zip(&floor) {
            assert!(a <= b, "full-recompute floor must not exceed auto's");
        }
    }

    #[test]
    fn memory_plan_for_fleet_projects_ranks_and_capacities() {
        let (mut cfg, _) = model_1b();
        cfg.memory_budget = Some(0.9);
        // Heterogeneous 4-rank cluster; rank 1 dies, survivors keep
        // their own capacities in physical order.
        cfg.rank_memory_bytes = Some(vec![48e9, 24e9, 48e9, 32e9]);
        let fleet = vec![0usize, 2, 3];
        let sub = Schedule::build(ScheduleKind::OneFOneB, 3, cfg.microbatches, 1);
        let layer_stage = balanced_partition(&cfg.model.layer_params(), 3);
        let plan = memory_plan_for_fleet(&cfg, &layer_stage, &sub, &fleet).unwrap();
        assert!(plan.floor.is_some(), "budgeted fleet plan must carry a floor");
        // The projection must match a hand-built 3-rank config.
        let mut hand = cfg.clone();
        hand.ranks = 3;
        hand.rank_memory_bytes = Some(vec![48e9, 48e9, 32e9]);
        assert_eq!(plan, memory_plan_for(&hand, &layer_stage, &sub).unwrap());
        // A fleet naming a rank outside the capacity table is a clean
        // error, not a panic.
        let err =
            memory_plan_for_fleet(&cfg, &layer_stage, &sub, &[0, 2, 9]).unwrap_err();
        assert!(err.contains("rank 9"), "{err}");
    }

    #[test]
    fn memory_plan_without_budget_only_recomputes_unconditionally() {
        let (mut cfg, _) = model_1b();
        let s = Schedule::build(ScheduleKind::OneFOneB, 4, cfg.microbatches, 1);
        let layer_stage = balanced_partition(&cfg.model.layer_params(), 4);
        cfg.memory_budget = None;
        for (policy, want) in [
            (RecomputePolicy::Off, None),
            (RecomputePolicy::Auto, None),
            (RecomputePolicy::Full, Some(vec![1.0; 4])),
            (RecomputePolicy::Fraction(0.4), Some(vec![0.4; 4])),
        ] {
            cfg.recompute = policy;
            let plan = memory_plan_for(&cfg, &layer_stage, &s).unwrap();
            assert_eq!(plan.floor, None);
            assert_eq!(plan.recompute, want);
        }
    }
}
