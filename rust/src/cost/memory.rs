//! Per-stage device-memory accounting and the freeze-ratio floor.
//!
//! A pipeline stage holds three kinds of bytes:
//!
//! * **weights** — the stage's parameters (resident regardless of
//!   freezing);
//! * **activations** — stashed between a microbatch's forward and
//!   backward; the peak count of simultaneously in-flight microbatches
//!   is a property of the *schedule* ([`peak_inflight`]);
//! * **trainable state** — gradients + optimizer moments + fp32 master
//!   copy, needed only for *unfrozen* parameters. This is the term
//!   freezing reclaims.
//!
//! Given a capacity, [`MemoryModel::required_ratios`] inverts the
//! accounting into the minimum average freeze ratio each stage needs to
//! fit — the per-stage floor the freeze LP enforces as constraint [5]
//! (see [`crate::lp::freeze_lp`]). This is the memory-pressure regime of
//! "Pipeline Parallelism with Controllable Memory" (Qi et al., 2024):
//! freezing is no longer purely a throughput knob but also a way to fit
//! a model on smaller devices.

use crate::config::{ExperimentConfig, GpuPreset, ModelPreset};
use crate::schedule::Schedule;
use crate::types::ActionKind;

/// Bytes per parameter held by the resident weights (bf16).
pub const WEIGHT_BYTES_PER_PARAM: f64 = 2.0;

/// Bytes per *trainable* parameter beyond the weight itself: bf16
/// gradient (2) + fp32 Adam moments (8) + fp32 master copy (4).
/// Freezing a parameter reclaims all of it.
pub const TRAIN_STATE_BYTES_PER_PARAM: f64 = 14.0;

/// Per-stage memory accounting for one experiment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct MemoryModel {
    /// Resident weight bytes per stage.
    pub weight_bytes: Vec<f64>,
    /// Activation bytes stashed per in-flight microbatch per stage.
    pub act_bytes_per_mb: Vec<f64>,
    /// Gradient + optimizer + master bytes per stage if *nothing* is
    /// frozen; the freeze ratio scales this term by `1 − r`.
    pub train_state_bytes: Vec<f64>,
    /// Device-memory capacity available to each stage.
    pub capacity_bytes: Vec<f64>,
}

/// Why a memory budget cannot be met.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MemoryError {
    /// Even at full freezing (`r = 1`, zero trainable state) the stage's
    /// weights + activations exceed its capacity.
    OverCapacity {
        /// The offending stage.
        stage: usize,
        /// Bytes required at full freezing.
        required_bytes: f64,
        /// The stage's capacity.
        capacity_bytes: f64,
    },
}

impl std::fmt::Display for MemoryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemoryError::OverCapacity { stage, required_bytes, capacity_bytes } => write!(
                f,
                "stage {stage} needs {:.2} GiB even fully frozen but only {:.2} GiB fit",
                required_bytes / (1u64 << 30) as f64,
                capacity_bytes / (1u64 << 30) as f64,
            ),
        }
    }
}

impl std::error::Error for MemoryError {}

impl MemoryModel {
    /// Derive the accounting from the paper presets: per-stage parameter
    /// sums from the layer→stage assignment, the coarse per-layer
    /// activation formula of
    /// [`ModelPreset::layer_act_bytes`], and an equal slice of the GPU's
    /// memory per virtual stage hosted on the rank (`chunks` slices).
    pub fn from_presets(
        model: &ModelPreset,
        gpu: &GpuPreset,
        layer_stage: &[usize],
        stages: usize,
        microbatch_size: usize,
        seq_len: usize,
        chunks: usize,
    ) -> MemoryModel {
        assert_eq!(layer_stage.len(), model.num_layers());
        assert!(chunks >= 1, "chunks must be ≥ 1");
        let mut weight = vec![0.0f64; stages];
        let mut act = vec![0.0f64; stages];
        for (l, &s) in layer_stage.iter().enumerate() {
            weight[s] += model.layer_params()[l] * WEIGHT_BYTES_PER_PARAM;
            act[s] += model.layer_act_bytes(l, microbatch_size, seq_len);
        }
        let train_state: Vec<f64> = weight
            .iter()
            .map(|w| w / WEIGHT_BYTES_PER_PARAM * TRAIN_STATE_BYTES_PER_PARAM)
            .collect();
        MemoryModel {
            weight_bytes: weight,
            act_bytes_per_mb: act,
            train_state_bytes: train_state,
            capacity_bytes: vec![gpu.memory_bytes / chunks as f64; stages],
        }
    }

    /// Number of stages covered.
    pub fn num_stages(&self) -> usize {
        self.weight_bytes.len()
    }

    /// Replace the uniform per-stage capacities with per-rank device
    /// capacities — the mixed-GPU-cluster case. Each virtual stage gets
    /// an equal slice (`1/chunks`) of the memory of the rank hosting it
    /// (`rank_of_stage`, from the schedule's placement).
    ///
    /// Panics when a stage names a rank without a capacity entry or a
    /// capacity is not positive.
    pub fn with_rank_capacities(
        mut self,
        rank_capacity_bytes: &[f64],
        rank_of_stage: &[usize],
        chunks: usize,
    ) -> MemoryModel {
        assert_eq!(rank_of_stage.len(), self.num_stages(), "rank_of_stage length mismatch");
        assert!(chunks >= 1, "chunks must be ≥ 1");
        assert!(
            rank_capacity_bytes.iter().all(|c| *c > 0.0 && c.is_finite()),
            "rank capacities must be positive"
        );
        for (s, &r) in rank_of_stage.iter().enumerate() {
            assert!(
                r < rank_capacity_bytes.len(),
                "stage {s} lives on rank {r} but only {} capacities were given",
                rank_capacity_bytes.len()
            );
            self.capacity_bytes[s] = rank_capacity_bytes[r] / chunks as f64;
        }
        self
    }

    /// Scale every stage's capacity by `frac` — the budget-sweep knob of
    /// the fig16 bench (`frac = 1.0` ⇒ the full device).
    pub fn scaled_capacity(mut self, frac: f64) -> MemoryModel {
        assert!(frac > 0.0 && frac.is_finite(), "capacity fraction must be positive");
        for c in &mut self.capacity_bytes {
            *c *= frac;
        }
        self
    }

    /// Peak bytes held by stage `s` with `inflight` microbatches in
    /// flight and an average freeze ratio of `r`.
    pub fn stage_bytes(&self, s: usize, inflight: usize, r: f64) -> f64 {
        self.weight_bytes[s]
            + self.act_bytes_per_mb[s] * inflight as f64
            + self.train_state_bytes[s] * (1.0 - r.clamp(0.0, 1.0))
    }

    /// The minimum average freeze ratio each stage needs to fit its
    /// capacity (0 where memory is not binding) — the LP's per-stage
    /// floor. `inflight[s]` is the schedule's peak in-flight microbatch
    /// count at stage `s` ([`peak_inflight`]).
    pub fn required_ratios(&self, inflight: &[usize]) -> Result<Vec<f64>, MemoryError> {
        assert_eq!(inflight.len(), self.num_stages(), "inflight length mismatch");
        let mut floor = Vec::with_capacity(self.num_stages());
        for s in 0..self.num_stages() {
            let fixed = self.weight_bytes[s] + self.act_bytes_per_mb[s] * inflight[s] as f64;
            let free = self.capacity_bytes[s] - fixed;
            if free < 0.0 {
                return Err(MemoryError::OverCapacity {
                    stage: s,
                    required_bytes: fixed,
                    capacity_bytes: self.capacity_bytes[s],
                });
            }
            let r = if self.train_state_bytes[s] <= free {
                0.0
            } else if self.train_state_bytes[s] > 0.0 {
                1.0 - free / self.train_state_bytes[s]
            } else {
                0.0
            };
            floor.push(r.clamp(0.0, 1.0));
        }
        Ok(floor)
    }
}

/// Derive the per-stage freeze-ratio floor for a configured experiment:
/// `Ok(None)` when the config carries no memory budget, `Ok(Some(floor))`
/// when the budgeted capacity is satisfiable, and a user-facing error
/// when it is not — either the device overflows even fully frozen
/// ([`MemoryError::OverCapacity`]) or a stage's floor exceeds the
/// accuracy budget `r_max` (the LP would reject it as
/// `FloorExceedsBudget` on every solve, so it is refused upfront here).
///
/// When the config names per-rank capacities
/// (`ExperimentConfig::rank_memory_bytes`, mixed-GPU clusters), each
/// stage is budgeted against the memory of the rank the schedule places
/// it on rather than the uniform GPU preset.
///
/// This is the single recipe shared by the simulator runner and the
/// `tfreeze` CLI, so the `lp` preview and the simulator always agree on
/// the floor.
pub fn stage_floor_for(
    cfg: &ExperimentConfig,
    layer_stage: &[usize],
    schedule: &Schedule,
) -> Result<Option<Vec<f64>>, String> {
    let Some(frac) = cfg.memory_budget else {
        if cfg.rank_memory_bytes.is_some() {
            return Err(
                "per-rank memory capacities are set but no memory budget is active — \
                 set memory_budget (CLI --mem-budget) to enable the per-rank floor"
                    .to_string(),
            );
        }
        return Ok(None);
    };
    let mut mem = MemoryModel::from_presets(
        &cfg.model,
        &cfg.gpu,
        layer_stage,
        cfg.stages(),
        cfg.microbatch_size,
        cfg.seq_len,
        cfg.effective_chunks(),
    );
    // Mixed-GPU clusters: per-rank capacities override the uniform
    // preset before the budget fraction scales them.
    if let Some(caps) = &cfg.rank_memory_bytes {
        if caps.len() != schedule.ranks {
            return Err(format!(
                "rank_memory_gb names {} ranks but the pipeline has {}",
                caps.len(),
                schedule.ranks
            ));
        }
        mem = mem.with_rank_capacities(caps, &schedule.rank_of_stage, cfg.effective_chunks());
    }
    let mem = mem.scaled_capacity(frac);
    let floor = mem
        .required_ratios(&peak_inflight(schedule))
        .map_err(|e| format!("memory budget {frac} infeasible for {}: {e}", cfg.model.name))?;
    if let Some((s, &r)) = floor.iter().enumerate().find(|&(_, &r)| r > cfg.r_max) {
        return Err(format!(
            "memory budget {frac} needs a stage-{s} freeze ratio of at least {r:.3}, \
             above the accuracy budget r_max = {} — raise the budget or r_max",
            cfg.r_max
        ));
    }
    Ok(Some(floor))
}

/// Peak number of simultaneously in-flight microbatches per stage: a
/// microbatch occupies a stage's activation memory from its forward
/// until the action that consumes the stashed activations completes —
/// the fused backward, or the parameter-gradient "W" under the
/// Zero-Bubble split ("B" alone still needs the stash for W).
///
/// Derived by replaying each rank's schedule order; deterministic and
/// schedule-exact (GPipe peaks at `M` everywhere, 1F1B at
/// `min(M, ranks − rank)`, ZBV between the two).
pub fn peak_inflight(schedule: &Schedule) -> Vec<usize> {
    let mut peak = vec![0usize; schedule.stages];
    let mut live = vec![0isize; schedule.stages];
    for order in &schedule.orders {
        for a in order {
            match a.kind {
                ActionKind::Forward => {
                    live[a.stage] += 1;
                    peak[a.stage] = peak[a.stage].max(live[a.stage] as usize);
                }
                ActionKind::Backward | ActionKind::BackwardWgrad => {
                    live[a.stage] -= 1;
                }
                ActionKind::BackwardDgrad => {}
            }
        }
    }
    peak
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::partition::balanced_partition;
    use crate::types::ScheduleKind;

    fn model_1b() -> (ExperimentConfig, MemoryModel) {
        let cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        let layer_stage = balanced_partition(&cfg.model.layer_params(), 4);
        let mem = MemoryModel::from_presets(
            &cfg.model,
            &cfg.gpu,
            &layer_stage,
            4,
            cfg.microbatch_size,
            cfg.seq_len,
            1,
        );
        (cfg, mem)
    }

    #[test]
    fn preset_accounting_plausible_for_1b() {
        let (cfg, mem) = model_1b();
        let total_weight: f64 = mem.weight_bytes.iter().sum();
        // ~1.24B params × 2 bytes ≈ 2.5 GB.
        assert!((1.8e9..3.5e9).contains(&total_weight), "{total_weight}");
        let total_state: f64 = mem.train_state_bytes.iter().sum();
        assert!((total_state / total_weight - 7.0).abs() < 1e-9);
        assert!(mem.act_bytes_per_mb.iter().all(|&a| a > 0.0));
        assert!(mem.capacity_bytes.iter().all(|&c| c == cfg.gpu.memory_bytes));
    }

    #[test]
    fn unconstrained_budget_needs_no_freezing() {
        let (cfg, mem) = model_1b();
        let s = Schedule::build(ScheduleKind::OneFOneB, 4, cfg.microbatches, 1);
        let floor = mem.required_ratios(&peak_inflight(&s)).unwrap();
        assert!(floor.iter().all(|&r| r == 0.0), "48 GB fits 1B easily: {floor:?}");
    }

    #[test]
    fn tight_budget_forces_freezing_and_tighter_oom() {
        let (cfg, mem) = model_1b();
        let s = Schedule::build(ScheduleKind::OneFOneB, 4, cfg.microbatches, 1);
        let inflight = peak_inflight(&s);
        // Shrink capacity until the trainable state no longer fits.
        let mut frac = 1.0;
        let floor = loop {
            let m = mem.clone().scaled_capacity(frac);
            match m.required_ratios(&inflight) {
                Ok(f) if f.iter().any(|&r| r > 0.0) => break f,
                Ok(_) => frac *= 0.8,
                Err(e) => panic!("walked past feasibility: {e}"),
            }
        };
        assert!(floor.iter().all(|&r| (0.0..=1.0).contains(&r)));
        // A budget below weights+activations is reported as infeasible.
        let oom = mem.clone().scaled_capacity(1e-4);
        assert!(matches!(
            oom.required_ratios(&inflight),
            Err(MemoryError::OverCapacity { .. })
        ));
    }

    #[test]
    fn floor_is_monotone_in_capacity() {
        let (cfg, mem) = model_1b();
        let s = Schedule::build(ScheduleKind::GPipe, 4, cfg.microbatches, 1);
        let inflight = peak_inflight(&s);
        let mut prev = vec![1.0f64; 4];
        for frac in [0.02, 0.05, 0.1, 0.2, 0.5, 1.0] {
            let m = mem.clone().scaled_capacity(frac);
            if let Ok(floor) = m.required_ratios(&inflight) {
                for (a, b) in floor.iter().zip(&prev) {
                    assert!(a <= b, "floor must shrink as capacity grows");
                }
                prev = floor;
            }
        }
        assert!(prev.iter().all(|&r| r == 0.0));
    }

    #[test]
    fn rank_capacities_map_through_stage_placement() {
        let (cfg, mem) = model_1b();
        // 4 ranks, 1 chunk: stage s lives on rank s. Rank 2 is a small
        // card; only its stage's capacity shrinks.
        let caps = [48e9, 48e9, 24e9, 48e9];
        let m = mem.clone().with_rank_capacities(&caps, &[0, 1, 2, 3], 1);
        assert_eq!(m.capacity_bytes, vec![48e9, 48e9, 24e9, 48e9]);
        // Two chunks per rank split each card across its stages (ZBV's
        // V placement: rank r hosts stages r and 2R−1−r).
        let caps2 = [48e9, 24e9];
        let m = MemoryModel {
            weight_bytes: vec![1.0; 4],
            act_bytes_per_mb: vec![1.0; 4],
            train_state_bytes: vec![7.0; 4],
            capacity_bytes: vec![0.0; 4],
        }
        .with_rank_capacities(&caps2, &[0, 1, 1, 0], 2);
        assert_eq!(m.capacity_bytes, vec![24e9, 12e9, 12e9, 24e9]);
        let _ = cfg;
    }

    #[test]
    fn hetero_floor_binds_only_on_the_small_card() {
        let (cfg, mem) = model_1b();
        let s = Schedule::build(ScheduleKind::OneFOneB, 4, cfg.microbatches, 1);
        let inflight = peak_inflight(&s);
        // Uniform capacity that needs no freezing…
        let uniform = mem.clone().required_ratios(&inflight).unwrap();
        assert!(uniform.iter().all(|&r| r == 0.0));
        // …then shrink one rank until its stage (and only its stage)
        // needs a floor.
        let mut small = cfg.gpu.memory_bytes;
        loop {
            small *= 0.8;
            let caps = [cfg.gpu.memory_bytes, cfg.gpu.memory_bytes, small, cfg.gpu.memory_bytes];
            match mem
                .clone()
                .with_rank_capacities(&caps, &s.rank_of_stage, 1)
                .required_ratios(&inflight)
            {
                Ok(floor) if floor[2] > 0.0 => {
                    assert_eq!(floor[0], 0.0);
                    assert_eq!(floor[1], 0.0);
                    assert_eq!(floor[3], 0.0);
                    break;
                }
                Ok(_) => continue,
                Err(e) => panic!("walked past feasibility: {e}"),
            }
        }
    }

    #[test]
    fn stage_floor_for_threads_rank_capacities() {
        let (mut cfg, mem) = model_1b();
        let s = Schedule::build(ScheduleKind::OneFOneB, 4, cfg.microbatches, 1);
        let layer_stage = balanced_partition(&cfg.model.layer_params(), 4);
        // A budget fraction that is floor-free on uniform cards…
        cfg.memory_budget = Some(1.0);
        let uniform = stage_floor_for(&cfg, &layer_stage, &s).unwrap().unwrap();
        assert!(uniform.iter().all(|&r| r == 0.0));
        // …but binds once rank 1 is a much smaller card. Probe for a
        // size that is binding-but-feasible under r_max.
        let mut small = cfg.gpu.memory_bytes;
        let floor = loop {
            small *= 0.9;
            cfg.rank_memory_bytes = Some(vec![
                cfg.gpu.memory_bytes,
                small,
                cfg.gpu.memory_bytes,
                cfg.gpu.memory_bytes,
            ]);
            match stage_floor_for(&cfg, &layer_stage, &s) {
                Ok(Some(f)) if f[1] > 0.0 => break f,
                Ok(_) => continue,
                Err(e) => panic!("probe overshot: {e}"),
            }
        };
        assert!(floor[1] > 0.0 && floor[0] == 0.0 && floor[2] == 0.0);
        // A capacity vector of the wrong arity is a clean error…
        cfg.rank_memory_bytes = Some(vec![48e9, 48e9]);
        assert!(stage_floor_for(&cfg, &layer_stage, &s).is_err());
        // …and so are rank capacities without an active budget (they
        // would otherwise be silently ignored).
        cfg.memory_budget = None;
        cfg.rank_memory_bytes = Some(vec![48e9; 4]);
        assert!(stage_floor_for(&cfg, &layer_stage, &s).is_err());
        let _ = mem;
    }

    #[test]
    fn peak_inflight_matches_schedule_theory() {
        // GPipe: every forward of the batch is in flight before the
        // first backward → peak M at every stage.
        let s = Schedule::build(ScheduleKind::GPipe, 4, 8, 1);
        assert_eq!(peak_inflight(&s), vec![8, 8, 8, 8]);
        // 1F1B: stage s admits min(M, ranks − s) in-flight microbatches.
        let s = Schedule::build(ScheduleKind::OneFOneB, 4, 8, 1);
        assert_eq!(peak_inflight(&s), vec![4, 3, 2, 1]);
        // ZBV: bounded by M, at least 1, defined for every stage.
        let s = Schedule::build(ScheduleKind::ZeroBubbleV, 4, 8, 2);
        let p = peak_inflight(&s);
        assert_eq!(p.len(), 8);
        assert!(p.iter().all(|&x| (1..=8).contains(&x)), "{p:?}");
    }

    #[test]
    fn stage_bytes_linear_in_ratio() {
        let (_, mem) = model_1b();
        let lo = mem.stage_bytes(0, 4, 1.0);
        let hi = mem.stage_bytes(0, 4, 0.0);
        let mid = mem.stage_bytes(0, 4, 0.5);
        assert!(hi > lo);
        assert!((mid - (lo + hi) / 2.0).abs() < 1.0);
    }
}
