//! First-class cost subsystem: execution-time and memory models feeding
//! the pipeline DAG, the freeze LP, and the discrete-event simulator.
//!
//! Three concerns live here, one per submodule:
//!
//! * [`model`] — [`CostModel`]: per-stage heterogeneous forward /
//!   backward (dgrad + wgrad) / optimizer times, per-stage node-charged
//!   communication, and P2P link costs for cross-rank DAG edges. The
//!   analytic constructor ([`CostModel::new`]) derives stage times from a
//!   model × GPU preset exactly as the pre-refactor `sim::cost` did —
//!   the uniform path is bit-identical (guarded by
//!   `tests/cost_model.rs`).
//! * [`profile`] — [`CostProfile`]: hand-specified stage-shape presets
//!   (uniform, skewed first/last stage, profiled-from-table) for
//!   heterogeneous-cluster studies that have no preset hardware model,
//!   plus [`ProfileRecorder`], which captures *observed* per-stage
//!   action times from the event-driven executor into a profiled table
//!   for online replanning.
//! * [`memory`] — [`MemoryModel`] and [`peak_inflight`]: per-stage
//!   activation / weight / trainable-state byte accounting against a
//!   device capacity, producing the per-stage *freeze-ratio floor* the
//!   LP consumes as constraint [5] (freezing chosen to fit a memory
//!   budget, not only to cut batch time), plus [`RecomputePolicy`] —
//!   activation recomputation as the alternative way to buy memory
//!   back, paying a per-stage forward-time surcharge instead of forced
//!   freezing ([`memory_plan_for`] resolves both knobs at once).
//! * [`rank`] — [`upward_ranks`]: HEFT-style critical-path (bottom-level)
//!   queries over the structural action DAG under any duration function;
//!   the priority tables the schedule synthesizer ranks candidates with.
//!
//! The split matters for the regimes "Pipeline Parallelism with
//! Controllable Memory" (Qi et al., 2024) and "OptPipe" (Li et al.,
//! 2025) study: once stages are heterogeneous or memory-tight, schedules
//! and freeze plans genuinely differ, and a flat per-action scalar model
//! cannot see it.

pub mod memory;
pub mod model;
pub mod profile;
pub mod rank;

pub use memory::{
    memory_plan_for, memory_plan_for_fleet, peak_inflight, stage_floor_for, MemoryError,
    MemoryModel, MemoryPlan, RecomputePolicy,
};
pub use model::CostModel;
pub use profile::{CostProfile, ProfileRecorder, StageProfile};
pub use rank::{quantize_ranks, upward_ranks};
