//! The per-stage execution-time model: action duration bounds
//! `[w_min, w_max]` for a model × GPU × partition (or a hand-written
//! [`CostProfile`](crate::cost::CostProfile)), feeding the discrete-event
//! simulator and the freeze LP.
//!
//! The decomposition follows Figure 3: forward time is freeze-invariant;
//! backward time splits into the activation-gradient part ("B",
//! irreducible) and the parameter-gradient part ("W", scaling with
//! 1 − freeze-ratio). Inter-stage communication is charged either to the
//! receiving action (`comm`, the analytic preset path) or to the DAG edge
//! that crosses ranks (`p2p` link costs, consumed via
//! [`PipelineDag::p2p_edge_costs`](crate::graph::pipeline::PipelineDag::p2p_edge_costs)).

use crate::config::{GpuPreset, ModelPreset};
use crate::cost::memory::MemoryModel;
use crate::types::{Action, ActionKind};

/// Cost model for one experiment configuration: per-stage action
/// durations, communication, and (optionally) memory accounting.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Number of virtual pipeline stages this model covers.
    pub stages: usize,
    /// Forward seconds per stage (freeze-invariant).
    fwd: Vec<f64>,
    /// Activation-gradient ("B") seconds per stage (freeze-invariant).
    dgrad: Vec<f64>,
    /// Parameter-gradient ("W") seconds per stage (removed by freezing).
    wgrad: Vec<f64>,
    /// Optimizer-step seconds per stage, charged once per batch as a
    /// tail barrier (zero for the analytic presets).
    optimizer: Vec<f64>,
    /// Node-charged communication seconds per stage (every action at
    /// stage `s` pays `comm[s]` — the analytic preset convention).
    comm: Vec<f64>,
    /// Fixed per-action overhead (kernel launch + sync), seconds.
    overhead: f64,
    /// P2P link cost between adjacent stages: `p2p[s]` is the seconds to
    /// cross the `s ↔ s+1` boundary in either direction (activations
    /// down, gradients back up). Empty ⇒ no edge-charged communication.
    p2p: Vec<f64>,
    /// Per-stage activation-recompute fractions `ρ_s`: every
    /// stash-consuming backward action at stage `s` (fused `Backward`,
    /// or the Zero-Bubble `BackwardDgrad`) re-runs `ρ_s` of the stage's
    /// forward, adding a `ρ_s · fwd_s` surcharge to both duration
    /// bounds. Empty ⇒ no recomputation — the surcharge-free paths are
    /// untouched.
    recompute: Vec<f64>,
    /// Optional per-stage memory accounting (activation / weight /
    /// trainable-state bytes against a capacity).
    memory: Option<MemoryModel>,
}

impl CostModel {
    /// Build from a model preset, a GPU preset, and a layer→virtual-stage
    /// assignment (`layer_stage[l] ∈ 0..stages`).
    ///
    /// This is the pre-refactor `sim::cost::CostModel::new` path, kept
    /// bit-identical: communication is node-charged (uniform per stage),
    /// `p2p` is empty, optimizer time is zero, and no memory model is
    /// attached (add one with [`CostModel::with_memory`]).
    pub fn new(
        model: &ModelPreset,
        gpu: &GpuPreset,
        layer_stage: &[usize],
        stages: usize,
        microbatch_size: usize,
        seq_len: usize,
    ) -> CostModel {
        assert_eq!(layer_stage.len(), model.num_layers());
        let tokens = (microbatch_size * seq_len) as f64;
        let mut fwd_flops = vec![0.0f64; stages];
        let mut dgrad_flops = vec![0.0f64; stages];
        let mut wgrad_flops = vec![0.0f64; stages];
        for (l, &s) in layer_stage.iter().enumerate() {
            fwd_flops[s] += model.layer_fwd_flops(l, tokens, seq_len);
            dgrad_flops[s] += model.layer_dgrad_flops(l, tokens, seq_len);
            wgrad_flops[s] += model.layer_wgrad_flops(l, tokens);
        }
        let c = gpu.compute_rate * model.compute_efficiency;
        let comm = model.boundary_bytes(microbatch_size, seq_len) / gpu.link_bandwidth;
        CostModel {
            stages,
            fwd: fwd_flops.iter().map(|f| f / c).collect(),
            dgrad: dgrad_flops.iter().map(|f| f / c).collect(),
            wgrad: wgrad_flops.iter().map(|f| f / c).collect(),
            optimizer: vec![0.0; stages],
            comm: vec![comm; stages],
            overhead: gpu.overhead,
            p2p: Vec::new(),
            recompute: Vec::new(),
            memory: None,
        }
    }

    /// Build directly from per-stage components. `p2p` must be empty or
    /// hold `stages − 1` boundary costs; the other vectors must have one
    /// entry per stage.
    pub fn from_stage_times(
        fwd: Vec<f64>,
        dgrad: Vec<f64>,
        wgrad: Vec<f64>,
        optimizer: Vec<f64>,
        comm: Vec<f64>,
        overhead: f64,
        p2p: Vec<f64>,
    ) -> CostModel {
        let stages = fwd.len();
        assert!(stages > 0, "need at least one stage");
        assert_eq!(dgrad.len(), stages, "dgrad length mismatch");
        assert_eq!(wgrad.len(), stages, "wgrad length mismatch");
        assert_eq!(optimizer.len(), stages, "optimizer length mismatch");
        assert_eq!(comm.len(), stages, "comm length mismatch");
        assert!(
            p2p.is_empty() || p2p.len() == stages - 1,
            "p2p must cover the {} stage boundaries, got {}",
            stages - 1,
            p2p.len()
        );
        for v in fwd
            .iter()
            .chain(&dgrad)
            .chain(&wgrad)
            .chain(&optimizer)
            .chain(&comm)
            .chain(&p2p)
            .chain(std::iter::once(&overhead))
        {
            assert!(v.is_finite() && *v >= 0.0, "cost entries must be finite and ≥ 0");
        }
        CostModel {
            stages,
            fwd,
            dgrad,
            wgrad,
            optimizer,
            comm,
            overhead,
            p2p,
            recompute: Vec::new(),
            memory: None,
        }
    }

    /// Attach per-stage activation-recompute fractions `ρ_s ∈ [0, 1]`
    /// (typically from
    /// [`memory_plan_for`](crate::cost::memory_plan_for)): every
    /// stash-consuming backward action at stage `s` gains a
    /// `ρ_s · fwd_s` duration surcharge — the forward re-run that
    /// regenerates the activations the stage chose not to stash. The
    /// surcharge is freeze-invariant (added to both bounds), so freeze
    /// ratios and their linearization are unchanged.
    pub fn with_recompute_fractions(mut self, rho: &[f64]) -> CostModel {
        assert_eq!(rho.len(), self.stages, "recompute fraction length mismatch");
        assert!(
            rho.iter().all(|r| r.is_finite() && (0.0..=1.0).contains(r)),
            "recompute fractions must be in [0, 1]"
        );
        self.recompute = rho.to_vec();
        self
    }

    /// The attached per-stage recompute fractions, if any.
    pub fn recompute_fractions(&self) -> Option<&[f64]> {
        (!self.recompute.is_empty()).then_some(self.recompute.as_slice())
    }

    /// Per-stage recompute surcharge seconds for fractions `rho`:
    /// `ρ_s × fwd_s`. This is the vector
    /// [`FreezeLpInput::with_recompute`](crate::lp::FreezeLpInput::with_recompute)
    /// consumes; callers that bake the fractions into the model instead
    /// ([`CostModel::with_recompute_fractions`]) get bit-identical
    /// bounds, because both paths append the same product as the last
    /// addend.
    pub fn recompute_surcharges_for(&self, rho: &[f64]) -> Vec<f64> {
        assert_eq!(rho.len(), self.stages, "recompute fraction length mismatch");
        rho.iter().zip(&self.fwd).map(|(r, f)| r * f).collect()
    }

    /// The baked-in per-stage surcharge vector (`ρ_s × fwd_s`), when
    /// fractions are attached.
    pub fn recompute_surcharges(&self) -> Option<Vec<f64>> {
        self.recompute_fractions().map(|rho| self.recompute_surcharges_for(rho))
    }

    /// Recompute surcharge seconds of one stage (0 with no fractions
    /// attached).
    fn recompute_surcharge(&self, s: usize) -> f64 {
        if self.recompute.is_empty() {
            0.0
        } else {
            self.recompute[s] * self.fwd[s]
        }
    }

    /// Re-charge communication to the network fabric: replace the
    /// node-charged `comm` with per-boundary `p2p` link costs (typically
    /// [`NetworkModel::expected_seconds`](crate::net::NetworkModel::expected_seconds)
    /// under the topology's steady-state link loads). Every action loses
    /// its `comm[s]` share and the DAG edges crossing the `s ↔ s+1`
    /// boundaries gain `p2p[s]` instead; the compute decomposition is
    /// untouched. `p2p` must hold `stages − 1` boundary costs.
    pub fn with_network_comm(mut self, p2p: Vec<f64>) -> CostModel {
        assert_eq!(
            p2p.len(),
            self.stages - 1,
            "p2p must cover the {} stage boundaries",
            self.stages - 1
        );
        assert!(
            p2p.iter().all(|c| c.is_finite() && *c >= 0.0),
            "p2p entries must be finite and ≥ 0"
        );
        self.comm = vec![0.0; self.stages];
        self.p2p = p2p;
        self
    }

    /// Attach per-stage memory accounting (consumed by
    /// [`MemoryModel::required_ratios`] and the fig16 bench).
    pub fn with_memory(mut self, memory: MemoryModel) -> CostModel {
        assert_eq!(memory.num_stages(), self.stages, "memory model stage count mismatch");
        self.memory = Some(memory);
        self
    }

    /// The attached memory model, if any.
    pub fn memory(&self) -> Option<&MemoryModel> {
        self.memory.as_ref()
    }

    /// Duration bounds (w_min, w_max) of an action — eq. 3 with Figure 3's
    /// decomposition. With recompute fractions attached, the
    /// stash-consuming backward kinds (`Backward`, `BackwardDgrad`)
    /// additionally carry the stage's `ρ_s · fwd_s` forward re-run,
    /// appended as the **last** addend to both bounds so the result is
    /// bit-identical to handing the surcharge-free bounds plus the same
    /// vector to
    /// [`FreezeLpInput::with_recompute`](crate::lp::FreezeLpInput::with_recompute).
    pub fn bounds(&self, a: Action) -> (f64, f64) {
        let s = a.stage;
        assert!(s < self.stages, "stage {s} out of range");
        match a.kind {
            ActionKind::Forward => {
                let w = self.fwd[s] + self.overhead + self.comm[s];
                (w, w)
            }
            ActionKind::Backward => {
                let lo = self.dgrad[s] + self.overhead + self.comm[s];
                let hi = lo + self.wgrad[s];
                if self.recompute.is_empty() {
                    (lo, hi)
                } else {
                    let sur = self.recompute_surcharge(s);
                    (lo + sur, hi + sur)
                }
            }
            ActionKind::BackwardDgrad => {
                let w = self.dgrad[s] + self.overhead + self.comm[s];
                if self.recompute.is_empty() {
                    (w, w)
                } else {
                    let sur = self.recompute_surcharge(s);
                    (w + sur, w + sur)
                }
            }
            ActionKind::BackwardWgrad => {
                let lo = self.overhead;
                (lo, lo + self.wgrad[s])
            }
        }
    }

    /// Duration at a given actual freeze ratio (linear interpolation —
    /// eq. 4 inverted, verified empirically in Appendix I / Figure 15).
    pub fn duration(&self, a: Action, afr: f64) -> f64 {
        let (lo, hi) = self.bounds(a);
        hi - afr.clamp(0.0, 1.0) * (hi - lo)
    }

    /// P2P cost of a DAG edge from `from_stage` to `to_stage`: the link
    /// cost of the boundary between adjacent stages, zero otherwise (and
    /// zero when no P2P costs are configured). Callers that know rank
    /// placement should suppress same-rank crossings — see
    /// [`PipelineDag::p2p_edge_costs`](crate::graph::pipeline::PipelineDag::p2p_edge_costs).
    pub fn p2p(&self, from_stage: usize, to_stage: usize) -> f64 {
        if self.p2p.is_empty() {
            return 0.0;
        }
        let boundary = if to_stage == from_stage + 1 {
            from_stage
        } else if from_stage == to_stage + 1 {
            to_stage
        } else {
            return 0.0;
        };
        self.p2p.get(boundary).copied().unwrap_or(0.0)
    }

    /// Whether any P2P link costs are configured (i.e. communication is
    /// edge-charged rather than node-charged).
    pub fn has_p2p(&self) -> bool {
        self.p2p.iter().any(|&c| c > 0.0)
    }

    /// Optimizer-step barrier added once per batch: the slowest stage's
    /// optimizer time (stages step in parallel after the last backward).
    /// Zero for the analytic presets.
    pub fn optimizer_tail(&self) -> f64 {
        self.optimizer.iter().cloned().fold(0.0f64, f64::max)
    }

    /// Node-charged communication seconds of one stage (every action at
    /// the stage pays this; zero for edge-charged profiles). The
    /// simulator's link-slowdown dynamics scale exactly this share of an
    /// action's duration.
    pub fn stage_comm(&self, s: usize) -> f64 {
        self.comm[s]
    }

    /// Forward seconds of one stage (freeze-invariant).
    pub fn stage_fwd(&self, s: usize) -> f64 {
        self.fwd[s]
    }

    /// Activation-gradient seconds of one stage (freeze-invariant).
    pub fn stage_dgrad(&self, s: usize) -> f64 {
        self.dgrad[s]
    }

    /// Parameter-gradient seconds of one stage (removed by freezing).
    pub fn stage_wgrad(&self, s: usize) -> f64 {
        self.wgrad[s]
    }

    /// Total *nominal* model FLOPs per token (2 fwd + 4 bwd per param) —
    /// the MFU numerator convention.
    pub fn nominal_flops_per_token(model: &ModelPreset) -> f64 {
        6.0 * model.total_params()
    }

    /// Per-layer forward+backward seconds (used by the time-based
    /// partition heuristic).
    pub fn layer_times(
        model: &ModelPreset,
        gpu: &GpuPreset,
        microbatch_size: usize,
        seq_len: usize,
    ) -> Vec<f64> {
        let tokens = (microbatch_size * seq_len) as f64;
        (0..model.num_layers())
            .map(|l| {
                (model.layer_fwd_flops(l, tokens, seq_len)
                    + model.layer_dgrad_flops(l, tokens, seq_len)
                    + model.layer_wgrad_flops(l, tokens))
                    / (gpu.compute_rate * model.compute_efficiency)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::partition::balanced_partition;

    fn model_8b() -> (ModelPreset, GpuPreset, CostModel) {
        let cfg = ExperimentConfig::paper_preset("llama-8b").unwrap();
        let layer_stage = balanced_partition(&cfg.model.layer_params(), 4);
        let cm = CostModel::new(&cfg.model, &cfg.gpu, &layer_stage, 4, cfg.microbatch_size, cfg.seq_len);
        (cfg.model, cfg.gpu, cm)
    }

    #[test]
    fn forward_bounds_are_fixed() {
        let (_, _, cm) = model_8b();
        let (lo, hi) = cm.bounds(Action::f(0, 1));
        assert_eq!(lo, hi);
        assert!(lo > 0.0);
    }

    #[test]
    fn backward_bounds_straddle_wgrad() {
        let (_, _, cm) = model_8b();
        let (lo, hi) = cm.bounds(Action::b(0, 1));
        assert!(hi > lo, "wgrad must be freezable");
        // Full freeze removes roughly half the backward (dgrad ≈ fwd,
        // wgrad ≈ slightly less than fwd).
        let ratio = lo / hi;
        assert!((0.35..0.75).contains(&ratio), "dgrad share {ratio}");
    }

    #[test]
    fn duration_interpolates_linearly() {
        let (_, _, cm) = model_8b();
        let a = Action::b(0, 2);
        let (lo, hi) = cm.bounds(a);
        assert_eq!(cm.duration(a, 0.0), hi);
        assert_eq!(cm.duration(a, 1.0), lo);
        let mid = cm.duration(a, 0.5);
        assert!((mid - (lo + hi) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn wgrad_action_nearly_free_when_frozen() {
        let (_, _, cm) = model_8b();
        let (lo, hi) = cm.bounds(Action::bw(0, 0));
        assert!(lo < hi * 0.05, "frozen W should be ≈ overhead only");
    }

    #[test]
    fn step_time_in_plausible_range_for_8b() {
        // Sanity: GPipe batch time for 8B on 4×H200 should be O(seconds)
        // (paper: 65536 tokens / 5737 tok/s ≈ 11 s per step).
        use crate::graph::pipeline::PipelineDag;
        use crate::schedule::Schedule;
        use crate::types::ScheduleKind;
        let (_, _, cm) = model_8b();
        let s = Schedule::build(ScheduleKind::GPipe, 4, 8, 1);
        let g = PipelineDag::from_schedule(&s);
        let w = g.weights(|a| cm.bounds(a).1);
        let t = g.batch_time(&w);
        assert!((2.0..40.0).contains(&t), "step time {t}s implausible");
    }

    #[test]
    fn layer_times_positive_and_sized() {
        let cfg = ExperimentConfig::paper_preset("convnextv2-l").unwrap();
        let times = CostModel::layer_times(&cfg.model, &cfg.gpu, cfg.microbatch_size, cfg.seq_len);
        assert_eq!(times.len(), cfg.model.num_layers());
        assert!(times.iter().all(|&t| t > 0.0));
        // ConvNeXt skew shows up in time too.
        let max = times.iter().cloned().fold(0.0f64, f64::max);
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 10.0);
    }

    #[test]
    fn analytic_model_has_no_p2p_or_optimizer_tail() {
        let (_, _, cm) = model_8b();
        assert!(!cm.has_p2p());
        assert_eq!(cm.p2p(0, 1), 0.0);
        assert_eq!(cm.optimizer_tail(), 0.0);
    }

    #[test]
    fn from_stage_times_p2p_lookup() {
        let cm = CostModel::from_stage_times(
            vec![1.0, 2.0, 3.0],
            vec![1.0, 2.0, 3.0],
            vec![0.5, 1.0, 1.5],
            vec![0.1, 0.3, 0.2],
            vec![0.0; 3],
            0.0,
            vec![0.25, 0.75],
        );
        assert_eq!(cm.p2p(0, 1), 0.25);
        assert_eq!(cm.p2p(1, 0), 0.25);
        assert_eq!(cm.p2p(2, 1), 0.75);
        assert_eq!(cm.p2p(0, 2), 0.0, "non-adjacent stages share no link");
        assert!(cm.has_p2p());
        assert_eq!(cm.optimizer_tail(), 0.3);
        let (lo, hi) = cm.bounds(Action::b(0, 2));
        assert_eq!(lo, 3.0);
        assert_eq!(hi, 4.5);
    }

    #[test]
    fn recompute_surcharge_is_freeze_invariant_and_bit_stable() {
        let (_, _, cm) = model_8b();
        let rho = [0.0, 0.5, 1.0, 0.25];
        let rc = cm.clone().with_recompute_fractions(&rho);
        let sur = cm.recompute_surcharges_for(&rho);
        for s in 0..4 {
            assert_eq!(sur[s], rho[s] * cm.stage_fwd(s));
            // Backward and dgrad bounds grow by exactly the surcharge,
            // appended last — bit-identical to the LP-side path.
            let (lo, hi) = cm.bounds(Action::b(0, s));
            let (rlo, rhi) = rc.bounds(Action::b(0, s));
            assert_eq!(rlo.to_bits(), (lo + sur[s]).to_bits());
            assert_eq!(rhi.to_bits(), (hi + sur[s]).to_bits());
            let (dlo, dhi) = cm.bounds(Action::bd(0, s));
            let (rdlo, rdhi) = rc.bounds(Action::bd(0, s));
            assert_eq!(rdlo.to_bits(), (dlo + sur[s]).to_bits());
            assert_eq!(rdhi.to_bits(), (dhi + sur[s]).to_bits());
            // Forward and wgrad are untouched; the freezable range is
            // invariant, so freeze-ratio linearization is unchanged.
            assert_eq!(rc.bounds(Action::f(0, s)), cm.bounds(Action::f(0, s)));
            assert_eq!(rc.bounds(Action::bw(0, s)), cm.bounds(Action::bw(0, s)));
            assert_eq!((rhi - rlo).to_bits(), (hi - lo).to_bits());
        }
        assert_eq!(rc.recompute_fractions(), Some(&rho[..]));
        assert_eq!(rc.recompute_surcharges(), Some(sur));
        assert!(cm.recompute_fractions().is_none());
        assert!(cm.recompute_surcharges().is_none());
        // All-zero fractions leave every bound bit-identical.
        let zero = cm.clone().with_recompute_fractions(&[0.0; 4]);
        for s in 0..4 {
            for a in [Action::f(0, s), Action::b(0, s), Action::bw(0, s)] {
                let (lo, hi) = cm.bounds(a);
                let (zlo, zhi) = zero.bounds(a);
                assert_eq!(lo.to_bits(), zlo.to_bits());
                assert_eq!(hi.to_bits(), zhi.to_bits());
            }
        }
    }

    #[test]
    fn network_comm_moves_charge_from_nodes_to_edges() {
        let (_, _, cm) = model_8b();
        assert!(!cm.has_p2p());
        let before = cm.bounds(Action::f(0, 1));
        let comm = cm.stage_comm(1);
        assert!(comm > 0.0, "analytic preset charges nodes");
        let net = cm.clone().with_network_comm(vec![0.25, 0.5, 0.75]);
        // Nodes no longer pay communication…
        assert_eq!(net.stage_comm(1), 0.0);
        let after = net.bounds(Action::f(0, 1));
        assert!((after.0 - (before.0 - comm)).abs() < 1e-12);
        // …the boundaries do.
        assert!(net.has_p2p());
        assert_eq!(net.p2p(0, 1), 0.25);
        assert_eq!(net.p2p(2, 1), 0.5);
        assert_eq!(net.p2p(3, 2), 0.75);
        // Compute decomposition untouched.
        assert_eq!(net.stage_fwd(2), cm.stage_fwd(2));
        assert_eq!(net.stage_wgrad(2), cm.stage_wgrad(2));
    }

    #[test]
    #[should_panic]
    fn network_comm_rejects_bad_boundary_count() {
        let (_, _, cm) = model_8b();
        let _ = cm.with_network_comm(vec![0.1, 0.2]); // 4 stages ⇒ 3 boundaries
    }

    #[test]
    #[should_panic]
    fn from_stage_times_rejects_bad_p2p_len() {
        CostModel::from_stage_times(
            vec![1.0, 1.0],
            vec![1.0, 1.0],
            vec![1.0, 1.0],
            vec![0.0, 0.0],
            vec![0.0, 0.0],
            0.0,
            vec![0.1, 0.2], // should be 1 boundary
        );
    }
}
