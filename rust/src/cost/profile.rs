//! Hand-specified stage-shape presets for heterogeneity studies, and
//! the observed-profile capture that closes the planning loop.
//!
//! The analytic [`CostModel::new`](crate::cost::CostModel::new) path
//! derives stage times from a hardware preset; a [`CostProfile`] instead
//! states the shape directly — uniform stages, a skewed first or last
//! stage (embedding/head imbalance, a straggler device), or a fully
//! profiled per-stage table (e.g. transcribed from a cluster profiler).
//! [`CostProfile::to_model`] lowers any profile to a [`CostModel`].
//!
//! [`ProfileRecorder`] is the capture side: the discrete-event runner
//! feeds it every executed action's `(kind, stage, freeze ratio,
//! observed seconds)` and it distills a `CostProfile::Profiled` table —
//! the per-stage world the execution *actually* exhibited, stragglers
//! and all — which `TimelyFreeze::replan_with_profile` re-solves the LP
//! against at phase boundaries.

use crate::cost::CostModel;
use crate::types::{Action, ActionKind};

/// One row of a profiled-from-table cost specification: the measured
/// per-microbatch seconds of a single pipeline stage.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StageProfile {
    /// Forward seconds (freeze-invariant).
    pub fwd: f64,
    /// Activation-gradient ("B") seconds (freeze-invariant).
    pub dgrad: f64,
    /// Parameter-gradient ("W") seconds (removed by freezing).
    pub wgrad: f64,
    /// Optimizer-step seconds, charged once per batch as a tail barrier.
    pub optimizer: f64,
    /// P2P cost of the link to the *next* stage (activations down,
    /// gradients back up). Ignored for the last stage.
    pub link: f64,
}

impl StageProfile {
    /// A compute-only row: no optimizer tail, no link cost.
    pub fn compute(fwd: f64, dgrad: f64, wgrad: f64) -> StageProfile {
        StageProfile { fwd, dgrad, wgrad, optimizer: 0.0, link: 0.0 }
    }
}

/// A stage-shape preset, lowered to a [`CostModel`] by
/// [`CostProfile::to_model`].
#[derive(Clone, Debug, PartialEq)]
pub enum CostProfile {
    /// Every stage identical — the PR 1 flat-scalar setting. With
    /// `link == 0` the resulting model reproduces flat per-action
    /// weights bit-for-bit (guarded by `tests/cost_model.rs`).
    Uniform {
        /// Forward seconds per stage.
        fwd: f64,
        /// Activation-gradient seconds per stage.
        dgrad: f64,
        /// Parameter-gradient seconds per stage.
        wgrad: f64,
        /// P2P cost of every stage boundary.
        link: f64,
    },
    /// Uniform except one end of the pipeline, whose compute entries are
    /// multiplied by `skew` — the embedding-heavy first stage or the
    /// head/loss-heavy last stage of real partitions, or a straggler
    /// device in a heterogeneous cluster.
    Skewed {
        /// Forward seconds of a regular stage.
        fwd: f64,
        /// Activation-gradient seconds of a regular stage.
        dgrad: f64,
        /// Parameter-gradient seconds of a regular stage.
        wgrad: f64,
        /// P2P cost of every stage boundary.
        link: f64,
        /// Multiplier applied to the skewed stage's fwd/dgrad/wgrad.
        skew: f64,
        /// `false` ⇒ the first stage is skewed; `true` ⇒ the last.
        last: bool,
    },
    /// Fully profiled per-stage table. `to_model` requires exactly one
    /// row per stage.
    Profiled(
        /// Measured per-stage rows, stage 0 first.
        Vec<StageProfile>,
    ),
}

impl CostProfile {
    /// Uniform stages with the given per-action seconds and boundary
    /// link cost.
    pub fn uniform(fwd: f64, dgrad: f64, wgrad: f64, link: f64) -> CostProfile {
        CostProfile::Uniform { fwd, dgrad, wgrad, link }
    }

    /// Uniform stages with stage 0's compute scaled by `skew`.
    pub fn skewed_first(fwd: f64, dgrad: f64, wgrad: f64, link: f64, skew: f64) -> CostProfile {
        CostProfile::Skewed { fwd, dgrad, wgrad, link, skew, last: false }
    }

    /// Uniform stages with the last stage's compute scaled by `skew`.
    pub fn skewed_last(fwd: f64, dgrad: f64, wgrad: f64, link: f64, skew: f64) -> CostProfile {
        CostProfile::Skewed { fwd, dgrad, wgrad, link, skew, last: true }
    }

    /// A profiled-from-table specification (one row per stage).
    pub fn profiled(rows: Vec<StageProfile>) -> CostProfile {
        CostProfile::Profiled(rows)
    }

    /// Lower this profile to a [`CostModel`] over `stages` stages.
    /// Profiles carry no kernel-launch overhead and no node-charged
    /// communication: all transfer cost is on the P2P links, so DAG
    /// weights are pure compute and edges carry the wire time.
    ///
    /// Panics if `stages == 0` or a profiled table's row count does not
    /// match `stages`.
    pub fn to_model(&self, stages: usize) -> CostModel {
        assert!(stages > 0, "need at least one stage");
        let rows: Vec<StageProfile> = match self {
            CostProfile::Uniform { fwd, dgrad, wgrad, link } => (0..stages)
                .map(|_| StageProfile {
                    fwd: *fwd,
                    dgrad: *dgrad,
                    wgrad: *wgrad,
                    optimizer: 0.0,
                    link: *link,
                })
                .collect(),
            CostProfile::Skewed { fwd, dgrad, wgrad, link, skew, last } => (0..stages)
                .map(|s| {
                    let hot = if *last { s + 1 == stages } else { s == 0 };
                    let m = if hot { *skew } else { 1.0 };
                    StageProfile {
                        fwd: fwd * m,
                        dgrad: dgrad * m,
                        wgrad: wgrad * m,
                        optimizer: 0.0,
                        link: *link,
                    }
                })
                .collect(),
            CostProfile::Profiled(rows) => {
                assert_eq!(
                    rows.len(),
                    stages,
                    "profiled table has {} rows for {} stages",
                    rows.len(),
                    stages
                );
                rows.clone()
            }
        };
        let p2p: Vec<f64> = rows.iter().take(stages - 1).map(|r| r.link).collect();
        CostModel::from_stage_times(
            rows.iter().map(|r| r.fwd).collect(),
            rows.iter().map(|r| r.dgrad).collect(),
            rows.iter().map(|r| r.wgrad).collect(),
            rows.iter().map(|r| r.optimizer).collect(),
            vec![0.0; stages],
            0.0,
            if p2p.iter().any(|&c| c > 0.0) { p2p } else { Vec::new() },
        )
    }
}

/// Per-stage accumulator of one stage's freezable-action samples:
/// running sums for the OLS fit of `duration = c₀ + c₁·afr`.
#[derive(Clone, Copy, Debug, Default)]
struct FreezableFit {
    kind: Option<ActionKind>,
    n: f64,
    sx: f64,
    sy: f64,
    sxx: f64,
    sxy: f64,
}

impl FreezableFit {
    fn push(&mut self, kind: ActionKind, afr: f64, duration: f64) {
        debug_assert!(
            self.kind.is_none() || self.kind == Some(kind),
            "a stage schedules one freezable kind, never both"
        );
        self.kind = Some(kind);
        self.n += 1.0;
        self.sx += afr;
        self.sy += duration;
        self.sxx += afr * afr;
        self.sxy += afr * duration;
    }

    /// `(duration at afr = 0, freezable share)` — by OLS when the
    /// window saw enough freeze-ratio spread to identify the slope,
    /// otherwise by scaling `prior`'s decomposition to the observed
    /// mean (exact for the multiplicative perturbations the scenarios
    /// inject: a straggler slows dgrad and wgrad alike).
    ///
    /// The estimate is hardened against degenerate windows: the split is
    /// always clamped non-negative and ordered (`0 ≤ wgrad ≤ hi`), a fit
    /// whose slope comes out positive (duration *growing* with freezing
    /// — unphysical, i.e. noise-dominated) falls back to the prior
    /// scaling, and a window poisoned by non-finite observations falls
    /// back to the prior's bounds outright.
    fn estimate(&self, s: usize, prior: &CostModel) -> Option<(f64, f64)> {
        let kind = self.kind?;
        let (n, mx, my) = (self.n, self.sx / self.n, self.sy / self.n);
        let sxx_c = self.sxx - n * mx * mx;
        let sxy_c = self.sxy - n * mx * my;
        // OLS only when the ratio spread is wide enough to identify the
        // slope against timing noise (stddev of afr > ~0.03); a narrow
        // spread would amplify noise into the slope, so the prior-scale
        // fallback is the better estimator there.
        if sxx_c > 1e-3 * n {
            let slope = sxy_c / sxx_c;
            if slope.is_finite() && slope <= 0.0 {
                let wgrad = -slope;
                return Some(clamp_split(my + wgrad * mx, wgrad));
            }
            // Positive or non-finite slope: ill-conditioned fit, fall
            // through to the prior-scale estimator.
        }
        let probe = Action { kind, mb: 0, stage: s };
        let (lo_p, hi_p) = prior.bounds(probe);
        if !my.is_finite() || my < 0.0 {
            // The window itself is poisoned (NaN/∞ observations): the
            // prior's unscaled decomposition is the only sane estimate.
            return Some(clamp_split(hi_p, hi_p - lo_p));
        }
        let expected = prior.duration(probe, mx);
        let scale = if expected > 0.0 { my / expected } else { 1.0 };
        let wgrad = ((hi_p - lo_p) * scale).max(0.0);
        Some(clamp_split(my + wgrad * mx, wgrad))
    }
}

/// Sanitize an estimated `(hi, wgrad)` split: both finite, `hi ≥ 0`,
/// and `0 ≤ wgrad ≤ hi`, so downstream LP bounds are always ordered.
fn clamp_split(hi: f64, wgrad: f64) -> (f64, f64) {
    let hi = if hi.is_finite() { hi.max(0.0) } else { 0.0 };
    let wgrad = if wgrad.is_finite() { wgrad.clamp(0.0, hi) } else { 0.0 };
    (hi, wgrad)
}

/// An observed per-stage mean that is usable as a cost entry; anything
/// non-finite or negative falls back to the prior's value for the
/// stage, so one poisoned sample cannot corrupt a whole replan.
fn sane(v: f64, fallback: f64) -> f64 {
    if v.is_finite() && v >= 0.0 {
        v
    } else {
        fallback
    }
}

/// Captures observed per-stage action times from the event-driven
/// executor and distills them into a [`CostProfile::Profiled`] table.
///
/// Feed every executed action through [`ProfileRecorder::record`]; at a
/// replan boundary, [`ProfileRecorder::to_profile`] estimates each
/// stage's forward / activation-gradient / parameter-gradient seconds
/// from the window's samples. The freezable split is identified by
/// regressing duration on the freeze ratio the actions actually ran at
/// (the linear law of eq. 4 / Figure 15); when the window's ratios have
/// no spread — a converged static plan — the recorder falls back to
/// scaling `prior`'s split to the observed mean, which is exact for
/// multiplicative slowdowns (stragglers, link contention).
///
/// Observed wall-clock is attributed whole: kernel-launch overhead and
/// node-charged communication fold into the estimated compute terms, so
/// the distilled profile reproduces observed durations rather than the
/// prior's decomposition.
#[derive(Clone, Debug)]
pub struct ProfileRecorder {
    stages: usize,
    /// (count, sum) of observed Forward durations per stage.
    fwd: Vec<(f64, f64)>,
    /// (count, sum) of observed BackwardDgrad durations per stage.
    dgrad: Vec<(f64, f64)>,
    frz: Vec<FreezableFit>,
    samples: usize,
}

impl ProfileRecorder {
    /// An empty recorder over `stages` pipeline stages.
    pub fn new(stages: usize) -> ProfileRecorder {
        ProfileRecorder {
            stages,
            fwd: vec![(0.0, 0.0); stages],
            dgrad: vec![(0.0, 0.0); stages],
            frz: vec![FreezableFit::default(); stages],
            samples: 0,
        }
    }

    /// Record one executed action: the freeze ratio it ran at and its
    /// observed duration in seconds.
    pub fn record(&mut self, a: Action, afr: f64, duration: f64) {
        debug_assert!(a.stage < self.stages, "stage {} out of range", a.stage);
        match a.kind {
            ActionKind::Forward => {
                self.fwd[a.stage].0 += 1.0;
                self.fwd[a.stage].1 += duration;
            }
            ActionKind::BackwardDgrad => {
                self.dgrad[a.stage].0 += 1.0;
                self.dgrad[a.stage].1 += duration;
            }
            ActionKind::Backward | ActionKind::BackwardWgrad => {
                self.frz[a.stage].push(a.kind, afr.clamp(0.0, 1.0), duration);
            }
        }
        self.samples += 1;
    }

    /// Total samples recorded since construction or the last reset.
    pub fn samples(&self) -> usize {
        self.samples
    }

    /// Drop the window's samples (called after each replan so the next
    /// window reflects only the current regime).
    pub fn reset(&mut self) {
        self.fwd.iter_mut().for_each(|a| *a = (0.0, 0.0));
        self.dgrad.iter_mut().for_each(|a| *a = (0.0, 0.0));
        self.frz.iter_mut().for_each(|a| *a = FreezableFit::default());
        self.samples = 0;
    }

    /// Distill the window into a profiled-from-table cost specification,
    /// or `None` when some stage lacks forward or freezable samples
    /// (an empty or truncated window).
    pub fn to_profile(&self, prior: &CostModel) -> Option<CostProfile> {
        let mut rows = Vec::with_capacity(self.stages);
        for s in 0..self.stages {
            let (fn_, fs) = self.fwd[s];
            if fn_ == 0.0 {
                return None;
            }
            let (hi, wgrad) = self.frz[s].estimate(s, prior)?;
            let dgrad = match self.frz[s].kind {
                // Combined backward: duration at afr = 0 is dgrad + wgrad.
                Some(ActionKind::Backward) => (hi - wgrad).max(0.0),
                // Zero-Bubble split: "b" is observed directly.
                _ => {
                    let (dn, ds) = self.dgrad[s];
                    if dn == 0.0 {
                        return None;
                    }
                    ds / dn
                }
            };
            rows.push(StageProfile {
                fwd: sane(fs / fn_, prior.stage_fwd(s)),
                dgrad: sane(dgrad, prior.stage_dgrad(s)),
                wgrad: sane(wgrad, prior.stage_wgrad(s)),
                optimizer: 0.0,
                link: 0.0,
            });
        }
        Some(CostProfile::Profiled(rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Action;

    #[test]
    fn uniform_profile_is_flat() {
        let cm = CostProfile::uniform(1.0, 1.3, 0.9, 0.0).to_model(4);
        for s in 0..4 {
            assert_eq!(cm.bounds(Action::f(0, s)), (1.0, 1.0));
            assert_eq!(cm.bounds(Action::b(0, s)), (1.3, 1.3 + 0.9));
        }
        assert!(!cm.has_p2p());
    }

    #[test]
    fn skewed_first_scales_stage_zero_only() {
        let cm = CostProfile::skewed_first(1.0, 1.0, 1.0, 0.0, 3.0).to_model(4);
        assert_eq!(cm.stage_fwd(0), 3.0);
        assert_eq!(cm.stage_fwd(1), 1.0);
        assert_eq!(cm.stage_wgrad(3), 1.0);
        let cm = CostProfile::skewed_last(1.0, 1.0, 1.0, 0.0, 2.0).to_model(4);
        assert_eq!(cm.stage_fwd(0), 1.0);
        assert_eq!(cm.stage_fwd(3), 2.0);
    }

    #[test]
    fn profiled_table_maps_rows_and_links() {
        let rows = vec![
            StageProfile { fwd: 1.0, dgrad: 1.0, wgrad: 0.5, optimizer: 0.2, link: 0.1 },
            StageProfile { fwd: 2.0, dgrad: 2.0, wgrad: 1.0, optimizer: 0.4, link: 0.3 },
            StageProfile::compute(3.0, 3.0, 1.5),
        ];
        let cm = CostProfile::profiled(rows).to_model(3);
        assert_eq!(cm.stage_fwd(2), 3.0);
        assert_eq!(cm.p2p(0, 1), 0.1);
        assert_eq!(cm.p2p(2, 1), 0.3);
        assert_eq!(cm.optimizer_tail(), 0.4);
    }

    #[test]
    #[should_panic]
    fn profiled_table_rejects_row_mismatch() {
        CostProfile::profiled(vec![StageProfile::compute(1.0, 1.0, 1.0)]).to_model(2);
    }

    #[test]
    fn uniform_link_becomes_edge_costs() {
        let cm = CostProfile::uniform(1.0, 1.0, 1.0, 0.25).to_model(3);
        assert!(cm.has_p2p());
        assert_eq!(cm.p2p(1, 2), 0.25);
        // Node-charged comm stays zero: edges carry the wire time.
        assert_eq!(cm.bounds(Action::f(0, 0)), (1.0, 1.0));
    }

    /// Observed samples with freeze-ratio spread identify the split by
    /// regression alone — the prior never enters.
    #[test]
    fn recorder_recovers_split_from_ratio_spread() {
        let truth = CostProfile::uniform(1.0, 1.3, 0.9, 0.0).to_model(2);
        // A deliberately wrong prior proves the fit path ignores it.
        let prior = CostProfile::uniform(5.0, 5.0, 5.0, 0.0).to_model(2);
        let mut rec = ProfileRecorder::new(2);
        for s in 0..2 {
            for afr in [0.0, 0.25, 0.5, 0.75] {
                rec.record(Action::f(0, s), 0.0, 1.0);
                rec.record(Action::b(0, s), afr, truth.duration(Action::b(0, s), afr));
            }
        }
        let model = rec.to_profile(&prior).unwrap().to_model(2);
        for s in 0..2 {
            assert!((model.stage_fwd(s) - 1.0).abs() < 1e-9);
            assert!((model.stage_dgrad(s) - 1.3).abs() < 1e-9, "{}", model.stage_dgrad(s));
            assert!((model.stage_wgrad(s) - 0.9).abs() < 1e-9, "{}", model.stage_wgrad(s));
        }
    }

    /// With no ratio spread (a converged static plan) the recorder
    /// scales the prior's split to the observed mean — exact for the
    /// multiplicative slowdowns the scenarios inject.
    #[test]
    fn recorder_prior_scale_fallback_recovers_straggler() {
        let prior = CostProfile::uniform(1.0, 1.3, 0.9, 0.0).to_model(3);
        let mut rec = ProfileRecorder::new(3);
        let slow = 1.5; // stage 1 runs on a straggler
        for s in 0..3 {
            let m = if s == 1 { slow } else { 1.0 };
            for _ in 0..4 {
                rec.record(Action::f(0, s), 0.0, m * 1.0);
                let afr = 0.4;
                rec.record(Action::b(0, s), afr, m * prior.duration(Action::b(0, s), afr));
            }
        }
        let model = rec.to_profile(&prior).unwrap().to_model(3);
        for s in 0..3 {
            let m = if s == 1 { slow } else { 1.0 };
            assert!((model.stage_fwd(s) - m * 1.0).abs() < 1e-9);
            assert!((model.stage_dgrad(s) - m * 1.3).abs() < 1e-9);
            assert!((model.stage_wgrad(s) - m * 0.9).abs() < 1e-9);
        }
    }

    /// The Zero-Bubble split path: "b" observed directly, "W" fitted.
    #[test]
    fn recorder_handles_split_backward() {
        let prior = CostProfile::uniform(1.0, 1.3, 0.9, 0.0).to_model(2);
        let mut rec = ProfileRecorder::new(2);
        for s in 0..2 {
            for afr in [0.1, 0.6] {
                rec.record(Action::f(0, s), 0.0, 1.0);
                rec.record(Action::bd(0, s), 0.0, 1.3);
                rec.record(Action::bw(0, s), afr, (1.0 - afr) * 0.9);
            }
        }
        let model = rec.to_profile(&prior).unwrap().to_model(2);
        assert!((model.stage_dgrad(0) - 1.3).abs() < 1e-9);
        assert!((model.stage_wgrad(0) - 0.9).abs() < 1e-9);
    }

    /// A constant-afr window (every backward at one ratio) has zero
    /// spread regardless of sample count; the fallback must keep the
    /// split ordered and scale the prior exactly.
    #[test]
    fn recorder_constant_afr_window_stays_ordered() {
        let prior = CostProfile::uniform(1.0, 1.3, 0.9, 0.0).to_model(2);
        let mut rec = ProfileRecorder::new(2);
        for s in 0..2 {
            for _ in 0..16 {
                rec.record(Action::f(0, s), 0.0, 1.0);
                let afr = 0.6;
                rec.record(Action::b(0, s), afr, 2.0 * prior.duration(Action::b(0, s), afr));
            }
        }
        let model = rec.to_profile(&prior).unwrap().to_model(2);
        for s in 0..2 {
            assert!((model.stage_dgrad(s) - 2.0 * 1.3).abs() < 1e-9);
            assert!((model.stage_wgrad(s) - 2.0 * 0.9).abs() < 1e-9);
            assert!(model.stage_wgrad(s) >= 0.0);
            assert!(model.stage_dgrad(s) >= 0.0);
        }
    }

    /// Adversarial noise that makes duration *grow* with the freeze
    /// ratio (a positive OLS slope — unphysical) must not zero out the
    /// split; the estimator falls back to scaling the prior instead.
    #[test]
    fn recorder_positive_slope_falls_back_to_prior() {
        let prior = CostProfile::uniform(1.0, 1.3, 0.9, 0.0).to_model(1);
        let mut rec = ProfileRecorder::new(1);
        for afr in [0.0, 0.25, 0.5, 0.75] {
            rec.record(Action::f(0, 0), 0.0, 1.0);
            // Duration increases with afr: slope is firmly positive.
            rec.record(Action::b(0, 0), afr, 1.3 + afr * 0.5);
        }
        let model = rec.to_profile(&prior).unwrap().to_model(1);
        assert!(model.stage_wgrad(0) > 0.0, "fallback keeps the stage freezable");
        assert!(model.stage_wgrad(0).is_finite() && model.stage_dgrad(0).is_finite());
        assert!(model.stage_dgrad(0) >= 0.0);
        // The split stays bounded by the observed afr=0 cost.
        let (lo, hi) = model.bounds(Action::b(0, 0));
        assert!(0.0 <= lo && lo <= hi, "bounds ordered: {lo} {hi}");
    }

    /// Poisoned observations (NaN / infinite durations) never leak into
    /// the distilled table — every row clamps finite and non-negative,
    /// falling back to the prior's per-stage values.
    #[test]
    fn recorder_non_finite_samples_do_not_poison_profile() {
        let prior = CostProfile::uniform(1.0, 1.3, 0.9, 0.0).to_model(2);
        let mut rec = ProfileRecorder::new(2);
        for s in 0..2 {
            rec.record(Action::f(0, s), 0.0, if s == 0 { f64::NAN } else { 1.0 });
            rec.record(Action::b(0, s), 0.3, if s == 1 { f64::INFINITY } else { 1.8 });
            rec.record(Action::f(0, s), 0.0, 1.0);
            rec.record(Action::b(0, s), 0.3, 1.8);
        }
        let model = rec.to_profile(&prior).unwrap().to_model(2);
        for s in 0..2 {
            for v in [model.stage_fwd(s), model.stage_dgrad(s), model.stage_wgrad(s)] {
                assert!(v.is_finite() && v >= 0.0, "stage {s}: {v}");
            }
            let (lo, hi) = model.bounds(Action::b(0, s));
            assert!(lo.is_finite() && hi.is_finite() && 0.0 <= lo && lo <= hi);
        }
        // The poisoned stages fall back to the prior's values.
        assert!((model.stage_fwd(0) - prior.stage_fwd(0)).abs() < 1e-9);
        assert!((model.stage_wgrad(1) - prior.stage_wgrad(1)).abs() < 1e-9);
    }

    #[test]
    fn recorder_reset_and_insufficient_windows() {
        let prior = CostProfile::uniform(1.0, 1.0, 1.0, 0.0).to_model(2);
        let mut rec = ProfileRecorder::new(2);
        assert!(rec.to_profile(&prior).is_none(), "empty window has no profile");
        rec.record(Action::f(0, 0), 0.0, 1.0);
        assert_eq!(rec.samples(), 1);
        // Stage 1 never observed → still no profile.
        rec.record(Action::b(0, 0), 0.2, 1.8);
        assert!(rec.to_profile(&prior).is_none());
        rec.record(Action::f(0, 1), 0.0, 1.0);
        rec.record(Action::b(0, 1), 0.2, 1.8);
        assert!(rec.to_profile(&prior).is_some());
        rec.reset();
        assert_eq!(rec.samples(), 0);
        assert!(rec.to_profile(&prior).is_none());
    }
}
