//! Hand-specified stage-shape presets for heterogeneity studies.
//!
//! The analytic [`CostModel::new`](crate::cost::CostModel::new) path
//! derives stage times from a hardware preset; a [`CostProfile`] instead
//! states the shape directly — uniform stages, a skewed first or last
//! stage (embedding/head imbalance, a straggler device), or a fully
//! profiled per-stage table (e.g. transcribed from a cluster profiler).
//! [`CostProfile::to_model`] lowers any profile to a [`CostModel`].

use crate::cost::CostModel;

/// One row of a profiled-from-table cost specification: the measured
/// per-microbatch seconds of a single pipeline stage.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StageProfile {
    /// Forward seconds (freeze-invariant).
    pub fwd: f64,
    /// Activation-gradient ("B") seconds (freeze-invariant).
    pub dgrad: f64,
    /// Parameter-gradient ("W") seconds (removed by freezing).
    pub wgrad: f64,
    /// Optimizer-step seconds, charged once per batch as a tail barrier.
    pub optimizer: f64,
    /// P2P cost of the link to the *next* stage (activations down,
    /// gradients back up). Ignored for the last stage.
    pub link: f64,
}

impl StageProfile {
    /// A compute-only row: no optimizer tail, no link cost.
    pub fn compute(fwd: f64, dgrad: f64, wgrad: f64) -> StageProfile {
        StageProfile { fwd, dgrad, wgrad, optimizer: 0.0, link: 0.0 }
    }
}

/// A stage-shape preset, lowered to a [`CostModel`] by
/// [`CostProfile::to_model`].
#[derive(Clone, Debug, PartialEq)]
pub enum CostProfile {
    /// Every stage identical — the PR 1 flat-scalar setting. With
    /// `link == 0` the resulting model reproduces flat per-action
    /// weights bit-for-bit (guarded by `tests/cost_model.rs`).
    Uniform {
        /// Forward seconds per stage.
        fwd: f64,
        /// Activation-gradient seconds per stage.
        dgrad: f64,
        /// Parameter-gradient seconds per stage.
        wgrad: f64,
        /// P2P cost of every stage boundary.
        link: f64,
    },
    /// Uniform except one end of the pipeline, whose compute entries are
    /// multiplied by `skew` — the embedding-heavy first stage or the
    /// head/loss-heavy last stage of real partitions, or a straggler
    /// device in a heterogeneous cluster.
    Skewed {
        /// Forward seconds of a regular stage.
        fwd: f64,
        /// Activation-gradient seconds of a regular stage.
        dgrad: f64,
        /// Parameter-gradient seconds of a regular stage.
        wgrad: f64,
        /// P2P cost of every stage boundary.
        link: f64,
        /// Multiplier applied to the skewed stage's fwd/dgrad/wgrad.
        skew: f64,
        /// `false` ⇒ the first stage is skewed; `true` ⇒ the last.
        last: bool,
    },
    /// Fully profiled per-stage table. `to_model` requires exactly one
    /// row per stage.
    Profiled(
        /// Measured per-stage rows, stage 0 first.
        Vec<StageProfile>,
    ),
}

impl CostProfile {
    /// Uniform stages with the given per-action seconds and boundary
    /// link cost.
    pub fn uniform(fwd: f64, dgrad: f64, wgrad: f64, link: f64) -> CostProfile {
        CostProfile::Uniform { fwd, dgrad, wgrad, link }
    }

    /// Uniform stages with stage 0's compute scaled by `skew`.
    pub fn skewed_first(fwd: f64, dgrad: f64, wgrad: f64, link: f64, skew: f64) -> CostProfile {
        CostProfile::Skewed { fwd, dgrad, wgrad, link, skew, last: false }
    }

    /// Uniform stages with the last stage's compute scaled by `skew`.
    pub fn skewed_last(fwd: f64, dgrad: f64, wgrad: f64, link: f64, skew: f64) -> CostProfile {
        CostProfile::Skewed { fwd, dgrad, wgrad, link, skew, last: true }
    }

    /// A profiled-from-table specification (one row per stage).
    pub fn profiled(rows: Vec<StageProfile>) -> CostProfile {
        CostProfile::Profiled(rows)
    }

    /// Lower this profile to a [`CostModel`] over `stages` stages.
    /// Profiles carry no kernel-launch overhead and no node-charged
    /// communication: all transfer cost is on the P2P links, so DAG
    /// weights are pure compute and edges carry the wire time.
    ///
    /// Panics if `stages == 0` or a profiled table's row count does not
    /// match `stages`.
    pub fn to_model(&self, stages: usize) -> CostModel {
        assert!(stages > 0, "need at least one stage");
        let rows: Vec<StageProfile> = match self {
            CostProfile::Uniform { fwd, dgrad, wgrad, link } => (0..stages)
                .map(|_| StageProfile {
                    fwd: *fwd,
                    dgrad: *dgrad,
                    wgrad: *wgrad,
                    optimizer: 0.0,
                    link: *link,
                })
                .collect(),
            CostProfile::Skewed { fwd, dgrad, wgrad, link, skew, last } => (0..stages)
                .map(|s| {
                    let hot = if *last { s + 1 == stages } else { s == 0 };
                    let m = if hot { *skew } else { 1.0 };
                    StageProfile {
                        fwd: fwd * m,
                        dgrad: dgrad * m,
                        wgrad: wgrad * m,
                        optimizer: 0.0,
                        link: *link,
                    }
                })
                .collect(),
            CostProfile::Profiled(rows) => {
                assert_eq!(
                    rows.len(),
                    stages,
                    "profiled table has {} rows for {} stages",
                    rows.len(),
                    stages
                );
                rows.clone()
            }
        };
        let p2p: Vec<f64> = rows.iter().take(stages - 1).map(|r| r.link).collect();
        CostModel::from_stage_times(
            rows.iter().map(|r| r.fwd).collect(),
            rows.iter().map(|r| r.dgrad).collect(),
            rows.iter().map(|r| r.wgrad).collect(),
            rows.iter().map(|r| r.optimizer).collect(),
            vec![0.0; stages],
            0.0,
            if p2p.iter().any(|&c| c > 0.0) { p2p } else { Vec::new() },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Action;

    #[test]
    fn uniform_profile_is_flat() {
        let cm = CostProfile::uniform(1.0, 1.3, 0.9, 0.0).to_model(4);
        for s in 0..4 {
            assert_eq!(cm.bounds(Action::f(0, s)), (1.0, 1.0));
            assert_eq!(cm.bounds(Action::b(0, s)), (1.3, 1.3 + 0.9));
        }
        assert!(!cm.has_p2p());
    }

    #[test]
    fn skewed_first_scales_stage_zero_only() {
        let cm = CostProfile::skewed_first(1.0, 1.0, 1.0, 0.0, 3.0).to_model(4);
        assert_eq!(cm.stage_fwd(0), 3.0);
        assert_eq!(cm.stage_fwd(1), 1.0);
        assert_eq!(cm.stage_wgrad(3), 1.0);
        let cm = CostProfile::skewed_last(1.0, 1.0, 1.0, 0.0, 2.0).to_model(4);
        assert_eq!(cm.stage_fwd(0), 1.0);
        assert_eq!(cm.stage_fwd(3), 2.0);
    }

    #[test]
    fn profiled_table_maps_rows_and_links() {
        let rows = vec![
            StageProfile { fwd: 1.0, dgrad: 1.0, wgrad: 0.5, optimizer: 0.2, link: 0.1 },
            StageProfile { fwd: 2.0, dgrad: 2.0, wgrad: 1.0, optimizer: 0.4, link: 0.3 },
            StageProfile::compute(3.0, 3.0, 1.5),
        ];
        let cm = CostProfile::profiled(rows).to_model(3);
        assert_eq!(cm.stage_fwd(2), 3.0);
        assert_eq!(cm.p2p(0, 1), 0.1);
        assert_eq!(cm.p2p(2, 1), 0.3);
        assert_eq!(cm.optimizer_tail(), 0.4);
    }

    #[test]
    #[should_panic]
    fn profiled_table_rejects_row_mismatch() {
        CostProfile::profiled(vec![StageProfile::compute(1.0, 1.0, 1.0)]).to_model(2);
    }

    #[test]
    fn uniform_link_becomes_edge_costs() {
        let cm = CostProfile::uniform(1.0, 1.0, 1.0, 0.25).to_model(3);
        assert!(cm.has_p2p());
        assert_eq!(cm.p2p(1, 2), 0.25);
        // Node-charged comm stays zero: edges carry the wire time.
        assert_eq!(cm.bounds(Action::f(0, 0)), (1.0, 1.0));
    }
}
