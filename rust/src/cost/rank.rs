//! Critical-path priority queries over a pipeline action set: upward
//! rank (a.k.a. bottom level) of every action under a duration function,
//! the classic HEFT priority. `schedule::synth` feeds these tables to
//! the weighted list scheduler — first from the cost model's `w_max`
//! durations, then re-ranked from the frozen durations the freeze LP
//! chose, which is what closes the schedule↔LP fixed-point loop.

use crate::graph::pipeline::structural_edges;
use crate::types::Action;
use std::collections::BTreeMap;

/// Upward rank (bottom level) of every action: `rank(a) = duration(a) +
/// max over structural successors of their rank` (0 for sinks), computed
/// over the Appendix B rule-1–3 edge set. Higher means more critical.
///
/// Durations must be finite and non-negative; the rule edge set is
/// acyclic by construction, so every action gets a rank.
pub fn upward_ranks(
    actions: &[Action],
    stages: usize,
    microbatches: usize,
    duration: impl Fn(Action) -> f64,
) -> BTreeMap<Action, f64> {
    let n = actions.len();
    let index: BTreeMap<Action, usize> = actions.iter().enumerate().map(|(i, a)| (*a, i)).collect();
    let mut preds_of: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut succs_left = vec![0usize; n];
    for (u, v) in structural_edges(actions, stages, microbatches) {
        let (ui, vi) = (index[&u], index[&v]);
        preds_of[vi].push(ui);
        succs_left[ui] += 1;
    }

    let mut rank = vec![0.0f64; n];
    // Finalize from the sinks backwards: an action's rank is final once
    // every successor's rank is; `best` accumulates the max successor
    // rank as successors finalize.
    let mut best = vec![0.0f64; n];
    let mut queue: Vec<usize> = (0..n).filter(|&i| succs_left[i] == 0).collect();
    let mut finalized = 0usize;
    while let Some(v) = queue.pop() {
        let d = duration(actions[v]);
        debug_assert!(d.is_finite() && d >= 0.0, "duration of {} must be finite ≥ 0", actions[v]);
        rank[v] = d + best[v];
        finalized += 1;
        for &u in &preds_of[v] {
            best[u] = best[u].max(rank[v]);
            succs_left[u] -= 1;
            if succs_left[u] == 0 {
                queue.push(u);
            }
        }
    }
    assert_eq!(finalized, n, "structural edge set must be acyclic");
    actions.iter().enumerate().map(|(i, a)| (*a, rank[i])).collect()
}

/// Quantize a float rank table into the `i64` scores
/// [`crate::schedule::Priority::with_table`] consumes: scaled so the maximum rank maps
/// to ~10¹², preserving relative order to well below any meaningful
/// duration difference. Deterministic.
pub fn quantize_ranks(ranks: &BTreeMap<Action, f64>) -> BTreeMap<Action, i64> {
    let max = ranks.values().fold(0.0f64, |m, &r| m.max(r));
    let scale = if max > 0.0 { 1e12 / max } else { 0.0 };
    ranks.iter().map(|(a, &r)| (*a, (r * scale).round() as i64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Single-stage chain F→B: forward's rank adds the backward's.
    #[test]
    fn chain_ranks_accumulate() {
        let actions = vec![Action::f(0, 0), Action::b(0, 0)];
        let r = upward_ranks(&actions, 1, 1, |a| match a.kind {
            crate::types::ActionKind::Forward => 1.0,
            _ => 2.0,
        });
        assert_eq!(r[&Action::b(0, 0)], 2.0);
        assert_eq!(r[&Action::f(0, 0)], 3.0);
    }

    /// Two-stage split set: the first forward sits on the longest path
    /// (through both stages and both dgrads) and outranks everything.
    #[test]
    fn first_forward_most_critical() {
        let mut actions = Vec::new();
        for s in 0..2 {
            actions.push(Action::f(0, s));
            actions.push(Action::bd(0, s));
            actions.push(Action::bw(0, s));
        }
        let r = upward_ranks(&actions, 2, 1, |_| 1.0);
        let f0 = r[&Action::f(0, 0)];
        assert!(actions.iter().all(|a| r[a] <= f0));
        // f(0,0) → f(0,1) → bd(0,1) → bd(0,0) → bw(0,0): depth 5.
        assert_eq!(f0, 5.0);
    }

    /// Quantization preserves order and tops out near 1e12.
    #[test]
    fn quantization_preserves_order() {
        let mut t = BTreeMap::new();
        t.insert(Action::f(0, 0), 3.0);
        t.insert(Action::f(1, 0), 1.5);
        let q = quantize_ranks(&t);
        assert_eq!(q[&Action::f(0, 0)], 1_000_000_000_000);
        assert_eq!(q[&Action::f(1, 0)], 500_000_000_000);
    }
}
