//! The real pipeline engine: a multi-threaded pipeline-parallel trainer
//! executing AOT-compiled PJRT artifacts, coordinated by the same
//! freeze controllers the simulator uses — the end-to-end proof that
//! all three layers compose (L1 Pallas kernels inside L2 HLO artifacts
//! driven by the L3 coordinator).
//!
//! Scope: combined-backward schedules (GPipe, 1F1B) on `stages == ranks`;
//! the split-backward ZBV / Interleaved variants are evaluated in the
//! simulator (docs/ARCHITECTURE.md).

pub mod params;
pub mod worker;

pub use params::{BlockParams, LayerMap, StageParams};
pub use worker::{run_worker, StepCmd, StepReport, WorkerCmd, WorkerEnv};

use crate::freeze::{ApfConfig, AutoFreezeConfig, ControllerFactory, ModelLayout, PhaseConfig};
use crate::runtime::Manifest;
use crate::schedule::Schedule;
use crate::train::lr::LrSchedule;
use crate::train::optimizer::OptimizerKind;
use crate::types::{FreezeMethod, ScheduleKind};
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifacts_dir: PathBuf,
    /// Total transformer blocks (layers reuse the shared block artifacts).
    pub blocks: usize,
    /// Pipeline stages (== ranks; one worker thread each).
    pub stages: usize,
    pub microbatches: usize,
    pub schedule: ScheduleKind,
    pub method: FreezeMethod,
    pub steps: usize,
    pub phases: PhaseConfig,
    pub r_max: f64,
    pub lambda: f64,
    pub apf: ApfConfig,
    pub auto: AutoFreezeConfig,
    pub optimizer: OptimizerKind,
    pub base_lr: f64,
    pub seed: u64,
    /// Steps between stability checks (metric controllers).
    pub check_interval: usize,
    /// Tiny-corpus cycle length in steps (0 = fresh data every step).
    pub corpus_cycle: usize,
}

impl EngineConfig {
    pub fn quick_defaults(artifacts_dir: PathBuf) -> EngineConfig {
        EngineConfig {
            artifacts_dir,
            blocks: 8,
            stages: 4,
            microbatches: 4,
            schedule: ScheduleKind::OneFOneB,
            method: FreezeMethod::TimelyFreeze,
            steps: 60,
            phases: PhaseConfig::new(6, 18, 30),
            r_max: 0.8,
            lambda: crate::lp::DEFAULT_LAMBDA,
            apf: ApfConfig::default(),
            auto: AutoFreezeConfig::default(),
            optimizer: OptimizerKind::adamw(),
            base_lr: 1e-3,
            seed: 42,
            check_interval: 5,
            corpus_cycle: 8,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct EngineTrajPoint {
    pub step: usize,
    pub loss: f64,
    pub step_time: f64,
    pub mean_afr: f64,
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    pub loss_curve: Vec<EngineTrajPoint>,
    pub tokens_per_step: usize,
    /// Full-run and post-ramp throughput, tokens/s (wall clock).
    pub throughput: f64,
    pub steady_throughput: f64,
    /// Mean step time in the upper-monitoring window vs post-T_f: the
    /// measured per-step speedup κ (eq. 12).
    pub baseline_step_time: f64,
    pub frozen_step_time: f64,
    /// Average freeze ratio (%), param-weighted over steps.
    pub freeze_ratio: f64,
    pub final_loss: f64,
    pub initial_loss: f64,
}

impl TrainReport {
    pub fn kappa(&self) -> f64 {
        if self.baseline_step_time > 0.0 {
            self.frozen_step_time / self.baseline_step_time
        } else {
            1.0
        }
    }
}

/// Engine model layout: one freeze unit per model layer
/// (embed, blocks…, head).
fn engine_layout(manifest: &Manifest, map: &LayerMap) -> ModelLayout {
    let cfg = &manifest.config;
    let block_params: u64 = cfg
        .matrix_shapes
        .values()
        .map(|&(a, b)| (a * b) as u64)
        .sum::<u64>()
        + 2 * cfg.d_model as u64;
    let mut unit_params = vec![(cfg.vocab * cfg.d_model) as u64];
    unit_params.extend(std::iter::repeat(block_params).take(map.blocks));
    unit_params.push((cfg.d_model * cfg.vocab) as u64);
    let unit_layer: Vec<usize> = (0..map.num_layers()).collect();
    ModelLayout::new(unit_params, unit_layer, map.layer_stage_vec(), map.stages)
}

/// Train end-to-end; returns the report (loss curve, throughput, κ).
pub fn train(cfg: &EngineConfig) -> Result<TrainReport> {
    if !matches!(cfg.schedule, ScheduleKind::GPipe | ScheduleKind::OneFOneB) {
        bail!("engine supports GPipe and 1F1B (got {})", cfg.schedule.name());
    }
    let manifest = Manifest::load(&cfg.artifacts_dir).context("loading artifact manifest")?;
    let map = LayerMap::new(cfg.blocks, cfg.stages);
    let schedule = Schedule::build(cfg.schedule, cfg.stages, cfg.microbatches, 1);
    let layout = engine_layout(&manifest, &map);
    let factory = ControllerFactory {
        phases: cfg.phases,
        r_max: cfg.r_max,
        lambda: cfg.lambda,
        apf: cfg.apf.clone(),
        auto: cfg.auto.clone(),
        stage_floor: None,
        edge_comm: None,
    };
    let mut controller = factory.build(cfg.method, &schedule, &layout);
    let lr = LrSchedule::cosine(cfg.base_lr, cfg.phases.t_warmup, cfg.steps);

    // ---- spawn workers ----
    let (report_tx, report_rx) = mpsc::channel::<StepReport>();
    let mut cmd_txs = Vec::with_capacity(cfg.stages);
    let mut handles = Vec::with_capacity(cfg.stages);
    // Forward channels: boundary i connects stage i → i+1; backward
    // channels mirror them.
    let mut fwd: Vec<Option<(mpsc::Sender<_>, mpsc::Receiver<_>)>> =
        (0..cfg.stages.saturating_sub(1)).map(|_| Some(mpsc::channel())).collect();
    let mut bwd: Vec<Option<(mpsc::Sender<_>, mpsc::Receiver<_>)>> =
        (0..cfg.stages.saturating_sub(1)).map(|_| Some(mpsc::channel())).collect();

    let mut fwd_rx_of: Vec<Option<mpsc::Receiver<crate::runtime::HostTensor>>> =
        (0..cfg.stages).map(|_| None).collect();
    let mut fwd_tx_of: Vec<Option<mpsc::Sender<crate::runtime::HostTensor>>> =
        (0..cfg.stages).map(|_| None).collect();
    let mut bwd_rx_of: Vec<Option<mpsc::Receiver<crate::runtime::HostTensor>>> =
        (0..cfg.stages).map(|_| None).collect();
    let mut bwd_tx_of: Vec<Option<mpsc::Sender<crate::runtime::HostTensor>>> =
        (0..cfg.stages).map(|_| None).collect();
    for s in 0..cfg.stages.saturating_sub(1) {
        let (ftx, frx) = fwd[s].take().unwrap();
        fwd_tx_of[s] = Some(ftx);
        fwd_rx_of[s + 1] = Some(frx);
        let (btx, brx) = bwd[s].take().unwrap();
        bwd_tx_of[s + 1] = Some(btx);
        bwd_rx_of[s] = Some(brx);
    }

    for stage in 0..cfg.stages {
        let (cmd_tx, cmd_rx) = mpsc::channel::<WorkerCmd>();
        cmd_txs.push(cmd_tx);
        let env = WorkerEnv {
            stage,
            map: map.clone(),
            manifest: manifest.clone(),
            schedule_order: schedule.orders[stage].clone(),
            microbatches: cfg.microbatches,
            optimizer: cfg.optimizer,
            seed: cfg.seed,
            corpus_cycle: cfg.corpus_cycle,
            cmd_rx,
            report_tx: report_tx.clone(),
            fwd_rx: fwd_rx_of[stage].take(),
            fwd_tx: fwd_tx_of[stage].take(),
            bwd_rx: bwd_rx_of[stage].take(),
            bwd_tx: bwd_tx_of[stage].take(),
        };
        handles.push(
            std::thread::Builder::new()
                .name(format!("stage-{stage}"))
                .spawn(move || run_worker(env))
                .context("spawning stage worker")?,
        );
    }
    drop(report_tx);

    // ---- training loop ----
    let tokens_per_step =
        cfg.microbatches * manifest.config.microbatch * manifest.config.seq_len;
    let mut loss_curve = Vec::with_capacity(cfg.steps);
    let mut total_time = 0.0;
    let mut steady_time = 0.0;
    let mut steady_steps = 0usize;
    let mut upper_time = 0.0;
    let mut upper_steps = 0usize;
    let mut freeze_sum = 0.0;
    let num_layers = map.num_layers();
    let mut initial_loss = f64::NAN;
    let mut final_loss = f64::NAN;

    let run = (|| -> Result<()> {
        for t in 1..=cfg.steps {
            let plan = controller.plan(t);
            let freezable: Vec<crate::types::Action> = schedule
                .all_actions()
                .into_iter()
                .filter(|a| a.kind.freezable())
                .collect();
            let collect = t % cfg.check_interval == 0;
            let start = Instant::now();
            for (stage, tx) in cmd_txs.iter().enumerate() {
                let afr = plan
                    .afr
                    .iter()
                    .filter(|(a, _)| schedule.rank_of_stage[a.stage] == stage)
                    .map(|(a, &r)| (*a, r))
                    .collect();
                tx.send(WorkerCmd::Step(StepCmd { t, lr: lr.at(t), afr, collect_deltas: collect }))
                    .map_err(|_| anyhow::anyhow!("worker {stage} died"))?;
            }
            let mut step_loss = None;
            let mut deltas = vec![crate::freeze::UnitDelta::default(); num_layers];
            let mut frozen_frac = 0.0;
            for _ in 0..cfg.stages {
                let report = report_rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("a worker exited early"))?;
                for (a, dur) in &report.timings {
                    controller.record_time(t, *a, *dur);
                }
                if let Some(l) = report.loss {
                    step_loss = Some(l);
                }
                for (layer, d) in report.deltas {
                    deltas[layer] = d;
                }
                frozen_frac += report.frozen_fraction / cfg.stages as f64;
            }
            let step_time = start.elapsed().as_secs_f64();
            total_time += step_time;
            freeze_sum += frozen_frac;
            if collect {
                controller.observe_updates(t, &deltas);
            }
            if t > cfg.phases.t_freeze {
                steady_time += step_time;
                steady_steps += 1;
            }
            if t > cfg.phases.t_warmup && t <= cfg.phases.monitor_mid() {
                upper_time += step_time;
                upper_steps += 1;
            }
            if let Some(l) = step_loss {
                if initial_loss.is_nan() {
                    initial_loss = l;
                }
                final_loss = l;
                loss_curve.push(EngineTrajPoint {
                    step: t,
                    loss: l,
                    step_time,
                    mean_afr: plan.mean_ratio(&freezable),
                });
            }
        }
        Ok(())
    })();

    for tx in &cmd_txs {
        tx.send(WorkerCmd::Shutdown).ok();
    }
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => eprintln!("worker error: {e:#}"),
            Err(_) => eprintln!("worker panicked"),
        }
    }
    run?;

    let baseline_step_time =
        if upper_steps > 0 { upper_time / upper_steps as f64 } else { f64::NAN };
    let frozen_step_time =
        if steady_steps > 0 { steady_time / steady_steps as f64 } else { f64::NAN };
    Ok(TrainReport {
        tokens_per_step,
        throughput: tokens_per_step as f64 * cfg.steps as f64 / total_time,
        steady_throughput: if steady_steps > 0 {
            tokens_per_step as f64 * steady_steps as f64 / steady_time
        } else {
            f64::NAN
        },
        baseline_step_time,
        frozen_step_time,
        freeze_ratio: 100.0 * freeze_sum / cfg.steps as f64,
        final_loss,
        initial_loss,
        loss_curve,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    /// Full three-layer smoke test: real schedules, real PJRT execution,
    /// real freezing. Kept tiny so `cargo test` stays fast; the full run
    /// lives in examples/train_e2e.rs.
    #[test]
    fn e2e_small_training_run_loss_decreases() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        };
        let mut cfg = EngineConfig::quick_defaults(dir);
        cfg.blocks = 4;
        cfg.stages = 2;
        cfg.microbatches = 2;
        cfg.steps = 24;
        cfg.phases = PhaseConfig::new(4, 10, 16);
        cfg.check_interval = 4;
        let report = train(&cfg).unwrap();
        assert_eq!(report.loss_curve.len(), 24);
        // Loss improves in the mean (individual steps are noisy on the
        // tiny cycled corpus).
        let first: f64 =
            report.loss_curve[..6].iter().map(|p| p.loss).sum::<f64>() / 6.0;
        let last: f64 =
            report.loss_curve[18..].iter().map(|p| p.loss).sum::<f64>() / 6.0;
        assert!(last < first - 0.5, "loss did not improve: {first:.3} → {last:.3}");
        assert!(report.throughput > 0.0);
        // Freezing engaged after T_f.
        let last = report.loss_curve.last().unwrap();
        assert!(last.mean_afr > 0.0, "no freezing at end");
        assert!(report.freeze_ratio > 0.0);
    }

    #[test]
    fn engine_rejects_split_backward_schedules() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut cfg = EngineConfig::quick_defaults(dir);
        cfg.schedule = ScheduleKind::ZeroBubbleV;
        assert!(train(&cfg).is_err());
    }
}
