//! Parameter storage for the real pipeline engine: per-stage model
//! parameters in the flat tensor order the AOT artifacts expect
//! (`PARAM_NAMES` in python/compile/model.py, recorded in the manifest).

use crate::runtime::{HostTensor, ManifestConfig};
use crate::util::rng::Rng;

/// One transformer block's parameters, in manifest `param_names` order:
/// wq, wk, wv, wo, w1, w2, w3, norm1, norm2.
#[derive(Clone, Debug)]
pub struct BlockParams {
    pub tensors: Vec<HostTensor>,
}

impl BlockParams {
    pub fn init(cfg: &ManifestConfig, rng: &mut Rng) -> BlockParams {
        let mut tensors = Vec::with_capacity(cfg.param_names.len());
        for name in &cfg.param_names {
            let t = if let Some(&(din, dout)) = cfg.matrix_shapes.get(name) {
                let scale = (din as f32).powf(-0.5);
                let data: Vec<f32> =
                    (0..din * dout).map(|_| rng.normal() as f32 * scale).collect();
                HostTensor::f32(vec![din, dout], data)
            } else {
                // Norm scales initialize to ones.
                HostTensor::full(&[cfg.d_model], 1.0)
            };
            tensors.push(t);
        }
        BlockParams { tensors }
    }

    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }
}

/// Global layer numbering: 0 = embedding, 1..=blocks, blocks+1 = head.
#[derive(Clone, Debug)]
pub struct LayerMap {
    pub blocks: usize,
    pub stages: usize,
}

impl LayerMap {
    pub fn new(blocks: usize, stages: usize) -> LayerMap {
        assert!(blocks >= stages, "need at least one block per stage");
        LayerMap { blocks, stages }
    }

    pub fn num_layers(&self) -> usize {
        self.blocks + 2
    }

    /// Stage of a global layer id (embed pinned to stage 0, head to the
    /// last stage, blocks split evenly).
    pub fn stage_of_layer(&self, layer: usize) -> usize {
        if layer == 0 {
            0
        } else if layer == self.blocks + 1 {
            self.stages - 1
        } else {
            ((layer - 1) * self.stages / self.blocks).min(self.stages - 1)
        }
    }

    /// Global block-layer ids owned by a stage (excluding embed/head).
    pub fn blocks_of_stage(&self, stage: usize) -> Vec<usize> {
        (1..=self.blocks).filter(|&l| self.stage_of_layer(l) == stage).collect()
    }

    pub fn layer_stage_vec(&self) -> Vec<usize> {
        (0..self.num_layers()).map(|l| self.stage_of_layer(l)).collect()
    }
}

/// All parameters owned by one stage.
pub struct StageParams {
    /// Embedding table (stage 0 only).
    pub embed: Option<HostTensor>,
    /// Transformer blocks, in model order.
    pub blocks: Vec<BlockParams>,
    /// Head projection (last stage only).
    pub head: Option<HostTensor>,
}

impl StageParams {
    /// Deterministic init shared with no one — each stage initializes its
    /// own layers from per-layer derived streams, so any partition of the
    /// same model yields identical weights.
    pub fn init(
        cfg: &ManifestConfig,
        map: &LayerMap,
        stage: usize,
        seed: u64,
    ) -> StageParams {
        let base = Rng::seed_from_u64(seed);
        let embed = (stage == 0).then(|| {
            let mut rng = base.derive(0xE4B, 0);
            let data: Vec<f32> = (0..cfg.vocab * cfg.d_model)
                .map(|_| rng.normal() as f32 * 0.02)
                .collect();
            HostTensor::f32(vec![cfg.vocab, cfg.d_model], data)
        });
        let blocks = map
            .blocks_of_stage(stage)
            .into_iter()
            .map(|layer| {
                let mut rng = base.derive(0xB10C, layer as u64);
                BlockParams::init(cfg, &mut rng)
            })
            .collect();
        let head = (stage == map.stages - 1).then(|| {
            let mut rng = base.derive(0x4EAD, 0);
            let scale = (cfg.d_model as f32).powf(-0.5);
            let data: Vec<f32> = (0..cfg.d_model * cfg.vocab)
                .map(|_| rng.normal() as f32 * scale)
                .collect();
            HostTensor::f32(vec![cfg.d_model, cfg.vocab], data)
        });
        StageParams { embed, blocks, head }
    }

    /// Flat tensor list in optimizer order:
    /// [embed?] ++ blocks×param_names ++ [head?].
    pub fn tensor_sizes(&self) -> Vec<usize> {
        let mut out = Vec::new();
        if let Some(e) = &self.embed {
            out.push(e.len());
        }
        for b in &self.blocks {
            out.extend(b.tensors.iter().map(|t| t.len()));
        }
        if let Some(h) = &self.head {
            out.push(h.len());
        }
        out
    }

    pub fn param_count(&self) -> usize {
        self.tensor_sizes().iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn tiny_cfg() -> ManifestConfig {
        let names: Vec<String> =
            ["wq", "wk", "wv", "wo", "w1", "w2", "w3", "norm1", "norm2"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let mut matrix_shapes = BTreeMap::new();
        for n in ["wq", "wk", "wv", "wo"] {
            matrix_shapes.insert(n.to_string(), (16, 16));
        }
        matrix_shapes.insert("w1".into(), (16, 32));
        matrix_shapes.insert("w2".into(), (32, 16));
        matrix_shapes.insert("w3".into(), (16, 32));
        ManifestConfig {
            d_model: 16,
            n_heads: 2,
            d_ff: 32,
            vocab: 64,
            seq_len: 8,
            microbatch: 1,
            param_names: names.clone(),
            masked_names: names[..7].to_vec(),
            mask_shapes: BTreeMap::new(),
            matrix_shapes,
        }
    }

    #[test]
    fn layer_map_partitions() {
        let m = LayerMap::new(8, 4);
        assert_eq!(m.stage_of_layer(0), 0); // embed
        assert_eq!(m.stage_of_layer(9), 3); // head
        assert_eq!(m.blocks_of_stage(0), vec![1, 2]);
        assert_eq!(m.blocks_of_stage(3), vec![7, 8]);
        assert_eq!(m.layer_stage_vec().len(), 10);
    }

    #[test]
    fn stage_params_ownership() {
        let cfg = tiny_cfg();
        let map = LayerMap::new(4, 2);
        let s0 = StageParams::init(&cfg, &map, 0, 1);
        let s1 = StageParams::init(&cfg, &map, 1, 1);
        assert!(s0.embed.is_some() && s0.head.is_none());
        assert!(s1.embed.is_none() && s1.head.is_some());
        assert_eq!(s0.blocks.len(), 2);
        assert_eq!(s1.blocks.len(), 2);
    }

    #[test]
    fn init_is_partition_invariant() {
        // The same global block gets identical weights regardless of how
        // many stages the model is cut into.
        let cfg = tiny_cfg();
        let a = StageParams::init(&cfg, &LayerMap::new(4, 2), 1, 7);
        let b = StageParams::init(&cfg, &LayerMap::new(4, 4), 2, 7);
        // Stage 1 of 2 owns blocks {3,4}; stage 2 of 4 owns block {3}.
        assert_eq!(a.blocks[0].tensors[0], b.blocks[0].tensors[0]);
    }

    #[test]
    fn block_param_count() {
        let cfg = tiny_cfg();
        let mut rng = Rng::seed_from_u64(0);
        let b = BlockParams::init(&cfg, &mut rng);
        // 4×(16·16) + 2×(16·32) + (32·16) + 2×16
        assert_eq!(b.param_count(), 4 * 256 + 2 * 512 + 512 + 32);
    }
}
