//! Stage worker: one OS thread per pipeline rank, owning its model
//! slice, optimizer state, PJRT runtime, and the rank's slice of the
//! schedule. Executes forward/backward actions in schedule order,
//! exchanging activations/gradients over channels (the inter-GPU links
//! of the paper's testbed), timing each action for the monitor, and
//! skipping per-layer wgrad work according to the controller's AFRs —
//! the real, wall-clock realization of Figure 3.

use crate::engine::params::{LayerMap, StageParams};
use crate::freeze::UnitDelta;
use crate::runtime::{HostTensor, Manifest, StageRuntime};
use crate::train::data::BigramCorpus;
use crate::train::optimizer::{Optimizer, OptimizerKind, UpdateStats};
use crate::types::{Action, ActionKind};
use crate::util::rng::Rng;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

/// Per-step command from the coordinator.
#[derive(Clone, Debug)]
pub struct StepCmd {
    pub t: usize,
    pub lr: f64,
    /// AFR per action on this rank (missing ⇒ 0).
    pub afr: BTreeMap<Action, f64>,
    /// Drain update statistics this step (stability check).
    pub collect_deltas: bool,
}

#[derive(Debug)]
pub enum WorkerCmd {
    Step(StepCmd),
    Shutdown,
}

/// Per-step report back to the coordinator.
#[derive(Clone, Debug, Default)]
pub struct StepReport {
    pub stage: usize,
    /// Measured compute duration per action (blocking waits excluded —
    /// w_i is execution time; start times come from dependencies).
    pub timings: Vec<(Action, f64)>,
    /// Mean loss over microbatches (last stage only).
    pub loss: Option<f64>,
    /// (global layer id, cumulative update stats) when requested.
    pub deltas: Vec<(usize, UnitDelta)>,
    /// Param-weighted frozen fraction this step on this stage.
    pub frozen_fraction: f64,
}

pub struct WorkerEnv {
    pub stage: usize,
    pub map: LayerMap,
    pub manifest: Manifest,
    pub schedule_order: Vec<Action>,
    pub microbatches: usize,
    pub optimizer: OptimizerKind,
    pub seed: u64,
    /// Cycle length of the tiny corpus (0 = fresh data every step).
    pub corpus_cycle: usize,
    pub cmd_rx: Receiver<WorkerCmd>,
    pub report_tx: Sender<StepReport>,
    pub fwd_rx: Option<Receiver<HostTensor>>,
    pub fwd_tx: Option<Sender<HostTensor>>,
    pub bwd_rx: Option<Receiver<HostTensor>>,
    pub bwd_tx: Option<Sender<HostTensor>>,
}

struct MbState {
    tokens: Option<Vec<i32>>,
    /// Input activation of each local block, in model order.
    block_inputs: Vec<HostTensor>,
    /// Final hidden state (last stage, for the head).
    final_h: Option<HostTensor>,
}

/// Accumulated per-layer update statistics between stability checks.
#[derive(Default, Clone, Copy)]
struct LayerDelta {
    signed: f64,
    abs: f64,
    sq: f64,
}

pub fn run_worker(env: WorkerEnv) -> Result<()> {
    let stage = env.stage;
    let is_first = stage == 0;
    let is_last = stage == env.map.stages - 1;
    let cfg = env.manifest.config.clone();

    // Artifact kinds this stage needs.
    let mut kinds = vec!["block_fwd", "block_bwd", "block_dgrad"];
    if is_first {
        kinds.push("embed_fwd");
        kinds.push("embed_wgrad");
    }
    if is_last {
        kinds.push("head_loss_grad");
    }
    let rt = StageRuntime::load(&env.manifest, Some(&kinds))
        .with_context(|| format!("stage {stage}: loading runtime"))?;

    let mut params = StageParams::init(&cfg, &env.map, stage, env.seed);
    let local_blocks = env.map.blocks_of_stage(stage);
    let sizes = params.tensor_sizes();
    let mut optimizer = Optimizer::new(env.optimizer, &sizes);
    let corpus = BigramCorpus::new(cfg.vocab, env.seed);

    // Zero ("live") freeze-mask tensors for block_bwd, in masked_names
    // order, shaped per the manifest.
    let zero_masks: Vec<HostTensor> = cfg
        .masked_names
        .iter()
        .map(|n| {
            let (a, b) = cfg.mask_shapes[n];
            HostTensor::zeros(&[a, b])
        })
        .collect();

    // Gradient accumulators aligned with optimizer tensor order.
    let mut grads: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![0.0; n]).collect();
    // How many microbatches contributed an (unfrozen) gradient per layer.
    let num_layers = env.map.num_layers();
    let mut layer_contrib = vec![0usize; num_layers];
    let mut layer_deltas = vec![LayerDelta::default(); num_layers];
    let layer_params: Vec<usize> = layer_param_counts(&params, &local_blocks, num_layers);
    let freeze_rng = Rng::seed_from_u64(env.seed ^ 0xF0F0_F0F0);

    loop {
        let cmd = env.cmd_rx.recv().map_err(|_| anyhow!("coordinator gone"))?;
        let StepCmd { t, lr, afr, collect_deltas } = match cmd {
            WorkerCmd::Shutdown => return Ok(()),
            WorkerCmd::Step(c) => c,
        };

        // Tiny-corpus epochs: cycle through a fixed window of batches.
        let data_step = if env.corpus_cycle > 0 { 1 + (t - 1) % env.corpus_cycle } else { t };
        let mut mb_states: Vec<Option<MbState>> = (0..env.microbatches).map(|_| None).collect();
        let mut timings = Vec::with_capacity(env.schedule_order.len());
        let mut loss_sum = 0.0f64;
        let mut loss_count = 0usize;
        for g in grads.iter_mut() {
            g.iter_mut().for_each(|x| *x = 0.0);
        }
        layer_contrib.iter_mut().for_each(|c| *c = 0);
        let mut frozen_weighted = 0.0f64;
        let mut frozen_events = 0usize;

        // Per-layer freeze decision for (t, mb, layer): uniform random
        // selection (§3.3) from a stream every rank can reconstruct.
        let frozen_for = |mb: usize, layer: usize, ratio: f64| -> bool {
            if ratio <= 0.0 {
                return false;
            }
            if ratio >= 1.0 {
                return true;
            }
            let mut r = freeze_rng
                .derive((t * 131 + mb) as u64, layer as u64);
            r.bernoulli(ratio)
        };

        for &action in &env.schedule_order {
            let mb = action.mb;
            match action.kind {
                ActionKind::Forward => {
                    // Receive input *before* starting the stopwatch.
                    let (tokens, mut x) = if is_first {
                        let (inp, _) = corpus.batch(
                            env.seed,
                            data_step,
                            mb,
                            cfg.microbatch,
                            cfg.seq_len,
                        );
                        (Some(inp), None)
                    } else {
                        let rx = env.fwd_rx.as_ref().expect("fwd_rx");
                        (None, Some(rx.recv().map_err(|_| anyhow!("fwd channel closed"))?))
                    };
                    let start = Instant::now();
                    if is_first {
                        let tok = HostTensor::i32(
                            vec![cfg.microbatch, cfg.seq_len],
                            tokens.clone().unwrap(),
                        );
                        let emb = params.embed.as_ref().unwrap().clone();
                        x = Some(
                            rt.execute("embed_fwd", &[emb, tok])?.remove(0),
                        );
                    }
                    let mut x = x.unwrap();
                    let mut block_inputs = Vec::with_capacity(local_blocks.len());
                    for b in &params.blocks {
                        block_inputs.push(x.clone());
                        let mut inputs: Vec<HostTensor> = b.tensors.clone();
                        inputs.push(x);
                        x = rt.execute("block_fwd", &inputs)?.remove(0);
                    }
                    timings.push((action, start.elapsed().as_secs_f64()));
                    let final_h = if is_last {
                        Some(x)
                    } else {
                        env.fwd_tx.as_ref().expect("fwd_tx").send(x).ok();
                        None
                    };
                    // Targets are generated at backward time on the last
                    // stage from the same deterministic stream.
                    mb_states[mb] = Some(MbState { tokens, block_inputs, final_h });
                }
                ActionKind::Backward => {
                    let state = mb_states[mb]
                        .take()
                        .ok_or_else(|| anyhow!("backward before forward for mb {mb}"))?;
                    let ratio = afr.get(&action).copied().unwrap_or(0.0);
                    // Receive upstream gradient before timing.
                    let incoming = if is_last {
                        None
                    } else {
                        let rx = env.bwd_rx.as_ref().expect("bwd_rx");
                        Some(rx.recv().map_err(|_| anyhow!("bwd channel closed"))?)
                    };
                    let start = Instant::now();

                    let mut gy = if is_last {
                        // Head + loss (fused artifact). The head layer's
                        // own freezing just drops its gradient.
                        let (_, tgt) = corpus.batch(
                            env.seed,
                            data_step,
                            mb,
                            cfg.microbatch,
                            cfg.seq_len,
                        );
                        let targets =
                            HostTensor::i32(vec![cfg.microbatch, cfg.seq_len], tgt);
                        let whead = params.head.as_ref().unwrap().clone();
                        let mut out = rt.execute(
                            "head_loss_grad",
                            &[whead, state.final_h.clone().unwrap(), targets],
                        )?;
                        let loss = out[0].as_f32()?[0] as f64;
                        loss_sum += loss;
                        loss_count += 1;
                        let gx = out.remove(1);
                        let gw = out.remove(1);
                        let head_layer = env.map.num_layers() - 1;
                        let head_frozen = frozen_for(mb, head_layer, ratio);
                        track_freeze(
                            &mut frozen_weighted,
                            &mut frozen_events,
                            head_frozen,
                            layer_params[head_layer],
                        );
                        if !head_frozen {
                            let idx = grads.len() - 1;
                            axpy(&mut grads[idx], gw.as_f32()?);
                            layer_contrib[head_layer] += 1;
                        }
                        gx
                    } else {
                        incoming.unwrap()
                    };

                    // Blocks in reverse model order.
                    for (local_idx, &layer) in local_blocks.iter().enumerate().rev() {
                        let frozen = frozen_for(mb, layer, ratio);
                        track_freeze(
                            &mut frozen_weighted,
                            &mut frozen_events,
                            frozen,
                            layer_params[layer],
                        );
                        let b = &params.blocks[local_idx];
                        let x_in = state.block_inputs[local_idx].clone();
                        if frozen {
                            // Figure 3: dgrad only — the wgrad share of
                            // this layer's backward is genuinely skipped.
                            let mut inputs: Vec<HostTensor> = b.tensors.clone();
                            inputs.push(x_in);
                            inputs.push(gy);
                            gy = rt.execute("block_dgrad", &inputs)?.remove(0);
                        } else {
                            let mut inputs: Vec<HostTensor> = b.tensors.clone();
                            inputs.extend(zero_masks.iter().cloned());
                            inputs.push(x_in);
                            inputs.push(gy);
                            let mut out = rt.execute("block_bwd", &inputs)?;
                            gy = out.remove(0);
                            let base = tensor_base(&params, local_idx);
                            for (k, g) in out.iter().enumerate() {
                                axpy(&mut grads[base + k], g.as_f32()?);
                            }
                            layer_contrib[layer] += 1;
                        }
                    }

                    // Embedding wgrad (stage 0).
                    if is_first {
                        let emb_frozen = frozen_for(mb, 0, ratio);
                        track_freeze(
                            &mut frozen_weighted,
                            &mut frozen_events,
                            emb_frozen,
                            layer_params[0],
                        );
                        if !emb_frozen {
                            let tok = HostTensor::i32(
                                vec![cfg.microbatch, cfg.seq_len],
                                state.tokens.clone().unwrap(),
                            );
                            let gemb =
                                rt.execute("embed_wgrad", &[tok, gy.clone()])?.remove(0);
                            axpy(&mut grads[0], gemb.as_f32()?);
                            layer_contrib[0] += 1;
                        }
                    }
                    timings.push((action, start.elapsed().as_secs_f64()));
                    if !is_first {
                        env.bwd_tx.as_ref().expect("bwd_tx").send(gy).ok();
                    }
                }
                // The real engine runs combined-backward schedules
                // (GPipe / 1F1B); ZBV's split units are simulator-only.
                ActionKind::BackwardDgrad | ActionKind::BackwardWgrad => {
                    return Err(anyhow!("engine does not execute split-backward schedules"));
                }
            }
        }

        // ---- optimizer step (update rule eq. 20: mean of masked
        // microbatch gradients; layers with zero contributions skip) ----
        let inv_m = 1.0 / env.microbatches as f32;
        apply_updates(
            &mut params,
            &local_blocks,
            &mut optimizer,
            &mut grads,
            lr,
            inv_m,
            &layer_contrib,
            &mut layer_deltas,
        );

        let deltas = if collect_deltas {
            let mut out = Vec::new();
            for (layer, d) in layer_deltas.iter_mut().enumerate() {
                if layer_params[layer] > 0 {
                    out.push((
                        layer,
                        UnitDelta { l2: d.sq.sqrt(), signed: d.signed, abs: d.abs },
                    ));
                    *d = LayerDelta::default();
                }
            }
            out
        } else {
            Vec::new()
        };

        env.report_tx
            .send(StepReport {
                stage,
                timings,
                loss: (loss_count > 0).then(|| loss_sum / loss_count as f64),
                deltas,
                frozen_fraction: if frozen_events == 0 {
                    0.0
                } else {
                    frozen_weighted / frozen_events as f64
                },
            })
            .ok();
    }
}

fn axpy(acc: &mut [f32], g: &[f32]) {
    debug_assert_eq!(acc.len(), g.len());
    for (a, &b) in acc.iter_mut().zip(g) {
        *a += b;
    }
}

fn track_freeze(weighted: &mut f64, events: &mut usize, frozen: bool, params: usize) {
    if frozen {
        *weighted += params as f64;
    }
    *events += params;
}

/// Optimizer tensor index where local block `local_idx`'s tensors start.
fn tensor_base(params: &StageParams, local_idx: usize) -> usize {
    let embed_off = params.embed.is_some() as usize;
    embed_off + local_idx * params.blocks[0].tensors.len()
}

/// Parameter count per global layer on this stage (0 elsewhere).
fn layer_param_counts(
    params: &StageParams,
    local_blocks: &[usize],
    num_layers: usize,
) -> Vec<usize> {
    let mut out = vec![0usize; num_layers];
    if let Some(e) = &params.embed {
        out[0] = e.len();
    }
    for (i, &layer) in local_blocks.iter().enumerate() {
        out[layer] = params.blocks[i].param_count();
    }
    if let Some(h) = &params.head {
        out[num_layers - 1] = h.len();
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn apply_updates(
    params: &mut StageParams,
    local_blocks: &[usize],
    optimizer: &mut Optimizer,
    grads: &mut [Vec<f32>],
    lr: f64,
    inv_m: f32,
    layer_contrib: &[usize],
    layer_deltas: &mut [LayerDelta],
) {
    let num_layers = layer_deltas.len();
    let mut idx = 0usize;
    let mut do_tensor = |tensor: &mut HostTensor,
                         layer: usize,
                         optimizer: &mut Optimizer,
                         grads: &mut [Vec<f32>],
                         idx: &mut usize| {
        let frozen = layer_contrib[layer] == 0;
        let g = &mut grads[*idx];
        g.iter_mut().for_each(|x| *x *= inv_m);
        let stats: UpdateStats =
            optimizer.step(*idx, tensor.as_f32_mut().unwrap(), g, lr, frozen);
        layer_deltas[layer].signed += stats.signed;
        layer_deltas[layer].abs += stats.abs;
        layer_deltas[layer].sq += stats.sq;
        *idx += 1;
    };
    if let Some(e) = params.embed.as_mut() {
        do_tensor(e, 0, optimizer, grads, &mut idx);
    }
    for (i, &layer) in local_blocks.iter().enumerate() {
        for t in params.blocks[i].tensors.iter_mut() {
            do_tensor(t, layer, optimizer, grads, &mut idx);
        }
    }
    if let Some(h) = params.head.as_mut() {
        do_tensor(h, num_layers - 1, optimizer, grads, &mut idx);
    }
}
