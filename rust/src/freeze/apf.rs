//! APF baseline (Chen et al. 2023, §2.3): freezes parameters whose
//! updates oscillate without a clear trend, measured by the *effective
//! perturbation score*
//!
//!   Score_K = |E_K| / E_K^abs,
//!   E_K     = α E_{K−1} + (1−α) Δ_K,
//!   E_K^abs = α E_{K−1}^abs + (1−α) |Δ_K|          (eq. 2)
//!
//! at periodic stability checks, where Δ_K is the cumulative parameter
//! update since the previous check. Units whose score falls below T_APF
//! are frozen. APF is pipeline-unaware: its freeze decisions ignore
//! schedule structure, which is exactly the over-freezing failure mode
//! Figure 1(b) illustrates.

use crate::freeze::layout::ModelLayout;
use crate::freeze::{Controller, FreezePlan, PhaseConfig, UnitDelta};
use crate::types::{Action, ActionKind, FreezeMethod};

/// APF tunables (eq. 2 and the check cadence).
#[derive(Clone, Debug)]
pub struct ApfConfig {
    /// Freezing threshold T_APF (Table 3: 1e-4 … 1e-2 depending on task).
    pub threshold: f64,
    /// EMA factor α of eq. 2.
    pub alpha: f64,
    /// Steps between stability checks.
    pub check_interval: usize,
}

impl Default for ApfConfig {
    fn default() -> Self {
        ApfConfig { threshold: 0.3, alpha: 0.5, check_interval: 10 }
    }
}

/// The APF baseline controller state.
pub struct Apf {
    cfg: ApfConfig,
    layout: ModelLayout,
    phases: PhaseConfig,
    /// E_K and E_K^abs per unit.
    e: Vec<f64>,
    e_abs: Vec<f64>,
    /// Latest scores (1.0 = trending, 0.0 = oscillating/stable).
    score: Vec<f64>,
    /// Current frozen mask.
    frozen: Vec<bool>,
    /// Number of stability checks performed.
    checks: usize,
    last_check_step: usize,
    /// Cached per-stage frozen fractions.
    stage_frac: Vec<f64>,
    /// Actions of one batch, used to emit per-action AFRs.
    actions: Vec<Action>,
}

impl Apf {
    /// A fresh controller (no unit frozen, scores at 1.0).
    pub fn new(cfg: ApfConfig, layout: ModelLayout, phases: PhaseConfig) -> Apf {
        let n = layout.num_units();
        let stages = layout.num_stages;
        Apf {
            cfg,
            layout,
            phases,
            e: vec![0.0; n],
            e_abs: vec![0.0; n],
            score: vec![1.0; n],
            frozen: vec![false; n],
            checks: 0,
            last_check_step: 0,
            stage_frac: vec![0.0; stages],
            actions: Vec::new(),
        }
    }

    /// Let the environment declare the batch's actions once so plans can
    /// enumerate backward actions. (Factory wiring calls this lazily via
    /// `ensure_actions`.)
    pub fn set_actions(&mut self, actions: Vec<Action>) {
        self.actions = actions;
    }

    fn stability_check(&mut self) {
        self.checks += 1;
        for u in 0..self.layout.num_units() {
            self.score[u] = if self.e_abs[u] > 0.0 {
                (self.e[u].abs() / self.e_abs[u]).clamp(0.0, 1.0)
            } else {
                // Never updated (or fully cancelled): treat as stable.
                0.0
            };
            self.frozen[u] = self.score[u] < self.cfg.threshold;
        }
        for s in 0..self.layout.num_stages {
            self.stage_frac[s] = self.layout.frozen_fraction_of_stage(&self.frozen, s);
        }
    }

    /// Continuous freeze priority for the hybrid variants (Appendix C.2):
    /// units already in APF's mask first, then by descending stability.
    pub fn priorities(&self) -> Vec<f64> {
        (0..self.layout.num_units())
            .map(|u| {
                let base = if self.frozen[u] { 10.0 } else { 0.0 };
                base + (1.0 - self.score[u])
            })
            .collect()
    }

    /// The current frozen-unit mask.
    pub fn frozen_mask(&self) -> &[bool] {
        &self.frozen
    }

    /// Latest per-unit effective perturbation scores.
    pub fn scores(&self) -> &[f64] {
        &self.score
    }
}

impl Controller for Apf {
    fn method(&self) -> FreezeMethod {
        FreezeMethod::Apf
    }

    fn plan(&mut self, t: usize) -> FreezePlan {
        if t <= self.phases.t_warmup || self.checks == 0 {
            return FreezePlan::none();
        }
        let mut plan = FreezePlan::none();
        for a in &self.actions {
            if a.kind.freezable() {
                let frac = self.stage_frac[a.stage.min(self.layout.num_stages - 1)];
                if frac > 0.0 {
                    plan.afr.insert(*a, frac);
                }
            }
        }
        plan.priority = Some(
            (0..self.layout.num_units())
                .map(|u| if self.frozen[u] { 1.0 } else { 0.0 })
                .collect(),
        );
        plan
    }

    fn observe_updates(&mut self, t: usize, deltas: &[UnitDelta]) {
        assert_eq!(deltas.len(), self.layout.num_units());
        if t <= self.phases.t_warmup {
            return;
        }
        // eq. 2 EMA update with the window-cumulative Δ_K.
        let a = self.cfg.alpha;
        for (u, d) in deltas.iter().enumerate() {
            self.e[u] = a * self.e[u] + (1.0 - a) * d.signed;
            self.e_abs[u] = a * self.e_abs[u] + (1.0 - a) * d.abs;
        }
        if t - self.last_check_step >= self.cfg.check_interval || self.last_check_step == 0 {
            self.last_check_step = t;
            self.stability_check();
        }
    }
}

/// Helper for environments: enumerate freezable backward actions for a
/// schedule once, to hand to metric-driven controllers.
pub fn backward_actions(schedule: &crate::schedule::Schedule) -> Vec<Action> {
    schedule
        .all_actions()
        .into_iter()
        .filter(|a| matches!(a.kind, ActionKind::Backward | ActionKind::BackwardWgrad))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::types::ScheduleKind;

    fn make() -> Apf {
        let layout = ModelLayout::uniform(4, 2, 100, 2);
        let mut apf = Apf::new(
            ApfConfig { threshold: 0.3, alpha: 0.9, check_interval: 1 },
            layout,
            PhaseConfig::new(5, 10, 20),
        );
        let s = Schedule::build(ScheduleKind::GPipe, 2, 2, 1);
        apf.set_actions(s.all_actions());
        apf
    }

    fn deltas(signed: &[f64]) -> Vec<UnitDelta> {
        signed
            .iter()
            .map(|&s| UnitDelta { l2: s.abs(), signed: s, abs: s.abs() })
            .collect()
    }

    #[test]
    fn oscillating_units_freeze_trending_units_do_not() {
        let mut apf = make();
        // Units 0..4: oscillate ±1; units 4..8: steady drift +1.
        for t in 6..=30 {
            let sign = if t % 2 == 0 { 1.0 } else { -1.0 };
            let d: Vec<f64> = (0..8).map(|u| if u < 4 { sign } else { 1.0 }).collect();
            apf.observe_updates(t, &deltas(&d));
        }
        let mask = apf.frozen_mask();
        assert!(mask[..4].iter().all(|&b| b), "oscillating units should freeze: {mask:?}");
        assert!(mask[4..].iter().all(|&b| !b), "trending units must stay live: {mask:?}");
    }

    #[test]
    fn plan_reports_stage_fractions() {
        let mut apf = make();
        for t in 6..=30 {
            let sign = if t % 2 == 0 { 1.0 } else { -1.0 };
            let d: Vec<f64> = (0..8).map(|u| if u < 4 { sign } else { 1.0 }).collect();
            apf.observe_updates(t, &deltas(&d));
        }
        let plan = apf.plan(31);
        // Units 0..4 = layers 0..2 = stage 0 fully frozen; stage 1 live.
        let b0 = Action::b(0, 0);
        let b1 = Action::b(0, 1);
        assert!((plan.ratio_of(&b0) - 1.0).abs() < 1e-9);
        assert_eq!(plan.ratio_of(&b1), 0.0);
    }

    #[test]
    fn silent_before_first_check_and_during_warmup() {
        let mut apf = make();
        assert!(apf.plan(3).afr.is_empty());
        // Updates during warm-up are ignored.
        apf.observe_updates(3, &deltas(&[0.0; 8]));
        assert!(apf.plan(6).afr.is_empty());
    }

    #[test]
    fn frozen_units_stay_frozen() {
        let mut apf = make();
        for t in 6..=20 {
            let sign = if t % 2 == 0 { 1.0 } else { -1.0 };
            apf.observe_updates(t, &deltas(&[sign; 8]));
        }
        assert!(apf.frozen_mask().iter().all(|&b| b));
        // Frozen ⇒ zero future updates ⇒ scores stay below threshold.
        for t in 21..=40 {
            apf.observe_updates(t, &deltas(&[0.0; 8]));
        }
        assert!(apf.frozen_mask().iter().all(|&b| b));
    }

    #[test]
    fn priorities_rank_frozen_first() {
        let mut apf = make();
        for t in 6..=20 {
            let sign = if t % 2 == 0 { 1.0 } else { -1.0 };
            let d: Vec<f64> = (0..8).map(|u| if u < 4 { sign } else { 1.0 }).collect();
            apf.observe_updates(t, &deltas(&d));
        }
        let pri = apf.priorities();
        assert!(pri[0] > pri[5]);
    }
}
