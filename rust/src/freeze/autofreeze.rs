//! AutoFreeze baseline (Liu et al. 2021, §2.3): monotonic prefix freezing
//! driven by the per-layer *gradient-norm change*
//!
//!   Score_K = | ‖Δ_{K−1}‖ − ‖Δ_K‖ | / ‖Δ_{K−1}‖            (eq. 1)
//!
//! where Δ_K is the layer's cumulative parameter update since the
//! previous stability check. A layer freezes when (i) every preceding
//! layer is already frozen and (ii) its score lies in the lower
//! P_Auto-th percentile among all layers. Once frozen, a layer stays
//! frozen (the prefix only grows).

use crate::freeze::layout::ModelLayout;
use crate::freeze::{Controller, FreezePlan, PhaseConfig, UnitDelta};
use crate::types::{Action, FreezeMethod};
use crate::util::stats::percentile;

/// AutoFreeze tunables (eq. 1 percentile and check cadence).
#[derive(Clone, Debug)]
pub struct AutoFreezeConfig {
    /// Percentile P_Auto (Table 3 uses 80%).
    pub percentile: f64,
    /// Steps between stability checks.
    pub check_interval: usize,
}

impl Default for AutoFreezeConfig {
    fn default() -> Self {
        AutoFreezeConfig { percentile: 80.0, check_interval: 10 }
    }
}

/// The AutoFreeze baseline controller state.
pub struct AutoFreeze {
    cfg: AutoFreezeConfig,
    layout: ModelLayout,
    phases: PhaseConfig,
    /// ‖Δ_{K−1}‖ per layer from the previous check.
    prev_norms: Option<Vec<f64>>,
    /// Scores from the latest check.
    scores: Vec<f64>,
    /// Frozen prefix length (layers 0..prefix are frozen).
    prefix: usize,
    checks: usize,
    last_check_step: usize,
    stage_frac: Vec<f64>,
    actions: Vec<Action>,
    /// Window accumulator of per-unit signed updates (for layer norms).
    acc_signed: Vec<f64>,
}

impl AutoFreeze {
    /// A fresh controller (empty prefix).
    pub fn new(cfg: AutoFreezeConfig, layout: ModelLayout, phases: PhaseConfig) -> AutoFreeze {
        let layers = layout.num_layers();
        let units = layout.num_units();
        let stages = layout.num_stages;
        AutoFreeze {
            cfg,
            layout,
            phases,
            prev_norms: None,
            scores: vec![f64::INFINITY; layers],
            prefix: 0,
            checks: 0,
            last_check_step: 0,
            stage_frac: vec![0.0; stages],
            actions: Vec::new(),
            acc_signed: vec![0.0; units],
        }
    }

    /// Declare the batch's actions so plans can enumerate backwards.
    pub fn set_actions(&mut self, actions: Vec<Action>) {
        self.actions = actions;
    }

    /// Number of layers in the frozen prefix.
    pub fn frozen_prefix(&self) -> usize {
        self.prefix
    }

    /// Latest per-layer norm-change scores.
    pub fn layer_scores(&self) -> &[f64] {
        &self.scores
    }

    /// Layer norms ‖Δ_K‖ from the window accumulator: the L2 norm of the
    /// vector of per-unit cumulative signed updates (exact for
    /// per-parameter units).
    fn layer_norms(&self) -> Vec<f64> {
        let mut sq = vec![0.0f64; self.layout.num_layers()];
        for u in 0..self.layout.num_units() {
            sq[self.layout.unit_layer[u]] += self.acc_signed[u] * self.acc_signed[u];
        }
        sq.into_iter().map(f64::sqrt).collect()
    }

    fn stability_check(&mut self) {
        let norms = self.layer_norms();
        self.acc_signed.iter_mut().for_each(|x| *x = 0.0);
        let Some(prev) = self.prev_norms.replace(norms.clone()) else {
            // First check only primes ‖Δ_{K−1}‖.
            self.checks += 1;
            return;
        };
        self.checks += 1;
        let layers = self.layout.num_layers();
        for l in 0..layers {
            self.scores[l] = if prev[l] > 0.0 {
                (prev[l] - norms[l]).abs() / prev[l]
            } else if norms[l] > 0.0 {
                f64::INFINITY
            } else {
                0.0 // frozen layer: unchanged, trivially stable
            };
        }
        // Percentile threshold over *all* layers' scores (eq. 1 rule ii),
        // with infinities clipped for percentile computation.
        let finite: Vec<f64> =
            self.scores.iter().map(|&s| if s.is_finite() { s } else { 1e9 }).collect();
        let thresh = percentile(&finite, self.cfg.percentile);
        // Rule (i): extend the frozen prefix while layers qualify.
        while self.prefix < layers && self.scores[self.prefix] <= thresh {
            self.prefix += 1;
        }
        // Cache stage fractions from the prefix mask.
        let mask = self.frozen_mask();
        for s in 0..self.layout.num_stages {
            self.stage_frac[s] = self.layout.frozen_fraction_of_stage(&mask, s);
        }
    }

    /// Frozen-unit mask implied by the prefix.
    pub fn frozen_mask(&self) -> Vec<bool> {
        (0..self.layout.num_units())
            .map(|u| self.layout.unit_layer[u] < self.prefix)
            .collect()
    }

    /// Hybrid priority (Appendix C.2): frozen prefix first, then layers
    /// by measured stability (small norm-change score), falling back to
    /// front-first order before the first scored check.
    pub fn priorities(&self) -> Vec<f64> {
        let layers = self.layout.num_layers().max(1) as f64;
        (0..self.layout.num_units())
            .map(|u| {
                let l = self.layout.unit_layer[u];
                let base = if l < self.prefix { 10.0 } else { 0.0 };
                let s = self.scores[l];
                let stability = if s.is_finite() {
                    1.0 / (1.0 + s)
                } else {
                    (layers - l as f64) / layers
                };
                base + stability
            })
            .collect()
    }
}

impl Controller for AutoFreeze {
    fn method(&self) -> FreezeMethod {
        FreezeMethod::AutoFreeze
    }

    fn plan(&mut self, t: usize) -> FreezePlan {
        if t <= self.phases.t_warmup || self.prefix == 0 {
            return FreezePlan::none();
        }
        let mut plan = FreezePlan::none();
        for a in &self.actions {
            if a.kind.freezable() {
                let frac = self.stage_frac[a.stage.min(self.layout.num_stages - 1)];
                if frac > 0.0 {
                    plan.afr.insert(*a, frac);
                }
            }
        }
        let mask = self.frozen_mask();
        plan.priority =
            Some(mask.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect());
        plan
    }

    fn observe_updates(&mut self, t: usize, deltas: &[UnitDelta]) {
        assert_eq!(deltas.len(), self.layout.num_units());
        if t <= self.phases.t_warmup {
            return;
        }
        for (acc, d) in self.acc_signed.iter_mut().zip(deltas) {
            *acc += d.signed;
        }
        if t - self.last_check_step >= self.cfg.check_interval || self.last_check_step == 0 {
            self.last_check_step = t;
            self.stability_check();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::types::ScheduleKind;

    fn make(pct: f64) -> AutoFreeze {
        let layout = ModelLayout::uniform(4, 1, 100, 2);
        let mut af = AutoFreeze::new(
            AutoFreezeConfig { percentile: pct, check_interval: 1 },
            layout,
            PhaseConfig::new(5, 10, 20),
        );
        let s = Schedule::build(ScheduleKind::GPipe, 2, 2, 1);
        af.set_actions(s.all_actions());
        af
    }

    /// Front layers converge (small norm change), back layers keep
    /// moving (large change): the frozen prefix should cover the front.
    #[test]
    fn freezes_converged_prefix() {
        let mut af = make(50.0);
        for t in 6..=30 {
            // Layer l's update norm: front layers constant (stable),
            // back layers growing each window (unstable).
            let d: Vec<UnitDelta> = (0..4)
                .map(|l| {
                    let mag = if l < 2 { 1.0 } else { 1.0 + 0.5 * t as f64 };
                    UnitDelta { l2: mag, signed: mag, abs: mag }
                })
                .collect();
            af.observe_updates(t, &d);
        }
        assert!(af.frozen_prefix() >= 2, "prefix {} < 2", af.frozen_prefix());
        assert!(af.frozen_prefix() < 4, "over-froze the moving tail");
    }

    #[test]
    fn prefix_is_monotone() {
        let mut af = make(80.0);
        let mut prev = 0;
        for t in 6..=40 {
            let d: Vec<UnitDelta> = (0..4)
                .map(|l| {
                    let mag = 1.0 + 0.2 * (t as f64) * (l as f64);
                    UnitDelta { l2: mag, signed: mag, abs: mag }
                })
                .collect();
            af.observe_updates(t, &d);
            assert!(af.frozen_prefix() >= prev, "prefix shrank");
            prev = af.frozen_prefix();
        }
    }

    #[test]
    fn plan_empty_until_first_freeze() {
        let mut af = make(80.0);
        assert!(af.plan(12).afr.is_empty());
    }

    #[test]
    fn plan_reflects_prefix_fractions() {
        let mut af = make(95.0);
        for t in 6..=30 {
            let d: Vec<UnitDelta> = (0..4)
                .map(|l| {
                    // Only layer 0 is stable.
                    let mag = if l == 0 { 1.0 } else { (t as f64) * (l as f64 + 1.0) };
                    UnitDelta { l2: mag, signed: mag, abs: mag }
                })
                .collect();
            af.observe_updates(t, &d);
        }
        let prefix = af.frozen_prefix();
        assert!(prefix >= 1);
        let plan = af.plan(31);
        // Stage 0 hosts layers 0..2 → frozen fraction = prefix/2 capped.
        let expect = (prefix.min(2) as f64) / 2.0;
        assert!((plan.ratio_of(&Action::b(0, 0)) - expect).abs() < 1e-9);
    }

    #[test]
    fn hybrid_priorities_prefer_front() {
        let af = make(80.0);
        let pri = af.priorities();
        assert!(pri[0] > pri[3], "front layers must outrank back layers");
    }
}
