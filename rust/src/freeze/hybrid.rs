//! Hybrid variants (§4.1, Appendix C.2): TimelyFreeze decides *how much*
//! to freeze per action (the LP budget), while a baseline metric decides
//! *which* parameters to freeze (Algorithm 2's metric-aware selection).

use crate::freeze::apf::{Apf, ApfConfig};
use crate::freeze::autofreeze::{AutoFreeze, AutoFreezeConfig};
use crate::freeze::layout::ModelLayout;
use crate::freeze::timely::TimelyFreeze;
use crate::freeze::{Controller, FreezePlan, UnitDelta};
use crate::types::{Action, FreezeMethod};
use std::collections::BTreeMap;

enum Metric {
    Apf(Apf),
    Auto(AutoFreeze),
}

/// A TimelyFreeze budget paired with a metric-driven selector.
pub struct Hybrid {
    timely: TimelyFreeze,
    metric: Metric,
}

impl Hybrid {
    /// TimelyFreeze+APF (Table 1's best-accuracy hybrid).
    pub fn with_apf(timely: TimelyFreeze, cfg: ApfConfig, layout: ModelLayout) -> Hybrid {
        // Reuse the Timely phase boundaries so the metric's warm-up gate
        // matches the budget controller's.
        let phases = crate::freeze::PhaseConfig::new(0, 1, 2);
        let _ = phases; // metric warm-up handled by observe gating below
        let apf = Apf::new(cfg, layout, crate::freeze::PhaseConfig::new(0, 1, 2));
        Hybrid { timely, metric: Metric::Apf(apf) }
    }

    /// TimelyFreeze+AutoFreeze.
    pub fn with_autofreeze(
        timely: TimelyFreeze,
        cfg: AutoFreezeConfig,
        layout: ModelLayout,
    ) -> Hybrid {
        let auto = AutoFreeze::new(cfg, layout, crate::freeze::PhaseConfig::new(0, 1, 2));
        Hybrid { timely, metric: Metric::Auto(auto) }
    }

    /// The wrapped budget controller.
    pub fn timely(&self) -> &TimelyFreeze {
        &self.timely
    }

    fn priorities(&self) -> Vec<f64> {
        match &self.metric {
            Metric::Apf(a) => a.priorities(),
            Metric::Auto(a) => a.priorities(),
        }
    }
}

impl Controller for Hybrid {
    fn method(&self) -> FreezeMethod {
        match self.metric {
            Metric::Apf(_) => FreezeMethod::TimelyApf,
            Metric::Auto(_) => FreezeMethod::TimelyAuto,
        }
    }

    fn plan(&mut self, t: usize) -> FreezePlan {
        // Budget from TimelyFreeze (Algorithm 2 input {r_i}); selection
        // priority from the baseline metric.
        let mut plan = self.timely.plan(t);
        if !plan.afr.is_empty() {
            plan.priority = Some(self.priorities());
        }
        plan
    }

    fn record_time(&mut self, t: usize, action: Action, duration: f64) {
        self.timely.record_time(t, action, duration);
    }

    fn observe_updates(&mut self, t: usize, deltas: &[UnitDelta]) {
        match &mut self.metric {
            Metric::Apf(a) => a.observe_updates(t, deltas),
            Metric::Auto(a) => a.observe_updates(t, deltas),
        }
    }

    fn expected_ratios(&self) -> Option<&BTreeMap<Action, f64>> {
        self.timely.expected_ratios()
    }

    fn replan_with_profile(&mut self, profile: &crate::cost::CostProfile) {
        // The budget half replans; metric selection is plan-independent.
        self.timely.replan_with_profile(profile);
    }

    fn set_stage_floor(&mut self, floor: Option<Vec<f64>>) {
        self.timely.set_stage_floor(floor);
    }

    fn planned_batch_time(&self) -> Option<f64> {
        Controller::planned_batch_time(&self.timely)
    }

    fn replan_failures(&self) -> usize {
        Controller::replan_failures(&self.timely)
    }

    fn degradation(&self) -> Option<&crate::freeze::DegradationReport> {
        Controller::degradation(&self.timely)
    }

    fn replan_with_model(&mut self, cost: &crate::cost::CostModel) {
        self.timely.replan_with_model(cost);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::freeze::timely::TimelyFreezeConfig;
    use crate::freeze::PhaseConfig;
    use crate::schedule::Schedule;
    use crate::types::{ActionKind, ScheduleKind};

    fn make_hybrid() -> (Hybrid, Schedule) {
        let schedule = Schedule::build(ScheduleKind::OneFOneB, 4, 8, 1);
        let layout = ModelLayout::uniform(8, 4, 1000, 4);
        let cfg = TimelyFreezeConfig {
            phases: PhaseConfig::new(10, 30, 50),
            r_max: 0.8,
            lambda: 1e-4,
        };
        let timely = TimelyFreeze::new(cfg, &schedule, layout.clone());
        (Hybrid::with_apf(timely, ApfConfig::default(), layout), schedule)
    }

    fn drive(h: &mut Hybrid, schedule: &Schedule) {
        for t in 1..=30 {
            let plan = h.plan(t);
            for a in schedule.all_actions() {
                let dur = match a.kind {
                    ActionKind::Forward => 1.0,
                    _ => 2.0 - plan.ratio_of(&a) * 1.2,
                };
                h.record_time(t, a, dur);
            }
            // Oscillating update stream → APF metric marks units stable.
            let sign = if t % 2 == 0 { 1.0 } else { -1.0 };
            let deltas: Vec<UnitDelta> = (0..32)
                .map(|_| UnitDelta { l2: 1.0, signed: sign, abs: 1.0 })
                .collect();
            h.observe_updates(t, &deltas);
        }
    }

    #[test]
    fn budget_from_timely_priority_from_metric() {
        let (mut h, schedule) = make_hybrid();
        drive(&mut h, &schedule);
        let plan = h.plan(60);
        assert!(!plan.afr.is_empty(), "hybrid should freeze after T_f");
        assert!(plan.priority.is_some(), "hybrid must attach metric priority");
        // Budget matches the pure TimelyFreeze expected ratios.
        let expected = h.expected_ratios().unwrap();
        for (a, &r) in expected {
            assert!((plan.ratio_of(a) - r).abs() < 1e-9);
        }
    }

    #[test]
    fn reports_hybrid_method() {
        let (h, _) = make_hybrid();
        assert_eq!(h.method(), FreezeMethod::TimelyApf);
    }

    #[test]
    fn no_priority_before_freezing_phase() {
        let (mut h, _) = make_hybrid();
        let plan = h.plan(5);
        assert!(plan.afr.is_empty());
        assert!(plan.priority.is_none());
    }
}
