//! Model layout: the bookkeeping map between parameter *units*, model
//! layers, and pipeline stages that every controller consumes.
//!
//! * A **layer** is a schedulable model block (transformer block,
//!   embedding, head, ConvNeXt stage slice, …).
//! * A **unit** is the granularity of freeze bookkeeping inside a layer:
//!   per-parameter (APF's original design), per-tensor block (real
//!   engine), or the layer itself (paper-scale simulator).
//! * A **stage** (virtual pipeline stage) owns a contiguous range of
//!   layers, assigned by a partitioning heuristic (`crate::partition`).

/// The unit ↔ layer ↔ stage bookkeeping map (see the module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelLayout {
    /// Parameter count per unit.
    pub unit_params: Vec<u64>,
    /// Layer owning each unit.
    pub unit_layer: Vec<usize>,
    /// Stage owning each layer.
    pub layer_stage: Vec<usize>,
    /// Total number of virtual stages.
    pub num_stages: usize,
}

impl ModelLayout {
    /// Validate internal consistency; panics on malformed layouts (these
    /// are constructed by code, not user input).
    pub fn new(
        unit_params: Vec<u64>,
        unit_layer: Vec<usize>,
        layer_stage: Vec<usize>,
        num_stages: usize,
    ) -> ModelLayout {
        assert_eq!(unit_params.len(), unit_layer.len(), "unit arrays disagree");
        assert!(!unit_params.is_empty(), "layout needs at least one unit");
        let num_layers = layer_stage.len();
        for &l in &unit_layer {
            assert!(l < num_layers, "unit references layer {l} ≥ {num_layers}");
        }
        for &s in &layer_stage {
            assert!(s < num_stages, "layer references stage {s} ≥ {num_stages}");
        }
        ModelLayout { unit_params, unit_layer, layer_stage, num_stages }
    }

    /// Uniform layout: `layers` layers of `units_per_layer` equal units of
    /// `params_per_unit` parameters, layers split evenly over stages.
    pub fn uniform(
        layers: usize,
        units_per_layer: usize,
        params_per_unit: u64,
        num_stages: usize,
    ) -> ModelLayout {
        assert!(layers >= num_stages, "fewer layers than stages");
        let layer_stage: Vec<usize> =
            (0..layers).map(|l| l * num_stages / layers).collect();
        let mut unit_params = Vec::new();
        let mut unit_layer = Vec::new();
        for l in 0..layers {
            for _ in 0..units_per_layer {
                unit_params.push(params_per_unit);
                unit_layer.push(l);
            }
        }
        ModelLayout::new(unit_params, unit_layer, layer_stage, num_stages)
    }

    /// Number of bookkeeping units.
    pub fn num_units(&self) -> usize {
        self.unit_params.len()
    }

    /// Number of model layers.
    pub fn num_layers(&self) -> usize {
        self.layer_stage.len()
    }

    /// Stage of a unit (through its layer).
    pub fn unit_stage(&self, unit: usize) -> usize {
        self.layer_stage[self.unit_layer[unit]]
    }

    /// Total parameters in the model.
    pub fn total_params(&self) -> u64 {
        self.unit_params.iter().sum()
    }

    /// Units belonging to a stage.
    pub fn units_of_stage(&self, stage: usize) -> Vec<usize> {
        (0..self.num_units()).filter(|&u| self.unit_stage(u) == stage).collect()
    }

    /// Layers belonging to a stage (ascending).
    pub fn layers_of_stage(&self, stage: usize) -> Vec<usize> {
        (0..self.num_layers()).filter(|&l| self.layer_stage[l] == stage).collect()
    }

    /// Parameter count per stage.
    pub fn params_of_stage(&self, stage: usize) -> u64 {
        (0..self.num_units())
            .filter(|&u| self.unit_stage(u) == stage)
            .map(|u| self.unit_params[u])
            .sum()
    }

    /// Parameter count of one layer.
    pub fn params_of_layer(&self, layer: usize) -> u64 {
        (0..self.num_units())
            .filter(|&u| self.unit_layer[u] == layer)
            .map(|u| self.unit_params[u])
            .sum()
    }

    /// Fraction of the model's parameters covered by a frozen-unit mask.
    pub fn frozen_fraction(&self, mask: &[bool]) -> f64 {
        assert_eq!(mask.len(), self.num_units());
        let frozen: u64 = (0..self.num_units())
            .filter(|&u| mask[u])
            .map(|u| self.unit_params[u])
            .sum();
        frozen as f64 / self.total_params().max(1) as f64
    }

    /// Fraction frozen within one stage.
    pub fn frozen_fraction_of_stage(&self, mask: &[bool], stage: usize) -> f64 {
        let total = self.params_of_stage(stage);
        if total == 0 {
            return 0.0;
        }
        let frozen: u64 = self
            .units_of_stage(stage)
            .iter()
            .filter(|&&u| mask[u])
            .map(|&u| self.unit_params[u])
            .sum();
        frozen as f64 / total as f64
    }

    /// Re-assign layers to stages (used by partition heuristics).
    pub fn with_layer_stage(&self, layer_stage: Vec<usize>, num_stages: usize) -> ModelLayout {
        ModelLayout::new(self.unit_params.clone(), self.unit_layer.clone(), layer_stage, num_stages)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_layout_partitions_evenly() {
        let l = ModelLayout::uniform(8, 2, 100, 4);
        assert_eq!(l.num_units(), 16);
        assert_eq!(l.num_layers(), 8);
        assert_eq!(l.total_params(), 1600);
        for s in 0..4 {
            assert_eq!(l.layers_of_stage(s).len(), 2);
            assert_eq!(l.params_of_stage(s), 400);
        }
    }

    #[test]
    fn unit_stage_mapping() {
        let l = ModelLayout::uniform(4, 1, 10, 2);
        assert_eq!(l.unit_stage(0), 0);
        assert_eq!(l.unit_stage(3), 1);
    }

    #[test]
    fn frozen_fraction_weighted_by_params() {
        let l = ModelLayout::new(vec![100, 300], vec![0, 1], vec![0, 0], 1);
        assert_eq!(l.frozen_fraction(&[true, false]), 0.25);
        assert_eq!(l.frozen_fraction(&[false, true]), 0.75);
        assert_eq!(l.frozen_fraction_of_stage(&[true, true], 0), 1.0);
    }

    #[test]
    #[should_panic]
    fn rejects_inconsistent_arrays() {
        ModelLayout::new(vec![1, 2], vec![0], vec![0], 1);
    }

    #[test]
    fn params_of_layer() {
        let l = ModelLayout::new(vec![10, 20, 30], vec![0, 0, 1], vec![0, 1], 2);
        assert_eq!(l.params_of_layer(0), 30);
        assert_eq!(l.params_of_layer(1), 30);
    }
}
