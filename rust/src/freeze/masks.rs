//! Frozen-unit selection: converts an action's actual freeze ratio into a
//! concrete unit mask for one stage.
//!
//! Two modes, matching the paper:
//! * **Uniform random** (§3.3): each unit of the stage is frozen
//!   independently with probability AFR, so `E[|I_i|] = AFR · N_s`
//!   (Algorithm 1 line 18). The RNG stream is derived from
//!   `(step, stage)` so every rank reconstructs identical masks without
//!   communication.
//! * **Priority-driven** (hybrids, Appendix C.2 / baselines): units are
//!   sorted by descending priority (most stable first) and frozen
//!   greedily until the stage's frozen-parameter fraction reaches AFR.

use crate::freeze::layout::ModelLayout;
use crate::util::rng::Rng;

/// Compute the frozen-unit mask (over *all* units; entries outside the
/// stage stay `false`) for one stage at the given ratio.
pub fn select_frozen_units(
    layout: &ModelLayout,
    stage: usize,
    ratio: f64,
    priority: Option<&[f64]>,
    rng: &mut Rng,
) -> Vec<bool> {
    let mut mask = Vec::new();
    select_frozen_units_into(layout, stage, ratio, priority, rng, &mut mask);
    mask
}

/// Allocation-free variant of [`select_frozen_units`] for per-step hot
/// loops: writes the mask into a caller-owned buffer (resized to the
/// unit count, cleared first). Identical RNG draw order, so masks match
/// the allocating variant bit-for-bit.
pub fn select_frozen_units_into(
    layout: &ModelLayout,
    stage: usize,
    ratio: f64,
    priority: Option<&[f64]>,
    rng: &mut Rng,
    mask: &mut Vec<bool>,
) {
    let n = layout.num_units();
    mask.clear();
    mask.resize(n, false);
    if ratio <= 0.0 {
        return;
    }
    match priority {
        None => {
            // Bernoulli(AFR) per unit — exact expectation, unbiased.
            // Units scanned in ascending order (the same order
            // `units_of_stage` yields) so the RNG stream is unchanged.
            let p = ratio.min(1.0);
            for u in 0..n {
                if layout.unit_stage(u) == stage && rng.bernoulli(p) {
                    mask[u] = true;
                }
            }
        }
        Some(pri) => {
            assert_eq!(pri.len(), n, "priority length mismatch");
            let units = layout.units_of_stage(stage);
            if units.is_empty() {
                return;
            }
            // Greedy: highest priority first; stop when the frozen
            // parameter mass reaches ratio · N_s.
            let mut sorted = units.clone();
            sorted.sort_by(|&a, &b| {
                pri[b].partial_cmp(&pri[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
            });
            let total: u64 = units.iter().map(|&u| layout.unit_params[u]).sum();
            let budget = (ratio.min(1.0) * total as f64).round() as u64;
            let mut frozen = 0u64;
            for &u in &sorted {
                if frozen >= budget {
                    break;
                }
                mask[u] = true;
                frozen += layout.unit_params[u];
            }
        }
    }
}

/// Merge per-stage masks into one model-wide mask (logical OR).
pub fn merge_masks(masks: &[Vec<bool>]) -> Vec<bool> {
    let n = masks.first().map(|m| m.len()).unwrap_or(0);
    let mut out = vec![false; n];
    for m in masks {
        assert_eq!(m.len(), n);
        for (o, &b) in out.iter_mut().zip(m) {
            *o |= b;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> ModelLayout {
        // 2 stages × 2 layers × 4 units of 100 params.
        ModelLayout::uniform(4, 4, 100, 2)
    }

    #[test]
    fn zero_ratio_freezes_nothing() {
        let l = layout();
        let mut rng = Rng::seed_from_u64(1);
        let m = select_frozen_units(&l, 0, 0.0, None, &mut rng);
        assert!(m.iter().all(|&b| !b));
    }

    #[test]
    fn random_selection_expectation() {
        let l = layout();
        let ratio = 0.6;
        let trials = 2000;
        let mut frozen = 0usize;
        let base = Rng::seed_from_u64(7);
        for t in 0..trials {
            let mut rng = base.derive(t as u64, 0);
            let m = select_frozen_units(&l, 0, ratio, None, &mut rng);
            frozen += m.iter().filter(|&&b| b).count();
        }
        let per_trial = frozen as f64 / trials as f64;
        // Stage 0 has 8 units → expect 4.8 frozen per trial.
        assert!((per_trial - 4.8).abs() < 0.15, "E[|I|]={per_trial}");
    }

    #[test]
    fn random_selection_stays_in_stage() {
        let l = layout();
        let mut rng = Rng::seed_from_u64(3);
        let m = select_frozen_units(&l, 1, 1.0, None, &mut rng);
        for u in 0..l.num_units() {
            if l.unit_stage(u) == 1 {
                assert!(m[u]);
            } else {
                assert!(!m[u]);
            }
        }
    }

    #[test]
    fn priority_selection_takes_top_units() {
        let l = layout();
        // Priorities: unit index (later units more stable).
        let pri: Vec<f64> = (0..l.num_units()).map(|u| u as f64).collect();
        let mut rng = Rng::seed_from_u64(5);
        let m = select_frozen_units(&l, 0, 0.5, Some(&pri), &mut rng);
        // Stage 0 units are 0..8; budget = 4 units (equal sizes); the
        // top-priority ones are 7,6,5,4.
        let frozen: Vec<usize> = (0..8).filter(|&u| m[u]).collect();
        assert_eq!(frozen, vec![4, 5, 6, 7]);
    }

    #[test]
    fn priority_respects_param_mass() {
        // Unequal unit sizes: one giant unit uses the whole budget.
        let l = ModelLayout::new(vec![900, 50, 50], vec![0, 0, 0], vec![0], 1);
        let pri = vec![3.0, 2.0, 1.0];
        let mut rng = Rng::seed_from_u64(5);
        let m = select_frozen_units(&l, 0, 0.9, Some(&pri), &mut rng);
        assert_eq!(m, vec![true, false, false]);
    }

    #[test]
    fn deterministic_given_same_stream() {
        let l = layout();
        let base = Rng::seed_from_u64(42);
        let m1 = select_frozen_units(&l, 0, 0.5, None, &mut base.derive(9, 0));
        let m2 = select_frozen_units(&l, 0, 0.5, None, &mut base.derive(9, 0));
        assert_eq!(m1, m2);
    }

    #[test]
    fn into_variant_matches_allocating_variant() {
        let l = layout();
        let base = Rng::seed_from_u64(4242);
        for stage in 0..2 {
            for &ratio in &[0.0, 0.3, 0.7, 1.0] {
                let a = select_frozen_units(&l, stage, ratio, None, &mut base.derive(1, 2));
                let mut b = vec![true; 3]; // wrong size + dirty: must reset
                select_frozen_units_into(&l, stage, ratio, None, &mut base.derive(1, 2), &mut b);
                assert_eq!(a, b, "stage {stage} ratio {ratio}");
            }
        }
        let pri: Vec<f64> = (0..l.num_units()).map(|u| u as f64).collect();
        let a = select_frozen_units(&l, 0, 0.5, Some(&pri), &mut base.derive(3, 4));
        let mut b = Vec::new();
        select_frozen_units_into(&l, 0, 0.5, Some(&pri), &mut base.derive(3, 4), &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn merge_masks_or() {
        let a = vec![true, false, false];
        let b = vec![false, false, true];
        assert_eq!(merge_masks(&[a, b]), vec![true, false, true]);
    }
}
