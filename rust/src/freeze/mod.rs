//! Freezing controllers: TimelyFreeze (§3), the APF and AutoFreeze
//! baselines (§2.3), the hybrid variants (§4.1 / Appendix C.2), and the
//! no-freezing reference.
//!
//! ## The controller contract
//!
//! Controllers are driven by an *environment* — either the real pipeline
//! engine (`crate::engine`) or the discrete-event simulator
//! (`crate::sim`). Per training step `t` the environment:
//!
//! 1. calls [`Controller::plan`] to obtain a [`FreezePlan`] — per-action
//!    actual freeze ratios (AFR, eq. 9) plus an optional per-unit
//!    priority for metric-driven selection;
//! 2. executes the step, shrinking freezable action durations by their
//!    AFR and masking optimizer updates of the frozen units;
//! 3. reports measured action durations via [`Controller::record_time`]
//!    (Alg. 1 line 5) and, at stability-check steps, per-unit update
//!    statistics via [`Controller::observe_updates`].
//!
//! A *unit* is the granularity of parameter bookkeeping: individual
//! parameters in APF's original formulation; per-tensor blocks in the
//! real engine (exact for uniform-random selection, memory-bounded for
//! metric selection); per-layer groups in the paper-scale simulator.

pub mod apf;
pub mod autofreeze;
pub mod hybrid;
pub mod layout;
pub mod masks;
pub mod none;
pub mod timely;

pub use apf::{Apf, ApfConfig};
pub use autofreeze::{AutoFreeze, AutoFreezeConfig};
pub use hybrid::Hybrid;
pub use layout::ModelLayout;
pub use masks::{select_frozen_units, select_frozen_units_into};
pub use none::NoFreezing;
pub use timely::{TimelyFreeze, TimelyFreezeConfig};

use crate::types::{Action, FreezeMethod};
use std::collections::BTreeMap;

/// Phase boundaries {T_w, T_m, T_f} (Table 3 row "Phase Boundaries").
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PhaseConfig {
    /// Last step of the warm-up phase (aligned with LR warm-up, §3.1).
    pub t_warmup: usize,
    /// Last step of the monitoring phase.
    pub t_monitor: usize,
    /// Last step of the progressive-freezing ramp.
    pub t_freeze: usize,
}

impl PhaseConfig {
    pub fn new(t_warmup: usize, t_monitor: usize, t_freeze: usize) -> Self {
        assert!(t_warmup < t_monitor, "T_w must precede T_m");
        assert!(t_monitor < t_freeze, "T_m must precede T_f");
        PhaseConfig { t_warmup, t_monitor, t_freeze }
    }

    /// Midpoint of the monitoring window: the boundary between
    /// upper-bound (no freezing) and lower-bound (full freezing)
    /// monitoring (§3.1).
    pub fn monitor_mid(&self) -> usize {
        self.t_warmup + (self.t_monitor - self.t_warmup) / 2
    }
}

/// Per-unit cumulative-update statistics since the previous stability
/// check, as produced by the environment.
#[derive(Clone, Copy, Debug, Default)]
pub struct UnitDelta {
    /// ‖Δ‖₂ of the unit's cumulative update (AutoFreeze, eq. 1).
    pub l2: f64,
    /// Signed representative update Σδ (APF's E recurrence, eq. 2).
    pub signed: f64,
    /// Σ|δ| (APF's E^abs recurrence).
    pub abs: f64,
}

/// The controller's decision for one training step.
#[derive(Clone, Debug, Default)]
pub struct FreezePlan {
    /// Actual freeze ratio per freezable action (missing ⇒ 0). The
    /// environment shrinks the action's duration by this ratio and
    /// freezes the corresponding fraction of the stage's parameters.
    pub afr: BTreeMap<Action, f64>,
    /// Optional per-unit freeze priority (higher = freeze first). `None`
    /// means uniform random selection (§3.3).
    pub priority: Option<Vec<f64>>,
}

impl FreezePlan {
    pub fn none() -> FreezePlan {
        FreezePlan::default()
    }

    pub fn ratio_of(&self, a: &Action) -> f64 {
        self.afr.get(a).copied().unwrap_or(0.0)
    }

    /// Mean AFR over the supplied actions' freezable subset (0 if empty).
    pub fn mean_ratio(&self, actions: &[Action]) -> f64 {
        let freezable: Vec<&Action> = actions.iter().filter(|a| a.kind.freezable()).collect();
        if freezable.is_empty() {
            return 0.0;
        }
        freezable.iter().map(|a| self.ratio_of(a)).sum::<f64>() / freezable.len() as f64
    }
}

/// Common interface of all freezing methods.
pub trait Controller: Send {
    fn method(&self) -> FreezeMethod;

    /// Produce the freeze plan for step `t` (1-based, matching the
    /// paper's `t ∈ {1..T_total}`).
    fn plan(&mut self, t: usize) -> FreezePlan;

    /// Record a measured action duration for step `t` (monitoring).
    /// Controllers that do not monitor may ignore this.
    fn record_time(&mut self, _t: usize, _action: Action, _duration: f64) {}

    /// Feed per-unit cumulative-update statistics at a stability check.
    fn observe_updates(&mut self, _t: usize, _deltas: &[UnitDelta]) {}

    /// Expected freeze ratios r* once computed (TimelyFreeze family);
    /// `None` for metric-only baselines.
    fn expected_ratios(&self) -> Option<&BTreeMap<Action, f64>> {
        None
    }
}

/// Construct a controller by method with shared inputs. `schedule` is
/// needed by the TimelyFreeze family; baselines use `layout` + their own
/// config.
#[derive(Clone, Debug)]
pub struct ControllerFactory {
    pub phases: PhaseConfig,
    pub r_max: f64,
    pub lambda: f64,
    pub apf: ApfConfig,
    pub auto: AutoFreezeConfig,
}

impl ControllerFactory {
    pub fn build(
        &self,
        method: FreezeMethod,
        schedule: &crate::schedule::Schedule,
        layout: &ModelLayout,
    ) -> Box<dyn Controller> {
        let timely_cfg = TimelyFreezeConfig {
            phases: self.phases,
            r_max: self.r_max,
            lambda: self.lambda,
        };
        match method {
            FreezeMethod::NoFreezing => Box::new(NoFreezing::new()),
            FreezeMethod::Apf => {
                let mut apf = Apf::new(self.apf.clone(), layout.clone(), self.phases);
                apf.set_actions(schedule.all_actions());
                Box::new(apf)
            }
            FreezeMethod::AutoFreeze => {
                let mut auto = AutoFreeze::new(self.auto.clone(), layout.clone(), self.phases);
                auto.set_actions(schedule.all_actions());
                Box::new(auto)
            }
            FreezeMethod::TimelyFreeze => {
                Box::new(TimelyFreeze::new(timely_cfg, schedule, layout.clone()))
            }
            FreezeMethod::TimelyApf => Box::new(Hybrid::with_apf(
                TimelyFreeze::new(timely_cfg, schedule, layout.clone()),
                self.apf.clone(),
                layout.clone(),
            )),
            FreezeMethod::TimelyAuto => Box::new(Hybrid::with_autofreeze(
                TimelyFreeze::new(timely_cfg, schedule, layout.clone()),
                self.auto.clone(),
                layout.clone(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_config_midpoint() {
        let p = PhaseConfig::new(60, 100, 200);
        assert_eq!(p.monitor_mid(), 80);
        let p = PhaseConfig::new(160, 200, 250);
        assert_eq!(p.monitor_mid(), 180);
    }

    #[test]
    #[should_panic]
    fn phase_config_validates_order() {
        PhaseConfig::new(100, 100, 200);
    }

    #[test]
    fn plan_mean_ratio() {
        let mut plan = FreezePlan::none();
        plan.afr.insert(Action::b(0, 0), 0.5);
        plan.afr.insert(Action::b(1, 0), 0.7);
        let actions =
            vec![Action::f(0, 0), Action::b(0, 0), Action::b(1, 0), Action::b(2, 0)];
        // Forward excluded; b(2,0) counts as 0.
        assert!((plan.mean_ratio(&actions) - 0.4).abs() < 1e-12);
    }
}
