//! Freezing controllers: TimelyFreeze (§3), the APF and AutoFreeze
//! baselines (§2.3), the hybrid variants (§4.1 / Appendix C.2), and the
//! no-freezing reference.
//!
//! ## The controller contract
//!
//! Controllers are driven by an *environment* — either the real pipeline
//! engine (`crate::engine`) or the discrete-event simulator
//! (`crate::sim`). Per training step `t` the environment:
//!
//! 1. calls [`Controller::plan`] to obtain a [`FreezePlan`] — per-action
//!    actual freeze ratios (AFR, eq. 9) plus an optional per-unit
//!    priority for metric-driven selection;
//! 2. executes the step, shrinking freezable action durations by their
//!    AFR and masking optimizer updates of the frozen units;
//! 3. reports measured action durations via [`Controller::record_time`]
//!    (Alg. 1 line 5) and, at stability-check steps, per-unit update
//!    statistics via [`Controller::observe_updates`].
//!
//! A *unit* is the granularity of parameter bookkeeping: individual
//! parameters in APF's original formulation; per-tensor blocks in the
//! real engine (exact for uniform-random selection, memory-bounded for
//! metric selection); per-layer groups in the paper-scale simulator.

pub mod apf;
pub mod autofreeze;
pub mod hybrid;
pub mod layout;
pub mod masks;
pub mod none;
pub mod timely;

pub use apf::{Apf, ApfConfig};
pub use autofreeze::{AutoFreeze, AutoFreezeConfig};
pub use hybrid::Hybrid;
pub use layout::ModelLayout;
pub use masks::{select_frozen_units, select_frozen_units_into};
pub use none::NoFreezing;
pub use timely::{TimelyFreeze, TimelyFreezeConfig};

use crate::types::{Action, FreezeMethod};
use std::collections::BTreeMap;

/// Phase boundaries {T_w, T_m, T_f} (Table 3 row "Phase Boundaries").
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PhaseConfig {
    /// Last step of the warm-up phase (aligned with LR warm-up, §3.1).
    pub t_warmup: usize,
    /// Last step of the monitoring phase.
    pub t_monitor: usize,
    /// Last step of the progressive-freezing ramp.
    pub t_freeze: usize,
}

impl PhaseConfig {
    /// Validated construction; panics unless `T_w < T_m < T_f`.
    pub fn new(t_warmup: usize, t_monitor: usize, t_freeze: usize) -> Self {
        assert!(t_warmup < t_monitor, "T_w must precede T_m");
        assert!(t_monitor < t_freeze, "T_m must precede T_f");
        PhaseConfig { t_warmup, t_monitor, t_freeze }
    }

    /// Midpoint of the monitoring window: the boundary between
    /// upper-bound (no freezing) and lower-bound (full freezing)
    /// monitoring (§3.1).
    pub fn monitor_mid(&self) -> usize {
        self.t_warmup + (self.t_monitor - self.t_warmup) / 2
    }
}

/// Per-unit cumulative-update statistics since the previous stability
/// check, as produced by the environment.
#[derive(Clone, Copy, Debug, Default)]
pub struct UnitDelta {
    /// ‖Δ‖₂ of the unit's cumulative update (AutoFreeze, eq. 1).
    pub l2: f64,
    /// Signed representative update Σδ (APF's E recurrence, eq. 2).
    pub signed: f64,
    /// Σ|δ| (APF's E^abs recurrence).
    pub abs: f64,
}

/// The controller's decision for one training step.
#[derive(Clone, Debug, Default)]
pub struct FreezePlan {
    /// Actual freeze ratio per freezable action (missing ⇒ 0). The
    /// environment shrinks the action's duration by this ratio and
    /// freezes the corresponding fraction of the stage's parameters.
    pub afr: BTreeMap<Action, f64>,
    /// Optional per-unit freeze priority (higher = freeze first). `None`
    /// means uniform random selection (§3.3).
    pub priority: Option<Vec<f64>>,
}

impl FreezePlan {
    /// The empty plan: freeze nothing.
    pub fn none() -> FreezePlan {
        FreezePlan::default()
    }

    /// The plan's AFR for one action (0 when absent).
    pub fn ratio_of(&self, a: &Action) -> f64 {
        self.afr.get(a).copied().unwrap_or(0.0)
    }

    /// Mean AFR over the supplied actions' freezable subset (0 if empty).
    pub fn mean_ratio(&self, actions: &[Action]) -> f64 {
        let freezable: Vec<&Action> = actions.iter().filter(|a| a.kind.freezable()).collect();
        if freezable.is_empty() {
            return 0.0;
        }
        freezable.iter().map(|a| self.ratio_of(a)).sum::<f64>() / freezable.len() as f64
    }
}

/// Which rung of the degraded-mode ladder a failed replan landed on.
/// Ordered by severity: reusing the last feasible plan is the mildest
/// response, dropping to no-freeze safe mode the most drastic.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DegradationRung {
    /// First consecutive failure with a feasible plan installed: keep
    /// executing that plan unchanged — it is still valid for the world
    /// it was solved in.
    ReuseLastPlan,
    /// Sustained failure: replace `r*` with the memory floor clamped
    /// into `[0, r_max]` — the cheapest ratios that still fit the
    /// device budget, with no optimality claim.
    HeuristicFloor,
    /// Ladder exhausted (or no floor to clamp to): freeze nothing until
    /// a solve succeeds again. Slow but always safe.
    SafeMode,
}

impl DegradationRung {
    /// Stable lower-case name for reports and JSON output.
    pub fn name(self) -> &'static str {
        match self {
            DegradationRung::ReuseLastPlan => "reuse-last-plan",
            DegradationRung::HeuristicFloor => "heuristic-floor",
            DegradationRung::SafeMode => "safe-mode",
        }
    }
}

/// One failed replan and how the controller degraded around it.
#[derive(Clone, Debug)]
pub struct DegradationEvent {
    /// Training step at which the failed replan was attempted (0 when
    /// the controller was driven outside a stepped run).
    pub step: usize,
    /// Human-readable cause — the LP error, memory infeasibility, or
    /// whatever made the solve impossible.
    pub cause: String,
    /// Which rung of the solver's own fallback ladder the failing
    /// attempt last reported (`None` before any solve completed).
    pub solve_path: Option<crate::lp::SolvePath>,
    /// The degraded-mode rung the controller fell to.
    pub rung: DegradationRung,
}

/// Structured record of every degraded-mode episode of a run — the
/// replacement for the bare `replan_failures` counter. Populated by the
/// TimelyFreeze family, carried through
/// [`SimResult`](crate::sim::SimResult), and printed under
/// `TF_BENCH_JSON`.
#[derive(Clone, Debug, Default)]
pub struct DegradationReport {
    /// Failed replans in attempt order. The TimelyFreeze family caps
    /// this log at [`timely::DEGRADATION_LOG_CAP`] entries so a run
    /// that never recovers cannot grow it unboundedly; the
    /// `replan_failures` counter keeps the full tally.
    pub events: Vec<DegradationEvent>,
}

impl DegradationReport {
    /// No degraded-mode episode occurred.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of failed replans recorded.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// The most severe rung any failure reached (`None` when clean).
    pub fn worst(&self) -> Option<DegradationRung> {
        self.events.iter().map(|e| e.rung).max()
    }

    /// One-line summary for CLI warnings:
    /// `3 failed replans (worst rung: safe-mode), first at step 120: <cause>`.
    pub fn summary(&self) -> String {
        match (self.events.first(), self.worst()) {
            (Some(first), Some(worst)) => format!(
                "{} failed replan{} (worst rung: {}), first at step {}: {}",
                self.events.len(),
                if self.events.len() == 1 { "" } else { "s" },
                worst.name(),
                first.step,
                first.cause
            ),
            _ => "no degraded-mode episodes".to_string(),
        }
    }
}

/// Common interface of all freezing methods.
pub trait Controller: Send {
    /// Which method this controller implements.
    fn method(&self) -> FreezeMethod;

    /// Produce the freeze plan for step `t` (1-based, matching the
    /// paper's `t ∈ {1..T_total}`).
    fn plan(&mut self, t: usize) -> FreezePlan;

    /// Record a measured action duration for step `t` (monitoring).
    /// Controllers that do not monitor may ignore this.
    fn record_time(&mut self, _t: usize, _action: Action, _duration: f64) {}

    /// Feed per-unit cumulative-update statistics at a stability check.
    fn observe_updates(&mut self, _t: usize, _deltas: &[UnitDelta]) {}

    /// Expected freeze ratios r* once computed (TimelyFreeze family);
    /// `None` for metric-only baselines.
    fn expected_ratios(&self) -> Option<&BTreeMap<Action, f64>> {
        None
    }

    /// Online replanning: re-solve the plan against a cost profile
    /// distilled from *observed* execution (stragglers, jitter, link
    /// contention included), replacing the bounds monitored before
    /// `T_m`. The TimelyFreeze family re-solves its warm-started LP;
    /// metric-only baselines have no plan to revise and ignore it.
    fn replan_with_profile(&mut self, _profile: &crate::cost::CostProfile) {}

    /// Replace the per-stage memory floor mid-run — the runner's
    /// `squeeze:` scenario hook tightens it at replan boundaries when
    /// the simulated memory budget shrinks. A floor the LP cannot
    /// satisfy makes the next re-solve fail into the degraded-mode
    /// ladder rather than crash. Metric-only baselines have no floor
    /// and ignore it.
    fn set_stage_floor(&mut self, _floor: Option<Vec<f64>>) {}

    /// The batch time the current plan expects (`P_d*` of the last LP
    /// solve); `None` for controllers without a planning model. Paired
    /// with realized step times, this is the planned-vs-realized gap the
    /// dynamics benches report.
    fn planned_batch_time(&self) -> Option<f64> {
        None
    }

    /// Replanning attempts whose LP fallback ladder exhausted without a
    /// feasible solution. The controller degrades through the ladder of
    /// [`DegradationRung`]s in that case; this counter surfaces how
    /// often it had to.
    fn replan_failures(&self) -> usize {
        0
    }

    /// The structured degraded-mode record, if the controller keeps one
    /// (TimelyFreeze family). `None` for metric-only baselines, which
    /// have no plan that can fail.
    fn degradation(&self) -> Option<&DegradationReport> {
        None
    }

    /// Re-solve the plan directly against a [`CostModel`] — the elastic
    /// recovery path uses this after a repartition, where the new
    /// topology's analytic model is the best available bound source and
    /// no observed profile exists yet for the shrunken fleet.
    /// Metric-only baselines have no plan to revise and ignore it.
    fn replan_with_model(&mut self, _cost: &crate::cost::CostModel) {}
}

/// Construct a controller by method with shared inputs. `schedule` is
/// needed by the TimelyFreeze family; baselines use `layout` + their own
/// config.
#[derive(Clone, Debug)]
pub struct ControllerFactory {
    /// Phase boundaries shared by every controller.
    pub phases: PhaseConfig,
    /// TimelyFreeze budget: maximum average freeze ratio per stage.
    pub r_max: f64,
    /// TimelyFreeze LP tie-breaker weight.
    pub lambda: f64,
    /// APF baseline tunables.
    pub apf: ApfConfig,
    /// AutoFreeze baseline tunables.
    pub auto: AutoFreezeConfig,
    /// Per-stage freeze-ratio floor from memory accounting
    /// ([`MemoryModel::required_ratios`](crate::cost::MemoryModel::required_ratios)),
    /// honoured by the TimelyFreeze family (constraint [5]). The
    /// metric-only baselines are memory-blind — exactly the gap the
    /// memory-aware LP closes.
    pub stage_floor: Option<Vec<f64>>,
    /// Per-CSR-edge communication split `(e0, traffic)` in seconds,
    /// ordered like [`PipelineDag::cross_rank_edge_map`](crate::graph::PipelineDag::cross_rank_edge_map):
    /// `e0` is the fixed latency floor, `traffic` the serialization time
    /// of the *unfrozen* gradient payload. The TimelyFreeze family feeds
    /// both into the LP (`with_edge_costs` + `with_edge_traffic`) so the
    /// plan sees that freezing a sender shrinks its gradient messages on
    /// a contended fabric. `None` keeps the network-blind LP bitwise.
    pub edge_comm: Option<(Vec<f64>, Vec<f64>)>,
}

impl ControllerFactory {
    /// Build the controller implementing `method`.
    pub fn build(
        &self,
        method: FreezeMethod,
        schedule: &crate::schedule::Schedule,
        layout: &ModelLayout,
    ) -> Box<dyn Controller> {
        let timely_cfg = TimelyFreezeConfig {
            phases: self.phases,
            r_max: self.r_max,
            lambda: self.lambda,
        };
        let timely = || {
            let mut tf = TimelyFreeze::new(timely_cfg, schedule, layout.clone());
            tf.set_stage_floor(self.stage_floor.clone());
            tf.set_edge_comm(self.edge_comm.clone());
            tf
        };
        match method {
            FreezeMethod::NoFreezing => Box::new(NoFreezing::new()),
            FreezeMethod::Apf => {
                let mut apf = Apf::new(self.apf.clone(), layout.clone(), self.phases);
                apf.set_actions(schedule.all_actions());
                Box::new(apf)
            }
            FreezeMethod::AutoFreeze => {
                let mut auto = AutoFreeze::new(self.auto.clone(), layout.clone(), self.phases);
                auto.set_actions(schedule.all_actions());
                Box::new(auto)
            }
            FreezeMethod::TimelyFreeze => Box::new(timely()),
            FreezeMethod::TimelyApf => {
                Box::new(Hybrid::with_apf(timely(), self.apf.clone(), layout.clone()))
            }
            FreezeMethod::TimelyAuto => {
                Box::new(Hybrid::with_autofreeze(timely(), self.auto.clone(), layout.clone()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_config_midpoint() {
        let p = PhaseConfig::new(60, 100, 200);
        assert_eq!(p.monitor_mid(), 80);
        let p = PhaseConfig::new(160, 200, 250);
        assert_eq!(p.monitor_mid(), 180);
    }

    #[test]
    #[should_panic]
    fn phase_config_validates_order() {
        PhaseConfig::new(100, 100, 200);
    }

    /// The factory must thread `stage_floor` into the TimelyFreeze
    /// family — this is the wiring a memory-budgeted simulator run
    /// relies on, asserted through the `Controller` interface alone.
    #[test]
    fn factory_threads_stage_floor_to_timely() {
        use crate::schedule::Schedule;
        use crate::types::{ActionKind, ScheduleKind};
        let schedule = Schedule::build(ScheduleKind::OneFOneB, 4, 8, 1);
        let layout = ModelLayout::uniform(8, 4, 1000, 4);
        let floor = 0.5f64;
        let factory = ControllerFactory {
            phases: PhaseConfig::new(10, 30, 50),
            r_max: 0.8,
            lambda: 1e-4,
            apf: ApfConfig::default(),
            auto: AutoFreezeConfig::default(),
            stage_floor: Some(vec![floor; 4]),
            edge_comm: None,
        };
        let mut c = factory.build(FreezeMethod::TimelyFreeze, &schedule, &layout);
        // Drive warm-up + monitoring with synthetic timings (forward
        // 1 ms; backward 2 ms unfrozen, 0.8 ms frozen).
        for t in 1..=30 {
            let plan = c.plan(t);
            for a in schedule.all_actions() {
                let dur = match a.kind {
                    ActionKind::Forward => 1.0,
                    _ => 2.0 - plan.ratio_of(&a) * 1.2,
                };
                c.record_time(t, a, dur);
            }
        }
        // Past T_f the plan's AFR equals r*; every stage must average
        // at least the floor (and stay within r_max).
        let plan = c.plan(100);
        for s in 0..4 {
            let rs: Vec<f64> = schedule
                .all_actions()
                .into_iter()
                .filter(|a| a.kind.freezable() && a.stage == s)
                .map(|a| plan.ratio_of(&a))
                .collect();
            let mean = rs.iter().sum::<f64>() / rs.len() as f64;
            assert!(mean >= floor - 1e-6, "stage {s} below wired floor: {mean}");
            assert!(mean <= 0.8 + 1e-6, "stage {s} over budget: {mean}");
        }
    }

    #[test]
    fn plan_mean_ratio() {
        let mut plan = FreezePlan::none();
        plan.afr.insert(Action::b(0, 0), 0.5);
        plan.afr.insert(Action::b(1, 0), 0.7);
        let actions =
            vec![Action::f(0, 0), Action::b(0, 0), Action::b(1, 0), Action::b(2, 0)];
        // Forward excluded; b(2,0) counts as 0.
        assert!((plan.mean_ratio(&actions) - 0.4).abs() < 1e-12);
    }
}
