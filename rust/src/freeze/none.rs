//! The no-freezing reference: every table's baseline row.

use crate::freeze::{Controller, FreezePlan};
use crate::types::FreezeMethod;

/// The trivial controller: freezes nothing, ever.
#[derive(Default)]
pub struct NoFreezing;

impl NoFreezing {
    /// The controller (stateless).
    pub fn new() -> NoFreezing {
        NoFreezing
    }
}

impl Controller for NoFreezing {
    fn method(&self) -> FreezeMethod {
        FreezeMethod::NoFreezing
    }

    fn plan(&mut self, _t: usize) -> FreezePlan {
        FreezePlan::none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_empty() {
        let mut c = NoFreezing::new();
        for t in [1, 100, 10_000] {
            assert!(c.plan(t).afr.is_empty());
        }
        assert_eq!(c.method(), FreezeMethod::NoFreezing);
    }
}
