//! The TimelyFreeze controller (§3, Algorithm 1): warm-up → two-part
//! monitoring (upper-bound, then lower-bound) → LP solve at t = T_m →
//! progressive freezing toward the expected ratios r*.
//!
//! Beyond the paper's algorithm the controller understands the cost
//! subsystem's memory accounting: attach a per-stage freeze-ratio floor
//! with [`TimelyFreeze::set_stage_floor`], or hand
//! [`TimelyFreeze::replan`] a [`CostModel`] carrying a
//! [`MemoryModel`](crate::cost::MemoryModel) and the floor is derived
//! from the schedule's peak in-flight microbatch counts — the LP then
//! picks freeze ratios that fit the device budget (constraint [5]).
//!
//! The controller is schedule-agnostic by construction: it only ever
//! sees the [`PipelineDag`], so synthesized schedules
//! ([`crate::schedule::synthesize`]) replan through exactly the same
//! path as the fixed four — no special-casing, and the persistent
//! [`FreezeLpSolver`] warm-start works across a re-synthesized DAG the
//! same way it does across an elastic repartition (the solver detects
//! the skeleton change and rebuilds).

use crate::cost::{peak_inflight, CostModel};
use crate::freeze::layout::ModelLayout;
use crate::freeze::{
    Controller, DegradationEvent, DegradationReport, DegradationRung, FreezePlan, PhaseConfig,
};
use crate::graph::pipeline::{Node, PipelineDag};
use crate::lp::{FreezeLpInput, FreezeLpSolver, FreezeSolution};
use crate::schedule::Schedule;
use crate::types::{Action, FreezeMethod};
use crate::util::stats::Accum;
use std::collections::BTreeMap;

/// Ceiling on recorded [`DegradationEvent`]s per controller. A run that
/// never recovers fails one replan per attempt indefinitely; only an
/// episode's first descents are informative, so the structured log
/// stops growing here while `replan_failures` keeps the full tally.
pub const DEGRADATION_LOG_CAP: usize = 256;

/// Tunables of the TimelyFreeze controller.
#[derive(Clone, Copy, Debug)]
pub struct TimelyFreezeConfig {
    /// Phase boundaries {T_w, T_m, T_f}.
    pub phases: PhaseConfig,
    /// User-specified maximum average freeze ratio per stage (§3.2.2).
    pub r_max: f64,
    /// LP tie-breaker weight λ ≪ 1 (eq. 6).
    pub lambda: f64,
}

/// Which monitoring window a step belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Steps `1..=T_w`: no freezing, LR warm-up.
    Warmup,
    /// First monitoring half: no freezing, measuring `w_max`.
    MonitorUpper,
    /// Second monitoring half: full freezing, measuring `w_min`.
    MonitorLower,
    /// Steps `> T_m`: progressive freezing toward r*.
    Freezing,
}

/// The TimelyFreeze controller state (see the module docs).
pub struct TimelyFreeze {
    cfg: TimelyFreezeConfig,
    pdag: PipelineDag,
    /// All freezable actions of one batch (constant across steps).
    freezable: Vec<Action>,
    /// Timing samples: (no-freezing window, full-freezing window).
    upper: BTreeMap<Action, Accum>,
    lower: BTreeMap<Action, Accum>,
    /// r* per action, computed once at the end of monitoring.
    expected: Option<BTreeMap<Action, f64>>,
    /// Full LP solution kept for reporting (κ, P_d*, envelopes).
    solution: Option<FreezeSolution>,
    /// LP solver with the previous optimal basis cached: re-plans over
    /// the same DAG (refreshed bounds, new r_max) warm-start in a
    /// handful of pivots.
    solver: FreezeLpSolver,
    /// Per-stage freeze-ratio floor from memory accounting (constraint
    /// [5]); `None` ⇒ memory-unconstrained.
    stage_floor: Option<Vec<f64>>,
    /// Per-stage recompute surcharge seconds added to the LP's backward
    /// envelopes ([`FreezeLpInput::with_recompute`]); `None` ⇒ the
    /// monitored (or observed) bounds already tell the whole story.
    /// Only set this when the bounds fed to the LP come from a
    /// *surcharge-free* world — the simulator bakes the surcharge into
    /// its cost model instead, so monitored durations carry it already
    /// and setting this too would double-charge.
    recompute_surcharge: Option<Vec<f64>>,
    /// Per-CSR-edge communication split `(e0, traffic)` fed to the LP
    /// as `with_edge_costs` + `with_edge_traffic`: each cross-rank edge
    /// costs `e0 + traffic·(1 − r_sender)` seconds, so the plan knows
    /// freezing a sender shrinks its gradient messages on a shared
    /// fabric. `None` keeps the network-blind problem bitwise.
    edge_comm: Option<(Vec<f64>, Vec<f64>)>,
    /// Observed-execution cost model distilled by the event engine
    /// ([`ProfileRecorder`](crate::cost::ProfileRecorder) →
    /// [`CostProfile`](crate::cost::CostProfile)); when set, LP bounds
    /// come from here instead of the pre-`T_m` monitoring windows.
    observed: Option<CostModel>,
    /// Peak in-flight microbatches per stage, a schedule constant —
    /// needed to re-derive the floor from a memory model in `replan`.
    inflight: Vec<usize>,
    /// Reusable per-solve bound buffers: the replan loop refreshes
    /// these in place instead of allocating two DAG-sized vectors per
    /// LP solve.
    scratch_w_min: Vec<f64>,
    scratch_w_max: Vec<f64>,
    /// Solve attempts whose LP fallback ladder exhausted; the
    /// controller fell down the degraded-mode ladder
    /// ([`DegradationRung`]) instead of crashing.
    replan_failures: usize,
    /// Consecutive failed solves since the last success — the index
    /// into the degraded-mode ladder. Reset to zero by any successful
    /// solve.
    consecutive_failures: usize,
    /// Structured record of every degraded-mode episode.
    degradation: DegradationReport,
    /// Latest training step seen via `plan` / `record_time`, stamped
    /// onto degradation events (replan entry points carry no step).
    cur_step: usize,
    #[allow(dead_code)]
    layout: ModelLayout,
}

impl TimelyFreeze {
    /// Build the controller for one schedule, deriving the pipeline DAG
    /// and the schedule's peak in-flight microbatch profile.
    pub fn new(cfg: TimelyFreezeConfig, schedule: &Schedule, layout: ModelLayout) -> TimelyFreeze {
        let pdag = PipelineDag::from_schedule(schedule);
        let freezable = schedule
            .all_actions()
            .into_iter()
            .filter(|a| a.kind.freezable())
            .collect();
        let inflight = peak_inflight(schedule);
        TimelyFreeze {
            cfg,
            pdag,
            freezable,
            upper: BTreeMap::new(),
            lower: BTreeMap::new(),
            expected: None,
            solution: None,
            solver: FreezeLpSolver::new(),
            stage_floor: None,
            recompute_surcharge: None,
            edge_comm: None,
            observed: None,
            inflight,
            scratch_w_min: Vec::new(),
            scratch_w_max: Vec::new(),
            replan_failures: 0,
            consecutive_failures: 0,
            degradation: DegradationReport::default(),
            cur_step: 0,
            layout,
        }
    }

    /// The phase step `t` belongs to.
    pub fn phase(&self, t: usize) -> Phase {
        let p = &self.cfg.phases;
        if t <= p.t_warmup {
            Phase::Warmup
        } else if t <= p.monitor_mid() {
            Phase::MonitorUpper
        } else if t <= p.t_monitor {
            Phase::MonitorLower
        } else {
            Phase::Freezing
        }
    }

    /// The LP solution (available once t > T_m and `plan` has run).
    pub fn solution(&self) -> Option<&FreezeSolution> {
        self.solution.as_ref()
    }

    /// Which rung of the LP solver's fallback ladder produced the last
    /// plan (`None` before the first solve) — incremental tableau
    /// patch, warm basis realization, or cold two-phase solve. The
    /// steady-state replan loop is expected to report
    /// [`SolvePath::Incremental`](crate::lp::SolvePath::Incremental).
    pub fn last_solve_path(&self) -> Option<crate::lp::SolvePath> {
        self.solver.last_solve_path()
    }

    /// Work counters of the last LP solve — simplex pivots, dual
    /// bound flips, and basis refactorizations, alongside the ladder
    /// rung that produced the plan (`None` before the first solve).
    /// A healthy steady-state replan loop shows single-digit pivots
    /// and zero refactorizations per call.
    pub fn last_solve_stats(&self) -> Option<crate::lp::SolveStats> {
        self.solver.last_solve_stats()
    }

    /// Re-plan from the current monitoring state: re-solves the LP
    /// warm-started from the previous optimal basis (a handful of pivots
    /// instead of a full two-phase solve), refreshing `r*`. For elastic
    /// controllers re-planning per check-interval.
    ///
    /// When `cost` carries a [`MemoryModel`](crate::cost::MemoryModel),
    /// the per-stage freeze-ratio floor is re-derived from it first, so
    /// an elastic run whose memory budget drifts **on the unchanged
    /// schedule** — a resized device slice, revised activation-byte
    /// estimates — re-plans against the fresh budget. The peak
    /// in-flight profile is a construction-time constant of the
    /// schedule; a run whose schedule shape changes (microbatch or rank
    /// count) needs a new controller, not a `replan`. Pass `None` to
    /// re-plan on timings alone, keeping any floor previously set. An unsatisfiable budget — the device
    /// overflows even fully frozen, or the derived floor exceeds
    /// `r_max` (the LP would reject it as `FloorExceedsBudget` on every
    /// solve) — keeps the previous floor and logs, so the controller
    /// keeps executing its last consistent plan rather than tripping
    /// the freeze-nothing fail-safe at maximum memory pressure.
    pub fn replan(&mut self, cost: Option<&CostModel>) {
        if let Some(mem) = cost.and_then(|c| c.memory()) {
            // A cost model carrying recompute fractions stashes only
            // `1 − ρ_s` of each stage's activations; the floor must be
            // derived from the same scaled accounting
            // (`memory_plan_for` semantics) or it would over-freeze.
            let scaled;
            let mem = match cost.and_then(|c| c.recompute_fractions()) {
                Some(rho) => {
                    scaled = mem.clone().apply_recompute(rho);
                    &scaled
                }
                None => mem,
            };
            match mem.required_ratios(&self.inflight) {
                Ok(mut floor) => {
                    // Tolerate the roundoff of a recompute-scaled floor
                    // landing an ulp above r_max (Auto-derived fractions
                    // target exactly r_max on deficit stages — the same
                    // guard `memory_plan_for` applies); genuine
                    // conflicts are still rejected below.
                    for r in &mut floor {
                        if *r > self.cfg.r_max && *r <= self.cfg.r_max + 1e-9 {
                            *r = self.cfg.r_max;
                        }
                    }
                    if let Some((s, &r)) =
                        floor.iter().enumerate().find(|&(_, &r)| r > self.cfg.r_max)
                    {
                        eprintln!(
                            "timelyfreeze: memory floor {r:.3} at stage {s} exceeds \
                             r_max = {}; keeping previous floor",
                            self.cfg.r_max
                        );
                    } else {
                        self.stage_floor =
                            if floor.iter().any(|&r| r > 0.0) { Some(floor) } else { None };
                    }
                }
                Err(e) => {
                    eprintln!("timelyfreeze: memory budget infeasible ({e}); keeping previous floor");
                }
            }
        }
        self.solve();
    }

    /// Online replanning against observed execution: lower `profile` —
    /// typically distilled by
    /// [`ProfileRecorder`](crate::cost::ProfileRecorder) from the event
    /// engine's observed action times — to a cost model, take LP bounds
    /// from it instead of the pre-`T_m` monitoring windows, and re-solve
    /// warm-started from the previous optimal basis. This is how the
    /// plan adapts to dynamics the monitoring phase never saw: a
    /// straggler appearing mid-run shifts the observed profile, the
    /// refreshed LP moves the freezing budget onto the new critical
    /// path. The memory floor (constraint [5]) carries over unchanged.
    pub fn replan_with_profile(&mut self, profile: &crate::cost::CostProfile) {
        self.observed = Some(profile.to_model(self.pdag.stages));
        self.solve();
    }

    /// Re-solve the plan directly against `cost`'s per-action duration
    /// bounds, bypassing both monitoring windows and observed profiles.
    /// The elastic recovery path calls this right after a repartition:
    /// the rebuilt topology has no execution history yet, so the
    /// analytic cost model of the shrunken fleet is the best available
    /// bound source.
    pub fn replan_with_model(&mut self, cost: &CostModel) {
        self.observed = Some(cost.clone());
        self.solve();
    }

    /// Drop any observed-profile override, returning LP bounds to the
    /// monitoring windows at the next solve.
    pub fn clear_observed_profile(&mut self) {
        self.observed = None;
    }

    /// Set (or clear) the per-stage freeze-ratio floor directly — the
    /// environment computed it from
    /// [`MemoryModel::required_ratios`](crate::cost::MemoryModel::required_ratios).
    /// Takes effect at the next LP solve.
    pub fn set_stage_floor(&mut self, floor: Option<Vec<f64>>) {
        self.stage_floor = floor.filter(|f| f.iter().any(|&r| r > 0.0));
    }

    /// The active per-stage freeze-ratio floor, if any.
    pub fn stage_floor(&self) -> Option<&[f64]> {
        self.stage_floor.as_deref()
    }

    /// Set (or clear) the per-stage recompute surcharge the LP should
    /// grow its backward envelopes by (`Δ_s = ρ_s · fwd_s`, see
    /// [`FreezeLpInput::with_recompute`]). Use only when the bounds the
    /// controller monitors come from a surcharge-free execution — an
    /// environment that already executes (and therefore measures) the
    /// forward re-runs, like the simulator with a baked
    /// [`CostModel::with_recompute_fractions`], must leave this unset
    /// or the surcharge would be charged twice. An all-zero vector is
    /// dropped. Takes effect at the next LP solve.
    pub fn set_recompute_surcharge(&mut self, surcharge: Option<Vec<f64>>) {
        self.recompute_surcharge = surcharge.filter(|s| s.iter().any(|&x| x > 0.0));
    }

    /// The active per-stage recompute surcharge, if any.
    pub fn recompute_surcharge(&self) -> Option<&[f64]> {
        self.recompute_surcharge.as_deref()
    }

    /// Set (or clear) the per-CSR-edge communication split `(e0,
    /// traffic)` the LP prices cross-rank edges with (see
    /// [`FreezeLpInput::with_edge_traffic`]). Both vectors follow
    /// [`PipelineDag::cross_rank_edge_map`](crate::graph::PipelineDag::cross_rank_edge_map)
    /// edge order. A pair whose traffic vector is all-zero is kept —
    /// the `e0` part still prices fixed latency. Takes effect at the
    /// next LP solve.
    pub fn set_edge_comm(&mut self, edge_comm: Option<(Vec<f64>, Vec<f64>)>) {
        self.edge_comm = edge_comm;
    }

    /// The active per-edge communication split, if any.
    pub fn edge_comm(&self) -> Option<(&[f64], &[f64])> {
        self.edge_comm.as_ref().map(|(e0, tr)| (e0.as_slice(), tr.as_slice()))
    }

    /// The pipeline DAG the controller plans over.
    pub fn pdag(&self) -> &PipelineDag {
        &self.pdag
    }

    /// Progressive ramp (eq. 9):
    /// `AFR_{i,t} = min(r_i, r_i · (t − T_m)/(T_f − T_m))`.
    fn ramp(&self, t: usize, r: f64) -> f64 {
        let p = &self.cfg.phases;
        let frac = (t - p.t_monitor) as f64 / (p.t_freeze - p.t_monitor) as f64;
        (r * frac).min(r)
    }

    /// Solve the LP from the recorded bounds (Alg. 1 lines 12–14) — or,
    /// when an observed profile is installed
    /// ([`TimelyFreeze::replan_with_profile`]), from that profile's
    /// duration model. The environment has effectively all-gathered
    /// timings by routing every stage's `record_time` into this
    /// controller.
    fn solve(&mut self) {
        let n = self.pdag.len();
        // Hoisted scratch: the replan loop calls this every interval,
        // so the two bound vectors live on the controller and are
        // refreshed in place.
        let mut w_min = std::mem::take(&mut self.scratch_w_min);
        let mut w_max = std::mem::take(&mut self.scratch_w_max);
        w_min.clear();
        w_min.resize(n, 0.0);
        w_max.clear();
        w_max.resize(n, 0.0);
        if let Some(model) = &self.observed {
            for (id, node) in self.pdag.dag.nodes.iter().enumerate() {
                if let Node::Act(a) = node {
                    let (lo, hi) = model.bounds(*a);
                    w_min[id] = lo;
                    w_max[id] = hi;
                }
            }
            self.solve_with_bounds(&w_min, &w_max);
            self.scratch_w_min = w_min;
            self.scratch_w_max = w_max;
            return;
        }
        for (id, node) in self.pdag.dag.nodes.iter().enumerate() {
            let Node::Act(a) = node else { continue };
            let up = self.upper.get(a).map(|acc| acc.mean());
            let lo = self.lower.get(a).map(|acc| acc.mean());
            if a.kind.freezable() {
                // Backward: upper window gives w_max, lower gives w_min.
                let hi = up.or(lo).unwrap_or(0.0);
                let mut lo_v = lo.or(up).unwrap_or(0.0);
                // Measurement noise can invert near-equal bounds; clamp.
                if lo_v > hi {
                    lo_v = hi;
                }
                w_max[id] = hi;
                w_min[id] = lo_v;
            } else {
                // Forward (and dgrad) durations are freeze-invariant:
                // pool both windows (w_min = w_max, eq. after Fig. 3).
                let mut acc = Accum::new();
                if let Some(u) = self.upper.get(a) {
                    if u.n > 0 {
                        acc.push(u.mean());
                    }
                }
                if let Some(l) = self.lower.get(a) {
                    if l.n > 0 {
                        acc.push(l.mean());
                    }
                }
                let v = acc.mean();
                w_min[id] = v;
                w_max[id] = v;
            }
        }
        self.solve_with_bounds(&w_min, &w_max);
        self.scratch_w_min = w_min;
        self.scratch_w_max = w_max;
    }

    /// Run the warm-started LP for explicit per-node bounds and install
    /// the resulting expected ratios (shared by the monitoring and
    /// observed-profile paths).
    fn solve_with_bounds(&mut self, w_min: &[f64], w_max: &[f64]) {
        let mut input =
            FreezeLpInput::new(&self.pdag, w_min, w_max, self.cfg.r_max, self.cfg.lambda);
        if let Some(floor) = self.stage_floor.as_deref() {
            input = input.with_stage_floor(floor);
        }
        if let Some(sur) = self.recompute_surcharge.as_deref() {
            input = input.with_recompute(sur);
        }
        if let Some((e0, tr)) = self.edge_comm.as_ref() {
            input = input.with_edge_costs(e0.as_slice()).with_edge_traffic(tr.as_slice());
        }
        match self.solver.solve(&input) {
            Ok(sol) => {
                let mut expected = BTreeMap::new();
                for (id, node) in self.pdag.dag.nodes.iter().enumerate() {
                    if let Node::Act(a) = node {
                        if a.kind.freezable() {
                            expected.insert(*a, sol.ratios[id]);
                        }
                    }
                }
                self.expected = Some(expected);
                self.solution = Some(sol);
                self.consecutive_failures = 0;
            }
            Err(e) => self.degrade(format!("{e}")),
        }
    }

    /// Fall one rung down the degraded-mode ladder after a failed solve
    /// (*reuse-last-plan → floor-clamped heuristic ratios → no-freeze
    /// safe mode*), recording a structured [`DegradationEvent`]. The
    /// next successful solve restores normal planning and resets the
    /// ladder; the event log is append-only for the life of the run,
    /// capped at [`DEGRADATION_LOG_CAP`] entries (the failure counters
    /// keep counting past the cap).
    fn degrade(&mut self, cause: String) {
        self.replan_failures += 1;
        self.consecutive_failures += 1;
        // A failure with no feasible plan installed has nothing to
        // reuse: it enters the ladder one rung down.
        let depth = if self.solution.is_some() {
            self.consecutive_failures
        } else {
            self.consecutive_failures + 1
        };
        let rung = match depth {
            1 => DegradationRung::ReuseLastPlan,
            2 if self.stage_floor.is_some() => DegradationRung::HeuristicFloor,
            _ => DegradationRung::SafeMode,
        };
        match rung {
            DegradationRung::ReuseLastPlan => {
                // The last feasible plan is still valid for the world
                // it was solved in; keep executing it unchanged.
            }
            DegradationRung::HeuristicFloor => {
                // No optimality claim: every freezable action gets its
                // stage's memory floor, clamped into [0, r_max] — the
                // cheapest ratios that still fit the device budget.
                let floor = self.stage_floor.as_deref().unwrap();
                let mut expected = BTreeMap::new();
                for a in &self.freezable {
                    expected.insert(*a, floor[a.stage].clamp(0.0, self.cfg.r_max));
                }
                self.expected = Some(expected);
                // The stale LP solution no longer describes the plan;
                // planned_batch_time must not report it.
                self.solution = None;
            }
            DegradationRung::SafeMode => {
                self.expected = Some(BTreeMap::new());
                self.solution = None;
            }
        }
        // Rate-limit the console warning: a run stuck in safe mode can
        // fail one replan per interval (or watchdog trigger) for
        // thousands of steps, and every failure past the ladder's last
        // rung carries no new information. Each episode prints its
        // first three descents; the counters keep the full tally.
        if self.consecutive_failures <= 3 {
            eprintln!(
                "timelyfreeze: LP failed at step {} ({cause}); degrading to {} (failure #{})",
                self.cur_step,
                rung.name(),
                self.replan_failures
            );
        }
        if self.degradation.events.len() < DEGRADATION_LOG_CAP {
            self.degradation.events.push(DegradationEvent {
                step: self.cur_step,
                cause,
                solve_path: self.solver.last_solve_path(),
                rung,
            });
        }
    }

    /// The structured degraded-mode record of this controller.
    pub fn degradation(&self) -> &DegradationReport {
        &self.degradation
    }
}

impl Controller for TimelyFreeze {
    fn method(&self) -> FreezeMethod {
        FreezeMethod::TimelyFreeze
    }

    fn plan(&mut self, t: usize) -> FreezePlan {
        self.cur_step = self.cur_step.max(t);
        match self.phase(t) {
            Phase::Warmup | Phase::MonitorUpper => FreezePlan::none(),
            Phase::MonitorLower => {
                // Lower-bound monitoring: freeze everything (Alg. 1 l.10).
                let mut plan = FreezePlan::none();
                for a in &self.freezable {
                    plan.afr.insert(*a, 1.0);
                }
                plan
            }
            Phase::Freezing => {
                if self.expected.is_none() {
                    self.solve();
                }
                let mut plan = FreezePlan::none();
                let expected = self.expected.as_ref().unwrap();
                for (a, &r) in expected {
                    let afr = self.ramp(t, r);
                    if afr > 0.0 {
                        plan.afr.insert(*a, afr);
                    }
                }
                plan
            }
        }
    }

    fn record_time(&mut self, t: usize, action: Action, duration: f64) {
        self.cur_step = self.cur_step.max(t);
        match self.phase(t) {
            Phase::MonitorUpper => {
                self.upper.entry(action).or_insert_with(Accum::new).push(duration);
            }
            Phase::MonitorLower => {
                self.lower.entry(action).or_insert_with(Accum::new).push(duration);
            }
            _ => {}
        }
    }

    fn expected_ratios(&self) -> Option<&BTreeMap<Action, f64>> {
        self.expected.as_ref()
    }

    fn replan_with_profile(&mut self, profile: &crate::cost::CostProfile) {
        TimelyFreeze::replan_with_profile(self, profile);
    }

    fn set_stage_floor(&mut self, floor: Option<Vec<f64>>) {
        TimelyFreeze::set_stage_floor(self, floor);
    }

    fn planned_batch_time(&self) -> Option<f64> {
        self.solution.as_ref().map(|s| s.batch_time)
    }

    fn replan_failures(&self) -> usize {
        self.replan_failures
    }

    fn degradation(&self) -> Option<&DegradationReport> {
        Some(&self.degradation)
    }

    fn replan_with_model(&mut self, cost: &crate::cost::CostModel) {
        TimelyFreeze::replan_with_model(self, cost);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{ActionKind, ScheduleKind};

    fn make(r_max: f64) -> (TimelyFreeze, Schedule) {
        let schedule = Schedule::build(ScheduleKind::OneFOneB, 4, 8, 1);
        let layout = ModelLayout::uniform(8, 4, 1000, 4);
        let cfg = TimelyFreezeConfig {
            phases: PhaseConfig::new(10, 30, 50),
            r_max,
            lambda: 1e-4,
        };
        (TimelyFreeze::new(cfg, &schedule, layout), schedule)
    }

    /// Drive warm-up + monitoring with synthetic timings: forward 1 ms,
    /// backward 2 ms unfrozen / 0.8 ms frozen.
    fn drive_monitoring(tf: &mut TimelyFreeze, schedule: &Schedule) {
        for t in 1..=30 {
            let plan = tf.plan(t);
            for a in schedule.all_actions() {
                let dur = match a.kind {
                    ActionKind::Forward => 1.0,
                    _ => {
                        let afr = plan.ratio_of(&a);
                        2.0 - afr * 1.2
                    }
                };
                tf.record_time(t, a, dur);
            }
        }
    }

    #[test]
    fn phases_progress() {
        let (tf, _) = make(0.8);
        assert_eq!(tf.phase(5), Phase::Warmup);
        assert_eq!(tf.phase(10), Phase::Warmup);
        assert_eq!(tf.phase(11), Phase::MonitorUpper);
        assert_eq!(tf.phase(20), Phase::MonitorUpper);
        assert_eq!(tf.phase(21), Phase::MonitorLower);
        assert_eq!(tf.phase(30), Phase::MonitorLower);
        assert_eq!(tf.phase(31), Phase::Freezing);
    }

    #[test]
    fn no_freezing_during_warmup_and_upper() {
        let (mut tf, _) = make(0.8);
        assert!(tf.plan(1).afr.is_empty());
        assert!(tf.plan(15).afr.is_empty());
    }

    #[test]
    fn full_freezing_during_lower_monitoring() {
        let (mut tf, schedule) = make(0.8);
        let plan = tf.plan(25);
        let backwards = schedule
            .all_actions()
            .into_iter()
            .filter(|a| a.kind.freezable())
            .count();
        assert_eq!(plan.afr.len(), backwards);
        assert!(plan.afr.values().all(|&r| r == 1.0));
    }

    #[test]
    fn progressive_ramp_reaches_expected() {
        let (mut tf, schedule) = make(0.8);
        drive_monitoring(&mut tf, &schedule);
        // Right after T_m the ramp is shallow…
        let early = tf.plan(31);
        let expected = tf.expected_ratios().unwrap().clone();
        let some_action = *expected
            .iter()
            .find(|(_, &r)| r > 0.1)
            .expect("LP should freeze something")
            .0;
        let r_star = expected[&some_action];
        let afr_early = early.ratio_of(&some_action);
        assert!(afr_early < r_star, "ramp should start below r*");
        assert!(
            (afr_early - r_star * (31.0 - 30.0) / 20.0).abs() < 1e-9,
            "eq. 9 violated"
        );
        // …and saturates at r* for t > T_f.
        let (mut tf2, schedule2) = make(0.8);
        drive_monitoring(&mut tf2, &schedule2);
        let late = tf2.plan(100);
        assert!((late.ratio_of(&some_action) - r_star).abs() < 1e-9);
    }

    #[test]
    fn lp_speedup_realized() {
        let (mut tf, schedule) = make(0.8);
        drive_monitoring(&mut tf, &schedule);
        tf.plan(31);
        let sol = tf.solution().unwrap();
        assert!(sol.batch_time < sol.p_d_max - 1e-9, "no speedup found");
        assert!(sol.kappa() < 1.0);
    }

    #[test]
    fn budget_respected_in_expected_ratios() {
        let r_max = 0.5;
        let (mut tf, schedule) = make(r_max);
        drive_monitoring(&mut tf, &schedule);
        tf.plan(31);
        let expected = tf.expected_ratios().unwrap();
        // Per-stage mean of r* within budget.
        for s in 0..4 {
            let rs: Vec<f64> = expected
                .iter()
                .filter(|(a, _)| a.stage == s)
                .map(|(_, &r)| r)
                .collect();
            let mean = rs.iter().sum::<f64>() / rs.len() as f64;
            assert!(mean <= r_max + 1e-6, "stage {s} over budget: {mean}");
        }
    }

    #[test]
    fn replan_warm_start_preserves_solution() {
        let (mut tf, schedule) = make(0.8);
        drive_monitoring(&mut tf, &schedule);
        tf.plan(31);
        let first = tf.solution().unwrap().clone();
        // Same monitoring state → the warm re-solve lands on the same
        // optimum in (almost) no pivots.
        tf.replan(None);
        let second = tf.solution().unwrap();
        assert!((first.batch_time - second.batch_time).abs() < 1e-9);
        assert!(
            second.iterations * 10 <= first.iterations.max(10),
            "replan took {} iterations vs first solve {}",
            second.iterations,
            first.iterations
        );
        for (a, b) in first.ratios.iter().zip(&second.ratios) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn replan_with_profile_chases_a_straggler() {
        use crate::cost::CostProfile;
        let (mut tf, schedule) = make(0.5);
        drive_monitoring(&mut tf, &schedule);
        tf.plan(31);
        let before = tf.solution().unwrap().clone();
        assert_eq!(Controller::planned_batch_time(&tf), Some(before.batch_time));
        // Observed execution: stage 2's device has slowed 2.5× since
        // monitoring (fwd 1 → 2.5, backward 2/0.8 → 5/2).
        let skewed = CostProfile::profiled(
            (0..4)
                .map(|s| {
                    let m = if s == 2 { 2.5 } else { 1.0 };
                    crate::cost::StageProfile::compute(m * 1.0, m * 0.8, m * 1.2)
                })
                .collect(),
        );
        tf.replan_with_profile(&skewed);
        let after = tf.solution().unwrap().clone();
        // The LP now plans against the slower world…
        assert!(after.p_d_max > before.p_d_max + 1e-9);
        // …and the straggler's stage gets at least as much freezing as
        // any other stage: its wgrad is the biggest absolute saving.
        let ratios = after.stage_ratios(tf.pdag());
        let others = ratios
            .iter()
            .enumerate()
            .filter(|&(s, _)| s != 2)
            .map(|(_, &r)| r)
            .fold(0.0f64, f64::max);
        assert!(
            ratios[2] >= others - 1e-9,
            "straggler stage under-frozen: {ratios:?}"
        );
        assert!(ratios[2] > 0.4, "straggler stage should use the budget: {ratios:?}");
        // Clearing the override returns the plan to monitored bounds.
        tf.clear_observed_profile();
        tf.replan(None);
        let back = tf.solution().unwrap();
        assert!((back.batch_time - before.batch_time).abs() < 1e-9);
    }

    #[test]
    fn rmax_zero_freezes_nothing() {
        let (mut tf, schedule) = make(0.0);
        drive_monitoring(&mut tf, &schedule);
        let plan = tf.plan(60);
        assert!(plan.afr.values().all(|&r| r < 1e-9));
    }

    #[test]
    fn stage_floor_raises_expected_ratios() {
        let (mut tf, schedule) = make(0.8);
        tf.set_stage_floor(Some(vec![0.6; 4]));
        drive_monitoring(&mut tf, &schedule);
        tf.plan(31);
        let sol = tf.solution().unwrap();
        for (s, &r) in sol.stage_ratios(tf.pdag()).iter().enumerate() {
            assert!(r >= 0.6 - 1e-6, "stage {s} below memory floor: {r}");
            assert!(r <= 0.8 + 1e-6, "stage {s} over budget: {r}");
        }
        // An all-zero floor is dropped entirely.
        tf.set_stage_floor(Some(vec![0.0; 4]));
        assert!(tf.stage_floor().is_none());
    }

    #[test]
    fn recompute_surcharge_inflates_the_plan_envelopes() {
        let (mut tf, schedule) = make(0.8);
        drive_monitoring(&mut tf, &schedule);
        tf.plan(31);
        let free = tf.solution().unwrap().clone();
        assert!(free.recompute_surcharge.is_none());
        // Monitored bounds from a surcharge-free world + an explicit
        // surcharge: the plan now accounts for the forward re-runs.
        tf.set_recompute_surcharge(Some(vec![0.5; 4]));
        tf.replan(None);
        let sur = tf.solution().unwrap();
        assert!(sur.p_d_max > free.p_d_max + 1e-9);
        assert!(sur.batch_time > free.batch_time + 1e-9);
        assert_eq!(sur.recompute_surcharge.as_deref(), Some(&[0.5f64; 4][..]));
        // An all-zero vector is dropped and the plan returns exactly.
        tf.set_recompute_surcharge(Some(vec![0.0; 4]));
        assert!(tf.recompute_surcharge().is_none());
        tf.replan(None);
        let back = tf.solution().unwrap();
        assert!((back.batch_time - free.batch_time).abs() < 1e-9);
    }

    #[test]
    fn exhausted_replan_keeps_last_feasible_plan() {
        let (mut tf, schedule) = make(0.8);
        drive_monitoring(&mut tf, &schedule);
        tf.plan(31);
        let before = tf.solution().unwrap().clone();
        let expected_before = tf.expected_ratios().unwrap().clone();
        assert_eq!(Controller::replan_failures(&tf), 0);
        // An infeasible floor (above r_max) makes every solve fail; the
        // controller must keep executing the previous plan and count the
        // failure instead of dropping to freeze-nothing.
        tf.set_stage_floor(Some(vec![0.9; 4]));
        tf.replan(None);
        assert_eq!(Controller::replan_failures(&tf), 1);
        let after = tf.solution().expect("last feasible plan must survive");
        assert_eq!(after.ratios, before.ratios);
        assert_eq!(tf.expected_ratios().unwrap(), &expected_before);
        // The kept plan keeps ramping normally.
        let plan = tf.plan(60);
        assert!(plan.afr.values().any(|&r| r > 0.0));
        // Failures accumulate across repeated exhausted replans.
        tf.replan(None);
        assert_eq!(Controller::replan_failures(&tf), 2);
        // A feasible floor restores normal replanning without resetting
        // the count.
        tf.set_stage_floor(None);
        tf.replan(None);
        assert_eq!(Controller::replan_failures(&tf), 2);
        assert!(tf.solution().is_some());
    }

    #[test]
    fn degradation_ladder_descends_and_recovers() {
        let (mut tf, schedule) = make(0.8);
        drive_monitoring(&mut tf, &schedule);
        tf.plan(31);
        assert!(tf.degradation().is_empty());
        // An infeasible floor makes every solve fail; consecutive
        // failures walk the ladder one rung at a time.
        tf.set_stage_floor(Some(vec![0.9; 4]));
        tf.replan(None); // #1: reuse last plan
        assert!(tf.solution().is_some());
        assert!(tf.plan(40).afr.values().any(|&r| r > 0.0));
        tf.replan(None); // #2: heuristic floor, clamped to r_max
        assert!(tf.solution().is_none(), "stale LP solution must not be reported");
        let exp = tf.expected_ratios().unwrap();
        assert!(!exp.is_empty());
        assert!(exp.values().all(|&r| (r - 0.8).abs() < 1e-12), "floor 0.9 clamps to r_max");
        tf.replan(None); // #3: safe mode
        assert!(tf.expected_ratios().unwrap().is_empty());
        assert!(tf.plan(60).afr.is_empty(), "safe mode freezes nothing");
        let rungs: Vec<_> = tf.degradation().events.iter().map(|e| e.rung).collect();
        assert_eq!(
            rungs,
            vec![
                DegradationRung::ReuseLastPlan,
                DegradationRung::HeuristicFloor,
                DegradationRung::SafeMode
            ]
        );
        assert_eq!(tf.degradation().worst(), Some(DegradationRung::SafeMode));
        assert!(tf.degradation().events.iter().all(|e| !e.cause.is_empty()));
        assert!(tf.degradation().events.iter().all(|e| e.step >= 31));
        assert!(tf.degradation().summary().contains("safe-mode"));
        // A feasible solve restores normal planning; the event log is
        // append-only and the ladder resets.
        tf.set_stage_floor(None);
        tf.replan(None);
        assert!(tf.solution().is_some());
        assert!(tf.plan(61).afr.values().any(|&r| r > 0.0));
        assert_eq!(tf.degradation().len(), 3);
        assert_eq!(Controller::replan_failures(&tf), 3);
        // The next failure starts over at the mildest rung.
        tf.set_stage_floor(Some(vec![0.9; 4]));
        tf.replan(None);
        assert_eq!(tf.degradation().events[3].rung, DegradationRung::ReuseLastPlan);
    }

    #[test]
    fn first_failure_without_plan_skips_reuse_rung() {
        // A failure before any feasible plan exists has nothing to
        // reuse: the ladder enters at the heuristic-floor rung (floor
        // present) and the expected ratios are the clamped floor.
        let (mut tf, _schedule) = make(0.8);
        tf.set_stage_floor(Some(vec![0.9; 4]));
        tf.replan(None);
        assert_eq!(tf.degradation().events[0].rung, DegradationRung::HeuristicFloor);
        let exp = tf.expected_ratios().unwrap();
        assert!(exp.values().all(|&r| (r - 0.8).abs() < 1e-12));
        // A second consecutive failure still without a plan exhausts
        // the ladder: safe mode, nothing frozen.
        tf.replan(None);
        assert_eq!(tf.degradation().events[1].rung, DegradationRung::SafeMode);
        assert!(tf.expected_ratios().unwrap().is_empty());
    }

    #[test]
    fn replan_with_model_uses_model_bounds() {
        use crate::config::ExperimentConfig;
        use crate::cost::CostModel;
        use crate::partition::balanced_partition;
        let (mut tf, schedule) = make(0.8);
        drive_monitoring(&mut tf, &schedule);
        tf.plan(31);
        let before = tf.solution().unwrap().clone();
        let cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        let layer_stage = balanced_partition(&cfg.model.layer_params(), 4);
        let cost = CostModel::new(
            &cfg.model,
            &cfg.gpu,
            &layer_stage,
            4,
            cfg.microbatch_size,
            cfg.seq_len,
        );
        Controller::replan_with_model(&mut tf, &cost);
        let after = tf.solution().expect("model replan must produce a plan");
        // The plan now reflects the analytic model's scale, not the
        // synthetic monitoring timings.
        assert!((after.p_d_max - before.p_d_max).abs() > 1e-9);
    }

    #[test]
    fn replan_derives_floor_from_recompute_scaled_memory() {
        use crate::config::ExperimentConfig;
        use crate::cost::{CostModel, MemoryModel};
        use crate::partition::balanced_partition;

        let (mut tf, schedule) = make(0.8);
        drive_monitoring(&mut tf, &schedule);
        tf.plan(31);
        // A capacity where the freeze-only floor binds…
        let cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        let layer_stage = balanced_partition(&cfg.model.layer_params(), 4);
        let mem = MemoryModel::from_presets(
            &cfg.model,
            &cfg.gpu,
            &layer_stage,
            4,
            cfg.microbatch_size,
            cfg.seq_len,
            1,
        );
        let inflight = crate::cost::peak_inflight(&schedule);
        let mut frac = 1.0;
        let mem = loop {
            let m = mem.clone().scaled_capacity(frac);
            match m.required_ratios(&inflight) {
                Ok(f) if f.iter().any(|&r| r > 0.05) => {
                    assert!(f.iter().all(|&r| r <= 0.7), "crossing too coarse: {f:?}");
                    break m;
                }
                Ok(_) => frac *= 0.98,
                Err(e) => panic!("overshot feasibility: {e}"),
            }
        };
        let base_cost = CostModel::new(
            &cfg.model,
            &cfg.gpu,
            &layer_stage,
            4,
            cfg.microbatch_size,
            cfg.seq_len,
        );
        // Freeze-only cost model installs the binding floor…
        tf.replan(Some(&base_cost.clone().with_memory(mem.clone())));
        let frozen_floor = tf
            .stage_floor()
            .expect("binding budget must install a floor")
            .to_vec();
        assert!(frozen_floor.iter().any(|&r| r > 0.05));
        // …while the same memory under full recompute needs less forced
        // freezing at every stage (activations no longer stashed).
        let rc_cost = base_cost.with_recompute_fractions(&[1.0; 4]).with_memory(mem);
        tf.replan(Some(&rc_cost));
        match tf.stage_floor() {
            None => {} // floor dissolved entirely — the strongest relaxation
            Some(relaxed) => {
                for (s, (&r, &f)) in relaxed.iter().zip(&frozen_floor).enumerate() {
                    assert!(r <= f + 1e-9, "stage {s}: recompute floor {r} above {f}");
                }
            }
        }
    }

    #[test]
    fn replan_with_memory_model_derives_floor() {
        use crate::config::ExperimentConfig;
        use crate::cost::{CostModel, MemoryModel};
        use crate::partition::balanced_partition;

        let (mut tf, schedule) = make(0.8);
        drive_monitoring(&mut tf, &schedule);
        tf.plan(31);
        assert!(tf.stage_floor().is_none());

        // A memory model whose capacity forces some freezing everywhere.
        let cfg = ExperimentConfig::paper_preset("llama-1b").unwrap();
        let layer_stage = balanced_partition(&cfg.model.layer_params(), 4);
        let mem = MemoryModel::from_presets(
            &cfg.model,
            &cfg.gpu,
            &layer_stage,
            4,
            cfg.microbatch_size,
            cfg.seq_len,
            1,
        );
        let inflight = crate::cost::peak_inflight(&schedule);
        // Find a capacity fraction with a binding floor that stays well
        // under r_max = 0.8 (fine 2% steps so the crossing is gentle).
        let mut frac = 1.0;
        let mem = loop {
            let m = mem.clone().scaled_capacity(frac);
            match m.required_ratios(&inflight) {
                Ok(f) if f.iter().any(|&r| r > 0.02) => {
                    assert!(f.iter().all(|&r| r <= 0.7), "crossing too coarse: {f:?}");
                    break m;
                }
                Ok(_) => frac *= 0.98,
                Err(e) => panic!("overshot feasibility: {e}"),
            }
        };
        let cost = CostModel::new(
            &cfg.model,
            &cfg.gpu,
            &layer_stage,
            4,
            cfg.microbatch_size,
            cfg.seq_len,
        )
        .with_memory(mem.clone());
        tf.replan(Some(&cost));
        let floor = tf.stage_floor().expect("binding budget must install a floor").to_vec();
        let sol = tf.solution().unwrap();
        for (s, (&r, &f)) in sol.stage_ratios(tf.pdag()).iter().zip(&floor).enumerate() {
            assert!(r >= f - 1e-6, "stage {s}: ratio {r} below derived floor {f}");
        }
        // The floored plan fits the budget the memory model describes
        // (slack: LP rows hold to simplex tolerance, which scaled by
        // multi-GB state sizes is a few kB).
        for s in 0..4 {
            let used = mem.stage_bytes(s, inflight[s], sol.stage_ratios(tf.pdag())[s]);
            assert!(
                used <= mem.capacity_bytes[s] + mem.train_state_bytes[s] * 1e-5,
                "stage {s}: {used} bytes over capacity {}",
                mem.capacity_bytes[s]
            );
        }
    }
}
