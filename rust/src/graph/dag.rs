//! Generic directed acyclic graph with the operations the paper's
//! formulation needs: topological sorting, longest-path start times
//! (eq. 5), and critical-path extraction.
//!
//! Two representations live here:
//!
//! * [`Dag`] — the mutable nested-`Vec` builder, also the reference
//!   implementation the equivalence tests compare against;
//! * [`Csr`] + [`Evaluator`] — the frozen compressed-sparse-row form
//!   with a topo order computed once at build time, whose forward sweep
//!   is the per-step hot path (no Kahn re-run, no allocation).
//!
//! Node payloads are generic; the pipeline-specific structure lives in
//! [`crate::graph::pipeline`].
//!
//! ## Edge weights
//!
//! Longest paths come in two flavours: node-weighted (`start_times`,
//! the PR 1 hot path — durations on nodes, edges free) and
//! node-plus-edge-weighted (`start_times_with_edges`, for P2P
//! communication charged to the cross-rank edges of the pipeline DAG).
//! Edge weights are supplied as one flat slice in **CSR edge order**:
//! edge `k` is the `k`-th edge of the u-major iteration
//! `for u in 0..n { for v in succs[u] }` over the *deduplicated*
//! adjacency — exactly the order [`Csr::from_dag`] freezes into
//! `succ_adj`, so the same slice indexes both representations.

/// Dense-id DAG. Node ids are `usize` handles into `nodes`.
#[derive(Clone, Debug)]
pub struct Dag<T> {
    /// Node payloads, indexed by node id.
    pub nodes: Vec<T>,
    /// Outgoing adjacency: `succs[i]` = nodes j with edge i → j.
    pub succs: Vec<Vec<usize>>,
    /// Incoming adjacency.
    pub preds: Vec<Vec<usize>>,
}

impl<T> Default for Dag<T> {
    fn default() -> Self {
        Dag { nodes: Vec::new(), succs: Vec::new(), preds: Vec::new() }
    }
}

impl<T> Dag<T> {
    /// An empty DAG.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Whether the DAG has no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Add a node, returning its id.
    pub fn add_node(&mut self, payload: T) -> usize {
        self.nodes.push(payload);
        self.succs.push(Vec::new());
        self.preds.push(Vec::new());
        self.nodes.len() - 1
    }

    /// Add edge u → v in O(1). The pipeline edge rules can produce the
    /// same dependency from several rules; duplicates are tolerated here
    /// and removed by [`Dag::dedup_edges`] once construction finishes —
    /// a per-insert `contains` scan made building dense-degree DAGs
    /// O(V·E).
    pub fn add_edge(&mut self, u: usize, v: usize) {
        assert!(u < self.len() && v < self.len(), "edge endpoints out of range");
        assert_ne!(u, v, "self-loop");
        self.succs[u].push(v);
        self.preds[v].push(u);
    }

    /// Finalize construction: sort each adjacency list and drop duplicate
    /// edges (O(E log E) once, instead of O(degree) per insert).
    pub fn dedup_edges(&mut self) {
        for l in self.succs.iter_mut() {
            l.sort_unstable();
            l.dedup();
        }
        for l in self.preds.iter_mut() {
            l.sort_unstable();
            l.dedup();
        }
    }

    /// Stored edge count. Exact once [`Dag::dedup_edges`] has run;
    /// during construction duplicates inserted by overlapping rules are
    /// still counted.
    pub fn edge_count(&self) -> usize {
        self.succs.iter().map(|s| s.len()).sum()
    }

    /// Whether an edge u → v is stored.
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.succs[u].contains(&v)
    }

    /// Kahn topological sort. `None` if the graph contains a cycle.
    pub fn topo_order(&self) -> Option<Vec<usize>> {
        let n = self.len();
        let mut indeg: Vec<usize> = self.preds.iter().map(|p| p.len()).collect();
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            order.push(u);
            for &v in &self.succs[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if order.len() == n {
            Some(order)
        } else {
            None
        }
    }

    /// Whether the graph contains no cycle.
    pub fn is_acyclic(&self) -> bool {
        self.topo_order().is_some()
    }

    /// Longest-path start times (eq. 5):
    /// `P_i = max over preds j of (P_j + w_j)`, with `P = 0` for sources.
    ///
    /// Returns `None` on a cycle. Weights are node durations.
    pub fn start_times(&self, weights: &[f64]) -> Option<Vec<f64>> {
        assert_eq!(weights.len(), self.len());
        let order = self.topo_order()?;
        let mut p = vec![0.0f64; self.len()];
        for &u in &order {
            for &v in &self.succs[u] {
                let cand = p[u] + weights[u];
                if cand > p[v] {
                    p[v] = cand;
                }
            }
        }
        Some(p)
    }

    /// Longest-path start times with per-edge costs:
    /// `P_j = max over edges (i→j) of (P_i + w_i + e_ij)`.
    ///
    /// `edge_costs` is indexed in CSR edge order (u-major over the
    /// deduplicated adjacency — see the module docs); this is the dense
    /// reference implementation the CSR equivalence tests compare
    /// against. Returns `None` on a cycle.
    pub fn start_times_with_edges(
        &self,
        weights: &[f64],
        edge_costs: &[f64],
    ) -> Option<Vec<f64>> {
        assert_eq!(weights.len(), self.len());
        assert_eq!(
            edge_costs.len(),
            self.edge_count(),
            "edge cost vector must cover every stored edge"
        );
        // Prefix offset of each node's edge block in the u-major order.
        let mut off = Vec::with_capacity(self.len() + 1);
        let mut acc = 0usize;
        off.push(acc);
        for l in &self.succs {
            acc += l.len();
            off.push(acc);
        }
        let order = self.topo_order()?;
        let mut p = vec![0.0f64; self.len()];
        for &u in &order {
            let finish = p[u] + weights[u];
            for (k, &v) in self.succs[u].iter().enumerate() {
                let cand = finish + edge_costs[off[u] + k];
                if cand > p[v] {
                    p[v] = cand;
                }
            }
        }
        Some(p)
    }

    /// Makespan: max over nodes of `P_i + w_i`.
    pub fn makespan(&self, weights: &[f64]) -> Option<f64> {
        let p = self.start_times(weights)?;
        Some(
            p.iter()
                .zip(weights)
                .map(|(pi, wi)| pi + wi)
                .fold(0.0f64, f64::max),
        )
    }

    /// One critical path (node ids, source → sink) realizing the makespan.
    pub fn critical_path(&self, weights: &[f64]) -> Option<Vec<usize>> {
        let p = self.start_times(weights)?;
        // Find sink with max finish.
        let mut end = 0usize;
        let mut best = f64::NEG_INFINITY;
        for i in 0..self.len() {
            let f = p[i] + weights[i];
            if f > best {
                best = f;
                end = i;
            }
        }
        // Walk back through predecessors whose finish equals our start.
        let mut path = vec![end];
        let mut cur = end;
        const EPS: f64 = 1e-9;
        while !self.preds[cur].is_empty() {
            let mut next = None;
            for &j in &self.preds[cur] {
                if (p[j] + weights[j] - p[cur]).abs() <= EPS * (1.0 + p[cur].abs()) {
                    next = Some(j);
                    break;
                }
            }
            match next {
                Some(j) => {
                    path.push(j);
                    cur = j;
                }
                // Start of the path: our start is 0 or determined by a
                // predecessor chain with slack (can happen only at P=0).
                None => break,
            }
        }
        path.reverse();
        Some(path)
    }

    /// Reachability from `u` (BFS over successors).
    pub fn reachable_from(&self, u: usize) -> Vec<bool> {
        let mut seen = vec![false; self.len()];
        let mut stack = vec![u];
        seen[u] = true;
        while let Some(x) = stack.pop() {
            for &v in &self.succs[x] {
                if !seen[v] {
                    seen[v] = true;
                    stack.push(v);
                }
            }
        }
        seen
    }

    /// Verify that `order` is a linear extension of this DAG: every edge
    /// u → v has u before v. Used by the schedule property tests.
    pub fn respects_order(&self, order: &[usize]) -> bool {
        if order.len() != self.len() {
            return false;
        }
        let mut pos = vec![usize::MAX; self.len()];
        for (i, &u) in order.iter().enumerate() {
            if u >= self.len() || pos[u] != usize::MAX {
                return false;
            }
            pos[u] = i;
        }
        for u in 0..self.len() {
            for &v in &self.succs[u] {
                if pos[u] >= pos[v] {
                    return false;
                }
            }
        }
        true
    }
}

/// Frozen compressed-sparse-row successor lists with the topological
/// order cached at build time. This is the hot-path representation: the
/// builder's nested `Vec`s cost a pointer chase per node and a full Kahn
/// pass per longest-path query; `Csr` pays for both exactly once.
///
/// Equality compares the frozen adjacency (used as the cache key of the
/// freeze-LP skeleton, which may only be reused across solves over the
/// same DAG).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Csr {
    /// `succ_off[i]..succ_off[i+1]` indexes `succ_adj` for node i.
    succ_off: Vec<u32>,
    succ_adj: Vec<u32>,
    /// One topological order, sources first.
    topo: Vec<u32>,
}

impl Csr {
    /// Freeze a built DAG. `None` if the graph contains a cycle. Call
    /// [`Dag::dedup_edges`] first if construction may have produced
    /// duplicate edges (duplicates are harmless for correctness but
    /// waste sweep time).
    pub fn from_dag<T>(dag: &Dag<T>) -> Option<Csr> {
        let n = dag.len();
        let mut succ_off = Vec::with_capacity(n + 1);
        let mut succ_adj = Vec::with_capacity(dag.edge_count());
        succ_off.push(0u32);
        for l in &dag.succs {
            for &v in l {
                succ_adj.push(v as u32);
            }
            succ_off.push(succ_adj.len() as u32);
        }
        // Kahn over the frozen lists, computed once and cached.
        let mut indeg = vec![0u32; n];
        for &v in &succ_adj {
            indeg[v as usize] += 1;
        }
        let mut topo: Vec<u32> = (0..n as u32).filter(|&i| indeg[i as usize] == 0).collect();
        let mut head = 0;
        while head < topo.len() {
            let u = topo[head] as usize;
            head += 1;
            for &v in &succ_adj[succ_off[u] as usize..succ_off[u + 1] as usize] {
                indeg[v as usize] -= 1;
                if indeg[v as usize] == 0 {
                    topo.push(v);
                }
            }
        }
        if topo.len() == n {
            Some(Csr { succ_off, succ_adj, topo })
        } else {
            None
        }
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.succ_off.len().saturating_sub(1)
    }

    /// Whether the CSR has no nodes.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of stored edges (the length an edge-cost vector must have).
    pub fn edge_count(&self) -> usize {
        self.succ_adj.len()
    }

    /// The cached topological order.
    pub fn topo(&self) -> &[u32] {
        &self.topo
    }

    /// Successors of node `u`.
    #[inline]
    pub fn succ(&self, u: usize) -> &[u32] {
        &self.succ_adj[self.succ_off[u] as usize..self.succ_off[u + 1] as usize]
    }

    /// Index range of node `u`'s outgoing edges in CSR edge order — the
    /// indices into an edge-cost vector that correspond to
    /// [`Csr::succ`]`(u)`, element for element.
    #[inline]
    pub fn edge_range(&self, u: usize) -> std::ops::Range<usize> {
        self.succ_off[u] as usize..self.succ_off[u + 1] as usize
    }

    /// Destination node of edge `e` (CSR edge order).
    #[inline]
    pub fn edge_dst(&self, e: usize) -> usize {
        self.succ_adj[e] as usize
    }

    /// Longest-path start times (eq. 5) into a caller-owned buffer:
    /// one forward sweep over the cached topo order, no allocation.
    pub fn start_times_into(&self, weights: &[f64], out: &mut Vec<f64>) {
        let n = self.len();
        assert_eq!(weights.len(), n);
        out.clear();
        out.resize(n, 0.0);
        for &u in &self.topo {
            let u = u as usize;
            let finish = out[u] + weights[u];
            for &v in self.succ(u) {
                let v = v as usize;
                if finish > out[v] {
                    out[v] = finish;
                }
            }
        }
    }

    /// Longest-path start times with per-edge costs
    /// (`P_j = max (P_i + w_i + e_ij)`) into a caller-owned buffer.
    /// `edge_costs` is in CSR edge order (aligned with `succ_adj`); the
    /// node-only [`Csr::start_times_into`] stays the hot path when no
    /// edges carry cost.
    pub fn start_times_with_edges_into(
        &self,
        weights: &[f64],
        edge_costs: &[f64],
        out: &mut Vec<f64>,
    ) {
        let n = self.len();
        assert_eq!(weights.len(), n);
        assert_eq!(
            edge_costs.len(),
            self.succ_adj.len(),
            "edge cost vector must cover every CSR edge"
        );
        out.clear();
        out.resize(n, 0.0);
        for &u in &self.topo {
            let u = u as usize;
            let finish = out[u] + weights[u];
            let (lo, hi) = (self.succ_off[u] as usize, self.succ_off[u + 1] as usize);
            for e in lo..hi {
                let v = self.succ_adj[e] as usize;
                let cand = finish + edge_costs[e];
                if cand > out[v] {
                    out[v] = cand;
                }
            }
        }
    }
}

/// Incremental ready-set tracker over a frozen [`Csr`] — the frontier
/// iteration the discrete-event engine drives execution with.
///
/// Where the batch sweeps above consume the whole topo order at once, a
/// `Frontier` releases nodes one completion at a time: construction (or
/// [`Frontier::reset`]) charges every node its in-degree, the zero-degree
/// sources form the initial ready set, and [`Frontier::complete`]
/// retires one node, reporting exactly the successors whose last
/// dependency that was. Feeding every released node back into
/// `complete` enumerates a topological order — the property
/// `tests/event_engine.rs` pins — but callers are free to interleave
/// completions in any dependency-respecting order, which is what an
/// event queue does.
#[derive(Clone, Debug)]
pub struct Frontier {
    /// In-degree of every node at construction time (immutable).
    base_indeg: Vec<u32>,
    /// Remaining unsatisfied dependencies per node.
    remaining: Vec<u32>,
    /// Number of nodes retired by `complete` since the last reset.
    done: usize,
}

impl Frontier {
    /// Build the tracker for a frozen CSR.
    pub fn new(csr: &Csr) -> Frontier {
        let mut indeg = vec![0u32; csr.len()];
        for &v in &csr.succ_adj {
            indeg[v as usize] += 1;
        }
        Frontier { remaining: indeg.clone(), base_indeg: indeg, done: 0 }
    }

    /// Restore the initial state (every dependency unsatisfied).
    pub fn reset(&mut self) {
        self.remaining.copy_from_slice(&self.base_indeg);
        self.done = 0;
    }

    /// Nodes with no dependencies — the initial ready set.
    pub fn sources(&self) -> impl Iterator<Item = usize> + '_ {
        self.base_indeg
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| i)
    }

    /// Whether every dependency of `v` has been satisfied.
    pub fn is_ready(&self, v: usize) -> bool {
        self.remaining[v] == 0
    }

    /// Number of nodes retired since construction/reset.
    pub fn completed(&self) -> usize {
        self.done
    }

    /// Whether every node has been retired.
    pub fn is_drained(&self) -> bool {
        self.done == self.remaining.len()
    }

    /// Retire node `u`, invoking `on_ready(v)` for each successor whose
    /// last unsatisfied dependency was the `u → v` edge. `u` must itself
    /// be ready (all dependencies satisfied) and not yet retired.
    pub fn complete<F: FnMut(usize)>(&mut self, csr: &Csr, u: usize, mut on_ready: F) {
        debug_assert_eq!(self.remaining[u], 0, "completing a non-ready node");
        self.done += 1;
        for &v in csr.succ(u) {
            let v = v as usize;
            if self.satisfy(v) {
                on_ready(v);
            }
        }
    }

    /// Satisfy a single dependency of `v`, returning `true` when it was
    /// the last outstanding one. This is the per-edge primitive behind
    /// [`Frontier::complete`]; the event engine calls it directly
    /// because a node's incoming edges deliver at *different* times
    /// (P2P messages in flight), so dependencies retire one arrival at
    /// a time rather than all at once.
    pub fn satisfy(&mut self, v: usize) -> bool {
        debug_assert!(self.remaining[v] > 0, "over-satisfying node {v}");
        self.remaining[v] -= 1;
        self.remaining[v] == 0
    }
}

/// Incremental longest-path evaluator: start times stay resident
/// between sweeps and a change to a few node weights re-relaxes only
/// the affected CSR frontier instead of re-running the whole forward
/// sweep — the graph-layer half of the incremental replan fast path.
///
/// A full sweep ([`DeltaEvaluator::full`]) primes the state; each
/// [`DeltaEvaluator::update`] then applies a change set `(node, new
/// weight)` by marking the changed nodes' successors dirty and pulling
/// fresh start times in topological-position order, propagating only
/// where a value actually moved. Results are **bit-identical** to the
/// full sweep on the same weights: the pull recomputation takes the max
/// over exactly the same `P_u + w_u (+ e)` candidates the push sweep
/// folds, and `f64::max` over a fixed candidate set is
/// order-independent (property-tested in `tests/perf_equivalence.rs`,
/// including empty and all-node change sets).
///
/// Edge costs (CSR edge order, as everywhere) are part of the primed
/// state; [`DeltaEvaluator::refresh`] is the convenience entry that
/// diffs a whole new weight vector against the resident one and picks
/// delta propagation or a full sweep, falling back to the full sweep
/// when the edge costs changed or the change set is too large for the
/// frontier walk to win.
#[derive(Clone, Debug)]
pub struct DeltaEvaluator {
    csr: Csr,
    /// Transposed adjacency: `pred_off[v]..pred_off[v+1]` indexes
    /// `pred_adj`/`pred_edge` for node v.
    pred_off: Vec<u32>,
    pred_adj: Vec<u32>,
    /// CSR edge id of each predecessor entry (edge-cost lookup).
    pred_edge: Vec<u32>,
    /// Topological position of every node (inverse of `csr.topo`).
    topo_pos: Vec<u32>,
    /// Resident node weights of the primed state.
    weights: Vec<f64>,
    /// Resident edge costs (empty ⇔ free edges).
    edge_costs: Vec<f64>,
    /// Resident start times (valid once primed).
    starts: Vec<f64>,
    primed: bool,
    /// Queued-for-recompute marker per node.
    dirty: Vec<bool>,
    /// Pending topological positions, smallest first.
    heap: std::collections::BinaryHeap<std::cmp::Reverse<u32>>,
    /// Scratch change list for [`DeltaEvaluator::refresh`].
    changed_scratch: Vec<(usize, f64)>,
}

impl DeltaEvaluator {
    /// Build the evaluator (with its predecessor transpose) for a
    /// frozen CSR. Unprimed until the first [`DeltaEvaluator::full`].
    pub fn new(csr: &Csr) -> DeltaEvaluator {
        let n = csr.len();
        let ne = csr.edge_count();
        let mut indeg = vec![0u32; n];
        for e in 0..ne {
            indeg[csr.edge_dst(e)] += 1;
        }
        let mut pred_off = Vec::with_capacity(n + 1);
        let mut acc = 0u32;
        pred_off.push(0u32);
        for &d in &indeg {
            acc += d;
            pred_off.push(acc);
        }
        let mut next: Vec<u32> = pred_off[..n].to_vec();
        let mut pred_adj = vec![0u32; ne];
        let mut pred_edge = vec![0u32; ne];
        for u in 0..n {
            for e in csr.edge_range(u) {
                let v = csr.edge_dst(e);
                let slot = next[v] as usize;
                next[v] += 1;
                pred_adj[slot] = u as u32;
                pred_edge[slot] = e as u32;
            }
        }
        let mut topo_pos = vec![0u32; n];
        for (pos, &u) in csr.topo().iter().enumerate() {
            topo_pos[u as usize] = pos as u32;
        }
        DeltaEvaluator {
            csr: csr.clone(),
            pred_off,
            pred_adj,
            pred_edge,
            topo_pos,
            weights: vec![0.0; n],
            edge_costs: Vec::new(),
            starts: vec![0.0; n],
            primed: false,
            dirty: vec![false; n],
            heap: std::collections::BinaryHeap::new(),
            changed_scratch: Vec::new(),
        }
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.csr.len()
    }

    /// Whether the underlying CSR has no nodes.
    pub fn is_empty(&self) -> bool {
        self.csr.is_empty()
    }

    /// Whether a full sweep has primed the resident state.
    pub fn is_primed(&self) -> bool {
        self.primed
    }

    /// Start times of the resident state (valid once primed).
    pub fn starts(&self) -> &[f64] {
        &self.starts
    }

    /// Prime (or re-prime) with a full forward sweep under `weights`
    /// and optional CSR-ordered `edge_costs`. Bit-identical to
    /// [`Csr::start_times_into`] / [`Csr::start_times_with_edges_into`].
    pub fn full(&mut self, weights: &[f64], edge_costs: Option<&[f64]>) -> &[f64] {
        assert_eq!(weights.len(), self.csr.len());
        self.weights.clear();
        self.weights.extend_from_slice(weights);
        match edge_costs {
            None => {
                self.edge_costs.clear();
                self.csr.start_times_into(weights, &mut self.starts);
            }
            Some(ec) => {
                self.edge_costs.clear();
                self.edge_costs.extend_from_slice(ec);
                self.csr.start_times_with_edges_into(weights, ec, &mut self.starts);
            }
        }
        self.dirty.fill(false);
        self.heap.clear();
        self.primed = true;
        &self.starts
    }

    /// Apply a change set `(node, new weight)` to the primed state,
    /// re-relaxing start times only over the affected frontier. Entries
    /// whose weight is unchanged cost nothing; an empty set is free.
    ///
    /// Panics if called before [`DeltaEvaluator::full`].
    pub fn update(&mut self, changed: &[(usize, f64)]) -> &[f64] {
        assert!(self.primed, "DeltaEvaluator::update before a priming full sweep");
        for &(u, w) in changed {
            if self.weights[u] == w {
                continue;
            }
            self.weights[u] = w;
            // P_u itself is unaffected by w_u; its successors are the
            // initial frontier.
            for e in self.csr.edge_range(u) {
                let v = self.csr.edge_dst(e);
                if !self.dirty[v] {
                    self.dirty[v] = true;
                    self.heap.push(std::cmp::Reverse(self.topo_pos[v]));
                }
            }
        }
        let edged = !self.edge_costs.is_empty();
        while let Some(std::cmp::Reverse(pos)) = self.heap.pop() {
            let v = self.csr.topo()[pos as usize] as usize;
            if !self.dirty[v] {
                continue; // stale duplicate
            }
            self.dirty[v] = false;
            // Pull: recompute P_v from scratch over its predecessors
            // (the same candidates the push sweep folds, so the max is
            // bit-identical). Every predecessor's position precedes
            // `pos`, so its value is already final.
            let mut p = 0.0f64;
            for k in self.pred_off[v] as usize..self.pred_off[v + 1] as usize {
                let u = self.pred_adj[k] as usize;
                let mut cand = self.starts[u] + self.weights[u];
                if edged {
                    cand += self.edge_costs[self.pred_edge[k] as usize];
                }
                if cand > p {
                    p = cand;
                }
            }
            if p != self.starts[v] {
                self.starts[v] = p;
                for e in self.csr.edge_range(v) {
                    let s = self.csr.edge_dst(e);
                    if !self.dirty[s] {
                        self.dirty[s] = true;
                        self.heap.push(std::cmp::Reverse(self.topo_pos[s]));
                    }
                }
            }
        }
        &self.starts
    }

    /// Diff a whole new weight vector (and optional edge costs) against
    /// the resident state and take the cheaper path: delta propagation
    /// for small change sets, a re-priming full sweep when unprimed,
    /// when the edge costs moved, or when more than ~1/8 of the nodes
    /// changed (the frontier walk's bookkeeping stops paying there).
    pub fn refresh(&mut self, weights: &[f64], edge_costs: Option<&[f64]>) -> &[f64] {
        let n = self.csr.len();
        assert_eq!(weights.len(), n);
        let edges_match = match edge_costs {
            None => self.edge_costs.is_empty(),
            Some(ec) => self.edge_costs == ec,
        };
        if !self.primed || !edges_match {
            return self.full(weights, edge_costs);
        }
        let mut changed = std::mem::take(&mut self.changed_scratch);
        changed.clear();
        let cutoff = (n / 8).max(8);
        let mut overflow = false;
        for (i, (&w_new, &w_old)) in weights.iter().zip(&self.weights).enumerate() {
            if w_new != w_old {
                if changed.len() >= cutoff {
                    overflow = true;
                    break;
                }
                changed.push((i, w_new));
            }
        }
        if overflow {
            self.changed_scratch = changed;
            return self.full(weights, edge_costs);
        }
        self.update(&changed);
        self.changed_scratch = changed;
        &self.starts
    }
}

/// Reusable longest-path evaluator: a [`Csr`] plus a scratch buffer, so
/// per-step callers (simulator, LP envelopes, benches) evaluate
/// `start_times` without allocating or re-sorting.
#[derive(Clone, Debug)]
pub struct Evaluator {
    csr: Csr,
    scratch: Vec<f64>,
}

impl Evaluator {
    /// Wrap a frozen CSR with a scratch buffer sized for it.
    pub fn new(csr: Csr) -> Evaluator {
        let n = csr.len();
        Evaluator { csr, scratch: vec![0.0; n] }
    }

    /// Freeze a built DAG into an evaluator. `None` on a cycle.
    pub fn from_dag<T>(dag: &Dag<T>) -> Option<Evaluator> {
        Csr::from_dag(dag).map(Evaluator::new)
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.csr.len()
    }

    /// Whether the underlying CSR has no nodes.
    pub fn is_empty(&self) -> bool {
        self.csr.is_empty()
    }

    /// The underlying CSR.
    pub fn csr(&self) -> &Csr {
        &self.csr
    }

    /// Start times under `weights`; the slice borrows the internal
    /// scratch buffer and is valid until the next call.
    pub fn start_times(&mut self, weights: &[f64]) -> &[f64] {
        let mut out = std::mem::take(&mut self.scratch);
        self.csr.start_times_into(weights, &mut out);
        self.scratch = out;
        &self.scratch
    }

    /// Start times under `weights` plus CSR-ordered `edge_costs`; the
    /// slice borrows the internal scratch buffer and is valid until the
    /// next call.
    pub fn start_times_with_edges(&mut self, weights: &[f64], edge_costs: &[f64]) -> &[f64] {
        let mut out = std::mem::take(&mut self.scratch);
        self.csr.start_times_with_edges_into(weights, edge_costs, &mut out);
        self.scratch = out;
        &self.scratch
    }

    /// Makespan: max over nodes of `P_i + w_i`.
    pub fn makespan(&mut self, weights: &[f64]) -> f64 {
        let p = self.start_times(weights);
        p.iter().zip(weights).map(|(pi, wi)| pi + wi).fold(0.0f64, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Dag<&'static str> {
        // a → b → d, a → c → d
        let mut g = Dag::new();
        let a = g.add_node("a");
        let b = g.add_node("b");
        let c = g.add_node("c");
        let d = g.add_node("d");
        g.add_edge(a, b);
        g.add_edge(a, c);
        g.add_edge(b, d);
        g.add_edge(c, d);
        g
    }

    #[test]
    fn topo_sort_diamond() {
        let g = diamond();
        let order = g.topo_order().unwrap();
        assert!(g.respects_order(&order));
        assert_eq!(order[0], 0);
        assert_eq!(order[3], 3);
    }

    #[test]
    fn cycle_detected() {
        let mut g = Dag::new();
        let a = g.add_node(());
        let b = g.add_node(());
        g.add_edge(a, b);
        g.add_edge(b, a);
        assert!(!g.is_acyclic());
        assert!(g.start_times(&[1.0, 1.0]).is_none());
    }

    #[test]
    fn longest_path_takes_slow_branch() {
        let g = diamond();
        // b is slow (5), c is fast (1).
        let w = [1.0, 5.0, 1.0, 2.0];
        let p = g.start_times(&w).unwrap();
        assert_eq!(p[0], 0.0);
        assert_eq!(p[1], 1.0);
        assert_eq!(p[2], 1.0);
        assert_eq!(p[3], 6.0); // via b
        assert_eq!(g.makespan(&w).unwrap(), 8.0);
    }

    #[test]
    fn critical_path_via_slow_branch() {
        let g = diamond();
        let w = [1.0, 5.0, 1.0, 2.0];
        let cp = g.critical_path(&w).unwrap();
        assert_eq!(cp, vec![0, 1, 3]);
    }

    #[test]
    fn duplicate_edges_removed_by_dedup() {
        let mut g = Dag::new();
        let a = g.add_node(());
        let b = g.add_node(());
        g.add_edge(a, b);
        g.add_edge(a, b);
        // O(1) inserts keep duplicates until the finalize pass…
        assert_eq!(g.edge_count(), 2);
        g.dedup_edges();
        assert_eq!(g.edge_count(), 1);
        assert_eq!(g.preds[b], vec![a]);
        // …and longest paths are correct either way.
        assert_eq!(g.makespan(&[1.0, 2.0]).unwrap(), 3.0);
    }

    #[test]
    fn csr_matches_dense_on_diamond() {
        let g = diamond();
        let csr = Csr::from_dag(&g).unwrap();
        assert_eq!(csr.len(), 4);
        assert_eq!(csr.topo().len(), 4);
        let w = [1.0, 5.0, 1.0, 2.0];
        let mut out = Vec::new();
        csr.start_times_into(&w, &mut out);
        assert_eq!(out, g.start_times(&w).unwrap());
        let mut ev = Evaluator::new(csr);
        assert_eq!(ev.makespan(&w), g.makespan(&w).unwrap());
        // Scratch reuse across weight vectors.
        let w2 = [1.0, 1.0, 7.0, 2.0];
        assert_eq!(ev.start_times(&w2), &g.start_times(&w2).unwrap()[..]);
    }

    #[test]
    fn edge_costs_shift_longest_paths() {
        let g = diamond();
        let w = [1.0, 5.0, 1.0, 2.0];
        // Edges in u-major order: a→b, a→c, b→d, c→d. A huge cost on
        // c→d reroutes the critical path through the fast branch.
        let ec = [0.0, 0.0, 0.0, 10.0];
        let dense = g.start_times_with_edges(&w, &ec).unwrap();
        assert_eq!(dense[3], 12.0); // via c: 1 + 1 + 10
        let csr = Csr::from_dag(&g).unwrap();
        let mut out = Vec::new();
        csr.start_times_with_edges_into(&w, &ec, &mut out);
        assert_eq!(out, dense);
        let mut ev = Evaluator::new(csr);
        assert_eq!(ev.start_times_with_edges(&w, &ec), &dense[..]);
        // Zero edge costs reproduce the node-only sweep bit-for-bit.
        let zeros = vec![0.0; 4];
        assert_eq!(
            g.start_times_with_edges(&w, &zeros).unwrap(),
            g.start_times(&w).unwrap()
        );
    }

    #[test]
    fn csr_detects_cycle() {
        let mut g = Dag::new();
        let a = g.add_node(());
        let b = g.add_node(());
        g.add_edge(a, b);
        g.add_edge(b, a);
        assert!(Csr::from_dag(&g).is_none());
        assert!(Evaluator::from_dag(&g).is_none());
    }

    #[test]
    fn frontier_releases_a_topo_order() {
        let g = diamond();
        let csr = Csr::from_dag(&g).unwrap();
        let mut frontier = Frontier::new(&csr);
        let mut ready: Vec<usize> = frontier.sources().collect();
        assert_eq!(ready, vec![0]);
        let mut order = Vec::new();
        while let Some(u) = ready.pop() {
            assert!(frontier.is_ready(u));
            order.push(u);
            frontier.complete(&csr, u, |v| ready.push(v));
        }
        assert!(frontier.is_drained());
        assert_eq!(frontier.completed(), 4);
        assert!(g.respects_order(&order));
        // Reset restores the initial state exactly.
        frontier.reset();
        assert_eq!(frontier.completed(), 0);
        assert!(frontier.is_ready(0) && !frontier.is_ready(3));
    }

    #[test]
    fn delta_evaluator_matches_full_sweep_on_diamond() {
        let g = diamond();
        let csr = Csr::from_dag(&g).unwrap();
        let mut de = DeltaEvaluator::new(&csr);
        assert!(!de.is_primed());
        let w = [1.0, 5.0, 1.0, 2.0];
        de.full(&w, None);
        assert_eq!(de.starts(), &g.start_times(&w).unwrap()[..]);
        // Change the slow branch: only b's descendants re-relax.
        let w2 = [1.0, 0.5, 1.0, 2.0];
        de.update(&[(1, 0.5)]);
        assert_eq!(de.starts(), &g.start_times(&w2).unwrap()[..]);
        // Empty change set is free and exact.
        de.update(&[]);
        assert_eq!(de.starts(), &g.start_times(&w2).unwrap()[..]);
        // Same-value entries cost nothing.
        de.update(&[(1, 0.5), (2, 1.0)]);
        assert_eq!(de.starts(), &g.start_times(&w2).unwrap()[..]);
        // All-node change set equals a fresh full sweep bit-for-bit.
        let w3 = [2.0, 1.0, 7.0, 0.5];
        let changed: Vec<(usize, f64)> = w3.iter().copied().enumerate().collect();
        de.update(&changed);
        let mut full = Vec::new();
        csr.start_times_into(&w3, &mut full);
        assert_eq!(de.starts(), &full[..]);
    }

    #[test]
    fn delta_evaluator_tracks_edge_costs() {
        let g = diamond();
        let csr = Csr::from_dag(&g).unwrap();
        let mut de = DeltaEvaluator::new(&csr);
        let w = [1.0, 5.0, 1.0, 2.0];
        let ec = [0.0, 0.0, 0.0, 10.0];
        de.full(&w, Some(&ec));
        assert_eq!(de.starts(), &g.start_times_with_edges(&w, &ec).unwrap()[..]);
        // Weight drift under resident edge costs.
        let w2 = [1.0, 9.0, 1.0, 2.0];
        de.update(&[(1, 9.0)]);
        assert_eq!(de.starts(), &g.start_times_with_edges(&w2, &ec).unwrap()[..]);
        // refresh() notices changed edge costs and re-primes.
        let ec2 = [0.0, 0.0, 0.0, 0.0];
        de.refresh(&w2, Some(&ec2));
        assert_eq!(de.starts(), &g.start_times_with_edges(&w2, &ec2).unwrap()[..]);
        // …and diffs weights when they match.
        let w3 = [1.0, 9.0, 4.0, 2.0];
        de.refresh(&w3, Some(&ec2));
        assert_eq!(de.starts(), &g.start_times_with_edges(&w3, &ec2).unwrap()[..]);
    }

    #[test]
    fn respects_order_rejects_violations() {
        let g = diamond();
        assert!(!g.respects_order(&[3, 2, 1, 0]));
        assert!(!g.respects_order(&[0, 1, 2])); // wrong length
        assert!(!g.respects_order(&[0, 0, 1, 2])); // duplicate
    }

    #[test]
    fn reachability() {
        let g = diamond();
        let r = g.reachable_from(1);
        assert_eq!(r, vec![false, true, false, true]);
    }

    #[test]
    fn empty_graph() {
        let g: Dag<()> = Dag::new();
        assert!(g.is_acyclic());
        assert_eq!(g.makespan(&[]), Some(0.0));
    }
}
