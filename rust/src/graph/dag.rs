//! Generic directed acyclic graph with the operations the paper's
//! formulation needs: topological sorting, longest-path start times
//! (eq. 5), and critical-path extraction.
//!
//! Node payloads are generic; the pipeline-specific structure lives in
//! [`crate::graph::pipeline`].

/// Dense-id DAG. Node ids are `usize` handles into `nodes`.
#[derive(Clone, Debug)]
pub struct Dag<T> {
    pub nodes: Vec<T>,
    /// Outgoing adjacency: `succs[i]` = nodes j with edge i → j.
    pub succs: Vec<Vec<usize>>,
    /// Incoming adjacency.
    pub preds: Vec<Vec<usize>>,
}

impl<T> Default for Dag<T> {
    fn default() -> Self {
        Dag { nodes: Vec::new(), succs: Vec::new(), preds: Vec::new() }
    }
}

impl<T> Dag<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn add_node(&mut self, payload: T) -> usize {
        self.nodes.push(payload);
        self.succs.push(Vec::new());
        self.preds.push(Vec::new());
        self.nodes.len() - 1
    }

    /// Add edge u → v. Duplicate edges are ignored (the pipeline edge
    /// rules can produce the same dependency from several rules).
    pub fn add_edge(&mut self, u: usize, v: usize) {
        assert!(u < self.len() && v < self.len(), "edge endpoints out of range");
        assert_ne!(u, v, "self-loop");
        if !self.succs[u].contains(&v) {
            self.succs[u].push(v);
            self.preds[v].push(u);
        }
    }

    pub fn edge_count(&self) -> usize {
        self.succs.iter().map(|s| s.len()).sum()
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.succs[u].contains(&v)
    }

    /// Kahn topological sort. `None` if the graph contains a cycle.
    pub fn topo_order(&self) -> Option<Vec<usize>> {
        let n = self.len();
        let mut indeg: Vec<usize> = self.preds.iter().map(|p| p.len()).collect();
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            order.push(u);
            for &v in &self.succs[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if order.len() == n {
            Some(order)
        } else {
            None
        }
    }

    pub fn is_acyclic(&self) -> bool {
        self.topo_order().is_some()
    }

    /// Longest-path start times (eq. 5):
    /// `P_i = max over preds j of (P_j + w_j)`, with `P = 0` for sources.
    ///
    /// Returns `None` on a cycle. Weights are node durations.
    pub fn start_times(&self, weights: &[f64]) -> Option<Vec<f64>> {
        assert_eq!(weights.len(), self.len());
        let order = self.topo_order()?;
        let mut p = vec![0.0f64; self.len()];
        for &u in &order {
            for &v in &self.succs[u] {
                let cand = p[u] + weights[u];
                if cand > p[v] {
                    p[v] = cand;
                }
            }
        }
        Some(p)
    }

    /// Makespan: max over nodes of `P_i + w_i`.
    pub fn makespan(&self, weights: &[f64]) -> Option<f64> {
        let p = self.start_times(weights)?;
        Some(
            p.iter()
                .zip(weights)
                .map(|(pi, wi)| pi + wi)
                .fold(0.0f64, f64::max),
        )
    }

    /// One critical path (node ids, source → sink) realizing the makespan.
    pub fn critical_path(&self, weights: &[f64]) -> Option<Vec<usize>> {
        let p = self.start_times(weights)?;
        // Find sink with max finish.
        let mut end = 0usize;
        let mut best = f64::NEG_INFINITY;
        for i in 0..self.len() {
            let f = p[i] + weights[i];
            if f > best {
                best = f;
                end = i;
            }
        }
        // Walk back through predecessors whose finish equals our start.
        let mut path = vec![end];
        let mut cur = end;
        const EPS: f64 = 1e-9;
        while !self.preds[cur].is_empty() {
            let mut next = None;
            for &j in &self.preds[cur] {
                if (p[j] + weights[j] - p[cur]).abs() <= EPS * (1.0 + p[cur].abs()) {
                    next = Some(j);
                    break;
                }
            }
            match next {
                Some(j) => {
                    path.push(j);
                    cur = j;
                }
                // Start of the path: our start is 0 or determined by a
                // predecessor chain with slack (can happen only at P=0).
                None => break,
            }
        }
        path.reverse();
        Some(path)
    }

    /// Reachability from `u` (BFS over successors).
    pub fn reachable_from(&self, u: usize) -> Vec<bool> {
        let mut seen = vec![false; self.len()];
        let mut stack = vec![u];
        seen[u] = true;
        while let Some(x) = stack.pop() {
            for &v in &self.succs[x] {
                if !seen[v] {
                    seen[v] = true;
                    stack.push(v);
                }
            }
        }
        seen
    }

    /// Verify that `order` is a linear extension of this DAG: every edge
    /// u → v has u before v. Used by the schedule property tests.
    pub fn respects_order(&self, order: &[usize]) -> bool {
        if order.len() != self.len() {
            return false;
        }
        let mut pos = vec![usize::MAX; self.len()];
        for (i, &u) in order.iter().enumerate() {
            if u >= self.len() || pos[u] != usize::MAX {
                return false;
            }
            pos[u] = i;
        }
        for u in 0..self.len() {
            for &v in &self.succs[u] {
                if pos[u] >= pos[v] {
                    return false;
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Dag<&'static str> {
        // a → b → d, a → c → d
        let mut g = Dag::new();
        let a = g.add_node("a");
        let b = g.add_node("b");
        let c = g.add_node("c");
        let d = g.add_node("d");
        g.add_edge(a, b);
        g.add_edge(a, c);
        g.add_edge(b, d);
        g.add_edge(c, d);
        g
    }

    #[test]
    fn topo_sort_diamond() {
        let g = diamond();
        let order = g.topo_order().unwrap();
        assert!(g.respects_order(&order));
        assert_eq!(order[0], 0);
        assert_eq!(order[3], 3);
    }

    #[test]
    fn cycle_detected() {
        let mut g = Dag::new();
        let a = g.add_node(());
        let b = g.add_node(());
        g.add_edge(a, b);
        g.add_edge(b, a);
        assert!(!g.is_acyclic());
        assert!(g.start_times(&[1.0, 1.0]).is_none());
    }

    #[test]
    fn longest_path_takes_slow_branch() {
        let g = diamond();
        // b is slow (5), c is fast (1).
        let w = [1.0, 5.0, 1.0, 2.0];
        let p = g.start_times(&w).unwrap();
        assert_eq!(p[0], 0.0);
        assert_eq!(p[1], 1.0);
        assert_eq!(p[2], 1.0);
        assert_eq!(p[3], 6.0); // via b
        assert_eq!(g.makespan(&w).unwrap(), 8.0);
    }

    #[test]
    fn critical_path_via_slow_branch() {
        let g = diamond();
        let w = [1.0, 5.0, 1.0, 2.0];
        let cp = g.critical_path(&w).unwrap();
        assert_eq!(cp, vec![0, 1, 3]);
    }

    #[test]
    fn duplicate_edges_ignored() {
        let mut g = Dag::new();
        let a = g.add_node(());
        let b = g.add_node(());
        g.add_edge(a, b);
        g.add_edge(a, b);
        assert_eq!(g.edge_count(), 1);
    }

    #[test]
    fn respects_order_rejects_violations() {
        let g = diamond();
        assert!(!g.respects_order(&[3, 2, 1, 0]));
        assert!(!g.respects_order(&[0, 1, 2])); // wrong length
        assert!(!g.respects_order(&[0, 0, 1, 2])); // duplicate
    }

    #[test]
    fn reachability() {
        let g = diamond();
        let r = g.reachable_from(1);
        assert_eq!(r, vec![false, true, false, true]);
    }

    #[test]
    fn empty_graph() {
        let g: Dag<()> = Dag::new();
        assert!(g.is_acyclic());
        assert_eq!(g.makespan(&[]), Some(0.0));
    }
}
