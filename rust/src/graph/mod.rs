//! Graph layer: a generic DAG (topological sort, longest path, critical
//! path) and the pipeline-schedule DAG of §3.2.1 built on top of it.

pub mod dag;
pub mod pipeline;

pub use dag::Dag;
pub use pipeline::{structural_edges, Node, PipelineDag};
