//! Graph layer: a generic DAG (topological sort, longest path, critical
//! path), its frozen CSR form with a cached topo order for the per-step
//! hot path, and the pipeline-schedule DAG of §3.2.1 built on top.

pub mod dag;
pub mod pipeline;

pub use dag::{Csr, Dag, DeltaEvaluator, Evaluator, Frontier};
pub use pipeline::{structural_edges, BatchEvaluator, Node, PipelineDag};
