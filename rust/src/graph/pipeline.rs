//! Pipeline DAG construction (§3.2.1 + Appendix B).
//!
//! Nodes are the schedule's actions plus abstract source/destination
//! nodes; edges encode:
//!   rule 1 — source/destination connections,
//!   rule 2 — intra-stage dependencies (microbatch order, f → b),
//!   rule 3 — inter-stage dependencies (forward chain down, backward
//!            chain up),
//!   rule 4 — same-rank schedule order (device exclusivity as scheduled).
//!
//! The same DAG serves three consumers: the LP formulation (§3.2.2), the
//! discrete-event simulator, and the schedule property tests.

use crate::graph::dag::{Csr, Dag, DeltaEvaluator, Evaluator};
use crate::schedule::Schedule;
use crate::types::{Action, ActionKind};
use std::collections::BTreeMap;

/// Node payload in the pipeline DAG.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Node {
    /// The abstract source node `v_s` (zero weight, starts the batch).
    Source,
    /// The abstract destination node `v_d` (zero weight, ends the batch).
    Dest,
    /// A schedule action.
    Act(Action),
}

impl Node {
    /// The wrapped action, if this is an action node.
    pub fn action(&self) -> Option<Action> {
        match self {
            Node::Act(a) => Some(*a),
            _ => None,
        }
    }
}

/// Structural dependencies (rules 2–3) derived purely from the action
/// set — used both by the DAG builder and by the greedy list scheduler
/// (which must not see rule-4 edges, since those are what it produces).
pub fn structural_edges(
    actions: &[Action],
    stages: usize,
    _microbatches: usize,
) -> Vec<(Action, Action)> {
    let set: std::collections::BTreeSet<Action> = actions.iter().copied().collect();
    let has = |a: Action| set.contains(&a);
    let mut edges = Vec::new();
    let mut push = |u: Action, v: Action| {
        if has(u) && has(v) {
            edges.push((u, v));
        }
    };
    for &a in actions {
        let (m, s) = (a.mb, a.stage);
        // Rule 2a: intra-stage microbatch ordering (a, m, s) → (a, m+1, s).
        push(a, Action { kind: a.kind, mb: m + 1, stage: s });
        match a.kind {
            ActionKind::Forward => {
                // Rule 3: forward chain down the stages.
                if s + 1 < stages {
                    push(a, Action::f(m, s + 1));
                }
                // Rule 2b: backward after its forward.
                push(a, Action::b(m, s));
                push(a, Action::bd(m, s));
            }
            ActionKind::Backward => {
                // Rule 3: backward chain up the stages.
                if s > 0 {
                    push(a, Action::b(m, s - 1));
                }
            }
            ActionKind::BackwardDgrad => {
                if s > 0 {
                    push(a, Action::bd(m, s - 1));
                }
                // Zero-Bubble: W consumes the incoming gradient that B
                // materializes; schedule W after its B.
                push(a, Action::bw(m, s));
            }
            ActionKind::BackwardWgrad => {}
        }
    }
    edges
}

/// The pipeline DAG of one batch.
#[derive(Clone, Debug)]
pub struct PipelineDag {
    /// The builder/reference DAG with [`Node`] payloads.
    pub dag: Dag<Node>,
    /// Frozen CSR form with the topo order cached at construction — the
    /// longest-path hot path. `dag` stays as the builder/reference form.
    pub csr: Csr,
    /// Node id of the abstract source `v_s`.
    pub source: usize,
    /// Node id of the abstract destination `v_d`.
    pub dest: usize,
    /// Action → node id.
    pub index: BTreeMap<Action, usize>,
    /// Rank hosting each node (source/dest map to rank 0 by convention —
    /// they carry zero weight and never execute).
    pub rank_of_node: Vec<usize>,
    /// Virtual stage count of the schedule.
    pub stages: usize,
    /// Physical rank count of the schedule.
    pub ranks: usize,
    /// Microbatches per batch.
    pub microbatches: usize,
}

impl PipelineDag {
    /// Build the batch DAG of a schedule (rules 1–4 of Appendix B) and
    /// freeze its CSR form.
    pub fn from_schedule(schedule: &Schedule) -> PipelineDag {
        debug_assert!(schedule.validate().is_ok());
        let mut dag: Dag<Node> = Dag::new();
        let source = dag.add_node(Node::Source);
        let dest = dag.add_node(Node::Dest);
        let mut index = BTreeMap::new();
        let mut rank_of_node = vec![0usize, 0usize];

        for (rank, order) in schedule.orders.iter().enumerate() {
            for &a in order {
                let id = dag.add_node(Node::Act(a));
                index.insert(a, id);
                rank_of_node.push(rank);
            }
        }

        // Rules 2–3.
        let actions = schedule.all_actions();
        for (u, v) in structural_edges(&actions, schedule.stages, schedule.microbatches) {
            dag.add_edge(index[&u], index[&v]);
        }
        // Rule 4: same-rank schedule order (consecutive pairs suffice —
        // transitivity gives the rest).
        for order in &schedule.orders {
            for pair in order.windows(2) {
                dag.add_edge(index[&pair[0]], index[&pair[1]]);
            }
        }
        // Edges were inserted in O(1); drop the duplicates produced by
        // overlapping rules before freezing the CSR form.
        dag.dedup_edges();
        // Rule 1: source feeds every orphan; every terminal feeds dest.
        // (The paper wires v_s → f(1,1) and b(M,1) → v_d; with rule 2–4
        // edges in place the only orphan is f(1,1) and the only terminal
        // is the last action of the batch, so this generalizes the
        // paper's rule to all schedule shapes, including ZBV's V.)
        for id in 2..dag.len() {
            if dag.preds[id].is_empty() {
                dag.add_edge(source, id);
            }
        }
        for id in 2..dag.len() {
            if dag.succs[id].is_empty() {
                dag.add_edge(id, dest);
            }
        }
        let csr = Csr::from_dag(&dag).expect("pipeline DAG must be acyclic");

        PipelineDag {
            dag,
            csr,
            source,
            dest,
            index,
            rank_of_node,
            stages: schedule.stages,
            ranks: schedule.ranks,
            microbatches: schedule.microbatches,
        }
    }

    /// Non-panicking variant of [`PipelineDag::from_schedule`] for
    /// arbitrary (synthesized or fuzzed) orders: runs
    /// [`Schedule::check_legal`] first and reports the violation as an
    /// `Err` instead of panicking inside the CSR freeze when the
    /// combined rule 1–4 edge set has a cycle.
    pub fn from_schedule_checked(schedule: &Schedule) -> Result<PipelineDag, String> {
        schedule.check_legal()?;
        Ok(PipelineDag::from_schedule(schedule))
    }

    /// Number of nodes (actions + source + dest).
    pub fn len(&self) -> usize {
        self.dag.len()
    }

    /// Whether the DAG has no nodes.
    pub fn is_empty(&self) -> bool {
        self.dag.is_empty()
    }

    /// The action at a node id (`None` for source/dest).
    pub fn node_action(&self, id: usize) -> Option<Action> {
        self.dag.nodes[id].action()
    }

    /// A structural fingerprint of the DAG: FNV-1a over the node count,
    /// shape parameters, per-node rank ownership, and the full CSR edge
    /// list. Two DAGs share a signature exactly when they describe the
    /// same batch structure over the same fleet — the runner keys its
    /// shadow-run memo on this so an elastic repartition (fewer ranks,
    /// different layer split) can never read a stale baseline.
    pub fn signature(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut mix = |x: u64| {
            for byte in x.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        };
        mix(self.dag.len() as u64);
        mix(self.stages as u64);
        mix(self.ranks as u64);
        mix(self.microbatches as u64);
        for &r in &self.rank_of_node {
            mix(r as u64);
        }
        for u in 0..self.csr.len() {
            for e in self.csr.edge_range(u) {
                mix(u as u64);
                mix(self.csr.edge_dst(e) as u64);
            }
        }
        h
    }

    /// Build a node-aligned weight vector from a per-action duration
    /// function; source/dest get zero (`w_s = w_d = 0`).
    pub fn weights<F: Fn(Action) -> f64>(&self, f: F) -> Vec<f64> {
        self.dag
            .nodes
            .iter()
            .map(|n| match n {
                Node::Act(a) => f(*a),
                _ => 0.0,
            })
            .collect()
    }

    /// Batch execution time `P_d` under the given weights (eq. 5).
    /// Single forward sweep over the cached topo order. Callers that
    /// evaluate every step should hold a [`BatchEvaluator`] instead,
    /// which also skips this call's output allocation.
    pub fn batch_time(&self, weights: &[f64]) -> f64 {
        let mut p = Vec::new();
        self.csr.start_times_into(weights, &mut p);
        p[self.dest]
    }

    /// Start times `P_i` for all nodes.
    pub fn start_times(&self, weights: &[f64]) -> Vec<f64> {
        let mut p = Vec::new();
        self.csr.start_times_into(weights, &mut p);
        p
    }

    /// Map every edge in CSR edge order: `f(a, b)` for edges connecting
    /// two *action* nodes hosted on **different ranks**, `default` for
    /// everything else (same-rank chunk crossings — e.g. ZBV's V turn —
    /// and source/dest wiring). The result aligns with both [`Csr`]
    /// sweeps and the u-major `dag.succs` iteration the freeze LP uses,
    /// because [`Csr::from_dag`] freezes edges in exactly that order.
    /// This is the single classification behind
    /// [`PipelineDag::p2p_edge_costs`] and the simulator's per-edge
    /// scenario bookkeeping.
    pub fn cross_rank_edge_map<T: Clone, F: Fn(Action, Action) -> T>(
        &self,
        f: F,
        default: T,
    ) -> Vec<T> {
        let mut out = Vec::with_capacity(self.dag.edge_count());
        for u in 0..self.dag.len() {
            for &v in &self.dag.succs[u] {
                let x = match (self.dag.nodes[u].action(), self.dag.nodes[v].action()) {
                    (Some(a), Some(b)) if self.rank_of_node[u] != self.rank_of_node[v] => {
                        f(a, b)
                    }
                    _ => default.clone(),
                };
                out.push(x);
            }
        }
        out
    }

    /// Per-edge P2P communication costs in CSR edge order: an edge pays
    /// `link_cost(from_stage, to_stage)` iff it crosses ranks between
    /// two action nodes (see [`PipelineDag::cross_rank_edge_map`]).
    ///
    /// Pair with
    /// [`CostModel::p2p`](crate::cost::CostModel::p2p):
    /// `pdag.p2p_edge_costs(|a, b| cost.p2p(a, b))`.
    pub fn p2p_edge_costs<F: Fn(usize, usize) -> f64>(&self, link_cost: F) -> Vec<f64> {
        self.cross_rank_edge_map(|a, b| link_cost(a.stage, b.stage), 0.0)
    }

    /// Batch execution time under node `weights` plus CSR-ordered
    /// `edge_costs` (P2P communication on cross-rank edges).
    pub fn batch_time_with_edges(&self, weights: &[f64], edge_costs: &[f64]) -> f64 {
        let mut p = Vec::new();
        self.csr.start_times_with_edges_into(weights, edge_costs, &mut p);
        p[self.dest]
    }

    /// Start times for all nodes under node weights plus edge costs.
    pub fn start_times_with_edges(&self, weights: &[f64], edge_costs: &[f64]) -> Vec<f64> {
        let mut p = Vec::new();
        self.csr.start_times_with_edges_into(weights, edge_costs, &mut p);
        p
    }

    /// Seed reference path: full Kahn sort + longest path on the nested
    /// `Vec` adjacency. Kept for the CSR equivalence tests and the
    /// before/after perf benches.
    pub fn batch_time_dense(&self, weights: &[f64]) -> f64 {
        let p = self
            .dag
            .start_times(weights)
            .expect("pipeline DAG must be acyclic");
        p[self.dest]
    }

    /// A reusable evaluator over this DAG's CSR form for per-step
    /// callers (simulator, LP envelopes, benches): repeated
    /// `batch_time` / `start_times` with zero allocation.
    pub fn evaluator(&self) -> BatchEvaluator {
        BatchEvaluator { eval: Evaluator::new(self.csr.clone()), dest: self.dest, delta: None }
    }

    /// Freezable action nodes grouped by stage — the sets `V_s` of
    /// constraint [4] (freezable backward nodes at stage s).
    pub fn freezable_by_stage(&self) -> Vec<Vec<usize>> {
        let mut by_stage: Vec<Vec<usize>> = vec![Vec::new(); self.stages];
        for (id, n) in self.dag.nodes.iter().enumerate() {
            if let Node::Act(a) = n {
                if a.kind.freezable() {
                    by_stage[a.stage].push(id);
                }
            }
        }
        by_stage
    }

    /// All action node ids.
    pub fn action_nodes(&self) -> Vec<usize> {
        (0..self.len())
            .filter(|&i| matches!(self.dag.nodes[i], Node::Act(_)))
            .collect()
    }
}

/// Held-across-steps longest-path evaluator for one [`PipelineDag`]:
/// owns the CSR (schedule-lifetime, cloned once) plus the scratch
/// buffer, so the per-step `batch_time` is a pure forward sweep.
///
/// For callers whose successive weight vectors differ in only a few
/// entries (the monitored-bounds replan pattern), the delta channel —
/// [`BatchEvaluator::prime`] then [`BatchEvaluator::update_weights`] —
/// re-relaxes start times only over the affected CSR frontier and is
/// bit-identical to the full sweep.
#[derive(Clone, Debug)]
pub struct BatchEvaluator {
    eval: Evaluator,
    dest: usize,
    /// Lazily-built delta-propagation channel (see
    /// [`DeltaEvaluator`]); `None` until the first [`BatchEvaluator::prime`].
    delta: Option<DeltaEvaluator>,
}

impl BatchEvaluator {
    /// `P_d` under `weights` — allocation-free.
    pub fn batch_time(&mut self, weights: &[f64]) -> f64 {
        self.eval.start_times(weights)[self.dest]
    }

    /// `P_d` under node `weights` plus CSR-ordered `edge_costs`
    /// (typically from [`PipelineDag::p2p_edge_costs`], computed once
    /// per schedule) — allocation-free.
    pub fn batch_time_with_edges(&mut self, weights: &[f64], edge_costs: &[f64]) -> f64 {
        self.eval.start_times_with_edges(weights, edge_costs)[self.dest]
    }

    /// Start times for all nodes; the slice borrows the internal
    /// scratch buffer and is valid until the next call.
    pub fn start_times(&mut self, weights: &[f64]) -> &[f64] {
        self.eval.start_times(weights)
    }

    /// Prime the delta channel with a full sweep under `weights`,
    /// returning `P_d`. Subsequent [`BatchEvaluator::update_weights`]
    /// calls then pay only for what changed.
    pub fn prime(&mut self, weights: &[f64]) -> f64 {
        if self.delta.is_none() {
            self.delta = Some(DeltaEvaluator::new(self.eval.csr()));
        }
        let delta = self.delta.as_mut().unwrap();
        delta.full(weights, None)[self.dest]
    }

    /// Apply a `(node, new weight)` change set to the primed delta
    /// channel, re-relaxing only the affected frontier, and return the
    /// updated `P_d`. Bit-identical to a full sweep over the same
    /// effective weights (including empty and all-node change sets).
    ///
    /// Panics if [`BatchEvaluator::prime`] has not run.
    pub fn update_weights(&mut self, changed: &[(usize, f64)]) -> f64 {
        let delta = self
            .delta
            .as_mut()
            .expect("BatchEvaluator::update_weights before prime()");
        delta.update(changed)[self.dest]
    }

    /// Start times of the primed delta channel (valid after
    /// [`BatchEvaluator::prime`], updated by
    /// [`BatchEvaluator::update_weights`]).
    pub fn delta_starts(&self) -> Option<&[f64]> {
        self.delta.as_ref().filter(|d| d.is_primed()).map(|d| d.starts())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::types::ScheduleKind;

    fn build(kind: ScheduleKind, ranks: usize, m: usize) -> PipelineDag {
        let s = Schedule::build(kind, ranks, m, Schedule::default_chunks(kind));
        PipelineDag::from_schedule(&s)
    }

    #[test]
    fn acyclic_for_all_schedules() {
        for kind in ScheduleKind::all() {
            let g = build(kind, 4, 8);
            assert!(g.dag.is_acyclic(), "{} produced a cycle", kind.name());
        }
    }

    #[test]
    fn checked_build_accepts_legal_and_rejects_broken_orders() {
        for kind in ScheduleKind::all() {
            let s = Schedule::build(kind, 3, 4, Schedule::default_chunks(kind));
            let g = PipelineDag::from_schedule_checked(&s).unwrap();
            assert_eq!(g.len(), 2 + s.action_count());
        }
        let s = Schedule::build(ScheduleKind::Synthesized, 3, 4, 2);
        assert!(PipelineDag::from_schedule_checked(&s).is_ok());
        let mut bad = Schedule::build(ScheduleKind::GPipe, 2, 1, 1);
        bad.orders[0].swap(0, 1);
        assert!(PipelineDag::from_schedule_checked(&bad).is_err());
    }

    #[test]
    fn signature_separates_structures() {
        let a = build(ScheduleKind::OneFOneB, 4, 8);
        assert_eq!(a.signature(), build(ScheduleKind::OneFOneB, 4, 8).signature());
        // Different schedule, fleet size, or microbatch count ⇒
        // different fingerprint.
        assert_ne!(a.signature(), build(ScheduleKind::GPipe, 4, 8).signature());
        assert_ne!(a.signature(), build(ScheduleKind::OneFOneB, 3, 8).signature());
        assert_ne!(a.signature(), build(ScheduleKind::OneFOneB, 4, 6).signature());
    }

    #[test]
    fn node_counts() {
        let g = build(ScheduleKind::GPipe, 4, 8);
        // 2 (source/dest) + 2·S·M actions.
        assert_eq!(g.len(), 2 + 2 * 4 * 8);
        let g = build(ScheduleKind::ZeroBubbleV, 4, 8);
        assert_eq!(g.len(), 2 + 3 * 8 * 8);
    }

    #[test]
    fn source_and_dest_are_unique_endpoints() {
        for kind in ScheduleKind::all() {
            let g = build(kind, 3, 5);
            assert!(g.dag.preds[g.source].is_empty());
            assert!(g.dag.succs[g.dest].is_empty());
            // Every node reachable from source; dest reachable from all.
            let reach = g.dag.reachable_from(g.source);
            assert!(reach.iter().all(|&r| r), "{}", kind.name());
        }
    }

    #[test]
    fn uniform_weights_gpipe_batch_time() {
        // With w_f = w_b = 1 on S stages and M microbatches, GPipe's
        // makespan is the classic (M + S − 1) forward + (M + S − 1)
        // backward = 2(M + S − 1).
        let g = build(ScheduleKind::GPipe, 4, 8);
        let w = g.weights(|_| 1.0);
        assert_eq!(g.batch_time(&w), 2.0 * (8.0 + 4.0 - 1.0));
    }

    #[test]
    fn one_f_one_b_matches_gpipe_makespan_uniform() {
        // Under uniform unit durations 1F1B has the same critical path
        // as GPipe (both are M + S − 1 per direction).
        let g = build(ScheduleKind::OneFOneB, 4, 8);
        let w = g.weights(|_| 1.0);
        assert_eq!(g.batch_time(&w), 2.0 * (8.0 + 4.0 - 1.0));
    }

    #[test]
    fn schedule_orders_are_linear_extensions() {
        // Each rank's order must be consistent with the DAG (rule 4
        // edges make this true by construction; this guards the
        // structural rules against contradicting the schedules).
        for kind in ScheduleKind::all() {
            for (ranks, m) in [(2, 4), (4, 8), (6, 6)] {
                let s = Schedule::build(kind, ranks, m, Schedule::default_chunks(kind));
                let g = PipelineDag::from_schedule(&s);
                assert!(g.dag.is_acyclic(), "{} {ranks}x{m}", kind.name());
            }
        }
    }

    #[test]
    fn evaluator_matches_dense_path_on_all_schedules() {
        for kind in ScheduleKind::all() {
            let g = build(kind, 4, 8);
            let mut ev = g.evaluator();
            for scale in [0.5, 1.0, 2.5] {
                let w = g.weights(|a| if a.kind.freezable() { 2.0 * scale } else { scale });
                let dense = g.batch_time_dense(&w);
                assert_eq!(g.batch_time(&w), dense, "{}", kind.name());
                assert_eq!(ev.batch_time(&w), dense, "{}", kind.name());
                assert_eq!(
                    ev.start_times(&w),
                    &g.dag.start_times(&w).unwrap()[..],
                    "{}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn batch_evaluator_delta_channel_matches_full_sweeps() {
        for kind in ScheduleKind::all() {
            let g = build(kind, 4, 8);
            let mut ev = g.evaluator();
            assert!(ev.delta_starts().is_none());
            let w = g.weights(|_| 1.0);
            let primed = ev.prime(&w);
            assert_eq!(primed.to_bits(), g.batch_time(&w).to_bits(), "{}", kind.name());
            // Slow one stage's backwards: only those nodes change.
            let mut w2 = w.clone();
            let mut changed = Vec::new();
            for (id, node) in g.dag.nodes.iter().enumerate() {
                if let Node::Act(a) = node {
                    if a.stage == 2 && a.kind.freezable() {
                        w2[id] = 3.0;
                        changed.push((id, 3.0));
                    }
                }
            }
            let dt = ev.update_weights(&changed);
            assert_eq!(dt.to_bits(), g.batch_time(&w2).to_bits(), "{}", kind.name());
            assert_eq!(
                ev.delta_starts().unwrap(),
                &g.start_times(&w2)[..],
                "{}",
                kind.name()
            );
            // The empty change set is free and exact.
            let same = ev.update_weights(&[]);
            assert_eq!(same.to_bits(), dt.to_bits(), "{}", kind.name());
        }
    }

    #[test]
    fn p2p_edge_costs_charge_cross_rank_edges_only() {
        // GPipe on 4 ranks: every stage boundary is a rank boundary.
        let g = build(ScheduleKind::GPipe, 4, 4);
        let ec = g.p2p_edge_costs(|_, _| 0.5);
        assert_eq!(ec.len(), g.dag.edge_count());
        assert!(ec.iter().any(|&c| c > 0.0));
        // With unit compute and a boundary cost c, each of the 2(S−1)
        // boundary hops on the critical path pays c: makespan grows by
        // exactly 2(S−1)·c versus the free-comm baseline.
        let w = g.weights(|_| 1.0);
        let base = g.batch_time(&w);
        let with = g.batch_time_with_edges(&w, &ec);
        assert!((with - (base + 2.0 * 3.0 * 0.5)).abs() < 1e-9, "{with} vs {base}");
        let mut ev = g.evaluator();
        assert_eq!(ev.batch_time_with_edges(&w, &ec), with);
        // ZBV hosts two chunks per rank: its V-turn edge (stage R−1 →
        // stage R) stays on one rank and must be free.
        let g = build(ScheduleKind::ZeroBubbleV, 4, 4);
        let mut eidx = 0usize;
        let ec = g.p2p_edge_costs(|_, _| 1.0);
        for u in 0..g.dag.len() {
            for &v in &g.dag.succs[u] {
                if g.rank_of_node[u] == g.rank_of_node[v] {
                    assert_eq!(ec[eidx], 0.0, "same-rank edge {u}→{v} charged");
                }
                eidx += 1;
            }
        }
        // Zero link costs reproduce the node-only batch time bit-for-bit.
        let w = g.weights(|_| 1.0);
        let zeros = g.p2p_edge_costs(|_, _| 0.0);
        assert_eq!(g.batch_time_with_edges(&w, &zeros), g.batch_time(&w));
    }

    #[test]
    fn freezable_sets_cover_backwards_only() {
        let g = build(ScheduleKind::GPipe, 4, 8);
        let sets = g.freezable_by_stage();
        assert_eq!(sets.len(), 4);
        for (s, set) in sets.iter().enumerate() {
            assert_eq!(set.len(), 8, "stage {s}");
            for &id in set {
                assert!(g.node_action(id).unwrap().kind.freezable());
            }
        }
    }

    #[test]
    fn interleaved_bubble_smaller_than_1f1b() {
        // The whole point of interleaving: with per-chunk durations half
        // of a full stage, the bubble shrinks. Compare fill ratios.
        let m = 8;
        let g1 = build(ScheduleKind::OneFOneB, 4, m);
        let w1 = g1.weights(|_| 1.0);
        let t1 = g1.batch_time(&w1);
        let gi = build(ScheduleKind::Interleaved1F1B, 4, m);
        // Interleaved chunks are half-stages: duration 0.5 each.
        let wi = gi.weights(|_| 0.5);
        let ti = gi.batch_time(&wi);
        // Ideal compute time per rank is identical (M·(1+1) units).
        // Interleaved must not be slower, and should strictly win.
        assert!(
            ti < t1,
            "interleaved ({ti}) should beat 1F1B ({t1}) under uniform costs"
        );
    }
}
