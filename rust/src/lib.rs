//! # TimelyFreeze
//!
//! A from-scratch reproduction of *"TimelyFreeze: Adaptive Parameter
//! Freezing Mechanism for Pipeline Parallelism"* (Cho et al., 2026) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the pipeline-parallel coordinator: the
//!   four schedules, the pipeline DAG, the LP-based freeze-ratio
//!   optimizer, the TimelyFreeze / APF / AutoFreeze controllers, the real
//!   multi-threaded PJRT execution engine, and the discrete-event
//!   simulator that regenerates the paper's evaluation.
//! * **Layer 2 (python/compile/model.py)** — a LLaMA-style model lowered
//!   once to per-layer HLO artifacts (fwd / dgrad / wgrad).
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the
//!   compute hot-spots (flash attention; block-masked wgrad).
//!
//! Python never runs at training time: `make artifacts` AOT-compiles
//! everything to `artifacts/*.hlo.txt`, which `runtime` loads via PJRT.
//!
//! See `README.md` for the repo map and quickstart,
//! `docs/ARCHITECTURE.md` for the schedule → DAG → LP → simulator
//! data flow, and `PERF.md` for the hot paths and the bench/regression
//! workflow.

#![warn(missing_docs)]

/// Experiment configuration: model/GPU presets and the TOML launcher.
pub mod config;
/// Execution-time + memory cost models (the planner's world model).
pub mod cost;
/// Freezing controllers: TimelyFreeze, APF, AutoFreeze, hybrids.
pub mod freeze;
/// Generic DAG + the pipeline batch DAG (CSR hot path).
pub mod graph;
/// From-scratch bounded simplex and the freeze-ratio LP.
pub mod lp;
/// Experiment metric recording (JSONL).
pub mod metrics;
/// Timing-sample collection (the engine's monitoring phase).
pub mod monitor;
/// Contention-aware network fabric: topologies, fair sharing, link costs.
pub mod net;
/// Layer → stage partition heuristics.
pub mod partition;
/// The four pipeline schedules (GPipe, 1F1B, Interleaved, ZBV).
pub mod schedule;
/// Discrete-event simulator and the paper-scale experiment runner.
pub mod sim;
/// Core identifiers: actions, schedule kinds, freeze methods.
pub mod types;
/// Dependency-free support code (rng, json, toml, stats, cli, tables).
pub mod util;
/// Gantt and histogram renderers (ASCII + SVG).
pub mod viz;

/// Micro/table bench harness shared by `benches/*.rs`.
pub mod bench_support;
/// Training-loop pieces shared by engine and simulator (data, LR,
/// optimizer).
pub mod train;

// The real PJRT execution layers need the external `xla` (and `anyhow`)
// crates, which the offline image does not ship. They are gated behind
// the `pjrt` feature so the default build — coordinator, simulator, LP,
// benches, tests — compiles with zero external dependencies; enable the
// feature after adding those crates to Cargo.toml (see its comments).
/// Real multi-threaded pipeline execution engine (PJRT-backed).
#[cfg(feature = "pjrt")]
pub mod engine;
/// PJRT client/runtime bindings (HLO artifact loading, device tensors).
#[cfg(feature = "pjrt")]
pub mod runtime;
