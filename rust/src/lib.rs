//! # TimelyFreeze
//!
//! A from-scratch reproduction of *"TimelyFreeze: Adaptive Parameter
//! Freezing Mechanism for Pipeline Parallelism"* (Cho et al., 2026) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the pipeline-parallel coordinator: the
//!   four schedules, the pipeline DAG, the LP-based freeze-ratio
//!   optimizer, the TimelyFreeze / APF / AutoFreeze controllers, the real
//!   multi-threaded PJRT execution engine, and the discrete-event
//!   simulator that regenerates the paper's evaluation.
//! * **Layer 2 (python/compile/model.py)** — a LLaMA-style model lowered
//!   once to per-layer HLO artifacts (fwd / dgrad / wgrad).
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the
//!   compute hot-spots (flash attention; block-masked wgrad).
//!
//! Python never runs at training time: `make artifacts` AOT-compiles
//! everything to `artifacts/*.hlo.txt`, which `runtime` loads via PJRT.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod config;
pub mod freeze;
pub mod graph;
pub mod lp;
pub mod metrics;
pub mod monitor;
pub mod partition;
pub mod schedule;
pub mod sim;
pub mod types;
pub mod util;
pub mod viz;

pub mod bench_support;
pub mod train;

// The real PJRT execution layers need the external `xla` (and `anyhow`)
// crates, which the offline image does not ship. They are gated behind
// the `pjrt` feature so the default build — coordinator, simulator, LP,
// benches, tests — compiles with zero external dependencies; enable the
// feature after adding those crates to Cargo.toml (see its comments).
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod runtime;
