//! Sparse LU factorization of a simplex basis, with a product-form eta
//! file for pivot-by-pivot updates — the numerical kernel behind the
//! sparse revised simplex core ([`super::revised`]).
//!
//! [`LuFactors::factorize`] decomposes the basis matrix `B` (given as
//! `m` sparse columns) into a sequence of elementary row operations
//! (`L`) and a permuted upper-triangular remainder (`U`):
//!
//! 1. **Singleton triangularization** — a queue-driven sweep that peels
//!    off column singletons (the pivot column has one active entry: no
//!    other row needs elimination) and row singletons (the pivot row has
//!    one active entry: eliminating the pivot column touches no other
//!    column). Both are *fill-free*; on the near-triangular bases that
//!    pipeline precedence LPs produce, this phase absorbs almost every
//!    pivot.
//! 2. **Markowitz bump elimination** — the small irreducible core that
//!    remains is eliminated with Markowitz-cost pivot selection
//!    (minimize `(r_i − 1)(c_j − 1)` over candidate entries) under a
//!    relative threshold-pivoting guard, trading a little growth control
//!    against sparsity of the factors.
//!
//! [`Factorization`] wraps the LU with a product-form eta file: each
//! basis change appends one eta column (the ftran'd entering column and
//! its pivot position), and [`Factorization::ftran`] /
//! [`Factorization::btran`] replay the file after / before the LU
//! triangular solves. Periodic refactorization (driven by the caller's
//! interval and the eta cap) collapses the file back into a fresh LU,
//! bounding both solve cost and f64 drift — the classic revised-simplex
//! discipline the dense seed path approximated with every-64th-solve
//! rebuilds.

/// Pivot values below this are treated as structural singularity.
const SING_TOL: f64 = 1e-11;
/// Entries below this are dropped when emitting factor rows.
const DROP_TOL: f64 = 1e-13;
/// Relative threshold for Markowitz pivot admission: a candidate must
/// be at least this fraction of its column's largest active entry.
const THRESH: f64 = 0.01;

/// One recorded basis change: entering column `w = B⁻¹ a_q` (in basis
/// position space) replacing the basic variable at position `r`.
#[derive(Clone, Debug)]
struct Eta {
    /// Basis position the entering column pivoted on.
    r: usize,
    /// `w[r]` — the pivot element of the eta column.
    wr: f64,
    /// Off-pivot nonzeros of `w` (position, value), `r` excluded.
    entries: Vec<(usize, f64)>,
}

/// Sparse LU factors of one basis realization: an ordered list of row
/// operations (`L`) plus a permuted upper-triangular system (`U`).
///
/// Step `k` pivoted matrix row `row_of[k]` against basis position
/// `col_of[k]`; `ops[k]` holds the row operations that zeroed the pivot
/// column below it, and `urow[k]` the pivot row's surviving entries over
/// later-eliminated basis positions.
#[derive(Clone, Debug, Default)]
pub(crate) struct LuFactors {
    m: usize,
    row_of: Vec<usize>,
    col_of: Vec<usize>,
    /// Per step: `(target_row, multiplier)` meaning
    /// `b[target] -= multiplier * b[row_of[k]]`.
    ops: Vec<Vec<(usize, f64)>>,
    pivot: Vec<f64>,
    urow: Vec<Vec<(usize, f64)>>,
}

impl LuFactors {
    /// Factorize the basis whose `m` columns are given as sparse
    /// `(row, value)` lists. `None` on (numerical) singularity.
    pub(crate) fn factorize(m: usize, cols: &[&[(usize, f64)]]) -> Option<LuFactors> {
        debug_assert_eq!(cols.len(), m);
        // Working copies with lazy deletion: entries stay in place and
        // are filtered through the active masks when scanned.
        let col_entries: Vec<Vec<(usize, f64)>> = cols.iter().map(|c| c.to_vec()).collect();
        let mut row_entries: Vec<Vec<(usize, f64)>> = vec![Vec::new(); m];
        for (k, col) in col_entries.iter().enumerate() {
            for &(i, v) in col {
                if i >= m {
                    return None;
                }
                row_entries[i].push((k, v));
            }
        }
        let mut row_active = vec![true; m];
        let mut col_active = vec![true; m];
        let mut row_cnt: Vec<usize> = row_entries.iter().map(Vec::len).collect();
        let mut col_cnt: Vec<usize> = col_entries.iter().map(Vec::len).collect();

        let mut lu = LuFactors {
            m,
            row_of: Vec::with_capacity(m),
            col_of: Vec::with_capacity(m),
            ops: Vec::with_capacity(m),
            pivot: Vec::with_capacity(m),
            urow: Vec::with_capacity(m),
        };

        // ---- Phase A: fill-free singleton elimination ----
        // Work stack of (is_col, index) candidates whose active count may
        // be 1; counts are re-checked on pop (lazy invalidation).
        let mut stack: Vec<(bool, usize)> = Vec::with_capacity(2 * m);
        for k in 0..m {
            if col_cnt[k] == 1 {
                stack.push((true, k));
            }
        }
        for i in 0..m {
            if row_cnt[i] == 1 {
                stack.push((false, i));
            }
        }
        let mut eliminated = 0usize;
        while let Some((is_col, idx)) = stack.pop() {
            if is_col {
                let k = idx;
                if !col_active[k] || col_cnt[k] != 1 {
                    continue;
                }
                // Column singleton: its unique active entry is the pivot;
                // no other active row has an entry in this column, so no
                // elimination (and no fill) is needed.
                let Some(&(r, v)) =
                    col_entries[k].iter().find(|&&(i, _)| row_active[i])
                else {
                    return None; // count said 1; structure disagrees
                };
                if v.abs() < SING_TOL {
                    return None;
                }
                lu.row_of.push(r);
                lu.col_of.push(k);
                lu.pivot.push(v);
                lu.ops.push(Vec::new());
                // The pivot row's other active entries move to U and
                // leave their columns' active counts.
                let mut u = Vec::new();
                for &(c, w) in &row_entries[r] {
                    if c != k && col_active[c] {
                        if w.abs() > DROP_TOL {
                            u.push((c, w));
                        }
                        col_cnt[c] -= 1;
                        if col_cnt[c] == 1 {
                            stack.push((true, c));
                        }
                    }
                }
                lu.urow.push(u);
                row_active[r] = false;
                col_active[k] = false;
                eliminated += 1;
            } else {
                let r = idx;
                if !row_active[r] || row_cnt[r] != 1 {
                    continue;
                }
                // Row singleton: the pivot row has a single active entry,
                // so zeroing the pivot column in other rows touches no
                // other column — record the row operations, no fill.
                let Some(&(k, v)) =
                    row_entries[r].iter().find(|&&(c, _)| col_active[c])
                else {
                    return None;
                };
                if v.abs() < SING_TOL {
                    return None;
                }
                let mut ops = Vec::new();
                for &(i, w) in &col_entries[k] {
                    if i != r && row_active[i] {
                        ops.push((i, w / v));
                        row_cnt[i] -= 1;
                        if row_cnt[i] == 1 {
                            stack.push((false, i));
                        }
                    }
                }
                lu.row_of.push(r);
                lu.col_of.push(k);
                lu.pivot.push(v);
                lu.ops.push(ops);
                lu.urow.push(Vec::new());
                row_active[r] = false;
                col_active[k] = false;
                eliminated += 1;
            }
        }

        // ---- Phase B: Markowitz-ordered bump elimination ----
        // The irreducible remainder is gathered into a dense working
        // square (small on precedence-structured bases); pivots are
        // chosen by Markowitz cost under a relative threshold, and the
        // resulting row operations / U rows are emitted in the same
        // global representation as phase A.
        let nb = m - eliminated;
        if nb > 0 {
            let gr: Vec<usize> = (0..m).filter(|&i| row_active[i]).collect();
            let gc: Vec<usize> = (0..m).filter(|&k| col_active[k]).collect();
            if gr.len() != nb || gc.len() != nb {
                return None;
            }
            let mut cpos = vec![usize::MAX; m];
            for (bj, &k) in gc.iter().enumerate() {
                cpos[k] = bj;
            }
            let mut b = vec![0.0f64; nb * nb];
            for (bi, &i) in gr.iter().enumerate() {
                for &(k, v) in &row_entries[i] {
                    if col_active[k] {
                        b[bi * nb + cpos[k]] = v;
                    }
                }
            }
            let mut ract = vec![true; nb];
            let mut cact = vec![true; nb];
            for _ in 0..nb {
                // Candidate scan: per active column, the largest entry
                // (for the threshold) and per entry its Markowitz cost.
                let mut best: Option<(usize, usize, f64, usize)> = None; // (bi,bj,val,cost)
                for bj in 0..nb {
                    if !cact[bj] {
                        continue;
                    }
                    let mut cmax = 0.0f64;
                    for bi in 0..nb {
                        if ract[bi] {
                            cmax = cmax.max(b[bi * nb + bj].abs());
                        }
                    }
                    if cmax < SING_TOL {
                        return None; // active column vanished: singular
                    }
                    let ccnt = (0..nb)
                        .filter(|&bi| ract[bi] && b[bi * nb + bj].abs() > DROP_TOL)
                        .count();
                    for bi in 0..nb {
                        if !ract[bi] {
                            continue;
                        }
                        let v = b[bi * nb + bj];
                        if v.abs() < THRESH * cmax || v.abs() < SING_TOL {
                            continue;
                        }
                        let rcnt = (0..nb)
                            .filter(|&j2| {
                                cact[j2] && b[bi * nb + j2].abs() > DROP_TOL
                            })
                            .count();
                        let cost = (rcnt - 1) * (ccnt - 1);
                        let better = match best {
                            None => true,
                            Some((_, _, bv, bcost)) => {
                                cost < bcost
                                    || (cost == bcost && v.abs() > bv.abs())
                            }
                        };
                        if better {
                            best = Some((bi, bj, v, cost));
                        }
                    }
                }
                let (pi, pj, pv, _) = best?;
                let mut ops = Vec::new();
                for bi in 0..nb {
                    if bi == pi || !ract[bi] {
                        continue;
                    }
                    let w = b[bi * nb + pj];
                    if w.abs() <= DROP_TOL {
                        continue;
                    }
                    let mult = w / pv;
                    ops.push((gr[bi], mult));
                    for bj2 in 0..nb {
                        if bj2 != pj && cact[bj2] {
                            b[bi * nb + bj2] -= mult * b[pi * nb + bj2];
                        }
                    }
                    b[bi * nb + pj] = 0.0;
                }
                let mut u = Vec::new();
                for bj2 in 0..nb {
                    if bj2 != pj && cact[bj2] {
                        let v = b[pi * nb + bj2];
                        if v.abs() > DROP_TOL {
                            u.push((gc[bj2], v));
                        }
                    }
                }
                lu.row_of.push(gr[pi]);
                lu.col_of.push(gc[pj]);
                lu.pivot.push(pv);
                lu.ops.push(ops);
                lu.urow.push(u);
                ract[pi] = false;
                cact[pj] = false;
            }
        }
        debug_assert_eq!(lu.row_of.len(), m);
        Some(lu)
    }

    /// Solve `B x = b`. `b` (row space, length `m`) is consumed as the
    /// forward-substitution workspace; the result lands in `out`,
    /// indexed by **basis position**.
    fn ftran(&self, b: &mut [f64], out: &mut [f64]) {
        for k in 0..self.m {
            let bv = b[self.row_of[k]];
            if bv != 0.0 {
                for &(t, mult) in &self.ops[k] {
                    b[t] -= mult * bv;
                }
            }
        }
        for k in (0..self.m).rev() {
            let mut v = b[self.row_of[k]];
            for &(c, u) in &self.urow[k] {
                v -= u * out[c];
            }
            out[self.col_of[k]] = v / self.pivot[k];
        }
    }

    /// Solve `Bᵀ y = c`. `c` (basis-position space, length `m`) is
    /// consumed as the forward workspace; the result lands in `out`,
    /// indexed by **matrix row**.
    fn btran(&self, c: &mut [f64], out: &mut [f64]) {
        for k in 0..self.m {
            let zk = c[self.col_of[k]] / self.pivot[k];
            out[self.row_of[k]] = zk;
            if zk != 0.0 {
                for &(c2, u) in &self.urow[k] {
                    c[c2] -= u * zk;
                }
            }
        }
        for k in (0..self.m).rev() {
            let mut v = out[self.row_of[k]];
            for &(t, mult) in &self.ops[k] {
                v -= mult * out[t];
            }
            out[self.row_of[k]] = v;
        }
    }
}

/// A live basis factorization: sparse LU plus the product-form eta file
/// accumulated since the last refactorization.
#[derive(Clone, Debug, Default)]
pub(crate) struct Factorization {
    lu: LuFactors,
    etas: Vec<Eta>,
}

impl Factorization {
    /// Factorize `B` from its sparse columns; `None` on singularity.
    pub(crate) fn factorize(m: usize, cols: &[&[(usize, f64)]]) -> Option<Factorization> {
        Some(Factorization { lu: LuFactors::factorize(m, cols)?, etas: Vec::new() })
    }

    /// Number of eta columns accumulated since the last factorization.
    pub(crate) fn eta_len(&self) -> usize {
        self.etas.len()
    }

    /// Solve `B x = b` through the LU and the eta file. `b` is the
    /// dense right-hand side over matrix rows (consumed); `out` receives
    /// the solution over basis positions.
    pub(crate) fn ftran(&mut self, b: &mut [f64], out: &mut [f64]) {
        self.lu.ftran(b, out);
        for eta in &self.etas {
            let t = out[eta.r] / eta.wr;
            if t != 0.0 {
                for &(i, wi) in &eta.entries {
                    out[i] -= wi * t;
                }
            }
            out[eta.r] = t;
        }
    }

    /// Solve `Bᵀ y = c` through the eta file (newest first) and the LU.
    /// `c` is dense over basis positions (consumed); `out` receives the
    /// solution over matrix rows.
    pub(crate) fn btran(&mut self, c: &mut [f64], out: &mut [f64]) {
        for eta in self.etas.iter().rev() {
            let mut v = c[eta.r];
            for &(i, wi) in &eta.entries {
                v -= wi * c[i];
            }
            c[eta.r] = v / eta.wr;
        }
        self.lu.btran(c, out);
    }

    /// Record a basis change: the ftran'd entering column `w = B⁻¹ a_q`
    /// (dense over positions) pivoting on position `r`. Returns `false`
    /// when the pivot element is too small to trust (caller should
    /// refactorize instead).
    pub(crate) fn push_eta(&mut self, r: usize, w: &[f64]) -> bool {
        let wr = w[r];
        if wr.abs() < SING_TOL {
            return false;
        }
        let entries: Vec<(usize, f64)> = w
            .iter()
            .enumerate()
            .filter(|&(i, &v)| i != r && v.abs() > DROP_TOL)
            .map(|(i, &v)| (i, v))
            .collect();
        self.etas.push(Eta { r, wr, entries });
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Multiply the dense column representation by `x` (positions).
    fn apply(m: usize, cols: &[Vec<(usize, f64)>], x: &[f64]) -> Vec<f64> {
        let mut b = vec![0.0; m];
        for (k, col) in cols.iter().enumerate() {
            for &(i, v) in col {
                b[i] += v * x[k];
            }
        }
        b
    }

    fn roundtrip(m: usize, cols: Vec<Vec<(usize, f64)>>, x_true: Vec<f64>) {
        let refs: Vec<&[(usize, f64)]> = cols.iter().map(|c| c.as_slice()).collect();
        let mut f = Factorization::factorize(m, &refs).expect("nonsingular");
        let mut b = apply(m, &cols, &x_true);
        let mut x = vec![0.0; m];
        f.ftran(&mut b, &mut x);
        for (a, e) in x.iter().zip(&x_true) {
            assert!((a - e).abs() < 1e-9, "ftran {a} vs {e}");
        }
        // btran: pick y_true, form c = Bᵀ y, solve back.
        let y_true: Vec<f64> = (0..m).map(|i| (i as f64) * 0.7 - 1.3).collect();
        let mut c = vec![0.0; m];
        for (k, col) in cols.iter().enumerate() {
            for &(i, v) in col {
                c[k] += v * y_true[i];
            }
        }
        let mut y = vec![0.0; m];
        f.btran(&mut c, &mut y);
        for (a, e) in y.iter().zip(&y_true) {
            assert!((a - e).abs() < 1e-9, "btran {a} vs {e}");
        }
    }

    #[test]
    fn identity_and_permutation() {
        roundtrip(
            3,
            vec![vec![(0, 1.0)], vec![(1, 1.0)], vec![(2, 1.0)]],
            vec![1.0, -2.0, 3.0],
        );
        roundtrip(
            3,
            vec![vec![(2, 2.0)], vec![(0, -1.0)], vec![(1, 4.0)]],
            vec![0.5, 2.5, -1.5],
        );
    }

    #[test]
    fn triangular_and_general() {
        // Lower-triangular-ish: singleton phase absorbs everything.
        roundtrip(
            3,
            vec![
                vec![(0, 2.0), (1, 1.0), (2, -1.0)],
                vec![(1, 3.0), (2, 0.5)],
                vec![(2, -2.0)],
            ],
            vec![1.0, 2.0, 3.0],
        );
        // Fully dense 3×3 (forces the Markowitz bump).
        roundtrip(
            3,
            vec![
                vec![(0, 2.0), (1, 1.0), (2, 1.0)],
                vec![(0, 1.0), (1, 3.0), (2, 2.0)],
                vec![(0, 1.0), (1, 2.0), (2, 4.0)],
            ],
            vec![-1.0, 2.0, 0.5],
        );
    }

    #[test]
    fn singular_is_rejected() {
        let cols: Vec<Vec<(usize, f64)>> = vec![
            vec![(0, 1.0), (1, 1.0)],
            vec![(0, 2.0), (1, 2.0)], // linearly dependent
        ];
        let refs: Vec<&[(usize, f64)]> = cols.iter().map(|c| c.as_slice()).collect();
        assert!(Factorization::factorize(2, &refs).is_none());
    }

    #[test]
    fn eta_updates_track_basis_changes() {
        // Start from the identity, replace position 1's column, and
        // check ftran/btran against the replaced matrix.
        let cols: Vec<Vec<(usize, f64)>> =
            vec![vec![(0, 1.0)], vec![(1, 1.0)], vec![(2, 1.0)]];
        let refs: Vec<&[(usize, f64)]> = cols.iter().map(|c| c.as_slice()).collect();
        let mut f = Factorization::factorize(3, &refs).unwrap();
        // New column a_q = (1, 2, 1)ᵀ enters at position 1.
        let aq = vec![(0usize, 1.0f64), (1, 2.0), (2, 1.0)];
        let mut b = vec![0.0; 3];
        for &(i, v) in &aq {
            b[i] = v;
        }
        let mut w = vec![0.0; 3];
        f.ftran(&mut b, &mut w); // B = I ⇒ w = a_q
        assert!(f.push_eta(1, &w));
        // New basis columns: e_0, a_q, e_2.
        let newcols: Vec<Vec<(usize, f64)>> =
            vec![vec![(0, 1.0)], aq.clone(), vec![(2, 1.0)]];
        let x_true = vec![1.5, -0.5, 2.0];
        let mut rhs = apply(3, &newcols, &x_true);
        let mut x = vec![0.0; 3];
        f.ftran(&mut rhs, &mut x);
        for (a, e) in x.iter().zip(&x_true) {
            assert!((a - e).abs() < 1e-9, "eta ftran {a} vs {e}");
        }
        let y_true = vec![0.3, -1.0, 0.7];
        let mut c = vec![0.0; 3];
        for (k, col) in newcols.iter().enumerate() {
            for &(i, v) in col {
                c[k] += v * y_true[i];
            }
        }
        let mut y = vec![0.0; 3];
        f.btran(&mut c, &mut y);
        for (a, e) in y.iter().zip(&y_true) {
            assert!((a - e).abs() < 1e-9, "eta btran {a} vs {e}");
        }
    }
}
