//! The TimelyFreeze linear program (§3.2.2): given the pipeline DAG and
//! per-action execution-time bounds [w_min, w_max] from the monitoring
//! phase, compute node durations w (hence expected freeze ratios r*) that
//! minimize batch time P_d, with minimal-freezing tie-breaking and the
//! per-stage budget r_max.
//!
//!   min  P_d − λ Σ_i δ_i w_i                               (eq. 6)
//!   s.t. P_j ≥ P_i + w_i + e_ij     ∀ (i→j) ∈ E            [1]
//!        w_min_i ≤ w_i ≤ w_max_i    ∀ i                    [2]
//!        P_s = 0, w_s = 0                                  [3]
//!        Σ_{i∈V_s} δ_i (w_max_i − w_i) ≤ r_max |V_s|  ∀ s  [4]
//!        Σ_{i∈V_s} δ_i (w_max_i − w_i) ≥ r_min_s |V_s| ∀ s [5]
//!
//! with δ_i = 1 / (w_max_i − w_min_i) for freezable nodes (0 otherwise),
//! so that r_i = δ_i (w_max_i − w_i) is the linearized freeze ratio
//! (eq. 4).
//!
//! Four optional extensions beyond the paper's formulation, all exactly
//! zero-cost when absent:
//!
//! * **edge costs** `e_ij` — P2P communication charged to cross-rank DAG
//!   edges (heterogeneous-interconnect studies). Supplied in CSR edge
//!   order via [`FreezeLpInput::with_edge_costs`]; when `None`, the
//!   precedence rows are bit-identical to the pre-refactor build.
//! * **edge traffic slopes** `g_ij` — contention-aware communication:
//!   the edge cost becomes *load-dependent*, `e_ij(r_u) = e0_ij + g_ij ·
//!   (1 − r_u)`, where `r_u` is the sending node's freeze ratio and
//!   `g_ij` is the expected serialization seconds of the edge's full
//!   payload on a shared fabric (`NetworkModel::expected_seconds`).
//!   Freezing the sender shrinks its gradient payload and with it the
//!   shared-link term. Substituting `r_u = δ_u (w_max_u − w_u)` keeps
//!   the rows linear: `P_j − P_i − (1 + g_ij δ_u) w_u ≥ e0_ij + g_ij (1
//!   − δ_u w_max_u)`. Supplied via [`FreezeLpInput::with_edge_traffic`];
//!   `None` (or all-zero slopes) is bit-identical to the constant-cost
//!   rows.
//! * **per-stage freeze-ratio floors** `r_min_s` — the memory-pressure
//!   constraint [5]: stage `s` must freeze at least an `r_min_s` average
//!   ratio so its gradient/optimizer state fits the device budget
//!   (derived by
//!   [`MemoryModel::required_ratios`](crate::cost::MemoryModel::required_ratios)).
//!   Supplied via [`FreezeLpInput::with_stage_floor`]; a floor above
//!   `r_max` is rejected upfront as [`FreezeLpError::FloorExceedsBudget`]
//!   (the memory budget and the accuracy budget genuinely conflict).
//! * **recompute surcharges** `Δ_s` — activation recomputation as the
//!   alternative memory policy
//!   ([`RecomputePolicy`](crate::cost::RecomputePolicy)): a stage that
//!   stashes only `1 − ρ_s` of its activations re-runs `ρ_s` of its
//!   forward during every stash-consuming backward, so `Δ_s = ρ_s ·
//!   fwd_s` grows *both* duration bounds of the stage's `Backward` /
//!   `BackwardDgrad` nodes ([`FreezeLpInput::with_recompute`]). The
//!   surcharge is freeze-invariant — the `[w_min, w_max]` range, hence
//!   `δ_i` and the ratio linearization, is unchanged — and the memory
//!   deficit it covers reaches the LP as a *relaxed* constraint-[5]
//!   floor (derived by
//!   [`memory_plan_for`](crate::cost::memory_plan_for), which trades
//!   the two off per stage). `None` keeps every path bit-identical and
//!   the warm-start basis valid (bounds and constants move; the row
//!   structure does not).

use crate::graph::dag::DeltaEvaluator;
use crate::graph::pipeline::{Node, PipelineDag};
use crate::lp::simplex::{
    self, Cmp, LpProblem, LpSolution, LpStatus, PersistentSimplex, SolvePath, SolveStats, INF,
};
use crate::types::ActionKind;

/// Default tie-breaker weight. The paper only requires λ ≪ 1 so that
/// minimizing P_d always dominates; we scale it against the number of
/// freezable nodes so that the tie-break term's full range stays below
/// one time unit (≪ any realistic P_d).
pub const DEFAULT_LAMBDA: f64 = 1e-4;

/// One freeze-LP instance. Construct with [`FreezeLpInput::new`] and
/// opt into the memory floor / edge-cost extensions with the builder
/// methods.
#[derive(Clone, Debug)]
pub struct FreezeLpInput<'a> {
    /// The pipeline DAG the LP runs over.
    pub pdag: &'a PipelineDag,
    /// Per-node minimum duration (all parameters frozen). Forward nodes
    /// must have `w_min == w_max`.
    pub w_min: &'a [f64],
    /// Per-node maximum duration (no freezing).
    pub w_max: &'a [f64],
    /// User budget: maximum average freeze ratio per stage (§3.2.2).
    pub r_max: f64,
    /// Tie-breaker weight λ ≪ 1.
    pub lambda: f64,
    /// Optional per-stage freeze-ratio floor (constraint [5], len ==
    /// `pdag.stages`): stage `s` must average at least `r_min[s]` to fit
    /// its memory budget. `None` ⇒ no floor rows.
    pub r_min: Option<&'a [f64]>,
    /// Optional per-edge communication costs in CSR edge order (len ==
    /// `pdag.csr.edge_count()`), typically from
    /// [`PipelineDag::p2p_edge_costs`]. `None` ⇒ free edges,
    /// bit-identical to the pre-refactor precedence rows.
    pub edge_costs: Option<&'a [f64]>,
    /// Optional per-edge traffic slopes in CSR edge order (len ==
    /// `pdag.csr.edge_count()`): `g_ij` seconds of extra serialization
    /// when the sending node freezes nothing, scaling down linearly
    /// with the sender's freeze ratio (see the module docs). `None` ⇒
    /// constant edge costs, bit-identical to the traffic-free rows.
    pub edge_traffic: Option<&'a [f64]>,
    /// Optional per-stage recompute surcharge seconds (len ==
    /// `pdag.stages`, typically
    /// [`CostModel::recompute_surcharges_for`](crate::cost::CostModel::recompute_surcharges_for)):
    /// added to both duration bounds of every stash-consuming backward
    /// node (`Backward`, `BackwardDgrad`) at the stage. `None` ⇒ no
    /// recomputation, bit-identical to the surcharge-free build.
    pub recompute: Option<&'a [f64]>,
}

impl<'a> FreezeLpInput<'a> {
    /// The paper's base formulation: no memory floor, free edges.
    pub fn new(
        pdag: &'a PipelineDag,
        w_min: &'a [f64],
        w_max: &'a [f64],
        r_max: f64,
        lambda: f64,
    ) -> FreezeLpInput<'a> {
        FreezeLpInput {
            pdag,
            w_min,
            w_max,
            r_max,
            lambda,
            r_min: None,
            edge_costs: None,
            edge_traffic: None,
            recompute: None,
        }
    }

    /// Enforce a per-stage freeze-ratio floor (constraint [5]).
    pub fn with_stage_floor(mut self, r_min: &'a [f64]) -> FreezeLpInput<'a> {
        self.r_min = Some(r_min);
        self
    }

    /// Charge P2P communication to DAG edges (CSR edge order).
    pub fn with_edge_costs(mut self, edge_costs: &'a [f64]) -> FreezeLpInput<'a> {
        self.edge_costs = Some(edge_costs);
        self
    }

    /// Make edge costs load-dependent: edge `i→j` costs `e0_ij + g_ij ·
    /// (1 − r_i)` seconds, so freezing the sender relaxes the shared
    /// fabric terms (CSR edge order; composes with
    /// [`FreezeLpInput::with_edge_costs`] supplying the `e0` part).
    pub fn with_edge_traffic(mut self, edge_traffic: &'a [f64]) -> FreezeLpInput<'a> {
        self.edge_traffic = Some(edge_traffic);
        self
    }

    /// Grow every stash-consuming backward node's duration bounds by its
    /// stage's recompute surcharge `Δ_s = ρ_s · fwd_s` (activation
    /// recomputation as a memory policy).
    pub fn with_recompute(mut self, surcharge: &'a [f64]) -> FreezeLpInput<'a> {
        self.recompute = Some(surcharge);
        self
    }
}

/// The solved freeze LP: per-node ratios and durations plus the batch
/// time and its envelopes.
#[derive(Clone, Debug)]
pub struct FreezeSolution {
    /// Expected freeze ratio per node (0 for forwards and source/dest).
    pub ratios: Vec<f64>,
    /// Chosen duration per node.
    pub w: Vec<f64>,
    /// Start time per node under the chosen durations (recomputed by
    /// longest path so slack nodes get earliest-start semantics).
    pub start_times: Vec<f64>,
    /// Optimized batch time `P_d*`.
    pub batch_time: f64,
    /// No-freezing makespan envelope (eq. 46, `w = w_max`).
    pub p_d_max: f64,
    /// Full-freezing makespan envelope (eq. 46, `w = w_min`).
    pub p_d_min: f64,
    /// Simplex iterations (for the perf log).
    pub iterations: usize,
    /// The per-stage recompute surcharge **in seconds** (`Δ_s = ρ_s ·
    /// fwd_s`, not a fraction — unlike
    /// [`MemoryPlan::recompute`](crate::cost::MemoryPlan)) that the
    /// envelopes included ([`FreezeLpInput::with_recompute`]) — the
    /// chosen memory policy, recorded so reports can attribute batch
    /// time to the forward re-runs. `None` ⇒ the solve saw no
    /// recomputation.
    pub recompute_surcharge: Option<Vec<f64>>,
    /// Persistent-solver counters (ladder rung, pivots, bound flips,
    /// refactorizations) for the solve that produced this solution.
    /// `None` on the one-shot [`solve_freeze_lp`] path, which runs the
    /// dense reference solver and reports `iterations` only.
    pub stats: Option<SolveStats>,
}

impl FreezeSolution {
    /// Average expected freeze ratio over freezable nodes — the white-box
    /// number quoted in Figure 2 ("average expected freeze ratio of 60%").
    pub fn mean_freezable_ratio(&self, pdag: &PipelineDag) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for (id, node) in pdag.dag.nodes.iter().enumerate() {
            if let Node::Act(a) = node {
                if a.kind.freezable() {
                    sum += self.ratios[id];
                    n += 1;
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Time-reduction factor κ = τ_ours / τ_base (eq. 50, observable
    /// form): optimized batch time over the no-freezing envelope.
    pub fn kappa(&self) -> f64 {
        if self.p_d_max <= 0.0 {
            1.0
        } else {
            self.batch_time / self.p_d_max
        }
    }

    /// Mean expected freeze ratio per stage (the quantity both the
    /// `r_max` budget [4] and the memory floor [5] constrain). Stages
    /// with no freezable nodes report 0.
    pub fn stage_ratios(&self, pdag: &PipelineDag) -> Vec<f64> {
        pdag.freezable_by_stage()
            .iter()
            .map(|set| {
                if set.is_empty() {
                    0.0
                } else {
                    set.iter().map(|&i| self.ratios[i]).sum::<f64>() / set.len() as f64
                }
            })
            .collect()
    }
}

/// Why a freeze-LP solve failed.
#[derive(Debug)]
pub enum FreezeLpError {
    /// The bound vectors do not match the DAG's node count.
    BadLength {
        /// Supplied length.
        got: usize,
        /// Expected length (DAG size).
        want: usize,
    },
    /// A node's `[w_min, w_max]` interval is malformed.
    BadBounds {
        /// Offending node id.
        node: usize,
        /// Supplied lower bound.
        w_min: f64,
        /// Supplied upper bound.
        w_max: f64,
    },
    /// `r_max` outside `[0, 1]`.
    BadRmax(f64),
    /// The per-stage floor vector is malformed (wrong length, or an
    /// entry outside `[0, 1]`).
    BadStageFloor {
        /// Offending stage (`usize::MAX` for a length mismatch).
        stage: usize,
        /// The offending value (or supplied length for a mismatch).
        r_min: f64,
    },
    /// A stage's memory floor exceeds the accuracy budget `r_max`: the
    /// configuration cannot simultaneously fit the device and respect
    /// the freeze-ratio cap.
    FloorExceedsBudget {
        /// Offending stage.
        stage: usize,
        /// Required floor from the memory model.
        r_min: f64,
        /// The user's budget.
        r_max: f64,
    },
    /// The edge-cost vector is malformed (wrong length or a negative /
    /// non-finite entry).
    BadEdgeCosts {
        /// Supplied length.
        got: usize,
        /// Expected length (CSR edge count).
        want: usize,
    },
    /// The edge-traffic vector is malformed (wrong length or a negative
    /// / non-finite entry).
    BadEdgeTraffic {
        /// Supplied length.
        got: usize,
        /// Expected length (CSR edge count).
        want: usize,
    },
    /// The recompute-surcharge vector is malformed (wrong length or a
    /// negative / non-finite entry).
    BadRecompute {
        /// Supplied length.
        got: usize,
        /// Expected length (stage count).
        want: usize,
    },
    /// The simplex terminated abnormally.
    Solver(LpStatus),
}

impl std::fmt::Display for FreezeLpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FreezeLpError::BadLength { got, want } => {
                write!(f, "w_min/w_max length {got} does not match DAG size {want}")
            }
            FreezeLpError::BadBounds { node, w_min, w_max } => {
                write!(f, "node {node}: invalid bounds w_min={w_min} w_max={w_max}")
            }
            FreezeLpError::BadRmax(r) => write!(f, "r_max must be in [0,1], got {r}"),
            FreezeLpError::BadStageFloor { stage, r_min } => {
                write!(f, "stage {stage}: invalid freeze-ratio floor {r_min}")
            }
            FreezeLpError::FloorExceedsBudget { stage, r_min, r_max } => write!(
                f,
                "stage {stage} needs a freeze ratio of at least {r_min:.3} to fit its \
                 memory budget, above the accuracy budget r_max = {r_max:.3}"
            ),
            FreezeLpError::BadEdgeCosts { got, want } => {
                write!(f, "edge cost length {got} does not match CSR edge count {want}")
            }
            FreezeLpError::BadEdgeTraffic { got, want } => write!(
                f,
                "edge traffic length {got} does not match CSR edge count {want} \
                 (or an entry is negative / non-finite)"
            ),
            FreezeLpError::BadRecompute { got, want } => write!(
                f,
                "recompute surcharge length {got} does not match stage count {want} \
                 (or an entry is negative / non-finite)"
            ),
            FreezeLpError::Solver(s) => write!(f, "LP terminated with status {s:?}"),
        }
    }
}

impl std::error::Error for FreezeLpError {}

/// Re-usable freeze-LP solver for the online replan loop: keeps the
/// constraint *skeleton*, the realized simplex *tableau*, and the
/// envelope *start-time state* alive between solves.
///
/// Successive freeze-LP instances over the *same* pipeline DAG differ
/// only in bound/envelope data (refreshed monitoring bounds, a changed
/// `r_max`, a drifting memory floor over the same binding stages), so a
/// replan:
///
/// * **rewrites** the cached precedence-row skeleton in place — only
///   RHS, objective, variable-bound, and stage-row δ entries move;
///   nothing is reallocated (the skeleton rebuilds only when the DAG,
///   the freezable set, or the floor-row pattern changes);
/// * **re-solves** through a [`PersistentSimplex`]: a re-solve whose
///   constraint matrix is unchanged patches through the stored basis
///   inverse (dual simplex for RHS drift, primal phase 2 for cost
///   drift, zero pivots on an unchanged problem) and only a δ change
///   pays the warm Gauss-Jordan realization — the cold two-phase solve
///   is the last rung of the ladder;
/// * **re-sweeps** the three longest-path envelopes (chosen durations
///   plus both eq. 46 envelopes) through [`DeltaEvaluator`] channels
///   that re-relax only the nodes whose weights moved.
///
/// Every fallback is transparent; results are bit-for-bit a valid LP
/// optimum whichever path ran ([`FreezeLpSolver::last_solve_path`]
/// reports which one did).
#[derive(Clone, Debug, Default)]
pub struct FreezeLpSolver {
    simplex: PersistentSimplex,
    skel: Option<Skeleton>,
}

impl FreezeLpSolver {
    /// A solver with no cached state (first solve runs cold).
    pub fn new() -> FreezeLpSolver {
        FreezeLpSolver::default()
    }

    /// Whether the next [`FreezeLpSolver::solve`] can reuse the stored
    /// tableau (incremental or warm-started re-solve).
    pub fn has_warm_basis(&self) -> bool {
        self.simplex.has_state()
    }

    /// Which rung of the simplex fallback ladder produced the last
    /// solution (`None` before the first solve): incremental tableau
    /// patch, warm basis realization, or cold two-phase solve.
    pub fn last_solve_path(&self) -> Option<SolvePath> {
        self.simplex.last_path()
    }

    /// Counters of the last solve — ladder rung, pivots, bound flips,
    /// refactorizations (`None` before the first solve). The same value
    /// lands on [`FreezeSolution::stats`].
    pub fn last_solve_stats(&self) -> Option<SolveStats> {
        self.simplex.last_stats()
    }

    /// Drop all cached state (e.g. after the schedule changed shape).
    pub fn reset(&mut self) {
        self.simplex.reset();
        self.skel = None;
    }

    /// Solve `input`, reusing the cached skeleton/tableau/envelope state
    /// where it still fits (see the type docs).
    pub fn solve(&mut self, input: &FreezeLpInput) -> Result<FreezeSolution, FreezeLpError> {
        validate(input)?;
        let reuse = self.skel.as_ref().map_or(false, |s| s.matches(input));
        if reuse {
            self.skel.as_mut().unwrap().refresh(input);
        } else {
            self.skel = Some(Skeleton::build(input)?);
        }
        let skel = self.skel.as_mut().unwrap();
        let sol: LpSolution = self.simplex.solve(&skel.built.lp);
        if sol.status != LpStatus::Optimal {
            self.reset();
            return Err(FreezeLpError::Solver(sol.status));
        }
        let mut out = skel.extract(input, &sol);
        out.stats = self.simplex.last_stats();
        Ok(out)
    }
}

/// The cached constraint skeleton of one (schedule, DAG) — the
/// assembled [`LpProblem`] plus everything needed to rewrite it in
/// place for a replan and to read a solution back out incrementally.
///
/// The key and the three envelope channels each own a CSR copy (a few
/// KiB at pipeline sizes): sharing would need `Arc` — controllers are
/// `Send` — for a structure that is cloned only on skeleton (re)build,
/// never per replan. Likewise the simplex layer keeps its own row
/// fingerprint: an O(nnz) memcmp per solve is the price of a
/// [`PersistentSimplex`] that is safe standalone, not only under this
/// cache.
#[derive(Clone, Debug)]
struct Skeleton {
    /// Frozen adjacency the skeleton was built for (reuse key).
    csr: crate::graph::dag::Csr,
    /// (kind, stage) signature per node (reuse key: identical adjacency
    /// with different payloads must not alias).
    node_sig: Vec<(u8, u32)>,
    /// Freezable mask (`δ_i > 0`) the variable layout was built for.
    freezable: Vec<bool>,
    /// Which stages carry a floor row (constraint [5]).
    floor_pattern: Vec<bool>,
    /// Freezable node ids per stage (the sets `V_s`), cached once.
    by_stage: Vec<Vec<usize>>,
    /// The assembled problem and its read-back maps, rewritten in place
    /// by [`Skeleton::refresh`].
    built: BuiltLp,
    /// Envelope channels: chosen durations, `w_max`, `w_min` (eq. 46).
    env_w: DeltaEvaluator,
    env_max: DeltaEvaluator,
    env_min: DeltaEvaluator,
}

impl Skeleton {
    /// Assemble the problem from scratch (the cold path of the input
    /// layer). `input` must already be validated.
    fn build(input: &FreezeLpInput) -> Result<Skeleton, FreezeLpError> {
        let pdag = input.pdag;
        let built = build_problem(input)?;
        let node_sig = node_signature(pdag);
        let freezable: Vec<bool> = built.delta.iter().map(|&d| d > 0.0).collect();
        let by_stage = pdag.freezable_by_stage();
        let floor_pattern: Vec<bool> = (0..pdag.stages)
            .map(|s| {
                input.r_min.map_or(false, |rmin| rmin[s] > 0.0) && !by_stage[s].is_empty()
            })
            .collect();
        Ok(Skeleton {
            csr: pdag.csr.clone(),
            node_sig,
            freezable,
            floor_pattern,
            by_stage,
            built,
            env_w: DeltaEvaluator::new(&pdag.csr),
            env_max: DeltaEvaluator::new(&pdag.csr),
            env_min: DeltaEvaluator::new(&pdag.csr),
        })
    }

    /// Whether this skeleton can be rewritten in place for `input`
    /// (same DAG, same freezable set, same floor-row pattern — the row
    /// *structure* is then identical and only data entries move).
    fn matches(&self, input: &FreezeLpInput) -> bool {
        let pdag = input.pdag;
        let n = pdag.len();
        if n != self.freezable.len()
            || pdag.stages != self.floor_pattern.len()
            || pdag.csr != self.csr
        {
            return false;
        }
        for (id, node) in pdag.dag.nodes.iter().enumerate() {
            if node_sig_of(node) != self.node_sig[id] {
                return false;
            }
            // Freezability must be judged on the same *effective* bounds
            // the build uses: the surcharge shifts both bounds equally,
            // which preserves the range mathematically but not always
            // bitwise (a huge surcharge can round a tiny range to 0), and
            // the stored variable layout keys off `δ > 0` exactly.
            let (mut lo, mut hi) = (input.w_min[id], input.w_max[id]);
            if let (Some(sur), Node::Act(a)) = (input.recompute, node) {
                if matches!(a.kind, ActionKind::Backward | ActionKind::BackwardDgrad) {
                    lo += sur[a.stage];
                    hi += sur[a.stage];
                }
            }
            if ((hi - lo) > 0.0) != self.freezable[id] {
                return false;
            }
        }
        for (s, set) in self.by_stage.iter().enumerate() {
            let wants_floor =
                input.r_min.map_or(false, |rmin| rmin[s] > 0.0) && !set.is_empty();
            if wants_floor != self.floor_pattern[s] {
                return false;
            }
        }
        true
    }

    /// Rewrite the cached problem's data entries for `input`: effective
    /// bounds, δ, objective, variable boxes, and every row's RHS (plus
    /// the stage rows' δ coefficients). Preconditions: `input` is
    /// validated and [`Skeleton::matches`] holds. Every float is
    /// computed by the same expressions in the same order as
    /// [`build_problem`], so the rewritten problem is bit-identical to
    /// a from-scratch build.
    fn refresh(&mut self, input: &FreezeLpInput) {
        let pdag = input.pdag;
        let n = pdag.len();
        let built = &mut self.built;
        // Effective duration bounds (recompute surcharge on both bounds
        // of stash-consuming backwards), reusing the scratch vectors.
        match input.recompute {
            None => {
                built.w_min_eff = None;
                built.w_max_eff = None;
            }
            Some(sur) => {
                let lo = built.w_min_eff.get_or_insert_with(Vec::new);
                lo.clear();
                lo.extend_from_slice(input.w_min);
                let hi = built.w_max_eff.get_or_insert_with(Vec::new);
                hi.clear();
                hi.extend_from_slice(input.w_max);
                for (id, node) in pdag.dag.nodes.iter().enumerate() {
                    if let Node::Act(a) = node {
                        if matches!(a.kind, ActionKind::Backward | ActionKind::BackwardDgrad) {
                            lo[id] += sur[a.stage];
                            hi[id] += sur[a.stage];
                        }
                    }
                }
            }
        }
        let w_min: &[f64] = built.w_min_eff.as_deref().unwrap_or(input.w_min);
        let w_max: &[f64] = built.w_max_eff.as_deref().unwrap_or(input.w_max);
        // δ in place (same formula and order as the build).
        built.delta.clear();
        built.delta.extend((0..n).map(|i| {
            let range = w_max[i] - w_min[i];
            if range > 0.0 {
                1.0 / range
            } else {
                0.0
            }
        }));
        // Tie-break scaling, replayed without the intermediate index
        // vector (identical summation order: ascending i).
        let mut count = 0usize;
        let mut range_sum = 0.0f64;
        for i in 0..n {
            if built.delta[i] > 0.0 {
                count += 1;
                range_sum += w_max[i] - w_min[i];
            }
        }
        let lam = if count == 0 {
            0.0
        } else {
            input.lambda * (range_sum / count as f64) / count as f64
        };
        // Objective and variable boxes of the w columns.
        for i in 0..n {
            if let Some(wi) = built.w_var[i] {
                built.lp.c[wi] = -lam * built.delta[i];
                built.lp.lower[wi] = w_min[i];
                built.lp.upper[wi] = w_max[i];
            }
        }
        // Precedence rows (rows 0..E in u-major edge order): RHS always,
        // plus the `w_u` coefficient when a traffic slope makes the edge
        // cost load-dependent (same expressions and branch structure as
        // `build_problem`, so the rewrite is bit-identical to a rebuild;
        // the simplex layer's row fingerprint notices coefficient drift
        // and drops to the warm rung automatically).
        let mut row = 0usize;
        let mut eidx = 0usize;
        for u in 0..n {
            for _ in &pdag.dag.succs[u] {
                let ec = input.edge_costs.map_or(0.0, |e| e[eidx]);
                let tr = input.edge_traffic.map(|g| g[eidx]);
                eidx += 1;
                let r = &mut built.lp.rows[row];
                match (built.w_var[u], tr) {
                    (Some(_), None) => {
                        r.coeffs[2].1 = -1.0;
                        r.rhs = ec;
                    }
                    (Some(_), Some(g)) => {
                        r.coeffs[2].1 = -(1.0 + g * built.delta[u]);
                        r.rhs = ec + g * (1.0 - built.delta[u] * w_max[u]);
                    }
                    (None, None) => r.rhs = w_max[u] + ec,
                    (None, Some(g)) => r.rhs = w_max[u] + ec + g,
                }
                row += 1;
            }
        }
        // Stage rows: budget [4] (and floor [5] where present) — δ
        // coefficients and RHS move, the variable layout does not.
        for (s, set) in self.by_stage.iter().enumerate() {
            if set.is_empty() {
                continue;
            }
            let wmax_term: f64 = set.iter().map(|&i| built.delta[i] * w_max[i]).sum::<f64>();
            let budget = &mut built.lp.rows[row];
            row += 1;
            let mut slot = 0usize;
            for &i in set {
                if built.w_var[i].is_some() {
                    budget.coeffs[slot].1 = built.delta[i];
                    slot += 1;
                }
            }
            debug_assert_eq!(slot, budget.coeffs.len());
            budget.rhs = wmax_term - input.r_max * set.len() as f64;
            if self.floor_pattern[s] {
                let rmin = input.r_min.expect("floor pattern implies r_min");
                let floor = &mut built.lp.rows[row];
                row += 1;
                let mut slot = 0usize;
                for &i in set {
                    if built.w_var[i].is_some() {
                        floor.coeffs[slot].1 = built.delta[i];
                        slot += 1;
                    }
                }
                debug_assert_eq!(slot, floor.coeffs.len());
                floor.rhs = wmax_term - rmin[s] * set.len() as f64;
            }
        }
        debug_assert_eq!(row, built.lp.rows.len());
    }

    /// Read a solved LP back out, sweeping the three envelopes through
    /// the persistent delta channels (bit-identical to the transient
    /// sweeps of [`solve_freeze_lp`]).
    fn extract(&mut self, input: &FreezeLpInput, sol: &LpSolution) -> FreezeSolution {
        let pdag = input.pdag;
        let n = pdag.len();
        let (w_min, w_max) = self.built.bounds(input);
        let w: Vec<f64> = (0..n)
            .map(|i| match self.built.w_var[i] {
                Some(wi) => sol.x[wi].clamp(w_min[i], w_max[i]),
                None => w_max[i],
            })
            .collect();
        let ratios: Vec<f64> = (0..n)
            .map(|i| (self.built.delta[i] * (w_max[i] - w[i])).clamp(0.0, 1.0))
            .collect();
        let (start_times, p_d_max, p_d_min) = match input.edge_traffic {
            None => {
                let ec = input.edge_costs;
                let start_times = self.env_w.refresh(&w, ec).to_vec();
                let p_d_max = self.env_max.refresh(w_max, ec)[pdag.dest];
                let p_d_min = self.env_min.refresh(w_min, ec)[pdag.dest];
                (start_times, p_d_max, p_d_min)
            }
            Some(tr) => {
                // Realized load-dependent edge costs per envelope:
                // chosen ratios, no freezing (full payload), and full
                // freezing (freezable senders drop to e0).
                let (cw, cmax, cmin) =
                    realized_edge_costs(input, &self.built, &ratios, w_min, w_max, tr);
                let start_times = self.env_w.refresh(&w, Some(cw.as_slice())).to_vec();
                let p_d_max = self.env_max.refresh(w_max, Some(cmax.as_slice()))[pdag.dest];
                let p_d_min = self.env_min.refresh(w_min, Some(cmin.as_slice()))[pdag.dest];
                (start_times, p_d_max, p_d_min)
            }
        };
        let batch_time = start_times[pdag.dest];
        FreezeSolution {
            ratios,
            w,
            start_times,
            batch_time,
            p_d_max,
            p_d_min,
            iterations: sol.iterations,
            recompute_surcharge: input.recompute.map(|s| s.to_vec()),
            stats: None,
        }
    }
}

/// (kind, stage) signature of one node (source/dest get sentinel 255).
fn node_sig_of(node: &Node) -> (u8, u32) {
    match node {
        Node::Source => (255, 0),
        Node::Dest => (255, 1),
        Node::Act(a) => {
            let k = match a.kind {
                ActionKind::Forward => 0u8,
                ActionKind::Backward => 1,
                ActionKind::BackwardDgrad => 2,
                ActionKind::BackwardWgrad => 3,
            };
            (k, a.stage as u32)
        }
    }
}

/// Node signatures of a whole DAG (skeleton reuse key).
fn node_signature(pdag: &PipelineDag) -> Vec<(u8, u32)> {
    pdag.dag.nodes.iter().map(node_sig_of).collect()
}

/// Build and solve the freeze LP from scratch. Without a stage floor the
/// LP is always feasible by construction (w = w_max satisfies every
/// constraint), so `Err(Solver(_))` indicates numerically hostile inputs
/// rather than modelling infeasibility; with a floor, genuine
/// infeasibility (floor above budget) is rejected upfront as
/// [`FreezeLpError::FloorExceedsBudget`] and the LP itself stays
/// feasible (any per-stage average in `[r_min_s, r_max]` is realizable
/// within the `[w_min, w_max]` boxes). Controllers that re-solve should
/// hold a [`FreezeLpSolver`] instead to reuse the skeleton and the
/// realized tableau; this one-shot entry builds, solves cold, and
/// sweeps transiently.
pub fn solve_freeze_lp(input: &FreezeLpInput) -> Result<FreezeSolution, FreezeLpError> {
    let built = build_problem(input)?;
    let sol = simplex::solve(&built.lp);
    if sol.status != LpStatus::Optimal {
        return Err(FreezeLpError::Solver(sol.status));
    }
    Ok(extract_solution(input, &built, &sol))
}

/// Assemble the raw [`LpProblem`] of the freeze formulation without
/// solving it — the sparse-vs-dense property tests and benches feed the
/// exact LP both solver cores see through this entry.
pub fn build_lp(input: &FreezeLpInput) -> Result<LpProblem, FreezeLpError> {
    Ok(build_problem(input)?.lp)
}

/// The assembled LP plus the variable maps needed to read a solution
/// back out.
#[derive(Clone, Debug)]
struct BuiltLp {
    lp: LpProblem,
    /// Node → `w` column (freezable nodes only).
    w_var: Vec<Option<usize>>,
    /// δ_i per node (0 where unfreezable).
    delta: Vec<f64>,
    /// Surcharge-grown lower bounds when the input carries recompute;
    /// `None` ⇒ use `input.w_min` directly (the bit-identical path).
    w_min_eff: Option<Vec<f64>>,
    /// Surcharge-grown upper bounds (see `w_min_eff`).
    w_max_eff: Option<Vec<f64>>,
}

impl BuiltLp {
    /// The duration bounds the LP was actually built from.
    fn bounds<'b>(&'b self, input: &'b FreezeLpInput<'_>) -> (&'b [f64], &'b [f64]) {
        (
            self.w_min_eff.as_deref().unwrap_or(input.w_min),
            self.w_max_eff.as_deref().unwrap_or(input.w_max),
        )
    }
}

/// Validate one freeze-LP instance's data without assembling anything —
/// shared by the from-scratch build and the in-place skeleton refresh.
fn validate(input: &FreezeLpInput) -> Result<(), FreezeLpError> {
    let pdag = input.pdag;
    let n = pdag.len();
    if input.w_min.len() != n || input.w_max.len() != n {
        return Err(FreezeLpError::BadLength { got: input.w_min.len(), want: n });
    }
    if !(0.0..=1.0).contains(&input.r_max) {
        return Err(FreezeLpError::BadRmax(input.r_max));
    }
    for i in 0..n {
        let (lo, hi) = (input.w_min[i], input.w_max[i]);
        if !(lo.is_finite() && hi.is_finite()) || lo < 0.0 || hi < lo {
            return Err(FreezeLpError::BadBounds { node: i, w_min: lo, w_max: hi });
        }
    }
    if let Some(sur) = input.recompute {
        if sur.len() != pdag.stages || sur.iter().any(|c| !c.is_finite() || *c < 0.0) {
            return Err(FreezeLpError::BadRecompute { got: sur.len(), want: pdag.stages });
        }
    }
    if let Some(rmin) = input.r_min {
        if rmin.len() != pdag.stages {
            return Err(FreezeLpError::BadStageFloor {
                stage: usize::MAX,
                r_min: rmin.len() as f64,
            });
        }
        for (s, &r) in rmin.iter().enumerate() {
            if !r.is_finite() || !(0.0..=1.0).contains(&r) {
                return Err(FreezeLpError::BadStageFloor { stage: s, r_min: r });
            }
            if r > input.r_max {
                return Err(FreezeLpError::FloorExceedsBudget {
                    stage: s,
                    r_min: r,
                    r_max: input.r_max,
                });
            }
        }
    }
    if let Some(ec) = input.edge_costs {
        let want = pdag.csr.edge_count();
        if ec.len() != want || ec.iter().any(|c| !c.is_finite() || *c < 0.0) {
            return Err(FreezeLpError::BadEdgeCosts { got: ec.len(), want });
        }
    }
    if let Some(g) = input.edge_traffic {
        let want = pdag.csr.edge_count();
        if g.len() != want || g.iter().any(|c| !c.is_finite() || *c < 0.0) {
            return Err(FreezeLpError::BadEdgeTraffic { got: g.len(), want });
        }
    }
    Ok(())
}

fn build_problem(input: &FreezeLpInput) -> Result<BuiltLp, FreezeLpError> {
    validate(input)?;
    let pdag = input.pdag;
    let n = pdag.len();
    // Effective duration bounds: the recompute surcharge (a partial
    // forward re-run per stash-consuming backward) grows both bounds of
    // the stage's Backward / BackwardDgrad nodes. Appending the
    // surcharge to the caller's bounds here mirrors
    // `CostModel::bounds` baking it in, bit for bit.
    let (w_min_eff, w_max_eff) = match input.recompute {
        None => (None, None),
        Some(sur) => {
            let mut lo = input.w_min.to_vec();
            let mut hi = input.w_max.to_vec();
            for (id, node) in pdag.dag.nodes.iter().enumerate() {
                if let Node::Act(a) = node {
                    if matches!(a.kind, ActionKind::Backward | ActionKind::BackwardDgrad) {
                        lo[id] += sur[a.stage];
                        hi[id] += sur[a.stage];
                    }
                }
            }
            (Some(lo), Some(hi))
        }
    };
    let w_min: &[f64] = w_min_eff.as_deref().unwrap_or(input.w_min);
    let w_max: &[f64] = w_max_eff.as_deref().unwrap_or(input.w_max);

    // δ_i (reciprocal execution-time range; 0 where unfreezable). The
    // surcharge is additive on both bounds, so the range — and with it
    // the freeze-ratio linearization — is unchanged by recompute.
    let delta: Vec<f64> = (0..n)
        .map(|i| {
            let range = w_max[i] - w_min[i];
            if range > 0.0 {
                1.0 / range
            } else {
                0.0
            }
        })
        .collect();

    // Tie-break scaling: λ/|freezable| keeps the secondary term ≤ λ·w̄.
    let freezable: Vec<usize> = (0..n).filter(|&i| delta[i] > 0.0).collect();
    let lam = if freezable.is_empty() {
        0.0
    } else {
        let mean_range: f64 =
            freezable.iter().map(|&i| w_max[i] - w_min[i]).sum::<f64>()
                / freezable.len() as f64;
        input.lambda * mean_range / freezable.len() as f64
    };

    let mut lp = LpProblem::new();
    // Variable layout: P_0..P_{n-1}, then w_i for *freezable* nodes only
    // — fixed-duration nodes (forwards, dgrad) enter the precedence rows
    // as constants, roughly halving the column count and, empirically,
    // cutting simplex time ~4× on ZBV-sized DAGs (PERF.md §2).
    let mut p_var = Vec::with_capacity(n);
    for i in 0..n {
        let cost = if i == pdag.dest { 1.0 } else { 0.0 };
        // [3]: P_source fixed at 0.
        let (lo, hi) = if i == pdag.source { (0.0, 0.0) } else { (0.0, INF) };
        p_var.push(lp.add_var(cost, lo, hi));
    }
    let mut w_var: Vec<Option<usize>> = Vec::with_capacity(n);
    for i in 0..n {
        if delta[i] > 0.0 {
            // Secondary objective: −λ δ_i w_i (maximize durations ⇔
            // minimize freezing) — tie-breaker only.
            let cost = -lam * delta[i];
            w_var.push(Some(lp.add_var(cost, w_min[i], w_max[i])));
        } else {
            w_var.push(None);
        }
    }

    // [1] precedence: P_j − P_i − w_i ≥ e_ij (w_i constant when fixed).
    // Edges iterate u-major over the deduplicated adjacency — the same
    // CSR edge order `p2p_edge_costs` produces, so `eidx` indexes
    // `input.edge_costs` / `input.edge_traffic` directly. With a traffic
    // slope the edge cost is load-dependent, `e0 + g·(1 − r_u)`;
    // substituting `r_u = δ_u (w_max_u − w_u)` folds it into the row as
    // `P_j − P_i − (1 + g δ_u) w_u ≥ e0 + g (1 − δ_u w_max_u)` (the
    // `None` branch keeps the traffic-free expressions bit-identical).
    let mut eidx = 0usize;
    for u in 0..n {
        for &v in &pdag.dag.succs[u] {
            let ec = input.edge_costs.map_or(0.0, |e| e[eidx]);
            let tr = input.edge_traffic.map(|g| g[eidx]);
            eidx += 1;
            match (w_var[u], tr) {
                (Some(wu), None) => lp.add_row(
                    vec![(p_var[v], 1.0), (p_var[u], -1.0), (wu, -1.0)],
                    Cmp::Ge,
                    ec,
                ),
                (Some(wu), Some(g)) => lp.add_row(
                    vec![(p_var[v], 1.0), (p_var[u], -1.0), (wu, -(1.0 + g * delta[u]))],
                    Cmp::Ge,
                    ec + g * (1.0 - delta[u] * w_max[u]),
                ),
                (None, None) => lp.add_row(
                    vec![(p_var[v], 1.0), (p_var[u], -1.0)],
                    Cmp::Ge,
                    w_max[u] + ec,
                ),
                // Unfreezable sender: r_u = 0, full payload always.
                (None, Some(g)) => lp.add_row(
                    vec![(p_var[v], 1.0), (p_var[u], -1.0)],
                    Cmp::Ge,
                    w_max[u] + ec + g,
                ),
            }
        }
    }

    // [4] stage budget: Σ δ_i w_i ≥ Σ δ_i w_max_i − r_max |V_s|, and
    // [5] memory floor: Σ δ_i w_i ≤ Σ δ_i w_max_i − r_min_s |V_s|.
    for (s, set) in pdag.freezable_by_stage().iter().enumerate() {
        if set.is_empty() {
            continue;
        }
        let wmax_term: f64 = set.iter().map(|&i| delta[i] * w_max[i]).sum::<f64>();
        let coeffs: Vec<(usize, f64)> =
            set.iter().filter_map(|&i| w_var[i].map(|wi| (wi, delta[i]))).collect();
        lp.add_row(coeffs.clone(), Cmp::Ge, wmax_term - input.r_max * set.len() as f64);
        if let Some(rmin) = input.r_min {
            if rmin[s] > 0.0 {
                lp.add_row(coeffs, Cmp::Le, wmax_term - rmin[s] * set.len() as f64);
            }
        }
    }

    Ok(BuiltLp { lp, w_var, delta, w_min_eff, w_max_eff })
}

/// Realized per-edge costs under load-dependent traffic, one vector per
/// envelope (u-major CSR edge order, matching the precedence rows):
/// `e0 + g·(1 − r_u)` for the chosen ratios, `e0 + g` for the
/// no-freezing envelope, and `e0 + g·(1 − r_full_u)` for full freezing
/// (freezable senders drop to `e0`; unfreezable senders keep `e0 + g`).
fn realized_edge_costs(
    input: &FreezeLpInput,
    built: &BuiltLp,
    ratios: &[f64],
    w_min: &[f64],
    w_max: &[f64],
    tr: &[f64],
) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let pdag = input.pdag;
    let count = pdag.csr.edge_count();
    let mut cw = vec![0.0; count];
    let mut cmax = vec![0.0; count];
    let mut cmin = vec![0.0; count];
    let mut eidx = 0usize;
    for u in 0..pdag.len() {
        for _ in &pdag.dag.succs[u] {
            let e0 = input.edge_costs.map_or(0.0, |e| e[eidx]);
            let g = tr[eidx];
            let full = (built.delta[u] * (w_max[u] - w_min[u])).clamp(0.0, 1.0);
            cw[eidx] = e0 + g * (1.0 - ratios[u]);
            cmax[eidx] = e0 + g;
            cmin[eidx] = e0 + g * (1.0 - full);
            eidx += 1;
        }
    }
    (cw, cmax, cmin)
}

fn extract_solution(
    input: &FreezeLpInput,
    built: &BuiltLp,
    sol: &LpSolution,
) -> FreezeSolution {
    let pdag = input.pdag;
    let n = pdag.len();
    // Recompute-grown bounds when a surcharge was supplied; the caller's
    // slices otherwise.
    let (w_min, w_max) = built.bounds(input);
    let w: Vec<f64> = (0..n)
        .map(|i| match built.w_var[i] {
            Some(wi) => sol.x[wi].clamp(w_min[i], w_max[i]),
            None => w_max[i],
        })
        .collect();
    let ratios: Vec<f64> = (0..n)
        .map(|i| (built.delta[i] * (w_max[i] - w[i])).clamp(0.0, 1.0))
        .collect();
    // Earliest start times under chosen durations (eq. 5) — the LP's P_i
    // may carry slack on non-critical nodes. The three longest-path
    // sweeps (chosen durations + both envelopes of eq. 46) run straight
    // off the DAG's cached CSR: no clone, one scratch buffer for the
    // envelopes. With edge costs, the same sweeps charge e_ij so the
    // reported times match the precedence rows the LP optimized.
    let realized = input
        .edge_traffic
        .map(|tr| realized_edge_costs(input, built, &ratios, w_min, w_max, tr));
    let sweep = |weights: &[f64], ec: Option<&[f64]>, out: &mut Vec<f64>| match ec {
        None => pdag.csr.start_times_into(weights, out),
        Some(ec) => pdag.csr.start_times_with_edges_into(weights, ec, out),
    };
    let (ec_w, ec_max, ec_min) = match &realized {
        None => (input.edge_costs, input.edge_costs, input.edge_costs),
        Some((cw, cmax, cmin)) => {
            (Some(cw.as_slice()), Some(cmax.as_slice()), Some(cmin.as_slice()))
        }
    };
    let mut start_times = Vec::new();
    sweep(&w, ec_w, &mut start_times);
    let batch_time = start_times[pdag.dest];
    let mut scratch = Vec::new();
    sweep(w_max, ec_max, &mut scratch);
    let p_d_max = scratch[pdag.dest];
    sweep(w_min, ec_min, &mut scratch);
    let p_d_min = scratch[pdag.dest];

    FreezeSolution {
        ratios,
        w,
        start_times,
        batch_time,
        p_d_max,
        p_d_min,
        iterations: sol.iterations,
        recompute_surcharge: input.recompute.map(|s| s.to_vec()),
        stats: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::types::{ActionKind, ScheduleKind};

    /// Build a small DAG plus bound vectors: forward = 1.0 fixed;
    /// backward ∈ [dgrad_frac·2.0, 2.0].
    fn setup(
        kind: ScheduleKind,
        ranks: usize,
        m: usize,
        dgrad_frac: f64,
    ) -> (PipelineDag, Vec<f64>, Vec<f64>) {
        let s = Schedule::build(kind, ranks, m, Schedule::default_chunks(kind));
        let g = PipelineDag::from_schedule(&s);
        let mut w_min = vec![0.0; g.len()];
        let mut w_max = vec![0.0; g.len()];
        for (id, node) in g.dag.nodes.iter().enumerate() {
            if let crate::graph::pipeline::Node::Act(a) = node {
                match a.kind {
                    ActionKind::Forward => {
                        w_min[id] = 1.0;
                        w_max[id] = 1.0;
                    }
                    ActionKind::Backward => {
                        w_max[id] = 2.0;
                        w_min[id] = 2.0 * dgrad_frac;
                    }
                    ActionKind::BackwardDgrad => {
                        w_min[id] = 1.0;
                        w_max[id] = 1.0;
                    }
                    ActionKind::BackwardWgrad => {
                        w_max[id] = 1.0;
                        w_min[id] = 0.0;
                    }
                }
            }
        }
        (g, w_min, w_max)
    }

    fn solve(g: &PipelineDag, w_min: &[f64], w_max: &[f64], r_max: f64) -> FreezeSolution {
        solve_freeze_lp(&FreezeLpInput::new(g, w_min, w_max, r_max, DEFAULT_LAMBDA)).unwrap()
    }

    #[test]
    fn rmax_zero_recovers_baseline() {
        let (g, w_min, w_max) = setup(ScheduleKind::GPipe, 4, 4, 0.5);
        let sol = solve(&g, &w_min, &w_max, 0.0);
        assert!((sol.batch_time - sol.p_d_max).abs() < 1e-6);
        assert!(sol.ratios.iter().all(|&r| r < 1e-7));
    }

    #[test]
    fn rmax_one_reaches_full_freeze_envelope() {
        let (g, w_min, w_max) = setup(ScheduleKind::GPipe, 4, 4, 0.5);
        let sol = solve(&g, &w_min, &w_max, 1.0);
        assert!(
            (sol.batch_time - sol.p_d_min).abs() < 1e-6,
            "batch {} vs envelope {}",
            sol.batch_time,
            sol.p_d_min
        );
    }

    #[test]
    fn batch_time_monotone_in_rmax() {
        let (g, w_min, w_max) = setup(ScheduleKind::OneFOneB, 4, 8, 0.4);
        let mut prev = f64::INFINITY;
        for rmax in [0.0, 0.2, 0.4, 0.6, 0.8, 1.0] {
            let sol = solve(&g, &w_min, &w_max, rmax);
            assert!(
                sol.batch_time <= prev + 1e-7,
                "P_d not monotone at r_max={rmax}: {} > {prev}",
                sol.batch_time
            );
            prev = sol.batch_time;
        }
    }

    #[test]
    fn stage_budget_respected() {
        let (g, w_min, w_max) = setup(ScheduleKind::OneFOneB, 4, 8, 0.4);
        let r_max = 0.5;
        let sol = solve(&g, &w_min, &w_max, r_max);
        for (s, set) in g.freezable_by_stage().iter().enumerate() {
            let avg: f64 =
                set.iter().map(|&i| sol.ratios[i]).sum::<f64>() / set.len() as f64;
            assert!(avg <= r_max + 1e-6, "stage {s} over budget: {avg}");
        }
    }

    #[test]
    fn ratios_within_unit_interval_and_forward_zero() {
        let (g, w_min, w_max) = setup(ScheduleKind::ZeroBubbleV, 4, 8, 0.5);
        let sol = solve(&g, &w_min, &w_max, 0.8);
        for (id, node) in g.dag.nodes.iter().enumerate() {
            assert!((0.0..=1.0 + 1e-9).contains(&sol.ratios[id]));
            if let crate::graph::pipeline::Node::Act(a) = node {
                if !a.kind.freezable() {
                    assert_eq!(sol.ratios[id], 0.0, "unfreezable node {a} got frozen");
                }
            }
        }
    }

    #[test]
    fn tie_breaker_avoids_ineffective_freezing() {
        // The Figure 1(b) scenario: freezing off the critical path buys
        // no time, so the tie-breaker must keep those ratios at ~0.
        // Construct GPipe where stage 3 dominates: its backward is the
        // bottleneck; early stages idle anyway.
        let s = Schedule::build(ScheduleKind::GPipe, 4, 4, 1);
        let g = PipelineDag::from_schedule(&s);
        let mut w_min = vec![0.0; g.len()];
        let mut w_max = vec![0.0; g.len()];
        for (id, node) in g.dag.nodes.iter().enumerate() {
            if let crate::graph::pipeline::Node::Act(a) = node {
                match a.kind {
                    ActionKind::Forward => {
                        w_min[id] = 1.0;
                        w_max[id] = 1.0;
                    }
                    _ => {
                        // Stage 3 backward is 4× heavier.
                        let hi = if a.stage == 3 { 8.0 } else { 2.0 };
                        w_max[id] = hi;
                        w_min[id] = 0.3 * hi;
                    }
                }
            }
        }
        let sol = solve(&g, &w_min, &w_max, 0.8);
        // Bottleneck stage should be frozen aggressively…
        let by_stage = g.freezable_by_stage();
        let avg = |s: usize| {
            by_stage[s].iter().map(|&i| sol.ratios[i]).sum::<f64>() / by_stage[s].len() as f64
        };
        assert!(avg(3) > 0.5, "bottleneck stage under-frozen: {}", avg(3));
        // …and the total freezing must stay *below* the max budget
        // everywhere (no gratuitous freezing off the critical path).
        let total: f64 = (0..4).map(avg).sum::<f64>() / 4.0;
        assert!(total < 0.8 - 1e-6, "tie-breaker failed: average ratio {total}");
        // Speedup achieved.
        assert!(sol.batch_time < sol.p_d_max - 1e-6);
    }

    #[test]
    fn matches_brute_force_on_tiny_instance() {
        // 2 stages × 2 microbatches GPipe; grid-search durations on a
        // 6-point lattice per backward node and compare achievable P_d
        // under the stage budget. The LP must be at least as good as the
        // best lattice point and no better than the continuous envelope.
        let (g, w_min, w_max) = setup(ScheduleKind::GPipe, 2, 2, 0.5);
        let r_max = 0.5;
        let sol = solve(&g, &w_min, &w_max, r_max);
        let freezable: Vec<usize> = (0..g.len())
            .filter(|&i| w_max[i] > w_min[i])
            .collect();
        assert_eq!(freezable.len(), 4);
        let grid = 6usize;
        let mut best = f64::INFINITY;
        let mut idx = vec![0usize; freezable.len()];
        loop {
            let mut w = w_max.clone();
            for (k, &node) in freezable.iter().enumerate() {
                let t = idx[k] as f64 / (grid - 1) as f64;
                w[node] = w_min[node] + t * (w_max[node] - w_min[node]);
            }
            // Budget check per stage.
            let mut ok = true;
            for set in g.freezable_by_stage() {
                if set.is_empty() {
                    continue;
                }
                let avg: f64 = set
                    .iter()
                    .map(|&i| (w_max[i] - w[i]) / (w_max[i] - w_min[i]))
                    .sum::<f64>()
                    / set.len() as f64;
                if avg > r_max + 1e-9 {
                    ok = false;
                    break;
                }
            }
            if ok {
                best = best.min(g.batch_time(&w));
            }
            // Advance lattice counter.
            let mut k = 0;
            loop {
                if k == idx.len() {
                    break;
                }
                idx[k] += 1;
                if idx[k] < grid {
                    break;
                }
                idx[k] = 0;
                k += 1;
            }
            if k == idx.len() {
                break;
            }
        }
        assert!(
            sol.batch_time <= best + 1e-6,
            "LP {} worse than lattice {best}",
            sol.batch_time
        );
    }

    #[test]
    fn kappa_and_mean_ratio_reported() {
        let (g, w_min, w_max) = setup(ScheduleKind::OneFOneB, 4, 8, 0.4);
        let sol = solve(&g, &w_min, &w_max, 0.8);
        assert!(sol.kappa() > 0.0 && sol.kappa() <= 1.0);
        let mean = sol.mean_freezable_ratio(&g);
        assert!((0.0..=0.8 + 1e-6).contains(&mean));
    }

    #[test]
    fn warm_solver_matches_cold_across_perturbed_instances() {
        // A controller re-planning per check-interval sees the same DAG
        // with slightly refreshed monitoring bounds. The warm-started
        // solver must return the same optimum as a cold solve each time.
        let (g, w_min, mut w_max) = setup(ScheduleKind::OneFOneB, 4, 8, 0.4);
        let mut solver = FreezeLpSolver::new();
        let mut rng = crate::util::rng::Rng::seed_from_u64(99);
        for round in 0..6 {
            let r_max = 0.4 + 0.1 * (round % 3) as f64;
            let input = FreezeLpInput::new(&g, &w_min, &w_max, r_max, DEFAULT_LAMBDA);
            let warm = solver.solve(&input).unwrap();
            let cold = solve_freeze_lp(&input).unwrap();
            assert!(
                (warm.batch_time - cold.batch_time).abs() < 1e-6,
                "round {round}: warm {} vs cold {}",
                warm.batch_time,
                cold.batch_time
            );
            assert!(solver.has_warm_basis());
            // Jitter the measured upper bounds a few percent, keeping
            // w_max ≥ w_min, like refreshed monitoring means would.
            for i in 0..g.len() {
                if w_max[i] > w_min[i] {
                    let jitter = 1.0 + 0.03 * (rng.next_f64() - 0.5);
                    w_max[i] = (w_max[i] * jitter).max(w_min[i]);
                }
            }
        }
    }

    #[test]
    fn warm_solver_converges_in_few_pivots() {
        let (g, w_min, w_max) = setup(ScheduleKind::OneFOneB, 4, 8, 0.4);
        let input = FreezeLpInput::new(&g, &w_min, &w_max, 0.8, DEFAULT_LAMBDA);
        let mut solver = FreezeLpSolver::new();
        let cold = solver.solve(&input).unwrap();
        // Identical re-solve: pricing certifies optimality immediately.
        let warm = solver.solve(&input).unwrap();
        assert!(
            warm.iterations * 10 <= cold.iterations.max(10),
            "warm resolve took {} iterations vs cold {}",
            warm.iterations,
            cold.iterations
        );
        assert!((warm.batch_time - cold.batch_time).abs() < 1e-9);
    }

    #[test]
    fn solves_synthesized_schedule_dags() {
        // The schedule synthesizer replans against arbitrary generated
        // orders; the LP must accept their DAGs exactly as it does the
        // fixed four, and the persistent solver must survive a reset
        // between two differently-shaped synthesized instances.
        let mut solver = FreezeLpSolver::new();
        for (ranks, m) in [(2, 4), (3, 6)] {
            let s = Schedule::build(ScheduleKind::Synthesized, ranks, m, 2);
            s.check_legal().unwrap();
            let g = PipelineDag::from_schedule(&s);
            let mut w_min = vec![0.0; g.len()];
            let mut w_max = vec![0.0; g.len()];
            for (id, node) in g.dag.nodes.iter().enumerate() {
                if let crate::graph::pipeline::Node::Act(a) = node {
                    match a.kind {
                        ActionKind::Forward | ActionKind::BackwardDgrad => {
                            w_min[id] = 1.0;
                            w_max[id] = 1.0;
                        }
                        ActionKind::Backward => {
                            w_min[id] = 1.0;
                            w_max[id] = 2.0;
                        }
                        ActionKind::BackwardWgrad => {
                            w_min[id] = 0.0;
                            w_max[id] = 1.0;
                        }
                    }
                }
            }
            solver.reset();
            let input = FreezeLpInput::new(&g, &w_min, &w_max, 0.5, DEFAULT_LAMBDA);
            let sol = solver.solve(&input).unwrap();
            let cold = solve_freeze_lp(&input).unwrap();
            assert!((sol.batch_time - cold.batch_time).abs() < 1e-6);
            assert!(sol.p_d_min - 1e-6 <= sol.batch_time && sol.batch_time <= sol.p_d_max + 1e-6);
            for (s, set) in g.freezable_by_stage().iter().enumerate() {
                if set.is_empty() {
                    continue;
                }
                let avg: f64 =
                    set.iter().map(|&i| sol.ratios[i]).sum::<f64>() / set.len() as f64;
                assert!(avg <= 0.5 + 1e-6, "stage {s} over budget: {avg}");
            }
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        let (g, w_min, w_max) = setup(ScheduleKind::GPipe, 2, 2, 0.5);
        let bad = FreezeLpInput::new(&g, &w_min[1..], &w_max, 0.5, 1e-4);
        assert!(matches!(solve_freeze_lp(&bad), Err(FreezeLpError::BadLength { .. })));
        let bad2 = FreezeLpInput::new(&g, &w_min, &w_max, 1.5, 1e-4);
        assert!(matches!(solve_freeze_lp(&bad2), Err(FreezeLpError::BadRmax(_))));
        // Floor outside [0,1], floor above budget, short edge vector.
        let floor = [0.2, 1.4];
        let bad3 = FreezeLpInput::new(&g, &w_min, &w_max, 0.5, 1e-4).with_stage_floor(&floor);
        assert!(matches!(
            solve_freeze_lp(&bad3),
            Err(FreezeLpError::BadStageFloor { stage: 1, .. })
        ));
        let floor = [0.2, 0.9];
        let bad4 = FreezeLpInput::new(&g, &w_min, &w_max, 0.5, 1e-4).with_stage_floor(&floor);
        assert!(matches!(
            solve_freeze_lp(&bad4),
            Err(FreezeLpError::FloorExceedsBudget { stage: 1, .. })
        ));
        let short = [0.0; 3];
        let bad5 = FreezeLpInput::new(&g, &w_min, &w_max, 0.5, 1e-4).with_edge_costs(&short);
        assert!(matches!(solve_freeze_lp(&bad5), Err(FreezeLpError::BadEdgeCosts { .. })));
    }

    #[test]
    fn stage_floor_binds_from_below() {
        // Without a floor, cheap stages freeze ~nothing (tie-breaker);
        // with a memory floor every stage must average at least r_min.
        let (g, w_min, w_max) = setup(ScheduleKind::OneFOneB, 4, 8, 0.4);
        let free = solve(&g, &w_min, &w_max, 0.8);
        let floor = vec![0.5; 4];
        let sol = solve_freeze_lp(
            &FreezeLpInput::new(&g, &w_min, &w_max, 0.8, DEFAULT_LAMBDA)
                .with_stage_floor(&floor),
        )
        .unwrap();
        let rs = sol.stage_ratios(&g);
        for (s, &r) in rs.iter().enumerate() {
            assert!(r >= 0.5 - 1e-6, "stage {s} below floor: {r}");
            assert!(r <= 0.8 + 1e-6, "stage {s} over budget: {r}");
        }
        // Forcing freezing can only help (or leave) the batch time.
        assert!(sol.batch_time <= free.batch_time + 1e-6);
        // A floor of zero reproduces the unconstrained optimum exactly.
        let zeros = vec![0.0; 4];
        let same = solve_freeze_lp(
            &FreezeLpInput::new(&g, &w_min, &w_max, 0.8, DEFAULT_LAMBDA)
                .with_stage_floor(&zeros),
        )
        .unwrap();
        assert_eq!(same.batch_time, free.batch_time);
        assert_eq!(same.ratios, free.ratios);
    }

    #[test]
    fn recompute_surcharge_grows_envelopes_zero_is_bit_identical() {
        let (g, w_min, w_max) = setup(ScheduleKind::OneFOneB, 4, 8, 0.4);
        let free = solve(&g, &w_min, &w_max, 0.8);
        assert!(free.recompute_surcharge.is_none());
        // A uniform surcharge inflates the whole envelope: every
        // microbatch's backward re-runs part of the forward.
        let sur = vec![0.4; 4];
        let sol = solve_freeze_lp(
            &FreezeLpInput::new(&g, &w_min, &w_max, 0.8, DEFAULT_LAMBDA).with_recompute(&sur),
        )
        .unwrap();
        assert!(sol.p_d_max > free.p_d_max + 1e-9);
        assert!(sol.p_d_min > free.p_d_min + 1e-9);
        assert!(sol.batch_time > free.batch_time + 1e-9);
        assert_eq!(sol.recompute_surcharge.as_deref(), Some(&sur[..]));
        // The surcharge is freeze-invariant: budgets still hold and the
        // reported time matches a sweep of the chosen durations.
        for (s, set) in g.freezable_by_stage().iter().enumerate() {
            let avg: f64 = set.iter().map(|&i| sol.ratios[i]).sum::<f64>() / set.len() as f64;
            assert!(avg <= 0.8 + 1e-6, "stage {s} over budget: {avg}");
        }
        assert!((sol.batch_time - g.batch_time(&sol.w)).abs() < 1e-9);
        // A zero surcharge is bit-identical to the surcharge-free path.
        let zeros = vec![0.0; 4];
        let same = solve_freeze_lp(
            &FreezeLpInput::new(&g, &w_min, &w_max, 0.8, DEFAULT_LAMBDA)
                .with_recompute(&zeros),
        )
        .unwrap();
        assert_eq!(same.batch_time.to_bits(), free.batch_time.to_bits());
        assert_eq!(same.p_d_max.to_bits(), free.p_d_max.to_bits());
        assert_eq!(same.ratios, free.ratios);
        assert_eq!(same.w, free.w);
        assert_eq!(same.iterations, free.iterations);
    }

    #[test]
    fn recompute_keeps_warm_start_valid() {
        // The surcharge moves bounds and RHS constants but not the row
        // structure, so one solver can alternate surcharge on/off and
        // keep warm-starting to the cold optimum.
        let (g, w_min, w_max) = setup(ScheduleKind::ZeroBubbleV, 4, 8, 0.5);
        let sur = vec![0.3; 8];
        let mut solver = FreezeLpSolver::new();
        for round in 0..4 {
            let mut input = FreezeLpInput::new(&g, &w_min, &w_max, 0.7, DEFAULT_LAMBDA);
            if round % 2 == 1 {
                input = input.with_recompute(&sur);
            }
            let warm = solver.solve(&input).unwrap();
            let cold = solve_freeze_lp(&input).unwrap();
            assert!(
                (warm.batch_time - cold.batch_time).abs() < 1e-6,
                "round {round}: warm {} vs cold {}",
                warm.batch_time,
                cold.batch_time
            );
            assert!(solver.has_warm_basis());
        }
    }

    #[test]
    fn rejects_bad_recompute_vectors() {
        let (g, w_min, w_max) = setup(ScheduleKind::GPipe, 2, 2, 0.5);
        // Wrong arity (per-stage, not per-node).
        let short = [0.1];
        let bad = FreezeLpInput::new(&g, &w_min, &w_max, 0.5, 1e-4).with_recompute(&short);
        assert!(matches!(solve_freeze_lp(&bad), Err(FreezeLpError::BadRecompute { .. })));
        // Negative surcharge.
        let neg = [0.1, -0.2];
        let bad = FreezeLpInput::new(&g, &w_min, &w_max, 0.5, 1e-4).with_recompute(&neg);
        assert!(matches!(solve_freeze_lp(&bad), Err(FreezeLpError::BadRecompute { .. })));
    }

    #[test]
    fn edge_costs_raise_batch_time_and_shift_optimum() {
        let (g, w_min, w_max) = setup(ScheduleKind::GPipe, 4, 4, 0.5);
        let free = solve(&g, &w_min, &w_max, 0.8);
        let ec = g.p2p_edge_costs(|_, _| 0.4);
        let sol = solve_freeze_lp(
            &FreezeLpInput::new(&g, &w_min, &w_max, 0.8, DEFAULT_LAMBDA).with_edge_costs(&ec),
        )
        .unwrap();
        // Communication inflates the whole envelope.
        assert!(sol.p_d_max > free.p_d_max + 1e-9);
        assert!(sol.batch_time > free.batch_time - 1e-9);
        // The reported batch time matches an edge-aware DAG sweep of the
        // chosen durations.
        assert!((sol.batch_time - g.batch_time_with_edges(&sol.w, &ec)).abs() < 1e-9);
        // Zero edge costs are bit-identical to the edge-free path.
        let zeros = vec![0.0; g.csr.edge_count()];
        let same = solve_freeze_lp(
            &FreezeLpInput::new(&g, &w_min, &w_max, 0.8, DEFAULT_LAMBDA)
                .with_edge_costs(&zeros),
        )
        .unwrap();
        assert_eq!(same.batch_time, free.batch_time);
        assert_eq!(same.ratios, free.ratios);
    }

    #[test]
    fn zero_edge_traffic_is_bit_identical() {
        let (g, w_min, w_max) = setup(ScheduleKind::OneFOneB, 4, 4, 0.5);
        let ec = g.p2p_edge_costs(|_, _| 0.4);
        let base = solve_freeze_lp(
            &FreezeLpInput::new(&g, &w_min, &w_max, 0.8, DEFAULT_LAMBDA).with_edge_costs(&ec),
        )
        .unwrap();
        let zeros = vec![0.0; g.csr.edge_count()];
        let same = solve_freeze_lp(
            &FreezeLpInput::new(&g, &w_min, &w_max, 0.8, DEFAULT_LAMBDA)
                .with_edge_costs(&ec)
                .with_edge_traffic(&zeros),
        )
        .unwrap();
        assert_eq!(same.batch_time.to_bits(), base.batch_time.to_bits());
        assert_eq!(same.p_d_max.to_bits(), base.p_d_max.to_bits());
        assert_eq!(same.p_d_min.to_bits(), base.p_d_min.to_bits());
        assert_eq!(same.ratios, base.ratios);
        assert_eq!(same.w, base.w);
        assert_eq!(same.iterations, base.iterations);
    }

    #[test]
    fn edge_traffic_lets_freezing_cut_comm() {
        // Backward compute barely shrinks under freezing (range 0.1) but
        // every cross-rank gradient edge pays a large load-dependent
        // serialization term. A constant-cost solve must pay the full
        // `e0 + g` on every edge; the traffic-aware solve freezes the
        // senders and realizes far cheaper communication.
        let (g, w_min, w_max) = setup(ScheduleKind::GPipe, 4, 4, 0.95);
        let e0 = g.p2p_edge_costs(|_, _| 0.1);
        let tr = g.cross_rank_edge_map(
            |a, _| if a.kind.freezable() { 5.0 } else { 0.0 },
            0.0,
        );
        let full: Vec<f64> = e0.iter().zip(&tr).map(|(a, b)| a + b).collect();
        let naive = solve_freeze_lp(
            &FreezeLpInput::new(&g, &w_min, &w_max, 0.6, DEFAULT_LAMBDA)
                .with_edge_costs(&full),
        )
        .unwrap();
        let aware = solve_freeze_lp(
            &FreezeLpInput::new(&g, &w_min, &w_max, 0.6, DEFAULT_LAMBDA)
                .with_edge_costs(&e0)
                .with_edge_traffic(&tr),
        )
        .unwrap();
        // Same no-freezing envelope (traffic at r = 0 is the full cost).
        assert!((aware.p_d_max - naive.p_d_max).abs() < 1e-9);
        // Freezing now cuts comm, so the optimum drops well below the
        // constant-cost optimum (g = 5.0 ≫ the 0.1 compute range).
        assert!(
            aware.batch_time < naive.batch_time - 1.0,
            "aware {} vs naive {}",
            aware.batch_time,
            naive.batch_time
        );
        // Budgets still hold.
        for (s, set) in g.freezable_by_stage().iter().enumerate() {
            if set.is_empty() {
                continue;
            }
            let avg: f64 = set.iter().map(|&i| aware.ratios[i]).sum::<f64>() / set.len() as f64;
            assert!(avg <= 0.6 + 1e-6, "stage {s} over budget: {avg}");
        }
        // The reported time matches an edge-aware sweep under the
        // realized (ratio-scaled) edge costs.
        let mut realized = vec![0.0; g.csr.edge_count()];
        let mut eidx = 0usize;
        for u in 0..g.len() {
            for _ in &g.dag.succs[u] {
                realized[eidx] = e0[eidx] + tr[eidx] * (1.0 - aware.ratios[u]);
                eidx += 1;
            }
        }
        assert!(
            (aware.batch_time - g.batch_time_with_edges(&aware.w, &realized)).abs() < 1e-6,
            "LP optimum must match the realized-cost sweep"
        );
    }

    #[test]
    fn edge_traffic_keeps_warm_start_valid() {
        // Toggling the traffic term rewrites precedence-row *matrix*
        // coefficients, which the persistent simplex must notice (row
        // fingerprint) and still land on the cold optimum.
        let (g, w_min, w_max) = setup(ScheduleKind::OneFOneB, 4, 8, 0.5);
        let e0 = g.p2p_edge_costs(|_, _| 0.2);
        let tr = g.cross_rank_edge_map(
            |a, _| if a.kind.freezable() { 1.5 } else { 0.0 },
            0.0,
        );
        let mut solver = FreezeLpSolver::new();
        for round in 0..4 {
            let mut input =
                FreezeLpInput::new(&g, &w_min, &w_max, 0.7, DEFAULT_LAMBDA).with_edge_costs(&e0);
            if round % 2 == 1 {
                input = input.with_edge_traffic(&tr);
            }
            let warm = solver.solve(&input).unwrap();
            let cold = solve_freeze_lp(&input).unwrap();
            assert!(
                (warm.batch_time - cold.batch_time).abs() < 1e-6,
                "round {round}: warm {} vs cold {}",
                warm.batch_time,
                cold.batch_time
            );
            assert!(solver.has_warm_basis());
        }
    }

    #[test]
    fn rejects_bad_edge_traffic_vectors() {
        let (g, w_min, w_max) = setup(ScheduleKind::GPipe, 2, 2, 0.5);
        let short = [0.0; 3];
        let bad = FreezeLpInput::new(&g, &w_min, &w_max, 0.5, 1e-4).with_edge_traffic(&short);
        assert!(matches!(solve_freeze_lp(&bad), Err(FreezeLpError::BadEdgeTraffic { .. })));
        let mut neg = vec![0.0; g.csr.edge_count()];
        neg[0] = -1.0;
        let bad = FreezeLpInput::new(&g, &w_min, &w_max, 0.5, 1e-4).with_edge_traffic(&neg);
        assert!(matches!(solve_freeze_lp(&bad), Err(FreezeLpError::BadEdgeTraffic { .. })));
    }
}
