//! Linear programming layer: a from-scratch bounded-variable simplex
//! solver and the TimelyFreeze freeze-ratio formulation built on it.

pub mod freeze_lp;
pub mod simplex;

pub use freeze_lp::{solve_freeze_lp, FreezeLpError, FreezeLpInput, FreezeSolution, DEFAULT_LAMBDA};
pub use simplex::{solve, Cmp, LpProblem, LpRow, LpSolution, LpStatus, INF};
