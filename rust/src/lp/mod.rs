//! Linear programming layer: a from-scratch bounded-variable simplex
//! solver and the TimelyFreeze freeze-ratio formulation built on it.
//!
//! Two solver cores live side by side. The dense two-phase tableau
//! simplex in [`simplex`] is the one-shot reference oracle
//! ([`solve`] / [`solve_from_basis`]); the sparse revised simplex in
//! `revised` (basis LU from `factor`, Devex pricing, long-step
//! bound-flipping dual ratio test) powers [`PersistentSimplex`]'s
//! incremental → warm → cold replan ladder and is tuned via
//! [`SimplexConfig`], reporting per-solve [`SolveStats`].

mod factor;
pub mod freeze_lp;
mod revised;
pub mod simplex;

pub use freeze_lp::{
    build_lp, solve_freeze_lp, FreezeLpError, FreezeLpInput, FreezeLpSolver,
    FreezeSolution, DEFAULT_LAMBDA,
};
pub use simplex::{
    solve, solve_from_basis, Basis, Cmp, LpProblem, LpRow, LpSolution, LpStatus,
    PersistentSimplex, SimplexConfig, SolvePath, SolveStats, INF,
};
