//! Linear programming layer: a from-scratch bounded-variable simplex
//! solver (flat tableau, partial pricing, warm starts) and the
//! TimelyFreeze freeze-ratio formulation built on it.

pub mod freeze_lp;
pub mod simplex;

pub use freeze_lp::{
    solve_freeze_lp, FreezeLpError, FreezeLpInput, FreezeLpSolver, FreezeSolution,
    DEFAULT_LAMBDA,
};
pub use simplex::{
    solve, solve_from_basis, Basis, Cmp, LpProblem, LpRow, LpSolution, LpStatus,
    PersistentSimplex, SolvePath, INF,
};
