//! Sparse revised simplex core: LU-factorized basis ([`super::factor`]),
//! Devex pricing, and a long-step bound-flipping dual ratio test.
//!
//! This module is the engine behind
//! [`PersistentSimplex`](super::simplex::PersistentSimplex). Where the
//! dense seed path stored `B⁻¹A` as an m×ntot tableau and paid O(m²)
//! per pivot, the revised core keeps only the basis factorization and
//! reconstructs what each pivot needs on demand:
//!
//! * the **entering column** `α = B⁻¹ a_q` by one ftran,
//! * the **pivot row** `α_r = eᵣᵀ B⁻¹ A` by one btran plus a pass over
//!   the (sparse) structural columns,
//!
//! so per-pivot cost is O(m + nnz) instead of O(m·ntot). Reduced costs
//! are maintained incrementally from the pivot row and recomputed from
//! scratch at every refactorization; primal pricing is Devex (reference
//! weights reset per solve), dual pricing is Devex over rows (weights
//! updated for free from the ftran'd entering column), and both fall
//! back to Bland's rule after a degeneracy stall, guaranteeing
//! termination. The dual ratio test is the long-step bound-flipping
//! variant: breakpoints are walked in ratio order and every *boxed*
//! nonbasic crossed on the way flips to its opposite bound in bulk —
//! one combined ftran repairs the basic values for all flips — so LPs
//! whose optimum pins many variables at a bound (the freeze LP's `w`
//! columns under a tight `r_max`) converge in a fraction of the pivots.
//!
//! Problem layout: `[structural 0..n | logical n..n+m]`, one logical
//! column (coefficient +1) per row with bounds `Le → [0, ∞)`,
//! `Ge → (−∞, 0]`, `Eq → [0, 0]` — no artificial variables. The cold
//! start seats nonbasics dual-feasibly against the all-logical basis
//! and *cost-shifts* the columns that cannot be seated (free variables
//! and semi-infinite boxes with the wrong cost sign), runs the dual
//! simplex to primal feasibility, then restores true costs for a primal
//! clean-up phase. With no artificials, an `Infeasible` verdict from
//! the dual ratio test is a genuine Farkas certificate (a violated row
//! whose every admissible move worsens it), not the pinned-artificial
//! ambiguity the dense incremental path had to refactorize around.

use super::factor::Factorization;
use super::simplex::{Basis, Cmp, LpProblem, LpSolution, LpStatus, INF};

const FEAS_TOL: f64 = 1e-9;
const OPT_TOL: f64 = 1e-9;
const PIVOT_TOL: f64 = 1e-10;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum VState {
    Basic(usize),
    AtLower,
    AtUpper,
}

/// The persistent sparse solver state: problem data in the
/// `[structural | logical]` layout, the current basis and resting
/// states, the live factorization, and the per-solve counters.
#[derive(Clone, Debug)]
pub(crate) struct RevisedSimplex {
    n: usize,
    m: usize,
    ntot: usize,
    /// All `ntot` columns, sparse `(row, value)`; logicals are unit.
    cols: Vec<Vec<(usize, f64)>>,
    lower: Vec<f64>,
    upper: Vec<f64>,
    /// True objective (logicals 0).
    c: Vec<f64>,
    /// Working objective (equal to `c` except while cost-shifted).
    ccur: Vec<f64>,
    rhs: Vec<f64>,
    senses: Vec<Cmp>,
    /// Structural fingerprint guarding incremental reuse.
    coeffs_fp: Vec<Vec<(usize, f64)>>,
    basis: Vec<usize>,
    state: Vec<VState>,
    xval: Vec<f64>,
    xb: Vec<f64>,
    d: Vec<f64>,
    fac: Factorization,
    // Devex weights (reset per optimize call).
    pweight: Vec<f64>,
    dweight: Vec<f64>,
    // Scratch buffers.
    work_row: Vec<f64>,
    work_pos: Vec<f64>,
    alpha_col: Vec<f64>,
    alpha_row: Vec<f64>,
    // Per-solve counters.
    pivots: usize,
    flips: usize,
    refactors: usize,
}

/// Internal failure signal: the state is numerically unusable for this
/// solve and the caller's ladder should fall through to a fresh rung.
pub(crate) struct NumericalFailure;

impl RevisedSimplex {
    /// Build a cold state for `p`: all-logical basis (identity
    /// factorization), nonbasics seated dual-feasibly where a finite
    /// bound allows it.
    pub(crate) fn from_problem(p: &LpProblem) -> RevisedSimplex {
        let n = p.num_vars();
        let m = p.num_rows();
        let ntot = n + m;
        let mut cols: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
        for (i, row) in p.rows.iter().enumerate() {
            for &(j, a) in &row.coeffs {
                if a != 0.0 {
                    cols[j].push((i, a));
                }
            }
        }
        let mut lower = p.lower.clone();
        let mut upper = p.upper.clone();
        let mut senses = Vec::with_capacity(m);
        for (i, row) in p.rows.iter().enumerate() {
            cols.push(vec![(i, 1.0)]);
            let (lo, hi) = logical_bounds(row.cmp);
            lower.push(lo);
            upper.push(hi);
            senses.push(row.cmp);
        }
        let mut c = vec![0.0; ntot];
        c[..n].copy_from_slice(&p.c);
        let mut state = vec![VState::AtLower; ntot];
        let mut xval = vec![0.0; ntot];
        for j in 0..n {
            let (st, v) = seat_cold(c[j], lower[j], upper[j]);
            state[j] = st;
            xval[j] = v;
        }
        let mut basis = Vec::with_capacity(m);
        for i in 0..m {
            basis.push(n + i);
            state[n + i] = VState::Basic(i);
        }
        let fac = identity_factorization(m, &cols[n..]);
        RevisedSimplex {
            n,
            m,
            ntot,
            ccur: c.clone(),
            c,
            rhs: p.rows.iter().map(|r| r.rhs).collect(),
            coeffs_fp: p.rows.iter().map(|r| r.coeffs.clone()).collect(),
            cols,
            lower,
            upper,
            senses,
            basis,
            state,
            xval,
            xb: vec![0.0; m],
            d: vec![0.0; ntot],
            fac,
            pweight: vec![1.0; ntot],
            dweight: vec![1.0; m],
            work_row: vec![0.0; m],
            work_pos: vec![0.0; m],
            alpha_col: vec![0.0; m],
            alpha_row: vec![0.0; ntot],
            pivots: 0,
            flips: 0,
            refactors: 0,
        }
    }

    /// Whether `p` has the same constraint matrix this state was built
    /// for (same dimensions, senses, and exact coefficients) — the
    /// precondition of the incremental rung.
    pub(crate) fn matches(&self, p: &LpProblem) -> bool {
        if p.num_vars() != self.n || p.num_rows() != self.m {
            return false;
        }
        p.rows.iter().zip(self.senses.iter().zip(&self.coeffs_fp)).all(
            |(row, (cmp, coeffs))| row.cmp == *cmp && row.coeffs == *coeffs,
        )
    }

    /// Patch drifted data (objective, RHS, variable bounds) into the
    /// state without touching the factorization. Requires
    /// [`RevisedSimplex::matches`]; `false` on inverted bounds.
    pub(crate) fn patch(&mut self, p: &LpProblem) -> bool {
        for j in 0..self.n {
            if p.lower[j] > p.upper[j] {
                return false;
            }
            self.lower[j] = p.lower[j];
            self.upper[j] = p.upper[j];
        }
        self.c[..self.n].copy_from_slice(&p.c);
        for (dst, row) in self.rhs.iter_mut().zip(&p.rows) {
            *dst = row.rhs;
        }
        self.reseat_nonbasics();
        true
    }

    /// Rebuild the state for a *structurally changed* `p` (same
    /// dimensions), keeping the current basis and resting states, and
    /// refactorize from scratch. `false` when the dimensions differ or
    /// the retained basis is singular under the new coefficients — the
    /// caller then falls through to a cold build.
    pub(crate) fn rebuild(&mut self, p: &LpProblem) -> bool {
        if p.num_vars() != self.n || p.num_rows() != self.m {
            return false;
        }
        for j in 0..self.n {
            if p.lower[j] > p.upper[j] {
                return false;
            }
            self.cols[j].clear();
            self.lower[j] = p.lower[j];
            self.upper[j] = p.upper[j];
        }
        for (i, row) in p.rows.iter().enumerate() {
            for &(j, a) in &row.coeffs {
                if a != 0.0 {
                    self.cols[j].push((i, a));
                }
            }
            let (lo, hi) = logical_bounds(row.cmp);
            self.lower[self.n + i] = lo;
            self.upper[self.n + i] = hi;
            self.senses[i] = row.cmp;
            self.rhs[i] = row.rhs;
            self.coeffs_fp[i].clear();
            self.coeffs_fp[i].extend_from_slice(&row.coeffs);
        }
        self.c[..self.n].copy_from_slice(&p.c);
        self.reseat_nonbasics();
        self.refactorize()
    }

    /// Per-solve counters of the last [`RevisedSimplex::optimize`]:
    /// `(pivots, bound_flips, refactorizations)`.
    pub(crate) fn counters(&self) -> (usize, usize, usize) {
        (self.pivots, self.flips, self.refactors)
    }

    /// Read the solution out against `p` (structural values, true
    /// objective, pivot+flip count as `iterations`).
    pub(crate) fn solution(&self, p: &LpProblem) -> LpSolution {
        let x: Vec<f64> = (0..self.n).map(|j| self.value(j)).collect();
        LpSolution {
            status: LpStatus::Optimal,
            objective: p.objective(&x),
            x,
            iterations: self.pivots + self.flips,
            basis: Some(self.dense_basis()),
        }
    }

    /// Re-optimize from the current state: restore dual feasibility by
    /// seating/cost-shifting, run the dual simplex (Devex + BFRT) to
    /// primal feasibility, then a primal clean-up under true costs.
    /// `eta_cap` bounds the eta file before an in-solve refactorization.
    ///
    /// `Ok(status)` is a trustworthy terminal verdict (`Optimal`,
    /// `Infeasible`, `Unbounded`); `Err(NumericalFailure)` means the
    /// state went numerically bad and the caller should fall through.
    pub(crate) fn optimize(
        &mut self,
        eta_cap: usize,
    ) -> Result<LpStatus, NumericalFailure> {
        self.pivots = 0;
        self.flips = 0;
        self.refactors = 0;
        self.pweight.fill(1.0);
        self.dweight.fill(1.0);
        self.ccur.copy_from_slice(&self.c);
        self.compute_d();
        // Dual-feasibility restoration: boxed columns whose reduced
        // cost has the wrong sign flip to their other bound; columns
        // with no finite bound to flip to are cost-shifted (d forced to
        // 0) until the post-dual clean-up.
        let mut shifted = false;
        for j in 0..self.ntot {
            if self.lower[j] == self.upper[j] {
                continue;
            }
            match self.state[j] {
                VState::Basic(_) => {}
                VState::AtLower => {
                    let free = self.lower[j] == -INF && self.upper[j] == INF;
                    if free {
                        if self.d[j].abs() > OPT_TOL {
                            self.ccur[j] -= self.d[j];
                            self.d[j] = 0.0;
                            shifted = true;
                        }
                    } else if self.d[j] < -OPT_TOL {
                        if self.upper[j] < INF {
                            self.state[j] = VState::AtUpper;
                            self.xval[j] = self.upper[j];
                            self.flips += 1;
                        } else {
                            self.ccur[j] -= self.d[j];
                            self.d[j] = 0.0;
                            shifted = true;
                        }
                    }
                }
                VState::AtUpper => {
                    if self.d[j] > OPT_TOL {
                        if self.lower[j] > -INF {
                            self.state[j] = VState::AtLower;
                            self.xval[j] = self.lower[j];
                            self.flips += 1;
                        } else {
                            self.ccur[j] -= self.d[j];
                            self.d[j] = 0.0;
                            shifted = true;
                        }
                    }
                }
            }
        }
        self.compute_xb();
        let max_iter = 50 * (self.m + self.ntot) + 1000;
        match self.dual_phase(max_iter, eta_cap)? {
            LpStatus::Optimal => {}
            verdict => return Ok(verdict),
        }
        // Restore true costs and clean up with the primal simplex; when
        // nothing was shifted the maintained d is already the true
        // reduced-cost row and pricing certifies optimality directly.
        if shifted {
            self.ccur.copy_from_slice(&self.c);
            self.compute_d();
        }
        self.primal_phase(max_iter, eta_cap)
    }

    // ---- phases ----

    /// Dual simplex with Devex row pricing and the bound-flipping ratio
    /// test. Returns `Optimal` (meaning: primal feasible — the caller
    /// decides whether that is terminal) or `Infeasible` (genuine
    /// certificate).
    fn dual_phase(
        &mut self,
        max_iter: usize,
        eta_cap: usize,
    ) -> Result<LpStatus, NumericalFailure> {
        let mut stall = 0usize;
        let mut bad_pivots = 0usize;
        for _ in 0..max_iter {
            let bland = stall > 2 * (self.m + self.ntot);
            // Leaving row: worst violation scaled by the Devex weight.
            let mut leave: Option<(usize, f64, bool)> = None; // (pos, score, above)
            for r in 0..self.m {
                let b = self.basis[r];
                let (viol, above) = if self.xb[r] < self.lower[b] - FEAS_TOL {
                    (self.lower[b] - self.xb[r], false)
                } else if self.xb[r] > self.upper[b] + FEAS_TOL {
                    (self.xb[r] - self.upper[b], true)
                } else {
                    continue;
                };
                if bland {
                    leave = Some((r, viol, above));
                    break;
                }
                let score = viol * viol / self.dweight[r];
                let better = match leave {
                    None => true,
                    Some((_, s, _)) => score > s,
                };
                if better {
                    leave = Some((r, score, above));
                }
            }
            let Some((r, _, above)) = leave else {
                return Ok(LpStatus::Optimal); // primal feasible
            };

            // Pivot row α_r = eᵣᵀ B⁻¹ A over all nonbasic columns.
            self.compute_pivot_row(r);

            // Candidates: nonbasics whose admissible move direction
            // reduces the violation. Ratio |d_j/α_rj| is the step in
            // dual space before d_j changes sign.
            let mut cands: Vec<(usize, f64, f64)> = Vec::new(); // (j, |α|, ratio)
            for j in 0..self.ntot {
                if self.lower[j] == self.upper[j]
                    || matches!(self.state[j], VState::Basic(_))
                {
                    continue;
                }
                let alpha = self.alpha_row[j];
                if alpha.abs() <= PIVOT_TOL {
                    continue;
                }
                let free = self.lower[j] == -INF && self.upper[j] == INF;
                let admissible = match self.state[j] {
                    VState::AtLower => free || (above == (alpha > 0.0)),
                    VState::AtUpper => above == (alpha < 0.0),
                    VState::Basic(_) => false,
                };
                if admissible {
                    cands.push((j, alpha.abs(), (self.d[j] / alpha).abs()));
                }
            }
            if cands.is_empty() {
                // No admissible column: the violated basic sits at its
                // extremum over the whole nonbasic box — a genuine
                // primal-infeasibility certificate (no artificials).
                return Ok(LpStatus::Infeasible);
            }

            // Long-step walk: cross boxed breakpoints while the
            // violation survives the flip, flipping them in bulk;
            // the first breakpoint that would overshoot enters.
            let b = self.basis[r];
            let viol =
                if above { self.xb[r] - self.upper[b] } else { self.lower[b] - self.xb[r] };
            let enter;
            let mut to_flip: Vec<usize> = Vec::new();
            if bland {
                // Bland mode: smallest admissible index, no flips.
                enter = cands.iter().map(|&(j, _, _)| j).min().expect("nonempty");
            } else {
                cands.sort_by(|a, b| {
                    a.2.partial_cmp(&b.2)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then_with(|| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal))
                        .then_with(|| a.0.cmp(&b.0))
                });
                let mut rem = viol;
                let mut chosen = None;
                for &(j, absalpha, _) in &cands {
                    let boxed = self.lower[j] > -INF && self.upper[j] < INF;
                    let range = self.upper[j] - self.lower[j];
                    if boxed && rem - absalpha * range > FEAS_TOL {
                        rem -= absalpha * range;
                        to_flip.push(j);
                    } else {
                        chosen = Some(j);
                        break;
                    }
                }
                let Some(q) = chosen else {
                    // Every admissible column flipped and the violation
                    // survives: infeasible even at the box extremum.
                    return Ok(LpStatus::Infeasible);
                };
                enter = q;
            }

            // Apply the bulk flips with one combined ftran.
            if !to_flip.is_empty() {
                self.work_row.fill(0.0);
                for &j in &to_flip {
                    let delta = match self.state[j] {
                        VState::AtLower => {
                            self.state[j] = VState::AtUpper;
                            let d = self.upper[j] - self.xval[j];
                            self.xval[j] = self.upper[j];
                            d
                        }
                        VState::AtUpper => {
                            self.state[j] = VState::AtLower;
                            let d = self.lower[j] - self.xval[j];
                            self.xval[j] = self.lower[j];
                            d
                        }
                        VState::Basic(_) => unreachable!(),
                    };
                    for &(i, v) in &self.cols[j] {
                        self.work_row[i] += v * delta;
                    }
                }
                self.flips += to_flip.len();
                let mut b_in = std::mem::take(&mut self.work_row);
                let mut shift = std::mem::take(&mut self.work_pos);
                self.fac.ftran(&mut b_in, &mut shift);
                for (xbv, s) in self.xb.iter_mut().zip(&shift) {
                    *xbv -= s;
                }
                self.work_row = b_in;
                self.work_pos = shift;
            }

            // Entering column by ftran; the true pivot element must
            // agree with the pivot-row pass, else the factorization has
            // drifted — refactorize and retry.
            self.load_column(enter);
            let mut b_in = std::mem::take(&mut self.work_row);
            let mut acol = std::mem::take(&mut self.alpha_col);
            self.fac.ftran(&mut b_in, &mut acol);
            self.work_row = b_in;
            self.alpha_col = acol;
            let alpha_rq = self.alpha_col[r];
            if alpha_rq.abs() < PIVOT_TOL {
                bad_pivots += 1;
                if bad_pivots > 3 || !self.refresh_factorization() {
                    return Err(NumericalFailure);
                }
                continue;
            }
            bad_pivots = 0;

            let target = if above { self.upper[b] } else { self.lower[b] };
            let delta_x = (self.xb[r] - target) / alpha_rq;
            let ratio = (self.d[enter] / alpha_rq).abs();
            if ratio <= OPT_TOL {
                stall += 1;
            } else {
                stall = 0;
            }
            let leaving = b;
            self.apply_pivot(r, enter, delta_x, target, above);
            // Dual Devex: weights ride on the ftran'd entering column.
            let wr = self.dweight[r];
            let arq2 = alpha_rq * alpha_rq;
            for i in 0..self.m {
                if i != r {
                    let a = self.alpha_col[i];
                    if a != 0.0 {
                        let cand = (a * a / arq2) * wr;
                        if cand > self.dweight[i] {
                            self.dweight[i] = cand;
                        }
                    }
                }
            }
            self.dweight[r] = (wr / arq2).max(1.0);
            self.post_pivot_update(r, enter, leaving, alpha_rq, eta_cap)?;
        }
        Err(NumericalFailure)
    }

    /// Primal simplex (phase 2) with Devex pricing and the
    /// bounded-variable ratio test (including entering-variable bound
    /// flips). Requires a primal-feasible basis. Returns `Optimal` or
    /// `Unbounded`.
    fn primal_phase(
        &mut self,
        max_iter: usize,
        eta_cap: usize,
    ) -> Result<LpStatus, NumericalFailure> {
        let mut stall = 0usize;
        for _ in 0..max_iter {
            let bland = stall > 2 * (self.m + self.ntot);
            // Pricing: Devex score d²/w over improving candidates.
            let mut best: Option<(usize, f64, f64)> = None; // (j, dir, score)
            for j in 0..self.ntot {
                let Some(dir) = self.improving_direction(j) else {
                    continue;
                };
                if bland {
                    best = Some((j, dir, 0.0));
                    break;
                }
                let score = self.d[j] * self.d[j] / self.pweight[j];
                let better = match best {
                    None => true,
                    Some((_, _, s)) => score > s,
                };
                if better {
                    best = Some((j, dir, score));
                }
            }
            let Some((q, dir, _)) = best else {
                return Ok(LpStatus::Optimal);
            };

            // Entering column and ratio test.
            self.load_column(q);
            let mut b_in = std::mem::take(&mut self.work_row);
            let mut acol = std::mem::take(&mut self.alpha_col);
            self.fac.ftran(&mut b_in, &mut acol);
            self.work_row = b_in;
            self.alpha_col = acol;

            let own_range = self.upper[q] - self.lower[q];
            let mut t_star = own_range;
            let mut leave: Option<(usize, bool)> = None; // (pos, hits upper)
            for i in 0..self.m {
                let rate = self.alpha_col[i] * dir;
                let bi = self.basis[i];
                if rate > PIVOT_TOL {
                    if self.lower[bi] > -INF {
                        let t = (self.xb[i] - self.lower[bi]) / rate;
                        if t < t_star - FEAS_TOL
                            || (bland && t <= t_star + FEAS_TOL && leave.is_none())
                        {
                            t_star = t.max(0.0);
                            leave = Some((i, false));
                        }
                    }
                } else if rate < -PIVOT_TOL && self.upper[bi] < INF {
                    let t = (self.upper[bi] - self.xb[i]) / (-rate);
                    if t < t_star - FEAS_TOL
                        || (bland && t <= t_star + FEAS_TOL && leave.is_none())
                    {
                        t_star = t.max(0.0);
                        leave = Some((i, true));
                    }
                }
            }
            if t_star == INF {
                return Ok(LpStatus::Unbounded);
            }
            if t_star <= FEAS_TOL {
                stall += 1;
            } else {
                stall = 0;
            }

            let delta = dir * t_star;
            match leave {
                None => {
                    // Entering variable flips to its other bound.
                    for i in 0..self.m {
                        let a = self.alpha_col[i];
                        if a != 0.0 {
                            self.xb[i] -= a * delta;
                        }
                    }
                    self.xval[q] += delta;
                    self.state[q] =
                        if dir > 0.0 { VState::AtUpper } else { VState::AtLower };
                    self.flips += 1;
                }
                Some((r, hits_upper)) => {
                    let b = self.basis[r];
                    let target = if hits_upper { self.upper[b] } else { self.lower[b] };
                    let alpha_rq = self.alpha_col[r];
                    if alpha_rq.abs() < PIVOT_TOL {
                        if !self.refresh_factorization() {
                            return Err(NumericalFailure);
                        }
                        continue;
                    }
                    let entering_value = self.xval[q] + delta;
                    for i in 0..self.m {
                        let a = self.alpha_col[i];
                        if a != 0.0 {
                            self.xb[i] -= a * delta;
                        }
                    }
                    self.xval[b] = target;
                    self.state[b] =
                        if hits_upper { VState::AtUpper } else { VState::AtLower };
                    self.basis[r] = q;
                    self.state[q] = VState::Basic(r);
                    self.xb[r] = entering_value;
                    self.pivots += 1;
                    // Pivot row (against the pre-pivot factorization)
                    // for the d update and primal Devex. The entering
                    // q is already marked basic, so its entry is stale:
                    // use the ftran'd pivot element directly.
                    self.compute_pivot_row(r);
                    let arq = alpha_rq;
                    let theta = self.d[q] / arq;
                    let wq = self.pweight[q];
                    let arq2 = arq * arq;
                    for j in 0..self.ntot {
                        if matches!(self.state[j], VState::Basic(_)) {
                            continue;
                        }
                        let a = self.alpha_row[j];
                        if a != 0.0 {
                            self.d[j] -= theta * a;
                            let cand = (a * a / arq2) * wq;
                            if cand > self.pweight[j] {
                                self.pweight[j] = cand;
                            }
                        }
                    }
                    self.d[b] = -theta;
                    self.d[q] = 0.0;
                    self.pweight[b] = (wq / arq2).max(1.0);
                    if !self.fac.push_eta(r, &self.alpha_col)
                        || self.fac.eta_len() > eta_cap
                    {
                        if !self.refresh_factorization() {
                            return Err(NumericalFailure);
                        }
                    }
                }
            }
        }
        Err(NumericalFailure)
    }

    // ---- shared pivot mechanics ----

    /// Apply the dual pivot: step the basics along the entering column,
    /// seat the leaving variable on its violated bound, swap the basis.
    fn apply_pivot(&mut self, r: usize, q: usize, delta_x: f64, target: f64, above: bool) {
        let b = self.basis[r];
        let entering_value = self.xval[q] + delta_x;
        for i in 0..self.m {
            let a = self.alpha_col[i];
            if a != 0.0 {
                self.xb[i] -= a * delta_x;
            }
        }
        self.xval[b] = target;
        self.state[b] = if above { VState::AtUpper } else { VState::AtLower };
        self.basis[r] = q;
        self.state[q] = VState::Basic(r);
        self.xb[r] = entering_value;
        self.pivots += 1;
    }

    /// After a dual pivot: update the reduced-cost row from the pivot
    /// row (already in `alpha_row`), then record the eta / refactorize.
    /// `leaving`'s entry in `alpha_row` is stale (it was basic when the
    /// row was computed, and α_r,leaving ≡ 1), so it is set explicitly.
    fn post_pivot_update(
        &mut self,
        r: usize,
        q: usize,
        leaving: usize,
        alpha_rq: f64,
        eta_cap: usize,
    ) -> Result<(), NumericalFailure> {
        let theta = self.d[q] / alpha_rq;
        for j in 0..self.ntot {
            if j == leaving || matches!(self.state[j], VState::Basic(_)) {
                continue;
            }
            let a = self.alpha_row[j];
            if a != 0.0 {
                self.d[j] -= theta * a;
            }
        }
        self.d[leaving] = -theta;
        self.d[q] = 0.0;
        if (!self.fac.push_eta(r, &self.alpha_col) || self.fac.eta_len() > eta_cap)
            && !self.refresh_factorization()
        {
            return Err(NumericalFailure);
        }
        Ok(())
    }

    /// Refactorize from the current basis and recompute `xb` and `d`
    /// from scratch (the drift-scrubbing refresh). `false` on a
    /// singular basis.
    fn refresh_factorization(&mut self) -> bool {
        if !self.refactorize() {
            return false;
        }
        self.refactors += 1;
        self.compute_xb();
        self.compute_d();
        true
    }

    /// Rebuild the LU from the current basis columns. Does not bump the
    /// refactorization counter: in-solve refreshes count through
    /// [`RevisedSimplex::refresh_factorization`], while the warm/cold
    /// rungs' initial factorizations are counted by the ladder (the
    /// per-solve counters reset at [`RevisedSimplex::optimize`] entry).
    fn refactorize(&mut self) -> bool {
        let cols: Vec<&[(usize, f64)]> =
            self.basis.iter().map(|&v| self.cols[v].as_slice()).collect();
        match Factorization::factorize(self.m, &cols) {
            Some(f) => {
                self.fac = f;
                true
            }
            None => false,
        }
    }

    // ---- linear algebra helpers ----

    /// `xb = B⁻¹ (b − Σ_{nonbasic j} A_j x̄_j)`.
    fn compute_xb(&mut self) {
        self.work_row.copy_from_slice(&self.rhs);
        for j in 0..self.ntot {
            if matches!(self.state[j], VState::Basic(_)) || self.xval[j] == 0.0 {
                continue;
            }
            let v = self.xval[j];
            for &(i, a) in &self.cols[j] {
                self.work_row[i] -= a * v;
            }
        }
        let mut b_in = std::mem::take(&mut self.work_row);
        let mut out = std::mem::take(&mut self.xb);
        self.fac.ftran(&mut b_in, &mut out);
        self.work_row = b_in;
        self.xb = out;
    }

    /// `d_j = c_j − yᵀ A_j` with `y = B⁻ᵀ c_B`, under the working
    /// costs `ccur`.
    fn compute_d(&mut self) {
        for (pos, w) in self.work_pos.iter_mut().enumerate() {
            *w = self.ccur[self.basis[pos]];
        }
        let mut c_in = std::mem::take(&mut self.work_pos);
        let mut y = std::mem::take(&mut self.work_row);
        self.fac.btran(&mut c_in, &mut y);
        self.work_pos = c_in;
        for j in 0..self.ntot {
            if matches!(self.state[j], VState::Basic(_)) {
                self.d[j] = 0.0;
                continue;
            }
            let mut z = 0.0;
            for &(i, v) in &self.cols[j] {
                z += y[i] * v;
            }
            self.d[j] = self.ccur[j] - z;
        }
        self.work_row = y;
    }

    /// `alpha_row = eᵣᵀ B⁻¹ A` for every nonbasic column (basic entries
    /// are left stale and must not be read).
    fn compute_pivot_row(&mut self, r: usize) {
        self.work_pos.fill(0.0);
        self.work_pos[r] = 1.0;
        let mut c_in = std::mem::take(&mut self.work_pos);
        let mut rho = std::mem::take(&mut self.work_row);
        self.fac.btran(&mut c_in, &mut rho);
        self.work_pos = c_in;
        for j in 0..self.ntot {
            if matches!(self.state[j], VState::Basic(_)) {
                continue;
            }
            let mut z = 0.0;
            for &(i, v) in &self.cols[j] {
                z += rho[i] * v;
            }
            self.alpha_row[j] = z;
        }
        self.work_row = rho;
    }

    /// Scatter column `j` densely into `work_row` (for an ftran).
    fn load_column(&mut self, j: usize) {
        self.work_row.fill(0.0);
        for &(i, v) in &self.cols[j] {
            self.work_row[i] = v;
        }
    }

    /// Improving direction of nonbasic `j` under the maintained `d`
    /// (mirrors the dense core's `entering_candidate`).
    fn improving_direction(&self, j: usize) -> Option<f64> {
        if self.lower[j] == self.upper[j] {
            return None;
        }
        match self.state[j] {
            VState::Basic(_) => None,
            VState::AtLower => {
                let free = self.lower[j] == -INF && self.upper[j] == INF;
                if self.d[j] < -OPT_TOL {
                    Some(1.0)
                } else if free && self.d[j] > OPT_TOL {
                    Some(-1.0)
                } else {
                    None
                }
            }
            VState::AtUpper => {
                if self.d[j] > OPT_TOL {
                    Some(-1.0)
                } else {
                    None
                }
            }
        }
    }

    /// Re-seat every nonbasic on the (possibly moved) bounds, keeping
    /// the previous bound choice where still available.
    fn reseat_nonbasics(&mut self) {
        for j in 0..self.ntot {
            if matches!(self.state[j], VState::Basic(_)) {
                continue;
            }
            let (l, u) = (self.lower[j], self.upper[j]);
            let prefer_upper = matches!(self.state[j], VState::AtUpper);
            let (st, v) = if l == u {
                (VState::AtLower, l)
            } else if prefer_upper && u < INF {
                (VState::AtUpper, u)
            } else if l > -INF {
                (VState::AtLower, l)
            } else if u < INF {
                (VState::AtUpper, u)
            } else {
                (VState::AtLower, 0.0)
            };
            self.state[j] = st;
            self.xval[j] = v;
        }
    }

    fn value(&self, j: usize) -> f64 {
        match self.state[j] {
            VState::Basic(r) => self.xb[r],
            _ => self.xval[j],
        }
    }

    /// Map the sparse basis into the dense `[structural | slack |
    /// artificial]` snapshot format, so
    /// [`solve_from_basis`](super::simplex::solve_from_basis) can
    /// warm-start from a persistent solver's state. Le/Ge logicals map
    /// to the row's slack column; an Eq logical basic at ~0 maps to the
    /// row's artificial (the redundant-row case).
    pub(crate) fn dense_basis(&self) -> Basis {
        // Slack column index per row in the dense layout (Le/Ge only).
        let mut slack_of = vec![usize::MAX; self.m];
        let mut next = self.n;
        for (i, cmp) in self.senses.iter().enumerate() {
            if matches!(cmp, Cmp::Le | Cmp::Ge) {
                slack_of[i] = next;
                next += 1;
            }
        }
        let n_struct_slack = next;
        let dense_ntot = n_struct_slack + self.m;
        let map = |v: usize| -> usize {
            if v < self.n {
                v
            } else {
                let row = v - self.n;
                match self.senses[row] {
                    Cmp::Le | Cmp::Ge => slack_of[row],
                    Cmp::Eq => n_struct_slack + row,
                }
            }
        };
        let row_to_var: Vec<usize> = self.basis.iter().map(|&v| map(v)).collect();
        let mut at_upper = vec![false; dense_ntot];
        for j in 0..self.n {
            if matches!(self.state[j], VState::AtUpper) {
                at_upper[j] = true;
            }
        }
        // Slacks/artificials rest at lower (0) in the dense layout: a
        // nonbasic Le logical sits at 0 (= slack lower) and a nonbasic
        // Ge logical at 0 (its upper) maps to the negated slack's lower.
        Basis { row_to_var, at_upper, n_struct_slack, ntot: dense_ntot }
    }
}

/// Logical-variable bounds per row sense (row form `A x + y = b`).
fn logical_bounds(cmp: Cmp) -> (f64, f64) {
    match cmp {
        Cmp::Le => (0.0, INF),
        Cmp::Ge => (-INF, 0.0),
        Cmp::Eq => (0.0, 0.0),
    }
}

/// Dual-feasible cold seat: rest where the cost sign wants the
/// variable, falling back to any finite bound (or 0 for free columns).
fn seat_cold(c: f64, l: f64, u: f64) -> (VState, f64) {
    if l == u {
        return (VState::AtLower, l);
    }
    let (prefer_lower, prefer_upper) = if c > 0.0 {
        (true, false)
    } else if c < 0.0 {
        (false, true)
    } else {
        (l > -INF, l == -INF && u < INF)
    };
    if prefer_lower && l > -INF {
        (VState::AtLower, l)
    } else if prefer_upper && u < INF {
        (VState::AtUpper, u)
    } else if l > -INF {
        (VState::AtLower, l)
    } else if u < INF {
        (VState::AtUpper, u)
    } else {
        (VState::AtLower, 0.0)
    }
}

/// The all-logical basis factorizes trivially (every column is a
/// singleton); build it through the standard path for uniformity.
fn identity_factorization(m: usize, logical_cols: &[Vec<(usize, f64)>]) -> Factorization {
    let refs: Vec<&[(usize, f64)]> =
        logical_cols.iter().map(|c| c.as_slice()).collect();
    Factorization::factorize(m, &refs).expect("unit logical basis cannot be singular")
}
