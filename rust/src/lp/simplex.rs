//! From-scratch linear-programming solver: two-phase primal simplex with
//! **bounded variables** (l ≤ x ≤ u handled implicitly, not as rows).
//!
//! The paper solves its freeze-ratio LP with "standard linear programming
//! solvers" (§3.2.2, citing Karmarkar's interior-point method for the
//! polynomial-time claim). No solver crate exists in the offline image,
//! so this module implements the classic bounded-variable simplex — exact
//! on the paper's problem sizes (|V| ≈ 2·M·S + 2 nodes → a few hundred
//! variables and constraints), and fast enough to re-solve per batch if a
//! schedule were elastic (see benches/perf_micro.rs).
//!
//! Method: rows are converted to equalities with slack variables; phase 1
//! minimizes the sum of artificial variables from an identity basis;
//! phase 2 minimizes the true objective. Nonbasic variables rest at a
//! finite bound; the ratio test accounts for basic variables hitting
//! either bound and for bound flips of the entering variable. Bland's
//! rule kicks in after a stall to guarantee termination.
//!
//! Hot-path layout: the tableau `B⁻¹A` is one row-major `Vec<f64>`
//! (m × ntot) rather than nested `Vec`s, pivots go through a scratch
//! pivot-row buffer, and pricing uses Dantzig rule over a rotating
//! partial window so one pivot no longer scans every column of large
//! problems. [`solve_from_basis`] warm-starts from a previous optimal
//! [`Basis`]: re-solves that differ only in a few objective/RHS entries
//! converge in a handful of pivots instead of replaying both phases.
//!
//! [`PersistentSimplex`] goes one step further for the online-replan
//! loop: it runs the **sparse revised simplex** in
//! [`super::revised`] — a sparse LU factorization of the basis
//! ([`super::factor`], Markowitz-ordered with product-form eta updates
//! per pivot and periodic refactorization), Devex pricing for both the
//! primal and dual phases, and a long-step bound-flipping dual ratio
//! test — behind the same incremental → warm basis → cold fallback
//! ladder. A re-solve whose constraint matrix is unchanged (only RHS,
//! objective, or variable bounds drifted) patches the new data through
//! the live factorization and repairs in O(m + nnz) per pivot; the
//! dense two-phase solver in this file remains the reference oracle
//! (and the persistent path's last-resort safety net). Interval and
//! drift tolerance are configurable via [`SimplexConfig`]; per-solve
//! pivot/flip/refactorization counters surface as [`SolveStats`].

use super::revised::RevisedSimplex;

/// Shorthand for an unbounded variable bound.
pub const INF: f64 = f64::INFINITY;

/// Comparison operator of a row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmp {
    /// `≤ rhs`.
    Le,
    /// `≥ rhs`.
    Ge,
    /// `= rhs`.
    Eq,
}

/// One sparse constraint row: `Σ coeffs · x  cmp  rhs`.
#[derive(Clone, Debug)]
pub struct LpRow {
    /// Sparse (column, coefficient) pairs.
    pub coeffs: Vec<(usize, f64)>,
    /// Row sense.
    pub cmp: Cmp,
    /// Right-hand side.
    pub rhs: f64,
}

/// `min cᵀx  s.t.  rows,  lower ≤ x ≤ upper`.
#[derive(Clone, Debug, Default)]
pub struct LpProblem {
    /// Objective coefficients.
    pub c: Vec<f64>,
    /// Per-variable lower bounds.
    pub lower: Vec<f64>,
    /// Per-variable upper bounds ([`INF`] allowed).
    pub upper: Vec<f64>,
    /// Constraint rows.
    pub rows: Vec<LpRow>,
}

impl LpProblem {
    /// An empty problem.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a variable, returning its index.
    pub fn add_var(&mut self, cost: f64, lower: f64, upper: f64) -> usize {
        assert!(lower <= upper, "lower {lower} > upper {upper}");
        self.c.push(cost);
        self.lower.push(lower);
        self.upper.push(upper);
        self.c.len() - 1
    }

    /// Add a constraint row over existing variables.
    pub fn add_row(&mut self, coeffs: Vec<(usize, f64)>, cmp: Cmp, rhs: f64) {
        for &(j, _) in &coeffs {
            assert!(j < self.c.len(), "row references unknown variable {j}");
        }
        self.rows.push(LpRow { coeffs, cmp, rhs });
    }

    /// Number of structural variables.
    pub fn num_vars(&self) -> usize {
        self.c.len()
    }

    /// Number of constraint rows.
    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Check a candidate point against all rows and bounds.
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        if x.len() != self.num_vars() {
            return false;
        }
        for j in 0..x.len() {
            if x[j] < self.lower[j] - tol || x[j] > self.upper[j] + tol {
                return false;
            }
        }
        for row in &self.rows {
            let lhs: f64 = row.coeffs.iter().map(|&(j, a)| a * x[j]).sum();
            let ok = match row.cmp {
                Cmp::Le => lhs <= row.rhs + tol,
                Cmp::Ge => lhs >= row.rhs - tol,
                Cmp::Eq => (lhs - row.rhs).abs() <= tol,
            };
            if !ok {
                return false;
            }
        }
        true
    }

    /// Evaluate `cᵀx`.
    pub fn objective(&self, x: &[f64]) -> f64 {
        self.c.iter().zip(x).map(|(c, x)| c * x).sum()
    }
}

/// Terminal state of a simplex solve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LpStatus {
    /// An optimal basic solution was found.
    Optimal,
    /// No point satisfies the rows and bounds.
    Infeasible,
    /// The objective decreases without bound.
    Unbounded,
    /// The pivot budget was exhausted (numerically hostile input).
    IterationLimit,
}

/// A basis snapshot of a solved LP, sufficient to warm-start a re-solve
/// of a structurally identical problem (same variables, same rows in the
/// same order with the same comparison kinds; objective coefficients,
/// RHS values, and bounds may change).
#[derive(Clone, Debug)]
pub struct Basis {
    /// Row → column in the `[structural | slack | artificial]` layout.
    pub row_to_var: Vec<usize>,
    /// Nonbasic columns resting at their upper bound (len `ntot`).
    pub at_upper: Vec<bool>,
    /// Structural + slack column count (artificials start here).
    pub n_struct_slack: usize,
    /// Total column count.
    pub ntot: usize,
}

/// Result of [`solve`] / [`solve_from_basis`].
#[derive(Clone, Debug)]
pub struct LpSolution {
    /// How the solve terminated.
    pub status: LpStatus,
    /// Values of the structural variables.
    pub x: Vec<f64>,
    /// Objective value at `x`.
    pub objective: f64,
    /// Simplex pivots performed.
    pub iterations: usize,
    /// Final basis (present on `Optimal`), reusable via
    /// [`solve_from_basis`].
    pub basis: Option<Basis>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum VarState {
    Basic(usize),
    AtLower,
    AtUpper,
}

const FEAS_TOL: f64 = 1e-9;
const OPT_TOL: f64 = 1e-9;
const PIVOT_TOL: f64 = 1e-10;
/// Minimum Dantzig pricing window; the effective window is
/// `max(PRICE_WINDOW, col_limit / 8)`, so small problems degrade to the
/// exact full-scan Dantzig rule.
const PRICE_WINDOW: usize = 64;
/// Basic-value tolerance when validating a warm-started basis.
const WARM_TOL: f64 = 1e-7;

#[derive(Clone, Debug)]
struct Tableau {
    /// Dense row-major B⁻¹·A, m × ntot in one allocation.
    a: Vec<f64>,
    /// Scratch copy of the (scaled) pivot row, reused across pivots.
    pivot_row: Vec<f64>,
    /// Current values of basic variables (in bound-shifted space: actual
    /// values, with nonbasics at their bounds).
    xb: Vec<f64>,
    /// Reduced-cost row d_j = c_j − c_Bᵀ B⁻¹ A_j (phase-dependent c).
    d: Vec<f64>,
    /// Basis: row → var.
    basis: Vec<usize>,
    state: Vec<VarState>,
    lower: Vec<f64>,
    upper: Vec<f64>,
    /// Current nonbasic resting value of each variable.
    xval: Vec<f64>,
    m: usize,
    ntot: usize,
    iterations: usize,
    /// Rotating start of the partial-pricing window.
    price_cursor: usize,
}

impl Tableau {
    fn value(&self, j: usize) -> f64 {
        match self.state[j] {
            VarState::Basic(r) => self.xb[r],
            _ => self.xval[j],
        }
    }

    /// Improving direction and score of nonbasic column `j`, if any.
    /// score = rate of objective decrease per unit step (> 0 ⇒
    /// improving). AtLower moves up (rate −d_j), AtUpper moves down
    /// (rate +d_j); free nonbasics (l = −∞, u = +∞, resting at 0 with
    /// AtLower state) may move either way.
    #[inline]
    fn entering_candidate(&self, j: usize, fixed: &[bool]) -> Option<(f64, f64)> {
        if fixed[j] || self.lower[j] == self.upper[j] {
            return None;
        }
        match self.state[j] {
            VarState::Basic(_) => None,
            VarState::AtLower => {
                let free = self.lower[j] == -INF && self.upper[j] == INF;
                if self.d[j] < -OPT_TOL {
                    Some((1.0, -self.d[j]))
                } else if free && self.d[j] > OPT_TOL {
                    Some((-1.0, self.d[j]))
                } else {
                    None
                }
            }
            VarState::AtUpper => {
                if self.d[j] > OPT_TOL {
                    Some((-1.0, self.d[j]))
                } else {
                    None
                }
            }
        }
    }

    /// Pick an entering variable. Bland mode scans from column 0 and
    /// takes the first improving index (termination guarantee); normal
    /// mode runs Dantzig's rule over a rotating partial window, only
    /// expanding the scan when the window holds no improving column —
    /// optimality is still certified by a full scan coming up empty.
    fn price(&mut self, fixed: &[bool], col_limit: usize, bland: bool) -> Option<(usize, f64)> {
        if col_limit == 0 {
            return None;
        }
        if bland {
            for j in 0..col_limit {
                if let Some((dir, _)) = self.entering_candidate(j, fixed) {
                    return Some((j, dir));
                }
            }
            return None;
        }
        let window = PRICE_WINDOW.max(col_limit / 8);
        let mut start = self.price_cursor % col_limit;
        let mut scanned = 0usize;
        while scanned < col_limit {
            let count = window.min(col_limit - scanned);
            let mut best: Option<(usize, f64, f64)> = None;
            for k in 0..count {
                let mut j = start + k;
                if j >= col_limit {
                    j -= col_limit;
                }
                if let Some((dir, score)) = self.entering_candidate(j, fixed) {
                    if best.map_or(true, |(_, _, s)| score > s) {
                        best = Some((j, dir, score));
                    }
                }
            }
            if let Some((j, dir, _)) = best {
                // Sticky window: keep pricing here while it still pays.
                self.price_cursor = start;
                return Some((j, dir));
            }
            scanned += count;
            start = (start + count) % col_limit;
        }
        None
    }

    /// Pivot row `r` on column `j`, updating columns `0..col_limit` of
    /// every row plus the reduced-cost row.
    ///
    /// One-shot phase-2 solves pass the structural+slack count (the
    /// artificial columns are pinned to zero and never read again);
    /// persistent solves pass `ntot` so the artificial block — which
    /// holds the running basis inverse `B⁻¹` (see
    /// [`PersistentSimplex`]) — stays current across pivots.
    fn pivot(&mut self, r: usize, j: usize, col_limit: usize) {
        let ntot = self.ntot;
        let base = r * ntot;
        let piv = self.a[base + j];
        debug_assert!(piv.abs() > PIVOT_TOL, "tiny pivot {piv}");
        let inv = 1.0 / piv;
        for v in self.a[base..base + col_limit].iter_mut() {
            *v *= inv;
        }
        self.pivot_row[..col_limit].copy_from_slice(&self.a[base..base + col_limit]);
        for i in 0..self.m {
            if i == r {
                continue;
            }
            let row_base = i * ntot;
            let f = self.a[row_base + j];
            if f != 0.0 {
                let row = &mut self.a[row_base..row_base + col_limit];
                for (rv, pv) in row.iter_mut().zip(&self.pivot_row[..col_limit]) {
                    *rv -= f * pv;
                }
                self.a[row_base + j] = 0.0; // exact zero
            }
        }
        let f = self.d[j];
        if f != 0.0 {
            for (dv, pv) in self.d[..col_limit].iter_mut().zip(&self.pivot_row[..col_limit]) {
                *dv -= f * pv;
            }
            self.d[j] = 0.0;
        }
    }

    /// One simplex phase: minimize the cost vector already loaded in `d`.
    /// `col_limit` bounds the columns touched by pricing; `update_limit`
    /// bounds the columns pivots rewrite (one-shot phase 2 passes the
    /// structural+slack count for both: artificial columns are pinned to
    /// zero and never read again, so updating them is wasted work —
    /// persistent solves pass `ntot` as `update_limit` to keep the
    /// stored basis inverse current). Returns Ok(()) at optimality,
    /// Err(Unbounded) otherwise.
    fn optimize(
        &mut self,
        max_iter: usize,
        fixed: &[bool],
        col_limit: usize,
        update_limit: usize,
    ) -> Result<(), LpStatus> {
        let mut stall = 0usize;
        for _ in 0..max_iter {
            self.iterations += 1;
            let bland = stall > 2 * (self.m + self.ntot);
            let Some((j, dir)) = self.price(fixed, col_limit, bland) else {
                return Ok(()); // optimal
            };

            // --- ratio test ---
            // x_j moves by dir·t; basic i moves by −a[i][j]·dir·t.
            let own_range = self.upper[j] - self.lower[j]; // may be INF
            let mut t_star = own_range;
            let mut leave: Option<(usize, VarState)> = None; // (row, bound hit)
            for i in 0..self.m {
                let rate = self.a[i * self.ntot + j] * dir; // x_b[i] decreases at `rate`
                let bi = self.basis[i];
                if rate > PIVOT_TOL {
                    if self.lower[bi] > -INF {
                        let t = (self.xb[i] - self.lower[bi]) / rate;
                        if t < t_star - FEAS_TOL
                            || (bland && t <= t_star + FEAS_TOL && leave.is_none())
                        {
                            t_star = t.max(0.0);
                            leave = Some((i, VarState::AtLower));
                        }
                    }
                } else if rate < -PIVOT_TOL && self.upper[bi] < INF {
                    let t = (self.upper[bi] - self.xb[i]) / (-rate);
                    if t < t_star - FEAS_TOL || (bland && t <= t_star + FEAS_TOL && leave.is_none())
                    {
                        t_star = t.max(0.0);
                        leave = Some((i, VarState::AtUpper));
                    }
                }
            }

            if t_star == INF {
                return Err(LpStatus::Unbounded);
            }

            // --- apply step ---
            // Degenerate steps make no objective progress; count them and
            // fall back to Bland's rule to guarantee termination.
            if t_star <= FEAS_TOL {
                stall += 1;
            } else {
                stall = 0;
            }

            match leave {
                None => {
                    // Bound flip: entering variable crosses to its other
                    // bound; basics shift, basis unchanged.
                    let delta = dir * t_star;
                    for i in 0..self.m {
                        self.xb[i] -= self.a[i * self.ntot + j] * delta;
                    }
                    self.xval[j] += delta;
                    self.state[j] = if dir > 0.0 { VarState::AtUpper } else { VarState::AtLower };
                }
                Some((r, bound_hit)) => {
                    // Update basic values for the step, then pivot.
                    let delta = dir * t_star;
                    for i in 0..self.m {
                        self.xb[i] -= self.a[i * self.ntot + j] * delta;
                    }
                    let entering_value = self.xval[j] + delta;
                    let leaving = self.basis[r];
                    // Snap the leaving variable exactly onto its bound.
                    let leave_val = match bound_hit {
                        VarState::AtLower => self.lower[leaving],
                        VarState::AtUpper => self.upper[leaving],
                        VarState::Basic(_) => unreachable!(),
                    };
                    self.xval[leaving] = leave_val;
                    self.state[leaving] = bound_hit;

                    self.pivot(r, j, update_limit);
                    self.basis[r] = j;
                    self.state[j] = VarState::Basic(r);
                    self.xb[r] = entering_value;
                }
            }
        }
        Err(LpStatus::IterationLimit)
    }

    /// Phase-2 reduced costs from the real objective:
    /// d_j = c_j − c_Bᵀ B⁻¹ A_j (B⁻¹A is the current tableau).
    fn load_phase2_costs(&mut self, c: &[f64]) {
        let mut c2 = vec![0.0f64; self.ntot];
        c2[..c.len()].copy_from_slice(c);
        let cb: Vec<f64> = self.basis.iter().map(|&b| c2[b]).collect();
        for j in 0..self.ntot {
            if matches!(self.state[j], VarState::Basic(_)) {
                self.d[j] = 0.0;
                continue;
            }
            let mut z = 0.0;
            for i in 0..self.m {
                if cb[i] != 0.0 {
                    z += cb[i] * self.a[i * self.ntot + j];
                }
            }
            self.d[j] = c2[j] - z;
        }
    }

    fn extract_basis(&self, n_struct_slack: usize) -> Basis {
        Basis {
            row_to_var: self.basis.clone(),
            at_upper: self.state.iter().map(|s| matches!(s, VarState::AtUpper)).collect(),
            n_struct_slack,
            ntot: self.ntot,
        }
    }
}

/// Column layout shared by cold and warm solves:
/// `[structural 0..n | slack n..n_struct_slack | artificial .. ntot]`.
struct Layout {
    lower: Vec<f64>,
    upper: Vec<f64>,
    /// col → (row, coef) for structural and slack columns.
    cols: Vec<Vec<(usize, f64)>>,
    n_struct_slack: usize,
    ntot: usize,
}

fn build_layout(p: &LpProblem) -> Layout {
    let m = p.num_rows();
    let n = p.num_vars();
    let mut lower = p.lower.clone();
    let mut upper = p.upper.clone();
    let mut cols: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
    for (i, row) in p.rows.iter().enumerate() {
        for &(j, a) in &row.coeffs {
            if a != 0.0 {
                cols[j].push((i, a));
            }
        }
    }
    for (i, row) in p.rows.iter().enumerate() {
        match row.cmp {
            Cmp::Le => {
                lower.push(0.0);
                upper.push(INF);
                cols.push(vec![(i, 1.0)]);
            }
            Cmp::Ge => {
                lower.push(0.0);
                upper.push(INF);
                cols.push(vec![(i, -1.0)]);
            }
            Cmp::Eq => {}
        }
    }
    let n_struct_slack = lower.len();
    // Artificials: one per row (identity basis for phase 1; pinned to
    // zero and basic-only-on-redundant-rows in warm starts).
    for _ in 0..m {
        lower.push(0.0);
        upper.push(INF);
    }
    let ntot = lower.len();
    Layout { lower, upper, cols, n_struct_slack, ntot }
}

/// Solve an [`LpProblem`] from scratch. Deterministic; exact up to f64
/// tolerance.
pub fn solve(p: &LpProblem) -> LpSolution {
    solve_with(p, None)
}

/// Solve warm-started from a previous optimal basis of a structurally
/// identical problem (same variable count, same rows in the same order
/// with the same comparison kinds). Falls back to a cold [`solve`] when
/// the basis no longer fits (dimension mismatch, singular under the new
/// data, or primal-infeasible after an RHS change) — the result is
/// always correct; warmth only affects iteration count.
pub fn solve_from_basis(p: &LpProblem, basis: &Basis) -> LpSolution {
    solve_with(p, Some(basis))
}

fn solve_with(p: &LpProblem, warm: Option<&Basis>) -> LpSolution {
    let n = p.num_vars();
    let m = p.num_rows();
    if m == 0 {
        // Bound-only problem: each variable sits at whichever finite
        // bound minimizes its cost term.
        let mut x = vec![0.0; n];
        for j in 0..n {
            x[j] = trivially_best(p.c[j], p.lower[j], p.upper[j]);
        }
        let feasible = x.iter().all(|v| v.is_finite());
        return LpSolution {
            status: if feasible { LpStatus::Optimal } else { LpStatus::Unbounded },
            objective: p.objective(&x),
            x,
            iterations: 0,
            basis: None,
        };
    }

    if let Some(b) = warm {
        if let Some(sol) = try_warm(p, b) {
            return sol;
        }
    }
    solve_cold(p)
}

/// Full two-phase cold solve — the dense reference path.
fn solve_cold(p: &LpProblem) -> LpSolution {
    let n = p.num_vars();
    let m = p.num_rows();
    let Layout { lower, upper, cols, n_struct_slack, ntot } = build_layout(p);

    // Initial nonbasic values: finite bound nearest zero; 0 for free vars.
    let mut xval = vec![0.0; ntot];
    for j in 0..n_struct_slack {
        xval[j] = initial_rest(lower[j], upper[j]);
    }

    // Flat tableau; artificial columns get ±1 to make residuals
    // nonnegative.
    let mut a = vec![0.0f64; m * ntot];
    for (j, col) in cols.iter().enumerate() {
        for &(i, v) in col {
            a[i * ntot + j] = v;
        }
    }
    let mut xb = vec![0.0f64; m];
    for i in 0..m {
        let mut resid = p.rows[i].rhs;
        for j in 0..n_struct_slack {
            resid -= a[i * ntot + j] * xval[j];
        }
        // Keep the basis an identity: if the residual is negative, negate
        // the whole row (coefficients and rhs) so the artificial enters
        // with +1 and a nonnegative value.
        if resid < 0.0 {
            for v in a[i * ntot..(i + 1) * ntot].iter_mut() {
                *v = -*v;
            }
            resid = -resid;
            // rhs negation is implicit: xb stores the shifted residual.
        }
        let art = n_struct_slack + i;
        a[i * ntot + art] = 1.0;
        xb[i] = resid;
    }

    let mut state = vec![VarState::AtLower; ntot];
    for j in 0..n_struct_slack {
        state[j] = if xval[j] == upper[j] && upper[j].is_finite() && lower[j] != upper[j] {
            VarState::AtUpper
        } else {
            VarState::AtLower
        };
    }
    let mut basis = Vec::with_capacity(m);
    for i in 0..m {
        let art = n_struct_slack + i;
        basis.push(art);
        state[art] = VarState::Basic(i);
    }

    // Phase-1 reduced costs: c = e on artificials ⇒ d_j = −Σ_i a[i][j]
    // for nonbasic j (c_B = 1 on all rows), d on artificials = 0.
    let mut d = vec![0.0f64; ntot];
    for (j, dj) in d.iter_mut().enumerate().take(n_struct_slack) {
        let mut s = 0.0;
        for i in 0..m {
            s += a[i * ntot + j];
        }
        *dj = -s;
    }

    let mut t = Tableau {
        a,
        pivot_row: vec![0.0; ntot],
        xb,
        d,
        basis,
        state,
        lower,
        upper,
        xval,
        m,
        ntot,
        iterations: 0,
        price_cursor: 0,
    };

    let max_iter = 50 * (m + ntot) + 1000;
    let fixed_none = vec![false; ntot];
    // Phase 1 (artificials active: full column range).
    match t.optimize(max_iter, &fixed_none, ntot, ntot) {
        Ok(()) => {}
        Err(LpStatus::Unbounded) => {
            // Phase-1 objective is bounded below by 0; unbounded is a bug.
            unreachable!("phase-1 cannot be unbounded");
        }
        Err(s) => return failed(s, n, t.iterations),
    }
    let phase1_obj: f64 = (0..m)
        .filter(|&i| t.basis[i] >= n_struct_slack)
        .map(|i| t.xb[i])
        .sum();
    if phase1_obj > 1e-6 {
        return failed(LpStatus::Infeasible, n, t.iterations);
    }

    // Pin artificials to zero so they can never re-enter; drive basic
    // artificials out where possible.
    let mut fixed = vec![false; ntot];
    for jart in n_struct_slack..ntot {
        t.lower[jart] = 0.0;
        t.upper[jart] = 0.0;
        fixed[jart] = true;
    }
    for r in 0..m {
        let b = t.basis[r];
        if b >= n_struct_slack {
            // Degenerate basic artificial (value ~0). Pivot in any
            // structural/slack column with a usable entry.
            let mut found = None;
            for j in 0..n_struct_slack {
                if !matches!(t.state[j], VarState::Basic(_)) && t.a[r * ntot + j].abs() > 1e-7 {
                    found = Some(j);
                    break;
                }
            }
            if let Some(j) = found {
                // Manual degenerate pivot (step 0).
                let entering_value = t.xval[j];
                t.pivot(r, j, ntot);
                t.state[b] = VarState::AtLower;
                t.xval[b] = 0.0;
                t.basis[r] = j;
                t.state[j] = VarState::Basic(r);
                t.xb[r] = entering_value; // ≈ old xb[r] = 0 shifted basis
            }
            // else: redundant row; artificial stays basic at 0 forever
            // (bounds [0,0] keep it there).
        }
    }

    t.load_phase2_costs(&p.c);

    // Phase 2: artificial columns are fixed at zero and never re-enter;
    // exclude them from pivot updates entirely.
    let status = match t.optimize(max_iter, &fixed, n_struct_slack, n_struct_slack) {
        Ok(()) => LpStatus::Optimal,
        Err(s) => s,
    };
    finish(p, &t, status, n_struct_slack)
}

/// Attempt a warm-started phase-2-only solve. `None` means the basis is
/// unusable for this problem and the caller should fall back to a cold
/// solve.
fn try_warm(p: &LpProblem, warm: &Basis) -> Option<LpSolution> {
    let m = p.num_rows();
    let Layout { mut lower, mut upper, cols, n_struct_slack, ntot } = build_layout(p);
    if warm.ntot != ntot
        || warm.n_struct_slack != n_struct_slack
        || warm.row_to_var.len() != m
        || warm.at_upper.len() != ntot
    {
        return None;
    }

    // Fresh tableau from the new problem data plus a RHS accumulator.
    let mut a = vec![0.0f64; m * ntot];
    for (j, col) in cols.iter().enumerate() {
        for &(i, v) in col {
            a[i * ntot + j] = v;
        }
    }
    for i in 0..m {
        a[i * ntot + n_struct_slack + i] = 1.0;
    }
    let mut rhs: Vec<f64> = p.rows.iter().map(|r| r.rhs).collect();

    // Realize the basis by Gauss-Jordan with row swaps: after step k,
    // column `basis[k]` is the k-th unit vector, i.e. rows hold B⁻¹A and
    // `rhs` holds B⁻¹b. Row order within the basis is arbitrary, so the
    // swap only relabels which row carries which basic variable.
    let mut basis = warm.row_to_var.clone();
    for k in 0..m {
        let j = basis[k];
        if j >= ntot {
            return None;
        }
        let mut best_i = k;
        let mut best_v = a[k * ntot + j].abs();
        for i in k + 1..m {
            let v = a[i * ntot + j].abs();
            if v > best_v {
                best_i = i;
                best_v = v;
            }
        }
        if best_v < 1e-9 {
            return None; // basis singular under the new coefficients
        }
        if best_i != k {
            for col in 0..ntot {
                a.swap(best_i * ntot + col, k * ntot + col);
            }
            rhs.swap(best_i, k);
        }
        let inv = 1.0 / a[k * ntot + j];
        for v in a[k * ntot..(k + 1) * ntot].iter_mut() {
            *v *= inv;
        }
        rhs[k] *= inv;
        for i in 0..m {
            if i == k {
                continue;
            }
            let f = a[i * ntot + j];
            if f != 0.0 {
                for col in 0..ntot {
                    a[i * ntot + col] -= f * a[k * ntot + col];
                }
                a[i * ntot + j] = 0.0;
                rhs[i] -= f * rhs[k];
            }
        }
    }

    // Nonbasic resting states and values from the snapshot.
    let mut state = vec![VarState::AtLower; ntot];
    let mut xval = vec![0.0f64; ntot];
    let mut in_basis = vec![false; ntot];
    for &b in &basis {
        in_basis[b] = true;
    }
    for j in 0..ntot {
        if in_basis[j] {
            continue;
        }
        let (st, v) = resting(lower[j], upper[j], warm.at_upper[j]);
        state[j] = st;
        xval[j] = v;
    }
    for (r, &b) in basis.iter().enumerate() {
        state[b] = VarState::Basic(r);
    }

    // Basic values: x_B = B⁻¹b − Σ_{nonbasic j} (B⁻¹A)_j · xval_j.
    let mut xb = rhs;
    for j in 0..n_struct_slack {
        if in_basis[j] || xval[j] == 0.0 {
            continue;
        }
        let v = xval[j];
        for i in 0..m {
            xb[i] -= a[i * ntot + j] * v;
        }
    }

    // The warm basis must be primal feasible under the new bounds/RHS;
    // otherwise phase 1 is needed and the cold path handles it.
    for (r, &b) in basis.iter().enumerate() {
        if b >= n_struct_slack {
            // Artificial basic: only legitimate for a redundant row, at 0.
            if xb[r].abs() > WARM_TOL {
                return None;
            }
        } else if xb[r] < lower[b] - WARM_TOL || xb[r] > upper[b] + WARM_TOL {
            return None;
        }
    }

    // Pin artificials and run phase 2 only.
    let mut fixed = vec![false; ntot];
    for jart in n_struct_slack..ntot {
        lower[jart] = 0.0;
        upper[jart] = 0.0;
        fixed[jart] = true;
    }
    let mut t = Tableau {
        a,
        pivot_row: vec![0.0; ntot],
        xb,
        d: vec![0.0; ntot],
        basis,
        state,
        lower,
        upper,
        xval,
        m,
        ntot,
        iterations: 0,
        price_cursor: 0,
    };
    t.load_phase2_costs(&p.c);
    let max_iter = 50 * (m + ntot) + 1000;
    let status = match t.optimize(max_iter, &fixed, n_struct_slack, n_struct_slack) {
        Ok(()) => LpStatus::Optimal,
        // A genuinely unbounded problem is unbounded from any basis.
        Err(LpStatus::Unbounded) => LpStatus::Unbounded,
        // Stalling out from a warm basis is not a verdict on the
        // problem: fall back to the cold path, which starts from a
        // fresh phase-1 basis (warmth must only affect iteration count).
        Err(_) => return None,
    };
    Some(finish(p, &t, status, n_struct_slack))
}

/// Which rung of [`PersistentSimplex::solve`]'s fallback ladder produced
/// the last solution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolvePath {
    /// RHS / objective / bound drift patched through the live basis
    /// factorization — no refactorization: the dual simplex (Devex
    /// pricing, bound-flipping ratio test) repairs RHS/bound drift and
    /// primal phase 2 repairs cost drift.
    Incremental,
    /// The stored basis and resting states were kept but the basis LU
    /// was refactorized from scratch under the (possibly changed)
    /// coefficients — the matrix-change path and the periodic refresh.
    WarmBasis,
    /// Fresh solve from the all-logical basis (first solve, or the
    /// stored state was unusable for this problem).
    Cold,
}

/// Tuning knobs for [`PersistentSimplex`], settable via
/// [`PersistentSimplex::with_config`] / [`PersistentSimplex::set_config`].
///
/// The defaults reproduce the solver's historical hard-coded behaviour;
/// both knobs exist for callers whose replan loops want a different
/// speed/robustness trade.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimplexConfig {
    /// Refactorization interval, default **64**. Bounds both the
    /// product-form eta file (a solve refactorizes its basis LU once
    /// this many pivot etas accumulate) and the number of consecutive
    /// [`SolvePath::Incremental`] solves before the ladder forces a
    /// [`SolvePath::WarmBasis`] refresh — the classic revised-simplex
    /// guard on accumulated f64 error. Smaller is more robust, larger
    /// is faster.
    pub refactor_interval: usize,
    /// Feasibility tolerance, default **1e-6**, that a persistent-path
    /// solution must verify against the *original* problem data before
    /// being trusted — the numerical-drift detector in front of the
    /// refactorization fallback. A solution outside the tolerance falls
    /// through to a fresher rung, ending at the dense two-phase oracle.
    pub drift_tol: f64,
}

impl Default for SimplexConfig {
    fn default() -> Self {
        SimplexConfig { refactor_interval: 64, drift_tol: 1e-6 }
    }
}

/// Per-solve counters of the last [`PersistentSimplex::solve`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SolveStats {
    /// Ladder rung that produced the solution.
    pub path: SolvePath,
    /// Basis-changing pivots.
    pub pivots: usize,
    /// Nonbasic bound flips: the long-step dual ratio test's bulk
    /// flips, primal entering-variable flips, and dual-feasibility
    /// seating flips.
    pub bound_flips: usize,
    /// Basis LU (re)factorizations, including the rung's initial one
    /// (warm/cold rungs always factorize at least once; incremental
    /// solves usually report zero).
    pub refactorizations: usize,
}

/// A simplex solver that keeps the factorized basis alive between
/// solves — the warm-start discipline of revised-simplex codes applied
/// to the controller replan loop. The engine is the sparse revised
/// core: a sparse LU factorization of the basis (Markowitz-ordered,
/// product-form eta update per pivot), Devex pricing in both the primal
/// and dual phases, and a long-step bound-flipping dual ratio test; see
/// [`super::revised`].
///
/// The fallback ladder of [`PersistentSimplex::solve`]:
///
/// 1. **Incremental** — when the constraint matrix is unchanged (same
///    rows, senses, and coefficients; only RHS, objective, and variable
///    bounds moved — the replan pattern), the new data patches through
///    the live factorization: the dual simplex repairs RHS/bound drift
///    in O(m + nnz) per pivot, primal phase 2 repairs cost drift, and
///    an unchanged problem certifies optimality in zero pivots.
///    Solutions are verified against the problem before being returned;
///    any doubt (structural change, non-optimal verdict, drift beyond
///    [`SimplexConfig::drift_tol`]) falls through.
/// 2. **Warm basis** — the basis and resting states are kept, the
///    problem data is rebuilt, and the basis LU is refactorized from
///    scratch. Also runs every [`SimplexConfig::refactor_interval`]-th
///    solve as the periodic refresh.
/// 3. **Cold** — a fresh sparse solve from the all-logical basis, whose
///    `Infeasible`/`Unbounded` verdicts are genuine certificates (the
///    sparse layout carries no artificial variables). If even this rung
///    fails numerically, the dense two-phase oracle ([`solve`]'s path)
///    answers.
///
/// Correctness never depends on which rung answered; the ladder only
/// affects pivot counts. Results are identical to [`solve`] up to LP
/// degeneracy (alternative optima tie-broken by pivot order).
#[derive(Clone, Debug, Default)]
pub struct PersistentSimplex {
    state: Option<RevisedSimplex>,
    config: SimplexConfig,
    /// Incremental resolves since the last (re)factorization.
    since_factor: usize,
    last_path: Option<SolvePath>,
    last_stats: Option<SolveStats>,
}

impl PersistentSimplex {
    /// A solver with no stored basis (first solve runs cold) and the
    /// default [`SimplexConfig`].
    pub fn new() -> PersistentSimplex {
        PersistentSimplex::default()
    }

    /// A solver with explicit tuning knobs.
    pub fn with_config(config: SimplexConfig) -> PersistentSimplex {
        PersistentSimplex { config, ..PersistentSimplex::default() }
    }

    /// The active tuning knobs.
    pub fn config(&self) -> SimplexConfig {
        self.config
    }

    /// Replace the tuning knobs (takes effect from the next solve; the
    /// stored basis is kept).
    pub fn set_config(&mut self, config: SimplexConfig) {
        self.config = config;
    }

    /// Drop the stored basis (next solve runs cold).
    pub fn reset(&mut self) {
        self.state = None;
        self.since_factor = 0;
        self.last_path = None;
        self.last_stats = None;
    }

    /// Whether a basis from a previous optimal solve is stored.
    pub fn has_state(&self) -> bool {
        self.state.is_some()
    }

    /// Which ladder rung produced the last solution (`None` before the
    /// first solve).
    pub fn last_path(&self) -> Option<SolvePath> {
        self.last_path
    }

    /// Counters of the last solve (`None` before the first solve).
    pub fn last_stats(&self) -> Option<SolveStats> {
        self.last_stats
    }

    /// The stored optimal basis, if any — interchange format with
    /// [`solve_from_basis`] (sparse logicals map onto the dense layout's
    /// slack and artificial columns).
    pub fn basis(&self) -> Option<Basis> {
        self.state.as_ref().map(|s| s.dense_basis())
    }

    /// Solve `p`, preferring the cheapest usable rung of the ladder (see
    /// the type docs). Always returns a correct terminal status; a
    /// row-bearing solve that terminates non-optimal drops the stored
    /// state (bound-only solves leave it untouched).
    pub fn solve(&mut self, p: &LpProblem) -> LpSolution {
        if p.num_rows() == 0 {
            // Bound-only problems have no basis to keep — but any
            // stored state stays put (the fingerprint already guards it
            // against reuse on the wrong problem), so interleaving a
            // row-less solve does not de-warm the ladder.
            self.record(SolvePath::Cold, (0, 0, 0), 0);
            return solve_with(p, None);
        }
        // Inverted bounds are infeasible by inspection (problems mutated
        // in place bypass `add_var`'s assertion).
        if p.lower.iter().zip(&p.upper).any(|(l, u)| l > u) {
            self.state = None;
            self.since_factor = 0;
            self.record(SolvePath::Cold, (0, 0, 0), 0);
            return failed(LpStatus::Infeasible, p.num_vars(), 0);
        }
        let eta_cap = self.config.refactor_interval.max(1);
        let drift_tol = self.config.drift_tol;

        // Rung 1: patch drifted data through the live factorization.
        // Only a verified Optimal is returned from here — any other
        // outcome (including an Infeasible verdict, which a drifted
        // eta file could in principle distort) refactorizes and lets a
        // fresher rung decide.
        if self.since_factor < self.config.refactor_interval {
            if let Some(rs) = self.state.as_mut() {
                if rs.matches(p) && rs.patch(p) {
                    if let Ok(LpStatus::Optimal) = rs.optimize(eta_cap) {
                        let sol = rs.solution(p);
                        let counters = rs.counters();
                        if p.is_feasible(&sol.x, drift_tol) {
                            self.since_factor += 1;
                            self.record(SolvePath::Incremental, counters, 0);
                            return sol;
                        }
                    }
                }
            }
        }

        // Rung 2: keep the basis and resting states, rebuild the data,
        // refactorize from scratch.
        if let Some(mut rs) = self.state.take() {
            if rs.rebuild(p) {
                if let Ok(LpStatus::Optimal) = rs.optimize(eta_cap) {
                    let sol = rs.solution(p);
                    if p.is_feasible(&sol.x, drift_tol) {
                        self.since_factor = 0;
                        self.record(SolvePath::WarmBasis, rs.counters(), 1);
                        self.state = Some(rs);
                        return sol;
                    }
                }
            }
        }

        // Rung 3: cold sparse solve from the all-logical basis. Its
        // terminal verdicts are genuine certificates (no artificials).
        let mut rs = RevisedSimplex::from_problem(p);
        match rs.optimize(eta_cap) {
            Ok(LpStatus::Optimal) => {
                let sol = rs.solution(p);
                if p.is_feasible(&sol.x, drift_tol) {
                    self.since_factor = 0;
                    self.record(SolvePath::Cold, rs.counters(), 1);
                    self.state = Some(rs);
                    return sol;
                }
            }
            Ok(status @ (LpStatus::Infeasible | LpStatus::Unbounded)) => {
                let (pivots, flips, _) = rs.counters();
                self.since_factor = 0;
                self.record(SolvePath::Cold, rs.counters(), 1);
                return failed(status, p.num_vars(), pivots + flips);
            }
            _ => {}
        }

        // Safety net: the dense two-phase oracle, numerically
        // independent of the sparse machinery.
        self.since_factor = 0;
        let sol = solve_cold(p);
        self.record(
            SolvePath::Cold,
            (sol.iterations, 0, rs.counters().2),
            1,
        );
        sol
    }

    fn record(&mut self, path: SolvePath, counters: (usize, usize, usize), base_refactors: usize) {
        let (pivots, bound_flips, refactors) = counters;
        self.last_path = Some(path);
        self.last_stats = Some(SolveStats {
            path,
            pivots,
            bound_flips,
            refactorizations: refactors + base_refactors,
        });
    }
}

fn finish(p: &LpProblem, t: &Tableau, status: LpStatus, n_struct_slack: usize) -> LpSolution {
    let n = p.num_vars();
    let mut x = vec![0.0; n];
    for (j, xj) in x.iter_mut().enumerate() {
        *xj = t.value(j);
    }
    let basis =
        (status == LpStatus::Optimal).then(|| t.extract_basis(n_struct_slack));
    LpSolution { status, objective: p.objective(&x), x, iterations: t.iterations, basis }
}

fn failed(status: LpStatus, n: usize, iterations: usize) -> LpSolution {
    LpSolution { status, x: vec![f64::NAN; n], objective: f64::NAN, iterations, basis: None }
}

fn initial_rest(l: f64, u: f64) -> f64 {
    if l > -INF && u < INF {
        if l.abs() <= u.abs() {
            l
        } else {
            u
        }
    } else if l > -INF {
        l
    } else if u < INF {
        u
    } else {
        0.0
    }
}

/// Resting state for a nonbasic variable in a warm start, honouring the
/// snapshot's bound choice where the new bounds still allow it.
fn resting(l: f64, u: f64, prefer_upper: bool) -> (VarState, f64) {
    if l == u {
        return (VarState::AtLower, l);
    }
    if prefer_upper && u < INF {
        return (VarState::AtUpper, u);
    }
    if l > -INF {
        (VarState::AtLower, l)
    } else if u < INF {
        (VarState::AtUpper, u)
    } else {
        (VarState::AtLower, 0.0) // free variable rests at 0
    }
}

fn trivially_best(c: f64, l: f64, u: f64) -> f64 {
    if c > 0.0 {
        l
    } else if c < 0.0 {
        u
    } else if l > -INF {
        l
    } else if u < INF {
        u
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(sol: &LpSolution, obj: f64, tol: f64) {
        assert_eq!(sol.status, LpStatus::Optimal, "{sol:?}");
        assert!(
            (sol.objective - obj).abs() <= tol,
            "objective {} != expected {obj}",
            sol.objective
        );
    }

    #[test]
    fn textbook_max_problem() {
        // max 3x + 5y s.t. x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18, x,y ≥ 0
        // ⇒ min −3x −5y; optimum (2, 6), obj −36.
        let mut p = LpProblem::new();
        let x = p.add_var(-3.0, 0.0, INF);
        let y = p.add_var(-5.0, 0.0, INF);
        p.add_row(vec![(x, 1.0)], Cmp::Le, 4.0);
        p.add_row(vec![(y, 2.0)], Cmp::Le, 12.0);
        p.add_row(vec![(x, 3.0), (y, 2.0)], Cmp::Le, 18.0);
        let sol = solve(&p);
        assert_opt(&sol, -36.0, 1e-7);
        assert!((sol.x[0] - 2.0).abs() < 1e-7);
        assert!((sol.x[1] - 6.0).abs() < 1e-7);
        assert!(p.is_feasible(&sol.x, 1e-7));
    }

    #[test]
    fn equality_constraints() {
        // min x + y s.t. x + y = 10, x − y = 2, x,y ≥ 0 → (6,4), obj 10.
        let mut p = LpProblem::new();
        let x = p.add_var(1.0, 0.0, INF);
        let y = p.add_var(1.0, 0.0, INF);
        p.add_row(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 10.0);
        p.add_row(vec![(x, 1.0), (y, -1.0)], Cmp::Eq, 2.0);
        let sol = solve(&p);
        assert_opt(&sol, 10.0, 1e-7);
        assert!((sol.x[0] - 6.0).abs() < 1e-7);
    }

    #[test]
    fn ge_constraints_and_bounds() {
        // min 2x + 3y s.t. x + y ≥ 5, x ≤ 3, y ≤ 4, x,y ≥ 0.
        // Cheapest: x = 3 (cost 2), y = 2 → obj 12.
        let mut p = LpProblem::new();
        let x = p.add_var(2.0, 0.0, 3.0);
        let y = p.add_var(3.0, 0.0, 4.0);
        p.add_row(vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 5.0);
        let sol = solve(&p);
        assert_opt(&sol, 12.0, 1e-7);
    }

    #[test]
    fn infeasible_detected() {
        let mut p = LpProblem::new();
        let x = p.add_var(1.0, 0.0, 1.0);
        p.add_row(vec![(x, 1.0)], Cmp::Ge, 2.0);
        assert_eq!(solve(&p).status, LpStatus::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut p = LpProblem::new();
        let x = p.add_var(-1.0, 0.0, INF);
        p.add_row(vec![(x, 1.0)], Cmp::Ge, 0.0);
        assert_eq!(solve(&p).status, LpStatus::Unbounded);
    }

    #[test]
    fn bound_only_problem() {
        let mut p = LpProblem::new();
        p.add_var(1.0, -2.0, 5.0); // min → lower
        p.add_var(-1.0, -2.0, 5.0); // min → upper
        let sol = solve(&p);
        assert_opt(&sol, -7.0, 1e-12);
        assert_eq!(sol.x, vec![-2.0, 5.0]);
    }

    #[test]
    fn negative_rhs_rows() {
        // min x s.t. −x ≤ −3 (i.e. x ≥ 3).
        let mut p = LpProblem::new();
        let x = p.add_var(1.0, 0.0, INF);
        p.add_row(vec![(x, -1.0)], Cmp::Le, -3.0);
        let sol = solve(&p);
        assert_opt(&sol, 3.0, 1e-7);
    }

    #[test]
    fn free_variable() {
        // min |shift|-style: min y s.t. y ≥ x − 4, y ≥ 4 − x, x free.
        // Optimum x = 4, y = 0.
        let mut p = LpProblem::new();
        let x = p.add_var(0.0, -INF, INF);
        let y = p.add_var(1.0, -INF, INF);
        p.add_row(vec![(y, 1.0), (x, -1.0)], Cmp::Ge, -4.0);
        p.add_row(vec![(y, 1.0), (x, 1.0)], Cmp::Ge, 4.0);
        let sol = solve(&p);
        assert_opt(&sol, 0.0, 1e-7);
        assert!((sol.x[0] - 4.0).abs() < 1e-7);
    }

    #[test]
    fn degenerate_vertex() {
        // Multiple constraints meet at the optimum — exercises degenerate
        // pivots. min −x − y s.t. x + y ≤ 1, x ≤ 1, y ≤ 1, x + 2y ≤ 2.
        let mut p = LpProblem::new();
        let x = p.add_var(-1.0, 0.0, INF);
        let y = p.add_var(-1.0, 0.0, INF);
        p.add_row(vec![(x, 1.0), (y, 1.0)], Cmp::Le, 1.0);
        p.add_row(vec![(x, 1.0)], Cmp::Le, 1.0);
        p.add_row(vec![(y, 1.0)], Cmp::Le, 1.0);
        p.add_row(vec![(x, 1.0), (y, 2.0)], Cmp::Le, 2.0);
        let sol = solve(&p);
        assert_opt(&sol, -1.0, 1e-7);
    }

    #[test]
    fn redundant_equalities() {
        // x + y = 2 stated twice — phase 1 leaves a basic artificial on a
        // redundant row.
        let mut p = LpProblem::new();
        let x = p.add_var(1.0, 0.0, INF);
        let y = p.add_var(2.0, 0.0, INF);
        p.add_row(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 2.0);
        p.add_row(vec![(x, 2.0), (y, 2.0)], Cmp::Eq, 4.0);
        let sol = solve(&p);
        assert_opt(&sol, 2.0, 1e-7);
        assert!((sol.x[0] - 2.0).abs() < 1e-7);
    }

    #[test]
    fn random_lps_feasible_and_not_worse_than_samples() {
        // Property: on random feasible LPs, the solver's solution is
        // feasible and no random feasible point beats it.
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(2024);
        for case in 0..30 {
            let nv = 2 + (case % 4);
            let mut p = LpProblem::new();
            for _ in 0..nv {
                let c = rng.range_f64(-2.0, 2.0);
                p.add_var(c, 0.0, rng.range_f64(1.0, 5.0));
            }
            // Rows of the form Σ a_j x_j ≤ b with b large enough that
            // x = 0 is feasible (b ≥ 0).
            for _ in 0..nv {
                let coeffs: Vec<(usize, f64)> =
                    (0..nv).map(|j| (j, rng.range_f64(-1.0, 2.0))).collect();
                p.add_row(coeffs, Cmp::Le, rng.range_f64(0.5, 6.0));
            }
            let sol = solve(&p);
            assert_eq!(sol.status, LpStatus::Optimal, "case {case}");
            assert!(p.is_feasible(&sol.x, 1e-6), "case {case}: {:?}", sol.x);
            // Random feasible points never beat the reported optimum.
            for _ in 0..200 {
                let cand: Vec<f64> =
                    (0..nv).map(|j| rng.range_f64(0.0, p.upper[j])).collect();
                if p.is_feasible(&cand, 1e-9) {
                    assert!(
                        p.objective(&cand) >= sol.objective - 1e-6,
                        "case {case}: sampled point beats 'optimum'"
                    );
                }
            }
        }
    }

    #[test]
    fn warm_restart_from_own_basis_is_immediate() {
        let mut p = LpProblem::new();
        let x = p.add_var(-3.0, 0.0, INF);
        let y = p.add_var(-5.0, 0.0, INF);
        p.add_row(vec![(x, 1.0)], Cmp::Le, 4.0);
        p.add_row(vec![(y, 2.0)], Cmp::Le, 12.0);
        p.add_row(vec![(x, 3.0), (y, 2.0)], Cmp::Le, 18.0);
        let cold = solve(&p);
        assert_opt(&cold, -36.0, 1e-7);
        let basis = cold.basis.clone().expect("optimal solve returns a basis");
        let warm = solve_from_basis(&p, &basis);
        assert_opt(&warm, -36.0, 1e-7);
        // The old optimum is still optimal: phase 2 certifies it in the
        // first pricing pass without pivoting.
        assert!(
            warm.iterations <= 1,
            "warm restart took {} iterations",
            warm.iterations
        );
    }

    #[test]
    fn warm_start_tracks_objective_perturbation() {
        // Shift costs so the optimal vertex moves; warm start must land
        // on the same optimum as a cold solve.
        let mut p = LpProblem::new();
        let x = p.add_var(-3.0, 0.0, INF);
        let y = p.add_var(-5.0, 0.0, INF);
        p.add_row(vec![(x, 1.0)], Cmp::Le, 4.0);
        p.add_row(vec![(y, 2.0)], Cmp::Le, 12.0);
        p.add_row(vec![(x, 3.0), (y, 2.0)], Cmp::Le, 18.0);
        let basis = solve(&p).basis.unwrap();
        let mut p2 = p.clone();
        p2.c = vec![-5.0, -1.0]; // now x is precious: optimum (4, 3)
        let cold = solve(&p2);
        let warm = solve_from_basis(&p2, &basis);
        assert_eq!(warm.status, LpStatus::Optimal);
        assert!(
            (warm.objective - cold.objective).abs() < 1e-7,
            "warm {} vs cold {}",
            warm.objective,
            cold.objective
        );
        assert!(p2.is_feasible(&warm.x, 1e-7));
    }

    #[test]
    fn warm_start_falls_back_on_infeasible_rhs_change() {
        // An RHS change that breaks the old basis's primal feasibility
        // must transparently fall back to the cold path.
        let mut p = LpProblem::new();
        let x = p.add_var(1.0, 0.0, 10.0);
        let y = p.add_var(1.0, 0.0, 10.0);
        p.add_row(vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 2.0);
        let basis = solve(&p).basis.unwrap();
        let mut p2 = p.clone();
        p2.rows[0].rhs = 15.0; // old vertex (2,0) now violates the row
        let warm = solve_from_basis(&p2, &basis);
        let cold = solve(&p2);
        assert_eq!(warm.status, LpStatus::Optimal);
        assert!((warm.objective - cold.objective).abs() < 1e-7);
        assert!(p2.is_feasible(&warm.x, 1e-7));
    }

    #[test]
    fn warm_start_rejects_mismatched_shapes() {
        let mut p = LpProblem::new();
        let x = p.add_var(1.0, 0.0, 1.0);
        p.add_row(vec![(x, 1.0)], Cmp::Le, 1.0);
        let basis = solve(&p).basis.unwrap();
        let mut p2 = LpProblem::new();
        let a = p2.add_var(1.0, 0.0, 1.0);
        let b = p2.add_var(1.0, 0.0, 1.0);
        p2.add_row(vec![(a, 1.0), (b, 1.0)], Cmp::Le, 1.0);
        // Different column count: must fall back to cold and stay correct.
        let sol = solve_from_basis(&p2, &basis);
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.objective - 0.0).abs() < 1e-9);
    }

    fn textbook() -> LpProblem {
        let mut p = LpProblem::new();
        let x = p.add_var(-3.0, 0.0, INF);
        let y = p.add_var(-5.0, 0.0, INF);
        p.add_row(vec![(x, 1.0)], Cmp::Le, 4.0);
        p.add_row(vec![(y, 2.0)], Cmp::Le, 12.0);
        p.add_row(vec![(x, 3.0), (y, 2.0)], Cmp::Le, 18.0);
        p
    }

    #[test]
    fn persistent_identical_resolve_is_incremental_and_pivot_free() {
        let p = textbook();
        let mut s = PersistentSimplex::new();
        let cold = s.solve(&p);
        assert_opt(&cold, -36.0, 1e-7);
        assert_eq!(s.last_path(), Some(SolvePath::Cold));
        let again = s.solve(&p);
        assert_eq!(s.last_path(), Some(SolvePath::Incremental));
        assert_eq!(again.iterations, 0, "unchanged problem should not pivot");
        assert_opt(&again, -36.0, 1e-7);
        // Same vertex; basic values are re-derived through the basis
        // inverse, so agreement is to rounding, not bitwise.
        for (a, c) in again.x.iter().zip(&cold.x) {
            assert!((a - c).abs() < 1e-9, "vertex moved: {a} vs {c}");
        }
    }

    #[test]
    fn persistent_rhs_drift_repairs_via_dual_simplex() {
        let p = textbook();
        let mut s = PersistentSimplex::new();
        s.solve(&p);
        // Tighten every row: the old vertex (2, 6) is now primal
        // infeasible, which is exactly the dual-simplex case.
        let mut p2 = p.clone();
        p2.rows[0].rhs = 3.0;
        p2.rows[1].rhs = 8.0;
        p2.rows[2].rhs = 13.0;
        let inc = s.solve(&p2);
        assert_eq!(s.last_path(), Some(SolvePath::Incremental));
        let cold = solve(&p2);
        assert_eq!(inc.status, LpStatus::Optimal);
        assert!(
            (inc.objective - cold.objective).abs() < 1e-7,
            "incremental {} vs cold {}",
            inc.objective,
            cold.objective
        );
        assert!(p2.is_feasible(&inc.x, 1e-7));
        assert!(inc.iterations <= 6, "dual repair took {} pivots", inc.iterations);
    }

    #[test]
    fn persistent_objective_drift_repairs_via_primal_phase2() {
        let p = textbook();
        let mut s = PersistentSimplex::new();
        s.solve(&p);
        let mut p2 = p.clone();
        p2.c = vec![-5.0, -1.0]; // optimum moves to (4, 3)
        let inc = s.solve(&p2);
        assert_eq!(s.last_path(), Some(SolvePath::Incremental));
        let cold = solve(&p2);
        assert!((inc.objective - cold.objective).abs() < 1e-7);
        assert!(p2.is_feasible(&inc.x, 1e-7));
    }

    #[test]
    fn persistent_bound_drift_stays_incremental() {
        let mut p = LpProblem::new();
        let x = p.add_var(-1.0, 0.0, 5.0);
        let y = p.add_var(-1.0, 0.0, 5.0);
        p.add_row(vec![(x, 1.0), (y, 1.0)], Cmp::Le, 8.0);
        let mut s = PersistentSimplex::new();
        s.solve(&p);
        // Box moves only: coefficients and RHS untouched.
        let mut p2 = p.clone();
        p2.upper = vec![2.0, 4.0];
        p2.lower = vec![0.5, 0.0];
        let inc = s.solve(&p2);
        assert_eq!(s.last_path(), Some(SolvePath::Incremental));
        let cold = solve(&p2);
        assert!((inc.objective - cold.objective).abs() < 1e-7);
        assert!(p2.is_feasible(&inc.x, 1e-7));
    }

    #[test]
    fn persistent_matrix_change_falls_back_and_stays_correct() {
        let p = textbook();
        let mut s = PersistentSimplex::new();
        s.solve(&p);
        let mut p2 = p.clone();
        p2.rows[2].coeffs = vec![(0, 2.0), (1, 2.0)]; // matrix changed
        let fb = s.solve(&p2);
        assert_ne!(s.last_path(), Some(SolvePath::Incremental));
        let cold = solve(&p2);
        assert_eq!(fb.status, LpStatus::Optimal);
        assert!((fb.objective - cold.objective).abs() < 1e-7);
        // A later re-solve of the *new* matrix is incremental again.
        let again = s.solve(&p2);
        assert_eq!(s.last_path(), Some(SolvePath::Incremental));
        assert!((again.objective - cold.objective).abs() < 1e-7);
    }

    #[test]
    fn persistent_infeasible_drift_reports_through_the_ladder() {
        let mut p = LpProblem::new();
        let x = p.add_var(1.0, 0.0, 1.0);
        p.add_row(vec![(x, 1.0)], Cmp::Ge, 0.5);
        let mut s = PersistentSimplex::new();
        assert_eq!(s.solve(&p).status, LpStatus::Optimal);
        let mut p2 = p.clone();
        p2.rows[0].rhs = 2.0; // x ≤ 1 cannot reach 2
        let sol = s.solve(&p2);
        assert_eq!(sol.status, LpStatus::Infeasible);
        assert!(!s.has_state(), "failed solve must drop the stored tableau");
        // The solver recovers cold on the next feasible problem.
        let back = s.solve(&p);
        assert_eq!(back.status, LpStatus::Optimal);
    }

    #[test]
    fn persistent_random_rhs_and_objective_drift_matches_cold() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(4242);
        for case in 0..15 {
            let nv = 3 + (case % 3);
            let mut p = LpProblem::new();
            for _ in 0..nv {
                p.add_var(rng.range_f64(-2.0, 2.0), 0.0, rng.range_f64(1.0, 5.0));
            }
            for _ in 0..nv {
                let coeffs: Vec<(usize, f64)> =
                    (0..nv).map(|j| (j, rng.range_f64(-1.0, 2.0))).collect();
                p.add_row(coeffs, Cmp::Le, rng.range_f64(0.5, 6.0));
            }
            let mut s = PersistentSimplex::new();
            let first = s.solve(&p);
            assert_eq!(first.status, LpStatus::Optimal, "case {case}");
            // A drifting sequence over the fixed matrix: every re-solve
            // must take the incremental rung and match a cold solve.
            for round in 0..6 {
                for c in p.c.iter_mut() {
                    *c += rng.range_f64(-0.1, 0.1);
                }
                for row in p.rows.iter_mut() {
                    row.rhs = (row.rhs + rng.range_f64(-0.3, 0.3)).max(0.1);
                }
                for u in p.upper.iter_mut() {
                    *u = (*u + rng.range_f64(-0.2, 0.2)).max(0.5);
                }
                let inc = s.solve(&p);
                let cold = solve(&p);
                assert_eq!(cold.status, LpStatus::Optimal, "case {case} round {round}");
                assert_eq!(inc.status, LpStatus::Optimal, "case {case} round {round}");
                assert_eq!(
                    s.last_path(),
                    Some(SolvePath::Incremental),
                    "case {case} round {round}"
                );
                assert!(
                    (inc.objective - cold.objective).abs() < 1e-6,
                    "case {case} round {round}: incremental {} vs cold {}",
                    inc.objective,
                    cold.objective
                );
                assert!(p.is_feasible(&inc.x, 1e-6), "case {case} round {round}");
            }
        }
    }

    #[test]
    fn warm_start_random_perturbations_match_cold() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(777);
        for case in 0..20 {
            let nv = 3 + (case % 3);
            let mut p = LpProblem::new();
            for _ in 0..nv {
                p.add_var(rng.range_f64(-2.0, 2.0), 0.0, rng.range_f64(1.0, 5.0));
            }
            for _ in 0..nv {
                let coeffs: Vec<(usize, f64)> =
                    (0..nv).map(|j| (j, rng.range_f64(-1.0, 2.0))).collect();
                p.add_row(coeffs, Cmp::Le, rng.range_f64(0.5, 6.0));
            }
            let base = solve(&p);
            assert_eq!(base.status, LpStatus::Optimal, "case {case}");
            let basis = base.basis.clone().unwrap();
            // Perturb objective and RHS by a few percent, as a
            // controller re-plan would.
            let mut p2 = p.clone();
            for c in p2.c.iter_mut() {
                *c += rng.range_f64(-0.05, 0.05);
            }
            for row in p2.rows.iter_mut() {
                row.rhs += rng.range_f64(-0.02, 0.02);
            }
            let cold = solve(&p2);
            let warm = solve_from_basis(&p2, &basis);
            assert_eq!(cold.status, LpStatus::Optimal, "case {case}");
            assert_eq!(warm.status, LpStatus::Optimal, "case {case}");
            assert!(
                (warm.objective - cold.objective).abs() < 1e-6,
                "case {case}: warm {} vs cold {}",
                warm.objective,
                cold.objective
            );
            assert!(p2.is_feasible(&warm.x, 1e-6), "case {case}");
        }
    }
}
