//! From-scratch linear-programming solver: two-phase primal simplex with
//! **bounded variables** (l ≤ x ≤ u handled implicitly, not as rows).
//!
//! The paper solves its freeze-ratio LP with "standard linear programming
//! solvers" (§3.2.2, citing Karmarkar's interior-point method for the
//! polynomial-time claim). No solver crate exists in the offline image,
//! so this module implements the classic bounded-variable simplex — exact
//! on the paper's problem sizes (|V| ≈ 2·M·S + 2 nodes → a few hundred
//! variables and constraints), and fast enough to re-solve per batch if a
//! schedule were elastic (see benches/lp_micro.rs).
//!
//! Method: rows are converted to equalities with slack variables; phase 1
//! minimizes the sum of artificial variables from an identity basis;
//! phase 2 minimizes the true objective. Nonbasic variables rest at a
//! finite bound; the ratio test accounts for basic variables hitting
//! either bound and for bound flips of the entering variable. Bland's
//! rule kicks in after a stall to guarantee termination.

pub const INF: f64 = f64::INFINITY;

/// Comparison operator of a row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// One sparse constraint row: `Σ coeffs · x  cmp  rhs`.
#[derive(Clone, Debug)]
pub struct LpRow {
    pub coeffs: Vec<(usize, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// `min cᵀx  s.t.  rows,  lower ≤ x ≤ upper`.
#[derive(Clone, Debug, Default)]
pub struct LpProblem {
    pub c: Vec<f64>,
    pub lower: Vec<f64>,
    pub upper: Vec<f64>,
    pub rows: Vec<LpRow>,
}

impl LpProblem {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a variable, returning its index.
    pub fn add_var(&mut self, cost: f64, lower: f64, upper: f64) -> usize {
        assert!(lower <= upper, "lower {lower} > upper {upper}");
        self.c.push(cost);
        self.lower.push(lower);
        self.upper.push(upper);
        self.c.len() - 1
    }

    pub fn add_row(&mut self, coeffs: Vec<(usize, f64)>, cmp: Cmp, rhs: f64) {
        for &(j, _) in &coeffs {
            assert!(j < self.c.len(), "row references unknown variable {j}");
        }
        self.rows.push(LpRow { coeffs, cmp, rhs });
    }

    pub fn num_vars(&self) -> usize {
        self.c.len()
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Check a candidate point against all rows and bounds.
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        if x.len() != self.num_vars() {
            return false;
        }
        for j in 0..x.len() {
            if x[j] < self.lower[j] - tol || x[j] > self.upper[j] + tol {
                return false;
            }
        }
        for row in &self.rows {
            let lhs: f64 = row.coeffs.iter().map(|&(j, a)| a * x[j]).sum();
            let ok = match row.cmp {
                Cmp::Le => lhs <= row.rhs + tol,
                Cmp::Ge => lhs >= row.rhs - tol,
                Cmp::Eq => (lhs - row.rhs).abs() <= tol,
            };
            if !ok {
                return false;
            }
        }
        true
    }

    pub fn objective(&self, x: &[f64]) -> f64 {
        self.c.iter().zip(x).map(|(c, x)| c * x).sum()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LpStatus {
    Optimal,
    Infeasible,
    Unbounded,
    IterationLimit,
}

#[derive(Clone, Debug)]
pub struct LpSolution {
    pub status: LpStatus,
    /// Values of the structural variables.
    pub x: Vec<f64>,
    pub objective: f64,
    pub iterations: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum VarState {
    Basic(usize),
    AtLower,
    AtUpper,
}

const FEAS_TOL: f64 = 1e-9;
const OPT_TOL: f64 = 1e-9;
const PIVOT_TOL: f64 = 1e-10;

struct Tableau {
    /// Dense rows of B⁻¹·A, m × ntot.
    a: Vec<Vec<f64>>,
    /// Current values of basic variables (in bound-shifted space: actual
    /// values, with nonbasics at their bounds).
    xb: Vec<f64>,
    /// Reduced-cost row d_j = c_j − c_Bᵀ B⁻¹ A_j (phase-dependent c).
    d: Vec<f64>,
    /// Basis: row → var.
    basis: Vec<usize>,
    state: Vec<VarState>,
    lower: Vec<f64>,
    upper: Vec<f64>,
    /// Current nonbasic resting value of each variable.
    xval: Vec<f64>,
    m: usize,
    ntot: usize,
    iterations: usize,
}

impl Tableau {
    fn value(&self, j: usize) -> f64 {
        match self.state[j] {
            VarState::Basic(r) => self.xb[r],
            _ => self.xval[j],
        }
    }

    /// One simplex phase: minimize the cost vector already loaded in `d`.
    /// `col_limit` bounds the columns touched by pivot updates (phase 2
    /// passes the structural+slack count: artificial columns are pinned
    /// to zero and never read again, so updating them is wasted work).
    /// Returns Ok(()) at optimality, Err(Unbounded) otherwise.
    fn optimize(&mut self, max_iter: usize, fixed: &[bool], col_limit: usize) -> Result<(), LpStatus> {
        let mut stall = 0usize;
        for _ in 0..max_iter {
            self.iterations += 1;
            let bland = stall > 2 * (self.m + self.ntot);
            // --- pricing: pick entering variable ---
            // score = rate of objective decrease per unit step (> 0 ⇒
            // improving). AtLower moves up (rate −d_j), AtUpper moves
            // down (rate +d_j); free nonbasics (l = −∞, u = +∞, resting
            // at 0 with AtLower state) may move either way.
            let mut enter: Option<(usize, f64, f64)> = None; // (var, dir, score)
            for j in 0..col_limit {
                if fixed[j] || self.lower[j] == self.upper[j] {
                    continue;
                }
                let cand: Option<(f64, f64)> = match self.state[j] {
                    VarState::Basic(_) => None,
                    VarState::AtLower => {
                        let free = self.lower[j] == -INF && self.upper[j] == INF;
                        if self.d[j] < -OPT_TOL {
                            Some((1.0, -self.d[j]))
                        } else if free && self.d[j] > OPT_TOL {
                            Some((-1.0, self.d[j]))
                        } else {
                            None
                        }
                    }
                    VarState::AtUpper => {
                        if self.d[j] > OPT_TOL {
                            Some((-1.0, self.d[j]))
                        } else {
                            None
                        }
                    }
                };
                if let Some((dir, score)) = cand {
                    if bland {
                        enter = Some((j, dir, score));
                        break;
                    }
                    if enter.map_or(true, |(_, _, s)| score > s) {
                        enter = Some((j, dir, score));
                    }
                }
            }
            let Some((j, dir, _)) = enter else {
                return Ok(()); // optimal
            };

            // --- ratio test ---
            // x_j moves by dir·t; basic i moves by −a[i][j]·dir·t.
            let own_range = self.upper[j] - self.lower[j]; // may be INF
            let mut t_star = own_range;
            let mut leave: Option<(usize, VarState)> = None; // (row, bound hit)
            for i in 0..self.m {
                let rate = self.a[i][j] * dir; // x_b[i] decreases at `rate`
                let bi = self.basis[i];
                if rate > PIVOT_TOL {
                    if self.lower[bi] > -INF {
                        let t = (self.xb[i] - self.lower[bi]) / rate;
                        if t < t_star - FEAS_TOL
                            || (bland && t <= t_star + FEAS_TOL && leave.is_none())
                        {
                            t_star = t.max(0.0);
                            leave = Some((i, VarState::AtLower));
                        }
                    }
                } else if rate < -PIVOT_TOL && self.upper[bi] < INF {
                    let t = (self.upper[bi] - self.xb[i]) / (-rate);
                    if t < t_star - FEAS_TOL || (bland && t <= t_star + FEAS_TOL && leave.is_none())
                    {
                        t_star = t.max(0.0);
                        leave = Some((i, VarState::AtUpper));
                    }
                }
            }

            if t_star == INF {
                return Err(LpStatus::Unbounded);
            }

            // --- apply step ---
            // Degenerate steps make no objective progress; count them and
            // fall back to Bland's rule to guarantee termination.
            if t_star <= FEAS_TOL {
                stall += 1;
            } else {
                stall = 0;
            }

            match leave {
                None => {
                    // Bound flip: entering variable crosses to its other
                    // bound; basics shift, basis unchanged.
                    let delta = dir * t_star;
                    for i in 0..self.m {
                        self.xb[i] -= self.a[i][j] * delta;
                    }
                    self.xval[j] += delta;
                    self.state[j] = if dir > 0.0 { VarState::AtUpper } else { VarState::AtLower };
                }
                Some((r, bound_hit)) => {
                    // Update basic values for the step, then pivot.
                    let delta = dir * t_star;
                    for i in 0..self.m {
                        self.xb[i] -= self.a[i][j] * delta;
                    }
                    let entering_value = self.xval[j] + delta;
                    let leaving = self.basis[r];
                    // Snap the leaving variable exactly onto its bound.
                    let leave_val = match bound_hit {
                        VarState::AtLower => self.lower[leaving],
                        VarState::AtUpper => self.upper[leaving],
                        VarState::Basic(_) => unreachable!(),
                    };
                    self.xval[leaving] = leave_val;
                    self.state[leaving] = bound_hit;

                    // Pivot row r on column j.
                    let piv = self.a[r][j];
                    debug_assert!(piv.abs() > PIVOT_TOL, "tiny pivot {piv}");
                    let inv = 1.0 / piv;
                    for col in 0..col_limit {
                        self.a[r][col] *= inv;
                    }
                    for i in 0..self.m {
                        if i != r {
                            let f = self.a[i][j];
                            if f != 0.0 {
                                for col in 0..col_limit {
                                    self.a[i][col] -= f * self.a[r][col];
                                }
                                self.a[i][j] = 0.0; // exact zero
                            }
                        }
                    }
                    // Reduced-cost row update.
                    let f = self.d[j];
                    if f != 0.0 {
                        for col in 0..col_limit {
                            self.d[col] -= f * self.a[r][col];
                        }
                        self.d[j] = 0.0;
                    }
                    self.basis[r] = j;
                    self.state[j] = VarState::Basic(r);
                    self.xb[r] = entering_value;
                }
            }
        }
        Err(LpStatus::IterationLimit)
    }
}

/// Solve an [`LpProblem`]. Deterministic; exact up to f64 tolerance.
pub fn solve(p: &LpProblem) -> LpSolution {
    let n = p.num_vars();
    let m = p.num_rows();
    if m == 0 {
        // Bound-only problem: each variable sits at whichever finite
        // bound minimizes its cost term.
        let mut x = vec![0.0; n];
        for j in 0..n {
            x[j] = trivially_best(p.c[j], p.lower[j], p.upper[j]);
        }
        let feasible = x.iter().all(|v| v.is_finite());
        return LpSolution {
            status: if feasible { LpStatus::Optimal } else { LpStatus::Unbounded },
            objective: p.objective(&x),
            x,
            iterations: 0,
        };
    }

    // Layout: [structural 0..n | slack n..n+ns | artificial ...]
    let mut lower = p.lower.clone();
    let mut upper = p.upper.clone();
    let mut cols: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n]; // col → (row, coef)
    for (i, row) in p.rows.iter().enumerate() {
        for &(j, a) in &row.coeffs {
            if a != 0.0 {
                cols[j].push((i, a));
            }
        }
    }
    let mut slack_of_row: Vec<Option<usize>> = vec![None; m];
    for (i, row) in p.rows.iter().enumerate() {
        match row.cmp {
            Cmp::Le => {
                let j = lower.len();
                lower.push(0.0);
                upper.push(INF);
                cols.push(vec![(i, 1.0)]);
                slack_of_row[i] = Some(j);
            }
            Cmp::Ge => {
                let j = lower.len();
                lower.push(0.0);
                upper.push(INF);
                cols.push(vec![(i, -1.0)]);
                slack_of_row[i] = Some(j);
            }
            Cmp::Eq => {}
        }
    }
    let n_struct_slack = lower.len();
    // Artificials: one per row (identity basis).
    for _ in 0..m {
        lower.push(0.0);
        upper.push(INF);
    }
    let ntot = lower.len();

    // Initial nonbasic values: finite bound nearest zero; 0 for free vars.
    let mut xval = vec![0.0; ntot];
    for j in 0..n_struct_slack {
        xval[j] = initial_rest(lower[j], upper[j]);
    }

    // Dense tableau rows; artificial columns get ±1 to make residuals
    // nonnegative.
    let mut a = vec![vec![0.0f64; ntot]; m];
    for (j, col) in cols.iter().enumerate() {
        for &(i, v) in col {
            a[i][j] = v;
        }
    }
    let mut xb = vec![0.0f64; m];
    for i in 0..m {
        let mut resid = p.rows[i].rhs;
        for j in 0..n_struct_slack {
            resid -= a[i][j] * xval[j];
        }
        // Keep the basis an identity: if the residual is negative, negate
        // the whole row (coefficients and rhs) so the artificial enters
        // with +1 and a nonnegative value.
        if resid < 0.0 {
            for v in a[i].iter_mut() {
                *v = -*v;
            }
            resid = -resid;
            // rhs negation is implicit: xb stores the shifted residual.
        }
        let art = n_struct_slack + i;
        a[i][art] = 1.0;
        xb[i] = resid;
    }

    let mut state = vec![VarState::AtLower; ntot];
    for j in 0..n_struct_slack {
        state[j] = if xval[j] == upper[j] && upper[j].is_finite() && lower[j] != upper[j] {
            VarState::AtUpper
        } else {
            VarState::AtLower
        };
    }
    let mut basis = Vec::with_capacity(m);
    for i in 0..m {
        let art = n_struct_slack + i;
        basis.push(art);
        state[art] = VarState::Basic(i);
    }

    // Phase-1 reduced costs: c = e on artificials ⇒ d_j = −Σ_i a[i][j]
    // for nonbasic j (c_B = 1 on all rows), d on artificials = 0.
    let mut d = vec![0.0f64; ntot];
    for j in 0..n_struct_slack {
        let mut s = 0.0;
        for i in 0..m {
            s += a[i][j];
        }
        d[j] = -s;
    }

    let mut t = Tableau {
        a,
        xb,
        d,
        basis,
        state,
        lower: lower.clone(),
        upper: upper.clone(),
        xval,
        m,
        ntot,
        iterations: 0,
    };

    let max_iter = 50 * (m + ntot) + 1000;
    let fixed_none = vec![false; ntot];
    // Phase 1 (artificials active: full column range).
    match t.optimize(max_iter, &fixed_none, ntot) {
        Ok(()) => {}
        Err(LpStatus::Unbounded) => {
            // Phase-1 objective is bounded below by 0; unbounded is a bug.
            unreachable!("phase-1 cannot be unbounded");
        }
        Err(s) => return failed(s, n, t.iterations),
    }
    let phase1_obj: f64 = (0..m)
        .filter(|&i| t.basis[i] >= n_struct_slack)
        .map(|i| t.xb[i])
        .sum();
    if phase1_obj > 1e-6 {
        return failed(LpStatus::Infeasible, n, t.iterations);
    }

    // Pin artificials to zero so they can never re-enter; drive basic
    // artificials out where possible.
    let mut fixed = vec![false; ntot];
    for jart in n_struct_slack..ntot {
        t.lower[jart] = 0.0;
        t.upper[jart] = 0.0;
        fixed[jart] = true;
    }
    for r in 0..m {
        let b = t.basis[r];
        if b >= n_struct_slack {
            // Degenerate basic artificial (value ~0). Pivot in any
            // structural/slack column with a usable entry.
            let mut found = None;
            for j in 0..n_struct_slack {
                if !matches!(t.state[j], VarState::Basic(_)) && t.a[r][j].abs() > 1e-7 {
                    found = Some(j);
                    break;
                }
            }
            if let Some(j) = found {
                // Manual degenerate pivot (step 0).
                let piv = t.a[r][j];
                let inv = 1.0 / piv;
                for col in 0..t.ntot {
                    t.a[r][col] *= inv;
                }
                for i in 0..t.m {
                    if i != r {
                        let f = t.a[i][j];
                        if f != 0.0 {
                            for col in 0..t.ntot {
                                t.a[i][col] -= f * t.a[r][col];
                            }
                            t.a[i][j] = 0.0;
                        }
                    }
                }
                let entering_value = t.xval[j];
                t.state[b] = VarState::AtLower;
                t.xval[b] = 0.0;
                t.basis[r] = j;
                t.state[j] = VarState::Basic(r);
                t.xb[r] = entering_value; // ≈ old xb[r] = 0 shifted basis
            }
            // else: redundant row; artificial stays basic at 0 forever
            // (bounds [0,0] keep it there).
        }
    }

    // Phase-2 reduced costs from the real objective.
    let mut c2 = vec![0.0f64; ntot];
    c2[..n].copy_from_slice(&p.c);
    // d_j = c_j − c_Bᵀ B⁻¹ A_j; B⁻¹A is the current tableau.
    let cb: Vec<f64> = t.basis.iter().map(|&b| c2[b]).collect();
    for j in 0..ntot {
        if matches!(t.state[j], VarState::Basic(_)) {
            t.d[j] = 0.0;
            continue;
        }
        let mut z = 0.0;
        for i in 0..m {
            if cb[i] != 0.0 {
                z += cb[i] * t.a[i][j];
            }
        }
        t.d[j] = c2[j] - z;
    }

    // Phase 2: artificial columns are fixed at zero and never re-enter;
    // exclude them from pivot updates entirely.
    let status = match t.optimize(max_iter, &fixed, n_struct_slack) {
        Ok(()) => LpStatus::Optimal,
        Err(s) => s,
    };
    // Extract structural solution.
    let mut x = vec![0.0; n];
    for j in 0..n {
        x[j] = t.value(j);
    }
    LpSolution { status, objective: p.objective(&x), x, iterations: t.iterations }
}

fn failed(status: LpStatus, n: usize, iterations: usize) -> LpSolution {
    LpSolution { status, x: vec![f64::NAN; n], objective: f64::NAN, iterations }
}

fn initial_rest(l: f64, u: f64) -> f64 {
    if l > -INF && u < INF {
        if l.abs() <= u.abs() {
            l
        } else {
            u
        }
    } else if l > -INF {
        l
    } else if u < INF {
        u
    } else {
        0.0
    }
}

fn trivially_best(c: f64, l: f64, u: f64) -> f64 {
    if c > 0.0 {
        l
    } else if c < 0.0 {
        u
    } else if l > -INF {
        l
    } else if u < INF {
        u
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(sol: &LpSolution, obj: f64, tol: f64) {
        assert_eq!(sol.status, LpStatus::Optimal, "{sol:?}");
        assert!(
            (sol.objective - obj).abs() <= tol,
            "objective {} != expected {obj}",
            sol.objective
        );
    }

    #[test]
    fn textbook_max_problem() {
        // max 3x + 5y s.t. x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18, x,y ≥ 0
        // ⇒ min −3x −5y; optimum (2, 6), obj −36.
        let mut p = LpProblem::new();
        let x = p.add_var(-3.0, 0.0, INF);
        let y = p.add_var(-5.0, 0.0, INF);
        p.add_row(vec![(x, 1.0)], Cmp::Le, 4.0);
        p.add_row(vec![(y, 2.0)], Cmp::Le, 12.0);
        p.add_row(vec![(x, 3.0), (y, 2.0)], Cmp::Le, 18.0);
        let sol = solve(&p);
        assert_opt(&sol, -36.0, 1e-7);
        assert!((sol.x[0] - 2.0).abs() < 1e-7);
        assert!((sol.x[1] - 6.0).abs() < 1e-7);
        assert!(p.is_feasible(&sol.x, 1e-7));
    }

    #[test]
    fn equality_constraints() {
        // min x + y s.t. x + y = 10, x − y = 2, x,y ≥ 0 → (6,4), obj 10.
        let mut p = LpProblem::new();
        let x = p.add_var(1.0, 0.0, INF);
        let y = p.add_var(1.0, 0.0, INF);
        p.add_row(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 10.0);
        p.add_row(vec![(x, 1.0), (y, -1.0)], Cmp::Eq, 2.0);
        let sol = solve(&p);
        assert_opt(&sol, 10.0, 1e-7);
        assert!((sol.x[0] - 6.0).abs() < 1e-7);
    }

    #[test]
    fn ge_constraints_and_bounds() {
        // min 2x + 3y s.t. x + y ≥ 5, x ≤ 3, y ≤ 4, x,y ≥ 0.
        // Cheapest: x = 3 (cost 2), y = 2 → obj 12.
        let mut p = LpProblem::new();
        let x = p.add_var(2.0, 0.0, 3.0);
        let y = p.add_var(3.0, 0.0, 4.0);
        p.add_row(vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 5.0);
        let sol = solve(&p);
        assert_opt(&sol, 12.0, 1e-7);
    }

    #[test]
    fn infeasible_detected() {
        let mut p = LpProblem::new();
        let x = p.add_var(1.0, 0.0, 1.0);
        p.add_row(vec![(x, 1.0)], Cmp::Ge, 2.0);
        assert_eq!(solve(&p).status, LpStatus::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut p = LpProblem::new();
        let x = p.add_var(-1.0, 0.0, INF);
        p.add_row(vec![(x, 1.0)], Cmp::Ge, 0.0);
        assert_eq!(solve(&p).status, LpStatus::Unbounded);
    }

    #[test]
    fn bound_only_problem() {
        let mut p = LpProblem::new();
        p.add_var(1.0, -2.0, 5.0); // min → lower
        p.add_var(-1.0, -2.0, 5.0); // min → upper
        let sol = solve(&p);
        assert_opt(&sol, -7.0, 1e-12);
        assert_eq!(sol.x, vec![-2.0, 5.0]);
    }

    #[test]
    fn negative_rhs_rows() {
        // min x s.t. −x ≤ −3 (i.e. x ≥ 3).
        let mut p = LpProblem::new();
        let x = p.add_var(1.0, 0.0, INF);
        p.add_row(vec![(x, -1.0)], Cmp::Le, -3.0);
        let sol = solve(&p);
        assert_opt(&sol, 3.0, 1e-7);
    }

    #[test]
    fn free_variable() {
        // min |shift|-style: min y s.t. y ≥ x − 4, y ≥ 4 − x, x free.
        // Optimum x = 4, y = 0.
        let mut p = LpProblem::new();
        let x = p.add_var(0.0, -INF, INF);
        let y = p.add_var(1.0, -INF, INF);
        p.add_row(vec![(y, 1.0), (x, -1.0)], Cmp::Ge, -4.0);
        p.add_row(vec![(y, 1.0), (x, 1.0)], Cmp::Ge, 4.0);
        let sol = solve(&p);
        assert_opt(&sol, 0.0, 1e-7);
        assert!((sol.x[0] - 4.0).abs() < 1e-7);
    }

    #[test]
    fn degenerate_vertex() {
        // Multiple constraints meet at the optimum — exercises degenerate
        // pivots. min −x − y s.t. x + y ≤ 1, x ≤ 1, y ≤ 1, x + 2y ≤ 2.
        let mut p = LpProblem::new();
        let x = p.add_var(-1.0, 0.0, INF);
        let y = p.add_var(-1.0, 0.0, INF);
        p.add_row(vec![(x, 1.0), (y, 1.0)], Cmp::Le, 1.0);
        p.add_row(vec![(x, 1.0)], Cmp::Le, 1.0);
        p.add_row(vec![(y, 1.0)], Cmp::Le, 1.0);
        p.add_row(vec![(x, 1.0), (y, 2.0)], Cmp::Le, 2.0);
        let sol = solve(&p);
        assert_opt(&sol, -1.0, 1e-7);
    }

    #[test]
    fn redundant_equalities() {
        // x + y = 2 stated twice — phase 1 leaves a basic artificial on a
        // redundant row.
        let mut p = LpProblem::new();
        let x = p.add_var(1.0, 0.0, INF);
        let y = p.add_var(2.0, 0.0, INF);
        p.add_row(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 2.0);
        p.add_row(vec![(x, 2.0), (y, 2.0)], Cmp::Eq, 4.0);
        let sol = solve(&p);
        assert_opt(&sol, 2.0, 1e-7);
        assert!((sol.x[0] - 2.0).abs() < 1e-7);
    }

    #[test]
    fn random_lps_feasible_and_not_worse_than_samples() {
        // Property: on random feasible LPs, the solver's solution is
        // feasible and no random feasible point beats it.
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(2024);
        for case in 0..30 {
            let nv = 2 + (case % 4);
            let mut p = LpProblem::new();
            for _ in 0..nv {
                let c = rng.range_f64(-2.0, 2.0);
                p.add_var(c, 0.0, rng.range_f64(1.0, 5.0));
            }
            // Rows of the form Σ a_j x_j ≤ b with b large enough that
            // x = 0 is feasible (b ≥ 0).
            for _ in 0..nv {
                let coeffs: Vec<(usize, f64)> =
                    (0..nv).map(|j| (j, rng.range_f64(-1.0, 2.0))).collect();
                p.add_row(coeffs, Cmp::Le, rng.range_f64(0.5, 6.0));
            }
            let sol = solve(&p);
            assert_eq!(sol.status, LpStatus::Optimal, "case {case}");
            assert!(p.is_feasible(&sol.x, 1e-6), "case {case}: {:?}", sol.x);
            // Random feasible points never beat the reported optimum.
            for _ in 0..200 {
                let cand: Vec<f64> =
                    (0..nv).map(|j| rng.range_f64(0.0, p.upper[j])).collect();
                if p.is_feasible(&cand, 1e-9) {
                    assert!(
                        p.objective(&cand) >= sol.objective - 1e-6,
                        "case {case}: sampled point beats 'optimum'"
                    );
                }
            }
        }
    }
}
