//! `tfreeze` — the TimelyFreeze launcher.
//!
//! Subcommands:
//!   simulate   run one paper-scale experiment through the event-driven
//!              simulator and print its result row (alias: sim)
//!   table      run a full table grid (4 schedules × 6 methods)
//!   train      train end-to-end on the real PJRT pipeline engine
//!   gantt      render a pipeline execution as ASCII (and optionally SVG)
//!   lp         LP walkthrough on measured bounds (Figure 2 example)
//!   schedules  print per-rank schedule orders
//!
//! Runtime dynamics ride on `simulate`: `tfreeze sim --scenario
//! "straggler:1x1.5@300,jitter:0.05"` perturbs execution, and
//! `--replan 50` turns on observation-driven online replanning.
//! Run `tfreeze help` for flags.

use timelyfreeze::config::ExperimentConfig;
use timelyfreeze::freeze::PhaseConfig;
use timelyfreeze::lp;
use timelyfreeze::sim;
use timelyfreeze::types::{FreezeMethod, ScheduleKind};
use timelyfreeze::util::cli::{render_help, Args, FlagSpec};
use timelyfreeze::util::table::Table;
use timelyfreeze::viz;

fn flag_specs() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "preset", takes_value: true, help: "model preset: llama-1b|llama-8b|llama-13b|vit-l32|convnextv2-l" },
        FlagSpec { name: "schedule", takes_value: true, help: "gpipe|1f1b|interleaved|zbv|synth (synth generates the order via the schedule-synthesis portfolio)" },
        FlagSpec { name: "method", takes_value: true, help: "none|apf|autofreeze|timely|timely+apf|timely+auto" },
        FlagSpec { name: "steps", takes_value: true, help: "training steps" },
        FlagSpec { name: "r-max", takes_value: true, help: "max average freeze ratio per stage" },
        FlagSpec { name: "mem-budget", takes_value: true, help: "fraction of device memory available (0,1]; enables the memory-aware LP floor" },
        FlagSpec { name: "rank-mem", takes_value: true, help: "per-rank device memory in GB for mixed clusters, e.g. 48,48,24,48 (with --mem-budget)" },
        FlagSpec { name: "recompute", takes_value: true, help: "activation recompute policy: off|full|auto|<fraction>; auto covers memory deficits beyond r_max by re-running forwards" },
        FlagSpec { name: "scenario", takes_value: true, help: "runtime dynamics and faults, e.g. straggler:1x1.5@300,jitter:0.05 or crash:2@500 (see docs)" },
        FlagSpec { name: "net", takes_value: true, help: "network topology: an inline spec (uniform | island:<size>x<bw>,spine:<bw>[,lat:<s>]) or a TOML file with a [network] section" },
        FlagSpec { name: "elastic", takes_value: false, help: "recover from rank faults elastically (shorthand for --recovery elastic)" },
        FlagSpec { name: "recovery", takes_value: true, help: "fault recovery strategy: elastic | restart (from-scratch baseline)" },
        FlagSpec { name: "ckpt-interval", takes_value: true, help: "microbatch checkpoint cadence for elastic recovery (0 = step boundaries only)" },
        FlagSpec { name: "replan", takes_value: true, help: "online replanning cadence in steps (0 = static plan)" },
        FlagSpec { name: "watchdog", takes_value: true, help: "divergence watchdog threshold in sigmas; fires an event-driven replan on sustained realized-vs-planned divergence" },
        FlagSpec { name: "exec", takes_value: true, help: "executor: event (discrete-event engine) | event-wc (bounded work-conserving dispatch) | analytic (fast sweep)" },
        FlagSpec { name: "seed", takes_value: true, help: "random seed" },
        FlagSpec { name: "ranks", takes_value: true, help: "pipeline ranks (GPUs)" },
        FlagSpec { name: "microbatches", takes_value: true, help: "microbatches per step" },
        FlagSpec { name: "artifacts", takes_value: true, help: "artifacts directory (train)" },
        FlagSpec { name: "blocks", takes_value: true, help: "transformer blocks (train)" },
        FlagSpec { name: "stages", takes_value: true, help: "pipeline stages (train)" },
        FlagSpec { name: "lr", takes_value: true, help: "base learning rate (train)" },
        FlagSpec { name: "warmup", takes_value: true, help: "phase boundary T_w" },
        FlagSpec { name: "monitor", takes_value: true, help: "phase boundary T_m" },
        FlagSpec { name: "freeze", takes_value: true, help: "phase boundary T_f" },
        FlagSpec { name: "svg", takes_value: true, help: "write SVG gantt to this path" },
        FlagSpec { name: "config", takes_value: true, help: "TOML config overriding the preset" },
        FlagSpec { name: "steady", takes_value: false, help: "report post-T_f steady throughput" },
        FlagSpec { name: "help", takes_value: false, help: "show help" },
    ]
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let specs = flag_specs();
    let args = match Args::parse(&raw, &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    if args.flag_bool("help") || cmd == "help" {
        println!("{}", render_help("tfreeze", "TimelyFreeze pipeline-parallel trainer", &specs));
        println!("subcommands: simulate (sim) | table | train | gantt | lp | schedules");
        return;
    }
    let result = match cmd.as_str() {
        "simulate" | "sim" => cmd_simulate(&args),
        "table" => cmd_table(&args),
        "train" => cmd_train(&args),
        "gantt" => cmd_gantt(&args),
        "lp" => cmd_lp(&args),
        "schedules" => cmd_schedules(&args),
        other => Err(format!("unknown subcommand '{other}' (try `tfreeze help`)")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn build_sim_config(args: &Args) -> Result<ExperimentConfig, String> {
    let preset = args.flag_or("preset", "llama-1b");
    let mut cfg = ExperimentConfig::paper_preset(&preset)
        .ok_or_else(|| format!("unknown preset '{preset}'"))?;
    if let Some(path) = args.flag("config") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        let doc = timelyfreeze::util::toml::TomlDoc::parse(&text).map_err(|e| e.to_string())?;
        cfg.apply_toml(&doc)?;
    }
    if let Some(s) = args.flag("schedule") {
        cfg.schedule = ScheduleKind::parse(s).ok_or_else(|| format!("bad schedule '{s}'"))?;
    }
    if let Some(m) = args.flag("method") {
        cfg.method = FreezeMethod::parse(m).ok_or_else(|| format!("bad method '{m}'"))?;
    }
    if let Some(v) = args.flag_usize("steps")? {
        cfg.steps = v;
    }
    if let Some(v) = args.flag_f64("r-max")? {
        cfg.r_max = v;
    }
    if let Some(v) = args.flag_f64("mem-budget")? {
        if !(0.0..=1.0).contains(&v) || v == 0.0 {
            return Err(format!("mem-budget {v} outside (0,1]"));
        }
        cfg.memory_budget = Some(v);
    }
    if let Some(spec) = args.flag("rank-mem") {
        let caps: Vec<f64> = spec
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f64>()
                    .ok()
                    .filter(|g| *g > 0.0 && g.is_finite())
                    .map(|g| g * 1e9)
                    .ok_or_else(|| format!("bad rank-mem entry '{s}' (GB, positive)"))
            })
            .collect::<Result<_, _>>()?;
        cfg.rank_memory_bytes = Some(caps);
    }
    if let Some(spec) = args.flag("recompute") {
        cfg.recompute = timelyfreeze::cost::RecomputePolicy::parse(spec)?;
    }
    if let Some(spec) = args.flag("scenario") {
        cfg.scenario = Some(timelyfreeze::config::Scenario::parse(spec)?);
    }
    if let Some(spec) = args.flag("net") {
        // A value naming a readable file is a topology TOML; anything
        // else parses as an inline spec.
        cfg.net = Some(match std::fs::read_to_string(spec) {
            Ok(text) => {
                let doc = timelyfreeze::util::toml::TomlDoc::parse(&text)
                    .map_err(|e| format!("parsing {spec}: {e}"))?;
                timelyfreeze::net::Topology::from_toml(&doc)
                    .map_err(|e| format!("in {spec}: {e}"))?
                    .ok_or_else(|| format!("{spec} has no [network] section"))?
            }
            Err(_) => timelyfreeze::net::Topology::parse(spec)?,
        });
    }
    if args.flag_bool("elastic") {
        cfg.recovery = Some(timelyfreeze::config::RecoveryStrategy::Elastic);
    }
    if let Some(s) = args.flag("recovery") {
        cfg.recovery = Some(
            timelyfreeze::config::RecoveryStrategy::parse(s)
                .ok_or_else(|| format!("bad recovery strategy '{s}' (elastic|restart)"))?,
        );
    }
    if let Some(v) = args.flag_usize("ckpt-interval")? {
        cfg.ckpt_interval = v;
    }
    if let Some(v) = args.flag_usize("replan")? {
        cfg.replan_interval = v;
    }
    if let Some(v) = args.flag_f64("watchdog")? {
        if !(v > 0.0) || !v.is_finite() {
            return Err(format!("watchdog sigma {v} must be positive and finite"));
        }
        cfg.watchdog = Some(v);
    }
    if let Some(s) = args.flag("exec") {
        cfg.exec = timelyfreeze::config::ExecMode::parse(s)
            .ok_or_else(|| format!("bad exec mode '{s}' (event|event-wc|analytic)"))?;
    }
    if let Some(v) = args.flag_u64("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.flag_usize("ranks")? {
        cfg.ranks = v;
    }
    if let Some(v) = args.flag_usize("microbatches")? {
        cfg.microbatches = v;
    }
    let (mut w, mut m, mut f) =
        (cfg.phases.t_warmup, cfg.phases.t_monitor, cfg.phases.t_freeze);
    if let Some(v) = args.flag_usize("warmup")? {
        w = v;
    }
    if let Some(v) = args.flag_usize("monitor")? {
        m = v;
    }
    if let Some(v) = args.flag_usize("freeze")? {
        f = v;
    }
    if w >= m || m >= f {
        return Err(format!("phase boundaries must satisfy {w} < {m} < {f}"));
    }
    cfg.phases = PhaseConfig::new(w, m, f);
    // Validate the memory budget upfront so the subcommand reports an
    // unsatisfiable one (device overflow, or a floor above r_max) as a
    // clean CLI error instead of a panic mid-run. The simulator derives
    // the same floor from the same helper, so preview and run agree.
    // (`table` re-validates per swept schedule — feasibility depends on
    // the schedule's in-flight activation profile.)
    validate_memory_budget(&cfg)?;
    if let Some(sc) = &cfg.scenario {
        sc.validate(cfg.ranks, cfg.stages())
            .map_err(|e| format!("invalid scenario: {e}"))?;
    }
    Ok(cfg)
}

/// Resolve the config's memory policy (budget fraction, per-rank
/// capacities, recompute) to a per-stage plan for the schedule it
/// currently names, surfacing infeasibility as a CLI error.
fn validate_memory_budget(cfg: &ExperimentConfig) -> Result<(), String> {
    if cfg.memory_budget.is_none()
        && cfg.rank_memory_bytes.is_none()
        && cfg.recompute.is_off()
    {
        return Ok(());
    }
    // Resolve the schedule first (`--schedule synth` generates it), so
    // the memory plan is checked against the shape the run will use.
    let world = sim::resolve_world(cfg, timelyfreeze::partition::PartitionMethod::Parameter);
    timelyfreeze::cost::memory_plan_for(&world.cfg, &world.layout.layer_stage, &world.schedule)
        .map(|_| ())
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let cfg = build_sim_config(args)?;
    let r = sim::run(&cfg).map_err(|e| e.to_string())?;
    println!(
        "{} · {} · {} — {} steps ({} executor)",
        cfg.model.name,
        cfg.schedule.name(),
        cfg.method.name(),
        cfg.steps,
        cfg.exec.name()
    );
    if let Some(sc) = &cfg.scenario {
        println!("  scenario        {sc}");
    }
    if let Some(topo) = &cfg.net {
        println!("  network         {}", topo.label());
    }
    let thpt = if args.flag_bool("steady") { r.steady_throughput } else { r.throughput };
    println!("  throughput      {:>10.0} tokens/s", thpt);
    println!("  MFU             {:>10.2} %", r.mfu);
    println!("  freeze ratio    {:>10.2} %", r.freeze_ratio);
    println!("  accuracy proxy  {:>10.2}", r.accuracy);
    println!(
        "  batch time      {:>10.4} s (no-freeze {:.4} s)",
        r.batch_time_final, r.batch_time_nofreeze
    );
    println!("  bubble fraction {:>10.2} %", 100.0 * r.bubble_fraction);
    println!(
        "  peak in-flight  {:>10} microbatches (max over {} stages)",
        r.peak_inflight.iter().copied().max().unwrap_or(0),
        r.peak_inflight.len()
    );
    if let Some(planned) = r.planned_batch_time {
        println!(
            "  planned P_d*    {:>10.4} s ({} replans)",
            planned, r.replans
        );
    }
    if !r.watchdog_triggers.is_empty() {
        let shown: Vec<String> =
            r.watchdog_triggers.iter().take(6).map(|t| t.to_string()).collect();
        let more = r.watchdog_triggers.len().saturating_sub(shown.len());
        let tail = if more > 0 { format!(" (+{more} more)") } else { String::new() };
        println!(
            "  watchdog        {:>10} trigger(s) at steps {}{tail}",
            r.watchdog_triggers.len(),
            shown.join(", ")
        );
    }
    if !r.degradation.is_empty() {
        println!("  warning: {}", r.degradation.summary());
    }
    if let Some(rho) = &r.recompute {
        println!(
            "  recompute       {} (mean ρ {:.3})",
            cfg.recompute.name(),
            rho.iter().sum::<f64>() / rho.len() as f64
        );
    }
    if r.faults > 0 {
        let strategy = cfg
            .recovery
            .map(|s| s.name())
            .unwrap_or("none");
        println!(
            "  faults          {:>10} ({} recovery, {}/{} ranks finished)",
            r.faults, strategy, r.final_ranks, cfg.ranks
        );
        println!("  lost microbatches {:>8}", r.lost_microbatches);
        println!("  recovery time   {:>10.2} s", r.recovery_time_s);
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<(), String> {
    let base = build_sim_config(args)?;
    // A memory budget feasible for the base schedule can be infeasible
    // for another (GPipe keeps every microbatch's activations in
    // flight); check each swept schedule before running any cell.
    for schedule in ScheduleKind::all() {
        let mut probe = base.clone();
        probe.schedule = schedule;
        validate_memory_budget(&probe)?;
    }
    for schedule in ScheduleKind::all() {
        let mut t = Table::new(
            &format!("{} — {}", base.model.name, schedule.name()),
            &["Method", "Avg. Acc. (Δ)", "Frz. Ratio", "Throughput (Δ%)", "MFU"],
        );
        let mut baseline: Option<sim::SimResult> = None;
        for method in FreezeMethod::all() {
            let mut cfg = base.clone();
            cfg.schedule = schedule;
            cfg.method = method;
            let r = sim::run(&cfg).map_err(|e| e.to_string())?;
            let b = baseline.get_or_insert_with(|| r.clone());
            t.row(vec![
                method.name().to_string(),
                format!("{:.2} ({:+.2})", r.accuracy, r.acc_delta(b)),
                format!("{:.2}", r.freeze_ratio),
                format!("{:.0} ({:+.2})", r.throughput, r.throughput_delta_pct(b)),
                format!("{:.2}", r.mfu),
            ]);
        }
        println!("{}", t.render());
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> Result<(), String> {
    Err(
        "this binary was built without the `pjrt` feature; the real PJRT engine \
         needs the external `xla`/`anyhow` crates (see Cargo.toml). \
         Rebuild with `--features pjrt`, or use `simulate` for the \
         discrete-event runner."
            .to_string(),
    )
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> Result<(), String> {
    use timelyfreeze::bench_support;
    use timelyfreeze::engine::{self, EngineConfig};
    let artifacts = args.flag_or(
        "artifacts",
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"),
    );
    let mut cfg = EngineConfig::quick_defaults(artifacts.into());
    if let Some(v) = args.flag_usize("blocks")? {
        cfg.blocks = v;
    }
    if let Some(v) = args.flag_usize("stages")? {
        cfg.stages = v;
    }
    if let Some(v) = args.flag_usize("microbatches")? {
        cfg.microbatches = v;
    }
    if let Some(v) = args.flag_usize("steps")? {
        cfg.steps = v;
    }
    if let Some(m) = args.flag("method") {
        cfg.method = FreezeMethod::parse(m).ok_or_else(|| format!("bad method '{m}'"))?;
    }
    if let Some(s) = args.flag("schedule") {
        cfg.schedule = ScheduleKind::parse(s).ok_or_else(|| format!("bad schedule '{s}'"))?;
    }
    if let Some(v) = args.flag_f64("r-max")? {
        cfg.r_max = v;
    }
    if let Some(v) = args.flag_f64("lr")? {
        cfg.base_lr = v;
    }
    if let Some(v) = args.flag_u64("seed")? {
        cfg.seed = v;
    }
    let (mut w, mut m, mut f) =
        (cfg.phases.t_warmup, cfg.phases.t_monitor, cfg.phases.t_freeze);
    if let Some(v) = args.flag_usize("warmup")? {
        w = v;
    }
    if let Some(v) = args.flag_usize("monitor")? {
        m = v;
    }
    if let Some(v) = args.flag_usize("freeze")? {
        f = v;
    }
    cfg.phases = PhaseConfig::new(w, m, f);
    println!(
        "training: {} blocks over {} stages, {} microbatches, {} ({}), {} steps",
        cfg.blocks,
        cfg.stages,
        cfg.microbatches,
        cfg.schedule.name(),
        cfg.method.name(),
        cfg.steps
    );
    let t0 = std::time::Instant::now();
    let report = engine::train(&cfg).map_err(|e| format!("{e:#}"))?;
    for p in &report.loss_curve {
        if p.step % 10 == 0 || p.step == 1 || p.step == cfg.steps {
            println!(
                "  step {:>5}  loss {:>8.4}  afr {:>5.2}  {:>8}/step",
                p.step,
                p.loss,
                p.mean_afr,
                bench_support::fmt_time(p.step_time)
            );
        }
    }
    println!(
        "done in {:.1}s — throughput {:.0} tok/s (steady {:.0}), κ = {:.3}, freeze ratio {:.1}%",
        t0.elapsed().as_secs_f64(),
        report.throughput,
        report.steady_throughput,
        report.kappa(),
        report.freeze_ratio
    );
    Ok(())
}

fn cmd_gantt(args: &Args) -> Result<(), String> {
    let mut cfg = build_sim_config(args)?;
    if args.flag("steps").is_none() {
        cfg.steps = cfg.phases.t_freeze + 30;
    }
    let r = sim::run(&cfg).map_err(|e| e.to_string())?;
    println!("— no freezing —");
    print!("{}", viz::ascii(&r.gantt_nofreeze, cfg.ranks, 100));
    println!("— {} (final step) —", cfg.method.name());
    print!("{}", viz::ascii(&r.gantt_final, cfg.ranks, 100));
    println!(
        "batch time reduction: {:.2}%",
        100.0 * (1.0 - r.batch_time_final / r.batch_time_nofreeze)
    );
    if let Some(path) = args.flag("svg") {
        let svg = viz::svg(
            &r.gantt_final,
            cfg.ranks,
            &format!("{} · {} · {}", cfg.model.name, cfg.schedule.name(), cfg.method.name()),
        );
        std::fs::write(path, svg).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_lp(args: &Args) -> Result<(), String> {
    use timelyfreeze::graph::pipeline::PipelineDag;
    let cfg = build_sim_config(args)?;
    // Resolve the schedule/layout/cost triple the same way the runner
    // does — `--schedule synth` previews the LP over the synthesized
    // order's DAG, exactly the one the simulator would execute.
    let world =
        sim::resolve_world(&cfg, timelyfreeze::partition::PartitionMethod::Parameter);
    let sim::ResolvedWorld { cfg, schedule, layout, cost, net } = world;
    let pdag = PipelineDag::from_schedule(&schedule);
    let w_min = pdag.weights(|a| cost.bounds(a).0);
    let w_max = pdag.weights(|a| cost.bounds(a).1);
    // Memory-constrained LP: resolve budget + recompute policy to the
    // per-stage floor and recompute fractions (same helper the
    // simulator runner uses), attach constraint [5], and grow the
    // backward envelopes by the recompute surcharge.
    let plan = timelyfreeze::cost::memory_plan_for(&cfg, &layout.layer_stage, &schedule)?;
    let surcharge = plan
        .recompute
        .as_ref()
        .map(|rho| cost.recompute_surcharges_for(rho));
    let mut input = lp::FreezeLpInput::new(&pdag, &w_min, &w_max, cfg.r_max, cfg.lambda);
    if let Some(f) = &plan.floor {
        input = input.with_stage_floor(f);
    }
    if let Some(sur) = &surcharge {
        input = input.with_recompute(sur);
    }
    // Under a network fabric, price cross-rank edges exactly as the
    // simulator's controller would: the contention-aware (e0, traffic)
    // split for the event executor, constant expected costs otherwise.
    let edge_comm = net.as_ref().map(|nm| {
        let pricing = if cfg.exec.is_event() {
            sim::NetLpPricing::Contended
        } else {
            sim::NetLpPricing::Expected
        };
        sim::net_edge_comm(nm, &pdag, &schedule, &cfg, pricing)
    });
    if let Some((e0, traffic)) = &edge_comm {
        input = input.with_edge_costs(e0).with_edge_traffic(traffic);
    }
    let sol = lp::solve_freeze_lp(&input).map_err(|e| e.to_string())?;
    println!(
        "LP over {} nodes / {} edges ({} iterations)",
        pdag.len(),
        pdag.dag.edge_count(),
        sol.iterations
    );
    println!("  P_d (no freezing)   {:.4} s", sol.p_d_max);
    println!("  P_d (full freezing) {:.4} s", sol.p_d_min);
    println!("  P_d* (optimized)    {:.4} s  → κ = {:.3}", sol.batch_time, sol.kappa());
    println!("  mean expected freeze ratio: {:.3}", sol.mean_freezable_ratio(&pdag));
    if let Some(rho) = &plan.recompute {
        let total: f64 = surcharge.iter().flatten().sum();
        println!(
            "  recompute policy {} — mean fraction {:.3}, surcharge Σ_s ρ_s·fwd_s = {:.4} s per microbatch",
            cfg.recompute.name(),
            rho.iter().sum::<f64>() / rho.len() as f64,
            total
        );
    }
    let mut headers = vec!["Stage", "mean r*"];
    if plan.floor.is_some() {
        headers.push("memory floor");
    }
    if plan.recompute.is_some() {
        headers.push("recompute ρ");
    }
    let mut t = Table::new("per-stage expected freeze ratios", &headers);
    let stage_ratios = sol.stage_ratios(&pdag);
    for (s, set) in pdag.freezable_by_stage().iter().enumerate() {
        if set.is_empty() {
            continue;
        }
        let mut row = vec![format!("{s}"), format!("{:.3}", stage_ratios[s])];
        if let Some(f) = &plan.floor {
            row.push(format!("{:.3}", f[s]));
        }
        if let Some(rho) = &plan.recompute {
            row.push(format!("{:.3}", rho[s]));
        }
        t.row(row);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_schedules(args: &Args) -> Result<(), String> {
    use timelyfreeze::schedule::Schedule;
    let ranks = args.flag_usize("ranks")?.unwrap_or(4);
    let microbatches = args.flag_usize("microbatches")?.unwrap_or(8);
    for kind in ScheduleKind::all().into_iter().chain([ScheduleKind::Synthesized]) {
        if let Some(s) = args.flag("schedule") {
            if ScheduleKind::parse(s) != Some(kind) {
                continue;
            }
        }
        let sched = Schedule::build(kind, ranks, microbatches, Schedule::default_chunks(kind));
        println!("== {} ({ranks} ranks × {microbatches} microbatches) ==", kind.name());
        for (rank, order) in sched.orders.iter().enumerate() {
            let line: Vec<String> = order.iter().map(|a| a.to_string()).collect();
            println!("  rank {rank}: {}", line.join(" "));
        }
        println!();
    }
    Ok(())
}
