//! Evaluation metrics (§4.2) and experiment-result recording.
//!
//! * throughput — tokens/s (language) or samples/s (vision);
//! * MFU — model-FLOPs utilization with nominal 6·P FLOPs/token;
//! * average freeze ratio — 𝔼_{t,i,j}[𝕀] over steps × parameters;
//! * time-to-accuracy bookkeeping (κ and p̄_eff of Appendix D).
//!
//! Results are written as JSON rows under `bench_out/` so figures can be
//! regenerated without re-running experiments.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Tokens/s from total tokens and elapsed seconds.
pub fn throughput(tokens: u64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return 0.0;
    }
    tokens as f64 / seconds
}

/// MFU percent with the 6·P FLOPs/token convention.
pub fn mfu_pct(throughput_tps: f64, total_params: f64, ranks: usize, peak_flops: f64) -> f64 {
    if peak_flops <= 0.0 || ranks == 0 {
        return 0.0;
    }
    100.0 * throughput_tps * 6.0 * total_params / (ranks as f64 * peak_flops)
}

/// Running average freeze ratio (param-weighted frozen fraction/step).
#[derive(Clone, Debug, Default)]
pub struct FreezeRatioMeter {
    sum: f64,
    steps: u64,
}

impl FreezeRatioMeter {
    /// Fold in one step's frozen fraction.
    pub fn push(&mut self, frozen_fraction: f64) {
        self.sum += frozen_fraction.clamp(0.0, 1.0);
        self.steps += 1;
    }

    /// Percent, averaged over all recorded steps.
    pub fn pct(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            100.0 * self.sum / self.steps as f64
        }
    }
}

/// Time-to-accuracy ratio (eq. 13): κ / p̄_eff.
pub fn tta_ratio(kappa: f64, p_eff: f64) -> f64 {
    if p_eff <= 0.0 {
        f64::INFINITY
    } else {
        kappa / p_eff
    }
}

/// Append-only experiment recorder: one JSON object per row, one file
/// per experiment id, under `bench_out/`.
pub struct Recorder {
    dir: PathBuf,
    rows: BTreeMap<String, Vec<Json>>,
}

impl Recorder {
    /// A recorder writing under `dir`.
    pub fn new<P: AsRef<Path>>(dir: P) -> Recorder {
        Recorder { dir: dir.as_ref().to_path_buf(), rows: BTreeMap::new() }
    }

    /// Standard location: `<repo>/bench_out`.
    pub fn default_dir() -> Recorder {
        Recorder::new(concat!(env!("CARGO_MANIFEST_DIR"), "/bench_out"))
    }

    /// Append a row to an experiment.
    pub fn push(&mut self, experiment: &str, row: Json) {
        self.rows.entry(experiment.to_string()).or_default().push(row);
    }

    /// Write all experiments to disk; returns written paths.
    pub fn flush(&mut self) -> std::io::Result<Vec<PathBuf>> {
        std::fs::create_dir_all(&self.dir)?;
        let mut written = Vec::new();
        for (name, rows) in &self.rows {
            let path = self.dir.join(format!("{name}.json"));
            let mut f = std::fs::File::create(&path)?;
            let doc = Json::Arr(rows.clone());
            f.write_all(doc.to_pretty().as_bytes())?;
            f.write_all(b"\n")?;
            written.push(path);
        }
        Ok(written)
    }
}

/// Build a standard result row shared by the table benches.
#[allow(clippy::too_many_arguments)]
pub fn result_row(
    schedule: &str,
    method: &str,
    accuracy: f64,
    acc_delta: f64,
    freeze_ratio: f64,
    throughput_v: f64,
    throughput_delta_pct: f64,
    mfu: f64,
) -> Json {
    Json::obj(vec![
        ("schedule", Json::str(schedule)),
        ("method", Json::str(method)),
        ("accuracy", Json::num(accuracy)),
        ("acc_delta", Json::num(acc_delta)),
        ("freeze_ratio", Json::num(freeze_ratio)),
        ("throughput", Json::num(throughput_v)),
        ("throughput_delta_pct", Json::num(throughput_delta_pct)),
        ("mfu", Json::num(mfu)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_and_mfu() {
        assert_eq!(throughput(1000, 2.0), 500.0);
        assert_eq!(throughput(1000, 0.0), 0.0);
        // 5737 tok/s · 6 · 8.83e9 / (4 · 2.93e14) ≈ 25.9%.
        let m = mfu_pct(5737.0, 8.83e9, 4, 2.93e14);
        assert!((m - 25.93).abs() < 0.1, "{m}");
    }

    #[test]
    fn freeze_meter_averages() {
        let mut m = FreezeRatioMeter::default();
        m.push(0.0);
        m.push(0.5);
        m.push(1.0);
        assert!((m.pct() - 50.0).abs() < 1e-9);
        assert_eq!(FreezeRatioMeter::default().pct(), 0.0);
    }

    #[test]
    fn tta_improvement_condition() {
        // κ < p̄_eff ⇒ ratio < 1 (Theorem D.15).
        assert!(tta_ratio(0.7, 0.9) < 1.0);
        assert!(tta_ratio(0.9, 0.7) > 1.0);
        assert!(tta_ratio(0.5, 0.0).is_infinite());
    }

    #[test]
    fn recorder_roundtrip() {
        let dir = std::env::temp_dir().join(format!("tf-rec-{}", std::process::id()));
        let mut r = Recorder::new(&dir);
        r.push("table1", result_row("GPipe", "TimelyFreeze", 54.79, 0.17, 35.6, 7821.0, 36.3, 35.7));
        r.push("table1", result_row("GPipe", "APF", 54.65, 0.02, 28.9, 7293.0, 27.1, 33.2));
        let paths = r.flush().unwrap();
        assert_eq!(paths.len(), 1);
        let text = std::fs::read_to_string(&paths[0]).unwrap();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 2);
        assert_eq!(
            parsed.as_arr().unwrap()[0].get("method").unwrap().as_str(),
            Some("TimelyFreeze")
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
