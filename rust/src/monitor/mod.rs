//! Timing monitor utilities: per-action duration records and the
//! backward-time-vs-freeze-ratio regression of Appendix I (Figure 15).
//!
//! The freeze controllers keep their own monitoring state (Alg. 1); this
//! module serves *reporting*: benches and the engine use it to summarize
//! measured action durations and verify the linear backward-time model
//! (`t = slope·r + intercept`) that the LP's eq. 4 interpolation relies
//! on.

use crate::types::{Action, ActionKind};
use crate::util::stats::{linear_fit, Accum, LinFit};
use std::collections::BTreeMap;

/// One timing observation.
#[derive(Clone, Copy, Debug)]
pub struct TimingSample {
    /// Which action was measured.
    pub action: Action,
    /// Actual freeze ratio in effect when measured.
    pub afr: f64,
    /// Measured duration, seconds.
    pub duration: f64,
}

/// Collected timing samples with per-action grouping.
#[derive(Clone, Debug, Default)]
pub struct TimingMonitor {
    /// All samples, grouped per action.
    per_action: BTreeMap<Action, Vec<(f64, f64)>>,
}

impl TimingMonitor {
    /// An empty monitor.
    pub fn new() -> TimingMonitor {
        TimingMonitor::default()
    }

    /// Record one sample.
    pub fn record(&mut self, sample: TimingSample) {
        self.per_action
            .entry(sample.action)
            .or_default()
            .push((sample.afr, sample.duration));
    }

    /// Record a batch of samples.
    pub fn record_all<I: IntoIterator<Item = TimingSample>>(&mut self, it: I) {
        for s in it {
            self.record(s);
        }
    }

    /// Total sample count.
    pub fn len(&self) -> usize {
        self.per_action.values().map(|v| v.len()).sum()
    }

    /// Whether no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.per_action.is_empty()
    }

    /// Mean duration of an action at ratios close to `afr` (± tol).
    pub fn mean_at(&self, action: Action, afr: f64, tol: f64) -> Option<f64> {
        let samples = self.per_action.get(&action)?;
        let mut acc = Accum::new();
        for &(r, d) in samples {
            if (r - afr).abs() <= tol {
                acc.push(d);
            }
        }
        (acc.n > 0).then(|| acc.mean())
    }

    /// Figure 15: per-stage linear fit of backward duration vs AFR,
    /// pooling all backward actions of the stage.
    pub fn backward_regression(&self, stages: usize) -> Vec<Option<LinFit>> {
        let mut xs: Vec<Vec<f64>> = vec![Vec::new(); stages];
        let mut ys: Vec<Vec<f64>> = vec![Vec::new(); stages];
        for (a, samples) in &self.per_action {
            if !a.kind.freezable() || a.stage >= stages {
                continue;
            }
            for &(r, d) in samples {
                xs[a.stage].push(r);
                ys[a.stage].push(d);
            }
        }
        (0..stages).map(|s| linear_fit(&xs[s], &ys[s])).collect()
    }

    /// Upper/lower duration bounds per action from samples at AFR 0 / 1
    /// — the monitoring-phase estimate of eq. 3's [w_min, w_max].
    pub fn bounds(&self, action: Action) -> Option<(f64, f64)> {
        let hi = self.mean_at(action, 0.0, 0.01)?;
        let lo = match action.kind {
            ActionKind::Forward | ActionKind::BackwardDgrad => hi,
            _ => self.mean_at(action, 1.0, 0.01).unwrap_or(hi),
        };
        Some((lo.min(hi), hi))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seed_monitor() -> TimingMonitor {
        let mut m = TimingMonitor::new();
        // Stage 0 backward: t = -50·r + 70 (Figure 15(a) shape).
        for i in 0..=10 {
            let r = i as f64 / 10.0;
            m.record(TimingSample { action: Action::b(0, 0), afr: r, duration: 70.0 - 50.0 * r });
            m.record(TimingSample { action: Action::b(1, 0), afr: r, duration: 70.0 - 50.0 * r });
        }
        // Forwards unaffected.
        m.record(TimingSample { action: Action::f(0, 0), afr: 0.0, duration: 30.0 });
        m
    }

    #[test]
    fn regression_recovers_line() {
        let m = seed_monitor();
        let fits = m.backward_regression(1);
        let fit = fits[0].unwrap();
        assert!((fit.slope + 50.0).abs() < 1e-9);
        assert!((fit.intercept - 70.0).abs() < 1e-9);
        assert!(fit.r2 > 0.999);
    }

    #[test]
    fn bounds_from_endpoint_samples() {
        let m = seed_monitor();
        let (lo, hi) = m.bounds(Action::b(0, 0)).unwrap();
        assert!((hi - 70.0).abs() < 1e-9);
        assert!((lo - 20.0).abs() < 1e-9);
        let (flo, fhi) = m.bounds(Action::f(0, 0)).unwrap();
        assert_eq!(flo, fhi);
    }

    #[test]
    fn mean_at_filters_by_ratio() {
        let m = seed_monitor();
        assert!((m.mean_at(Action::b(0, 0), 0.5, 0.01).unwrap() - 45.0).abs() < 1e-9);
        assert!(m.mean_at(Action::b(0, 3), 0.5, 0.01).is_none());
    }

    #[test]
    fn empty_monitor() {
        let m = TimingMonitor::new();
        assert!(m.is_empty());
        assert!(m.backward_regression(2).iter().all(|f| f.is_none()));
    }
}
