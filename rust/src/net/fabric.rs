//! Max-min fair-sharing throughput model for shared links.
//!
//! Concurrent transfers on a link split its bandwidth by progressive
//! water-filling: repeatedly find the most contended finite link, give
//! every transfer crossing it an equal share of the link's remaining
//! capacity, fix those transfers, and subtract their share from every
//! other link they cross. Rates are re-solved on every arrival and
//! departure; the solution is the unique max-min fair allocation, so it
//! does not depend on iteration order — but all iteration here is in
//! deterministic (id, link) order anyway, keeping contended runs
//! bit-reproducible.
//!
//! The discrete-event engine drives this through three calls: `begin`
//! when a producer finishes and its payload enters the fabric,
//! `predictions` to queue epoch-stamped completion events, and
//! `complete` when a still-current prediction pops. Every state change
//! bumps `epoch`, so completion events queued before the change are
//! recognized as stale and skipped (lazy deletion).

/// One in-flight transfer.
#[derive(Debug, Clone)]
struct Xfer {
    /// Bytes still to move.
    remaining: f64,
    /// Current fair-share rate in bytes/s (always > 0 while live).
    rate: f64,
    /// Link ids this transfer crosses (at least one finite link).
    path: Vec<usize>,
    /// Caller payload (the engine stores the DAG edge id here).
    tag: u64,
    /// False once completed; slots are recycled through a free list.
    live: bool,
}

/// Execution-side shared-link fabric (see the module docs).
///
/// Transfers whose path has no finite-capacity link are *not* admitted:
/// [`FairShareFabric::begin`] returns `None` and the caller delivers
/// the message after plain latency, exactly like the pre-network
/// fixed-delay path. This is what makes infinite-capacity topologies
/// bit-identical to fixed-delay runs.
#[derive(Debug, Clone, Default)]
pub struct FairShareFabric {
    caps: Vec<f64>,
    now: f64,
    epoch: u64,
    xfers: Vec<Xfer>,
    free: Vec<usize>,
    /// Live transfer ids in insertion order.
    active: Vec<usize>,
    // Water-filling scratch, kept to avoid per-event allocation.
    rem_cap: Vec<f64>,
    load: Vec<usize>,
}

impl FairShareFabric {
    /// An empty fabric; call [`FairShareFabric::reset`] before use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Install link capacities and drop all state. Call once per
    /// simulated step (capacities may change under `linkcap` terms).
    pub fn reset(&mut self, caps: &[f64]) {
        self.caps.clear();
        self.caps.extend_from_slice(caps);
        self.now = 0.0;
        self.epoch = 0;
        self.xfers.clear();
        self.free.clear();
        self.active.clear();
    }

    /// True when no transfer is in flight.
    pub fn idle(&self) -> bool {
        self.active.is_empty()
    }

    /// The current epoch; bumped on every arrival/departure.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Admit `bytes` over `path` at time `t`. Returns the transfer id,
    /// or `None` when the transfer is instantaneous (zero bytes, empty
    /// path, or only infinite-capacity links) and the caller should
    /// deliver it after plain latency.
    pub fn begin(&mut self, t: f64, bytes: f64, path: &[usize], tag: u64) -> Option<usize> {
        debug_assert!(bytes.is_finite() && bytes >= 0.0, "transfer of {bytes} bytes");
        let constrained = path.iter().any(|&l| self.caps[l].is_finite());
        if bytes <= 0.0 || !constrained {
            return None;
        }
        self.advance(t);
        let xfer = Xfer {
            remaining: bytes,
            rate: 0.0,
            path: path.to_vec(),
            tag,
            live: true,
        };
        let id = match self.free.pop() {
            Some(id) => {
                self.xfers[id] = xfer;
                id
            }
            None => {
                self.xfers.push(xfer);
                self.xfers.len() - 1
            }
        };
        self.active.push(id);
        self.recompute();
        Some(id)
    }

    /// Finish transfer `id` at time `t` and return its tag. Only call
    /// for a prediction that [`FairShareFabric::is_due`] accepts.
    pub fn complete(&mut self, t: f64, id: usize) -> u64 {
        self.advance(t);
        debug_assert!(self.xfers[id].live, "completing a dead transfer");
        self.xfers[id].live = false;
        self.active.retain(|&a| a != id);
        self.free.push(id);
        let tag = self.xfers[id].tag;
        self.recompute();
        tag
    }

    /// Whether a queued completion event is still current.
    pub fn is_due(&self, id: usize, epoch: u64) -> bool {
        epoch == self.epoch && id < self.xfers.len() && self.xfers[id].live
    }

    /// Visit predicted completion times for every live transfer as
    /// `(id, epoch, due_time)`. Call after each `begin`/`complete` to
    /// queue fresh predictions; earlier ones are lazily skipped.
    pub fn predictions(&self, mut f: impl FnMut(usize, u64, f64)) {
        for &id in &self.active {
            let x = &self.xfers[id];
            debug_assert!(x.rate > 0.0, "live transfer with no rate");
            let due = self.now + (x.remaining / x.rate).max(0.0);
            f(id, self.epoch, due);
        }
    }

    /// Sum of current rates crossing `link` (test probe for the
    /// fair-share conservation property: never exceeds the capacity).
    pub fn link_allocation(&self, link: usize) -> f64 {
        self.active
            .iter()
            .map(|&id| &self.xfers[id])
            .filter(|x| x.path.contains(&link))
            .map(|x| x.rate)
            .sum()
    }

    /// Number of links the fabric was reset with.
    pub fn link_count(&self) -> usize {
        self.caps.len()
    }

    /// Integrate transferred bytes up to `t`.
    fn advance(&mut self, t: f64) {
        debug_assert!(t >= self.now - 1e-9, "fabric time moved backwards: {t} < {}", self.now);
        let dt = t - self.now;
        if dt > 0.0 {
            for &id in &self.active {
                let x = &mut self.xfers[id];
                x.remaining = (x.remaining - x.rate * dt).max(0.0);
            }
        }
        self.now = if t > self.now { t } else { self.now };
    }

    /// Re-solve max-min fair rates for all live transfers.
    fn recompute(&mut self) {
        self.epoch += 1;
        let links = self.caps.len();
        self.rem_cap.clear();
        self.rem_cap.extend_from_slice(&self.caps);
        self.load.clear();
        self.load.resize(links, 0);
        for &id in &self.active {
            self.xfers[id].rate = -1.0; // unfixed marker
            for &l in &self.xfers[id].path {
                self.load[l] += 1;
            }
        }
        let mut unfixed = self.active.len();
        while unfixed > 0 {
            // Bottleneck link: the smallest per-transfer share among
            // loaded finite links (ties to the smallest link id).
            let mut best: Option<(f64, usize)> = None;
            for l in 0..links {
                if self.load[l] == 0 || !self.rem_cap[l].is_finite() {
                    continue;
                }
                let share = self.rem_cap[l] / self.load[l] as f64;
                if best.map_or(true, |(s, _)| share < s) {
                    best = Some((share, l));
                }
            }
            let Some((share, bneck)) = best else {
                // Only possible if a live transfer crosses no finite
                // link, which `begin` rejects.
                unreachable!("unfixed transfers but no loaded finite link");
            };
            let share = share.max(0.0);
            for i in 0..self.active.len() {
                let id = self.active[i];
                let x = &self.xfers[id];
                if x.rate >= 0.0 || !x.path.contains(&bneck) {
                    continue;
                }
                self.xfers[id].rate = share;
                for j in 0..self.xfers[id].path.len() {
                    let l = self.xfers[id].path[j];
                    self.load[l] -= 1;
                    if self.rem_cap[l].is_finite() {
                        self.rem_cap[l] = (self.rem_cap[l] - share).max(0.0);
                    }
                }
                unfixed -= 1;
            }
        }
        // A link driven to exactly zero remaining capacity can hand out
        // a zero share; keep rates positive (and predicted due times
        // finite) with a slow trickle proportional to the payload.
        for &id in &self.active {
            let x = &mut self.xfers[id];
            if x.rate <= 0.0 {
                x.rate = (x.remaining / 1e12).max(f64::MIN_POSITIVE);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn due_of(fabric: &FairShareFabric, want: usize) -> f64 {
        let mut due = f64::NAN;
        fabric.predictions(|id, _, t| {
            if id == want {
                due = t;
            }
        });
        assert!(!due.is_nan(), "transfer {want} has no prediction");
        due
    }

    #[test]
    fn single_transfer_gets_the_full_link() {
        let mut f = FairShareFabric::new();
        f.reset(&[100.0]);
        let id = f.begin(0.0, 50.0, &[0], 7).unwrap();
        assert_eq!(due_of(&f, id), 0.5);
        assert_eq!(f.complete(0.5, id), 7);
        assert!(f.idle());
    }

    #[test]
    fn concurrent_transfers_split_the_link() {
        let mut f = FairShareFabric::new();
        f.reset(&[100.0]);
        let a = f.begin(0.0, 100.0, &[0], 0).unwrap();
        // Alone, `a` would finish at t=1. At t=0.5 a second transfer
        // arrives; the remaining 50 bytes now move at 50 B/s.
        let b = f.begin(0.5, 50.0, &[0], 1).unwrap();
        assert_eq!(due_of(&f, a), 1.5);
        assert_eq!(due_of(&f, b), 1.5);
        assert_eq!(f.link_allocation(0), 100.0);
        // `a` departs: `b`'s remaining bytes speed back up.
        f.complete(1.5, a);
        assert!(f.idle() || due_of(&f, b) >= 1.5);
    }

    #[test]
    fn max_min_gives_the_bottleneck_flows_equal_shares() {
        // Two links: link 0 cap 100 shared by x and y; link 1 cap 30
        // crossed only by y. Max-min: y gets 30, x gets 70.
        let mut f = FairShareFabric::new();
        f.reset(&[100.0, 30.0]);
        let x = f.begin(0.0, 700.0, &[0], 0).unwrap();
        let y = f.begin(0.0, 300.0, &[0, 1], 1).unwrap();
        assert_eq!(due_of(&f, x), 10.0, "x rate 70 B/s");
        assert_eq!(due_of(&f, y), 10.0, "y rate 30 B/s");
        assert_eq!(f.link_allocation(0), 100.0);
        assert_eq!(f.link_allocation(1), 30.0);
    }

    #[test]
    fn infinite_only_paths_are_not_admitted() {
        let mut f = FairShareFabric::new();
        f.reset(&[f64::INFINITY, 100.0]);
        assert!(f.begin(0.0, 1e9, &[0], 0).is_none(), "infinite link only");
        assert!(f.begin(0.0, 0.0, &[1], 0).is_none(), "zero bytes");
        assert!(f.begin(0.0, 1.0, &[], 0).is_none(), "empty path");
        assert!(f.begin(0.0, 1.0, &[1], 0).is_some(), "finite link admits");
    }

    #[test]
    fn epochs_invalidate_stale_predictions() {
        let mut f = FairShareFabric::new();
        f.reset(&[100.0]);
        let a = f.begin(0.0, 100.0, &[0], 0).unwrap();
        let mut stale = Vec::new();
        f.predictions(|id, ep, t| stale.push((id, ep, t)));
        let _b = f.begin(0.5, 50.0, &[0], 1).unwrap();
        for (id, ep, _) in &stale {
            assert!(!f.is_due(*id, *ep), "pre-arrival prediction must go stale");
        }
        let mut fresh = Vec::new();
        f.predictions(|id, ep, t| fresh.push((id, ep, t)));
        assert!(fresh.iter().any(|&(id, ep, _)| id == a && f.is_due(id, ep)));
    }

    #[test]
    fn slots_are_recycled_deterministically() {
        let mut f = FairShareFabric::new();
        f.reset(&[10.0]);
        let a = f.begin(0.0, 10.0, &[0], 0).unwrap();
        f.complete(1.0, a);
        let b = f.begin(1.0, 10.0, &[0], 1).unwrap();
        assert_eq!(a, b, "free list reuses the slot");
        let mut g = FairShareFabric::new();
        g.reset(&[10.0]);
        let a2 = g.begin(0.0, 10.0, &[0], 0).unwrap();
        g.complete(1.0, a2);
        let b2 = g.begin(1.0, 10.0, &[0], 1).unwrap();
        assert_eq!((a, b), (a2, b2), "identical drive → identical ids");
    }

    #[test]
    fn conservation_holds_under_churn() {
        let mut f = FairShareFabric::new();
        let caps = [50.0, 20.0, f64::INFINITY];
        f.reset(&caps);
        let paths: [&[usize]; 4] = [&[0], &[0, 1], &[1, 2], &[0, 2]];
        let mut live = Vec::new();
        let mut t = 0.0;
        for k in 0..16 {
            t += 0.1;
            if k % 3 == 2 && !live.is_empty() {
                let id = live.remove(0);
                // Complete early (before its predicted due) — allowed.
                f.complete(t, id);
            } else if let Some(id) = f.begin(t, 5.0 + k as f64, paths[k % 4], k as u64) {
                live.push(id);
            }
            for (l, cap) in caps.iter().enumerate() {
                if cap.is_finite() {
                    assert!(
                        f.link_allocation(l) <= cap * (1.0 + 1e-9),
                        "link {l} over capacity at t={t}"
                    );
                }
            }
        }
    }
}
