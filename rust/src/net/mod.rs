//! Contention-aware network fabric: hierarchical topologies, max-min
//! fair-sharing throughput, and the planning-side expected link costs.
//!
//! Three pieces live here:
//!
//! * [`Topology`] — the user-facing description (`--net`): either
//!   `uniform` (today's fixed-delay edges on an infinite-capacity
//!   fabric — the network layer is fully disengaged and runs are
//!   bit-identical to a config with no `--net` at all), or
//!   `hierarchical` with fast per-island links (NVLink-class) joined by
//!   a slower spine (IB-class). Parse/Display round-trip like
//!   [`crate::config::Scenario`]; a TOML form (`[network]`) is accepted
//!   from `--net topo.toml` and `--config` files.
//! * [`NetworkModel`] — the resolved planning view for a fleet of `R`
//!   ranks: link capacities, rank→island routing, and *expected*
//!   per-transfer costs under static fair sharing (each link's capacity
//!   divided by the number of pipeline boundaries routed over it).
//!   These feed [`crate::cost::CostModel`] as P2P edge costs and the
//!   freeze LP as per-edge traffic slopes, so freezing a stage —
//!   which shrinks its gradient payload — visibly relaxes the shared
//!   spine terms (constraint [5]'s comm envelopes become
//!   load-dependent).
//! * [`FairShareFabric`] — the execution-side throughput model (dslab
//!   `network`/`throughput-model` style): concurrent transfers on a
//!   link split its bandwidth by progressive (max-min) water-filling,
//!   and completion times are re-solved on every arrival/departure.
//!   The discrete-event engine prices P2P sends through it via
//!   epoch-versioned `NetDue` events.

pub mod fabric;

pub use fabric::FairShareFabric;

use std::fmt;

use crate::util::toml::TomlDoc;

/// Spelled capacity for an infinite-bandwidth link in specs and TOML.
const INF_SPELLING: &str = "inf";

/// The topology shape behind a [`Topology`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TopologyKind {
    /// Infinite-capacity fabric: the network layer is disengaged and
    /// every P2P edge keeps its fixed-delay cost. Bit-identical to not
    /// passing `--net` at all (guarded by `tests/network_contention.rs`).
    Uniform,
    /// Islands of `island_size` consecutive ranks joined by a spine.
    /// Intra-island transfers cross only the island link; inter-island
    /// transfers cross source island, spine, and destination island.
    Hierarchical {
        /// Ranks per island (island `i` holds ranks `i*s..(i+1)*s`).
        island_size: usize,
        /// Per-island link bandwidth in bytes/s (`f64::INFINITY` allowed).
        island_bw: f64,
        /// Spine bandwidth in bytes/s (`f64::INFINITY` allowed).
        spine_bw: f64,
        /// Per-message latency in seconds (paid once per transfer).
        latency: f64,
    },
}

/// A network topology: parseable spec, display label, and validation.
///
/// Specs use the same mini-language style as scenarios:
///
/// ```text
/// uniform
/// island:<size>x<bw>,spine:<bw>[,lat:<seconds>]
/// ```
///
/// Bandwidths are bytes/s and accept `inf`. `Display` prints the label
/// (the original spec for parsed topologies), so parse → Display →
/// parse round-trips exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    label: String,
    /// The resolved shape.
    pub kind: TopologyKind,
}

impl Topology {
    /// The infinite-capacity passthrough topology.
    pub fn uniform() -> Self {
        Topology { label: "uniform".to_string(), kind: TopologyKind::Uniform }
    }

    /// A hierarchical topology with canonical label.
    pub fn hierarchical(island_size: usize, island_bw: f64, spine_bw: f64, latency: f64) -> Self {
        let kind = TopologyKind::Hierarchical { island_size, island_bw, spine_bw, latency };
        let mut t = Topology { label: String::new(), kind };
        t.label = t.canonical_spec();
        t
    }

    /// The topology's display label (the spec it was parsed from).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// True when the network layer is disengaged (no capacity to model).
    pub fn is_uniform(&self) -> bool {
        matches!(self.kind, TopologyKind::Uniform)
    }

    /// Canonical spec string (what `hierarchical()` uses as its label).
    pub fn canonical_spec(&self) -> String {
        match self.kind {
            TopologyKind::Uniform => "uniform".to_string(),
            TopologyKind::Hierarchical { island_size, island_bw, spine_bw, latency } => {
                let mut s = format!(
                    "island:{island_size}x{},spine:{}",
                    fmt_bw(island_bw),
                    fmt_bw(spine_bw)
                );
                if latency != 0.0 {
                    s.push_str(&format!(",lat:{latency}"));
                }
                s
            }
        }
    }

    /// Parse a topology spec (see the type-level grammar).
    pub fn parse(spec: &str) -> Result<Self, String> {
        let trimmed = spec.trim();
        if trimmed.is_empty() {
            return Err("empty topology spec".to_string());
        }
        if trimmed == "uniform" {
            let mut t = Topology::uniform();
            t.label = trimmed.to_string();
            return Ok(t);
        }
        let mut island: Option<(usize, f64)> = None;
        let mut spine: Option<f64> = None;
        let mut latency = 0.0;
        for term in trimmed.split(',') {
            let term = term.trim();
            if term.is_empty() {
                continue;
            }
            let (head, rest) = match term.split_once(':') {
                Some((h, r)) => (h, r),
                None => (term, ""),
            };
            match head {
                "island" => {
                    let (size_s, bw_s) = rest.split_once('x').ok_or_else(|| {
                        format!("island term '{term}' wants island:<size>x<bandwidth>")
                    })?;
                    let size: usize = size_s
                        .parse()
                        .map_err(|_| format!("bad island size in '{term}'"))?;
                    let bw = parse_bw(bw_s, term)?;
                    island = Some((size, bw));
                }
                "spine" => spine = Some(parse_bw(rest, term)?),
                "lat" => {
                    latency = rest
                        .parse::<f64>()
                        .ok()
                        .filter(|x| x.is_finite() && *x >= 0.0)
                        .ok_or_else(|| format!("bad latency in '{term}'"))?;
                }
                _ => {
                    return Err(format!(
                        "unknown topology term '{term}' (try uniform, \
                         island:<size>x<bw>, spine:<bw>, lat:<seconds>)"
                    ));
                }
            }
        }
        let (island_size, island_bw) =
            island.ok_or_else(|| format!("topology '{trimmed}' is missing an island term"))?;
        let spine_bw =
            spine.ok_or_else(|| format!("topology '{trimmed}' is missing a spine term"))?;
        let mut t = Topology::hierarchical(island_size, island_bw, spine_bw, latency);
        t.label = trimmed.to_string();
        t.validate()?;
        Ok(t)
    }

    /// Parse the `[network]` section of a TOML document. Returns
    /// `Ok(None)` when the document has no such section.
    pub fn from_toml(doc: &TomlDoc) -> Result<Option<Self>, String> {
        let mode = match doc.get_str("network.mode") {
            Some(m) => m,
            None => {
                if doc.keys_under("network").is_empty() {
                    return Ok(None);
                }
                return Err("[network] section is missing mode = \"uniform\"|\"hierarchical\""
                    .to_string());
            }
        };
        match mode {
            "uniform" => Ok(Some(Topology::uniform())),
            "hierarchical" => {
                let island_size = doc
                    .get_usize("network.island_size")
                    .ok_or("[network] hierarchical mode wants island_size = <ranks>")?;
                let island_bw = toml_bw(doc, "network.island_bandwidth")?;
                let spine_bw = toml_bw(doc, "network.spine_bandwidth")?;
                let latency = doc.get_f64("network.latency").unwrap_or(0.0);
                let t = Topology::hierarchical(island_size, island_bw, spine_bw, latency);
                t.validate()?;
                Ok(Some(t))
            }
            other => Err(format!("[network] mode '{other}' is neither uniform nor hierarchical")),
        }
    }

    /// Emit the canonical `[network]` TOML section. `from_toml` on the
    /// output reproduces `self` up to the label (which is canonical).
    pub fn to_toml(&self) -> String {
        match self.kind {
            TopologyKind::Uniform => "[network]\nmode = \"uniform\"\n".to_string(),
            TopologyKind::Hierarchical { island_size, island_bw, spine_bw, latency } => {
                let mut s = String::from("[network]\nmode = \"hierarchical\"\n");
                s.push_str(&format!("island_size = {island_size}\n"));
                s.push_str(&format!("island_bandwidth = {}\n", fmt_bw_toml(island_bw)));
                s.push_str(&format!("spine_bandwidth = {}\n", fmt_bw_toml(spine_bw)));
                s.push_str(&format!("latency = {latency:?}\n"));
                s
            }
        }
    }

    /// Shape checks: positive bandwidths (infinite allowed), island
    /// size ≥ 1, finite non-negative latency.
    pub fn validate(&self) -> Result<(), String> {
        match self.kind {
            TopologyKind::Uniform => Ok(()),
            TopologyKind::Hierarchical { island_size, island_bw, spine_bw, latency } => {
                if island_size == 0 {
                    return Err("island size must be >= 1".to_string());
                }
                for (name, bw) in [("island", island_bw), ("spine", spine_bw)] {
                    if bw.is_nan() || bw <= 0.0 {
                        return Err(format!("{name} bandwidth must be positive (or inf)"));
                    }
                }
                if !latency.is_finite() || latency < 0.0 {
                    return Err("latency must be finite and >= 0".to_string());
                }
                Ok(())
            }
        }
    }
}

impl fmt::Display for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label)
    }
}

fn fmt_bw(bw: f64) -> String {
    if bw.is_infinite() {
        INF_SPELLING.to_string()
    } else {
        format!("{bw}")
    }
}

fn fmt_bw_toml(bw: f64) -> String {
    if bw.is_infinite() {
        format!("\"{INF_SPELLING}\"")
    } else {
        format!("{bw:?}")
    }
}

fn parse_bw(s: &str, term: &str) -> Result<f64, String> {
    if s == INF_SPELLING {
        return Ok(f64::INFINITY);
    }
    s.parse::<f64>()
        .ok()
        .filter(|x| !x.is_nan() && *x > 0.0)
        .ok_or_else(|| format!("bad bandwidth in '{term}' (want bytes/s or inf)"))
}

fn toml_bw(doc: &TomlDoc, key: &str) -> Result<f64, String> {
    if let Some(s) = doc.get_str(key) {
        if s == INF_SPELLING {
            return Ok(f64::INFINITY);
        }
        return s
            .parse::<f64>()
            .ok()
            .filter(|x| !x.is_nan() && *x > 0.0)
            .ok_or_else(|| format!("{key} = \"{s}\" is not a bandwidth (bytes/s or \"inf\")"));
    }
    doc.get_f64(key)
        .filter(|x| !x.is_nan() && *x > 0.0)
        .ok_or_else(|| format!("[network] hierarchical mode wants {key} = <bytes/s>"))
}

/// The resolved planning view of a hierarchical topology over `ranks`
/// ranks: one link per island plus the spine (the last link id).
///
/// `NetworkModel::new` returns `None` for [`TopologyKind::Uniform`] —
/// callers treat an absent model as "network disengaged" so the uniform
/// path stays bit-identical to pre-network builds.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    ranks: usize,
    island_size: usize,
    latency: f64,
    /// Link capacities, islands first, spine last.
    caps: Vec<f64>,
}

impl NetworkModel {
    /// Resolve a topology for a fleet. `None` when uniform.
    pub fn new(topo: &Topology, ranks: usize) -> Option<Self> {
        match topo.kind {
            TopologyKind::Uniform => None,
            TopologyKind::Hierarchical { island_size, island_bw, spine_bw, latency } => {
                assert!(ranks > 0, "network model over an empty fleet");
                let islands = ranks.div_ceil(island_size);
                let mut caps = vec![island_bw; islands];
                caps.push(spine_bw);
                Some(NetworkModel { ranks, island_size, latency, caps })
            }
        }
    }

    /// Number of links (islands + spine).
    pub fn link_count(&self) -> usize {
        self.caps.len()
    }

    /// Link capacities in bytes/s, islands first, spine last.
    pub fn caps(&self) -> &[f64] {
        &self.caps
    }

    /// Per-message latency in seconds.
    pub fn latency(&self) -> f64 {
        self.latency
    }

    /// The spine's link id.
    pub fn spine(&self) -> usize {
        self.caps.len() - 1
    }

    /// Which island hosts a rank.
    pub fn island_of(&self, rank: usize) -> usize {
        assert!(rank < self.ranks, "rank {rank} outside fleet of {}", self.ranks);
        rank / self.island_size
    }

    /// The links a transfer from `a` to `b` crosses, in route order.
    /// Empty for `a == b` (no network hop). Same-island transfers cross
    /// only the island link; inter-island transfers cross source
    /// island, spine, destination island.
    pub fn path(&self, a: usize, b: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(3);
        self.path_into(a, b, &mut out);
        out
    }

    /// Allocation-free variant of [`NetworkModel::path`].
    pub fn path_into(&self, a: usize, b: usize, out: &mut Vec<usize>) {
        out.clear();
        if a == b {
            return;
        }
        let (ia, ib) = (self.island_of(a), self.island_of(b));
        if ia == ib {
            out.push(ia);
        } else {
            out.push(ia);
            out.push(self.spine());
            out.push(ib);
        }
    }

    /// Per-link load: how many of the given rank pairs route over each
    /// link, floored at 1 so dividing by it never inflates bandwidth.
    pub fn link_loads(&self, pairs: &[(usize, usize)]) -> Vec<f64> {
        let mut count = vec![0usize; self.link_count()];
        let mut path = Vec::with_capacity(3);
        for &(a, b) in pairs {
            self.path_into(a, b, &mut path);
            for &l in &path {
                count[l] += 1;
            }
        }
        count.iter().map(|&c| c.max(1) as f64).collect()
    }

    /// Serialization seconds for `bytes` from `a` to `b` on a dedicated
    /// (contention-free) fabric: latency + bytes over the path's
    /// bottleneck capacity. Zero when `a == b`.
    pub fn dedicated_seconds(&self, bytes: f64, a: usize, b: usize) -> f64 {
        if a == b {
            return 0.0;
        }
        let mut path = Vec::with_capacity(3);
        self.path_into(a, b, &mut path);
        self.latency + bytes / bottleneck(&self.caps, &path, None)
    }

    /// Expected serialization seconds under static fair sharing: each
    /// link's capacity is split across `loads` concurrent boundary
    /// flows (from [`NetworkModel::link_loads`]). Zero when `a == b`.
    pub fn expected_seconds(&self, bytes: f64, a: usize, b: usize, loads: &[f64]) -> f64 {
        if a == b {
            return 0.0;
        }
        let mut path = Vec::with_capacity(3);
        self.path_into(a, b, &mut path);
        self.latency + bytes / bottleneck(&self.caps, &path, Some(loads))
    }
}

/// Bottleneck effective bandwidth over `path`: min of `cap/load`.
/// Returns infinity when every link on the path is infinite.
fn bottleneck(caps: &[f64], path: &[usize], loads: Option<&[f64]>) -> f64 {
    let mut bw = f64::INFINITY;
    for &l in path {
        let eff = match loads {
            Some(ld) => caps[l] / ld[l],
            None => caps[l],
        };
        if eff < bw {
            bw = eff;
        }
    }
    bw
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_round_trips_and_resolves_to_none() {
        let t = Topology::parse("uniform").unwrap();
        assert!(t.is_uniform());
        assert_eq!(t.to_string(), "uniform");
        assert_eq!(Topology::parse(&t.to_string()).unwrap(), t);
        assert!(NetworkModel::new(&t, 8).is_none());
    }

    #[test]
    fn hierarchical_specs_round_trip() {
        for spec in [
            "island:4x600000000000,spine:100000000000",
            "island:2x1e12,spine:5e10,lat:0.000002",
            "island:1xinf,spine:16000000000",
            "island:8xinf,spine:inf",
        ] {
            let t = Topology::parse(spec).unwrap();
            assert_eq!(t.to_string(), spec, "label preserves the original spec");
            let again = Topology::parse(&t.to_string()).unwrap();
            assert_eq!(again, t, "parse(Display) round-trips for {spec}");
            // The canonical spec also round-trips (modulo label).
            let canon = Topology::parse(&t.canonical_spec()).unwrap();
            assert_eq!(canon.kind, t.kind, "canonical spec keeps the shape for {spec}");
        }
    }

    #[test]
    fn toml_round_trips() {
        for t in [
            Topology::uniform(),
            Topology::hierarchical(4, 6.0e11, 1.0e11, 2.0e-6),
            Topology::hierarchical(2, f64::INFINITY, 1.6e10, 0.0),
            Topology::hierarchical(8, 1.25e11, f64::INFINITY, 0.0),
        ] {
            let toml = t.to_toml();
            let doc = TomlDoc::parse(&toml).unwrap();
            let back = Topology::from_toml(&doc).unwrap().unwrap();
            assert_eq!(back.kind, t.kind, "TOML round-trip keeps the shape:\n{toml}");
        }
    }

    #[test]
    fn from_toml_is_none_without_a_network_section() {
        let doc = TomlDoc::parse("[experiment]\nranks = 4\n").unwrap();
        assert!(Topology::from_toml(&doc).unwrap().is_none());
    }

    #[test]
    fn malformed_specs_name_the_offence() {
        for (spec, needle) in [
            ("", "empty"),
            ("island:4", "island:<size>x<bandwidth>"),
            ("island:ax1e9,spine:1e9", "island size"),
            ("island:4x-3,spine:1e9", "bandwidth"),
            ("island:4xnan,spine:1e9", "bandwidth"),
            ("island:4x1e9", "missing a spine"),
            ("spine:1e9", "missing an island"),
            ("island:4x1e9,spine:1e9,lat:-1", "latency"),
            ("island:0x1e9,spine:1e9", "island size must be >= 1"),
            ("mesh:4", "unknown topology term"),
        ] {
            let err = Topology::parse(spec).unwrap_err();
            assert!(err.contains(needle), "error for '{spec}' should mention '{needle}': {err}");
        }
    }

    #[test]
    fn malformed_toml_names_the_offence() {
        for (toml, needle) in [
            ("[network]\nisland_size = 4\n", "mode"),
            ("[network]\nmode = \"ring\"\n", "neither uniform nor hierarchical"),
            ("[network]\nmode = \"hierarchical\"\n", "island_size"),
            (
                "[network]\nmode = \"hierarchical\"\nisland_size = 4\nisland_bandwidth = \"fast\"\nspine_bandwidth = 1e9\n",
                "not a bandwidth",
            ),
        ] {
            let doc = TomlDoc::parse(toml).unwrap();
            let err = Topology::from_toml(&doc).unwrap_err();
            assert!(err.contains(needle), "error for {toml:?} should mention '{needle}': {err}");
        }
    }

    #[test]
    fn paths_follow_the_island_spine_island_route() {
        let t = Topology::hierarchical(2, 6.0e11, 1.0e11, 0.0);
        let nm = NetworkModel::new(&t, 6).unwrap();
        assert_eq!(nm.link_count(), 4, "three islands + spine");
        assert_eq!(nm.spine(), 3);
        assert_eq!(nm.path(0, 0), Vec::<usize>::new());
        assert_eq!(nm.path(0, 1), vec![0], "same island: island link only");
        assert_eq!(nm.path(1, 2), vec![0, 3, 1], "cross island: src, spine, dst");
        assert_eq!(nm.path(5, 0), vec![2, 3, 0]);
    }

    #[test]
    fn expected_costs_divide_capacity_by_load() {
        let t = Topology::hierarchical(2, f64::INFINITY, 100.0, 0.5);
        let nm = NetworkModel::new(&t, 4).unwrap();
        // Boundaries 0-1 (same island), 1-2 (spine), plus a second
        // spine crosser to double the load.
        let pairs = [(0, 1), (1, 2), (3, 0)];
        let loads = nm.link_loads(&pairs);
        assert_eq!(loads[nm.spine()], 2.0);
        // Dedicated: 0.5 + 100/100 = 1.5; expected halves the spine.
        assert_eq!(nm.dedicated_seconds(100.0, 1, 2), 1.5);
        assert_eq!(nm.expected_seconds(100.0, 1, 2, &loads), 2.5);
        // Same-island path over infinite links: latency only.
        assert_eq!(nm.expected_seconds(100.0, 0, 1, &loads), 0.5);
        // Same rank: free.
        assert_eq!(nm.expected_seconds(100.0, 2, 2, &loads), 0.0);
    }

    #[test]
    fn infinite_capacity_is_latency_only() {
        let t = Topology::hierarchical(2, f64::INFINITY, f64::INFINITY, 0.25);
        let nm = NetworkModel::new(&t, 4).unwrap();
        let loads = nm.link_loads(&[(1, 2)]);
        assert_eq!(nm.dedicated_seconds(1e12, 1, 2), 0.25);
        assert_eq!(nm.expected_seconds(1e12, 1, 2, &loads), 0.25);
    }
}
