//! Model-partitioning heuristics (Appendix G.1): assign contiguous layer
//! ranges to pipeline stages balancing **memory**, **parameter count**,
//! or **measured time**.
//!
//! All three reduce to the classic *linear partition* problem — split a
//! sequence of layer weights into S contiguous chunks minimizing the
//! maximum chunk weight — solved exactly by dynamic programming.

/// Exact linear partition: split `weights` into `k` contiguous chunks
/// minimizing the maximum chunk sum. Returns the stage of each layer
/// (non-decreasing, all stages in 0..k used when `len ≥ k`).
pub fn balanced_partition(weights: &[f64], k: usize) -> Vec<usize> {
    let n = weights.len();
    assert!(k >= 1, "need at least one stage");
    assert!(n >= k, "fewer layers ({n}) than stages ({k})");
    assert!(weights.iter().all(|w| *w >= 0.0), "negative layer weight");

    // prefix[i] = Σ weights[..i]
    let mut prefix = vec![0.0f64; n + 1];
    for i in 0..n {
        prefix[i + 1] = prefix[i] + weights[i];
    }
    let seg = |a: usize, b: usize| prefix[b] - prefix[a]; // weights[a..b]

    // dp[j][i] = minimal max-chunk over the first i layers in j chunks.
    // To force every stage non-empty, dp over i ≥ j.
    let inf = f64::INFINITY;
    let mut dp = vec![vec![inf; n + 1]; k + 1];
    let mut cut = vec![vec![0usize; n + 1]; k + 1];
    dp[0][0] = 0.0;
    for j in 1..=k {
        for i in j..=n {
            // Last chunk = layers p..i (non-empty ⇒ p ≥ j−1).
            for p in (j - 1)..i {
                if dp[j - 1][p] == inf {
                    continue;
                }
                let cand = dp[j - 1][p].max(seg(p, i));
                if cand < dp[j][i] {
                    dp[j][i] = cand;
                    cut[j][i] = p;
                }
            }
        }
    }

    // Recover cut points.
    let mut bounds = vec![n];
    let mut i = n;
    for j in (1..=k).rev() {
        i = cut[j][i];
        bounds.push(i);
    }
    bounds.reverse(); // [0, c1, c2, …, n]
    debug_assert_eq!(bounds[0], 0);

    let mut stage_of_layer = vec![0usize; n];
    for s in 0..k {
        for l in bounds[s]..bounds[s + 1] {
            stage_of_layer[l] = s;
        }
    }
    stage_of_layer
}

/// The three heuristics of Appendix G.1 as weight selectors over a layer
/// profile.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PartitionMethod {
    /// Balance peak activation + parameter memory (OOM avoidance).
    Memory,
    /// Balance raw parameter counts (profiling-free default).
    Parameter,
    /// Balance measured per-layer forward+backward latency (throughput).
    Time,
}

impl PartitionMethod {
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            PartitionMethod::Memory => "Memory",
            PartitionMethod::Parameter => "Parameter",
            PartitionMethod::Time => "Time",
        }
    }

    /// Every heuristic, in Appendix G.1's order.
    pub fn all() -> [PartitionMethod; 3] {
        [PartitionMethod::Memory, PartitionMethod::Parameter, PartitionMethod::Time]
    }
}

/// Per-layer profile used by the heuristics.
#[derive(Clone, Debug)]
pub struct LayerProfile {
    /// Parameter count per layer.
    pub params: Vec<f64>,
    /// Peak memory per layer (activations + params), bytes.
    pub memory: Vec<f64>,
    /// Measured forward+backward time per layer.
    pub time: Vec<f64>,
}

impl LayerProfile {
    /// Validated construction: the three weight vectors must agree on
    /// the layer count, be non-empty, and carry only finite,
    /// non-negative weights. The elastic-recovery repartition builds its
    /// profile through here so a malformed model description fails at
    /// construction, not deep inside the partition DP.
    pub fn new(params: Vec<f64>, memory: Vec<f64>, time: Vec<f64>) -> LayerProfile {
        assert!(!params.is_empty(), "layer profile needs at least one layer");
        assert_eq!(params.len(), memory.len(), "params/memory length mismatch");
        assert_eq!(params.len(), time.len(), "params/time length mismatch");
        for v in [&params, &memory, &time] {
            assert!(
                v.iter().all(|w| w.is_finite() && *w >= 0.0),
                "layer weights must be finite and non-negative"
            );
        }
        LayerProfile { params, memory, time }
    }

    /// Layer count.
    pub fn len(&self) -> usize {
        self.params.len()
    }

    /// Whether the profile has no layers (never true for a validated
    /// profile; kept for the `len`/`is_empty` convention).
    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    /// Partition by the weight vector `method` selects.
    pub fn partition(&self, method: PartitionMethod, stages: usize) -> Vec<usize> {
        let weights = match method {
            PartitionMethod::Memory => &self.memory,
            PartitionMethod::Parameter => &self.params,
            PartitionMethod::Time => &self.time,
        };
        balanced_partition(weights, stages)
    }

    /// Max-stage/mean-stage imbalance of a partition under a weight kind.
    pub fn imbalance(&self, stage_of_layer: &[usize], method: PartitionMethod) -> f64 {
        let weights = match method {
            PartitionMethod::Memory => &self.memory,
            PartitionMethod::Parameter => &self.params,
            PartitionMethod::Time => &self.time,
        };
        let stages = stage_of_layer.iter().copied().max().unwrap_or(0) + 1;
        let mut sums = vec![0.0f64; stages];
        for (l, &s) in stage_of_layer.iter().enumerate() {
            sums[s] += weights[l];
        }
        let mean = sums.iter().sum::<f64>() / stages as f64;
        let max = sums.iter().copied().fold(0.0f64, f64::max);
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_weights_split_evenly() {
        let p = balanced_partition(&[1.0; 8], 4);
        assert_eq!(p, vec![0, 0, 1, 1, 2, 2, 3, 3]);
    }

    #[test]
    fn heavy_tail_isolated() {
        // ConvNeXt-like skew: deep layers much heavier.
        let w = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 10.0, 10.0];
        let p = balanced_partition(&w, 4);
        // The two heavy layers must land in separate stages.
        assert_ne!(p[6], p[7]);
        // Max chunk weight is optimal (10).
        let mut sums = [0.0; 4];
        for (l, &s) in p.iter().enumerate() {
            sums[s] += w[l];
        }
        assert!(sums.iter().copied().fold(0.0f64, f64::max) <= 10.0 + 1e-9);
    }

    #[test]
    fn partition_is_contiguous_and_complete() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(11);
        for _ in 0..50 {
            let n = 4 + (rng.next_below(20) as usize);
            let k = 1 + (rng.next_below(4) as usize).min(n - 1);
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 5.0)).collect();
            let p = balanced_partition(&w, k);
            assert_eq!(p.len(), n);
            // Non-decreasing and covering 0..k.
            for pair in p.windows(2) {
                assert!(pair[1] == pair[0] || pair[1] == pair[0] + 1);
            }
            assert_eq!(p[0], 0);
            assert_eq!(*p.last().unwrap(), k - 1);
        }
    }

    #[test]
    fn dp_is_optimal_vs_brute_force() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(5);
        for _ in 0..20 {
            let n = 6;
            let k = 3;
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(0.5, 4.0)).collect();
            let p = balanced_partition(&w, k);
            let mut sums = vec![0.0; k];
            for (l, &s) in p.iter().enumerate() {
                sums[s] += w[l];
            }
            let dp_max = sums.iter().copied().fold(0.0f64, f64::max);
            // Brute force all cut pairs (c1 < c2).
            let mut best = f64::INFINITY;
            for c1 in 1..n - 1 {
                for c2 in c1 + 1..n {
                    let s1: f64 = w[..c1].iter().sum();
                    let s2: f64 = w[c1..c2].iter().sum();
                    let s3: f64 = w[c2..].iter().sum();
                    best = best.min(s1.max(s2).max(s3));
                }
            }
            assert!((dp_max - best).abs() < 1e-9, "dp {dp_max} vs brute {best}");
        }
    }

    #[test]
    fn heuristics_pick_their_weight_vector() {
        let profile = LayerProfile {
            params: vec![1.0, 1.0, 1.0, 9.0],
            memory: vec![9.0, 1.0, 1.0, 1.0],
            time: vec![1.0, 9.0, 1.0, 1.0],
        };
        let by_param = profile.partition(PartitionMethod::Parameter, 2);
        assert_eq!(by_param, vec![0, 0, 0, 1]); // isolate heavy-param tail
        let by_mem = profile.partition(PartitionMethod::Memory, 2);
        assert_eq!(by_mem, vec![0, 1, 1, 1]); // isolate heavy-memory head
        let by_time = profile.partition(PartitionMethod::Time, 2);
        assert_eq!(by_time[1], 0); // heavy-time layer stays in stage 0…
        assert_eq!(by_time, vec![0, 0, 1, 1]);
    }

    #[test]
    fn imbalance_metric() {
        let profile = LayerProfile {
            params: vec![1.0, 1.0, 1.0, 1.0],
            memory: vec![1.0; 4],
            time: vec![1.0; 4],
        };
        let even = vec![0, 0, 1, 1];
        assert!((profile.imbalance(&even, PartitionMethod::Parameter) - 1.0).abs() < 1e-12);
        let skew = vec![0, 0, 0, 1];
        assert!(profile.imbalance(&skew, PartitionMethod::Parameter) > 1.4);
    }

    #[test]
    #[should_panic]
    fn too_few_layers_panics() {
        balanced_partition(&[1.0], 2);
    }

    #[test]
    fn validated_constructor_accepts_and_repartitions() {
        let p = LayerProfile::new(vec![1.0; 8], vec![2.0; 8], vec![3.0; 8]);
        assert_eq!(p.len(), 8);
        assert!(!p.is_empty());
        // The same profile re-splits over a shrunken fleet: 4 stages →
        // 3 stages, still contiguous and complete.
        let four = p.partition(PartitionMethod::Parameter, 4);
        let three = p.partition(PartitionMethod::Parameter, 3);
        assert_eq!(four.iter().copied().max(), Some(3));
        assert_eq!(three.iter().copied().max(), Some(2));
    }

    #[test]
    #[should_panic]
    fn constructor_rejects_length_mismatch() {
        LayerProfile::new(vec![1.0; 4], vec![1.0; 3], vec![1.0; 4]);
    }

    #[test]
    #[should_panic]
    fn constructor_rejects_negative_weights() {
        LayerProfile::new(vec![1.0, -1.0], vec![1.0, 1.0], vec![1.0, 1.0]);
    }
}
