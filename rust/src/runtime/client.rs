//! Per-thread PJRT runtime: loads HLO-text artifacts, compiles them on a
//! CPU PJRT client, and executes them with host tensors.
//!
//! One `StageRuntime` lives on each stage-worker thread (the `xla`
//! crate's `PjRtClient` is `Rc`-based, hence `!Send`); each worker
//! compiles only the artifact kinds its stage needs.

use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::tensor::HostTensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

pub struct StageRuntime {
    client: xla::PjRtClient,
    executables: BTreeMap<String, (xla::PjRtLoadedExecutable, ArtifactSpec)>,
    pub manifest: Manifest,
}

impl StageRuntime {
    /// Create a CPU PJRT client and compile the named artifact kinds
    /// (all kinds in the manifest if `kinds` is `None`).
    pub fn load(manifest: &Manifest, kinds: Option<&[&str]>) -> Result<StageRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = BTreeMap::new();
        let names: Vec<String> = match kinds {
            Some(ks) => ks.iter().map(|s| s.to_string()).collect(),
            None => manifest.artifacts.keys().cloned().collect(),
        };
        for name in names {
            let spec = manifest.artifact(&name)?.clone();
            let path = spec
                .file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {:?}", spec.file))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            executables.insert(name, (exe, spec));
        }
        Ok(StageRuntime { client, executables, manifest: manifest.clone() })
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute an artifact: validate inputs against the manifest,
    /// convert, run, and unwrap the output tuple back to host tensors.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let (exe, spec) = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded on this stage"))?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{name}': {} inputs given, {} expected",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.shape || t.dtype() != s.dtype {
                bail!(
                    "artifact '{name}' input {i}: got {:?}/{}, want {:?}/{}",
                    t.shape,
                    t.dtype(),
                    s.shape,
                    s.dtype
                );
            }
        }
        // Stage inputs as explicitly-owned device buffers and run via
        // `execute_b`: the crate's literal-based `execute` allocates
        // device buffers internally that are never released, leaking one
        // params-worth of memory per call (OOM after a few hundred
        // steps); buffers created here are freed by their `Drop`.
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let buffers: Vec<xla::PjRtBuffer> = literals
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l))
            .collect::<std::result::Result<_, _>>()
            .with_context(|| format!("staging inputs of '{name}'"))?;
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .with_context(|| format!("executing '{name}'"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of '{name}'"))?;
        // aot.py lowers with return_tuple=True.
        let parts = tuple.to_tuple().context("unwrapping result tuple")?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact '{name}': {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, s)| HostTensor::from_literal(lit, &s.shape, &s.dtype))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then(|| Manifest::load(&dir).unwrap())
    }

    /// End-to-end: load the real embed_fwd artifact and check the gather
    /// semantics numerically.
    #[test]
    fn embed_fwd_roundtrip() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = StageRuntime::load(&m, Some(&["embed_fwd"])).unwrap();
        let cfg = &rt.manifest.config;
        let vocab = cfg.vocab;
        let d = cfg.d_model;
        // emb[t][j] = t + j/1000 — recognizable rows.
        let emb: Vec<f32> = (0..vocab * d)
            .map(|i| (i / d) as f32 + (i % d) as f32 / 1000.0)
            .collect();
        let tokens: Vec<i32> =
            (0..cfg.microbatch * cfg.seq_len).map(|i| (i % vocab) as i32).collect();
        let out = rt
            .execute(
                "embed_fwd",
                &[
                    HostTensor::f32(vec![vocab, d], emb),
                    HostTensor::i32(vec![cfg.microbatch, cfg.seq_len], tokens),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let x = out[0].as_f32().unwrap();
        // Token 1's row starts at value 1.0.
        assert!((x[d] - 1.0).abs() < 1e-6, "got {}", x[d]);
    }

    #[test]
    fn input_validation_rejects_wrong_shapes() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = StageRuntime::load(&m, Some(&["embed_fwd"])).unwrap();
        let err = rt.execute("embed_fwd", &[HostTensor::zeros(&[1])]);
        assert!(err.is_err());
        assert!(!rt.has("block_fwd"));
        assert!(rt.execute("block_fwd", &[]).is_err());
    }
}
