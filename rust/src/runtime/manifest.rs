//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime — artifact files, input/output tensor specs, and the
//! model configuration they were lowered for.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model configuration recorded by the AOT pipeline.
#[derive(Clone, Debug)]
pub struct ManifestConfig {
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub microbatch: usize,
    pub param_names: Vec<String>,
    pub masked_names: Vec<String>,
    pub mask_shapes: BTreeMap<String, (usize, usize)>,
    pub matrix_shapes: BTreeMap<String, (usize, usize)>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ManifestConfig,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensor specs"))?
        .iter()
        .map(|t| {
            let shape = t
                .get("shape")
                .and_then(|s| s.as_arr())
                .ok_or_else(|| anyhow!("missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            let dtype = t
                .get("dtype")
                .and_then(|d| d.as_str())
                .ok_or_else(|| anyhow!("missing dtype"))?
                .to_string();
            Ok(TensorSpec { shape, dtype })
        })
        .collect()
}

fn shape_pairs(v: &Json) -> Result<BTreeMap<String, (usize, usize)>> {
    let mut out = BTreeMap::new();
    for (name, shape) in v.as_obj().ok_or_else(|| anyhow!("expected object"))? {
        let arr = shape.as_arr().ok_or_else(|| anyhow!("bad shape for {name}"))?;
        if arr.len() != 2 {
            bail!("shape for {name} must be 2-d");
        }
        out.insert(
            name.clone(),
            (
                arr[0].as_usize().ok_or_else(|| anyhow!("bad dim"))?,
                arr[1].as_usize().ok_or_else(|| anyhow!("bad dim"))?,
            ),
        );
    }
    Ok(out)
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let json = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let cfg = json.get("config").ok_or_else(|| anyhow!("missing config"))?;
        let get_usize = |k: &str| -> Result<usize> {
            cfg.get(k).and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("config.{k} missing"))
        };
        let strings = |k: &str| -> Result<Vec<String>> {
            Ok(cfg
                .get(k)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("config.{k} missing"))?
                .iter()
                .filter_map(|s| s.as_str().map(str::to_string))
                .collect())
        };
        let config = ManifestConfig {
            d_model: get_usize("d_model")?,
            n_heads: get_usize("n_heads")?,
            d_ff: get_usize("d_ff")?,
            vocab: get_usize("vocab")?,
            seq_len: get_usize("seq_len")?,
            microbatch: get_usize("microbatch")?,
            param_names: strings("param_names")?,
            masked_names: strings("masked_names")?,
            mask_shapes: shape_pairs(
                cfg.get("mask_shapes").ok_or_else(|| anyhow!("mask_shapes missing"))?,
            )?,
            matrix_shapes: shape_pairs(
                cfg.get("matrix_shapes").ok_or_else(|| anyhow!("matrix_shapes missing"))?,
            )?,
        };

        let mut artifacts = BTreeMap::new();
        for (name, spec) in json
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("missing artifacts"))?
        {
            let file = spec
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("artifact {name}: missing file"))?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: dir.join(file),
                    inputs: specs(spec.get("inputs").ok_or_else(|| anyhow!("inputs"))?)?,
                    outputs: specs(spec.get("outputs").ok_or_else(|| anyhow!("outputs"))?)?,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), config, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.config.d_model > 0);
        assert!(m.artifacts.contains_key("block_fwd"));
        let spec = m.artifact("block_fwd").unwrap();
        assert!(spec.file.exists());
        // block_fwd: 9 params + x.
        assert_eq!(spec.inputs.len(), 10);
        assert_eq!(spec.outputs.len(), 1);
        assert!(m.artifact("nonexistent").is_err());
    }

    #[test]
    fn parses_minimal_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!("tf-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "config": {"d_model": 8, "n_heads": 2, "d_ff": 16, "vocab": 32,
                         "seq_len": 4, "microbatch": 1,
                         "param_names": ["wq"], "masked_names": ["wq"],
                         "mask_shapes": {"wq": [1, 1]},
                         "matrix_shapes": {"wq": [8, 8]}},
              "artifacts": {"x": {"file": "x.hlo.txt",
                "inputs": [{"shape": [8, 8], "dtype": "float32"}],
                "outputs": [{"shape": [8], "dtype": "float32"}]}}
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config.mask_shapes["wq"], (1, 1));
        assert_eq!(m.artifact("x").unwrap().inputs[0].shape, vec![8, 8]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
