//! PJRT runtime layer: manifest parsing, host tensors, and per-thread
//! artifact execution. This is the only module that touches the `xla`
//! crate; everything above it works with [`HostTensor`]s.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::StageRuntime;
pub use manifest::{ArtifactSpec, Manifest, ManifestConfig, TensorSpec};
pub use tensor::{HostTensor, TensorData};
