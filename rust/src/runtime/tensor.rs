//! Host-side tensors: the data that crosses stage-worker channels.
//!
//! PJRT `Literal`s wrap raw pointers and are not `Send`, so inter-stage
//! "communication" (the paper's NVLink/PCIe transfers) moves plain host
//! buffers; each stage worker converts to/from `Literal` at its own PJRT
//! client boundary.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::I32(data) }
    }

    pub fn zeros(shape: &[usize]) -> HostTensor {
        HostTensor::f32(shape.to_vec(), vec![0.0; shape.iter().product()])
    }

    pub fn full(shape: &[usize], value: f32) -> HostTensor {
        HostTensor::f32(shape.to_vec(), vec![value; shape.iter().product()])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> &'static str {
        match self.data {
            TensorData::F32(_) => "float32",
            TensorData::I32(_) => "int32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// L2 norm (f32 tensors).
    pub fn l2(&self) -> f64 {
        match &self.data {
            TensorData::F32(v) => v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt(),
            TensorData::I32(_) => 0.0,
        }
    }

    /// Convert to a PJRT literal (on the calling thread's client).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v.as_slice()).reshape(&dims)?,
            TensorData::I32(v) => xla::Literal::vec1(v.as_slice()).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Read a literal back into host memory. `shape`/`dtype` come from
    /// the artifact manifest (literal shape introspection in the xla
    /// crate is limited).
    pub fn from_literal(lit: &xla::Literal, shape: &[usize], dtype: &str) -> Result<HostTensor> {
        match dtype {
            "float32" => Ok(HostTensor::f32(shape.to_vec(), lit.to_vec::<f32>()?)),
            "int32" => Ok(HostTensor::i32(shape.to_vec(), lit.to_vec::<i32>()?)),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![1.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), "float32");
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        let i = HostTensor::i32(vec![4], vec![1, 2, 3, 4]);
        assert_eq!(i.dtype(), "int32");
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn l2_norm() {
        let t = HostTensor::f32(vec![2], vec![3.0, 4.0]);
        assert!((t.l2() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn zeros_and_full() {
        let z = HostTensor::zeros(&[2, 2]);
        assert_eq!(z.as_f32().unwrap(), &[0.0; 4]);
        let f = HostTensor::full(&[3], 2.5);
        assert_eq!(f.as_f32().unwrap(), &[2.5; 3]);
    }
}
