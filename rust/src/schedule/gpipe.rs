//! GPipe schedule (Huang et al. 2019): all forward microbatches first,
//! then all backwards. Appendix B rule 4 notes the GPipe-specific
//! constraint `v_(f,M,s) → v_(b,1,s)` — encoded here by the per-rank
//! order, from which the DAG builder derives the rule-4 edges.

use super::{chunkmajor_rank_of_stage, Schedule};
use crate::types::{Action, ScheduleKind};

pub fn build(ranks: usize, microbatches: usize) -> Schedule {
    let stages = ranks;
    let mut orders = Vec::with_capacity(ranks);
    for rank in 0..ranks {
        let mut order = Vec::with_capacity(2 * microbatches);
        for m in 0..microbatches {
            order.push(Action::f(m, rank));
        }
        // Backward in microbatch order (Appendix B rule 2 requires
        // (b,m,s) → (b,m+1,s), i.e. ascending microbatch order).
        for m in 0..microbatches {
            order.push(Action::b(m, rank));
        }
        orders.push(order);
    }
    Schedule {
        kind: ScheduleKind::GPipe,
        ranks,
        chunks: 1,
        stages,
        microbatches,
        rank_of_stage: chunkmajor_rank_of_stage(ranks, 1),
        orders,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::ActionKind;

    #[test]
    fn forwards_before_backwards_on_every_rank() {
        let s = build(4, 8);
        for order in &s.orders {
            let first_b = order.iter().position(|a| a.kind == ActionKind::Backward).unwrap();
            let last_f = order
                .iter()
                .rposition(|a| a.kind == ActionKind::Forward)
                .unwrap();
            assert!(last_f < first_b, "GPipe must finish all forwards first");
        }
    }

    #[test]
    fn microbatch_order_ascending() {
        let s = build(2, 4);
        let fwd_mbs: Vec<usize> = s.orders[0]
            .iter()
            .filter(|a| a.kind == ActionKind::Forward)
            .map(|a| a.mb)
            .collect();
        assert_eq!(fwd_mbs, vec![0, 1, 2, 3]);
        let bwd_mbs: Vec<usize> = s.orders[0]
            .iter()
            .filter(|a| a.kind == ActionKind::Backward)
            .map(|a| a.mb)
            .collect();
        assert_eq!(bwd_mbs, vec![0, 1, 2, 3]);
    }

    #[test]
    fn single_rank_single_microbatch() {
        let s = build(1, 1);
        assert_eq!(s.orders[0], vec![Action::f(0, 0), Action::b(0, 0)]);
        s.validate().unwrap();
    }
}
