//! Interleaved 1F1B (Narayanan et al. 2021, Megatron-LM): each rank hosts
//! `V` model chunks ("micro-stages"), shrinking the pipeline bubble by a
//! factor of V at the cost of more communication.
//!
//! For `M % ranks == 0` we reproduce Megatron's closed-form unit order
//! (`get_model_chunk_id`); otherwise we fall back to the greedy list
//! scheduler with 1F1B priority, which yields a legal interleaved order
//! for any (ranks, M, V).

use super::{chunkmajor_rank_of_stage, list_sched, Schedule};
use crate::types::{Action, ScheduleKind};

pub fn build(ranks: usize, microbatches: usize, chunks: usize) -> Schedule {
    let stages = ranks * chunks;
    let rank_of_stage = chunkmajor_rank_of_stage(ranks, chunks);
    let orders = if microbatches % ranks == 0 && ranks > 1 {
        megatron_orders(ranks, microbatches, chunks)
    } else {
        fallback_orders(ranks, microbatches, chunks, &rank_of_stage)
    };
    Schedule {
        kind: ScheduleKind::Interleaved1F1B,
        ranks,
        chunks,
        stages,
        microbatches,
        rank_of_stage,
        orders,
    }
}

/// Megatron's interleaved unit mapping. A "unit" is one (chunk,
/// microbatch) forward or backward on a rank; every rank executes
/// `M · V` forward units and the same number of backward units.
fn unit_to_action(i: usize, ranks: usize, chunks: usize, forward: bool, rank: usize) -> Action {
    let group = ranks * chunks;
    let in_group = i % group;
    let mut chunk = in_group / ranks;
    if !forward {
        chunk = chunks - 1 - chunk;
    }
    let mb = (i / group) * ranks + (in_group % ranks);
    let stage = chunk * ranks + rank;
    if forward {
        Action::f(mb, stage)
    } else {
        Action::b(mb, stage)
    }
}

fn megatron_orders(ranks: usize, m: usize, chunks: usize) -> Vec<Vec<Action>> {
    let total = m * chunks;
    let mut orders = Vec::with_capacity(ranks);
    for rank in 0..ranks {
        // Megatron warm-up depth.
        let warmup = if m == ranks {
            total
        } else {
            ((ranks - rank - 1) * 2 + (chunks - 1) * ranks).min(total)
        };
        let mut order = Vec::with_capacity(2 * total);
        for i in 0..warmup {
            order.push(unit_to_action(i, ranks, chunks, true, rank));
        }
        for k in 0..total {
            if warmup + k < total {
                order.push(unit_to_action(warmup + k, ranks, chunks, true, rank));
            }
            order.push(unit_to_action(k, ranks, chunks, false, rank));
        }
        orders.push(order);
    }
    orders
}

fn fallback_orders(
    ranks: usize,
    m: usize,
    chunks: usize,
    rank_of_stage: &[usize],
) -> Vec<Vec<Action>> {
    let stages = ranks * chunks;
    let mut actions = Vec::with_capacity(2 * stages * m);
    for mb in 0..m {
        for s in 0..stages {
            actions.push(Action::f(mb, s));
            actions.push(Action::b(mb, s));
        }
    }
    list_sched::list_schedule(
        &actions,
        stages,
        m,
        rank_of_stage,
        ranks,
        &list_sched::Priority::one_f_one_b(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::ActionKind;

    #[test]
    fn megatron_unit_mapping_r2_v2() {
        // R=2, V=2: forward units on rank 0 →
        // (c0,m0) (c0,m1) (c1,m0) (c1,m1) (c0,m2) …
        let a0 = unit_to_action(0, 2, 2, true, 0);
        assert_eq!((a0.mb, a0.stage), (0, 0));
        let a2 = unit_to_action(2, 2, 2, true, 0);
        assert_eq!((a2.mb, a2.stage), (0, 2)); // chunk 1 → stage 2
        let a4 = unit_to_action(4, 2, 2, true, 0);
        assert_eq!((a4.mb, a4.stage), (2, 0));
        // Backward reverses chunks.
        let b0 = unit_to_action(0, 2, 2, false, 0);
        assert_eq!((b0.mb, b0.stage), (0, 2));
    }

    #[test]
    fn covers_all_actions_paper_config() {
        // Paper main config: 4 ranks, 8 microbatches, 2 chunks.
        let s = build(4, 8, 2);
        s.validate().unwrap();
        assert_eq!(s.stages, 8);
        assert_eq!(s.action_count(), 2 * 8 * 8);
    }

    #[test]
    fn fallback_covers_non_divisible() {
        let s = build(4, 6, 2);
        s.validate().unwrap();
        assert_eq!(s.action_count(), 2 * 8 * 6);
    }

    #[test]
    fn warmup_shallower_on_later_ranks() {
        let s = build(4, 8, 2);
        // Count leading forwards per rank: later ranks start backward
        // sooner.
        let lead = |r: usize| {
            s.orders[r]
                .iter()
                .take_while(|a| a.kind == ActionKind::Forward)
                .count()
        };
        assert!(lead(0) > lead(3));
    }
}
