//! List-scheduling schedule *generator*: given a set of actions, their
//! structural dependencies (Appendix B rules 1–3), and a pluggable
//! priority rule, simulate one executor per rank and emit a legal
//! per-rank execution order.
//!
//! Two generators live here:
//!
//! * [`list_schedule`] — the original unit-duration tick simulation,
//!   used to construct the hand-tuned-style ZBV order (W actions fill
//!   bubbles) and as the general fallback for Interleaved 1F1B when
//!   `M % ranks ≠ 0` (where the Megatron closed form is undefined).
//! * [`list_schedule_weighted`] — HEFT-style list scheduling over real
//!   action durations: repeatedly commit the highest-priority *available*
//!   action (all predecessors scheduled) to its rank's order. With an
//!   upward-rank table as the priority this is classic HEFT restricted
//!   to fixed placement; `schedule::synth` feeds it critical-path ranks
//!   from the [`CostModel`](crate::cost::CostModel) and from frozen LP
//!   durations.
//!
//! Both emit per-rank total orders that are linear extensions of the
//! structural edges, so [`Schedule::check_legal`](crate::schedule::Schedule::check_legal)
//! holds by construction for any priority rule — the fuzz suite in
//! `tests/schedule_synth.rs` pins that claim.

use crate::graph::pipeline::structural_edges;
use crate::types::{Action, ActionKind};
use crate::util::rng::Rng;
use std::collections::BTreeMap;

fn kind_index(k: ActionKind) -> usize {
    match k {
        ActionKind::Forward => 0,
        ActionKind::Backward => 1,
        ActionKind::BackwardDgrad => 2,
        ActionKind::BackwardWgrad => 3,
    }
}

/// Priority rule for picking among ready actions. Higher wins.
///
/// Scoring is two-level: an optional per-action table (e.g. quantized
/// upward ranks) dominates, then a per-kind score breaks ties. Rules
/// carry a display name so fuzz failures can print the offending
/// (seed, profile, priority) triple.
pub struct Priority {
    /// Display name for diagnostics and fuzz-failure triples.
    name: String,
    /// Per-kind scores indexed `[Forward, Backward, BackwardDgrad,
    /// BackwardWgrad]`.
    kind_scores: [i64; 4],
    /// Optional per-action score that dominates the kind score.
    table: Option<BTreeMap<Action, i64>>,
}

impl Priority {
    /// ZBV priority: dgrad first — it unblocks upstream ranks — then the
    /// fused backward (which carries a dgrad), then forwards, then W
    /// (wgrad) to fill bubbles. The split dgrad outranks the fused
    /// backward: on a mixed action set the pure unblocking move must win
    /// the tie against the heavier fused node (fused B previously tied
    /// dgrad at 3, which let a fused backward starve a ready dgrad).
    pub fn zero_bubble() -> Priority {
        Priority {
            name: "zero_bubble".to_string(),
            // [F, B, Bd, Bw]
            kind_scores: [2, 3, 4, 1],
            table: None,
        }
    }

    /// 1F1B-like priority: backward preferred once ready (bounds live
    /// activations), forwards otherwise.
    pub fn one_f_one_b() -> Priority {
        Priority {
            name: "one_f_one_b".to_string(),
            kind_scores: [0, 2, 2, 1],
            table: None,
        }
    }

    /// Memory-first priority (Controllable-Memory-style): retire whole
    /// microbatches — dgrad, then wgrad (which releases the stash), and
    /// forwards (which grow it) last.
    pub fn memory_first() -> Priority {
        Priority {
            name: "memory_first".to_string(),
            kind_scores: [1, 3, 4, 2],
            table: None,
        }
    }

    /// Priority dominated by a per-action score table (e.g. quantized
    /// upward ranks from [`crate::cost::upward_ranks`]); kind scores fall
    /// back to [`Priority::zero_bubble`] ordering for ties.
    pub fn with_table(name: impl Into<String>, table: BTreeMap<Action, i64>) -> Priority {
        Priority { name: name.into(), kind_scores: [2, 3, 4, 1], table: Some(table) }
    }

    /// Seeded random rule for the fuzz suite: a random permutation of the
    /// kind scores. Any permutation must still yield a legal,
    /// deadlock-free order.
    pub fn random(seed: u64) -> Priority {
        let mut rng = Rng::seed_from_u64(seed).derive(0x5072_696f, 0);
        let mut scores = [1i64, 2, 3, 4];
        rng.shuffle(&mut scores);
        Priority { name: format!("random(seed=0x{seed:016x})"), kind_scores: scores, table: None }
    }

    /// Attach a per-action score table to an existing rule (the table
    /// dominates; existing kind scores keep breaking ties). Used by the
    /// fuzz suite to combine random kind permutations with random
    /// per-action jitter.
    pub fn and_table(mut self, table: BTreeMap<Action, i64>) -> Priority {
        self.table = Some(table);
        self
    }

    /// Display name, e.g. `upward_rank` or `random(seed=0x…)`.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Two-level score of one action: (table score, kind score).
    pub fn score(&self, a: Action) -> (i64, i64) {
        let t = self.table.as_ref().map_or(0, |t| t.get(&a).copied().unwrap_or(0));
        (t, self.kind_scores[kind_index(a.kind)])
    }
}

/// Index actions and wire up the rule-1–3 predecessor counts and
/// successor lists shared by both generators.
fn dependency_lists(
    actions: &[Action],
    stages: usize,
    microbatches: usize,
) -> (Vec<usize>, Vec<Vec<usize>>) {
    let n = actions.len();
    let index: BTreeMap<Action, usize> = actions.iter().enumerate().map(|(i, a)| (*a, i)).collect();
    let mut preds_left = vec![0usize; n];
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (u, v) in structural_edges(actions, stages, microbatches) {
        let (ui, vi) = (index[&u], index[&v]);
        succs[ui].push(vi);
        preds_left[vi] += 1;
    }
    (preds_left, succs)
}

/// Simulate unit-duration execution with one executor per rank; returns
/// per-rank orders. Panics if the dependency graph deadlocks (cannot
/// happen for the rule-1–3 edge set, which is acyclic by construction).
pub fn list_schedule(
    actions: &[Action],
    stages: usize,
    microbatches: usize,
    rank_of_stage: &[usize],
    ranks: usize,
    prio: &Priority,
) -> Vec<Vec<Action>> {
    let n = actions.len();
    let (mut preds_left, succs) = dependency_lists(actions, stages, microbatches);

    let mut ready: Vec<Vec<usize>> = vec![Vec::new(); ranks]; // per rank
    for i in 0..n {
        if preds_left[i] == 0 {
            ready[rank_of_stage[actions[i].stage]].push(i);
        }
    }

    let mut orders: Vec<Vec<Action>> = vec![Vec::new(); ranks];
    let mut done = 0usize;
    // Time-stepped simulation with unit durations: at each tick every
    // idle rank executes its best ready action; completions release
    // successors for the *next* tick (communication is instantaneous).
    while done < n {
        let mut executed: Vec<usize> = Vec::with_capacity(ranks);
        for rank in 0..ranks {
            if ready[rank].is_empty() {
                continue;
            }
            // Pick max priority; tie-break on (mb, stage) ascending for
            // determinism.
            let best_pos = ready[rank]
                .iter()
                .enumerate()
                .max_by_key(|(_, &i)| {
                    let a = actions[i];
                    (prio.score(a), std::cmp::Reverse(a.mb), std::cmp::Reverse(a.stage))
                })
                .map(|(pos, _)| pos)
                .unwrap();
            let i = ready[rank].swap_remove(best_pos);
            orders[rank].push(actions[i]);
            executed.push(i);
        }
        assert!(
            !executed.is_empty(),
            "list scheduler deadlocked with {} of {} actions done (priority {})",
            done,
            n,
            prio.name()
        );
        done += executed.len();
        for i in executed {
            for &j in &succs[i] {
                preds_left[j] -= 1;
                if preds_left[j] == 0 {
                    ready[rank_of_stage[actions[j].stage]].push(j);
                }
            }
        }
    }
    orders
}

/// HEFT-style list scheduling over real durations: repeatedly pick the
/// highest-priority action whose predecessors are all scheduled, and
/// commit it to its rank at `max(rank free time, latest pred finish)`.
/// Placement is fixed (the stage names the rank), so only the *order*
/// is synthesized; the emitted per-rank orders are linear extensions of
/// the structural edges by construction. Panics on deadlock, naming the
/// priority rule (cannot happen for the acyclic rule-1–3 edge set).
pub fn list_schedule_weighted(
    actions: &[Action],
    stages: usize,
    microbatches: usize,
    rank_of_stage: &[usize],
    ranks: usize,
    prio: &Priority,
    duration: &dyn Fn(Action) -> f64,
) -> Vec<Vec<Action>> {
    let n = actions.len();
    let (mut preds_left, succs) = dependency_lists(actions, stages, microbatches);

    // `release[i]` = latest finish among scheduled predecessors; valid
    // once preds_left[i] == 0.
    let mut release = vec![0.0f64; n];
    let mut avail: Vec<usize> = (0..n).filter(|&i| preds_left[i] == 0).collect();
    let mut rank_free = vec![0.0f64; ranks];
    let mut orders: Vec<Vec<Action>> = vec![Vec::new(); ranks];

    for scheduled in 0..n {
        assert!(
            !avail.is_empty(),
            "weighted list scheduler deadlocked with {} of {} actions done (priority {})",
            scheduled,
            n,
            prio.name()
        );
        let best_pos = avail
            .iter()
            .enumerate()
            .max_by_key(|(_, &i)| {
                let a = actions[i];
                (prio.score(a), std::cmp::Reverse(a.mb), std::cmp::Reverse(a.stage))
            })
            .map(|(pos, _)| pos)
            .unwrap();
        let i = avail.swap_remove(best_pos);
        let a = actions[i];
        let rank = rank_of_stage[a.stage];
        let start = rank_free[rank].max(release[i]);
        let finish = start + duration(a);
        rank_free[rank] = finish;
        orders[rank].push(a);
        for &j in &succs[i] {
            release[j] = release[j].max(finish);
            preds_left[j] -= 1;
            if preds_left[j] == 0 {
                avail.push(j);
            }
        }
    }
    orders
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two-stage, two-microbatch combined-backward pipeline scheduled with
    /// 1F1B priority must produce a legal order with all 8 actions.
    #[test]
    fn schedules_small_pipeline() {
        let mut actions = Vec::new();
        for m in 0..2 {
            for s in 0..2 {
                actions.push(Action::f(m, s));
                actions.push(Action::b(m, s));
            }
        }
        let orders = list_schedule(&actions, 2, 2, &[0, 1], 2, &Priority::one_f_one_b());
        let total: usize = orders.iter().map(|o| o.len()).sum();
        assert_eq!(total, 8);
        // Rank 1 (last stage) must run b(0,1) before f/b of mb1 backward…
        let r1 = &orders[1];
        let pos = |a: Action| r1.iter().position(|x| *x == a).unwrap();
        assert!(pos(Action::f(0, 1)) < pos(Action::b(0, 1)));
        assert!(pos(Action::b(0, 1)) < pos(Action::b(1, 1)));
    }

    /// ZBV priority defers W actions behind dgrad.
    #[test]
    fn wgrad_deferred() {
        let actions = vec![Action::f(0, 0), Action::bd(0, 0), Action::bw(0, 0)];
        let orders = list_schedule(&actions, 1, 1, &[0], 1, &Priority::zero_bubble());
        assert_eq!(orders[0], vec![Action::f(0, 0), Action::bd(0, 0), Action::bw(0, 0)]);
    }

    /// On a mixed fused/split action set the pure dgrad must outrank the
    /// fused backward (the pre-fix tie let the fused node starve it).
    #[test]
    fn split_dgrad_outranks_fused_backward() {
        let prio = Priority::zero_bubble();
        let bd = Action::bd(0, 1);
        let b = Action::b(0, 0);
        assert!(prio.score(bd) > prio.score(b));
        assert!(prio.score(b) > prio.score(Action::f(1, 0)));
        assert!(prio.score(Action::f(1, 0)) > prio.score(Action::bw(0, 1)));
    }

    /// The weighted generator emits the same rank totals and respects the
    /// same structural order as the unit-tick one.
    #[test]
    fn weighted_schedules_small_pipeline() {
        let mut actions = Vec::new();
        for m in 0..3 {
            for s in 0..2 {
                actions.push(Action::f(m, s));
                actions.push(Action::bd(m, s));
                actions.push(Action::bw(m, s));
            }
        }
        let dur = |a: Action| match a.kind {
            ActionKind::Forward => 1.0,
            ActionKind::BackwardDgrad => 2.0,
            _ => 0.5,
        };
        let orders = list_schedule_weighted(
            &actions,
            2,
            3,
            &[0, 1],
            2,
            &Priority::zero_bubble(),
            &dur,
        );
        let total: usize = orders.iter().map(|o| o.len()).sum();
        assert_eq!(total, 18);
        let r0 = &orders[0];
        let pos = |a: Action| r0.iter().position(|x| *x == a).unwrap();
        assert!(pos(Action::f(0, 0)) < pos(Action::bd(0, 0)));
        assert!(pos(Action::bd(0, 0)) < pos(Action::bw(0, 0)));
    }

    /// A random priority permutation still schedules every action.
    #[test]
    fn random_priority_is_total() {
        let mut actions = Vec::new();
        for m in 0..2 {
            for s in 0..2 {
                actions.push(Action::f(m, s));
                actions.push(Action::b(m, s));
            }
        }
        for seed in 0..8 {
            let prio = Priority::random(seed);
            let orders = list_schedule(&actions, 2, 2, &[0, 1], 2, &prio);
            assert_eq!(orders.iter().map(|o| o.len()).sum::<usize>(), 8, "{}", prio.name());
        }
    }
}
