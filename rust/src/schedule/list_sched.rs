//! Greedy list scheduler: given a set of actions, their structural
//! dependencies (Appendix B rules 1–3), and a priority rule, simulate one
//! executor per rank and emit a legal per-rank execution order.
//!
//! Used to construct the hand-tuned-style ZBV order (W actions fill
//! bubbles) and as the general fallback for Interleaved 1F1B when
//! `M % ranks ≠ 0` (where the Megatron closed form is undefined).

use crate::graph::pipeline::structural_edges;
use crate::types::{Action, ActionKind};
use std::collections::BTreeMap;

/// Priority rule for picking among ready actions. Higher wins.
pub struct Priority {
    /// Rank-ordering of kinds, e.g. dgrad before forward before wgrad.
    pub kind_score: fn(ActionKind) -> i64,
}

impl Priority {
    /// ZBV priority: B (dgrad) first — it unblocks upstream ranks — then
    /// forwards, then W (wgrad) to fill bubbles.
    pub fn zero_bubble() -> Priority {
        Priority {
            kind_score: |k| match k {
                ActionKind::BackwardDgrad => 3,
                ActionKind::Forward => 2,
                ActionKind::BackwardWgrad => 1,
                ActionKind::Backward => 3,
            },
        }
    }

    /// 1F1B-like priority: backward preferred once ready (bounds live
    /// activations), forwards otherwise.
    pub fn one_f_one_b() -> Priority {
        Priority {
            kind_score: |k| match k {
                ActionKind::Backward | ActionKind::BackwardDgrad => 2,
                ActionKind::BackwardWgrad => 1,
                ActionKind::Forward => 0,
            },
        }
    }
}

/// Simulate unit-duration execution with one executor per rank; returns
/// per-rank orders. Panics if the dependency graph deadlocks (cannot
/// happen for the rule-1–3 edge set, which is acyclic by construction).
pub fn list_schedule(
    actions: &[Action],
    stages: usize,
    microbatches: usize,
    rank_of_stage: &[usize],
    ranks: usize,
    prio: &Priority,
) -> Vec<Vec<Action>> {
    let n = actions.len();
    let index: BTreeMap<Action, usize> = actions.iter().enumerate().map(|(i, a)| (*a, i)).collect();
    let mut preds_left = vec![0usize; n];
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (u, v) in structural_edges(actions, stages, microbatches) {
        let (ui, vi) = (index[&u], index[&v]);
        succs[ui].push(vi);
        preds_left[vi] += 1;
    }

    let mut ready: Vec<Vec<usize>> = vec![Vec::new(); ranks]; // per rank
    for i in 0..n {
        if preds_left[i] == 0 {
            ready[rank_of_stage[actions[i].stage]].push(i);
        }
    }

    let mut orders: Vec<Vec<Action>> = vec![Vec::new(); ranks];
    let mut done = 0usize;
    // Time-stepped simulation with unit durations: at each tick every
    // idle rank executes its best ready action; completions release
    // successors for the *next* tick (communication is instantaneous).
    while done < n {
        let mut executed: Vec<usize> = Vec::with_capacity(ranks);
        for rank in 0..ranks {
            if ready[rank].is_empty() {
                continue;
            }
            // Pick max priority; tie-break on (mb, stage) ascending for
            // determinism.
            let best_pos = ready[rank]
                .iter()
                .enumerate()
                .max_by_key(|(_, &i)| {
                    let a = actions[i];
                    (
                        (prio.kind_score)(a.kind),
                        std::cmp::Reverse(a.mb),
                        std::cmp::Reverse(a.stage),
                    )
                })
                .map(|(pos, _)| pos)
                .unwrap();
            let i = ready[rank].swap_remove(best_pos);
            orders[rank].push(actions[i]);
            executed.push(i);
        }
        assert!(
            !executed.is_empty(),
            "list scheduler deadlocked with {} of {} actions done",
            done,
            n
        );
        done += executed.len();
        for i in executed {
            for &j in &succs[i] {
                preds_left[j] -= 1;
                if preds_left[j] == 0 {
                    ready[rank_of_stage[actions[j].stage]].push(j);
                }
            }
        }
    }
    orders
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two-stage, two-microbatch combined-backward pipeline scheduled with
    /// 1F1B priority must produce a legal order with all 8 actions.
    #[test]
    fn schedules_small_pipeline() {
        let mut actions = Vec::new();
        for m in 0..2 {
            for s in 0..2 {
                actions.push(Action::f(m, s));
                actions.push(Action::b(m, s));
            }
        }
        let orders = list_schedule(&actions, 2, 2, &[0, 1], 2, &Priority::one_f_one_b());
        let total: usize = orders.iter().map(|o| o.len()).sum();
        assert_eq!(total, 8);
        // Rank 1 (last stage) must run b(0,1) before f/b of mb1 backward…
        let r1 = &orders[1];
        let pos = |a: Action| r1.iter().position(|x| *x == a).unwrap();
        assert!(pos(Action::f(0, 1)) < pos(Action::b(0, 1)));
        assert!(pos(Action::b(0, 1)) < pos(Action::b(1, 1)));
    }

    /// ZBV priority defers W actions behind dgrad.
    #[test]
    fn wgrad_deferred() {
        let actions = vec![Action::f(0, 0), Action::bd(0, 0), Action::bw(0, 0)];
        let orders = list_schedule(&actions, 1, 1, &[0], 1, &Priority::zero_bubble());
        assert_eq!(orders[0], vec![Action::f(0, 0), Action::bd(0, 0), Action::bw(0, 0)]);
    }
}
