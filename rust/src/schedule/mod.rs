//! Pipeline schedules (§2.1, §4.2): GPipe, 1F1B, Interleaved 1F1B, and
//! Zero-Bubble V (ZBV).
//!
//! A [`Schedule`] is the ground truth the rest of the system consumes:
//! * `orders[rank]` — the exact per-rank execution order of actions
//!   (Appendix B rule 4: same-rank actions respect this order);
//! * `rank_of_stage` — virtual-stage → GPU-rank placement (Interleaved and
//!   ZBV place multiple model chunks per rank);
//! * structural dependencies are *not* stored here — they are derived by
//!   [`crate::graph::pipeline`] from rules 1–3 of Appendix B.
//!
//! All builders are deterministic and panic-free for `ranks ≥ 1`,
//! `microbatches ≥ 1`.

mod gpipe;
mod interleaved;
mod list_sched;
mod one_f_one_b;
mod zbv;

pub use list_sched::{list_schedule, Priority};

use crate::types::{Action, ActionKind, ScheduleKind};

/// A fully-instantiated pipeline schedule for one batch.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Which schedule family built this.
    pub kind: ScheduleKind,
    /// Number of physical GPU ranks.
    pub ranks: usize,
    /// Model chunks hosted per rank (1 for GPipe/1F1B, ≥2 otherwise).
    pub chunks: usize,
    /// Total virtual stages = `ranks * chunks`.
    pub stages: usize,
    /// Microbatches per batch.
    pub microbatches: usize,
    /// Virtual stage → rank placement.
    pub rank_of_stage: Vec<usize>,
    /// Per-rank execution order (Appendix B rule 4).
    pub orders: Vec<Vec<Action>>,
}

impl Schedule {
    /// Build the schedule `kind` for `ranks` GPUs and `microbatches`
    /// microbatches. `chunks` is honoured by Interleaved 1F1B (ZBV is
    /// fixed at 2 chunks by its V shape; GPipe/1F1B at 1).
    pub fn build(kind: ScheduleKind, ranks: usize, microbatches: usize, chunks: usize) -> Schedule {
        assert!(ranks >= 1, "need at least one rank");
        assert!(microbatches >= 1, "need at least one microbatch");
        match kind {
            ScheduleKind::GPipe => gpipe::build(ranks, microbatches),
            ScheduleKind::OneFOneB => one_f_one_b::build(ranks, microbatches),
            ScheduleKind::Interleaved1F1B => {
                interleaved::build(ranks, microbatches, chunks.max(2))
            }
            ScheduleKind::ZeroBubbleV => zbv::build(ranks, microbatches),
        }
    }

    /// Default chunk count used in the paper's experiments.
    pub fn default_chunks(kind: ScheduleKind) -> usize {
        match kind {
            ScheduleKind::GPipe | ScheduleKind::OneFOneB => 1,
            ScheduleKind::Interleaved1F1B | ScheduleKind::ZeroBubbleV => 2,
        }
    }

    /// All actions across all ranks (order: rank-major, schedule order).
    pub fn all_actions(&self) -> Vec<Action> {
        self.orders.iter().flatten().copied().collect()
    }

    /// Total number of action nodes in the pipeline DAG (excluding
    /// source/destination).
    pub fn action_count(&self) -> usize {
        self.orders.iter().map(|o| o.len()).sum()
    }

    /// Expected number of forward actions (one per stage per microbatch).
    pub fn expected_forward_count(&self) -> usize {
        self.stages * self.microbatches
    }

    /// Sanity checks shared by all builders; called from tests and from
    /// `debug_assert!` in the DAG builder.
    pub fn validate(&self) -> Result<(), String> {
        if self.rank_of_stage.len() != self.stages {
            return Err("rank_of_stage length mismatch".into());
        }
        if self.orders.len() != self.ranks {
            return Err("orders length mismatch".into());
        }
        if self.stages != self.ranks * self.chunks {
            return Err("stages != ranks*chunks".into());
        }
        // Every action appears exactly once, on the rank that owns its
        // stage; forward/backward coverage is complete.
        let mut seen = std::collections::BTreeSet::new();
        let mut fwd = 0usize;
        let mut bwd_units = 0usize; // Backward or BackwardDgrad
        for (rank, order) in self.orders.iter().enumerate() {
            for a in order {
                if a.stage >= self.stages || a.mb >= self.microbatches {
                    return Err(format!("action {a} out of range"));
                }
                if self.rank_of_stage[a.stage] != rank {
                    return Err(format!(
                        "action {a} scheduled on rank {rank} but stage {} lives on rank {}",
                        a.stage, self.rank_of_stage[a.stage]
                    ));
                }
                if !seen.insert(*a) {
                    return Err(format!("duplicate action {a}"));
                }
                match a.kind {
                    ActionKind::Forward => fwd += 1,
                    ActionKind::Backward | ActionKind::BackwardDgrad => bwd_units += 1,
                    ActionKind::BackwardWgrad => {}
                }
            }
        }
        let expect = self.stages * self.microbatches;
        if fwd != expect {
            return Err(format!("forward count {fwd} != {expect}"));
        }
        if bwd_units != expect {
            return Err(format!("backward count {bwd_units} != {expect}"));
        }
        Ok(())
    }
}

/// Helper shared by builders: stage placement for `chunks` model chunks
/// per rank, chunk-major (`stage = chunk*ranks + rank`), i.e. forward
/// traverses ranks 0..R for chunk 0, then 0..R again for chunk 1, …
pub(crate) fn chunkmajor_rank_of_stage(ranks: usize, chunks: usize) -> Vec<usize> {
    (0..ranks * chunks).map(|s| s % ranks).collect()
}

/// Stage placement for ZBV's V shape: rank r hosts virtual stages `r`
/// (descending leg) and `2R−1−r` (ascending leg), so forward goes
/// 0→1→…→R−1 (down the ranks) then R→…→2R−1 back up: rank of stage s is
/// `s` for s < R and `2R−1−s` for s ≥ R.
pub(crate) fn vshape_rank_of_stage(ranks: usize) -> Vec<usize> {
    (0..2 * ranks)
        .map(|s| if s < ranks { s } else { 2 * ranks - 1 - s })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_schedules_validate_across_sizes() {
        for kind in ScheduleKind::all() {
            for ranks in [1, 2, 4, 6, 8] {
                for m in [1, 2, 4, 8, 12] {
                    let s = Schedule::build(kind, ranks, m, Schedule::default_chunks(kind));
                    s.validate().unwrap_or_else(|e| {
                        panic!("{} ranks={ranks} m={m}: {e}", kind.name())
                    });
                }
            }
        }
    }

    #[test]
    fn chunkmajor_placement() {
        assert_eq!(chunkmajor_rank_of_stage(4, 2), vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn vshape_placement() {
        assert_eq!(vshape_rank_of_stage(4), vec![0, 1, 2, 3, 3, 2, 1, 0]);
        assert_eq!(vshape_rank_of_stage(1), vec![0, 0]);
    }

    #[test]
    fn zbv_emits_split_backward() {
        let s = Schedule::build(ScheduleKind::ZeroBubbleV, 4, 8, 2);
        let has_w = s
            .all_actions()
            .iter()
            .any(|a| a.kind == ActionKind::BackwardWgrad);
        let has_bd = s
            .all_actions()
            .iter()
            .any(|a| a.kind == ActionKind::BackwardDgrad);
        assert!(has_w && has_bd);
        // W count equals B count equals stage*mb.
        let w = s
            .all_actions()
            .iter()
            .filter(|a| a.kind == ActionKind::BackwardWgrad)
            .count();
        assert_eq!(w, s.stages * s.microbatches);
    }
}
