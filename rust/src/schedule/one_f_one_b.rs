//! 1F1B schedule (PipeDream-Flush / DAPPLE; Narayanan et al. 2019, Fan et
//! al. 2021): each rank runs a warm-up of forwards, then alternates one
//! forward with one backward, then drains the remaining backwards. This
//! bounds in-flight activations at `S − rank` microbatches.

use super::{chunkmajor_rank_of_stage, Schedule};
use crate::types::{Action, ScheduleKind};

pub fn build(ranks: usize, microbatches: usize) -> Schedule {
    let stages = ranks;
    let m = microbatches;
    let mut orders = Vec::with_capacity(ranks);
    for rank in 0..ranks {
        // Standard 1F1B warm-up depth: the last rank starts steady-state
        // immediately; rank r runs (S − 1 − r) forwards first.
        let warmup = (ranks - 1 - rank).min(m);
        let mut order = Vec::with_capacity(2 * m);
        for i in 0..warmup {
            order.push(Action::f(i, rank));
        }
        // Steady state: F(warmup + k) then B(k) while forwards remain.
        for k in 0..m {
            if warmup + k < m {
                order.push(Action::f(warmup + k, rank));
            }
            order.push(Action::b(k, rank));
        }
        orders.push(order);
    }
    Schedule {
        kind: ScheduleKind::OneFOneB,
        ranks,
        chunks: 1,
        stages,
        microbatches: m,
        rank_of_stage: chunkmajor_rank_of_stage(ranks, 1),
        orders,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::ActionKind;

    /// Canonical 4-rank, 8-microbatch 1F1B pattern (Figure 8 of the
    /// paper): rank 3 strictly alternates F B F B …, rank 0 has 3 warmup
    /// forwards and 3 drain backwards.
    #[test]
    fn canonical_4x8_pattern() {
        let s = build(4, 8);
        let kinds = |r: usize| -> String {
            s.orders[r].iter().map(|a| a.kind.label()).collect()
        };
        assert_eq!(kinds(3), "FBFBFBFBFBFBFBFB");
        assert_eq!(kinds(0), "FFFFBFBFBFBFBBBB");
    }

    #[test]
    fn in_flight_activation_bound() {
        // At any prefix of a rank's order, (#F − #B) ≤ S − rank.
        let ranks = 6;
        let s = build(ranks, 12);
        for (rank, order) in s.orders.iter().enumerate() {
            let mut live: i64 = 0;
            for a in order {
                match a.kind {
                    ActionKind::Forward => live += 1,
                    ActionKind::Backward => live -= 1,
                    _ => {}
                }
                assert!(
                    live <= (ranks - rank) as i64,
                    "rank {rank} exceeds activation bound: {live}"
                );
                assert!(live >= 0, "backward before its forward on rank {rank}");
            }
        }
    }

    #[test]
    fn backward_mb_order_ascending() {
        let s = build(4, 8);
        for order in &s.orders {
            let bw: Vec<usize> = order
                .iter()
                .filter(|a| a.kind == ActionKind::Backward)
                .map(|a| a.mb)
                .collect();
            let mut sorted = bw.clone();
            sorted.sort_unstable();
            assert_eq!(bw, sorted);
        }
    }

    #[test]
    fn fewer_microbatches_than_ranks() {
        // Degenerate but legal: M < S. Warm-up saturates at M.
        let s = build(8, 2);
        s.validate().unwrap();
        for order in &s.orders {
            assert_eq!(order.len(), 4);
        }
    }
}
