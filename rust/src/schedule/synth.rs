//! Schedule *synthesis*: instead of consuming one of the four fixed
//! orders, generate the order itself and co-optimize it with the freeze
//! LP.
//!
//! The synthesizer is a **portfolio with a fixed-point refinement**:
//!
//! 1. **Portfolio** — candidates over both pipeline shapes: the exact
//!    four fixed schedules (GPipe and 1F1B on the flat R-stage shape;
//!    Interleaved 1F1B and ZBV on the 2-chunk, 2R-stage shape),
//!    rebranded [`ScheduleKind::Synthesized`], plus generated orders
//!    from the list schedulers — the split dgrad/wgrad action set under
//!    the zero-bubble, memory-first (Controllable-Memory-style V
//!    placement), and HEFT upward-rank priorities, on the flat and
//!    V-shape placements.
//! 2. **Scoring** — every candidate is scored by its *exact* no-freeze
//!    makespan under the shape-matched [`CostModel`]: the longest path
//!    over `w_max` durations (plus P2P edge delays where the model
//!    carries them) plus the optimizer tail — bit-identical to the
//!    `batch_time_nofreeze` the simulator reports. Because the four
//!    fixed schedules are themselves candidates, the winner is **never
//!    worse than the best fixed schedule by construction**; that is the
//!    acceptance property `benches/fig7to13_schedules.rs` asserts per
//!    grid cell and `tests/schedule_synth.rs` asserts on random cost
//!    profiles.
//! 3. **Fixed point** — solve the freeze LP on the winner's DAG (via
//!    the persistent [`FreezeLpSolver`]), re-rank actions by upward
//!    rank under the *frozen* durations the LP chose, regenerate with
//!    the weighted list scheduler, and adopt the new order only when
//!    its no-freeze makespan strictly improves; repeat until the
//!    makespan stops improving (bounded rounds). Re-ranking uses the
//!    frozen cost model — a bubble that exists at `w_max` may vanish
//!    once wgrads shrink — while *selection* stays on the no-freeze
//!    makespan, which keeps the portfolio guarantee monotone.
//!
//! Legality is structural: both generators emit per-rank linear
//! extensions of the Appendix B rule-1–3 edges, so every candidate
//! passes [`Schedule::check_legal`]; the fuzz suite pins that for
//! random priorities too.

use crate::cost::{quantize_ranks, upward_ranks, CostModel};
use crate::graph::pipeline::PipelineDag;
use crate::lp::{FreezeLpInput, FreezeLpSolver};
use crate::types::{Action, ScheduleKind};
use std::collections::BTreeMap;

use super::{
    chunkmajor_rank_of_stage, list_schedule, list_schedule_weighted, vshape_rank_of_stage,
    Priority, Schedule,
};

/// Maximum schedule↔LP fixed-point rounds (each adopts only a strict
/// makespan improvement, so the loop usually converges in one or two).
const FIXPOINT_ROUNDS: usize = 3;

/// One scored portfolio candidate.
#[derive(Clone, Debug)]
pub struct CandidateScore {
    /// Candidate label, e.g. `fixed:ZBV` or `heft:upward_rank@v`.
    pub name: String,
    /// No-freeze makespan (see [`makespan_of`]).
    pub makespan: f64,
}

/// The synthesized schedule plus its provenance.
#[derive(Clone, Debug)]
pub struct SynthOutcome {
    /// The winning order, rebranded [`ScheduleKind::Synthesized`]
    /// (`chunks` is 1 for flat winners, 2 for V-shape winners).
    pub schedule: Schedule,
    /// The winner's no-freeze makespan (see [`makespan_of`]).
    pub makespan: f64,
    /// `P_d*` of the freeze LP on the winner's DAG plus the optimizer
    /// tail; `None` when the LP was skipped or infeasible.
    pub planned_batch_time: Option<f64>,
    /// Every candidate evaluated, in generation order.
    pub candidates: Vec<CandidateScore>,
}

/// Exact no-freeze makespan of a schedule under `cost` — mirrors the
/// simulator's `batch_time_nofreeze` bit for bit: longest path over
/// `duration(a, 0)` node weights, P2P edge delays when the model
/// carries them, plus the once-per-batch optimizer tail.
pub fn makespan_of(schedule: &Schedule, cost: &CostModel) -> f64 {
    assert_eq!(schedule.stages, cost.stages, "cost model shape mismatch");
    let pdag = PipelineDag::from_schedule(schedule);
    let w = pdag.weights(|a| cost.duration(a, 0.0));
    let span = if cost.has_p2p() {
        let delays = pdag.p2p_edge_costs(|a, b| cost.p2p(a, b));
        pdag.batch_time_with_edges(&w, &delays)
    } else {
        pdag.batch_time(&w)
    };
    span + cost.optimizer_tail()
}

/// The split dgrad/wgrad action set: F, B(dgrad), W per (microbatch,
/// stage).
fn split_actions(stages: usize, microbatches: usize) -> Vec<Action> {
    let mut v = Vec::with_capacity(3 * stages * microbatches);
    for m in 0..microbatches {
        for s in 0..stages {
            v.push(Action::f(m, s));
            v.push(Action::bd(m, s));
            v.push(Action::bw(m, s));
        }
    }
    v
}

/// Wrap generated per-rank orders into a `Synthesized` schedule.
fn from_orders(
    ranks: usize,
    chunks: usize,
    microbatches: usize,
    rank_of_stage: Vec<usize>,
    orders: Vec<Vec<Action>>,
) -> Schedule {
    Schedule {
        kind: ScheduleKind::Synthesized,
        ranks,
        chunks,
        stages: ranks * chunks,
        microbatches,
        rank_of_stage,
        orders,
    }
}

fn rebrand(mut s: Schedule) -> Schedule {
    s.kind = ScheduleKind::Synthesized;
    s
}

/// A candidate awaiting scoring: the schedule and which shape's cost
/// model scores it (`flat` = true ⇒ R stages, else 2R).
struct Candidate {
    name: String,
    schedule: Schedule,
    flat: bool,
}

/// Generate the full candidate portfolio for both shapes. Deterministic.
fn portfolio(
    flat_cost: &CostModel,
    chunked_cost: &CostModel,
    ranks: usize,
    microbatches: usize,
) -> Vec<Candidate> {
    let m = microbatches;
    let mut out = Vec::new();
    // The exact fixed four — the floor of the portfolio: scoring them
    // under the same shape-matched cost models the simulator would use
    // makes "synthesized ≤ best fixed" hold by construction.
    for kind in ScheduleKind::all() {
        let chunks = Schedule::default_chunks(kind);
        out.push(Candidate {
            name: format!("fixed:{}", kind.name()),
            schedule: rebrand(Schedule::build(kind, ranks, m, chunks)),
            flat: chunks == 1,
        });
    }
    // Flat shape, split backward: zero-bubble 1F1B with W filling
    // bubbles — often the real winner (same per-rank work as 1F1B, no
    // extra chunk overhead, smaller tail).
    let flat_ros: Vec<usize> = (0..ranks).collect();
    let flat_split = split_actions(ranks, m);
    out.push(Candidate {
        name: "list:zero_bubble@flat".to_string(),
        schedule: from_orders(
            ranks,
            1,
            m,
            flat_ros.clone(),
            list_schedule(&flat_split, ranks, m, &flat_ros, ranks, &Priority::zero_bubble()),
        ),
        flat: true,
    });
    let flat_dur = |a: Action| flat_cost.duration(a, 0.0);
    let flat_table = quantize_ranks(&upward_ranks(&flat_split, ranks, m, flat_dur));
    out.push(Candidate {
        name: "heft:upward_rank@flat".to_string(),
        schedule: from_orders(
            ranks,
            1,
            m,
            flat_ros.clone(),
            list_schedule_weighted(
                &flat_split,
                ranks,
                m,
                &flat_ros,
                ranks,
                &Priority::with_table("upward_rank", flat_table),
                &flat_dur,
            ),
        ),
        flat: true,
    });
    // V shape, split backward: HEFT upward rank and the memory-first
    // variant (retire microbatches early, à la Controllable-Memory).
    let v_ros = vshape_rank_of_stage(ranks);
    let v_stages = 2 * ranks;
    let v_split = split_actions(v_stages, m);
    let v_dur = |a: Action| chunked_cost.duration(a, 0.0);
    let v_table = quantize_ranks(&upward_ranks(&v_split, v_stages, m, v_dur));
    out.push(Candidate {
        name: "heft:upward_rank@v".to_string(),
        schedule: from_orders(
            ranks,
            2,
            m,
            v_ros.clone(),
            list_schedule_weighted(
                &v_split,
                v_stages,
                m,
                &v_ros,
                ranks,
                &Priority::with_table("upward_rank", v_table.clone()),
                &v_dur,
            ),
        ),
        flat: false,
    });
    out.push(Candidate {
        name: "list:memory_first@v".to_string(),
        schedule: from_orders(
            ranks,
            2,
            m,
            v_ros.clone(),
            list_schedule(&v_split, v_stages, m, &v_ros, ranks, &Priority::memory_first()),
        ),
        flat: false,
    });
    // Chunk-major placement with the split set — interleaved's data
    // flow but wgrads free to fill bubbles.
    let cm_ros = chunkmajor_rank_of_stage(ranks, 2);
    out.push(Candidate {
        name: "heft:upward_rank@chunkmajor".to_string(),
        schedule: from_orders(
            ranks,
            2,
            m,
            cm_ros.clone(),
            list_schedule_weighted(
                &v_split,
                v_stages,
                m,
                &cm_ros,
                ranks,
                &Priority::with_table("upward_rank", v_table),
                &v_dur,
            ),
        ),
        flat: false,
    });
    out
}

/// Synthesize a schedule for `ranks × microbatches` under shape-matched
/// cost models: `flat_cost` must describe the R-stage (1-chunk) shape
/// and `chunked_cost` the 2R-stage (2-chunk) shape — the simulator
/// derives both from the same layer partition
/// (`sim::resolve_world`). Runs the portfolio, then the schedule↔LP
/// fixed point on the winner. Deterministic.
///
/// The returned schedule's no-freeze makespan is ≤ every fixed
/// schedule's under these cost models (the fixed four are candidates).
pub fn synthesize(
    flat_cost: &CostModel,
    chunked_cost: &CostModel,
    ranks: usize,
    microbatches: usize,
    r_max: f64,
    lambda: f64,
) -> SynthOutcome {
    assert!(ranks >= 1 && microbatches >= 1);
    assert_eq!(flat_cost.stages, ranks, "flat cost model must have R stages");
    assert_eq!(chunked_cost.stages, 2 * ranks, "chunked cost model must have 2R stages");

    let cands = portfolio(flat_cost, chunked_cost, ranks, microbatches);
    let mut scores = Vec::with_capacity(cands.len());
    let mut best: Option<(Schedule, bool, f64)> = None;
    for c in cands {
        let cost = if c.flat { flat_cost } else { chunked_cost };
        let span = makespan_of(&c.schedule, cost);
        scores.push(CandidateScore { name: c.name, makespan: span });
        let better = best.as_ref().map_or(true, |(_, _, b)| span < *b);
        if better {
            best = Some((c.schedule, c.flat, span));
        }
    }
    let (mut schedule, flat, mut makespan) = best.expect("portfolio is never empty");
    let cost = if flat { flat_cost } else { chunked_cost };

    // Schedule↔LP fixed point: re-rank under the frozen durations the
    // LP chose, adopt only strict no-freeze-makespan improvements.
    let mut solver = FreezeLpSolver::new();
    let mut planned = None;
    for round in 0..=FIXPOINT_ROUNDS {
        let pdag = PipelineDag::from_schedule(&schedule);
        let w_min = pdag.weights(|a| cost.bounds(a).0);
        let w_max = pdag.weights(|a| cost.bounds(a).1);
        // The DAG changes shape between rounds; drop the stale basis.
        solver.reset();
        let input = FreezeLpInput::new(&pdag, &w_min, &w_max, r_max, lambda);
        let Ok(sol) = solver.solve(&input) else { break };
        planned = Some(sol.batch_time + cost.optimizer_tail());
        if round == FIXPOINT_ROUNDS {
            break;
        }
        let frozen: BTreeMap<Action, f64> =
            pdag.index.iter().map(|(a, &i)| (*a, sol.w[i])).collect();
        let actions = schedule.all_actions();
        let frozen_dur = |a: Action| frozen[&a];
        let table =
            quantize_ranks(&upward_ranks(&actions, schedule.stages, microbatches, frozen_dur));
        let prio = Priority::with_table(format!("upward_rank:lp{round}"), table);
        let orders = list_schedule_weighted(
            &actions,
            schedule.stages,
            microbatches,
            &schedule.rank_of_stage,
            ranks,
            &prio,
            &frozen_dur,
        );
        let cand = from_orders(
            ranks,
            schedule.chunks,
            microbatches,
            schedule.rank_of_stage.clone(),
            orders,
        );
        let span = makespan_of(&cand, cost);
        scores.push(CandidateScore { name: format!("fixpoint:lp{round}"), makespan: span });
        if span < makespan * (1.0 - 1e-12) {
            schedule = cand;
            makespan = span;
        } else {
            break;
        }
    }

    SynthOutcome { schedule, makespan, planned_batch_time: planned, candidates: scores }
}

/// Uniform per-stage cost model for the default (cost-blind) build:
/// every stage costs `scale` for forward, dgrad, and wgrad alike.
fn unit_cost(stages: usize, scale: f64) -> CostModel {
    CostModel::from_stage_times(
        vec![scale; stages],
        vec![scale; stages],
        vec![scale; stages],
        vec![0.0; stages],
        vec![0.0; stages],
        0.0,
        Vec::new(),
    )
}

/// The `Schedule::build(ScheduleKind::Synthesized, …)` path: the
/// portfolio under uniform unit costs (a flat stage does 1 unit of
/// work per action kind, a V-shape stage half that), no LP refinement.
/// Cheap, deterministic, and still never worse than the fixed four
/// under the unit model.
pub(crate) fn default_build(ranks: usize, microbatches: usize) -> Schedule {
    let flat = unit_cost(ranks, 1.0);
    let chunked = unit_cost(2 * ranks, 0.5);
    let cands = portfolio(&flat, &chunked, ranks, microbatches);
    let mut best: Option<(Schedule, f64)> = None;
    for c in cands {
        let cost = if c.flat { &flat } else { &chunked };
        let span = makespan_of(&c.schedule, cost);
        if best.as_ref().map_or(true, |(_, b)| span < *b) {
            best = Some((c.schedule, span));
        }
    }
    best.expect("portfolio is never empty").0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::DEFAULT_LAMBDA;

    #[test]
    fn default_build_is_legal_and_deterministic() {
        for (ranks, m) in [(1, 1), (2, 3), (4, 8), (3, 5)] {
            let a = default_build(ranks, m);
            let b = default_build(ranks, m);
            a.check_legal().unwrap_or_else(|e| panic!("ranks={ranks} m={m}: {e}"));
            assert_eq!(a.kind, ScheduleKind::Synthesized);
            assert_eq!(a.orders, b.orders, "default synthesis must be deterministic");
            assert_eq!(a.rank_of_stage, b.rank_of_stage);
        }
    }

    #[test]
    fn synthesized_not_worse_than_fixed_under_unit_costs() {
        let (ranks, m) = (4, 8);
        let flat = unit_cost(ranks, 1.0);
        let chunked = unit_cost(2 * ranks, 0.5);
        let out = synthesize(&flat, &chunked, ranks, m, 0.6, DEFAULT_LAMBDA);
        for kind in ScheduleKind::all() {
            let chunks = Schedule::default_chunks(kind);
            let s = Schedule::build(kind, ranks, m, chunks);
            let cost = if chunks == 1 { &flat } else { &chunked };
            let fixed = makespan_of(&s, cost);
            assert!(
                out.makespan <= fixed + 1e-9,
                "synthesized {} > fixed {} ({})",
                out.makespan,
                fixed,
                kind.name()
            );
        }
        out.schedule.check_legal().unwrap();
        assert!(out.planned_batch_time.is_some());
        assert!(out.candidates.len() >= 9);
    }

    #[test]
    fn makespan_matches_fixed_schedule_rebrand() {
        // Rebranding must not change the score: the fixed:ZBV candidate
        // ties the real ZBV bit for bit.
        let chunked = unit_cost(8, 0.5);
        let zbv = Schedule::build(ScheduleKind::ZeroBubbleV, 4, 6, 2);
        let re = rebrand(zbv.clone());
        assert_eq!(makespan_of(&zbv, &chunked), makespan_of(&re, &chunked));
    }
}
