//! Zero-Bubble V schedule (ZBV; Qi et al. 2023, 2024).
//!
//! Two ideas combine here:
//! 1. **B/W split** — the backward pass is decomposed into the
//!    activation-gradient part B (must stay on the critical chain: it
//!    unblocks the upstream stage) and the parameter-gradient part W
//!    (free-floating: only the optimizer step needs it). W actions fill
//!    pipeline bubbles, driving utilization toward 100%.
//! 2. **V-shaped placement** — rank r hosts virtual stages r and
//!    2R−1−r, so the first rank holds both the first and the last model
//!    chunk; forward descends the ranks then ascends back ("V").
//!
//! TimelyFreeze interacts with ZBV precisely through the W actions: the
//! freeze ratio shrinks W durations toward zero (`w_min ≈ 0`), which is
//! why Table 1's ZBV block shows the highest freeze ratios (~70%) at
//! modest batch-time gains — W is often already off the critical path.
//!
//! The exact hand-crafted ZBV order is memory-schedule dependent; we
//! derive ours with the greedy list scheduler under zero-bubble priority
//! (B > F > W), which reproduces the qualitative structure (W-filled
//! bubbles) and is provably legal w.r.t. Appendix B rules 1–3.

use super::{list_sched, vshape_rank_of_stage, Schedule};
use crate::types::{Action, ScheduleKind};

pub fn build(ranks: usize, microbatches: usize) -> Schedule {
    let chunks = 2;
    let stages = ranks * chunks;
    let rank_of_stage = vshape_rank_of_stage(ranks);
    let mut actions = Vec::with_capacity(3 * stages * microbatches);
    for mb in 0..microbatches {
        for s in 0..stages {
            actions.push(Action::f(mb, s));
            actions.push(Action::bd(mb, s));
            actions.push(Action::bw(mb, s));
        }
    }
    let orders = list_sched::list_schedule(
        &actions,
        stages,
        microbatches,
        &rank_of_stage,
        ranks,
        &list_sched::Priority::zero_bubble(),
    );
    Schedule {
        kind: ScheduleKind::ZeroBubbleV,
        ranks,
        chunks,
        stages,
        microbatches,
        rank_of_stage,
        orders,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::ActionKind;

    #[test]
    fn paper_config_counts() {
        let s = build(4, 8);
        s.validate().unwrap();
        assert_eq!(s.stages, 8);
        // F + B + W per (stage, mb).
        assert_eq!(s.action_count(), 3 * 8 * 8);
    }

    #[test]
    fn v_placement_first_rank_has_first_and_last_stage() {
        let s = build(4, 4);
        assert_eq!(s.rank_of_stage[0], 0);
        assert_eq!(s.rank_of_stage[7], 0);
        assert_eq!(s.rank_of_stage[3], 3);
        assert_eq!(s.rank_of_stage[4], 3);
    }

    #[test]
    fn w_actions_never_precede_their_dgrad() {
        let s = build(3, 6);
        for order in &s.orders {
            for (i, a) in order.iter().enumerate() {
                if a.kind == ActionKind::BackwardWgrad {
                    let d = Action::bd(a.mb, a.stage);
                    let dpos = order.iter().position(|x| *x == d).unwrap();
                    assert!(dpos < i, "W {a} before its B");
                }
            }
        }
    }

    #[test]
    fn w_fills_tail_bubbles() {
        // With zero-bubble priority, some W actions must be scheduled
        // strictly after later-microbatch B actions (deferred W) —
        // otherwise the schedule degenerates to combined backward.
        let s = build(4, 8);
        let mut found_deferred = false;
        for order in &s.orders {
            for (i, a) in order.iter().enumerate() {
                if a.kind == ActionKind::BackwardWgrad {
                    if order[..i]
                        .iter()
                        .any(|x| x.kind == ActionKind::BackwardDgrad && x.mb > a.mb)
                    {
                        found_deferred = true;
                    }
                }
            }
        }
        assert!(found_deferred, "expected at least one deferred W action");
    }
}
